"""Trace-time sharding context.

Model code is mesh-agnostic: it calls ``shard(x, *dims)`` to attach sharding
constraints and consults ``get_ctx()`` for mesh-dependent code paths (e.g.
flash-decoding via shard_map). With no active mesh everything is a no-op, so
the same model runs single-device on CPU for smoke tests.

``dims`` vocabulary (resolved against the active mesh):
    "dp"    -> the data-parallel axes ("data",) or ("pod", "data")
    "tp"    -> the tensor-parallel axis "model"
    None    -> replicated
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class ShardCtx:
    mesh: Mesh | None = None
    dp_axes: tuple[str, ...] = ("data",)
    tp_axis: str | None = "model"
    # batch sharding disabled when global batch < |dp| (e.g. long_500k B=1)
    shard_batch: bool = True
    # sequence-parallel residual stream (shard seq over tp between blocks)
    seq_parallel: bool = True
    # FSDP "mcast" mode: explicit per-layer param gather using the paper's
    # collectives (sharding/fsdp.make_param_gather); None = XLA-inserted.
    gather_params: object = None
    # explicit compute/gather overlap: prefetch layer i+1's params while
    # computing layer i (the paper's interleaved-collectives discipline)
    prefetch_params: bool = False


_CTX: list[ShardCtx] = [ShardCtx(mesh=None)]


def get_ctx() -> ShardCtx:
    return _CTX[-1]


@contextlib.contextmanager
def use_ctx(ctx: ShardCtx):
    _CTX.append(ctx)
    try:
        yield ctx
    finally:
        _CTX.pop()


def _resolve(dim) -> object:
    c = get_ctx()
    if dim is None:
        return None
    if dim == "dp":
        return c.dp_axes if c.shard_batch else None
    if dim == "tp":
        return c.tp_axis
    if dim == "sp":  # sequence dim sharded over tp when seq_parallel
        return c.tp_axis if c.seq_parallel else None
    raise ValueError(dim)


def maybe_gather_params(tree):
    """Hook called inside layer-scan bodies: explicit FSDP gather (paper
    schedule) when active, identity otherwise (XLA auto-gather)."""
    c = get_ctx()
    if c.gather_params is None:
        return tree
    return c.gather_params(tree)


def shard(x: jax.Array, *dims) -> jax.Array:
    """with_sharding_constraint if a mesh is active; no-op otherwise."""
    c = get_ctx()
    if c.mesh is None:
        return x
    spec = P(*(_resolve(d) for d in dims))
    return jax.lax.with_sharding_constraint(x, NamedSharding(c.mesh, spec))


def spec(*dims) -> P:
    return P(*(_resolve(d) for d in dims))


def mesh_axis_size(axis: str) -> int:
    c = get_ctx()
    if c.mesh is None:
        return 1
    if axis == "dp":
        n = 1
        for a in c.dp_axes:
            n *= c.mesh.shape[a]
        return n
    return c.mesh.shape[c.tp_axis] if c.tp_axis else 1
