"""FSDP (ZeRO-3) parameter gathering with the paper's collectives.

Two modes (CollectiveConfig.fsdp_mode):

  "xla"   — parameters stay sharded (specs.py); XLA/GSPMD inserts all-gather
            before use and reduce-scatter for grads. Baseline.
  "mcast" — the paper's schedule, explicit: inside the layer scan each
            dp-sharded weight is gathered by a shard_map ppermute kernel
            (bidirectional ring = Fig. 1's two trees, or the general M-chain
            broadcast composition). The AD transpose of the gather is the
            matching ring reduce-scatter on the opposite direction, i.e. the
            Insight-2 direction split of grad traffic vs weight traffic
            falls out of the schedule for free.

On the multi-pod mesh the gather is hierarchical: ICI ring over "data" inside
the pod, then the M-chain broadcast composition over the switched "pod" axis —
the axis where the paper's multicast protocol literally applies (DESIGN.md §2).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.configs.base import CollectiveConfig, MeshConfig
from repro import compat
from repro.core import collectives as C
from repro.sharding.specs import _leaf_spec, dp_axes


def _remove_axis(entry, axis):
    if entry is None:
        return None
    if isinstance(entry, str):
        return None if entry == axis else entry
    rest = tuple(a for a in entry if a != axis)
    return rest if len(rest) > 1 else (rest[0] if rest else None)


def _ag_local(flat, axis, mode, n_chains):
    if mode == "bidi" and flat.shape[0] % 2 == 0:
        return C.bidi_ring_allgather_local(flat, axis)
    if mode == "bcast":
        return C.bcast_allgather_local(flat, axis, n_chains=n_chains)
    return C.ring_allgather_local(flat, axis)


def gather_dim(x: jax.Array, spec: P, axis: str, dim: int, mesh: Mesh,
               mode: str, n_chains: int) -> tuple[jax.Array, P]:
    """Explicitly allgather mesh axis ``axis`` out of dim ``dim`` of ``x``."""
    out_entries = list(spec) + [None] * (x.ndim - len(spec))
    out_entries[dim] = _remove_axis(out_entries[dim], axis)
    out_spec = P(*out_entries)
    p = mesh.shape[axis]

    def local(xl):
        moved = jnp.moveaxis(xl, dim, 0)
        flat = moved.reshape(-1)
        full = _ag_local(flat, axis, mode, min(n_chains, p))
        out = full.reshape((p * moved.shape[0],) + moved.shape[1:])
        return jnp.moveaxis(out, 0, dim)

    y = compat.shard_map(
        local, mesh=mesh, in_specs=spec, out_specs=out_spec, check_vma=False
    )(x)
    return y, out_spec


def gather_leaf(x: jax.Array, spec: P, mesh: Mesh, dp: tuple[str, ...],
                mode: str, n_chains: int) -> jax.Array:
    """Gather every dp-axis out of a weight slice; tp axes stay sharded.
    Hierarchical: minor (intra-pod "data") ring first, then the "pod" axis
    via the M-chain broadcast composition."""
    entries = list(spec)
    for dim, entry in enumerate(entries):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        for a in [ax for ax in reversed(dp) if ax in axes]:
            pod_axis = a == "pod"
            x, spec = gather_dim(
                x, spec, a, dim,
                mesh,
                # the switched pod axis always uses the paper's M-chain
                # broadcast-composed schedule; intra-pod uses `mode`
                "bcast" if pod_axis else mode,
                n_chains,
            )
            entries = list(spec) + [None] * (x.ndim - len(spec))
    return x


def make_param_gather(mesh: Mesh, mesh_cfg: MeshConfig,
                      coll: CollectiveConfig) -> Callable | None:
    """The ShardCtx.gather_params hook: tree-maps the explicit gather over a
    one-layer parameter slice (specs re-derived from leaf names/shapes)."""
    if coll.fsdp_mode == "xla":
        return None
    dp = dp_axes(mesh_cfg)
    mode = {"mcast": "bidi", "mcast_ring": "ring", "mcast_bcast": "bcast"}.get(
        coll.fsdp_mode, "bidi"
    )

    def gather(tree):
        def one(path, leaf):
            spec = _leaf_spec(path, leaf, mesh, dp)
            if all(e is None for e in spec):
                return leaf
            return gather_leaf(leaf, spec, mesh, dp, mode, coll.n_chains)

        return jax.tree_util.tree_map_with_path(one, tree)

    return gather


# ----------------------------------------------------- flat-bucket utilities


def flatten_bucket(tree, pad_to: int = 1):
    """Flatten a pytree into one contiguous padded fp bucket (the paper's
    collectives operate on flat byte buffers; used by benchmarks/examples)."""
    leaves, treedef = jax.tree.flatten(tree)
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    n = flat.shape[0]
    padded = -(-n // pad_to) * pad_to
    if padded != n:
        flat = jnp.pad(flat, (0, padded - n))

    shapes = [(l.shape, l.dtype) for l in leaves]

    def unflatten(buf):
        out, off = [], 0
        for shape, dtype in shapes:
            k = 1
            for s in shape:
                k *= s
            out.append(buf[off : off + k].reshape(shape).astype(dtype))
            off += k
        return jax.tree.unflatten(treedef, out)

    return flat, unflatten
