from repro.sharding.ctx import ShardCtx, get_ctx, mesh_axis_size, shard, spec, use_ctx

__all__ = ["ShardCtx", "get_ctx", "mesh_axis_size", "shard", "spec", "use_ctx"]
