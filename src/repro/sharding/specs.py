"""Parameter / batch / cache PartitionSpec rules (DP-FSDP x TP x EP x SP).

Rules are keyed by leaf name (the last path component) and apply to the
trailing dims; leading stack dims (layers L, expert E handled explicitly) get
None. Any dim that does not divide its mesh axes falls back to replicated on
that dim — uneven GSPMD sharding is legal but pads, so we only take even
shards (recorded: smollm 9-head / whisper 8-head attention is head-replicated).
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import MeshConfig, ModelConfig, ShapeConfig

Axes = Any  # str | tuple[str, ...] | None

# trailing-dims sharding rule per leaf name: "dp" = FSDP axes, "tp" = model
_IN_OUT = ("dp", "tp")     # (fan_in, fan_out) matrices
_OUT_IN = ("tp", "dp")     # (fan_out-side, fan_in-side): wo / w_down style
_RULES: dict[str, tuple] = {
    "embed": ("tp", "dp"),           # vocab x d_model
    "lm_head": ("dp", "tp"),
    "patch_proj": (None, None),
    # dense attention + mlp
    "wq": _IN_OUT, "wk": _IN_OUT, "wv": _IN_OUT, "wo": _OUT_IN,
    "w_gate": _IN_OUT, "w_up": _IN_OUT, "w_down": _OUT_IN,
    "w_in": _IN_OUT, "w_out_mlp": _OUT_IN,
    # rwkv
    "wg": _IN_OUT, "wr": _IN_OUT,
    "cm_wk": _IN_OUT, "cm_wv": _OUT_IN, "cm_wr": _IN_OUT,
    "ts_w1": ("dp", None), "ts_w2": (None, None, "dp"),
    "decay_w1": ("dp", None), "decay_w2": (None, "dp"),
    # rg-lru
    "w_gate_in": _IN_OUT, "w_rec_in": _IN_OUT,
    "lru_a_gate": _IN_OUT, "lru_x_gate": _IN_OUT,
    "conv_w": (None, "tp"),
    "lru_a_bias": ("tp",), "lru_x_bias": ("tp",), "lru_lam": ("tp",),
    "conv_b": ("tp",),
}
# MoE expert tensors carry a leading E dim sharded over tp (EP):
_MOE_RULES = {
    "w_gate": ("tp", "dp", None),
    "w_up": ("tp", "dp", None),
    "w_down": ("tp", None, "dp"),
    "router": ("dp", None),
}


def dp_axes(mesh_cfg: MeshConfig) -> tuple[str, ...]:
    return ("pod", "data") if mesh_cfg.multi_pod else ("data",)


def _axes_size(mesh: Mesh, axes: Axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _resolve_dim(dim_size: int, tag, mesh: Mesh, dp: tuple[str, ...]):
    if tag is None:
        return None
    axes = dp if tag == "dp" else "model"
    return axes if dim_size % _axes_size(mesh, axes) == 0 else None


def _leaf_spec(path: tuple, leaf, mesh: Mesh, dp: tuple[str, ...]) -> P:
    names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    name = names[-1]
    in_moe = "moe" in names and "shared" not in names
    rules = _MOE_RULES if (in_moe and name in _MOE_RULES) else _RULES
    # rwkv w_out is d_model->d_model ("wo"-like); rglru w_out is (W, D)
    if name == "w_out":
        rules = {"w_out": _OUT_IN}
    if name not in rules:
        return P()  # replicate (norms, biases, mu, bonus, small loras)
    tags = rules[name]
    nd = leaf.ndim
    k = len(tags)
    if nd < k:
        return P()
    lead = [None] * (nd - k)
    dims = [
        _resolve_dim(leaf.shape[nd - k + i], tags[i], mesh, dp) for i in range(k)
    ]
    return P(*lead, *dims)


def param_pspecs(params, mesh: Mesh, mesh_cfg: MeshConfig):
    """Pytree of PartitionSpec matching ``params`` (works on ShapeDtypeStructs)."""
    dp = dp_axes(mesh_cfg)
    return jax.tree_util.tree_map_with_path(
        lambda p, l: _leaf_spec(p, l, mesh, dp), params
    )


def param_shardings(params, mesh: Mesh, mesh_cfg: MeshConfig):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), param_pspecs(params, mesh, mesh_cfg)
    )


# ------------------------------------------------------------- batch / cache


def batch_pspecs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                 mesh_cfg: MeshConfig):
    """Input batch specs: batch dim over dp (when divisible), rest replicated."""
    from repro.models import batch_dims

    dp = dp_axes(mesh_cfg)
    bdims = batch_dims(cfg, shape)
    ndp = _axes_size(mesh, dp)
    out = {}
    for name, shp in bdims.items():
        bspec = dp if shp[0] % ndp == 0 else None
        out[name] = P(bspec, *([None] * (len(shp) - 1)))
    return out


def cache_pspecs(cfg: ModelConfig, cache, mesh: Mesh, mesh_cfg: MeshConfig,
                 seq_len: int):
    """Decode caches: batch over dp; KV sequence dim over 'model'
    (flash-decoding layout); recurrent state channels over 'model'."""
    dp = dp_axes(mesh_cfg)
    ndp = _axes_size(mesh, dp)
    tp = mesh.shape["model"]

    def spec_for(path, leaf):
        names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        name = names[-1]
        nd = leaf.ndim
        b = leaf.shape[1] if nd >= 2 else 0
        bspec = dp if (b and b % ndp == 0) else None
        if name in ("k", "v", "xk", "xv", "ks", "vs"):  # (L, B, KV, S, hd|1)
            sspec = "model" if leaf.shape[3] % tp == 0 else None
            return P(None, bspec, None, sspec, None)
        if name in ("attn_k", "attn_v"):    # (G, B, KV, W, hd) — window cache
            return P(None, bspec, None, None, None)
        if name == "wkv":                    # (L, B, H, K, V)
            hspec = "model" if leaf.shape[2] % tp == 0 else None
            return P(None, bspec, hspec, None, None)
        if name in ("tm_x", "cm_x"):         # (L, B, D)
            dspec = "model" if leaf.shape[2] % tp == 0 else None
            return P(None, bspec, dspec)
        if name == "h":                      # (G, B, W) rg-lru state
            wspec = "model" if leaf.shape[2] % tp == 0 else None
            return P(None, bspec, wspec)
        if name == "conv":                   # (G, B, K-1, W)
            wspec = "model" if leaf.shape[3] % tp == 0 else None
            return P(None, bspec, None, wspec)
        return P()

    return jax.tree_util.tree_map_with_path(spec_for, cache)
