"""Fault tolerance: checkpoint/restart supervision, failure injection,
straggler detection.

At 1000+ nodes the dominant failure mode is a lost worker: the supervisor
(a) checkpoints every K steps (async, atomic rename), (b) on failure restores
the latest checkpoint and replays the deterministic data stream from the
saved step, and (c) watches per-step wall time against an EMA to flag
stragglers (on a real fleet this triggers hot-spare swap / re-slicing; here
the hook records and optionally calls a user callback).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

from repro.checkpoint import latest_step, restore, save


class InjectedFailure(RuntimeError):
    pass


@dataclass
class FailureInjector:
    """Deterministically fail at given steps (tests) or with probability p."""
    fail_at_steps: tuple[int, ...] = ()
    seen: set = field(default_factory=set)

    def check(self, step: int):
        if step in self.fail_at_steps and step not in self.seen:
            self.seen.add(step)
            raise InjectedFailure(f"injected failure at step {step}")


@dataclass
class StragglerMonitor:
    """EMA step-time watchdog. threshold x EMA -> straggler event."""
    ema: float | None = None
    beta: float = 0.9
    threshold: float = 3.0
    events: list = field(default_factory=list)
    on_straggler: Callable[[int, float, float], None] | None = None

    def observe(self, step: int, dt: float) -> bool:
        is_straggler = self.ema is not None and dt > self.threshold * self.ema
        if is_straggler:
            self.events.append((step, dt, self.ema))
            if self.on_straggler:
                self.on_straggler(step, dt, self.ema)
        # don't poison the EMA with the straggler sample
        sample = min(dt, (self.ema or dt) * self.threshold)
        self.ema = sample if self.ema is None else self.beta * self.ema + (1 - self.beta) * sample
        return is_straggler


@dataclass
class TrainSupervisor:
    """Run the train loop with checkpoint/restart fault tolerance."""
    step_fn: Callable           # (state, batch) -> (state, metrics)
    pipeline: Any               # .next_batch(step)
    ckpt_dir: str
    ckpt_every: int = 50
    max_restarts: int = 10
    monitor: StragglerMonitor = field(default_factory=StragglerMonitor)
    injector: FailureInjector | None = None
    async_ckpt: bool = True

    def run(self, state, n_steps: int, start_step: int = 0):
        history = []
        step = start_step
        restarts = 0
        pending = None
        while step < n_steps:
            try:
                batch = self.pipeline.next_batch(step)
                if self.injector:
                    self.injector.check(step)
                t0 = time.monotonic()
                state, metrics = self.step_fn(state, batch)
                dt = time.monotonic() - t0
                self.monitor.observe(step, dt)
                history.append({"step": step, "dt": dt, **{
                    k: float(v) for k, v in metrics.items()
                }})
                step += 1
                if self.ckpt_every and step % self.ckpt_every == 0:
                    if pending is not None and not self.async_ckpt:
                        pending = None
                    pending = save(
                        state, self.ckpt_dir, step,
                        blocking=not self.async_ckpt,
                        metadata={"step": step},
                    )
            except InjectedFailure:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                last = latest_step(self.ckpt_dir)
                if last is None:
                    step = start_step
                    continue  # restart from scratch (state unchanged = rebuilt upstream)
                state, _ = restore(self.ckpt_dir, last, state)
                step = last
        if pending is not None and hasattr(pending, "result"):
            pending.result()
        return state, history
