"""Training step factory: FSDP(+TP/SP/EP) train_step with gradient
accumulation, remat, AdamW, and the paper's collective layer wired in through
ShardCtx (fsdp_mode = "xla" | "mcast" | "mcast_ring" | "mcast_bcast").
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import RunConfig
from repro.models import build_model
from repro.optim import adamw
from repro.sharding.ctx import ShardCtx, use_ctx
from repro.sharding.fsdp import make_param_gather
from repro.sharding.specs import batch_pspecs, dp_axes, param_pspecs


class TrainState(NamedTuple):
    params: Any
    opt: adamw.OptState


def _dp_size(run: RunConfig) -> int:
    n = 1
    shape = run.mesh.shape
    axes = run.mesh.axes
    for s, a in zip(shape, axes):
        if a in ("pod", "data"):
            n *= s
    return n


def make_ctx(run: RunConfig, mesh: Mesh | None, *, for_decode: bool = False) -> ShardCtx:
    if mesh is None:
        return ShardCtx(mesh=None)
    shard_batch = run.shape.global_batch % _dp_size(run) == 0
    gather = None
    if not for_decode:
        gather = make_param_gather(mesh, run.mesh, run.collective)
    return ShardCtx(
        mesh=mesh,
        dp_axes=dp_axes(run.mesh),
        tp_axis="model",
        shard_batch=shard_batch,
        seq_parallel=not for_decode,
        gather_params=gather,
        prefetch_params=run.collective.prefetch and gather is not None,
    )


def make_train_step(run: RunConfig, mesh: Mesh | None):
    """Returns (api, ctx, train_step). train_step: (state, batch) -> (state, metrics)."""
    cfg, tc = run.model, run.train
    api = build_model(cfg, remat=tc.remat)
    ctx = make_ctx(run, mesh)

    def loss_for(params, batch):
        return api.loss_fn(params, batch)

    def train_step(state: TrainState, batch):
        with use_ctx(ctx):
            params = state.params
            if tc.grad_accum > 1:
                a = tc.grad_accum

                def split(x):
                    return x.reshape((a, x.shape[0] // a) + x.shape[1:])

                micro = jax.tree.map(split, batch)

                def acc_body(carry, mb):
                    g_acc, loss_acc = carry
                    (loss, _), g = jax.value_and_grad(loss_for, has_aux=True)(
                        params, mb
                    )
                    g_acc = jax.tree.map(
                        lambda ga, gg: ga + gg.astype(jnp.float32), g_acc, g
                    )
                    return (g_acc, loss_acc + loss), None

                g0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params
                )
                (grads, loss), _ = jax.lax.scan(acc_body, (g0, jnp.zeros(())), micro)
                grads = jax.tree.map(lambda g: g / a, grads)
                loss = loss / a
                metrics = {"xent": loss}
            else:
                (loss, metrics), grads = jax.value_and_grad(loss_for, has_aux=True)(
                    params, batch
                )
            new_params, new_opt, om = adamw.apply_updates(params, grads, state.opt, tc)
            metrics = dict(metrics)
            metrics.update(om)
            metrics["loss"] = loss
        return TrainState(new_params, new_opt), metrics

    return api, ctx, train_step


def abstract_state(run: RunConfig) -> TrainState:
    """ShapeDtypeStruct state (no allocation) — dry-run / spec derivation."""
    api = build_model(run.model)
    params = jax.eval_shape(api.init_params, jax.random.PRNGKey(0))
    opt = jax.eval_shape(lambda p: adamw.init(p), params)
    return TrainState(params, opt)


def state_pspecs(run: RunConfig, mesh: Mesh):
    st = abstract_state(run)
    pspec = param_pspecs(st.params, mesh, run.mesh)
    mspec = param_pspecs(st.opt.m, mesh, run.mesh)
    return TrainState(
        pspec, adamw.OptState(m=mspec, v=mspec, step=P())
    )


def init_state(run: RunConfig, mesh: Mesh | None, rng) -> TrainState:
    """Materialize params+opt, directly sharded when a mesh is given."""
    api = build_model(run.model)

    def make(rng):
        params = api.init_params(rng)
        return TrainState(params, adamw.init(params))

    if mesh is None:
        return make(rng)
    specs = state_pspecs(run, mesh)
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                             is_leaf=lambda x: isinstance(x, P))
    return jax.jit(make, out_shardings=shardings)(rng)


def jit_train_step(run: RunConfig, mesh: Mesh):
    """Fully-specified jitted train step (used by launch/train.py and dryrun)."""
    api, ctx, step = make_train_step(run, mesh)
    specs = state_pspecs(run, mesh)
    state_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                            is_leaf=lambda x: isinstance(x, P))
    bspecs = batch_pspecs(run.model, run.shape, mesh, run.mesh)
    batch_sh = {k: NamedSharding(mesh, v) for k, v in bspecs.items()}
    return api, jax.jit(
        step,
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, None),
        donate_argnums=(0,),
    )
