"""Elastic scaling: reshard train state between meshes of different size.

The parameter sharding rules (sharding/specs.py) are pure functions of
(leaf name, shape, mesh), so moving to a grown/shrunk mesh is: compute the
target specs on the new mesh and device_put. Combined with the host-gathered
checkpoint format this supports both in-memory resharding (same job, new
topology after re-slicing) and restore-into-different-mesh (checkpoint
written on 256 chips, restored on 512).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import RunConfig
from repro.runtime.train_loop import TrainState, state_pspecs


def reshard_state(state: TrainState, run_new: RunConfig, mesh_new: Mesh) -> TrainState:
    """Re-place every leaf with the sharding the new mesh prescribes."""
    specs = state_pspecs(run_new, mesh_new)
    sh = jax.tree.map(
        lambda s: NamedSharding(mesh_new, s), specs, is_leaf=lambda x: isinstance(x, P)
    )
    return jax.tree.map(lambda x, s: jax.device_put(x, s), state, sh)


def scale_plan(old_dp: int, new_dp: int, global_batch: int) -> dict:
    """What changes when the dp extent changes: per-replica batch and the
    grad-accumulation factor that keeps the global batch constant."""
    assert global_batch % old_dp == 0
    plan = {
        "old_per_replica": global_batch // old_dp,
        "new_per_replica": global_batch // new_dp if global_batch % new_dp == 0 else None,
        "needs_accum": global_batch % new_dp != 0,
    }
    return plan
