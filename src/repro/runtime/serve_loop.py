"""Serving runtime: prefill + decode steps with sharded KV caches.

decode_32k / long_500k lower ``serve_step`` — one new token against a
seq_len-deep cache. Caches are sharded batch-over-dp and sequence-over-model
(flash-decoding, models/attention.py); recurrent-state families (rwkv,
hybrid) carry O(1)-per-token state instead.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import RunConfig
from repro.models import build_model
from repro.runtime.train_loop import make_ctx
from repro.sharding.ctx import use_ctx
from repro.sharding.specs import batch_pspecs, cache_pspecs, dp_axes, param_pspecs


class ServeState(NamedTuple):
    cache: Any
    pos: jax.Array     # (B,) next write position per sequence


def make_prefill_step(run: RunConfig, mesh: Mesh | None):
    api = build_model(run.model, remat="none")
    ctx = make_ctx(run, mesh)

    def prefill(params, batch):
        with use_ctx(ctx):
            logits, cache = api.prefill_fn(params, batch)
        return logits, cache

    return api, ctx, prefill


def make_decode_step(run: RunConfig, mesh: Mesh | None):
    """decode_step: (params, state, token) -> (next_token_logits, state)."""
    api = build_model(run.model, remat="none")
    ctx = make_ctx(run, mesh, for_decode=True)

    def decode(params, state: ServeState, token):
        with use_ctx(ctx):
            logits, cache = api.decode_fn(params, state.cache, token, state.pos)
        return logits, ServeState(cache, state.pos + 1)

    return api, ctx, decode


def abstract_cache(run: RunConfig):
    api = build_model(run.model)
    b, s = run.shape.global_batch, run.shape.seq_len
    return jax.eval_shape(lambda: api.init_cache(b, s))


def _strip_dp(spec: P, dp: tuple[str, ...]) -> P:
    def strip(e):
        if e is None:
            return None
        if isinstance(e, str):
            return None if e in dp else e
        rest = tuple(a for a in e if a not in dp)
        return rest if len(rest) > 1 else (rest[0] if rest else None)

    return P(*(strip(e) for e in spec))


def serve_shardings(run: RunConfig, mesh: Mesh):
    api = build_model(run.model)
    params = jax.eval_shape(api.init_params, jax.random.PRNGKey(0))
    cache = abstract_cache(run)
    pspec = param_pspecs(params, mesh, run.mesh)
    if run.collective.serve_params_replicated:
        # decode is otherwise collective-bound on per-token FSDP gathers;
        # replicate weights over dp (they are still TP-sharded) — §Perf knob
        dp = dp_axes(run.mesh)
        pspec = jax.tree.map(
            lambda s: _strip_dp(s, dp), pspec, is_leaf=lambda x: isinstance(x, P)
        )
    cspec = cache_pspecs(run.model, cache, mesh, run.mesh, run.shape.seq_len)
    ndp = 1
    for a in dp_axes(run.mesh):
        ndp *= mesh.shape[a]
    bspec = P(dp_axes(run.mesh)) if run.shape.global_batch % ndp == 0 else P()
    to_sh = lambda t: jax.tree.map(
        lambda s: NamedSharding(mesh, s), t, is_leaf=lambda x: isinstance(x, P)
    )
    return to_sh(pspec), to_sh(cspec), NamedSharding(mesh, bspec)


def jit_decode_step(run: RunConfig, mesh: Mesh):
    api, ctx, decode = make_decode_step(run, mesh)
    psh, csh, bsh = serve_shardings(run, mesh)
    state_sh = ServeState(csh, bsh)
    return api, jax.jit(
        decode,
        in_shardings=(psh, state_sh, bsh),
        out_shardings=(None, state_sh),
        donate_argnums=(1,),
    )


def jit_prefill_step(run: RunConfig, mesh: Mesh):
    api, ctx, prefill = make_prefill_step(run, mesh)
    psh, csh, _ = serve_shardings(run, mesh)
    bspecs = batch_pspecs(run.model, run.shape, mesh, run.mesh)
    bsh = {k: NamedSharding(mesh, v) for k, v in bspecs.items()}
    return api, jax.jit(
        prefill, in_shardings=(psh, bsh), out_shardings=(None, csh)
    )


def greedy_generate(api, params, prompt_tokens, max_new: int, cache_len: int):
    """Simple single-host generation driver (examples/serve.py)."""
    b, s = prompt_tokens.shape
    cache = api.init_cache(b, cache_len)
    state = ServeState(cache, jnp.zeros((b,), jnp.int32))
    decode = jax.jit(
        lambda p, st, t: (
            lambda lg, c: (jnp.argmax(lg, -1).astype(jnp.int32), ServeState(c, st.pos + 1))
        )(*api.decode_fn(p, st.cache, t, st.pos))
    )
    tok = prompt_tokens[:, 0]
    out = [tok]
    for t in range(1, s + max_new):
        nxt, state = decode(params, state, tok)
        tok = prompt_tokens[:, t] if t < s else nxt
        out.append(tok)
    return jnp.stack(out, axis=1)
