from repro.runtime.train_loop import (
    TrainState,
    abstract_state,
    init_state,
    jit_train_step,
    make_ctx,
    make_train_step,
    state_pspecs,
)

__all__ = [
    "TrainState",
    "abstract_state",
    "init_state",
    "jit_train_step",
    "make_ctx",
    "make_train_step",
    "state_pspecs",
]
