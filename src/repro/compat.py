"""jax API compatibility shims — one place absorbing upstream renames.

shard_map: promoted from jax.experimental.shard_map (<=0.4.x, flag name
check_rep) to jax.shard_map (flag renamed check_vma). axis_size: added to
jax.lax after 0.4.x; older jax exposes the concrete size via core.axis_frame.
Every call site in the repo goes through these wrappers so either jax works.
"""
from __future__ import annotations

import inspect

import jax
from jax import lax


def axis_size(axis_name) -> int:
    """Concrete size of a mapped axis inside shard_map/pmap."""
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_name)
    frame = jax.core.axis_frame(axis_name)   # 0.4.x: int (or frame object)
    return getattr(frame, "size", frame)

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map

# the public promotion and the check_rep->check_vma rename shipped in
# different releases — feature-detect the kwarg instead of inferring it
_CHECK_KW = (
    "check_vma"
    if "check_vma" in inspect.signature(_shard_map).parameters
    else "check_rep"
)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_CHECK_KW: check_vma})
