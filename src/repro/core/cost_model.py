"""Analytic cost models from the paper.

  - Traffic of P2P vs multicast Broadcast/Allgather on a fat-tree (Fig. 2),
    computed exactly by routing over ``core.topology.FatTree`` and counting
    per-link bytes (the software analogue of Fig. 12's switch counters).
  - routed_ring_allgather: the same P2P ring schedule pushed through the
    fluid engine as routed flows — time AND per-link bytes from one run,
    the baseline the fabric_sweep benchmark compares multicast against.
  - The concurrent-{AG,RS} speedup S = 2 - 2/P (Appendix B).
  - Constant-time Broadcast schedule times (Fig. 10/11 throughput models):
    pipelined multicast vs k-nomial / binary trees / ring.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.engine import Engine, FabricParams
from repro.core.topology import FatTree


# ------------------------------------------------------------- traffic (Fig 2)


def p2p_ring_allgather_traffic(tree: FatTree, p: int, nbytes: int) -> int:
    """Ring allgather: P-1 rounds; at round t, rank i sends shard (i-t) to i+1.
    Every rank sends (P-1) * (N/P) bytes to its ring neighbor."""
    tree.reset()
    shard = nbytes // p
    for step in range(p - 1):
        for src in range(p):
            tree.unicast(src, (src + 1) % p, shard)
    return tree.counters.total()


def p2p_knomial_bcast_traffic(tree: FatTree, p: int, nbytes: int, k: int = 4) -> int:
    """k-nomial tree broadcast from rank 0: each holder forwards to k-1 new
    ranks per round."""
    tree.reset()
    have = [0]
    while len(have) < p:
        new = []
        for h in have:
            for j in range(1, k):
                t = h + j * len(have)
                if t < p:
                    tree.unicast(h, t, nbytes)
                    new.append(t)
        have += new
    return tree.counters.total()


def p2p_linear_allgather_traffic(tree: FatTree, p: int, nbytes: int) -> int:
    """Linear (direct) allgather: every rank sends its shard to P-1 peers."""
    tree.reset()
    shard = nbytes // p
    for src in range(p):
        for dst in range(p):
            if dst != src:
                tree.unicast(src, dst, shard)
    return tree.counters.total()


def p2p_ring_pipeline_bcast_traffic(tree: FatTree, p: int, nbytes: int) -> int:
    """Segment-pipelined ring broadcast (locality-friendly P2P baseline):
    every rank forwards the full buffer to its ring neighbour once."""
    tree.reset()
    for src in range(p - 1):
        tree.unicast(src, src + 1, nbytes)
    return tree.counters.total()


def mcast_bcast_traffic(tree: FatTree, p: int, nbytes: int, root: int = 0) -> int:
    tree.reset()
    tree.multicast(root, list(range(p)), nbytes)
    return tree.counters.total()


def mcast_allgather_traffic(tree: FatTree, p: int, nbytes: int) -> int:
    """Composition of broadcasts: every rank multicasts its shard once; every
    byte crosses every tree link exactly once (Insight 1)."""
    tree.reset()
    shard = nbytes // p
    members = list(range(p))
    for root in range(p):
        tree.multicast(root, members, shard)
    return tree.counters.total()


# ------------------------------------- routed-engine baselines (fabric sweep)


def routed_ring_allgather(topology, p: int, nbytes: int,
                          fabric: FabricParams | None = None,
                          hosts=None) -> tuple[float, dict[str, float]]:
    """The P2P ring allgather as ROUTED fluid flows: one flow per ring
    neighbor pair carrying the whole collective's forwarding traffic
    (P-1 rounds x N/P bytes), traversing the real up-down ECMP path. Returns
    (completion_time, per-link bytes) from the same engine run — per-link
    bytes are identical to the static p2p_ring_allgather_traffic pass for the
    same schedule, but here ECMP collisions between neighbor routes actually
    cost time. Completion adds the P-1 per-round activation latencies the
    ring serializes on (multicast pays only its constant sync — Fig. 11)."""
    fabric = fabric or FabricParams()
    hosts = list(hosts) if hosts is not None else list(range(p))
    assert len(hosts) == p, (len(hosts), p)
    topology.reset()
    eng = Engine()
    shard = nbytes // p
    flows = [
        eng.submit_route(topology.route(hosts[i], hosts[(i + 1) % p]),
                         (p - 1) * shard, tag="ring")
        for i in range(p)
    ]
    t = eng.run()
    assert all(f.done for f in flows)
    return t + (p - 1) * fabric.latency, eng.link_bytes()


# ------------------------------------------------- Appendix B: speedup S(P)


def concurrent_ag_rs_speedup(p: int) -> float:
    """S = T_{ring,ring} / T_{mc,inc} = 2 - 2/P."""
    return 2.0 - 2.0 / p


@dataclass(frozen=True)
class NicShare:
    """NIC direction bandwidth shares for concurrently running AG+RS."""
    ag_send: float
    ag_recv: float
    rs_send: float
    rs_recv: float


def ring_ring_share() -> NicShare:
    # ring AG and ring RS each need both directions equally (Insight 2)
    return NicShare(0.5, 0.5, 0.5, 0.5)


def mc_inc_share(p: int) -> NicShare:
    # AG_mc is receive-bound, RS_inc is send-bound -> no shared bottleneck
    return NicShare(1.0 / p, 1.0 - 1.0 / p, 1.0 - 1.0 / p, 1.0 / p)


def concurrent_completion_time(n: int, p: int, b_nic: float, mode: str) -> float:
    """Completion time of {AG, RS} issued concurrently; N = per-rank AG send
    buffer (= RS receive shard). Both must move N*(P-1) bytes through the
    bottleneck path."""
    if mode == "ring_ring":
        share = ring_ring_share()
        return n * (p - 1) / (share.ag_recv * b_nic)
    if mode == "mc_inc":
        share = mc_inc_share(p)
        return n * (p - 1) / (share.ag_recv * b_nic)
    raise ValueError(mode)


# -------------------------------------------- Broadcast schedule-time models


def bcast_time_multicast(n: int, b_link: float, p: int, mtu: int = 4096,
                         alpha: float = 5e-6) -> float:
    """Constant-time pipelined multicast broadcast: the switch fans out, so
    T ~ N/B + sync overhead (independent of P for fixed N)."""
    return n / b_link + alpha * 2  # RNR barrier + final handshake, amortized


def bcast_time_binary_tree(n: int, b_link: float, p: int,
                           alpha: float = 5e-6) -> float:
    """Non-pipelined binary-tree broadcast (store-and-forward per level):
    depth x N/B — the 4.75x-slower baseline of Fig. 11."""
    import math

    depth = math.ceil(math.log2(max(p, 2)))
    return depth * (n / b_link + alpha)


def bcast_time_knomial(n: int, b_link: float, p: int, k: int = 2,
                       seg: int = 1 << 15, alpha: float = 1.5e-6) -> float:
    """Segment-pipelined k-nomial broadcast (the UCC large-message scheme):
    bandwidth-bound at (k-1) x N/B plus per-segment posting overhead and the
    pipeline fill — the ~1.3x baseline of Fig. 11."""
    import math

    depth = math.ceil(math.log(max(p, 2), max(k, 2)))
    n_segs = max(n // seg, 1)
    return (k - 1) * n / b_link + depth * (seg / b_link + alpha) + n_segs * alpha


def allgather_time_ring(n: int, b_link: float, p: int, alpha: float = 5e-6) -> float:
    """Receive-bound optimum: (P-1)/P * N_total / B, N_total = N*P."""
    return n * (p - 1) / b_link + (p - 1) * alpha


def allgather_time_multicast(n: int, b_link: float, p: int, m_chains: int | None = None,
                             alpha: float = 5e-6) -> float:
    """Multicast allgather is receive-bound by N*(P-1) bytes arriving on the
    one receive path — same bound as ring (paper: "such alignment is
    expected"), but with ~P x less send-path traffic."""
    return n * (p - 1) / b_link + 2 * alpha


# ------------------------------------------------------ torus adaptation notes


def torus_ring_per_link_bytes(p: int, nbytes: int, *, bidi: bool) -> float:
    """Per-link bytes of (bi)directional ring allgather on a torus ring:
    the torus 'bandwidth-optimal' criterion (DESIGN.md §2): each byte crosses
    each link once per direction used."""
    shard = nbytes / p
    per_dir = shard * (p - 1)
    return per_dir / (2 if bidi else 1)
