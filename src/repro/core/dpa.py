"""Datapath Accelerator (DPA) offload model (paper §II-C, §VI-C, Table I).

Hardware: 16 RISC-V cores @ 1.8 GHz, 16 HW threads/core (256 contexts),
1.5 MB LLC, interfaced with the NIC DMA engine. The receive datapath is
low-IPC data movement (Table I: IPC ~ 0.1), so hardware multithreading hides
load/store latency and throughput scales near-linearly in threads until the
link saturates.

Calibration (provenance in comments):
  - Table I single-thread: UD 5.2 GiB/s (1084 cyc/CQE), UC 11.9 GiB/s (598).
  - Fig 13/14: UC saturates 200 Gbit/s at ~4 threads, UD at 8-16.
  - Fig 16: 64 B chunks, 128 threads sustain the 1.6 Tbit/s arrival rate.
  - Fig 5 / §VII-d: one server CPU core sustains only ~1/2-2/3 of 200 Gbit/s.
"""
from __future__ import annotations

from dataclasses import dataclass

GIB = 1 << 30

DPA_CORES = 16
DPA_THREADS_PER_CORE = 16
DPA_FREQ_HZ = 1.8e9
DPA_LLC_BYTES = 1.5e6

LINK_200G_BYTES = 200e9 / 8
LINK_1600G_BYTES = 1600e9 / 8

# Table I (measured on BF-3, 8 MiB receive buffer, 4 KiB chunks)
TABLE1 = {
    "UD": {"tput_gib": 5.2, "instr_per_cqe": 113, "cycles_per_cqe": 1084, "ipc": 0.1},
    "UC": {"tput_gib": 11.9, "instr_per_cqe": 66, "cycles_per_cqe": 598, "ipc": 0.11},
}

# Within-core multithread scaling exponent (latency hiding with shared core
# resources), calibrated so UC saturates 200G at ~4 threads and UD at 8-16
# (Figs 13/14). Across cores the scaling is linear, with each core's datapath
# capped at its 200 Gbit/s NIC-engine interface rate — which is exactly why
# 8 cores (128 threads) sustain the 1.6 Tbit/s arrival rate of Fig 16.
MT_SCALING_EXP = 0.55
CORE_CAP_CHUNKS_PER_S = LINK_200G_BYTES / 4096.0

# single server-CPU-core receive datapaths (Fig 5; 2.6 GHz AMD Epyc):
# UD + segmentation/reassembly + software reliability (UCX) and a custom
# RC-chunked engine without the reliability layer. Neither reaches 200 Gbit/s.
CPU_CORE_TPUT_GIB = {"UD_reliability": 9.0, "RC_no_reliability": 18.6}
CPU_FREQ_HZ = 2.6e9

# ---- event-engine calibration (core/dpa_engine.py) -------------------------
# Within-core memory-contention slope of the EVENT-level engine: a thread's
# stalled-on-memory cycles inflate by (1 + slope * (T-1)) when T contexts
# share the core's LLC ports / load-store queue. Calibrated against the same
# anchors as MT_SCALING_EXP — T=1 lands exactly on the Table-I throughput,
# UC saturates 200G at ~4 threads, UD within 8-16 (Figs 13/14) — but through
# the latency-hiding *mechanism* (stalls overlap other threads' compute)
# instead of the closed-form T^e envelope. The two curves agree at the
# anchors and diverge mid-range (DESIGN.md §7 records the deviation).
MEM_CONTENTION = {"UD": 0.17, "UC": 0.35}

# Stall inflation once outstanding chunk state spills the 1.5 MB LLC
# (staging descriptors + bitmap words fall out to DRAM; §III-D keeps
# communicator state LLC-resident precisely to avoid this).
LLC_MISS_PENALTY = 1.6

REF_CHUNK_BYTES = 4096   # Table I was measured at 4 KiB chunks


def cqe_service_cycles(transport: str, *, freq_hz: float = DPA_FREQ_HZ,
                       ref_chunk: int = REF_CHUNK_BYTES) -> tuple[float, float]:
    """(compute_cycles, stall_cycles) per CQE for the event engine.

    The TOTAL wall cycles per CQE are anchored on the Table-I throughput
    (freq * chunk / tput — the measured cycles_per_cqe column undercounts
    queueing outside the core, so the throughput anchor wins), and the
    compute share is the measured instruction fraction instr/cycles = IPC:
    at IPC ~ 0.1 a thread spends ~90% of its CQE stalled on data movement,
    which is exactly the budget hardware multithreading can hide."""
    row = TABLE1[transport]
    total = freq_hz * ref_chunk / (row["tput_gib"] * GIB)
    compute = total * row["instr_per_cqe"] / row["cycles_per_cqe"]
    return compute, total - compute


def host_cqe_service_cycles(datapath: str = "UD_reliability", *,
                            freq_hz: float = CPU_FREQ_HZ,
                            ref_chunk: int = REF_CHUNK_BYTES,
                            ) -> tuple[float, float]:
    """Host-CPU baseline per-CQE cycles (Fig 5 anchors): one Epyc-class core
    running the receive datapath in software. No hardware thread contexts —
    the stall cycles are real wall time, nothing hides them."""
    total = freq_hz * ref_chunk / (CPU_CORE_TPUT_GIB[datapath] * GIB)
    # same measured instruction fraction as the UD DPA datapath: the work is
    # the same; the host just cannot overlap the stalls
    frac = TABLE1["UD"]["instr_per_cqe"] / TABLE1["UD"]["cycles_per_cqe"]
    return total * frac, total * (1.0 - frac)


@dataclass(frozen=True)
class DpaConfig:
    transport: str = "UD"            # UD | UC
    n_threads: int = 1
    chunk_bytes: int = 4096
    link_bytes_per_s: float = LINK_200G_BYTES


def single_thread_tput(transport: str) -> float:
    """Bytes/s, 4 KiB chunks (Table I)."""
    return TABLE1[transport]["tput_gib"] * GIB


def chunk_rate_per_thread(transport: str) -> float:
    """Chunks/s per thread: per-CQE cost dominates, independent of payload for
    small chunks (the Fig 16 projection rests on this)."""
    return single_thread_tput(transport) / 4096.0


def thread_scaling(n_threads: int) -> float:
    return float(n_threads) ** MT_SCALING_EXP


def _pool_chunk_rate(transport: str, n_threads: int) -> float:
    """Chunks/s of a compactly-placed thread pool (§VI-C: fill core 1, then
    core 2, ...): within-core T^e latency-hiding, per-core NIC-interface cap,
    linear across cores."""
    r1 = chunk_rate_per_thread(transport)
    full_cores, rem = divmod(n_threads, DPA_THREADS_PER_CORE)
    per_full = min(r1 * thread_scaling(DPA_THREADS_PER_CORE), CORE_CAP_CHUNKS_PER_S)
    rate = full_cores * per_full
    if rem:
        rate += min(r1 * thread_scaling(rem), CORE_CAP_CHUNKS_PER_S)
    return rate


def pool_tput(cfg: DpaConfig) -> float:
    """Uncapped processing capacity of the thread pool (bytes/s): the leaf
    service rate the discrete-event engine consumes (core/engine.py). Link
    capping belongs to the fabric model, not the worker pool."""
    return _pool_chunk_rate(cfg.transport, cfg.n_threads) * cfg.chunk_bytes


def sustained_tput(cfg: DpaConfig) -> float:
    """Bytes/s the receive datapath sustains (Fig 13/14/15 model).

    Processing is CQE-bound: rate = chunk_rate * chunk_bytes, capped by link.
    Larger UC chunks (multi-packet RDMA writes) raise bytes-per-CQE (Fig 15).
    """
    return min(pool_tput(cfg), cfg.link_bytes_per_s)


def nack_rate(cfg: DpaConfig) -> float:
    """NACK messages/s the DPA progress engine sustains (core/packet.py
    recovery rounds): NACK handling is CQE-bound exactly like the data
    path (Table I), so the pool's chunk rate is its NACK rate — with
    in-tree aggregation the root serves O(1) NACKs/round, which is why the
    recovery engine stays flat as P grows."""
    return _pool_chunk_rate(cfg.transport, cfg.n_threads)


def sustained_chunk_rate(cfg: DpaConfig) -> float:
    """Chunks/s (Fig 16: compare against the arrival rate of a Tbit/s link)."""
    return min(
        _pool_chunk_rate(cfg.transport, cfg.n_threads),
        cfg.link_bytes_per_s / max(cfg.chunk_bytes, 1),
    )


def threads_to_saturate(transport: str, link_bytes_per_s: float = LINK_200G_BYTES,
                        chunk_bytes: int = 4096) -> int:
    for t in range(1, DPA_CORES * DPA_THREADS_PER_CORE + 1):
        if sustained_tput(DpaConfig(transport, t, chunk_bytes, link_bytes_per_s)) >= (
            link_bytes_per_s * 0.99
        ):
            return t
    return DPA_CORES * DPA_THREADS_PER_CORE


def link_chunk_arrival_rate(link_bytes_per_s: float, mtu: int = 4096) -> float:
    """MTU-sized packets/s at 100% utilization (§VII-a)."""
    return link_bytes_per_s / mtu


def tbit_feasible(transport: str = "UD", n_threads: int = 128) -> bool:
    """§VII-a: can half the DPA sustain a 1.6 Tbit/s chunk arrival rate?
    (Modeled with 64 B chunks to match the arrival rate of 4 KiB MTU at 1.6T.)"""
    rate = sustained_chunk_rate(
        DpaConfig(transport, n_threads, chunk_bytes=64,
                  link_bytes_per_s=LINK_1600G_BYTES)
    )
    return rate >= link_chunk_arrival_rate(LINK_1600G_BYTES, 4096)


def economics_summary() -> dict:
    """§VII-d: SuperPOD node: 2x 54-core Xeon vs 4x CX-7 NICs with DPA."""
    cores_per_100g = 1.0
    links_gbit = 4 * 1600
    cpu_cores_needed = links_gbit / 100 * cores_per_100g * 2  # both directions
    return {
        "cpu_cores_needed_4x1600g": cpu_cores_needed,
        "nic_cost_ratio": 1 / 2.5,   # NICs ~2.5x cheaper than the CPUs
        "nic_energy_ratio": 1 / 7.0, # ~7x lower energy
    }
