"""Throughput-optimal schedule search over the Collective Schedule IR.

Since PR 5 the repo can *lower* any Multicast/Unicast/Reduce op-DAG to
analytic/fluid/packet fidelity, but it could only *execute* schedules a
human wrote. This module closes the loop (ForestColl, arXiv:2402.06787:
throughput-optimal schedules are constructible from the fabric's cut
structure): given a collective and a ``Topology``, it

  1. seeds the search with every in-tree builder (schedule.py/sched_ir
     builders become seed points — the searcher can only match or beat
     them),
  2. derives extra candidates from the fabric's structure: chain counts M
     from the per-tier bottleneck cuts (``topology.bottleneck_cuts`` /
     ``tier_capacities``), ring-vs-multicast transport for the AG leg,
     RS∘AG chunk-granularity pipelining via extra Activation edges
     (``build_pipelined_allreduce``), and — on tiered island fabrics
     (topology.IslandFatTree) — hierarchical mixed-transport allgathers
     (``build_hierarchical_allgather``) mutated by island-grouping and
     per-op transport-flip moves seeded from ``tier_capacities()``
     (``hier_candidates``),
  3. scores candidates with ``sched_ir.execute`` at fluid fidelity through
     a memoized evaluation cache (keyed on the schedule's canonical
     content hash + the evaluation context), pruned branch-and-bound
     style: a candidate whose admissible lower bound — the
     ``protocol.analytic_*`` closed form, maxed with the fabric-cut bound
     bytes-across-cut / cut-capacity — already exceeds the incumbent is
     cut without simulation,
  4. validates the winner at packet fidelity (loss-recovery converges,
     exactly-once delivery is enforced inside the packet engine) and
     reports a ``protocol.BoundCertificate`` with the winner-time / bound
     ratio.

``sched_ir.autotune_chains`` is the trivial 1-D special case: it delegates
to ``sweep_chains`` here and shares the same evaluation cache, so
benchmarks stop re-simulating identical schedules.

Why the bounds are admissible:

* analytic closed forms: every host must ingest the collective's bytes
  through its NIC at the slower of wire and worker-pool rate; on a
  topology the per-host attach capacity is at most the fastest tier, so
  the closed form evaluated at ``b = max(tier_capacities)`` lower-bounds
  the topology-fluid time too (latency terms only grow with multi-hop
  paths).
* fabric cuts: in the fluid max-min model the aggregate rate across a cut
  never exceeds the sum of its link capacities, so (bytes that must cross
  the cut) / (cut capacity) lower-bounds completion time. Multicasts are
  counted once per crossing (in-network duplication could deliver a group
  with a single traversal), which undercounts the routed lowering —
  conservative, hence admissible.
* pipelined allreduce: ``protocol.pipeline_schedule_time`` is monotone in
  every stage time, so feeding it per-segment analytic lower bounds yields
  a lower bound of the pipelined execution (one shared recurrence between
  the executor and the bound).
"""
from __future__ import annotations

import json
import math
import os
import time as _time
from dataclasses import astuple, dataclass

import numpy as np

from repro.core import protocol, sched_ir
from repro.core.engine import FabricParams, WorkerParams
from repro.core.sched_ir import Multicast, Reduce, Schedule, Unicast

COLLECTIVES = ("broadcast", "allgather", "reduce_scatter", "allreduce")

# RS∘AG pipelining depths tried for derived allreduce candidates.
SEGMENT_CANDIDATES = (2, 4, 8)


# ------------------------------------------------------------ eval context


def _topology_key(topology):
    if topology is None:
        return None
    sig = getattr(topology, "signature", None)
    # shape-identical topologies share cache entries; anything without a
    # signature() is keyed by identity (deterministic: evaluate() resets it)
    return sig() if sig is not None else ("id", id(topology))


@dataclass(frozen=True)
class EvalContext:
    """Everything besides the schedule itself that determines a fluid
    evaluation's outcome — the second half of the cache key."""
    fabric: FabricParams
    workers: WorkerParams
    topology: object = None
    hosts: tuple | None = None
    fidelity: str = "fluid"
    seed: int = 0

    def key(self) -> tuple:
        return (astuple(self.fabric), astuple(self.workers),
                _topology_key(self.topology), self.hosts, self.fidelity,
                self.seed)


@dataclass
class EvalResult:
    time: float
    fabric_bytes: float          # routed bytes (sum of link_bytes on a
                                 # topology; payload bytes otherwise)


def _evaluate_uncached(sched: Schedule, ctx: EvalContext) -> EvalResult:
    """One fluid/analytic evaluation, no memoization — the unit of work the
    cache memoizes and the search process pool ships to workers."""
    if ctx.topology is not None:
        ctx.topology.reset()
    if ctx.fidelity == "analytic":
        res = sched_ir.execute(sched, ctx.fabric, ctx.workers,
                               fidelity="analytic")
        return EvalResult(time=float(res),
                          fabric_bytes=sched_ir.payload_bytes(sched))
    res = sched_ir.execute(
        sched, ctx.fabric, ctx.workers,
        np.random.default_rng(ctx.seed), fidelity=ctx.fidelity,
        topology=ctx.topology,
        hosts=list(ctx.hosts) if ctx.hosts is not None else None)
    if ctx.topology is not None and res.link_bytes:
        fabric_bytes = float(sum(res.link_bytes.values()))
    else:
        fabric_bytes = sched_ir.payload_bytes(sched)
    return EvalResult(time=res.time, fabric_bytes=fabric_bytes)


def _key_persistable(key: tuple) -> bool:
    """Disk-persistable cache keys only: a topology keyed by object identity
    (no ``signature()``) is process-local, so its entries never leave RAM."""
    topo_key = key[1][2]
    return not (isinstance(topo_key, tuple) and len(topo_key) == 2
                and topo_key[0] == "id")


class EvalCache:
    """Memoized schedule evaluations keyed on (canonical schedule hash,
    context key). Shared between search(), sweep_chains() and
    sched_ir.autotune_chains so repeated sweeps over the same fabric never
    re-simulate a schedule.

    With ``path=`` the cache is *content-addressed on disk* too: entries
    load on construction (a disk hit counts toward ``hits`` like any other)
    and ``save()`` writes them back atomically, keyed by
    ``repr((canonical_key, ctx.key()))`` — repr of the float/str/tuple key
    is deterministic, so runs in different processes address the same
    entries. Identity-keyed topology entries (``("id", ...)`` — no
    ``signature()``) are never persisted: ids are process-local.
    ``search()``/``sweep_chains()`` save automatically on completion, so
    repeated benchmark/CI runs and ``autotune_chains`` reuse scores across
    processes."""

    def __init__(self, path: str | None = None) -> None:
        self._store: dict[tuple, EvalResult] = {}
        self._bounds: dict[tuple, tuple[float, str]] = {}
        self.hits = 0
        self.misses = 0
        self.path = path
        self._disk: dict[str, list] = {}
        self._disk_bounds: dict[str, list] = {}
        if path and os.path.exists(path):
            try:
                with open(path) as f:
                    payload = json.load(f)
                assert payload.get("version") == 1, payload.get("version")
                self._disk = payload["entries"]
                self._disk_bounds = payload.get("bounds", {})
            except (OSError, ValueError, KeyError, AssertionError):
                self._disk = {}    # corrupt/foreign file: start cold
                self._disk_bounds = {}

    @classmethod
    def persistent(cls) -> "EvalCache":
        """A cache at ``$REPRO_EVAL_CACHE`` (in-memory only when unset) —
        the hook CI nightlies use to carry scores across runs."""
        return cls(os.environ.get("REPRO_EVAL_CACHE") or None)

    def __len__(self) -> int:
        return len(self._store)

    def evaluate(self, sched: Schedule, ctx: EvalContext) -> EvalResult:
        key = (sched_ir.canonical_key(sched), ctx.key())
        got = self._store.get(key)
        if got is None and self._disk:
            row = self._disk.get(repr(key))
            if row is not None:
                got = EvalResult(time=row[0], fabric_bytes=row[1])
                self._store[key] = got
        if got is not None:
            self.hits += 1
            return got
        self.misses += 1
        out = _evaluate_uncached(sched, ctx)
        self._store[key] = out
        return out

    def bound(self, sched: Schedule, ctx: EvalContext) -> tuple[float, str]:
        """Memoized ``lower_bound`` — the bound is a pure function of
        (schedule content, context), so warm searches skip the analytic
        executor entirely. Persisted alongside the evaluations (same
        identity-key exclusion)."""
        key = (sched_ir.canonical_key(sched), ctx.key())
        got = self._bounds.get(key)
        if got is None and self._disk_bounds:
            row = self._disk_bounds.get(repr(key))
            if row is not None:
                got = (row[0], row[1])
                self._bounds[key] = got
        if got is None:
            got = lower_bound(sched, ctx)
            self._bounds[key] = got
        return got

    def save(self) -> None:
        """Atomically persist the persistable entries (no-op without a
        path). Merges over what is already on disk, so concurrent sweeps
        only ever add entries."""
        if not self.path:
            return
        entries = dict(self._disk)
        entries.update({
            repr(k): [r.time, r.fabric_bytes]
            for k, r in self._store.items() if _key_persistable(k)})
        bounds = dict(self._disk_bounds)
        bounds.update({
            repr(k): [b, binding]
            for k, (b, binding) in self._bounds.items()
            if _key_persistable(k)})
        tmp = f"{self.path}.tmp.{os.getpid()}"
        os.makedirs(os.path.dirname(os.path.abspath(self.path)),
                    exist_ok=True)
        with open(tmp, "w") as f:
            json.dump({"version": 1, "entries": entries, "bounds": bounds}, f)
        os.replace(tmp, self.path)
        self._disk = entries
        self._disk_bounds = bounds


# ------------------------------------------------------------ lower bounds


def cut_lower_bound(sched: Schedule, topology, hosts=None) -> float:
    """max over bottleneck cuts of bytes-that-must-cross / cut-capacity.
    A true fluid-model lower bound (see module docstring); returns 0.0 when
    the topology exposes no cuts."""
    cuts = getattr(topology, "bottleneck_cuts", None)
    if cuts is None:
        return 0.0
    host_of = list(hosts) if hosts is not None else list(range(sched.p))
    best = 0.0
    for cut in cuts():
        inside = cut.hosts
        b_in = b_out = 0.0
        for op in sched.ops:
            if isinstance(op, Multicast):
                root_in = host_of[op.root] in inside
                memb = [host_of[r] in inside for r in op.group
                        if r != op.root]
                if not root_in and any(memb):
                    b_in += op.nbytes
                if root_in and not all(memb):
                    b_out += op.nbytes
            elif isinstance(op, Unicast):
                src_in = host_of[op.src] in inside
                dst_in = host_of[op.dst] in inside
                if not src_in and dst_in:
                    b_in += op.nbytes
                elif src_in and not dst_in:
                    b_out += op.nbytes
            elif isinstance(op, Reduce):
                dst_in = host_of[op.dst] in inside
                for s in op.srcs:
                    src_in = host_of[s] in inside
                    if not src_in and dst_in:
                        b_in += op.nbytes
                    elif src_in and not dst_in:
                        b_out += op.nbytes
        if cut.cap_in > 0:
            best = max(best, b_in / cut.cap_in)
        if cut.cap_out > 0:
            best = max(best, b_out / cut.cap_out)
    return best


def lower_bound(sched: Schedule, ctx: EvalContext) -> tuple[float, str]:
    """Admissible lower bound on ``sched``'s fluid time in ``ctx``; returns
    (bound, binding) where binding names the binding constraint
    ("analytic" or "cut:<name-of-tier>"). Tiered hier_allgather schedules
    get the tiered closed form (protocol.analytic_hier_allgather_time):
    the stripe term at the switched-tier host attach, the island-ring term
    at the fastest tier capacity — both upper bounds on the respective
    phase's ingest rate, so the form stays admissible per phase."""
    fabric = ctx.fabric
    binding = "analytic"
    tier_caps: dict[str, float] = {}
    if ctx.topology is not None:
        tiers = getattr(ctx.topology, "tier_capacities", None)
        tier_caps = tiers() if tiers is not None else {}
    if sched.kind == "hier_allgather":
        w = ctx.workers
        # switched attach for phase B ("host" NIC on island fabrics);
        # fastest tier for the phase-C ring hop — each generous, hence safe
        b_stripe = tier_caps.get("host", max(tier_caps.values())
                                 if tier_caps else fabric.b_link)
        b_ring = max(tier_caps.values()) if tier_caps else fabric.b_link
        bound = protocol.analytic_hier_allgather_time(
            sched.p, sched.n_bytes, b_stripe, fabric.latency,
            island_size=sched.meta["island_size"], m=sched.meta.get("m"),
            stripe_mode=sched.meta["stripe_mode"],
            pool_rate=w.n_recv_workers * w.thread_tput,
            rnr_hop=w.rnr_barrier_hop, b_island=b_ring)
        if ctx.topology is not None:
            cut = cut_lower_bound(sched, ctx.topology, ctx.hosts)
            if cut > bound:
                bound, binding = cut, "cut"
        return bound, binding
    if ctx.topology is not None:
        # the closed forms assume a single NIC at b_link; on a fabric a
        # host's attach capacity is its boundary cut (a Torus2D node has 4
        # incident links -> 4x one link's rate). Evaluate at the
        # representative single-host cut's capacity — an upper bound on
        # ingest rate for these tier-symmetric fabrics, so the closed form
        # stays a lower bound — falling back to the fastest tier.
        b_eff = None
        cuts_fn = getattr(ctx.topology, "bottleneck_cuts", None)
        if cuts_fn is not None:
            solo = [max(c.cap_in, c.cap_out) for c in cuts_fn()
                    if len(c.hosts) == 1]
            if solo:
                b_eff = max(solo)
        if b_eff is None:
            tiers = getattr(ctx.topology, "tier_capacities", None)
            caps = tiers() if tiers is not None else {}
            b_eff = max(caps.values()) if caps else None
        if b_eff is not None:
            from dataclasses import replace
            fabric = replace(fabric, b_link=b_eff)
    bound = sched_ir.execute(sched, fabric, ctx.workers, fidelity="analytic")
    if ctx.topology is not None:
        cut = cut_lower_bound(sched, ctx.topology, ctx.hosts)
        if cut > bound:
            bound, binding = cut, "cut"
    return bound, binding


# ------------------------------------------------------- candidate space


@dataclass(frozen=True)
class Candidate:
    name: str
    sched: Schedule
    origin: str                  # "builder" (seed) or "derived"


def chain_candidates(p: int, topology=None) -> list[int]:
    """Chain counts M to sweep: divisors of P (the autotune_chains default)
    plus cut-structure-derived suggestions — on an oversubscribed fabric
    the tier-capacity ratio says roughly how many concurrent chains the
    thin tier can carry, so P/ratio (and its neighbours) join the set."""
    ms = {m for m in range(1, p + 1) if p % m == 0}
    if topology is not None:
        tiers = getattr(topology, "tier_capacities", None)
        if tiers is not None:
            caps = tiers()
            if caps and min(caps.values()) > 0:
                ratio = max(caps.values()) / min(caps.values())
                m_star = max(1, min(p, round(p / ratio)))
                ms.update(x for x in (m_star, m_star + 1, max(1, m_star - 1))
                          if 1 <= x <= p)
    return sorted(ms)


def hier_candidates(p: int, n_bytes: int, topology=None, *,
                    fanout_moves: bool = True) -> list[Candidate]:
    """Tiered-fabric allgather candidates: on a topology exposing islands
    (``island_size``), seed the canonical hierarchical builder (the fabric's
    own island grouping, one chain per stripe) and derive the searcher's
    mutation moves around it —

      island-grouping: regroup into sub-islands g' | island_size (a smaller
        ring still rides the island-tier cables; g' = island_size is the
        physical grouping),
      chain-count: M per stripe seeded from ``tier_capacities()`` (the
        island/switched capacity ratio says how many switched chains the
        stripe NICs carry), plus the M=1 / full-parallel endpoints,
      fan-out/depth mutations: halve/double the chain fan-out around M*
        (M chains per generation is the activation tree's fan-out; the
        chain depth R = ceil(I/M) moves inversely), probing the incast
        knee the capacity-ratio seed can straddle — disable with
        ``fanout_moves=False`` (the never-worsened regression pin),
      transport flips: stripe multicast -> routed unicast ring
        (stripe_mode="ring") and island redistribution -> back over the
        switched tier (redistribute_transport="switched").
    """
    g0 = getattr(topology, "island_size", None)
    if g0 is None or p % g0 != 0 or p // g0 < 2:
        return []
    caps = topology.tier_capacities()
    ratio = (max(caps.values()) / min(caps.values())
             if caps and min(caps.values()) > 0 else 1.0)
    out: list[Candidate] = []
    for g in (d for d in range(2, g0 + 1) if g0 % d == 0):
        n_islands = p // g
        m_star = max(1, min(n_islands, round(n_islands / ratio)))
        base_ms = sorted({1, m_star, n_islands})
        for i, m in enumerate(base_ms):
            out.append(Candidate(
                f"{'builder' if (i == 0 and g == g0) else 'derived'}"
                f":hier[g={g},m={m}]",
                sched_ir.build_hierarchical_allgather(p, n_bytes, g, m),
                "builder" if (i == 0 and g == g0) else "derived"))
        if fanout_moves:
            for m in sorted({max(1, m_star // 2),
                             min(n_islands, 2 * m_star)} - set(base_ms)):
                out.append(Candidate(
                    f"derived:hier[g={g},m={m},fanout]",
                    sched_ir.build_hierarchical_allgather(p, n_bytes, g, m),
                    "derived"))
        out.append(Candidate(
            f"derived:hier[g={g},ring-stripe]",
            sched_ir.build_hierarchical_allgather(p, n_bytes, g,
                                                  stripe_mode="ring"),
            "derived"))
        out.append(Candidate(
            f"derived:hier[g={g},m={m_star},switched-redist]",
            sched_ir.build_hierarchical_allgather(
                p, n_bytes, g, m_star, redistribute_transport="switched"),
            "derived"))
    return out


def candidates(collective: str, p: int, n_bytes: int,
               topology=None) -> list[Candidate]:
    """The search space: builder seeds first (force-evaluated so the
    incumbent equals the best hand-written schedule before any pruning),
    then derived candidates."""
    assert collective in COLLECTIVES, collective
    out: list[Candidate] = []
    if collective == "broadcast":
        out.append(Candidate("builder:tree",
                             sched_ir.build_broadcast_tree(p, n_bytes),
                             "builder"))
        return out
    if collective == "reduce_scatter":
        out.append(Candidate("builder:ring",
                             sched_ir.build_ring_reduce_scatter(p, n_bytes),
                             "builder"))
        return out
    ms = chain_candidates(p, topology)
    if collective == "allgather":
        out.append(Candidate("builder:ring",
                             sched_ir.build_ring_allgather(p, n_bytes),
                             "builder"))
        for m in ms:
            origin = "builder" if p % m == 0 else "derived"
            out.append(Candidate(f"{origin}:mcast[m={m}]",
                                 sched_ir.build_allgather(p, n_bytes, m),
                                 origin))
        out += hier_candidates(p, n_bytes, topology)
        return out
    # allreduce: barrier builders (ring AG and every M-chain AG), then the
    # derived segment-pipelined schedules (extra Activation edges let
    # segment s+1's RS overlap segment s's AG)
    out.append(Candidate("builder:rs+ring_ag",
                         sched_ir.build_allreduce(p, n_bytes, None),
                         "builder"))
    builder_ms = [m for m in ms if p % m == 0]
    for m in builder_ms:
        out.append(Candidate(f"builder:rs+mcast_ag[m={m}]",
                             sched_ir.build_allreduce(p, n_bytes, m),
                             "builder"))
    # pipelined candidates sweep segments x a TRIMMED chain grid ({ring,
    # full-parallel, cut-derived}) — the full divisor grid already ran as
    # barrier seeds, and each pipelined eval costs n_segments engine runs,
    # so the 2-D product must stay small to hold the P<=64 wall budget
    cut_ms = sorted(set(ms) - set(m for m in ms if p % m == 0))
    seg_ms = [None, p] + [m for m in (p // 2,) if p % 2 == 0 and p // 2 >= 1] \
        + cut_ms
    seg_ms = list(dict.fromkeys(seg_ms))
    for s in SEGMENT_CANDIDATES:
        if s > max(n_bytes // max(p, 1), 1):
            continue
        for m in seg_ms:
            tag = f"m={m}" if m else "ring"
            out.append(Candidate(
                f"derived:pipelined[S={s},{tag}]",
                sched_ir.build_pipelined_allreduce(p, n_bytes, m,
                                                   n_segments=s),
                "derived"))
    return out


# ------------------------------------------------------------- the search


@dataclass
class CandidateReport:
    name: str
    origin: str
    bound: float
    time: float | None           # None -> pruned without simulation
    fabric_bytes: float | None


@dataclass
class SearchResult:
    collective: str
    p: int
    n_bytes: int
    winner: Candidate
    winner_time: float
    winner_fabric_bytes: float
    best_builder: Candidate
    best_builder_time: float
    best_builder_fabric_bytes: float
    certificate: protocol.BoundCertificate
    table: list[CandidateReport]
    evaluations: int
    cache_hits: int
    pruned: int
    wall_s: float
    packet_validated: bool | None = None

    @property
    def searched_vs_best_builder(self) -> float:
        return self.winner_time / self.best_builder_time


def _packet_converged(res) -> bool:
    """Walk a packet-fidelity result for convergence: every component that
    reports a ``completed`` flag (broadcast runs, allgather legs, pipelined
    segments) must have delivered everything within the round budget."""
    ok = True
    seen = False
    for attr in ("completed",):
        if hasattr(res, attr):
            ok &= bool(getattr(res, attr))
            seen = True
    for attr in ("rs", "ag", "stripe", "ring"):
        sub = getattr(res, attr, None)
        if sub is not None:
            sub_ok = _packet_converged(sub)
            ok &= sub_ok
            seen = True
    for pair in getattr(res, "segments", ()) or ():
        for sub in pair:
            ok &= _packet_converged(sub)
            seen = True
    return ok if seen else math.isfinite(res.time)


def _prefetch_parallel(scored, n_seeds, incumbent_time, ctx, cache,
                       n_jobs: int) -> dict[tuple, EvalResult]:
    """Evaluate not-yet-cached derived candidates concurrently in a fork
    process pool, gated by incumbent broadcast: candidates go out in
    ascending-bound order and a candidate is only dispatched while its
    bound still beats the best time any completed worker has reported
    (seed incumbent included). Returns {cache key: result} for the replay
    loop — which stays bitwise identical to the serial search because the
    prefetched results are injected exactly where a serial evaluation
    would have happened. Pickling failures degrade to an empty prefetch
    (the replay loop just evaluates serially)."""
    import multiprocessing as mp
    from concurrent.futures import FIRST_COMPLETED, ProcessPoolExecutor, wait

    todo = []
    queued: set[tuple] = set()
    for bound, _binding, cand in scored[n_seeds:]:
        if bound >= incumbent_time:
            break                          # sorted: the rest prune too
        key = (sched_ir.canonical_key(cand.sched), ctx.key())
        if key not in queued and cache._store.get(key) is None \
                and (not cache._disk or repr(key) not in cache._disk):
            queued.add(key)
            todo.append((bound, key, cand))
    prefetched: dict[tuple, EvalResult] = {}
    if not todo:
        return prefetched
    try:
        with ProcessPoolExecutor(
                max_workers=min(n_jobs, len(todo)),
                mp_context=mp.get_context("fork")) as pool:
            best_seen = incumbent_time
            pending: dict = {}
            i = 0
            while i < len(todo) or pending:
                while i < len(todo) and len(pending) < n_jobs:
                    bound, key, cand = todo[i]
                    i += 1
                    if bound >= best_seen:
                        continue           # incumbent broadcast: stale bound
                    fut = pool.submit(_evaluate_uncached, cand.sched, ctx)
                    pending[fut] = key
                if not pending:
                    continue
                done, _ = wait(pending, return_when=FIRST_COMPLETED)
                for fut in done:
                    key = pending.pop(fut)
                    res = fut.result()
                    prefetched[key] = res
                    best_seen = min(best_seen, res.time)
    except (TypeError, AttributeError, OSError, ImportError):
        return {}                          # unpicklable schedule/topology
    return prefetched


def search(collective: str, p: int, n_bytes: int, *, topology=None,
           hosts=None, fabric: FabricParams | None = None,
           workers: WorkerParams | None = None, cache: EvalCache | None = None,
           seed: int = 0, validate_packet: bool = True,
           loss=None, n_jobs: int | None = None) -> SearchResult:
    """Branch-and-bound schedule search (module docstring). Builder seeds
    are force-evaluated to establish the incumbent; derived candidates are
    visited in ascending bound order and pruned when their admissible lower
    bound already meets the incumbent. The winner is re-validated at packet
    fidelity (optionally under ``loss``).

    ``n_jobs`` > 1 turns on the parallel tier: derived candidates that the
    seed incumbent cannot prune are *prefetched* in a fork process pool
    (with incumbent-broadcast dispatch gating), then the serial loop
    replays over the prefetched results — the SearchResult is bitwise
    identical to ``n_jobs=1`` by construction, parallelism only moves
    wall-clock. Defaults to ``$REPRO_SEARCH_WORKERS`` else serial (the
    gated benchmark ratios stay machine-independent)."""
    t0 = _time.perf_counter()
    fabric = fabric or FabricParams(jitter=0.0)
    workers = workers or WorkerParams(n_recv_workers=8)
    cache = cache if cache is not None else EvalCache()
    if n_jobs is None:
        n_jobs = int(os.environ.get("REPRO_SEARCH_WORKERS", "0") or 0)
    ctx = EvalContext(fabric, workers, topology,
                      tuple(hosts) if hosts is not None else None,
                      "fluid", seed)
    pool = candidates(collective, p, n_bytes, topology)
    for cand in pool:
        sched_ir.validate(cand.sched)

    hits0 = cache.hits
    table: list[CandidateReport] = []
    incumbent: Candidate | None = None
    incumbent_time = math.inf
    incumbent_bytes = math.inf
    best_builder: Candidate | None = None
    best_builder_time = math.inf
    best_builder_bytes = math.inf
    evaluations = pruned = 0
    min_bound = math.inf
    min_binding = "analytic"

    seeds = [c for c in pool if c.origin == "builder"]
    derived = [c for c in pool if c.origin != "builder"]

    scored: list[tuple[float, str, Candidate]] = []
    for cand in seeds + derived:
        bound, binding = cache.bound(cand.sched, ctx)
        if bound < min_bound:
            min_bound, min_binding = bound, binding
        scored.append((bound, binding, cand))
    n_seeds = len(seeds)
    # seeds keep submission order (all run); derived sorted by bound so the
    # most promising run first and tighten the incumbent for pruning
    scored[n_seeds:] = sorted(scored[n_seeds:], key=lambda t: t[0])

    prefetched: dict[tuple, EvalResult] = {}

    def _eval(cand: Candidate) -> EvalResult:
        # replay shim: a prefetched result lands exactly where the serial
        # loop would have evaluated — same miss accounting, same store
        nonlocal evaluations
        evaluations += 1
        key = (sched_ir.canonical_key(cand.sched), ctx.key())
        res = prefetched.pop(key, None)
        if res is not None and key not in cache._store:
            cache.misses += 1
            cache._store[key] = res
            return res
        return cache.evaluate(cand.sched, ctx)

    for i, (bound, binding, cand) in enumerate(scored):
        is_seed = i < n_seeds
        if i == n_seeds and n_jobs > 1:
            # seeds fixed the incumbent: fan the survivors out to workers
            prefetched = _prefetch_parallel(scored, n_seeds, incumbent_time,
                                            ctx, cache, n_jobs)
        if not is_seed and bound >= incumbent_time:
            pruned += 1
            table.append(CandidateReport(cand.name, cand.origin, bound,
                                         None, None))
            continue
        res = _eval(cand)
        table.append(CandidateReport(cand.name, cand.origin, bound,
                                     res.time, res.fabric_bytes))
        if is_seed and (res.time, res.fabric_bytes) < (best_builder_time,
                                                       best_builder_bytes):
            best_builder, best_builder_time, best_builder_bytes = \
                cand, res.time, res.fabric_bytes
        if (res.time, res.fabric_bytes) < (incumbent_time, incumbent_bytes):
            incumbent, incumbent_time, incumbent_bytes = \
                cand, res.time, res.fabric_bytes

    assert incumbent is not None and best_builder is not None
    cert = protocol.BoundCertificate(
        kind=collective, p=p, n_bytes=n_bytes, bound=min_bound,
        winner_time=incumbent_time, binding=min_binding)

    packet_ok: bool | None = None
    if validate_packet:
        # every stock fabric resolves packet leaf paths via topology.host()
        # (supports_packet=True); a custom fabric that opts out falls back
        # to validating loss-recovery convergence on the abstract fabric
        pkt_topo = topology if getattr(topology, "supports_packet",
                                       topology is not None) else None
        if pkt_topo is not None:
            pkt_topo.reset()
        pres = sched_ir.execute(
            incumbent.sched, fabric, workers, np.random.default_rng(seed),
            fidelity="packet", topology=pkt_topo,
            hosts=list(hosts) if pkt_topo is not None and hosts is not None
            else None, loss=loss)
        packet_ok = _packet_converged(pres) and math.isfinite(pres.time)

    cache.save()
    return SearchResult(
        collective=collective, p=p, n_bytes=n_bytes,
        winner=incumbent, winner_time=incumbent_time,
        winner_fabric_bytes=incumbent_bytes,
        best_builder=best_builder, best_builder_time=best_builder_time,
        best_builder_fabric_bytes=best_builder_bytes,
        certificate=cert, table=table, evaluations=evaluations,
        cache_hits=cache.hits - hits0, pruned=pruned,
        wall_s=_time.perf_counter() - t0, packet_validated=packet_ok)


# -------------------------------------------- the 1-D special case (M sweep)


def sweep_chains(schedule_builder, topology=None, *, p: int, n_bytes: int,
                 fabric: FabricParams, workers: WorkerParams,
                 candidates, fidelity: str = "fluid", seed: int = 0,
                 cache: EvalCache | None = None) -> tuple[int, dict[int, float]]:
    """The trivial 1-D slice of the searcher: sweep the chain count M for
    ``schedule_builder(p, n_bytes, m)`` through the shared memoized cache
    and return (argmin, the full {m: time} sweep). Backs
    ``sched_ir.autotune_chains``."""
    cache = cache if cache is not None else EvalCache()
    ctx = EvalContext(fabric, workers, topology, None, fidelity, seed)
    times: dict[int, float] = {}
    for m in candidates:
        times[m] = cache.evaluate(schedule_builder(p, n_bytes, m), ctx).time
    best = min(times, key=lambda m: (times[m], m))
    cache.save()
    return best, times
