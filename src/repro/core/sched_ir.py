"""Collective Schedule IR: one schedule graph, every fidelity.

The paper's core contribution is an Allgather *schedule* — a round-robin
composition of reliable Broadcasts (§IV-A, Appendix A). This module makes
that schedule the system's central representation instead of rank arithmetic
scattered across the engines: a ``Schedule`` is an explicit DAG of typed
communication ops

  Multicast(root, group, nbytes)   switch-replicated stream root -> group
  Unicast(src, dst, nbytes)        point-to-point stream (RC transport)
  Reduce(dst, srcs, nbytes, op)    payloads combined (op) on the way to dst:
                                   a ring step is a single-source edge, an
                                   in-network aggregation tree reduces every
                                   source on the way up

connected by *Activation* edges — the §IV-A chain signal ("when I finish
multicasting I activate my chain successor") promoted to a first-class DAG
edge. Builders construct schedules from the Appendix-A math in
core/schedule.py (uneven chains included); ``execute()`` lowers ANY schedule
onto the chosen fidelity:

  fidelity="fluid"    the max-min fluid engine (core/engine.py), abstract
                      NIC links or a routed core/topology.py fabric
  fidelity="packet"   the MTU-granular reliable-multicast protocol engine
                      (core/packet.py machinery) with per-Link loss, NACK
                      aggregation and retransmission rounds; the DPA itself
                      has scalar/event fidelities (``dpa_fidelity=``)
  fidelity="analytic" the closed-form oracle (core/protocol.py analytic_*);
                      returns a float time, the lower bound the property
                      tests hold the engines against

The legacy entry points (simulator.simulate_broadcast/simulate_allgather,
packet.simulate_packet_allgather, engine.simulate_fsdp_step's flow
construction) are thin facades over these builders + executors and reproduce
the pre-IR results exactly at loss 0 (pinned by tests/test_sched_ir.py).

Schedule generations are derived from the Activation DAG (topological
layering), so "round r" is not a convention of the executor but a property
of the graph; the §IV-A chain semantics is per-chain, while the engine
lowerings apply the (slightly conservative) round-barrier execution the
pre-IR engines used: a generation starts when the whole previous generation
delivered.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import numpy as np

from repro.core import profiling, protocol
from repro.core import schedule as seq
from repro.core.engine import (
    Engine,
    FabricParams,
    WorkerParams,
    staging_rnr_mask,
    worker_pool_completion,
    worker_pool_completion_rows,
)

FIDELITIES = ("analytic", "fluid", "packet")
KINDS = ("broadcast", "allgather", "ring_allgather", "reduce_scatter",
         "allreduce", "hier_allgather", "fsdp_step")

#: per-op transport tags (topology.LINK_TIERS plus None = "let the fabric
#: route"). An op tagged "island" must stay inside one island and rides the
#: NVLink/ICI ring; "switched" forces the fat-tree even for island-local
#: pairs. Multicast is switched-only — islands have no switch replication.
TRANSPORTS = (None, "intra_host", "island", "switched")


# -------------------------------------------------------------- shared pieces
# (moved here from simulator.py so every lowering — fluid, packet, ring —
# shares one definition; simulator.py re-exports them for compatibility)


@dataclass
class PhaseBreakdown:
    rnr_sync: float = 0.0
    multicast: float = 0.0
    reliability: float = 0.0
    handshake: float = 0.0

    def total(self) -> float:
        return self.rnr_sync + self.multicast + self.reliability + self.handshake


@dataclass
class BcastResult:
    completion: np.ndarray            # per-leaf completion time (s)
    phases: PhaseBreakdown
    delivered_fast: int
    recovered: int
    rnr_drops: int
    bytes_fast: int
    bytes_recovery: int
    bytes_total: int                  # conservation: fast + recovery == total
    link_bytes: dict[str, float] = field(default_factory=dict)
    # ^ routed mode only: live per-fabric-link bytes from the same engine run

    @property
    def time(self) -> float:
        return float(self.completion.max(initial=0.0))


@dataclass
class AllgatherResult:
    time: float
    phases: PhaseBreakdown
    recovered: int
    bytes_fast: int
    bytes_recovery: int
    bytes_total: int
    per_rank_recv_tput: float         # (P-1)*N / time  (Fig. 11 metric)
    link_bytes: dict[str, float] = field(default_factory=dict)
    # ^ routed mode only: live per-fabric-link bytes from the same engine run


def _chunking(n_bytes: int, mtu: int) -> tuple[int, int]:
    n_chunks = max(-(-n_bytes // mtu), 1)
    chunk = min(mtu, n_bytes) if n_bytes else mtu
    return n_chunks, chunk


def _rnr_barrier(p: int, fabric: FabricParams, workers: WorkerParams) -> float:
    # RNR barrier: recursive doubling (§V-A)
    rounds = int(np.ceil(np.log2(max(p, 2))))
    return rounds * (fabric.latency + workers.rnr_barrier_hop)


# ------------------------------------------------------------------- the ops


@dataclass(frozen=True)
class Multicast:
    """Switch-replicated stream: ``root`` sends ``nbytes`` once, every other
    member of ``group`` receives it (Insight 1). ``transport`` pins the op
    to a fabric tier on tiered topologies (switched-only for multicast)."""
    root: int
    group: tuple[int, ...]
    nbytes: float
    transport: str | None = None

    @property
    def receivers(self) -> tuple[int, ...]:
        return tuple(x for x in self.group if x != self.root)

    @property
    def payload_bytes(self) -> float:
        """Receiver-side payload this op delivers (wire-conservation unit)."""
        return self.nbytes * len(self.receivers)

    def ranks(self):
        return self.group


@dataclass(frozen=True)
class Unicast:
    """Point-to-point stream on reliable (RC) transport. ``transport`` pins
    the op to a fabric tier on tiered topologies ("island" asserts src and
    dst share an island)."""
    src: int
    dst: int
    nbytes: float
    transport: str | None = None

    @property
    def payload_bytes(self) -> float:
        return self.nbytes

    def ranks(self):
        return (self.src, self.dst)


@dataclass(frozen=True)
class Reduce:
    """Reduction op: each source's payload is combined (``op``) on its edge
    toward ``dst``. A ring reduce-scatter step is a single-source edge; an
    in-network aggregation tree (Insight 2's RS_inc) reduces every source on
    the way up, so ``dst`` receives only the combined ``nbytes``."""
    dst: int
    srcs: tuple[int, ...]
    nbytes: float
    op: str = "sum"
    transport: str | None = None

    @property
    def payload_bytes(self) -> float:
        # receiver-side, like Multicast: the sources' contributions are
        # combined in-network, so dst receives only the reduced nbytes
        return self.nbytes

    def ranks(self):
        return (self.dst, *self.srcs)


Op = Multicast | Unicast | Reduce


@dataclass(frozen=True)
class Schedule:
    """A collective as an explicit op DAG. ``activation`` edges (i, j) are
    op-index pairs: op j may start only after op i completed (the §IV-A
    chain signal, phase barriers, prefetch chains)."""
    kind: str
    p: int
    n_bytes: int                       # per-rank payload of the collective
    ops: tuple[Op, ...]
    activation: tuple[tuple[int, int], ...] = ()
    meta: dict = field(default_factory=dict)

    def rounds(self) -> list[list[int]]:
        """Topological generations of the activation DAG (ASAP layering):
        generation g holds every op whose longest activation chain from a
        source has length g. Raises on a cycle — acyclicity is the IR's
        structural invariant."""
        n = len(self.ops)
        succs: list[list[int]] = [[] for _ in range(n)]
        indeg = [0] * n
        for a, b in self.activation:
            succs[a].append(b)
            indeg[b] += 1
        gen = [0] * n
        q = deque(i for i in range(n) if indeg[i] == 0)
        seen = 0
        while q:
            i = q.popleft()
            seen += 1
            for j in succs[i]:
                gen[j] = max(gen[j], gen[i] + 1)
                indeg[j] -= 1
                if indeg[j] == 0:
                    q.append(j)
        assert seen == n, "activation edges must form a DAG"
        out: list[list[int]] = [[] for _ in range(max(gen, default=-1) + 1)]
        for i in range(n):
            out[gen[i]].append(i)
        return out

    @property
    def n_rounds(self) -> int:
        return len(self.rounds())


def payload_bytes(sched: Schedule) -> float:
    """Receiver-side payload the whole schedule delivers — the builder-side
    conservation quantity the executor byte counters are tested against."""
    return sum(op.payload_bytes for op in sched.ops)


#: meta keys that change what the executor runs (everything else in meta is
#: derived bookkeeping or nested sub-schedules already covered by the op DAG)
_CANONICAL_META = ("n_chains", "m", "n_segments", "policy", "n_layers",
                   "layer_bytes", "island_size", "stripe_mode",
                   "redistribute_transport")


def canonical_key(sched: Schedule) -> str:
    """Stable content hash of a schedule: kind, shape, the full typed op
    DAG and the executor-relevant meta scalars. Two schedules with equal
    keys lower to identical runs at every fidelity, so the search layer
    (core/sched_search.py) uses this as the memoized-evaluation cache key —
    e.g. ``build_allreduce(p, n)`` and ``build_pipelined_allreduce(p, n,
    n_segments=1)`` hash differently only if their DAGs or meta differ.
    Memoized per object (Schedule is frozen): the searcher hashes the same
    candidate for bound lookup, evaluation and prefetch keying."""
    import hashlib

    memo = getattr(sched, "_canonical_memo", None)
    if memo is not None:
        return memo

    parts: list = [sched.kind, sched.p, sched.n_bytes]
    for op in sched.ops:
        if isinstance(op, Multicast):
            parts.append(("M", op.root, op.group, op.nbytes, op.transport))
        elif isinstance(op, Unicast):
            parts.append(("U", op.src, op.dst, op.nbytes, op.transport))
        else:
            parts.append(("R", op.dst, op.srcs, op.nbytes, op.op,
                          op.transport))
    parts.append(tuple(sorted(sched.activation)))
    parts.append(tuple((k, sched.meta[k]) for k in _CANONICAL_META
                       if k in sched.meta))
    key = hashlib.blake2b(repr(parts).encode(), digest_size=16).hexdigest()
    object.__setattr__(sched, "_canonical_memo", key)
    return key


def validate(sched: Schedule) -> None:
    """Structural invariants every builder must satisfy."""
    assert sched.kind in KINDS, sched.kind
    n = len(sched.ops)
    for op in sched.ops:
        for r in op.ranks():
            assert 0 <= r < sched.p, (op, sched.p)
        assert op.nbytes >= 0, op
        assert op.transport in TRANSPORTS, op
        if isinstance(op, Multicast):
            assert op.transport in (None, "switched"), \
                (op, "multicast exists only on the switched tier")
    for a, b in sched.activation:
        assert 0 <= a < n and 0 <= b < n and a != b, (a, b)
    rounds = sched.rounds()            # raises on cycle
    targets = {b for _, b in sched.activation}
    for g, idxs in enumerate(rounds[1:], start=1):
        assert any(i in targets for i in idxs), \
            f"generation {g} has no activation predecessor"
    if sched.kind == "allgather":
        roots = [op.root for op in sched.ops]
        assert sorted(roots) == list(range(sched.p)), \
            "every rank must broadcast exactly once"
        m = sched.meta["n_chains"]
        for r, idxs in enumerate(rounds):
            assert tuple(sched.ops[i].root for i in idxs) == \
                seq.active_group(r, sched.p, m), (r, m)
    if sched.kind == "hier_allgather":
        g = sched.meta["island_size"]
        assert g >= 2 and sched.p % g == 0 and sched.p // g >= 2, \
            (sched.p, g)
        for op in sched.ops:
            if isinstance(op, Multicast):
                # phase B stripe multicast: the root's stripe peers only
                # (one member per island), over the switched tier
                assert op.transport == "switched", op
                assert set(op.group) == {x for x in range(sched.p)
                                         if x % g == op.root % g}, (op, g)
            else:
                assert isinstance(op, Unicast), op
                if op.transport == "island":
                    assert op.src // g == op.dst // g, \
                        (op, g, "island op must stay inside one island")


# ------------------------------------------------------------------ builders


def build_broadcast_tree(p: int, n_bytes: int, root: int = 0) -> Schedule:
    """One reliable Broadcast: a single Multicast op rooted at ``root``."""
    return Schedule("broadcast", p, n_bytes,
                    (Multicast(root, tuple(range(p)), n_bytes),))


def build_allgather(p: int, n_bytes: int, m: int = 1) -> Schedule:
    """The paper's Allgather (Appendix A): R = ceil(P/M) generations of up
    to M concurrent Multicasts; the §IV-A chain activation signal becomes
    explicit edges between each chain member and its successor. Uneven
    chains (M not dividing P) are supported — the last chains are shorter
    and the final generations activate fewer roots."""
    group = tuple(range(p))
    ops: list[Op] = []
    op_of_root: dict[int, int] = {}
    for st in seq.allgather_schedule(p, m):
        for root in st.roots:
            op_of_root[root] = len(ops)
            ops.append(Multicast(root, group, n_bytes))
    act = tuple((op_of_root[f], op_of_root[t])
                for f, t in seq.activation_edges(p, m))
    return Schedule("allgather", p, n_bytes, tuple(ops), act,
                    meta={"n_chains": m})


def build_ring_allgather(p: int, n_bytes: int) -> Schedule:
    """Classical ring Allgather: P-1 generations, each rank forwarding the
    shard it just received to its right neighbour (RC unicasts)."""
    ops: list[Op] = []
    act: list[tuple[int, int]] = []
    idx: dict[tuple[int, int], int] = {}
    for s in range(p - 1):
        for i in range(p):
            idx[(s, i)] = len(ops)
            ops.append(Unicast(i, (i + 1) % p, n_bytes))
        if s:
            act += [(idx[(s - 1, (i - 1) % p)], idx[(s, i)])
                    for i in range(p)]
    return Schedule("ring_allgather", p, n_bytes, tuple(ops), tuple(act))


def build_hierarchical_allgather(p: int, n_bytes: int, island_size: int,
                                 m: int = 1, *, stripe_mode: str = "mcast",
                                 redistribute_transport: str = "island"
                                 ) -> Schedule:
    """FlexLink-style tiered allgather (arXiv:2510.15882) for island fabrics
    (topology.IslandFatTree): hosts are grouped into islands of
    ``island_size`` (= g), giving I = P/g islands, and *stripe* r is the set
    of ranks {j*g + r} — one member per island. Two phases:

      B (switched tier): each stripe runs the paper's M-chain multicast
        allgather among its I members over the fat-tree — every NIC ingests
        only (I-1)*N instead of (P-1)*N, the full multicast win at 1/g the
        fan-in. ``stripe_mode="ring"`` flips the stripe legs to routed
        unicast rings (the searcher's multicast<->unicast transport move).
      C (island tier): after its stripe completes, every rank holds its
        stripe's full I*N bundle; g-1 island-ring generations rotate the g
        distinct bundles inside each island (classical ring allgather with
        bundle-sized shards) on ``redistribute_transport`` ("island" = the
        NVLink/ICI ring; "switched" is the searcher's flip back onto the
        fat-tree).

    meta carries the two phase sub-schedules (``stripe_ag``, one stripe's
    template; ``island_ring``, the phase-C ring over all P ranks) the
    composite executor lowers, exactly like build_allreduce's rs/ag pair."""
    assert stripe_mode in ("mcast", "ring"), stripe_mode
    assert redistribute_transport in ("island", "switched"), \
        redistribute_transport
    g = island_size
    assert g >= 2 and p % g == 0, (p, g, "islands must tile the ranks")
    n_islands = p // g
    assert n_islands >= 2, (p, g, "need at least two islands")
    if stripe_mode == "mcast":
        stripe_tpl = build_allgather(n_islands, n_bytes, m)
    else:
        stripe_tpl = build_ring_allgather(n_islands, n_bytes)
        m = None
    tpl_rounds = stripe_tpl.rounds()
    ops: list[Op] = []
    act: list[tuple[int, int]] = []
    stripe_last: list[list[int]] = []  # per stripe: last-generation op idxs
    for r in range(g):
        members = tuple(j * g + r for j in range(n_islands))
        off = len(ops)
        for op in stripe_tpl.ops:
            if isinstance(op, Multicast):
                ops.append(Multicast(members[op.root],
                                     tuple(members[x] for x in op.group),
                                     op.nbytes, transport="switched"))
            else:
                ops.append(Unicast(members[op.src], members[op.dst],
                                   op.nbytes, transport="switched"))
        act += [(a + off, b + off) for a, b in stripe_tpl.activation]
        stripe_last.append([i + off for i in tpl_rounds[-1]])
    bundle = n_islands * n_bytes
    ring_ops: list[Op] = []
    ring_act: list[tuple[int, int]] = []
    off = len(ops)
    idx: dict[tuple[int, int], int] = {}
    for s in range(g - 1):
        for i in range(p):
            base = (i // g) * g
            idx[(s, i)] = len(ring_ops)
            ring_ops.append(Unicast(i, base + (i - base + 1) % g, bundle,
                                    transport=redistribute_transport))
        if s:
            ring_act += [(idx[(s - 1, (i // g) * g + (i % g - 1) % g)],
                          idx[(s, i)]) for i in range(p)]
    ops += ring_ops
    act += [(a + off, b + off) for a, b in ring_act]
    # phase barrier per stripe: rank i's redistribution starts once its OWN
    # stripe's last generation delivered (stripe of rank i is i % g)
    for i in range(p):
        act += [(a, off + idx[(0, i)]) for a in stripe_last[i % g]]
    island_ring = Schedule("ring_allgather", p, bundle, tuple(ring_ops),
                           tuple(ring_act))
    return Schedule("hier_allgather", p, n_bytes, tuple(ops), tuple(act),
                    meta={"island_size": g, "m": m,
                          "stripe_mode": stripe_mode,
                          "redistribute_transport": redistribute_transport,
                          "bundle_bytes": bundle,
                          "stripe_ag": stripe_tpl,
                          "island_ring": island_ring})


def build_ring_reduce_scatter(p: int, n_bytes: int) -> Schedule:
    """Ring Reduce-Scatter over a per-rank buffer of ``n_bytes``: P-1
    generations of single-source Reduce edges, each carrying the N/P shard
    being accumulated around the ring."""
    shard = n_bytes / p
    ops: list[Op] = []
    act: list[tuple[int, int]] = []
    idx: dict[tuple[int, int], int] = {}
    for s in range(p - 1):
        for i in range(p):
            idx[(s, i)] = len(ops)
            ops.append(Reduce((i + 1) % p, (i,), shard))
        if s:
            act += [(idx[(s - 1, (i - 1) % p)], idx[(s, i)])
                    for i in range(p)]
    return Schedule("reduce_scatter", p, n_bytes, tuple(ops), tuple(act),
                    meta={"shard_bytes": shard})


def build_allreduce(p: int, n_bytes: int, m: int | None = None) -> Schedule:
    """Allreduce = RS ∘ AG: a ring Reduce-Scatter of the ``n_bytes`` buffer
    followed by an Allgather of the reduced N/P shards — ``m=None`` uses the
    classical ring AG, ``m >= 1`` the paper's M-chain multicast AG. A full
    activation barrier joins the phases (the executor runs them
    back-to-back; the shard payload is rounded to whole bytes for the
    packet-granular AG leg)."""
    assert p >= 2, f"allreduce needs at least 2 ranks, got p={p}"
    shard_int = max(n_bytes // p, 1)
    rs = build_ring_reduce_scatter(p, n_bytes)
    ag = (build_allgather(p, shard_int, m) if m
          else build_ring_allgather(p, shard_int))
    off = len(rs.ops)
    act = list(rs.activation) + [(a + off, b + off) for a, b in ag.activation]
    rs_last = rs.rounds()[-1]
    ag_first = [i + off for i in ag.rounds()[0]]
    act += [(a, b) for a in rs_last for b in ag_first]   # phase barrier
    return Schedule("allreduce", p, n_bytes, rs.ops + ag.ops, tuple(act),
                    meta={"m": m, "shard_bytes": shard_int,
                          "n_rs_ops": off, "rs": rs, "ag": ag})


def segment_bytes(n_bytes: int, n_segments: int) -> tuple[int, ...]:
    """Canonical buffer split for chunk-granularity pipelining: equal-ish
    contiguous segments, the first ``n_bytes % n_segments`` one byte
    longer. Shared by the builder, the pipelined executor and the analytic
    form so all three agree on segment payloads."""
    assert n_segments >= 1
    q, rem = divmod(n_bytes, n_segments)
    return tuple(q + (1 if i < rem else 0) for i in range(n_segments))


def build_pipelined_allreduce(p: int, n_bytes: int, m: int | None = None,
                              n_segments: int = 2) -> Schedule:
    """Chunk-granularity pipelined Allreduce (the ROADMAP's RS∘AG overlap
    follow-on, now a first-class candidate of the schedule searcher): the
    buffer is split into ``n_segments`` segments, each an RS ∘ AG pair, and
    the Activation edges wire the two-stage pipeline — segment s's AG is
    activated by its own RS, segment s+1's RS by segment s's RS (NOT by its
    AG), so the next segment's Reduce-Scatter genuinely overlaps the
    previous segment's Allgather. ``n_segments=1`` is exactly
    build_allreduce's barrier composition. Extra segments trade per-segment
    latency/RNR overhead for overlap — the searcher sweeps the knob."""
    assert p >= 2, f"allreduce needs at least 2 ranks, got p={p}"
    assert 1 <= n_segments <= max(n_bytes, 1), (n_segments, n_bytes)
    segments: list[tuple[Schedule, Schedule]] = []
    ops: list[Op] = []
    act: list[tuple[int, int]] = []
    prev_rs_last: list[int] = []
    for seg in segment_bytes(n_bytes, n_segments):
        shard_int = max(seg // p, 1)
        rs = build_ring_reduce_scatter(p, seg)
        ag = (build_allgather(p, shard_int, m) if m
              else build_ring_allgather(p, shard_int))
        segments.append((rs, ag))
        rs_off = len(ops)
        ops += rs.ops
        act += [(a + rs_off, b + rs_off) for a, b in rs.activation]
        rs_first = [i + rs_off for i in rs.rounds()[0]]
        rs_last = [i + rs_off for i in rs.rounds()[-1]]
        ag_off = len(ops)
        ops += ag.ops
        act += [(a + ag_off, b + ag_off) for a, b in ag.activation]
        ag_first = [i + ag_off for i in ag.rounds()[0]]
        # pipeline wiring: RS_s -> AG_s and RS_s -> RS_{s+1}
        act += [(a, b) for a in prev_rs_last for b in rs_first]
        act += [(a, b) for a in rs_last for b in ag_first]
        prev_rs_last = rs_last
    return Schedule("allreduce", p, n_bytes, tuple(ops), tuple(act),
                    meta={"m": m, "n_segments": n_segments,
                          "segments": tuple(segments),
                          "shard_bytes": max(n_bytes // p, 1)})


def build_fsdp_step(*, p: int, n_layers: int = 32, layer_bytes: float = 256e6,
                    policy: str = "mcast", n_chains: int = 2,
                    **compute) -> Schedule:
    """One FSDP training step as a schedule graph: per layer a forward AG
    (prefetched), a backward AG and a backward RS, each in the op type the
    policy puts on the wire —

      naive   AG and RS both P2P rings (Unicast / single-source Reduce
              edges carrying the full (P-1)/P gather bytes)
      mcast   AG as P Multicasts of the 1/P shard (switch replication);
              RS stays a ring of Reduce edges
      split   AG Multicasts down + in-network aggregation Reduces up
              (every source reduced toward dst — Insight 2's RS_inc)

    Activation edges encode the per-rank prefetch chain (layer i+1's AG
    activates after layer i's) and each layer's RS depending on its
    backward AG. fsdp_submitters() lowers the per-layer op template onto an
    Engine (abstract NIC links or routed fabric);
    engine.simulate_fsdp_step interleaves the lowered flows with compute."""
    assert policy in ("naive", "mcast", "split"), policy
    assert p >= 2 and n_layers >= 1
    gather = (p - 1) / p * layer_bytes
    shard = layer_bytes / p
    group = tuple(range(p))

    def ag_ops() -> list[Op]:
        if policy == "naive":
            return [Unicast(i, (i + 1) % p, gather) for i in range(p)]
        return [Multicast(i, group, shard) for i in range(p)]

    def rs_ops() -> list[Op]:
        if policy == "split":
            return [Reduce(i, tuple(x for x in group if x != i), shard)
                    for i in range(p)]
        return [Reduce((i + 1) % p, (i,), gather) for i in range(p)]

    ops: list[Op] = []
    act: list[tuple[int, int]] = []
    fwd: list[list[int]] = []
    for layer in range(n_layers):
        base = len(ops)
        ops += ag_ops()
        fwd.append(list(range(base, base + p)))
        if layer:
            act += [(fwd[layer - 1][i], fwd[layer][i]) for i in range(p)]
    prev = fwd[-1]
    for layer in range(n_layers - 1, -1, -1):
        base = len(ops)
        ops += ag_ops()
        idx = list(range(base, base + p))
        act += [(prev[i], idx[i]) for i in range(p)]
        rbase = len(ops)
        ops += rs_ops()
        act += [(idx[i], rbase + i) for i in range(p)]
        prev = idx
    return Schedule("fsdp_step", p, int(layer_bytes), tuple(ops), tuple(act),
                    meta=dict(policy=policy, n_layers=n_layers,
                              layer_bytes=layer_bytes, n_chains=n_chains,
                              gather_bytes=gather, shard_bytes=shard,
                              compute=dict(compute)))


# ----------------------------------------------------------- fluid lowerings


def _fluid_broadcast(sched: Schedule, fabric: FabricParams,
                     workers: WorkerParams, rng: np.random.Generator, *,
                     topology=None, hosts=None) -> BcastResult:
    """Fluid lowering of a single-Multicast schedule (the body that was
    simulator.simulate_broadcast's fluid path, verbatim)."""
    (op,) = sched.ops
    p, n_bytes, root = sched.p, sched.n_bytes, op.root
    n_chunks, chunk = _chunking(n_bytes, fabric.mtu)
    t_rnr = _rnr_barrier(p, fabric, workers)

    eng = Engine()
    if topology is not None:
        hosts = list(hosts) if hosts is not None else list(range(p))
        assert len(hosts) == p, (len(hosts), p)
        topology.reset()
        tree = topology.multicast_tree(hosts[root], hosts)
        flow = eng.submit_tree(tree, n_chunks * chunk, t_start=t_rnr,
                               tag="mcast")
        hop_lat = [len(topology.route(hosts[root], hosts[leaf])) * fabric.latency
                   for leaf in range(p)]
    else:
        # abstract mode: a single flow on the root's send link, one hop
        eng.add_link("root.send", fabric.b_link)
        flow = eng.submit("root.send", n_chunks * chunk, t_start=t_rnr)
        hop_lat = [fabric.latency] * p
    eng.run()
    inject = flow.chunk_times(n_chunks, chunk)
    service = chunk / workers.thread_tput

    completion = np.zeros(p)
    recovered_total = 0
    rnr_total = 0
    fast_total = 0
    t_mcast_end = t_rnr
    t_rel_end = 0.0

    cutoff = t_rnr + protocol.cutoff_time(n_bytes, fabric.b_link, fabric.alpha)

    for leaf in range(p):
        if leaf == root:
            completion[leaf] = inject[-1]
            continue
        delay = hop_lat[leaf] + rng.uniform(0.0, fabric.jitter, size=n_chunks)
        dropped = rng.random(n_chunks) < fabric.p_drop
        arrivals = np.sort((inject + delay)[~dropped])
        done, rnr = worker_pool_completion(
            arrivals, workers.n_recv_workers, service, workers.staging_chunks
        )
        rnr_total += rnr
        fast = n_chunks - int(dropped.sum()) - rnr
        fast_total += fast
        t_fast = done[-1] if done.size else t_rnr
        missing = int(dropped.sum()) + rnr
        if missing:
            # fetch ring (§III-C): wait for cutoff, then selective RDMA reads
            # from the left neighbour (holder is >= left neighbour or root).
            t0 = max(t_fast, cutoff)
            t_fetch = t0 + missing * (2 * fabric.latency + chunk / fabric.b_link)
            recovered_total += missing
            completion[leaf] = t_fetch
            t_rel_end = max(t_rel_end, t_fetch - t0)
        else:
            completion[leaf] = t_fast
        t_mcast_end = max(t_mcast_end, t_fast)

    # final handshake: send final to left, need final from right (§III-C)
    shifted = np.roll(completion, -1)
    completion = np.maximum(completion, shifted) + fabric.latency

    phases = PhaseBreakdown(
        rnr_sync=t_rnr,
        multicast=t_mcast_end - t_rnr,
        reliability=t_rel_end,
        handshake=fabric.latency,
    )
    return BcastResult(
        completion=completion,
        phases=phases,
        delivered_fast=fast_total,
        recovered=recovered_total,
        rnr_drops=rnr_total,
        bytes_fast=fast_total * chunk,
        bytes_recovery=recovered_total * chunk,
        bytes_total=(p - 1) * n_chunks * chunk,
        link_bytes=eng.link_bytes() if topology is not None else {},
    )


def _fluid_allgather(sched: Schedule, fabric: FabricParams,
                     workers: WorkerParams, rng: np.random.Generator, *,
                     topology=None, hosts=None,
                     co_hosts=()) -> AllgatherResult:
    """Fluid lowering of an Appendix-A allgather schedule: each activation
    generation's Multicast roots inject concurrently; the leaf receive path
    (link + worker pool) is the shared bottleneck; generations are chained
    by the activation signal. (The body that was
    simulator.simulate_allgather's fluid path, with the round structure now
    read off the schedule DAG.)

    ``co_hosts`` (topology mode only) lists additional host sets running the
    SAME schedule concurrently — the hierarchical allgather's sibling
    stripes. Their structurally identical tree flows are co-submitted each
    round, so the representative stripe's rates reflect genuine uplink
    contention and the engine's per-link bytes count every stripe."""
    p, n_bytes = sched.p, sched.n_bytes
    generations = sched.rounds()
    n_chunks, chunk = _chunking(n_bytes, fabric.mtu)
    service = chunk / workers.thread_tput

    t_rnr = _rnr_barrier(p, fabric, workers)

    eng = Engine()
    if topology is not None:
        hosts = list(hosts) if hosts is not None else list(range(p))
        assert len(hosts) == p, (len(hosts), p)
        topology.reset()
    else:
        eng.add_link("leaf.recv", fabric.b_link)

    t = t_rnr
    recovered_total = 0
    fast_bytes = 0
    rec_bytes = 0
    mcast_time = 0.0
    rel_time = 0.0
    for round_ops in generations:
        m = len(round_ops)
        total_chunks = m * n_chunks
        if topology is not None:
            # Appendix A: round roots G^r multicast concurrently through the
            # fabric; each tree flow's rate is min-share over its edges, so
            # chains genuinely collide in the core and at every ejection port
            roots = [hosts[sched.ops[i].root] for i in round_ops]
            flows = [
                eng.submit_tree(topology.multicast_tree(root, hosts),
                                n_chunks * chunk, t_start=t, tag=f"chain{root}")
                for root in roots
            ]
            for co in co_hosts:
                for i in round_ops:
                    co_root = co[sched.ops[i].root]
                    eng.submit_tree(topology.multicast_tree(co_root, list(co)),
                                    n_chunks * chunk, t_start=t,
                                    tag=f"costripe{co_root}")
        else:
            # m chain roots inject concurrently; the leaf's ejection link is
            # the shared resource — m equal flows, each chain rate b_link/m
            flows = [
                eng.submit("leaf.recv", n_chunks * chunk, t_start=t,
                           tag=f"chain{sched.ops[i].root}")
                for i in round_ops
            ]
        eng.run()
        arrive_spacing = np.sort(
            np.concatenate([f.chunk_times(n_chunks, chunk) for f in flows])
        )
        delay = fabric.latency + rng.uniform(0.0, fabric.jitter, size=total_chunks)
        dropped = rng.random(total_chunks) < fabric.p_drop
        arrivals = np.sort((arrive_spacing + delay)[~dropped])
        done, rnr = worker_pool_completion(
            arrivals, workers.n_recv_workers, service, workers.staging_chunks
        )
        t_fast = done[-1] if done.size else t
        missing = int(dropped.sum()) + rnr
        cutoff = t + protocol.cutoff_time(m * n_bytes, fabric.b_link,
                                          fabric.alpha)
        t_round_end = t_fast
        if missing:
            t0 = max(t_fast, cutoff)
            t_round_end = t0 + missing * (2 * fabric.latency + chunk / fabric.b_link)
            rel_time += t_round_end - t0
            recovered_total += missing
        mcast_time += max(t_fast - t, 0.0)
        fast_bytes += (total_chunks - missing) * chunk
        rec_bytes += missing * chunk
        # activation signal to the next root in every chain; the engine clock
        # can only run ahead of t_round_end if every chunk was dropped
        t = max(t_round_end + fabric.latency, eng.now)

    t_done = t + fabric.latency  # final handshake
    phases = PhaseBreakdown(
        rnr_sync=t_rnr, multicast=mcast_time, reliability=rel_time,
        handshake=fabric.latency,
    )
    total = (p - 1) * n_bytes
    return AllgatherResult(
        time=t_done,
        phases=phases,
        recovered=recovered_total,
        bytes_fast=fast_bytes,
        bytes_recovery=rec_bytes,
        bytes_total=p * n_chunks * chunk,
        per_rank_recv_tput=total / t_done,
        link_bytes=eng.link_bytes() if topology is not None else {},
    )


# ------------------------------------------------------------- ring lowering


@dataclass
class RingCollectiveResult:
    """Result of a ring schedule (ring_allgather / reduce_scatter):
    generation-synchronous neighbour exchange on RC transport."""
    time: float
    phases: PhaseBreakdown
    n_rounds: int
    bytes_total: float                 # receiver payload (== payload_bytes)
    bytes_recovery: float = 0.0        # packet fidelity: RC goodput inflation
    link_bytes: dict[str, float] = field(default_factory=dict)


def _fluid_ring(sched: Schedule, fabric: FabricParams,
                workers: WorkerParams, rng: np.random.Generator, *,
                topology=None, hosts=None,
                co_hosts=()) -> RingCollectiveResult:
    """Fluid lowering of a ring schedule: each generation every rank
    forwards its current shard to the right neighbour. Abstractly the NIC is
    full duplex — one send + one receive flow on the representative rank per
    generation; with a topology every op is a routed unicast and the
    generations genuinely contend on shared fabric links. Reduction combines
    at line rate (in-switch / SIMD), so Reduce edges cost their wire bytes.
    ``co_hosts`` co-submits sibling stripes' identical flows (see
    _fluid_allgather) so shared fabric links are genuinely contended."""
    p = sched.p
    generations = sched.rounds()
    eng = Engine()
    if topology is not None:
        hosts = list(hosts) if hosts is not None else list(range(p))
        assert len(hosts) == p, (len(hosts), p)
        topology.reset()
        route_cache: dict[tuple, list] = {}
        tiered = getattr(topology, "supports_transport", False)

        def route_of(op: Op):
            src = op.src if isinstance(op, Unicast) else op.srcs[0]
            dst = op.dst
            key = (src, dst, op.transport)
            if key not in route_cache:
                # per-op transport pins the fabric tier on topologies that
                # have tiers; flat fabrics route the same links regardless
                route_cache[key] = (
                    topology.route(hosts[src], hosts[dst],
                                   transport=op.transport)
                    if tiered else topology.route(hosts[src], hosts[dst]))
            return route_cache[key]
    else:
        eng.add_link("ring.send", fabric.b_link)
        eng.add_link("ring.recv", fabric.b_link)

    t = 0.0
    wire_time = 0.0
    for round_ops in generations:
        ops = [sched.ops[i] for i in round_ops]
        for op in ops:
            assert isinstance(op, (Unicast, Reduce)), op
            if isinstance(op, Reduce):
                assert len(op.srcs) == 1, \
                    "ring lowering takes single-source Reduce edges"
        if topology is not None:
            flows = [eng.submit_route(route_of(op), op.nbytes, t_start=t,
                                      tag=f"ring{i}")
                     for i, op in enumerate(ops)]
            for s, co in enumerate(co_hosts):
                for i, op in enumerate(ops):
                    src = op.src if isinstance(op, Unicast) else op.srcs[0]
                    r = (topology.route(co[src], co[op.dst],
                                        transport=op.transport)
                         if tiered else topology.route(co[src], co[op.dst]))
                    eng.submit_route(r, op.nbytes, t_start=t,
                                     tag=f"costripe{s}.{i}")
        else:
            nbytes = ops[0].nbytes
            flows = [eng.submit("ring.send", nbytes, t_start=t, tag="ring"),
                     eng.submit("ring.recv", nbytes, t_start=t, tag="ring")]
        eng.run()
        t_end = max(f.t_end for f in flows)
        wire_time += t_end - t
        t = t_end + fabric.latency     # the shard must reach the neighbour
    return RingCollectiveResult(
        time=t,
        phases=PhaseBreakdown(multicast=wire_time,
                              handshake=len(generations) * fabric.latency),
        n_rounds=len(generations),
        bytes_total=payload_bytes(sched),
        link_bytes=eng.link_bytes() if topology is not None else {},
    )


def _packet_ring(sched: Schedule, fabric: FabricParams,
                 workers: WorkerParams, rng: np.random.Generator, *,
                 topology=None, hosts=None, loss=None) -> RingCollectiveResult:
    """Packet fidelity for ring schedules: RC transport retransmits in
    hardware (go-back-N), so loss appears as deterministic goodput inflation
    1/(1 - q_path) on the wire component — the same mean-field treatment the
    FSDP "naive" overlay and protocol.analytic_ring_pipeline_bcast_time use.
    At loss 0 this reproduces the fluid lowering exactly."""
    from repro.core import packet as pk   # deferred: packet imports this module

    base = _fluid_ring(sched, fabric, workers, rng, topology=topology,
                       hosts=hosts)
    template = pk.resolve_loss(loss, fabric)
    if template is None:
        return base
    if topology is not None:
        host_list = list(hosts) if hosts is not None else list(range(sched.p))
        tiered = getattr(topology, "supports_transport", False)

        def route_len(op):
            src = op.src if isinstance(op, Unicast) else op.srcs[0]
            if tiered:
                return len(topology.route(host_list[src], host_list[op.dst],
                                          transport=op.transport))
            return len(topology.route(host_list[src], host_list[op.dst]))

        hops = [route_len(sched.ops[i]) for i in sched.rounds()[0]]
        path_len = max(sum(hops) / len(hops), 1.0)
    else:
        path_len = 1.0
    inflate = pk.rc_goodput_inflation(template.mean_rate, path_len)
    extra = base.phases.multicast * inflate
    base.time += extra
    base.phases.reliability = extra
    base.bytes_recovery = base.bytes_total * inflate
    return base


# --------------------------------------------------------------- allreduce


@dataclass
class AllreduceResult:
    """Allreduce = RS ∘ AG, phases run back-to-back (the activation barrier
    of build_allreduce) or segment-pipelined (build_pipelined_allreduce —
    ``segments`` then holds every (rs, ag) result pair and ``rs``/``ag``
    the first segment's): per-phase results kept for inspection."""
    time: float
    rs_time: float                     # total RS stage busy time
    ag_time: float                     # total AG stage busy time
    bytes_total: float
    rs: RingCollectiveResult
    ag: object                         # AllgatherResult | RingCollectiveResult
    link_bytes: dict[str, float] = field(default_factory=dict)
    segments: tuple = ()               # pipelined: ((rs, ag) result, ...)


def _exec_allreduce(sched: Schedule, fabric, workers, rng, *, fidelity,
                    topology, hosts, loss, kw) -> AllreduceResult:
    if "segments" in sched.meta:
        return _exec_pipelined_allreduce(
            sched, fabric, workers, rng, fidelity=fidelity,
            topology=topology, hosts=hosts, loss=loss, kw=kw)
    # the two phase sub-schedules are carried in meta by build_allreduce
    # (their ops/edges also make up the merged DAG, for introspection)
    rs = execute(sched.meta["rs"], fabric, workers, rng, fidelity=fidelity,
                 topology=topology, hosts=hosts, loss=loss)
    rs_links = dict(rs.link_bytes)
    ag = execute(sched.meta["ag"], fabric, workers, rng, fidelity=fidelity,
                 topology=topology, hosts=hosts, loss=loss, **kw)
    merged = dict(rs_links)
    for k, v in ag.link_bytes.items():
        merged[k] = merged.get(k, 0.0) + v
    return AllreduceResult(
        time=rs.time + ag.time,
        rs_time=rs.time,
        ag_time=ag.time,
        bytes_total=rs.bytes_total + ag.bytes_total,
        rs=rs,
        ag=ag,
        link_bytes=merged,
    )


def _exec_pipelined_allreduce(sched: Schedule, fabric, workers, rng, *,
                              fidelity, topology, hosts, loss,
                              kw) -> AllreduceResult:
    """Segment-pipelined Allreduce execution: each segment's RS and AG are
    lowered independently (the RS stage rides the neighbour ring, the AG
    stage the multicast trees / full-duplex receive path — disjoint stage
    resources at this model's granularity, exactly as the barrier
    composition already treats them), then composed with the two-stage
    pipeline recurrence protocol.pipeline_schedule_time — segment s+1's RS
    overlaps segment s's AG. The same recurrence over per-segment analytic
    forms is the admissible bound (protocol.analytic_pipelined_allreduce_
    time), so analytic <= fluid <= packet carries over segment-wise."""
    results = []
    merged: dict[str, float] = {}
    for rs_sched, ag_sched in sched.meta["segments"]:
        rs = execute(rs_sched, fabric, workers, rng, fidelity=fidelity,
                     topology=topology, hosts=hosts, loss=loss)
        rs_links = dict(rs.link_bytes)
        ag = execute(ag_sched, fabric, workers, rng, fidelity=fidelity,
                     topology=topology, hosts=hosts, loss=loss, **kw)
        results.append((rs, ag))
        for lb in (rs_links, ag.link_bytes):
            for k, v in lb.items():
                merged[k] = merged.get(k, 0.0) + v
    rs_times = [rs.time for rs, _ in results]
    ag_times = [ag.time for _, ag in results]
    return AllreduceResult(
        time=protocol.pipeline_schedule_time(rs_times, ag_times),
        rs_time=sum(rs_times),
        ag_time=sum(ag_times),
        bytes_total=sum(rs.bytes_total + ag.bytes_total
                        for rs, ag in results),
        rs=results[0][0],
        ag=results[0][1],
        link_bytes=merged,
        segments=tuple(results),
    )


# ------------------------------------------------- hierarchical allgather


@dataclass
class HierAllgatherResult:
    """Hierarchical allgather = striped switched allgather ∘ island-ring
    redistribution (build_hierarchical_allgather). ``stripe`` is the
    phase-B result of stripe 0 — stripes are member-disjoint and
    structurally identical, so one is the timing representative; at fluid
    fidelity ALL stripes' flows run on one engine (stripe 0's rates see the
    siblings' uplink contention, the engine counts every stripe's bytes),
    at packet fidelity stripe 0's time carries the fluid-validated
    inter-stripe contention factor (DESIGN §11)."""
    time: float
    stripe: object                   # AllgatherResult | RingCollectiveResult
    ring: RingCollectiveResult       # phase C (island redistribution)
    bytes_total: float
    per_rank_recv_tput: float
    phases: PhaseBreakdown
    link_bytes: dict[str, float] = field(default_factory=dict)
    completed: bool = True           # packet: phase B converged (C is RC)


def _stripe_contention_factor(stripe_sched: Schedule, fabric, workers,
                              topology, stripe_hosts, co_stripes) -> float:
    """Inter-stripe uplink contention as a fluid-measured slowdown: the
    stripe template executed alone vs with every sibling stripe's flows on
    the same engine. Deterministic (fresh seed, jitter draws identical
    across the pair) and >= 1 by max-min monotonicity; the packet stripe
    leg scales by this factor (DESIGN §11)."""
    if not co_stripes:
        return 1.0
    exec_stripe = (_fluid_allgather if stripe_sched.kind == "allgather"
                   else _fluid_ring)
    solo = exec_stripe(stripe_sched, fabric, workers,
                       np.random.default_rng(0), topology=topology,
                       hosts=stripe_hosts)
    full = exec_stripe(stripe_sched, fabric, workers,
                       np.random.default_rng(0), topology=topology,
                       hosts=stripe_hosts, co_hosts=co_stripes)
    return max(full.time / solo.time, 1.0)


def _exec_hier_allgather(sched: Schedule, fabric, workers, rng, *, fidelity,
                         topology, hosts, loss, kw) -> HierAllgatherResult:
    """Composite lowering of a hier_allgather schedule: execute the phase-B
    stripe template on stripe 0's members WITH the sibling stripes' flows
    co-submitted (fluid: directly on one engine; packet: stripe 0's packet
    run scaled by the fluid contention factor, siblings' fabric bytes
    counted statically), then execute the phase-C island ring over all
    ranks (per-op transports route it onto the island tier). Phase C tagged
    wholly "island" runs lossless at packet fidelity — intra-island ICI is
    reliable (DESIGN §2); the switched-redistribution variant keeps the
    caller's loss model."""
    p, g = sched.p, sched.meta["island_size"]
    n_islands = p // g
    stripe_sched: Schedule = sched.meta["stripe_ag"]
    ring_sched: Schedule = sched.meta["island_ring"]
    host_list = list(hosts) if hosts is not None else list(range(p))
    assert len(host_list) == p, (len(host_list), p)
    stripe_hosts = ([host_list[j * g] for j in range(n_islands)]
                    if topology is not None else None)
    co_stripes = ([[host_list[j * g + r] for j in range(n_islands)]
                   for r in range(1, g)] if topology is not None else [])
    if fidelity == "fluid" and topology is not None:
        exec_stripe = (_fluid_allgather if stripe_sched.kind == "allgather"
                       else _fluid_ring)
        stripe_res = exec_stripe(stripe_sched, fabric, workers, rng,
                                 topology=topology, hosts=stripe_hosts,
                                 co_hosts=co_stripes)
        link_bytes = dict(stripe_res.link_bytes)
    else:
        # packet-only options (engine=, max_rounds, ...) apply to the
        # multicast stripe leg; a ring-mode stripe is RC transport and
        # takes none
        stripe_kw = kw if stripe_sched.kind == "allgather" else {}
        stripe_res = execute(stripe_sched, fabric, workers, rng,
                             fidelity=fidelity, topology=topology,
                             hosts=stripe_hosts, loss=loss, **stripe_kw)
        link_bytes = dict(stripe_res.link_bytes)
        if topology is not None:
            factor = _stripe_contention_factor(
                stripe_sched, fabric, workers, topology, stripe_hosts,
                co_stripes)
            if factor > 1.0:
                extra = stripe_res.time * (factor - 1.0)
                stripe_res.time += extra
                stripe_res.phases.multicast += extra
            topology.reset()
            for r in range(1, g):
                members = [host_list[j * g + r] for j in range(n_islands)]
                for op in stripe_sched.ops:
                    if isinstance(op, Multicast):
                        topology.multicast(members[op.root], members,
                                           op.nbytes)
                    else:
                        topology.unicast(members[op.src], members[op.dst],
                                         op.nbytes)
            for (a, b), v in topology.counters.bytes_by_link.items():
                link_bytes[f"{a}->{b}"] = link_bytes.get(f"{a}->{b}", 0.0) + v
    ring_loss = loss
    if all(op.transport == "island" for op in ring_sched.ops):
        ring_loss = 0.0               # packet.resolve_loss: lossless
    ring_res = execute(ring_sched, fabric, workers, rng, fidelity=fidelity,
                       topology=topology, hosts=host_list,
                       loss=ring_loss if fidelity == "packet" else None)
    for k, v in ring_res.link_bytes.items():
        link_bytes[k] = link_bytes.get(k, 0.0) + v
    total_time = stripe_res.time + ring_res.time
    sp = stripe_res.phases
    rp = ring_res.phases
    return HierAllgatherResult(
        time=total_time,
        stripe=stripe_res,
        ring=ring_res,
        bytes_total=payload_bytes(sched),
        per_rank_recv_tput=(p - 1) * sched.n_bytes / total_time,
        phases=PhaseBreakdown(rnr_sync=sp.rnr_sync,
                              multicast=sp.multicast + rp.multicast,
                              reliability=sp.reliability + rp.reliability,
                              handshake=sp.handshake + rp.handshake),
        link_bytes=link_bytes,
        completed=bool(getattr(stripe_res, "completed", True)),
    )


# --------------------------------------------------- packet-fidelity rounds


class _PacketChainRun:
    """Runtime state of one Multicast op (one chain root) in a packet-level
    allgather generation: its tree flow, per-leaf root->leaf paths/models
    and per-leaf missing bitmaps. Replaces packet.py's ad-hoc _ChainState —
    the round/root structure now comes from the schedule's activation DAG.
    Unlike the standalone Broadcast, delivery is NOT self-contained — all
    chains of a generation share every leaf's worker pool, so the executor
    merges arrivals across chains before the pool pass."""

    __slots__ = ("root", "tree", "paths", "models", "flow", "inject",
                 "masks", "missing", "retx", "wire", "rmasks")

    def __init__(self, run_args, root: int, template,
                 rng: np.random.Generator, shared_carriers, model_cache):
        from repro.core import packet as pk   # deferred: import cycle

        p, n_chunks, fabric, topology, host_list = run_args
        self.root = root
        if topology is not None:
            self.tree = topology.multicast_tree(host_list[root], host_list)
            names = {leaf: topology.host(host_list[leaf])
                     for leaf in range(p) if leaf != root}
            by_name = pk.tree_paths(self.tree, topology.host(host_list[root]),
                                    list(names.values()))
            self.paths = {leaf: by_name[n] for leaf, n in names.items()}
            # model_cache: one loss process per physical Link, shared by
            # every chain crossing it and persistent across rounds
            self.models = pk._link_models(
                {names[leaf]: self.paths[leaf] for leaf in names}, template,
                rng, cache=model_cache)
        else:
            # abstract: loss lives on each leaf's ejection carrier, shared
            # by every chain (it is the same physical link); a chain sends
            # nothing to its own root, so its carrier is NOT in the model
            # set (sampling it would time-shift the shared loss process)
            self.tree = None
            self.paths = {leaf: [shared_carriers[leaf]] for leaf in range(p)
                          if leaf != root}
            self.models = {id(c): c.loss
                           for path in self.paths.values() for c in path}
        self.missing = {}                      # leaf -> bool mask over chunks
        self.flow = None
        self.retx = None                       # (flow, union, ...) per round
        self.rmasks = None
        self.wire = 0


def _packet_allgather(sched: Schedule, fabric: FabricParams,
                      workers: WorkerParams, rng: np.random.Generator, *,
                      topology=None, hosts=None, loss=None,
                      max_rounds: int | None = None,
                      aggregate_nacks: bool = True,
                      dpa_fidelity: str = "scalar", dpa=None,
                      engine: str = "auto"):
    """Packet-fidelity lowering of an allgather schedule: each activation
    generation's Multicast roots run concurrent packet Broadcasts — fast
    paths AND retransmission flows share one engine (recovery traffic
    collides with data on the fabric), every leaf's worker pool serves the
    MERGED arrival stream of all chains, and the next generation's
    activation waits for every chain of this one to complete.
    ``dpa_fidelity="event"`` gives every host a persistent event-level DPA
    (core/dpa_engine.py); a chain root's NACK service and retransmit
    posting then run on the SAME contexts that receive the other chains —
    protocol work steals cycles from the receive datapath. (The round loop
    that was packet.simulate_packet_allgather, with roots and round count
    read off the schedule DAG.)"""
    from repro.core import packet as pk   # deferred: packet imports this module
    from repro.core.dpa_engine import (
        DPA_FIDELITIES,
        DpaEventPool,
        resolve_event_params,
    )

    p, n_bytes = sched.p, sched.n_bytes
    if max_rounds is None:
        max_rounds = pk.DEFAULT_MAX_ROUNDS
    assert dpa_fidelity in DPA_FIDELITIES, dpa_fidelity
    assert dpa is None or dpa_fidelity == "event", \
        "dpa= requires dpa_fidelity='event'"
    generations = sched.rounds()
    # merged per-leaf row bytes = widest generation's concurrent chains
    # times the payload; "auto" picks the faster bit-exact executor for it
    width = max(len(g) for g in generations) if generations else 1
    engine = pk.resolve_engine(engine, sched.kind, p, width * n_bytes)
    vec = engine == "vectorized"
    n_chunks, chunk = _chunking(n_bytes, fabric.mtu)
    service = chunk / workers.thread_tput
    t_rnr = _rnr_barrier(p, fabric, workers)
    template = pk.resolve_loss(loss, fabric)
    if dpa_fidelity == "event":
        ev_params = resolve_event_params(dpa, workers.n_recv_workers)
        pools = {leaf: DpaEventPool(ev_params) for leaf in range(p)}
    else:
        pools = None
    eng = Engine()
    if topology is not None:
        host_list = list(hosts) if hosts is not None else list(range(p))
        assert len(host_list) == p, (len(host_list), p)
        topology.reset()
        shared_carriers = None
        recv_link = None
    else:
        host_list = list(range(p))
        recv_link = eng.add_link("leaf.recv", fabric.b_link)
        shared_carriers = {leaf: pk._AbstractCarrier() for leaf in range(p)}
        for leaf in range(p):
            if template is not None:
                shared_carriers[leaf].loss = template.fork(rng)
    run_args = (p, n_chunks, fabric, topology, host_list)
    # one loss process per physical fabric Link for the WHOLE allgather:
    # chains sharing a cable share its (possibly bursty) channel state
    model_cache: dict[int, object] = {}

    def hop_lat(ch: _PacketChainRun, leaf: int) -> float:
        if topology is None:
            return fabric.latency
        return len(ch.paths[leaf]) * fabric.latency

    def pool_merged(entries, t_floor: float, leaf: int):
        """Merge (chain, psns, arrivals) triples through ONE leaf pool pass
        (the leaf's scalar queue, or its persistent event DPA); returns
        (t_done, per-chain surviving psns after RNR)."""
        if not entries:
            return t_floor, {}, 0
        arr = np.concatenate([e[2] for e in entries])
        key = np.concatenate([np.full(e[2].shape[0], i)
                              for i, e in enumerate(entries)])
        psn = np.concatenate([e[1] for e in entries])
        order = np.argsort(arr, kind="stable")
        if pools is None:
            done, _ = worker_pool_completion(
                arr[order], workers.n_recv_workers, service,
                workers.staging_chunks)
        else:
            done = pools[leaf].service_batch(arr[order], chunk)
        rnr = staging_rnr_mask(done, arr[order], workers.staging_chunks)
        got = {}
        ko, po, ro = key[order], psn[order], rnr
        for i, e in enumerate(entries):
            sel = ko == i
            got[e[0]] = (po[sel & ~ro], po[sel & ro])   # (delivered, rnr)
        # max, not done[-1]: a persistent event pool's last-arriving item is
        # not necessarily the last one to complete (busy-context backlog)
        t_done = float(done.max()) if done.size else t_floor
        n_rnr = int(rnr.sum())
        return t_done, got, n_rnr

    # ---- vectorized-engine machinery (engine="vectorized"; DESIGN.md §9).
    # Jitter elision: at jitter==0 every per-(leaf,chain) draw returns
    # exactly 0.0 and x + 0.0 == x bitwise for the (positive) arrival
    # times, so the draws can be skipped outright — but ONLY when nothing
    # later reads the shared rng: with a routed topology AND a loss
    # template, later generations fork per-link models from the same rng,
    # so the (all-zero) draws are still consumed, as one batch. numpy's
    # uniform fills are stream-splittable: one sized draw is bitwise the
    # concatenation of the reference's per-(leaf,chain) draws, and size-0
    # draws do not advance the stream.
    skip_jitter = vec and fabric.jitter == 0.0 and (
        topology is None or template is None)

    def draw_jitter(total: int):
        if skip_jitter:
            return None
        return rng.uniform(0.0, fabric.jitter, size=total)

    def _cat(parts, dtype=None):
        if not parts:
            return np.empty(0, dtype=(dtype or float))
        return np.concatenate(parts)

    def pool_merged_rows(counts, arr_flat, key_flat, psn_flat, key_of,
                         t_floors, padded=False):
        """Batched pool_merged over a block of leaves (scalar pool only):
        pad the ragged per-leaf merged rows to one matrix, row-sort by
        arrival, run ONE worker_pool_completion_rows pass, and split the
        results back into pool_merged's (t_done, got, n_rnr) per leaf.
        ``key_of[k]`` maps row k's integer chain keys to chain objects.
        With ``padded=True`` the three flats are already (B, maxc) matrices
        whose sentinel entries (+inf arrival / -1 key / -1 psn) may sit
        mid-row (a chain's slot at its own root leaf): the sort check below
        sees the +inf descent and reorders them past the real prefix, and
        ``counts`` stays the REAL per-row entry count."""
        B = len(counts)
        counts = np.asarray(counts, dtype=np.intp)
        if padded:
            arr_pad, key_pad, psn_pad = arr_flat, key_flat, psn_flat
            maxc = arr_pad.shape[1]
            total = int(counts.sum())
            rows_full = True                   # sentinels already in place
        else:
            total = int(counts.sum())
            maxc = int(counts.max()) if B else 0
            rows_full = bool(B) and total == B * maxc
        if rows_full and not padded:
            # dense block (lossless rounds): every row is full, so the
            # row-major flats ARE the matrix — skip the scatter-pad
            arr_pad = arr_flat.reshape(B, maxc)
            key_pad = key_flat.reshape(B, maxc)
            psn_pad = psn_flat.reshape(B, maxc)
        elif not padded:
            starts = np.cumsum(counts) - counts
            rows = np.repeat(np.arange(B, dtype=np.intp), counts)
            within = (np.arange(total, dtype=np.intp)
                      - np.repeat(starts, counts))
            arr_pad = np.full((B, maxc), np.inf)
            key_pad = np.full((B, maxc), -1, dtype=np.intp)
            psn_pad = np.full((B, maxc), -1, dtype=np.intp)
            arr_pad[rows, within] = arr_flat
            key_pad[rows, within] = key_flat
            psn_pad[rows, within] = psn_flat
        # row sort == the reference's per-leaf argsort; elide it when every
        # row is already nondecreasing (single chain, no jitter: a stable
        # argsort of a sorted row is the identity). The pool only consumes
        # the sorted VALUE sequence, so a plain np.sort feeds it (bitwise
        # the sequence a stable-argsort gather produces — arrivals are
        # nonnegative, no -0.0/NaN ambiguity); the stable permutation that
        # attributes RNR drops back to (chain, psn) is materialised per
        # row in the epilogue, and only for rows the mask actually hit —
        # in the dense lossless regime that is none, saving the full
        # argsort + three take_along_axis passes
        sorted_rows = False
        arr_sorted = arr_pad
        if total and bool(np.any(arr_pad[:, 1:] < arr_pad[:, :-1])):
            sorted_rows = True
            if profiling.ENABLED:
                with profiling.phase("packing"):
                    arr_sorted = np.sort(arr_pad, axis=1)
            else:
                arr_sorted = np.sort(arr_pad, axis=1)
        done, rnr_mask = worker_pool_completion_rows(
            arr_sorted, workers.n_recv_workers, service,
            workers.staging_chunks)
        # row-batched epilogue: per-row t_done (max over the real prefix —
        # the -inf fill never wins for a nonempty row) and RNR totals; the
        # per-chain got split is only materialised for rows that actually
        # dropped something (got=None == "every submitted PSN delivered")
        nrnr = rnr_mask.sum(axis=1)
        if maxc:
            tdone = np.max(np.where(np.arange(maxc)[None, :]
                                    < counts[:, None], done, -np.inf),
                           axis=1)
        out = []
        for k in range(B):
            c = int(counts[k])
            if c == 0:
                out.append((t_floors[k], {}, 0))
                continue
            if not nrnr[k]:
                out.append((float(tdone[k]), None, 0))
                continue
            if sorted_rows:
                order_k = np.argsort(arr_pad[k], kind="stable")
                ko = key_pad[k][order_k][:c]
                po = psn_pad[k][order_k][:c]
            else:
                ko, po = key_pad[k, :c], psn_pad[k, :c]
            ro = rnr_mask[k, :c]
            got = {}
            for ky, ch in key_of[k].items():
                sel = ko == ky
                got[ch] = (po[sel & ~ro], po[sel & ro])
            out.append((float(tdone[k]), got, int(nrnr[k])))
        return out

    t = t_rnr
    traces: list = []
    mcast_time = 0.0
    rel_time = 0.0
    recovered_total = 0
    rnr_total = 0
    retx_wire = 0
    fast_total = 0
    undelivered = 0
    completed = True
    for round_ops in generations:
        roots = [sched.ops[i].root for i in round_ops]
        chains = [_PacketChainRun(run_args, root, template, rng,
                                  shared_carriers, model_cache)
                  for root in roots]
        for ch in chains:
            nbytes = n_chunks * chunk
            if ch.tree is not None:
                ch.flow = eng.submit_tree(ch.tree, nbytes, t_start=t,
                                          tag=f"chain{host_list[ch.root]}")
            else:
                ch.flow = eng.submit(recv_link, nbytes, t_start=t,
                                     tag=f"chain{ch.root}")
        eng.run()
        for ch in chains:
            ch.inject = ch.flow.chunk_times(n_chunks, chunk)
            ch.masks = pk._sample_link_round(ch.models, n_chunks)
        cutoff = max(ch.flow.t_end for ch in chains) + fabric.alpha
        # fast path: merged per-leaf pool over every chain's survivors
        t_fast = t
        leaf_done = np.full(p, t)
        if vec:
            # pass 1 (rng-free: masks are presampled): per-chain batched
            # loss rows, then per-(leaf, chain) surviving PSNs leaf-major
            psn_all = np.arange(n_chunks)
            chain_lost = []
            for ch in chains:
                if any(m is not None for m in ch.models.values()):
                    lv = sorted(ch.paths)
                    chain_lost.append(
                        (pk._stacked_lost(ch.paths, ch.masks, lv, n_chunks),
                         {lf: k for k, lf in enumerate(lv)}))
                else:
                    chain_lost.append(None)
            m = len(chains)
            dense = pools is None and all(cl is None for cl in chain_lost)
            if dense:
                # lossless scalar-pool generation: every (leaf, chain!=root)
                # pair receives the full PSN range, so the whole block's
                # merged rows are one broadcasted (leaves, chains, chunks)
                # tensor — no per-(leaf, chain) python at all. Each chain
                # skips exactly its root leaf, hence the jitter total.
                jall = draw_jitter((p * m - m) * n_chunks)
            else:
                ent = {}
                sizes = []
                for leaf in range(p):
                    for ci, ch in enumerate(chains):
                        if leaf == ch.root:
                            continue
                        cl = chain_lost[ci]
                        if cl is None:
                            psns = psn_all
                        else:
                            row = cl[0][cl[1][leaf]]
                            psns = np.nonzero(~row)[0]
                            if psns.shape[0] < n_chunks:
                                ch.missing[leaf] = row.copy()
                        ent[leaf, ci] = psns
                        sizes.append(psns.shape[0])
                jall = draw_jitter(int(np.sum(sizes, dtype=np.int64)))
            jpos = 0
            blk = max(1, pk._BLOCK_ELEMS
                      // max(n_chunks * len(chains), 1))
            inj = np.stack([ch.inject for ch in chains]) if dense else None
            for b0 in range(0, p, blk):
                b1 = min(b0 + blk, p)
                leaves_blk = range(b0, b1)
                if dense:
                    bp = b1 - b0
                    hop = np.empty((bp, m))
                    valid = np.ones((bp, m), dtype=bool)
                    for ci, ch in enumerate(chains):
                        # a chain has no path to its own root; that slot is
                        # masked out (sentinel / valid=False) below
                        hop[:, ci] = [hop_lat(ch, lf) if lf != ch.root
                                      else 0.0 for lf in leaves_blk]
                        if b0 <= ch.root < b1:
                            valid[ch.root - b0, ci] = False
                    # inject + hop in the reference's operand order (the
                    # add is bitwise order-independent, but keep it legible)
                    arr3 = inj[None, :, :] + hop[:, :, None]
                    counts = valid.sum(axis=1) * n_chunks
                    key_of = [{ci: chains[ci] for ci in range(m)
                               if chains[ci].root != leaf}
                              for leaf in leaves_blk]
                    if jall is None:
                        # no jitter draws to line up per entry: hand the
                        # broadcasted tensor over as pre-padded matrices,
                        # each chain's own-root slot turned into sentinels
                        key_pat = np.repeat(np.arange(m, dtype=np.intp),
                                            n_chunks)
                        psn_pat = np.tile(psn_all, m)
                        key_mat = np.broadcast_to(key_pat,
                                                  (bp, m * n_chunks))
                        psn_mat = np.broadcast_to(psn_pat,
                                                  (bp, m * n_chunks))
                        if not valid.all():
                            key_mat = key_mat.copy()
                            psn_mat = psn_mat.copy()
                            for ci, ch in enumerate(chains):
                                if b0 <= ch.root < b1:
                                    sl = slice(ci * n_chunks,
                                               (ci + 1) * n_chunks)
                                    arr3[ch.root - b0, ci, :] = np.inf
                                    key_mat[ch.root - b0, sl] = -1
                                    psn_mat[ch.root - b0, sl] = -1
                        res = pool_merged_rows(
                            counts, arr3.reshape(bp, m * n_chunks),
                            key_mat, psn_mat, key_of, [t] * bp,
                            padded=True)
                    else:
                        arr_flat = arr3[valid].reshape(-1)
                        arr_flat = arr_flat + jall[jpos:jpos
                                                   + arr_flat.size]
                        jpos += arr_flat.size
                        nv = int(valid.sum())
                        psn_flat = np.tile(psn_all, nv)
                        key_flat = np.repeat(
                            np.tile(np.arange(m, dtype=np.intp),
                                    bp)[valid.reshape(-1)], n_chunks)
                        res = pool_merged_rows(counts, arr_flat, key_flat,
                                               psn_flat, key_of, [t] * bp)
                    for leaf, (t_done, got, n_rnr) in zip(leaves_blk, res):
                        rnr_total += n_rnr
                        if got:
                            for ch in chains:
                                if ch in got:
                                    _, dropped = got[ch]
                                    if dropped.size:
                                        mm = ch.missing.setdefault(
                                            leaf,
                                            np.zeros(n_chunks, dtype=bool))
                                        mm[dropped] = True
                        leaf_done[leaf] = t_done
                        t_fast = max(t_fast, t_done)
                    continue
                counts, key_of = [], []
                arrs, keys, psns_f = [], [], []
                ev_entries = []
                for leaf in leaves_blk:
                    c, kd, ev = 0, {}, []
                    for ci, ch in enumerate(chains):
                        if leaf == ch.root:
                            continue
                        psns = ent.pop((leaf, ci))
                        a = ch.inject[psns] + hop_lat(ch, leaf)
                        if jall is not None:
                            a = a + jall[jpos:jpos + psns.shape[0]]
                            jpos += psns.shape[0]
                        if pools is None:
                            arrs.append(a)
                            psns_f.append(psns)
                            keys.append(np.full(psns.shape[0], ci,
                                                dtype=np.intp))
                            kd[ci] = ch
                            c += psns.shape[0]
                        else:
                            ev.append((ch, psns, a))
                    if pools is None:
                        counts.append(c)
                        key_of.append(kd)
                    else:
                        ev_entries.append(ev)
                if pools is None:
                    res = pool_merged_rows(
                        counts, _cat(arrs), _cat(keys, np.intp),
                        _cat(psns_f, np.intp), key_of,
                        [t] * len(counts))
                else:
                    res = [pool_merged(ev, t, leaf)
                           for leaf, ev in zip(leaves_blk, ev_entries)]
                for leaf, (t_done, got, n_rnr) in zip(leaves_blk, res):
                    rnr_total += n_rnr
                    if got:
                        for ch in chains:
                            if ch in got:
                                _, dropped = got[ch]
                                if dropped.size:
                                    mm = ch.missing.setdefault(
                                        leaf, np.zeros(n_chunks, dtype=bool))
                                    mm[dropped] = True
                    leaf_done[leaf] = t_done
                    t_fast = max(t_fast, t_done)
        else:
            for leaf in range(p):
                entries = []
                for ch in chains:
                    if leaf == ch.root:
                        continue
                    lost = pk._leaf_lost(ch.paths[leaf], ch.masks, n_chunks)
                    psns = np.nonzero(~lost)[0]
                    if lost.any():
                        ch.missing[leaf] = lost.copy()
                    arr = (ch.inject[psns] + hop_lat(ch, leaf)
                           + rng.uniform(0.0, fabric.jitter,
                                         size=psns.shape[0]))
                    entries.append((ch, psns, arr))
                t_done, got, n_rnr = pool_merged(entries, t, leaf)
                rnr_total += n_rnr
                for ch in chains:
                    if ch in got:
                        _, dropped = got[ch]
                        if dropped.size:
                            m = ch.missing.setdefault(
                                leaf, np.zeros(n_chunks, dtype=bool))
                            m[dropped] = True
                leaf_done[leaf] = t_done
                t_fast = max(t_fast, t_done)
        mcast_time += max(t_fast - t, 0.0)
        # interleaved recovery: every incomplete chain NACKs + retransmits
        # concurrently; retx flows contend on the shared engine and the
        # leaves' pools again serve the merged retransmission stream
        t_round_end = t_fast
        for _ in range(max_rounds):
            live = [ch for ch in chains if ch.missing]
            if not live:
                break
            for ch in live:
                union = np.zeros(n_chunks, dtype=bool)
                for lost in ch.missing.values():
                    union |= lost
                upos = np.nonzero(union)[0]
                nackers = sorted(ch.missing)
                t_send = [max(leaf_done[lf], cutoff) + hop_lat(ch, lf)
                          for lf in nackers]
                arrivals = (np.array([max(t_send)]) if aggregate_nacks
                            else np.sort(np.array(t_send)))
                if pools is None:
                    t_root_done, _ = pk._pool_with_rnr_psns(
                        arrivals, np.arange(arrivals.shape[0]), workers,
                        pk._nack_service(n_chunks, workers, fabric.mtu))
                else:
                    # the chain root's DPA serves the NACKs — the same
                    # contexts that receive every OTHER chain's stream
                    wire = pk._nack_wire_bytes(n_chunks, fabric.mtu)
                    t_root_done, _ = pools[ch.root].service_with_rnr(
                        arrivals, np.arange(arrivals.shape[0]), wire,
                        workers.staging_chunks, kind="nack",
                        wire_bytes=wire)
                t_retx = max(t_root_done, eng.now)
                if pools is not None:
                    pools[ch.root].service_batch(
                        np.full(upos.size, t_retx), chunk, kind="retx")
                if ch.tree is not None:
                    members = [host_list[ch.root]] + [host_list[x]
                                                      for x in nackers]
                    rtree = topology.multicast_tree(host_list[ch.root],
                                                    members)
                    rflow = eng.submit_tree(
                        rtree, upos.size * chunk, t_start=t_retx,
                        tag=f"chain{host_list[ch.root]}.retx")
                else:
                    rflow = eng.submit(recv_link, upos.size * chunk,
                                       t_start=t_retx,
                                       tag=f"chain{ch.root}.retx")
                ch.retx = (rflow, upos, nackers, arrivals)
                ch.wire += int(upos.size) * chunk
                retx_wire += int(upos.size) * chunk
            eng.run()
            cutoff = max(ch.retx[0].t_end for ch in live) + fabric.alpha
            for ch in live:
                # pruned-tree links only (see _BroadcastRun.deliver_retransmit)
                ch.rmasks = pk._sample_link_round(
                    pk._models_on_paths(ch.paths, ch.models,
                                        sorted(ch.missing)),
                    ch.retx[1].size)
            chain_recovered = {id(ch): 0 for ch in live}
            if vec:
                # per chain: retransmit injection times ONCE (the reference
                # recomputes them per leaf — equal values) and one batched
                # loss-row matrix over its nackers
                linfo = []
                for ch in live:
                    rflow, upos, _, _ = ch.retx
                    nk = sorted(ch.missing)
                    lm = None
                    if any(ch.models[id(lk)] is not None
                           for lf in nk for lk in ch.paths[lf]):
                        lm = (pk._stacked_lost(ch.paths, ch.rmasks, nk,
                                               upos.size),
                              {lf: k for k, lf in enumerate(nk)})
                    linfo.append((rflow.chunk_times(upos.size, chunk),
                                  upos, lm))
                rleaves = sorted({lf for ch in live for lf in ch.missing})
                ent = {}
                sizes = []
                for leaf in rleaves:
                    for li, ch in enumerate(live):
                        if leaf not in ch.missing:
                            continue
                        inject_r, upos, lm = linfo[li]
                        miss = np.nonzero(ch.missing[leaf])[0]
                        pos = np.searchsorted(upos, miss)   # upos ⊇ miss
                        if lm is None:
                            got_pos, got_psn = pos, miss
                        else:
                            la = lm[0][lm[1][leaf], pos]
                            got_pos, got_psn = pos[~la], miss[~la]
                        ent[leaf, li] = (got_psn,
                                         inject_r[got_pos]
                                         + hop_lat(ch, leaf))
                        sizes.append(got_psn.shape[0])
                jall = draw_jitter(int(np.sum(sizes, dtype=np.int64)))
                jpos = 0
                u_max = max((info[1].size for info in linfo), default=0)
                blk = max(1, pk._BLOCK_ELEMS // max(u_max * len(live), 1))
                for b0 in range(0, len(rleaves), blk):
                    leaves_blk = rleaves[b0:b0 + blk]
                    counts, key_of, t_floors = [], [], []
                    arrs, keys, psns_f = [], [], []
                    ev_entries, subms = [], []
                    for leaf in leaves_blk:
                        c, kd, ev, sd = 0, {}, [], {}
                        for li, ch in enumerate(live):
                            if (leaf, li) not in ent:
                                continue
                            got_psn, a = ent.pop((leaf, li))
                            if jall is not None:
                                a = a + jall[jpos:jpos + got_psn.shape[0]]
                                jpos += got_psn.shape[0]
                            if pools is None:
                                arrs.append(a)
                                psns_f.append(got_psn)
                                keys.append(np.full(got_psn.shape[0], li,
                                                    dtype=np.intp))
                                kd[li] = ch
                                sd[ch] = got_psn
                                c += got_psn.shape[0]
                            else:
                                ev.append((ch, got_psn, a))
                        subms.append(sd)
                        if pools is None:
                            counts.append(c)
                            key_of.append(kd)
                            t_floors.append(float(leaf_done[leaf]))
                        else:
                            ev_entries.append(ev)
                    if pools is None:
                        res = pool_merged_rows(
                            counts, _cat(arrs), _cat(keys, np.intp),
                            _cat(psns_f, np.intp), key_of, t_floors)
                    else:
                        res = [pool_merged(ev, float(leaf_done[leaf]), leaf)
                               for leaf, ev in zip(leaves_blk, ev_entries)]
                    for leaf, (t_done, got, n_rnr), sd in zip(
                            leaves_blk, res, subms):
                        rnr_total += n_rnr
                        # got=None: nothing hit RNR, so every chain's
                        # delivered set is exactly the PSNs it submitted
                        g = sd if got is None else got
                        for ch in live:
                            if leaf not in ch.missing or ch not in g:
                                continue
                            delivered = (g[ch] if got is None
                                         else got[ch][0])
                            ch.missing[leaf][delivered] = False
                            recovered_total += delivered.shape[0]
                            chain_recovered[id(ch)] += delivered.shape[0]
                            if not ch.missing[leaf].any():
                                del ch.missing[leaf]
                        leaf_done[leaf] = t_done
                        t_round_end = max(t_round_end, t_done)
            else:
                for leaf in range(p):
                    entries = []
                    for ch in live:
                        if leaf not in ch.missing:
                            continue
                        rflow, upos, _, _ = ch.retx
                        inject_r = rflow.chunk_times(upos.size, chunk)
                        miss = np.nonzero(ch.missing[leaf])[0]
                        pos = np.searchsorted(upos, miss)
                        lost = pk._leaf_lost(ch.paths[leaf], ch.rmasks,
                                             upos.size)[pos]
                        got_pos, got_psn = pos[~lost], miss[~lost]
                        arr = (inject_r[got_pos] + hop_lat(ch, leaf)
                               + rng.uniform(0.0, fabric.jitter,
                                             size=got_psn.shape[0]))
                        entries.append((ch, got_psn, arr))
                    t_done, got, n_rnr = pool_merged(
                        entries, float(leaf_done[leaf]), leaf)
                    rnr_total += n_rnr
                    for ch in live:
                        if leaf not in ch.missing or ch not in got:
                            continue
                        delivered, _ = got[ch]
                        ch.missing[leaf][delivered] = False
                        recovered_total += delivered.shape[0]
                        chain_recovered[id(ch)] += delivered.shape[0]
                        if not ch.missing[leaf].any():
                            del ch.missing[leaf]
                    if entries:
                        leaf_done[leaf] = t_done
                        t_round_end = max(t_round_end, t_done)
            for ch in live:
                rflow, upos, nackers, arrivals = ch.retx
                traces.append(pk.RoundTrace(
                    nack_leaves=len(nackers),
                    root_nack_msgs=int(arrivals.shape[0]),
                    union_chunks=int(upos.size),
                    t_nack_root=float(arrivals.max()),
                    t_retx_start=float(rflow.t_start),
                    t_end=t_round_end,
                    recovered=chain_recovered[id(ch)],
                ))
                ch.retx = None
                ch.rmasks = None
        completed &= not any(ch.missing for ch in chains)
        undelivered += sum(int(m.sum()) for ch in chains
                           for m in ch.missing.values())
        rel_time += max(t_round_end - t_fast, 0.0)
        fast_total += len(chains) * (p - 1) * n_chunks
        # activation signal to the next generation's roots
        t = max(t_round_end + fabric.latency, eng.now)
    # fast = everything not recovered and not still missing (max_rounds can
    # truncate recovery: completed=False, conservation shows the shortfall)
    fast_total -= recovered_total + undelivered

    t_done = t + fabric.latency  # final handshake
    phases = PhaseBreakdown(
        rnr_sync=t_rnr, multicast=mcast_time, reliability=rel_time,
        handshake=fabric.latency,
    )
    return pk.PacketAllgatherResult(
        time=t_done,
        phases=phases,
        recovered=recovered_total,
        bytes_fast=fast_total * chunk,
        bytes_recovery=recovered_total * chunk,
        # ALL receivers counted (the fluid model tracks one representative
        # leaf): p chains, each delivering n_chunks to p-1 leaves
        bytes_total=p * (p - 1) * n_chunks * chunk,
        per_rank_recv_tput=(p - 1) * n_bytes / t_done,
        link_bytes=eng.link_bytes() if topology is not None else {},
        rounds=traces,
        rnr_drops=rnr_total,
        retransmit_wire_bytes=retx_wire,
        completed=completed,
    )


# ----------------------------------------------------------- FSDP lowering


def fsdp_submitters(sched: Schedule, eng: Engine, fabric: FabricParams, *,
                    topology=None, hosts=None):
    """Lower the per-layer AG/RS op template of a build_fsdp_step schedule
    onto an Engine: returns (submit_ag, submit_rs, ag_sync) closures the
    FSDP timeline executor calls per layer. This replaces the per-policy
    flow construction that used to live in engine.py
    (_routed_fsdp_submitters + the abstract NIC branches): with a topology
    every op becomes a routed unicast / multicast tree / aggregation tree
    flow; abstractly the ops collapse onto the representative rank's NIC
    links (naive: one shared half-duplex medium; mcast/split: full-duplex
    send+recv). The caller owns topology.reset() (multi-job runs share one
    fabric).

    Each closure takes ``(t, scale=1.0)``: ``scale`` multiplies the wire
    bytes of that layer's flows relative to the schedule's reference
    layer_bytes, which is how heterogeneous per-layer parameter volumes
    (engine.simulate_fsdp_step ``layers=``) reuse one op template."""
    p = sched.p
    meta = sched.meta
    n_chains = meta["n_chains"]
    # byte quantities come from meta (the builder's exact legacy
    # expressions — bit-exactness pins depend on them); the op TEMPLATE of
    # the first layer decides the policy's wire structure, so builder and
    # lowering cannot silently diverge
    gather_bytes, shard_bytes = meta["gather_bytes"], meta["shard_bytes"]
    b = fabric.b_link
    ag_template = sched.ops[:p]                    # layer 0's AG ops
    rs_template = [op for op in sched.ops
                   if isinstance(op, Reduce)][:p]  # first backward RS block
    if isinstance(ag_template[0], Unicast):
        policy = "naive"
    elif len(rs_template[0].srcs) > 1:
        policy = "split"
    else:
        policy = "mcast"
    assert policy == meta["policy"], (policy, meta["policy"])

    if topology is not None:
        hosts = list(hosts) if hosts is not None else list(range(p))
        assert len(hosts) == p, (len(hosts), p)

        def submit_ring(routes, tag, nbytes, t):
            return [eng.submit_route(r, nbytes, t_start=t, tag=tag)
                    for r in routes]

        if policy == "naive":
            # both collectives as P2P rings in the same direction (the
            # template's Unicast/Reduce edges): their flows share every
            # host up/down link and the ECMP paths between them
            ring = [topology.route(hosts[op.src], hosts[op.dst])
                    for op in ag_template]
            submit_ag = lambda t, scale=1.0: submit_ring(  # noqa: E731
                ring, "ag", gather_bytes * scale, t)
            submit_rs = lambda t, scale=1.0: submit_ring(  # noqa: E731
                ring, "rs", gather_bytes * scale, t)
            return submit_ag, submit_rs, (p - 1) * fabric.latency

        mcast_trees = [topology.multicast_tree(hosts[op.root], hosts)
                       for op in ag_template]

        def submit_ag(t, scale=1.0):
            # every host multicasts its 1/P shard; switches replicate
            return [eng.submit_tree(tree, shard_bytes * scale, t_start=t,
                                    tag="ag")
                    for tree in mcast_trees]

        if policy == "mcast":
            ring = [topology.route(hosts[op.srcs[0]], hosts[op.dst])
                    for op in rs_template]
            submit_rs = lambda t, scale=1.0: submit_ring(  # noqa: E731
                ring, "rs", gather_bytes * scale, t)
        else:  # split: RS_inc — aggregation trees run opposite the AG trees
            agg_trees = [topology.aggregation_tree(hosts[op.dst], hosts)
                         for op in rs_template]

            def submit_rs(t, scale=1.0):
                return [eng.submit_tree(tree, shard_bytes * scale, t_start=t,
                                        tag="rs")
                        for tree in agg_trees]

        rounds = max(p // max(n_chains, 1), 1)
        return submit_ag, submit_rs, rounds * fabric.latency

    if policy == "naive":
        eng.add_link("shared", b)

        def submit_ag(t, scale=1.0):
            # ring AG: (p-1)/p*L sent + received, all through the shared medium
            return [eng.submit("shared", 2 * gather_bytes * scale, t_start=t,
                               tag="ag")]

        def submit_rs(t, scale=1.0):
            return [eng.submit("shared", 2 * gather_bytes * scale, t_start=t,
                               tag="rs")]

        return submit_ag, submit_rs, (p - 1) * fabric.latency

    # mcast / split share the multicast AG; they differ in the RS side
    eng.add_link("send", b)
    eng.add_link("recv", b)

    def submit_ag(t, scale=1.0):
        # AG_mc: receive-bound (send share 1/p — cost_model.mc_inc_share)
        return [eng.submit("send", shard_bytes * scale, t_start=t, tag="ag"),
                eng.submit("recv", gather_bytes * scale, t_start=t, tag="ag")]

    if policy == "mcast":
        def submit_rs(t, scale=1.0):
            # ring RS: full gather bytes in both directions, so its
            # receive stream contends with AG_mc on the ejection link
            return [eng.submit("send", gather_bytes * scale, t_start=t,
                               tag="rs"),
                    eng.submit("recv", gather_bytes * scale, t_start=t,
                               tag="rs")]
    else:
        def submit_rs(t, scale=1.0):
            # RS_inc: send-bound — the switch reduces in-network, the
            # node receives only its own reduced shard
            return [eng.submit("send", gather_bytes * scale, t_start=t,
                               tag="rs"),
                    eng.submit("recv", shard_bytes * scale, t_start=t,
                               tag="rs")]

    rounds = max(p // max(n_chains, 1), 1)
    return submit_ag, submit_rs, rounds * fabric.latency


# ------------------------------------------------------------ analytic path


def _exec_analytic(sched: Schedule, fabric: FabricParams,
                   workers: WorkerParams) -> float:
    """Closed-form oracle per schedule kind (core/protocol.py analytic_*).
    Returns a float: the lossless lower bound the engines are tested
    against (analytic <= fluid <= packet)."""
    b, lat = fabric.b_link, fabric.latency
    pool = workers.n_recv_workers * workers.thread_tput
    hop = workers.rnr_barrier_hop      # the lower-bound property must hold
    p, n = sched.p, sched.n_bytes      # for the CALLER's worker pool too
    if sched.kind == "broadcast":
        return protocol.analytic_bcast_time(p, n, b, lat, pool_rate=pool,
                                            rnr_hop=hop)
    if sched.kind == "allgather":
        return protocol.analytic_allgather_time(
            p, n, b, lat, n_chains=sched.meta["n_chains"], pool_rate=pool,
            rnr_hop=hop)
    if sched.kind == "ring_allgather":
        return protocol.analytic_ring_allgather_time(p, n, b, lat)
    if sched.kind == "hier_allgather":
        return protocol.analytic_hier_allgather_time(
            p, n, b, lat, island_size=sched.meta["island_size"],
            m=sched.meta.get("m"), stripe_mode=sched.meta["stripe_mode"],
            pool_rate=pool, rnr_hop=hop)
    if sched.kind == "reduce_scatter":
        return protocol.analytic_ring_reduce_scatter_time(p, n, b, lat)
    if sched.kind == "allreduce":
        if sched.meta.get("n_segments", 1) > 1:
            return protocol.analytic_pipelined_allreduce_time(
                p, n, b, lat, m=sched.meta["m"],
                n_segments=sched.meta["n_segments"], pool_rate=pool,
                rnr_hop=hop)
        return protocol.analytic_allreduce_time(
            p, n, b, lat, m=sched.meta["m"], pool_rate=pool, rnr_hop=hop)
    raise NotImplementedError(f"no analytic form for kind={sched.kind}")


# -------------------------------------------------------------- the executor


def execute(sched: Schedule, fabric: FabricParams | None = None,
            workers: WorkerParams | None = None,
            rng: np.random.Generator | None = None, *,
            fidelity: str = "fluid", topology=None, hosts=None, loss=None,
            **kw):
    """Lower ``sched`` onto the chosen fidelity and run it. One entry point
    for every schedule kind — the per-collective flow construction that used
    to be duplicated across simulator.py / engine.py / packet.py lives in
    the lowering functions above. Extra keyword arguments are
    fidelity-specific (packet: max_rounds / aggregate_nacks / dpa_fidelity /
    dpa, plus engine="auto"|"vectorized"|"reference" selecting the batched
    packet executor or the per-leaf oracle it is pinned bit-exact against —
    "auto" (default) resolves per-call via packet.resolve_engine — always
    "vectorized" since the pool scan closed the DESIGN §9 dense regime,
    unless REPRO_PACKET_ENGINE overrides;
    fsdp_step: the compute keywords of engine.simulate_fsdp_step)."""
    assert fidelity in FIDELITIES, fidelity
    fabric = fabric or FabricParams()
    workers = workers or WorkerParams()
    rng = rng if rng is not None else np.random.default_rng(0)

    if sched.kind == "fsdp_step":
        from repro.core import engine as engine_mod  # deferred: imports us

        meta = sched.meta
        assert fidelity in ("fluid", "packet"), \
            "fsdp_step supports fluid/packet fidelities"
        return engine_mod.simulate_fsdp_step(
            n_layers=meta["n_layers"], layer_bytes=meta["layer_bytes"],
            p=sched.p, fabric=fabric, policy=meta["policy"],
            n_chains=meta["n_chains"], topology=topology, hosts=hosts,
            fidelity=fidelity, loss=loss, rng=rng, workers=workers,
            schedule=sched, **meta.get("compute", {}), **kw)

    if fidelity == "analytic":
        assert loss is None and not kw, \
            "the analytic oracle is lossless and takes no engine options"
        # same footgun guard as the fluid path: the closed forms know
        # nothing about routed fabrics — silently ignoring topology= would
        # let a caller believe the fabric was modeled
        assert topology is None and hosts is None, \
            "the analytic oracle has no routed mode (topology=/hosts=)"
        return _exec_analytic(sched, fabric, workers)

    if fidelity == "fluid":
        assert loss is None, "loss models require fidelity='packet'"
        # same footgun: dpa_fidelity=/dpa=/... silently ignored would let a
        # caller believe the event DPA (or any packet option) was simulated
        assert not kw, f"{sorted(kw)} require fidelity='packet'"
        if sched.kind == "broadcast":
            return _fluid_broadcast(sched, fabric, workers, rng,
                                    topology=topology, hosts=hosts)
        if sched.kind == "allgather":
            return _fluid_allgather(sched, fabric, workers, rng,
                                    topology=topology, hosts=hosts)
        if sched.kind in ("ring_allgather", "reduce_scatter"):
            return _fluid_ring(sched, fabric, workers, rng,
                               topology=topology, hosts=hosts)
        if sched.kind == "hier_allgather":
            return _exec_hier_allgather(sched, fabric, workers, rng,
                                        fidelity=fidelity, topology=topology,
                                        hosts=hosts, loss=loss, kw=kw)
        if sched.kind == "allreduce":
            return _exec_allreduce(sched, fabric, workers, rng,
                                   fidelity=fidelity, topology=topology,
                                   hosts=hosts, loss=loss, kw=kw)
        raise NotImplementedError(sched.kind)

    # fidelity == "packet"
    if sched.kind == "broadcast":
        from repro.core import packet as pk  # deferred: packet imports us

        return pk.simulate_packet_broadcast(
            sched.p, sched.n_bytes, fabric, workers, rng, sched.ops[0].root,
            topology=topology, hosts=hosts, loss=loss, **kw)
    if sched.kind == "allgather":
        return _packet_allgather(sched, fabric, workers, rng,
                                 topology=topology, hosts=hosts, loss=loss,
                                 **kw)
    if sched.kind in ("ring_allgather", "reduce_scatter"):
        assert not kw, \
            f"{sorted(kw)} not supported for ring schedules (RC transport)"
        return _packet_ring(sched, fabric, workers, rng, topology=topology,
                            hosts=hosts, loss=loss)
    if sched.kind == "hier_allgather":
        return _exec_hier_allgather(sched, fabric, workers, rng,
                                    fidelity=fidelity, topology=topology,
                                    hosts=hosts, loss=loss, kw=kw)
    if sched.kind == "allreduce":
        return _exec_allreduce(sched, fabric, workers, rng,
                               fidelity=fidelity, topology=topology,
                               hosts=hosts, loss=loss, kw=kw)
    raise NotImplementedError(sched.kind)


# ----------------------------------------------------------------- autotune


def autotune_chains(schedule_builder, topology=None, *, p: int,
                    n_bytes: int, fabric: FabricParams | None = None,
                    workers: WorkerParams | None = None,
                    candidates=None, fidelity: str = "fluid",
                    seed: int = 0, cache=None) -> tuple[int, dict[int, float]]:
    """Sweep the chain count M for ``schedule_builder(p, n_bytes, m)`` on a
    given fabric and pick the fastest (the per-fabric incast-control knob of
    §IV-A: full parallelism on flat fabrics, fewer chains when the fabric or
    the leaf pool is the bottleneck). Returns (best_m, {m: time}) — the full
    sweep alongside the argmin. Candidates default to the divisors of P
    (uneven chains are legal too — pass them explicitly).

    This is the trivial 1-D special case of core/sched_search.py: it
    delegates to ``sched_search.sweep_chains`` and accepts its memoized
    ``cache=`` (an ``EvalCache``), so benchmarks sweeping overlapping M
    grids never re-simulate the same schedule."""
    from repro.core import sched_search   # deferred: sched_search imports us

    fabric = fabric or FabricParams(jitter=0.0)
    workers = workers or WorkerParams(n_recv_workers=8)
    if candidates is None:
        candidates = [m for m in range(1, p + 1) if p % m == 0]
    return sched_search.sweep_chains(
        schedule_builder, topology, p=p, n_bytes=n_bytes, fabric=fabric,
        workers=workers, candidates=candidates, fidelity=fidelity,
        seed=seed, cache=cache)
