"""Distributed Broadcast sequencer (paper §IV-A + Appendix A).

The Allgather schedule is a round-robin composition of Broadcasts: the P
participants are split into M parallel *broadcast chains* of length R = P/M.
At schedule step i the active root group is

    G^i = { P_i, P_{R+i}, P_{2R+i}, ..., P_{(M-1)R+i} }        (Appendix A)

Within a chain, members broadcast one-by-one (the activation signal travels
along the chain); across chains everything is concurrent. M controls the
aggregate multicast traffic in flight (fabric incast control); on a TPU torus
the analogue of "parallel multicast trees" is the set of ring directions, so
the performance-optimal choice intra-pod is full parallelism (see
core/collectives.py), while the faithful general-M schedule is used on the
switched pod axis.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class BroadcastStep:
    """One step of the Allgather schedule."""
    index: int
    roots: tuple[int, ...]          # active broadcasting processes G^i


def chain_of(rank: int, p: int, m: int) -> int:
    """Which chain a rank belongs to: chain m holds ranks [m*R, (m+1)*R)."""
    r = p // m
    return rank // r


def chain_members(m_idx: int, p: int, m: int) -> tuple[int, ...]:
    r = p // m
    return tuple(range(m_idx * r, (m_idx + 1) * r))


def active_group(step: int, p: int, m: int) -> tuple[int, ...]:
    """G^step per Appendix A."""
    if p % m:
        raise ValueError(f"P={p} must be divisible by M={m}")
    r = p // m
    if not 0 <= step < r:
        raise ValueError(f"step {step} out of range [0, {r})")
    return tuple(step + j * r for j in range(m))


def allgather_schedule(p: int, m: int) -> list[BroadcastStep]:
    """The full R-step schedule; every rank roots exactly once."""
    r = p // m
    return [BroadcastStep(i, active_group(i, p, m)) for i in range(r)]


def activation_edges(p: int, m: int) -> list[tuple[int, int]]:
    """(from, to) pairs of the chain activation signal (§IV-A): when ``from``
    finishes multicasting it activates ``to`` — its successor in the chain."""
    edges = []
    for c in range(m):
        members = chain_members(c, p, m)
        edges += list(zip(members[:-1], members[1:]))
    return edges


def subgroup_assignment(n_subgroups: int, buffer_len: int) -> list[tuple[int, int]]:
    """Packet parallelism (§IV-C): split the send buffer into contiguous blocks,
    one per multicast subgroup / worker queue. Returns [start, end) per subgroup."""
    q, rem = divmod(buffer_len, n_subgroups)
    out, off = [], 0
    for i in range(n_subgroups):
        ln = q + (1 if i < rem else 0)
        out.append((off, off + ln))
        off += ln
    return out


def worker_split(n_subgroups: int, n_participants: int) -> tuple[int, int]:
    """Send/receive worker allocation (§IV-C discrepancy rule): the receive
    path handles (P-1)x the send-path bytes, so receive workers scale with
    subgroups while one send worker serves all send queues (paper example:
    1 send worker / 4 recv workers at 16 procs, 4 subgroups)."""
    return 1, n_subgroups


def validate_schedule(p: int, m: int) -> None:
    """Invariants the hypothesis tests rely on."""
    sched = allgather_schedule(p, m)
    r = p // m
    assert len(sched) == r
    seen: set[int] = set()
    for st in sched:
        assert len(st.roots) == m
        # one root per chain in every step
        assert {chain_of(x, p, m) for x in st.roots} == set(range(m))
        seen.update(st.roots)
    assert seen == set(range(p)), "every rank must broadcast exactly once"
