"""Distributed Broadcast sequencer (paper §IV-A + Appendix A).

The Allgather schedule is a round-robin composition of Broadcasts: the P
participants are split into M parallel *broadcast chains*. When M divides P
every chain has length R = P/M and at schedule step i the active root group is

    G^i = { P_i, P_{R+i}, P_{2R+i}, ..., P_{(M-1)R+i} }        (Appendix A)

When M does not divide P the schedule generalizes with UNEVEN chains: the
first P mod M chains carry ceil(P/M) ranks, the rest carry floor(P/M) (the
last chains are shorter), so the step count is R = ceil(P/M) and the last
steps activate fewer than M roots — every rank still broadcasts exactly once.

Within a chain, members broadcast one-by-one (the activation signal travels
along the chain); across chains everything is concurrent. M controls the
aggregate multicast traffic in flight (fabric incast control); on a TPU torus
the analogue of "parallel multicast trees" is the set of ring directions, so
the performance-optimal choice intra-pod is full parallelism (see
core/collectives.py), while the faithful general-M schedule is used on the
switched pod axis.

This module is the pure rank arithmetic; the explicit schedule GRAPH (typed
Multicast/Unicast/Reduce ops + Activation edges) that the engines execute is
built from it by core/sched_ir.py.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class BroadcastStep:
    """One step of the Allgather schedule."""
    index: int
    roots: tuple[int, ...]          # active broadcasting processes G^i


def n_rounds(p: int, m: int) -> int:
    """Schedule length R = ceil(P/M) (== P/M when M | P)."""
    _check(p, m)
    return -(-p // m)


def chain_lengths(p: int, m: int) -> tuple[int, ...]:
    """Ranks per chain: the first P mod M chains take the extra rank, so the
    last chains are the shorter ones (even split when M | P)."""
    _check(p, m)
    r, rem = divmod(p, m)
    return (r + 1,) * rem + (r,) * (m - rem)


def _check(p: int, m: int) -> None:
    if not 1 <= m <= p:
        raise ValueError(f"need 1 <= M={m} <= P={p}")


def _chain_starts(p: int, m: int) -> tuple[int, ...]:
    starts, off = [], 0
    for ln in chain_lengths(p, m):
        starts.append(off)
        off += ln
    return tuple(starts)


def chain_of(rank: int, p: int, m: int) -> int:
    """Which chain a rank belongs to: chain c holds the contiguous block
    [start_c, start_c + len_c)."""
    assert 0 <= rank < p, (rank, p)
    starts = _chain_starts(p, m)
    for c in range(m - 1, -1, -1):
        if rank >= starts[c]:
            return c
    raise AssertionError(rank)


def chain_members(m_idx: int, p: int, m: int) -> tuple[int, ...]:
    start = _chain_starts(p, m)[m_idx]
    return tuple(range(start, start + chain_lengths(p, m)[m_idx]))


def active_group(step: int, p: int, m: int) -> tuple[int, ...]:
    """G^step per Appendix A, generalized to uneven chains: the step-th
    member of every chain still that long. For M | P this is exactly
    { step + j*R : j < M }."""
    r = n_rounds(p, m)
    if not 0 <= step < r:
        raise ValueError(f"step {step} out of range [0, {r})")
    starts = _chain_starts(p, m)
    lens = chain_lengths(p, m)
    return tuple(starts[c] + step for c in range(m) if lens[c] > step)


def allgather_schedule(p: int, m: int) -> list[BroadcastStep]:
    """The full R-step schedule; every rank roots exactly once."""
    return [BroadcastStep(i, active_group(i, p, m))
            for i in range(n_rounds(p, m))]


def activation_edges(p: int, m: int) -> list[tuple[int, int]]:
    """(from, to) pairs of the chain activation signal (§IV-A): when ``from``
    finishes multicasting it activates ``to`` — its successor in the chain."""
    edges = []
    for c in range(m):
        members = chain_members(c, p, m)
        edges += list(zip(members[:-1], members[1:]))
    return edges


def subgroup_assignment(n_subgroups: int, buffer_len: int) -> list[tuple[int, int]]:
    """Packet parallelism (§IV-C): split the send buffer into contiguous blocks,
    one per multicast subgroup / worker queue. Returns [start, end) per subgroup."""
    q, rem = divmod(buffer_len, n_subgroups)
    out, off = [], 0
    for i in range(n_subgroups):
        ln = q + (1 if i < rem else 0)
        out.append((off, off + ln))
        off += ln
    return out


def worker_split(n_subgroups: int, n_participants: int) -> tuple[int, int]:
    """Send/receive worker allocation (§IV-C discrepancy rule): the receive
    path handles (P-1)x the send-path bytes, so receive workers scale with
    the multicast subgroup count — but never beyond the P-1 peers that can
    be concurrently sending (extra workers past that would idle). One send
    worker serves all send queues. Paper example: 16 procs, 4 subgroups ->
    1 send worker / 4 receive workers."""
    assert n_subgroups >= 1 and n_participants >= 1, (n_subgroups,
                                                     n_participants)
    return 1, max(min(n_subgroups, n_participants - 1), 1)


def validate_schedule(p: int, m: int) -> None:
    """Invariants the hypothesis tests rely on (uneven chains included)."""
    sched = allgather_schedule(p, m)
    r = n_rounds(p, m)
    lens = chain_lengths(p, m)
    assert len(sched) == r
    assert sum(lens) == p
    assert max(lens) - min(lens) <= 1           # last chains at most 1 shorter
    seen: set[int] = set()
    for st in sched:
        live = {c for c in range(m) if lens[c] > st.index}
        assert len(st.roots) == len(live)
        # one root per still-active chain in every step
        assert {chain_of(x, p, m) for x in st.roots} == live
        assert not (set(st.roots) & seen)
        seen.update(st.roots)
    assert seen == set(range(p)), "every rank must broadcast exactly once"
