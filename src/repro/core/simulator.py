"""Protocol-level timing simulators (paper §III/§IV/§VI) — facade layer.

``simulate_broadcast`` and ``simulate_allgather`` are thin facades over the
Collective Schedule IR (core/sched_ir.py): each call builds the explicit
schedule graph (``build_broadcast_tree`` / ``build_allgather`` — typed
Multicast ops + §IV-A Activation edges) and hands it to ``sched_ir.execute``,
which lowers it onto the chosen fidelity. The per-collective flow
construction that used to live in this module IS the IR's fluid lowering
now; the facades reproduce the pre-IR results exactly (pinned by
tests/test_sched_ir.py).

Models, per chunk: root injection at send-link rate, fabric latency + adaptive
-routing jitter (out-of-order delivery), Bernoulli fabric drops, the leaf
worker pool (CPU or DPA threads; service = chunk/thread_tput), staging-ring
occupancy (RNR drops), cutoff timer, fetch-ring recovery, RNR barrier and the
final ring handshake. Produces the phase breakdown of Fig. 10, the throughput
curves of Fig. 11 and the drop-recovery behaviour the property tests verify.

Both simulators take an optional ``topology=`` (core/topology.py FatTree /
Torus2D): ranks are then placed on real hosts (``hosts=`` ids, default
0..P-1) and every transfer becomes a routed flow — the Broadcast is one
multicast tree flow per chain root, rate-limited by the most-contended fabric
link it crosses, M concurrent chains genuinely collide in the core, and the
per-leaf fabric latency scales with hop count. The same Engine run then
yields both the timing AND the per-link switch-port bytes
(result.link_bytes, Fig. 12) — there is no separate static counting pass.
Build the topology with b_host=fabric.b_link so the NIC and its fabric port
agree on line rate.

Both simulators also take ``fidelity=``:

  "fluid"  (default) the fluid lowering: drops are an aggregate Bernoulli
           thinning of the arrival stream and recovery is the closed-form
           fetch-ring term — fast, but the reliability protocol itself is
           not exercised.
  "packet" the packet lowering (core/packet.py machinery): MTU packets,
           per-Link loss models (``loss=`` — i.i.d. rate, or a
           packet.LossModel such as GilbertElliottLoss), per-receiver packed
           bitmaps, NACK aggregation and multicast retransmission rounds on
           the DPA worker pool. At loss 0 it reproduces the fluid times
           exactly (tests/test_packet.py pins the equivalence). The packet
           engine's DPA itself has two fidelities
           (``dpa_fidelity="scalar"|"event"``, forwarded): the scalar
           worker-pool rate, or the event-level progress-engine simulator of
           core/dpa_engine.py (per-CQE compute/stall cycles, per-core caps,
           LLC occupancy, protocol work stealing receive cycles).

``n_chains`` no longer has to divide P: the Appendix-A schedule generalizes
to uneven chains (the last chains are shorter — core/schedule.py).
"""
from __future__ import annotations

import numpy as np

from repro.core import sched_ir
from repro.core.engine import (  # noqa: F401  (re-exported public API)
    Engine,
    FabricParams,
    WorkerParams,
    worker_pool_completion,
    workers_from_dpa,
)
from repro.core.sched_ir import (  # noqa: F401  (re-exported public API)
    AllgatherResult,
    BcastResult,
    PhaseBreakdown,
    _chunking,
    _rnr_barrier,
)

FIDELITIES = ("fluid", "packet")


def simulate_broadcast(p: int, n_bytes: int, fabric: FabricParams,
                       workers: WorkerParams, rng: np.random.Generator,
                       root: int = 0, *, topology=None, hosts=None,
                       fidelity: str = "fluid", loss=None,
                       **packet_kw) -> BcastResult:
    """Reliable multicast Broadcast: build_broadcast_tree + execute.
    Without ``topology`` the datapath is the abstract root-injection link of
    the original model; with a core/topology.py Topology the root's stream
    is ONE multicast tree flow whose rate is set by the most-contended
    fabric link (switch replication), per-leaf latency scales with routed
    hop count, and result.link_bytes carries the per-link switch-port
    traffic of the same engine run. ``fidelity="packet"`` replays the run at
    MTU granularity with per-Link loss injection and NACK/retransmission
    recovery."""
    assert fidelity in FIDELITIES, fidelity
    sched = sched_ir.build_broadcast_tree(p, n_bytes, root)
    return sched_ir.execute(sched, fabric, workers, rng, fidelity=fidelity,
                            topology=topology, hosts=hosts, loss=loss,
                            **packet_kw)


def simulate_allgather(p: int, n_bytes: int, fabric: FabricParams,
                       workers: WorkerParams, rng: np.random.Generator,
                       n_chains: int = 1, *, topology=None,
                       hosts=None, fidelity: str = "fluid", loss=None,
                       **packet_kw) -> AllgatherResult:
    """Allgather = R generations of up to M concurrent Broadcasts (§IV-A):
    build_allgather + execute. Within a generation the chain roots multicast
    concurrently; the leaf receive path (link + worker pool) is the shared
    bottleneck; generations are chained by the Activation edges of the
    schedule graph.

    With ``topology=`` the chains are real multicast tree flows rooted at
    the Appendix-A round roots placed on fabric hosts: they collide on
    shared edge/agg/core links and on every leaf's ejection link, and
    result.link_bytes returns the same run's switch-port byte counters (the
    Fig. 12 measurement, no static pass). ``fidelity="packet"`` replays the
    generations at MTU granularity with per-Link loss and per-chain
    NACK/retransmission recovery."""
    assert fidelity in FIDELITIES, fidelity
    sched = sched_ir.build_allgather(p, n_bytes, n_chains)
    return sched_ir.execute(sched, fabric, workers, rng, fidelity=fidelity,
                            topology=topology, hosts=hosts, loss=loss,
                            **packet_kw)


def sweep_phase_breakdown(sizes: list[int], nodes: list[int],
                          fabric: FabricParams | None = None,
                          workers: WorkerParams | None = None,
                          seed: int = 0):
    """Fig. 10: fraction of protocol time per phase across scale/message size."""
    fabric = fabric or FabricParams(b_link=56e9 / 8)   # UCC testbed: 56 Gbit CX-3
    workers = workers or WorkerParams(n_recv_workers=1, thread_tput=9.0 * (1 << 30))
    out = []
    rng = np.random.default_rng(seed)
    for p in nodes:
        for n in sizes:
            res = simulate_allgather(p, n, fabric, workers, rng)
            ph = res.phases
            tot = ph.total()
            out.append({
                "nodes": p, "bytes": n,
                "rnr_frac": ph.rnr_sync / tot,
                "mcast_frac": ph.multicast / tot,
                "reliability_frac": ph.reliability / tot,
                "handshake_frac": ph.handshake / tot,
                "time": res.time,
            })
    return out
