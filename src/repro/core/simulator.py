"""Discrete-event timing simulator for the reliable multicast Broadcast /
Allgather protocol (paper §III/§IV/§VI).

Models, per chunk: root injection at send-link rate, fabric latency + adaptive
-routing jitter (out-of-order delivery), Bernoulli fabric drops, the leaf
worker pool (CPU or DPA threads; service = chunk/thread_tput), staging-ring
occupancy (RNR drops), cutoff timer, fetch-ring recovery, RNR barrier and the
final ring handshake. Produces the phase breakdown of Fig. 10, the throughput
curves of Fig. 11 and the drop-recovery behaviour the property tests verify.

The bandwidth timing (root injection, per-round leaf ingest under M concurrent
chains) runs on the shared fluid engine (core/engine.py); the leaf receive
queue uses its vectorized worker pool. FabricParams / WorkerParams live in
engine.py and are re-exported here for backwards compatibility.

Both simulators take an optional ``topology=`` (core/topology.py FatTree /
Torus2D): ranks are then placed on real hosts (``hosts=`` ids, default
0..P-1) and every transfer becomes a routed flow — the Broadcast is one
multicast tree flow per chain root, rate-limited by the most-contended fabric
link it crosses, M concurrent chains genuinely collide in the core, and the
per-leaf fabric latency scales with hop count. The same Engine run then
yields both the timing AND the per-link switch-port bytes
(result.link_bytes, Fig. 12) — there is no separate static counting pass.
Build the topology with b_host=fabric.b_link so the NIC and its fabric port
agree on line rate.

Both simulators also take ``fidelity=``:

  "fluid"  (default) this module's model: drops are an aggregate Bernoulli
           thinning of the arrival stream and recovery is the closed-form
           fetch-ring term — fast, but the reliability protocol itself is
           not exercised.
  "packet" the core/packet.py engine: MTU packets, per-Link loss models
           (``loss=`` — i.i.d. rate, or a packet.LossModel such as
           GilbertElliottLoss), per-receiver packed bitmaps, NACK
           aggregation and multicast retransmission rounds on the DPA
           worker pool. At loss 0 it reproduces the fluid times exactly
           (tests/test_packet.py pins the equivalence). The packet engine's
           DPA itself has two fidelities (``dpa_fidelity="scalar"|"event"``,
           forwarded): the scalar worker-pool rate, or the event-level
           progress-engine simulator of core/dpa_engine.py (per-CQE
           compute/stall cycles, per-core caps, LLC occupancy, protocol
           work stealing receive cycles).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core import protocol
from repro.core.engine import (  # noqa: F401  (re-exported public API)
    Engine,
    FabricParams,
    WorkerParams,
    worker_pool_completion,
    workers_from_dpa,
)

FIDELITIES = ("fluid", "packet")


@dataclass
class PhaseBreakdown:
    rnr_sync: float = 0.0
    multicast: float = 0.0
    reliability: float = 0.0
    handshake: float = 0.0

    def total(self) -> float:
        return self.rnr_sync + self.multicast + self.reliability + self.handshake


@dataclass
class BcastResult:
    completion: np.ndarray            # per-leaf completion time (s)
    phases: PhaseBreakdown
    delivered_fast: int
    recovered: int
    rnr_drops: int
    bytes_fast: int
    bytes_recovery: int
    bytes_total: int                  # conservation: fast + recovery == total
    link_bytes: dict[str, float] = field(default_factory=dict)
    # ^ routed mode only: live per-fabric-link bytes from the same engine run

    @property
    def time(self) -> float:
        return float(self.completion.max(initial=0.0))


def _chunking(n_bytes: int, mtu: int) -> tuple[int, int]:
    n_chunks = max(-(-n_bytes // mtu), 1)
    chunk = min(mtu, n_bytes) if n_bytes else mtu
    return n_chunks, chunk


def _rnr_barrier(p: int, fabric: FabricParams, workers: WorkerParams) -> float:
    # RNR barrier: recursive doubling (§V-A)
    rounds = int(np.ceil(np.log2(max(p, 2))))
    return rounds * (fabric.latency + workers.rnr_barrier_hop)


def simulate_broadcast(p: int, n_bytes: int, fabric: FabricParams,
                       workers: WorkerParams, rng: np.random.Generator,
                       root: int = 0, *, topology=None, hosts=None,
                       fidelity: str = "fluid", loss=None,
                       **packet_kw) -> BcastResult:
    """Reliable multicast Broadcast. Without ``topology`` the datapath is the
    abstract root-injection link of the original model; with a
    core/topology.py Topology the root's stream is ONE multicast tree flow
    whose rate is set by the most-contended fabric link (switch replication),
    per-leaf latency scales with routed hop count, and result.link_bytes
    carries the per-link switch-port traffic of the same engine run.
    ``fidelity="packet"`` replays the run at MTU granularity with per-Link
    loss injection and NACK/retransmission recovery (core/packet.py)."""
    assert fidelity in FIDELITIES, fidelity
    if fidelity == "packet":
        from repro.core import packet  # deferred: packet imports this module

        return packet.simulate_packet_broadcast(
            p, n_bytes, fabric, workers, rng, root, topology=topology,
            hosts=hosts, loss=loss, **packet_kw)
    assert loss is None, "loss models require fidelity='packet'"
    # same footgun: dpa_fidelity=/dpa=/... silently ignored would let a
    # caller believe the event DPA (or any packet option) was simulated
    assert not packet_kw, \
        f"{sorted(packet_kw)} require fidelity='packet'"
    n_chunks, chunk = _chunking(n_bytes, fabric.mtu)
    t_rnr = _rnr_barrier(p, fabric, workers)

    eng = Engine()
    if topology is not None:
        hosts = list(hosts) if hosts is not None else list(range(p))
        assert len(hosts) == p, (len(hosts), p)
        topology.reset()
        tree = topology.multicast_tree(hosts[root], hosts)
        flow = eng.submit_tree(tree, n_chunks * chunk, t_start=t_rnr, tag="mcast")
        hop_lat = [len(topology.route(hosts[root], hosts[leaf])) * fabric.latency
                   for leaf in range(p)]
    else:
        # abstract mode: a single flow on the root's send link, one hop
        eng.add_link("root.send", fabric.b_link)
        flow = eng.submit("root.send", n_chunks * chunk, t_start=t_rnr)
        hop_lat = [fabric.latency] * p
    eng.run()
    inject = flow.chunk_times(n_chunks, chunk)
    service = chunk / workers.thread_tput

    completion = np.zeros(p)
    recovered_total = 0
    rnr_total = 0
    fast_total = 0
    t_mcast_end = t_rnr
    t_rel_end = 0.0

    cutoff = t_rnr + protocol.cutoff_time(n_bytes, fabric.b_link, fabric.alpha)

    for leaf in range(p):
        if leaf == root:
            completion[leaf] = inject[-1]
            continue
        delay = hop_lat[leaf] + rng.uniform(0.0, fabric.jitter, size=n_chunks)
        dropped = rng.random(n_chunks) < fabric.p_drop
        arrivals = np.sort((inject + delay)[~dropped])
        done, rnr = worker_pool_completion(
            arrivals, workers.n_recv_workers, service, workers.staging_chunks
        )
        rnr_total += rnr
        fast = n_chunks - int(dropped.sum()) - rnr
        fast_total += fast
        t_fast = done[-1] if done.size else t_rnr
        missing = int(dropped.sum()) + rnr
        if missing:
            # fetch ring (§III-C): wait for cutoff, then selective RDMA reads
            # from the left neighbour (holder is >= left neighbour or root).
            t0 = max(t_fast, cutoff)
            t_fetch = t0 + missing * (2 * fabric.latency + chunk / fabric.b_link)
            recovered_total += missing
            completion[leaf] = t_fetch
            t_rel_end = max(t_rel_end, t_fetch - t0)
        else:
            completion[leaf] = t_fast
        t_mcast_end = max(t_mcast_end, t_fast)

    # final handshake: send final to left, need final from right (§III-C)
    shifted = np.roll(completion, -1)
    completion = np.maximum(completion, shifted) + fabric.latency

    phases = PhaseBreakdown(
        rnr_sync=t_rnr,
        multicast=t_mcast_end - t_rnr,
        reliability=t_rel_end,
        handshake=fabric.latency,
    )
    return BcastResult(
        completion=completion,
        phases=phases,
        delivered_fast=fast_total,
        recovered=recovered_total,
        rnr_drops=rnr_total,
        bytes_fast=fast_total * chunk,
        bytes_recovery=recovered_total * chunk,
        bytes_total=(p - 1) * n_chunks * chunk,
        link_bytes=eng.link_bytes() if topology is not None else {},
    )


@dataclass
class AllgatherResult:
    time: float
    phases: PhaseBreakdown
    recovered: int
    bytes_fast: int
    bytes_recovery: int
    bytes_total: int
    per_rank_recv_tput: float         # (P-1)*N / time  (Fig. 11 metric)
    link_bytes: dict[str, float] = field(default_factory=dict)
    # ^ routed mode only: live per-fabric-link bytes from the same engine run


def simulate_allgather(p: int, n_bytes: int, fabric: FabricParams,
                       workers: WorkerParams, rng: np.random.Generator,
                       n_chains: int = 1, *, topology=None,
                       hosts=None, fidelity: str = "fluid", loss=None,
                       **packet_kw) -> AllgatherResult:
    """Allgather = R sequential rounds of M concurrent Broadcasts (§IV-A).
    Within a round the M chain roots multicast concurrently; the leaf receive
    path (link + worker pool) is the shared bottleneck — modeled as M flows
    contending for the leaf's ejection link in the fluid engine; rounds are
    chained by the activation signal.

    With ``topology=`` the M chains are real multicast tree flows rooted at
    the Appendix-A round roots G^r = {r, R+r, 2R+r, ...} placed on fabric
    hosts: they collide on shared edge/agg/core links and on every leaf's
    ejection link, and result.link_bytes returns the same run's switch-port
    byte counters (the Fig. 12 measurement, no static pass).
    ``fidelity="packet"`` replays the rounds at MTU granularity with
    per-Link loss and per-chain NACK/retransmission recovery
    (core/packet.py)."""
    assert fidelity in FIDELITIES, fidelity
    if fidelity == "packet":
        from repro.core import packet  # deferred: packet imports this module

        return packet.simulate_packet_allgather(
            p, n_bytes, fabric, workers, rng, n_chains, topology=topology,
            hosts=hosts, loss=loss, **packet_kw)
    assert loss is None, "loss models require fidelity='packet'"
    assert not packet_kw, \
        f"{sorted(packet_kw)} require fidelity='packet'"
    assert p % n_chains == 0
    rounds = p // n_chains
    n_chunks, chunk = _chunking(n_bytes, fabric.mtu)
    service = chunk / workers.thread_tput

    t_rnr = _rnr_barrier(p, fabric, workers)

    eng = Engine()
    if topology is not None:
        hosts = list(hosts) if hosts is not None else list(range(p))
        assert len(hosts) == p, (len(hosts), p)
        topology.reset()
    else:
        eng.add_link("leaf.recv", fabric.b_link)

    t = t_rnr
    recovered_total = 0
    fast_bytes = 0
    rec_bytes = 0
    mcast_time = 0.0
    rel_time = 0.0
    for r in range(rounds):
        m = n_chains
        total_chunks = m * n_chunks
        if topology is not None:
            # Appendix A: round roots G^r multicast concurrently through the
            # fabric; each tree flow's rate is min-share over its edges, so
            # chains genuinely collide in the core and at every ejection port
            roots = [hosts[i] for i in range(p) if i % rounds == r]
            flows = [
                eng.submit_tree(topology.multicast_tree(root, hosts),
                                n_chunks * chunk, t_start=t, tag=f"chain{root}")
                for root in roots
            ]
        else:
            # m chain roots inject concurrently; the leaf's ejection link is
            # the shared resource — m equal flows, each chain rate b_link/m
            flows = [
                eng.submit("leaf.recv", n_chunks * chunk, t_start=t, tag=f"chain{c}")
                for c in range(m)
            ]
        eng.run()
        arrive_spacing = np.sort(
            np.concatenate([f.chunk_times(n_chunks, chunk) for f in flows])
        )
        delay = fabric.latency + rng.uniform(0.0, fabric.jitter, size=total_chunks)
        dropped = rng.random(total_chunks) < fabric.p_drop
        arrivals = np.sort((arrive_spacing + delay)[~dropped])
        done, rnr = worker_pool_completion(
            arrivals, workers.n_recv_workers, service, workers.staging_chunks
        )
        t_fast = done[-1] if done.size else t
        missing = int(dropped.sum()) + rnr
        cutoff = t + protocol.cutoff_time(m * n_bytes, fabric.b_link,
                                          fabric.alpha)
        t_round_end = t_fast
        if missing:
            t0 = max(t_fast, cutoff)
            t_round_end = t0 + missing * (2 * fabric.latency + chunk / fabric.b_link)
            rel_time += t_round_end - t0
            recovered_total += missing
        mcast_time += max(t_fast - t, 0.0)
        fast_bytes += (total_chunks - missing) * chunk
        rec_bytes += missing * chunk
        # activation signal to the next root in every chain; the engine clock
        # can only run ahead of t_round_end if every chunk was dropped
        t = max(t_round_end + fabric.latency, eng.now)

    t_done = t + fabric.latency  # final handshake
    phases = PhaseBreakdown(
        rnr_sync=t_rnr, multicast=mcast_time, reliability=rel_time,
        handshake=fabric.latency,
    )
    total = (p - 1) * n_bytes
    return AllgatherResult(
        time=t_done,
        phases=phases,
        recovered=recovered_total,
        bytes_fast=fast_bytes,
        bytes_recovery=rec_bytes,
        bytes_total=p * n_chunks * chunk,
        per_rank_recv_tput=total / t_done,
        link_bytes=eng.link_bytes() if topology is not None else {},
    )


def sweep_phase_breakdown(sizes: list[int], nodes: list[int],
                          fabric: FabricParams | None = None,
                          workers: WorkerParams | None = None,
                          seed: int = 0):
    """Fig. 10: fraction of protocol time per phase across scale/message size."""
    fabric = fabric or FabricParams(b_link=56e9 / 8)   # UCC testbed: 56 Gbit CX-3
    workers = workers or WorkerParams(n_recv_workers=1, thread_tput=9.0 * (1 << 30))
    out = []
    rng = np.random.default_rng(seed)
    for p in nodes:
        for n in sizes:
            res = simulate_allgather(p, n, fabric, workers, rng)
            ph = res.phases
            tot = ph.total()
            out.append({
                "nodes": p, "bytes": n,
                "rnr_frac": ph.rnr_sync / tot,
                "mcast_frac": ph.multicast / tot,
                "reliability_frac": ph.reliability / tot,
                "handshake_frac": ph.handshake / tot,
                "time": res.time,
            })
    return out
