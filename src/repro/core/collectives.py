"""The paper's collective algorithms as jax-native shard_map kernels.

Mapping (DESIGN.md §2): hardware multicast does not exist on a TPU torus, so
"bandwidth-optimal" is restated per-link: every byte crosses every ring link
at most once per direction. The pieces:

  pipelined_broadcast   constant-time Broadcast (§III): chain-pipelined chunks;
                        T ~ (C + P - 2)/C * N/B -> N/B, independent of P.
  bcast_allgather       Allgather as composition of Broadcasts with M parallel
                        chains (§IV-A / Appendix A). M=P degenerates to the
                        fully-pipelined ring; M<P keeps the chain-sequential
                        activation semantics (used on the switched pod axis).
  ring_allgather        the degenerate M=P schedule (baseline).
  bidi_ring_allgather   Fig. 1's "two parallel multicast trees" analogue: the
                        buffer is split across both ring directions (M=2
                        direction-chains), halving completion time on
                        full-duplex ICI links.
  ring_reduce_scatter / bidi_ring_reduce_scatter
  concurrent_ag_rs      Insight 2: AG streams one direction while RS streams
                        the opposite direction -> no shared link bottleneck
                        for interleaved FSDP collectives.

All functions with the ``_local`` suffix run *inside* shard_map (per-device
shards + lax.ppermute); ``make_*`` wrappers build jitted global-array versions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat


def _perm(p: int, direction: int):
    return [(i, (i + direction) % p) for i in range(p)]


# ----------------------------------------------------------------- broadcast


def pipelined_broadcast_local(x: jax.Array, axis: str, *, root: int = 0,
                              n_chunks: int = 8) -> jax.Array:
    """Chain-pipelined broadcast of ``x`` (defined on root; other devices pass
    anything of the same shape). Returns the full buffer everywhere.

    Per-link bytes: N * (1 + (P-2)/C); schedule time constant in P for C >> P.
    """
    p = compat.axis_size(axis)
    idx = lax.axis_index(axis)
    dist = (idx - root) % p
    n = x.shape[0]
    assert n % n_chunks == 0, (n, n_chunks)
    xc = x.reshape(n_chunks, n // n_chunks)
    steps = n_chunks + p - 2

    def step(carry, t):
        out, cur = carry
        send = jnp.where(dist == 0, xc[jnp.clip(t, 0, n_chunks - 1)], cur)
        recv = lax.ppermute(send, axis, _perm(p, +1))
        c_idx = t - (dist - 1)
        write = (dist > 0) & (c_idx >= 0) & (c_idx < n_chunks)
        ci = jnp.clip(c_idx, 0, n_chunks - 1)
        out = out.at[ci].set(jnp.where(write, recv, out[ci]))
        return (out, recv), None

    out0 = jnp.where(dist == 0, xc, jnp.zeros_like(xc))
    (out, _), _ = lax.scan(step, (out0, jnp.zeros_like(xc[0])), jnp.arange(steps))
    return out.reshape(n)


# ----------------------------------------------------------------- allgather


def ring_allgather_local(x: jax.Array, axis: str, *, direction: int = +1) -> jax.Array:
    """Unidirectional ring allgather: P-1 forwarding steps. x: (n,) shard.
    Returns (P*n,) in rank order."""
    p = compat.axis_size(axis)
    idx = lax.axis_index(axis)
    out = jnp.zeros((p,) + x.shape, x.dtype).at[idx].set(x)

    def step(carry, s):
        out, cur = carry
        recv = lax.ppermute(cur, axis, _perm(p, direction))
        src = (idx - direction * (s + 1)) % p
        out = out.at[src].set(recv)
        return (out, recv), None

    (out, _), _ = lax.scan(step, (out, x), jnp.arange(p - 1))
    return out.reshape((p * x.shape[0],) + x.shape[1:])


def bidi_ring_allgather_local(x: jax.Array, axis: str) -> jax.Array:
    """Bidirectional ring allgather (Fig. 1's two trees): each half-shard
    travels one direction; both directions are concurrently active, so the
    completion time halves on full-duplex links. x: (n,), n even."""
    p = compat.axis_size(axis)
    idx = lax.axis_index(axis)
    n = x.shape[0]
    half = n // 2
    xa, xb = x[:half], x[half:]
    out_a = jnp.zeros((p, half), x.dtype).at[idx].set(xa)
    out_b = jnp.zeros((p, n - half), x.dtype).at[idx].set(xb)

    def step(carry, s):
        oa, ob, ca, cb = carry
        ra = lax.ppermute(ca, axis, _perm(p, +1))
        rb = lax.ppermute(cb, axis, _perm(p, -1))
        oa = oa.at[(idx - (s + 1)) % p].set(ra)
        ob = ob.at[(idx + (s + 1)) % p].set(rb)
        return (oa, ob, ra, rb), None

    (out_a, out_b, _, _), _ = lax.scan(
        step, (out_a, out_b, xa, xb), jnp.arange(p - 1)
    )
    return jnp.concatenate([out_a, out_b], axis=-1).reshape(p * n)


def bcast_allgather_local(x: jax.Array, axis: str, *, n_chains: int) -> jax.Array:
    """Allgather as a composition of Broadcasts with M = n_chains parallel
    chains (Appendix A). Rounds are sequential (chain activation semantics);
    within a round the M chain roots broadcast concurrently around the ring.

    M = P is the fully-parallel degenerate case == ring allgather.
    """
    p = compat.axis_size(axis)
    assert p % n_chains == 0, (p, n_chains)
    rounds = p // n_chains
    idx = lax.axis_index(axis)
    out = jnp.zeros((p,) + x.shape, x.dtype).at[idx].set(x)

    for r in range(rounds):
        # Appendix A: G^r = {r, R + r, 2R + r, ...}; roots inject their shard
        is_root = (idx % rounds) == r
        cur = jnp.where(is_root, x, jnp.zeros_like(x))

        def step(carry, s):
            out, cur = carry
            recv = lax.ppermute(cur, axis, _perm(p, +1))
            src = (idx - (s + 1)) % p
            active = (src % rounds) == r
            out = out.at[src].set(jnp.where(active, recv, out[src]))
            return (out, recv), None

        (out, _), _ = lax.scan(step, (out, cur), jnp.arange(p - 1))
    return out.reshape((p * x.shape[0],) + x.shape[1:])


# ------------------------------------------------------------ reduce-scatter


def ring_reduce_scatter_local(x: jax.Array, axis: str, *, direction: int = +1) -> jax.Array:
    """Ring reduce-scatter. x: (P*n,) full per-device contribution; returns
    (n,) — the sum over devices of shard idx."""
    p = compat.axis_size(axis)
    idx = lax.axis_index(axis)
    n = x.shape[0] // p
    xv = x.reshape((p, n) + x.shape[1:])
    cur = xv[(idx - direction) % p]

    def step(cur, t):
        recv = lax.ppermute(cur, axis, _perm(p, direction))
        cur = recv + xv[(idx - direction * (t + 2)) % p]
        return cur, None

    cur, _ = lax.scan(step, cur, jnp.arange(p - 1))
    return cur


def bidi_ring_reduce_scatter_local(x: jax.Array, axis: str) -> jax.Array:
    """Both directions carry half the shard each."""
    p = compat.axis_size(axis)
    n = x.shape[0] // p
    half = n // 2
    xv = x.reshape(p, n)
    xa = xv[:, :half].reshape(p * half)
    xb = xv[:, half:].reshape(p * (n - half))
    ra = ring_reduce_scatter_local(xa, axis, direction=+1)
    rb = ring_reduce_scatter_local(xb, axis, direction=-1)
    return jnp.concatenate([ra, rb], axis=0)


# ------------------------------------------- Insight 2: direction-split AG+RS


def concurrent_ag_rs_local(ag_shard: jax.Array, rs_full: jax.Array, axis: str):
    """Concurrently progress an Allgather (clockwise) and a Reduce-Scatter
    (counter-clockwise). The two ppermute streams use opposite ICI directions,
    so — like the paper's {AG_mc, RS_inc} pairing — they do not share a link
    bottleneck. Returns (ag_full (P*n,), rs_shard (m,))."""
    p = compat.axis_size(axis)
    idx = lax.axis_index(axis)
    n = ag_shard.shape[0]
    m = rs_full.shape[0] // p
    rsv = rs_full.reshape(p, m)

    ag_out = jnp.zeros((p, n), ag_shard.dtype).at[idx].set(ag_shard)
    rs_cur = rsv[(idx + 1) % p]

    def step(carry, s):
        ag_out, ag_cur, rs_cur = carry
        ag_recv = lax.ppermute(ag_cur, axis, _perm(p, +1))
        rs_recv = lax.ppermute(rs_cur, axis, _perm(p, -1))
        ag_out = ag_out.at[(idx - (s + 1)) % p].set(ag_recv)
        rs_cur = rs_recv + rsv[(idx + s + 2) % p]
        return (ag_out, ag_recv, rs_cur), None

    (ag_out, _, rs_cur), _ = lax.scan(
        step, (ag_out, ag_shard, rs_cur), jnp.arange(p - 1)
    )
    return ag_out.reshape(p * n), rs_cur


# --------------------------------------------------------------- jit wrappers


def _flat_spec(axes):
    return P(axes)


def make_allgather(mesh: Mesh, axis: str, mode: str = "bidi", *, n_chains: int | None = None):
    """Global-array allgather over ``axis``: (P*n,) sharded -> (P*n,) replicated
    on that axis. mode: ring | bidi | bcast | xla."""
    if mode == "xla":
        def fn(x):
            return lax.with_sharding_constraint(x, NamedSharding(mesh, P()))
        return jax.jit(fn)

    local = {
        "ring": functools.partial(ring_allgather_local, axis=axis),
        "bidi": functools.partial(bidi_ring_allgather_local, axis=axis),
        "bcast": functools.partial(
            bcast_allgather_local, axis=axis,
            n_chains=n_chains or mesh.shape[axis],
        ),
    }[mode]
    sm = compat.shard_map(
        local, mesh=mesh, in_specs=P(axis), out_specs=P(), check_vma=False
    )
    return jax.jit(sm)


def make_reduce_scatter(mesh: Mesh, axis: str, mode: str = "bidi"):
    """(P*n,) per-device full contributions (unsharded dim) -> (P*n,) sharded sum."""
    local = {
        "ring": functools.partial(ring_reduce_scatter_local, axis=axis),
        "bidi": functools.partial(bidi_ring_reduce_scatter_local, axis=axis),
    }[mode]
    sm = compat.shard_map(
        local, mesh=mesh, in_specs=P(), out_specs=P(axis), check_vma=False
    )
    return jax.jit(sm)


def make_broadcast(mesh: Mesh, axis: str, *, root: int = 0, n_chunks: int = 8):
    """Global (P*n,) sharded input -> (n,) output = root's shard, replicated."""
    local = functools.partial(
        pipelined_broadcast_local, axis=axis, root=root, n_chunks=n_chunks
    )
    sm = compat.shard_map(local, mesh=mesh, in_specs=P(axis), out_specs=P(), check_vma=False)
    return jax.jit(sm)
