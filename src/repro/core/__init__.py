"""The paper's primary contribution: bandwidth-optimal Broadcast/Allgather
collectives — the Appendix-A broadcast sequencer, jax shard_map collective
kernels, fat-tree/torus traffic cost models, the reliable-broadcast protocol
simulator, the packet-level reliability engine (packet.py), the shared
discrete-event contention engine (engine.py), and the DPA SmartNIC offload
model.

Submodules load lazily (PEP 562): collectives pulls in jax, while the
simulator/protocol/packet/engine path is numpy-only — importing the package
for the discrete-event side must not pay (or require) the jax import."""
import importlib

__all__ = ["collectives", "cost_model", "dpa", "dpa_engine", "engine",
           "packet", "protocol", "sched_ir", "sched_search", "schedule",
           "simulator", "topology"]


def __getattr__(name):
    if name in __all__:
        return importlib.import_module(f"repro.core.{name}")
    raise AttributeError(f"module 'repro.core' has no attribute {name!r}")
