"""The paper's primary contribution: bandwidth-optimal Broadcast/Allgather
collectives — the Appendix-A broadcast sequencer, jax shard_map collective
kernels, fat-tree/torus traffic cost models, the reliable-broadcast protocol
simulator, the shared discrete-event contention engine (engine.py), and the
DPA SmartNIC offload model."""

from repro.core import collectives, cost_model, engine, schedule, topology

__all__ = ["collectives", "cost_model", "engine", "schedule", "topology"]
