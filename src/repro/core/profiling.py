"""Opt-in per-phase wall-clock accounting for the simulator hot paths.

``benchmarks/run.py --profile`` enables it; the accumulated per-phase
seconds land in the report JSON under ``"profile"`` so a wall-clock
regression in BENCH_*.json is attributable to a phase (engine max-min
solves / leaf pool solves / RNG draws / bitmap packing) instead of a
number that just got bigger.

Disabled (the default) the hot paths pay a single module-attribute bool
check — no perf_counter calls, no dict updates. The instrumented choke
points are the four phase owners:

  engine_solve  Engine max-min rate solves (full + incremental component)
  pool_solve    worker pool completion scans (engine.py / kernels/pool_np)
  rng           packet-engine loss-mask + jitter sampling
  packing       bitmap pack/popcount + merged-row padding/sorting

Not thread-safe by design: the simulator is single-threaded and the
search process pool profiles per worker (child accumulators die with the
worker — only the parent's phases are reported).
"""
from __future__ import annotations

import time
from contextlib import contextmanager

ENABLED = False

PHASES = ("engine_solve", "pool_solve", "rng", "packing")

_acc: dict[str, float] = {}
_calls: dict[str, int] = {}


def enable() -> None:
    global ENABLED
    ENABLED = True


def disable() -> None:
    global ENABLED
    ENABLED = False


def reset() -> None:
    _acc.clear()
    _calls.clear()


def record(phase: str, seconds: float) -> None:
    _acc[phase] = _acc.get(phase, 0.0) + seconds
    _calls[phase] = _calls.get(phase, 0) + 1


@contextmanager
def phase(name: str):
    """Time a block into ``name`` — no-op (yield only) when disabled."""
    if not ENABLED:
        yield
        return
    t0 = time.perf_counter()
    try:
        yield
    finally:
        record(name, time.perf_counter() - t0)


def report() -> dict[str, dict[str, float | int]]:
    """{phase: {"wall_s": seconds, "calls": n}} for every phase seen."""
    return {name: {"wall_s": round(_acc[name], 4), "calls": _calls[name]}
            for name in sorted(_acc)}
