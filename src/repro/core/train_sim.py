"""GPT-scale training-step co-simulation: compute rooflines x collective engines.

``simulate_training_run`` predicts step time, bubble fraction and MFU for a
REGISTRY model (configs/registry.py) trained with FSDP over a real or
abstract fabric, at any of the three fidelities of the collective stack:

  analytic   a closed-form lower bound: the engine's prefetch/re-gather
             timeline recurrence with each AG/RS leg replaced by an
             admissible per-flow bound (single-flow bytes at the fabric's
             maximum link capacity) — analytic <= fluid <= packet by
             construction, mirroring sched_ir's fidelity ordering.
  fluid      engine.simulate_fsdp_step with heterogeneous per-layer
             profiles (LayerProfile): max-min fair flows on the abstract
             NIC or a routed core/topology.py fabric.
  packet     fluid + the per-layer NACK/retransmission loss overlay.

The per-layer profiles come from the same first-principles cost model the
roofline uses (launch/analytic_costs.py): per-layer FLOPs and HBM bytes at
the shape's token count give roofline fwd/bwd seconds at ``ChipConstants``
(default TPU v5e: 197 TFLOP/s bf16, 819 GB/s HBM); per-layer parameter
bytes give the FSDP AG/RS wire volume. Layers are genuinely heterogeneous:
the input embedding rides with the first layer and the LM head (flops and
params) with the last, so the schedule sees real volume skew.

Parallelism mix: ``n_hosts`` fabric hosts are split into ``pp`` pipeline
stages of ``dp = n_hosts // pp`` FSDP ranks; ``tp`` chips per host split
every matmul (TP traffic stays on intra-host ICI and is not put on the
fabric — the fabric simulates the DP axis, the paper's setting). The
heaviest stage (max sum of fwd+bwd seconds) is co-simulated and the step
composes 1F1B-style: step = (grad_accum + pp - 1) * stage_micro_time,
pipeline bubble = (pp - 1) / (grad_accum + pp - 1). Each microbatch pays
the full AG+RS (a slight overcount for grad_accum > 1: real runs skip the
RS on non-final microbatches), which keeps MFU conservative.

MFU = useful model FLOPs per step / (step_time * n_devices * peak): always
in (0, 1] because every layer's roofline seconds are >= its implemented
FLOPs at peak and the simulated stage is the compute-heaviest one.

``search=`` drops the schedule searcher into the loop: the winning
searched allgather for the stage's largest layer projects an alternative
step time through the same analytic recurrence (searched_step_time), with
the full sched_search.SearchResult attached.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.engine import (FSDP_POLICIES, FabricParams, FsdpStepResult,
                               LayerProfile, WorkerParams,
                               simulate_fsdp_step)

TRAIN_FIDELITIES = ("analytic", "fluid", "packet")


@dataclass(frozen=True)
class ChipConstants:
    """Roofline chip model (benchmarks/roofline.py constants, but
    configurable so other accelerators can be swept)."""
    name: str = "tpu_v5e"
    peak_flops: float = 197e12        # bf16 FLOP/s per chip
    hbm_bw: float = 819e9             # HBM bytes/s per chip
    opt_bytes_per_param: float = 8.0  # f32 Adam m+v (matches cell_cost)


TPU_V5E = ChipConstants()


@dataclass
class TrainingRunResult:
    model: str
    shape: str
    n_hosts: int
    dp: int
    tp: int
    pp: int
    grad_accum: int
    policy: str
    fidelity: str
    loss_rate: float | None
    step_time: float                  # full step: (ga + pp - 1) microbatches
    micro_time: float                 # one microbatch on the heaviest stage
    compute_time: float               # useful compute seconds per step
    bubble_fraction: float            # 1 - compute_time / step_time
    pipeline_bubble_fraction: float   # (pp - 1) / (ga + pp - 1)
    mfu: float                        # useful FLOPs / (step * devices * peak)
    model_flops_per_step: float       # useful (MODEL_FLOPS) per optimizer step
    n_devices: int                    # n_hosts * tp chips
    layer_profiles: list[LayerProfile] = field(repr=False, default_factory=list)
    stage_span: tuple[int, int] = (0, 0)   # [lo, hi) layer slice simulated
    fsdp: FsdpStepResult | None = field(repr=False, default=None)
    searched: object | None = field(repr=False, default=None)
    searched_step_time: float | None = None


def _resolve_model(model):
    if isinstance(model, str):
        from repro.configs.registry import get_model_config  # lazy: configs

        return get_model_config(model)
    return model


def _resolve_shape(shape):
    if isinstance(shape, str):
        from repro.configs.registry import get_shape  # lazy: configs

        return get_shape(shape)
    return shape


def derive_layer_profiles(model, shape="train_4k", *, dp: int, tp: int = 1,
                          grad_accum: int = 1, remat: str = "full",
                          chip: ChipConstants = TPU_V5E,
                          dtype_bytes: float = 2.0) -> list[LayerProfile]:
    """Per-layer (fwd_s, bwd_s, layer_bytes) from a registry model at a
    training shape — the analytic_costs.py formulas resolved per layer.

    Compute: one microbatch's tokens split over dp ranks and tp chips;
    fwd seconds = max(FLOPs/peak, HBM/bw) roofline, bwd = 2x FLOPs
    (+1x recompute under remat="full") with the backward's activation
    traffic. Comm: the layer's parameter bytes after the TP split (the
    FSDP-sharded volume of collective_cost's ``pbytes``); embedding rides
    with layer 0, the LM head with the last layer."""
    cfg = _resolve_model(model)
    shp = _resolve_shape(shape)
    assert shp.kind == "train", f"training shapes only, got {shp.kind!r}"
    assert dp >= 1 and tp >= 1 and grad_accum >= 1
    from repro.launch.analytic_costs import _fwd_flops, _n_layers_eff  # lazy
    from repro.models import count_params_analytic  # lazy: model builders

    n_layers = _n_layers_eff(cfg)
    batch_micro = shp.global_batch / grad_accum
    toks_micro = batch_micro * shp.seq_len
    toks_local = toks_micro / dp

    # ---- FLOPs: split the implemented forward into body layers + LM head
    _, impl_fwd = _fwd_flops(cfg, shp.seq_len, batch_micro)
    head_flops = 2.0 * cfg.d_model * cfg.vocab_size * toks_micro
    body_flops_layer = max(impl_fwd - head_flops, 0.0) / n_layers
    bwd_mult = 2.0 + (1.0 if remat == "full" else 0.0)

    # ---- parameter bytes: body layers + embedding/head extremes
    params_total = count_params_analytic(cfg)
    emb_params = cfg.d_model * cfg.vocab_size * (1 if cfg.tie_embeddings
                                                 else 2)
    emb_params = min(emb_params, params_total // 2)   # smoke-model guard
    body_bytes_layer = (params_total - emb_params) / n_layers * dtype_bytes
    emb_half = emb_params / (1 if cfg.tie_embeddings else 2) * dtype_bytes

    bpe = dtype_bytes
    out: list[LayerProfile] = []
    for i in range(n_layers):
        flops = body_flops_layer
        lbytes = body_bytes_layer
        if i == 0:
            lbytes += emb_half                         # input embedding
        if i == n_layers - 1:
            flops += head_flops
            if not cfg.tie_embeddings:
                lbytes += emb_half                     # LM head
        lbytes /= tp                                   # TP split first
        fwd_flops_dev = flops / (dp * tp)
        # HBM per device: gathered weights re-read, ~2 activation passes
        # forward / ~6 backward (cell_cost's 8 total), optimizer r/w on
        # the local shard during the backward's update
        acts = toks_local * cfg.d_model * bpe
        hbm_fwd = lbytes + 2.0 * acts
        hbm_bwd = (lbytes * (2.0 if remat == "full" else 1.0) + 6.0 * acts
                   + lbytes / dp * (2.0 + chip.opt_bytes_per_param / bpe))
        fwd_s = max(fwd_flops_dev / chip.peak_flops, hbm_fwd / chip.hbm_bw)
        bwd_s = max(bwd_mult * fwd_flops_dev / chip.peak_flops,
                    hbm_bwd / chip.hbm_bw)
        out.append(LayerProfile(fwd_s, bwd_s, lbytes))
    return out


# ------------------------------------------------------- analytic timeline


def _fixed_timeline(fwd, bwd, t_ag, t_rs, sync: float) -> tuple[float, float]:
    """The engine's prefetch/re-gather recurrence with FIXED comm legs —
    the analytic fidelity (legs are admissible per-flow lower bounds) and
    the searched-allgather projection both reuse it. Returns
    (step_time, t_fwd_end)."""
    n = len(fwd)
    ready = [0.0] * n
    ready[0] = t_ag[0] + sync
    t = 0.0
    for i in range(n):
        start = max(t, ready[i])
        if i + 1 < n:
            ready[i + 1] = start + t_ag[i + 1] + sync
        t = start + fwd[i]
    t_fwd = t
    ready_b = [0.0] * n
    ready_b[n - 1] = t_fwd + t_ag[n - 1] + sync
    rs_done = t
    for i in range(n - 1, -1, -1):
        start = max(t, ready_b[i])
        if i - 1 >= 0:
            ready_b[i - 1] = start + t_ag[i - 1] + sync
        t = start + bwd[i]
        rs_done = max(rs_done, t + t_rs[i])
    return max(t, rs_done), t_fwd


def _analytic_legs(profiles, p: int, policy: str, fabric: FabricParams,
                   topology) -> tuple[list[float], list[float], float]:
    """(t_ag, t_rs, bw) per layer: single-flow bytes at the fabric's max
    link capacity. Every submitted AG/RS set contains a flow carrying at
    least these bytes and no flow can stream faster than the fastest link,
    so eng.wait(...) >= submit + leg — the fluid step dominates the fixed
    timeline leg-for-leg (analytic <= fluid)."""
    if topology is None:
        bw = fabric.b_link
        # abstract naive: the single shared-medium flow carries send+recv
        ag_mult = rs_mult = (2.0 if policy == "naive" else 1.0)
        ag_of = rs_of = (lambda g, s: g)
    else:
        bw = max(topology.tier_capacities().values())
        ag_mult = rs_mult = 1.0
        if policy == "naive":
            ag_of = rs_of = (lambda g, s: g)      # ring flows carry gather
        elif policy == "mcast":
            ag_of = (lambda g, s: s)              # one mcast tree: a shard
            rs_of = (lambda g, s: g)              # ring RS still gathers
        else:
            ag_of = rs_of = (lambda g, s: s)      # agg trees carry shards
    t_ag, t_rs = [], []
    for lp in profiles:
        g = (p - 1) / p * lp.layer_bytes
        s = lp.layer_bytes / p
        t_ag.append(ag_mult * ag_of(g, s) / bw)
        t_rs.append(rs_mult * rs_of(g, s) / bw)
    return t_ag, t_rs, bw


def _ag_sync(p: int, policy: str, n_chains: int, fabric: FabricParams) -> float:
    if policy == "naive":
        return (p - 1) * fabric.latency
    return max(p // max(n_chains, 1), 1) * fabric.latency


# ------------------------------------------------------------ entry point


def simulate_training_run(model, shape="train_4k", *, n_hosts: int,
                          policy: str = "split", tp: int = 1, pp: int = 1,
                          grad_accum: int = 1, remat: str = "full",
                          topology=None, hosts=None,
                          fabric: FabricParams | None = None,
                          workers: WorkerParams | None = None,
                          fidelity: str = "fluid", loss=None,
                          rng: np.random.Generator | None = None,
                          chip: ChipConstants = TPU_V5E, n_chains: int = 2,
                          dtype_bytes: float = 2.0,
                          progress_engine: str = "dpa",
                          host_cores: int = 2, host_total_cores: int = 108,
                          search=None, search_cache=None) -> TrainingRunResult:
    """Co-simulate one optimizer step of ``model`` at ``shape`` on
    ``n_hosts`` fabric hosts (see module docstring for the model). With a
    degenerate mix (pp=1, grad_accum=1, dp>=2) the fluid/packet result is
    BIT-EXACT engine.simulate_fsdp_step on the derived profiles —
    tests/test_train_sim.py pins it."""
    assert policy in FSDP_POLICIES, policy
    assert fidelity in TRAIN_FIDELITIES, fidelity
    assert n_hosts >= 1 and pp >= 1 and grad_accum >= 1
    assert n_hosts % pp == 0, (n_hosts, pp)
    dp = n_hosts // pp
    fabric = fabric or FabricParams()
    cfg = _resolve_model(model)
    shp = _resolve_shape(shape)

    profiles = derive_layer_profiles(cfg, shp, dp=dp, tp=tp,
                                     grad_accum=grad_accum, remat=remat,
                                     chip=chip, dtype_bytes=dtype_bytes)
    n_layers = len(profiles)
    assert pp <= n_layers, (pp, n_layers)

    # heaviest pipeline stage: contiguous slices of ceil(L/pp) layers;
    # its step_time bounds every stage's, which is what the 1F1B
    # composition (and the MFU <= 1 argument) needs
    per = -(-n_layers // pp)
    spans = [(lo, min(lo + per, n_layers)) for lo in range(0, n_layers, per)]
    lo, hi = max(spans, key=lambda sp: sum(p.fwd_s + p.bwd_s
                                           for p in profiles[sp[0]:sp[1]]))
    stage = profiles[lo:hi]

    fsdp_res: FsdpStepResult | None = None
    if dp == 1:
        # no data parallelism: nothing on the wire, every fidelity is the
        # pure-compute timeline
        micro = sum(p.fwd_s for p in stage) + sum(p.bwd_s for p in stage)
        stage_compute = micro
    elif fidelity == "analytic":
        t_ag, t_rs, _ = _analytic_legs(stage, dp, policy, fabric, topology)
        micro, _ = _fixed_timeline([p.fwd_s for p in stage],
                                   [p.bwd_s for p in stage],
                                   t_ag, t_rs,
                                   _ag_sync(dp, policy, n_chains, fabric))
        stage_compute = sum(p.fwd_s + p.bwd_s for p in stage)
    else:
        fsdp_res = simulate_fsdp_step(
            layers=stage, p=dp, fabric=fabric, policy=policy,
            n_chains=n_chains, topology=topology,
            hosts=hosts if hosts is not None else range(dp),
            fidelity=fidelity, loss=loss, rng=rng, workers=workers,
            progress_engine=progress_engine, host_cores=host_cores,
            host_total_cores=host_total_cores)
        micro = fsdp_res.step_time
        stage_compute = fsdp_res.compute_time

    n_micro = grad_accum + pp - 1
    step_time = n_micro * micro if n_micro > 1 else micro
    compute_time = (grad_accum * stage_compute if grad_accum > 1
                    else stage_compute)

    from repro.launch.analytic_costs import _fwd_flops  # lazy
    use_fwd, _ = _fwd_flops(cfg, shp.seq_len, shp.global_batch)
    model_flops = 3.0 * use_fwd                      # fwd + 2x bwd, useful
    n_devices = n_hosts * tp
    mfu = model_flops / (step_time * n_devices * chip.peak_flops)

    searched = searched_step = None
    if search and dp >= 2:
        from repro.core import sched_search  # lazy: imports half of core

        ref = max(p.layer_bytes for p in stage)
        searched = sched_search.search(
            "allgather", dp, max(int(ref / dp), 1), topology=topology,
            validate_packet=False, cache=search_cache)
        t_ag = [searched.winner_time * (p.layer_bytes / ref) for p in stage]
        _, t_rs, _ = _analytic_legs(stage, dp, policy, fabric, topology)
        s_micro, _ = _fixed_timeline([p.fwd_s for p in stage],
                                     [p.bwd_s for p in stage], t_ag, t_rs,
                                     0.0)
        searched_step = n_micro * s_micro if n_micro > 1 else s_micro

    return TrainingRunResult(
        model=cfg.name, shape=shp.name, n_hosts=n_hosts, dp=dp, tp=tp,
        pp=pp, grad_accum=grad_accum, policy=policy, fidelity=fidelity,
        loss_rate=(None if loss is None else getattr(loss, "mean_rate",
                                                     loss)),
        step_time=step_time, micro_time=micro, compute_time=compute_time,
        bubble_fraction=1.0 - compute_time / step_time,
        pipeline_bubble_fraction=(pp - 1) / n_micro,
        mfu=mfu, model_flops_per_step=model_flops, n_devices=n_devices,
        layer_profiles=profiles, stage_span=(lo, hi), fsdp=fsdp_res,
        searched=searched, searched_step_time=searched_step)


def sweep_training_runs(models, host_counts, *, policies=("naive", "split"),
                        shape="train_4k", fidelity="fluid", pp: int = 1,
                        **kw) -> list[TrainingRunResult]:
    """Grid helper for benchmarks/paper_figs.training_run_sweep."""
    out = []
    for m in models:
        for n in host_counts:
            for pol in policies:
                out.append(simulate_training_run(
                    m, shape, n_hosts=n, policy=pol, pp=pp,
                    fidelity=fidelity, **kw))
    return out


def _is_pow2(x: int) -> bool:
    return x > 0 and (x & (x - 1)) == 0


def make_fabric(spec: str | None, n_hosts: int, *,
                oversubscription: float = 4.0, island_size: int = 8):
    """String-addressed fabric construction for the launch facade and the
    benchmark sweep: "abstract"/None, "fattree", "island", "torus"."""
    if spec in (None, "abstract"):
        return None
    from repro.core.topology import FatTree, IslandFatTree, Torus2D  # lazy

    if spec == "fattree" or spec == "island":
        k = 4
        while k * k * k // 4 < n_hosts:
            k += 2
        if spec == "fattree":
            return FatTree(k=k, n_hosts=n_hosts,
                           oversubscription=oversubscription)
        return IslandFatTree(k, n_hosts, island_size=island_size,
                             oversubscription=oversubscription)
    if spec == "torus":
        nx = 1 << max((n_hosts.bit_length() - 1) // 2, 0)
        while nx * nx < n_hosts:
            nx *= 2
        ny = -(-n_hosts // nx)
        assert nx * ny == n_hosts and _is_pow2(n_hosts), \
            f"torus wants a power-of-two host count, got {n_hosts}"
        return Torus2D(nx, ny)
    raise ValueError(f"unknown fabric spec {spec!r}")
