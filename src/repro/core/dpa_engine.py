"""Event-level DPA progress-engine simulator (paper §II-C, §VI-C, Figs 13-16).

core/dpa.py models the DPA worker pool as CLOSED-FORM throughput curves
(`pool_tput`: Table-I single-thread rate x a T^e multithread envelope x a
per-core cap). This module simulates the same hardware at EVENT granularity,
so the microarchitectural claims are exercised instead of assumed:

  - N RISC-V cores x M hardware thread contexts. CQEs are dispatched
    round-robin over the contexts (compact placement: core 1 fills before
    core 2 — §VI-C), each context owning a DMA/doorbell queue (its
    ``thread_free`` horizon).
  - Per-CQE service cost is SPLIT into compute cycles and stalled-on-memory
    cycles (dpa.cqe_service_cycles: the Table-I throughput anchor sized by
    the measured IPC ~ 0.1). Compute serializes on the core's single issue
    pipeline; stalls overlap other contexts' compute — hardware
    multithreading genuinely hides data movement here, rather than applying
    dpa.MT_SCALING_EXP. Contexts sharing a core inflate each other's stalls
    by dpa.MEM_CONTENTION per co-resident context (shared LLC ports).
  - Each core's NIC-engine interface ingests CQEs at most at
    dpa.CORE_CAP_CHUNKS_PER_S (the per-core 200 Gbit/s interface of Fig 16:
    8 cores = 128 threads are exactly a 1.6 Tbit/s arrival rate).
  - An LLC-occupancy term degrades service while outstanding chunk state
    (arrived-but-unserviced bytes) exceeds the 1.5 MB LLC
    (dpa.LLC_MISS_PENALTY on the stall component).
  - Work is typed: data CQEs, NACK messages (bitmap streaming — scaled by
    wire bytes) and retransmit-post items run on the SAME contexts, so
    protocol work steals cycles from the receive datapath — the effect the
    paper offloads to the DPA to keep off the host CPU.
  - ``EventDpaParams.host_cpu`` is the host baseline: 1-4 Epyc-class cores,
    ONE context per core — no latency hiding, the Fig 5 curves.

The analytic curves in core/dpa.py are retained as the cross-check oracle:
tests pin the event engine's measured throughput against `dpa.pool_tput`
(exact at the T=1 and per-core-cap anchors, within a documented band
mid-range — DESIGN.md §7), and `threads_to_saturate_event` /
`tbit_feasible_event` reproduce the Fig 13/14/16 claims.

Degenerate contract (pinned in tests/test_dpa_engine.py): with zero compute
cycles, zero contention, no cap and no LLC term, `DpaEventPool` IS the
scalar T-server queue `engine.worker_pool_completion` — which is how the
packet engine's ``dpa_fidelity="event"`` mode reproduces the scalar mode
exactly at zero per-CQE cost (tests/test_packet.py).
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, replace

import numpy as np

from repro.core import dpa as dpa_model
from repro.core import engine as engine_mod

#: send-side retransmit posting (WQE build + doorbell) as a fraction of a
#: data CQE: no payload staging/reassembly bookkeeping, the RDMA engine
#: reads the user buffer directly (§III-A zero-copy)
RETX_POST_FRAC = 0.25

DPA_FIDELITIES = ("scalar", "event")


@dataclass(frozen=True)
class EventDpaParams:
    """Hardware description consumed by DpaEventPool. Build via
    `from_table1` (calibrated BF-3 DPA), `host_cpu` (Epyc baseline) or
    `zero_cost` (the degenerate exactness config); the raw constructor is
    for property tests that explore the space."""
    transport: str = "UD"
    n_threads: int = 16
    threads_per_core: int = dpa_model.DPA_THREADS_PER_CORE
    freq_hz: float = dpa_model.DPA_FREQ_HZ
    cycles_compute: float = 0.0
    cycles_stall: float = 0.0
    mem_contention: float = 0.0          # stall inflation per co-resident ctx
    core_cap_msgs: float | None = dpa_model.CORE_CAP_CHUNKS_PER_S
    llc_bytes: float = dpa_model.DPA_LLC_BYTES
    llc_penalty: float = dpa_model.LLC_MISS_PENALTY
    ref_bytes: int = dpa_model.REF_CHUNK_BYTES   # byte-scaled work reference

    def __post_init__(self):
        assert self.n_threads >= 1 and self.threads_per_core >= 1
        assert self.cycles_compute >= 0 and self.cycles_stall >= 0
        assert self.mem_contention >= 0 and self.llc_penalty >= 1.0

    @classmethod
    def from_table1(cls, transport: str = "UD",
                    n_threads: int = 16) -> "EventDpaParams":
        comp, stall = dpa_model.cqe_service_cycles(transport)
        return cls(transport=transport, n_threads=n_threads,
                   cycles_compute=comp, cycles_stall=stall,
                   mem_contention=dpa_model.MEM_CONTENTION[transport])

    @classmethod
    def from_dpa_config(cls, cfg: dpa_model.DpaConfig) -> "EventDpaParams":
        """The event twin of the analytic DpaConfig (chunk size is per-CQE
        in the event engine, so only transport/threads carry over)."""
        return cls.from_table1(cfg.transport, cfg.n_threads)

    @classmethod
    def host_cpu(cls, n_cores: int = 2,
                 datapath: str = "UD_reliability") -> "EventDpaParams":
        """Fig 5 host baseline: Epyc-class cores, one context each — stalls
        are exposed (nothing to overlap them with), no NIC-interface cap
        (the bottleneck IS the core), no DPA LLC model."""
        comp, stall = dpa_model.host_cqe_service_cycles(datapath)
        return cls(transport=datapath, n_threads=n_cores, threads_per_core=1,
                   freq_hz=dpa_model.CPU_FREQ_HZ, cycles_compute=comp,
                   cycles_stall=stall, mem_contention=0.0,
                   core_cap_msgs=None, llc_bytes=math.inf)

    @classmethod
    def zero_cost(cls, n_threads: int = 16) -> "EventDpaParams":
        """Free progress engine: every CQE completes at its arrival. The
        packet engine with this config reproduces the scalar-DPA mode with
        infinite thread throughput EXACTLY (tests pin it)."""
        return cls(n_threads=n_threads, cycles_compute=0.0, cycles_stall=0.0,
                   mem_contention=0.0, core_cap_msgs=None,
                   llc_bytes=math.inf)

    @property
    def n_cores(self) -> int:
        return -(-self.n_threads // self.threads_per_core)

    def threads_on_core(self, core: int) -> int:
        full, rem = divmod(self.n_threads, self.threads_per_core)
        if core < full:
            return self.threads_per_core
        return rem

    def service_cycles(self, kind: str = "data",
                       wire_bytes: int | None = None) -> tuple[float, float]:
        """(compute, stall) cycles for one work item.

        data  one receive CQE — CQE-bound, payload-size independent for
              small chunks (the Fig 16 projection rests on this; larger UC
              chunks raise bytes-per-CQE, Fig 15).
        nack  one (aggregated) NACK message: a CQE plus streaming the packed
              bitmap — cycles scale with wire_bytes / ref_bytes, matching
              the scalar model's (mtu + bitmap) / thread_tput service.
        retx  posting one retransmit send WQE: RETX_POST_FRAC of a CQE.
        """
        c, s = self.cycles_compute, self.cycles_stall
        if kind == "data":
            return c, s
        if kind == "nack":
            assert wire_bytes is not None
            scale = wire_bytes / self.ref_bytes
            return c * scale, s * scale
        if kind == "retx":
            return c * RETX_POST_FRAC, s * RETX_POST_FRAC
        raise ValueError(f"unknown work kind: {kind}")


class DpaEventPool:
    """One NIC's DPA progress engine: persistent across service batches, so
    protocol work (NACK service, retransmit posting) steals cycles from data
    CQEs that land on the same contexts later.

    service_batch(arrivals, ...) simulates the batch CQE by CQE:

        ingest  = max(arrival, core NIC-interface pacing)     # per-core cap
        start   = max(ingest, context's doorbell-queue horizon)
        compute = serialized on the core's issue pipeline     # C cycles
        stall   = overlapped, inflated by co-resident contexts and by LLC
                  overflow of outstanding chunk state         # S cycles
        done    = compute_end + stall

    Conservation invariant: every submitted item gets exactly one done time;
    ``n_served`` counts them (property-tested).
    """

    def __init__(self, params: EventDpaParams, t0: float = 0.0):
        self.params = params
        p = params
        self._thread_free = [t0] * p.n_threads
        self._pipe_free = [t0] * p.n_cores
        self._ingest_next = [t0] * p.n_cores
        self._contention = [
            1.0 + p.mem_contention * (p.threads_on_core(c) - 1)
            for c in range(p.n_cores)
        ]
        self._inflight: list[tuple[float, float]] = []   # (done, bytes) heap
        self._inflight_bytes = 0.0
        self.n_served = 0
        self.llc_spill_events = 0

    def service_batch(self, arrivals: np.ndarray, chunk_bytes: float, *,
                      kind: str = "data",
                      wire_bytes: int | None = None) -> np.ndarray:
        """Done times for a sorted arrival batch (one work item each)."""
        p = self.params
        n = int(np.asarray(arrivals).shape[0])
        if n == 0:
            return np.empty(0)
        comp_cyc, stall_cyc = p.service_cycles(kind, wire_bytes)
        comp_s = comp_cyc / p.freq_hz
        inv_cap = 0.0 if p.core_cap_msgs is None else 1.0 / p.core_cap_msgs
        tpc = p.threads_per_core
        done = np.empty(n)
        arr = np.asarray(arrivals, dtype=float)
        track_llc = math.isfinite(p.llc_bytes)
        for k in range(n):
            a = arr[k]
            j = k % p.n_threads
            c = j // tpc
            if track_llc:
                while self._inflight and self._inflight[0][0] <= a:
                    self._inflight_bytes -= heapq.heappop(self._inflight)[1]
            t_in = a if inv_cap == 0.0 else max(a, self._ingest_next[c])
            if inv_cap:
                self._ingest_next[c] = t_in + inv_cap
            start = max(t_in, self._thread_free[j])
            comp_start = max(start, self._pipe_free[c])
            comp_end = comp_start + comp_s
            self._pipe_free[c] = comp_end
            stall_s = stall_cyc * self._contention[c] / p.freq_hz
            if track_llc and self._inflight_bytes + chunk_bytes > p.llc_bytes:
                stall_s *= p.llc_penalty
                self.llc_spill_events += 1
            t_done = comp_end + stall_s
            self._thread_free[j] = t_done
            if track_llc:
                heapq.heappush(self._inflight, (t_done, float(chunk_bytes)))
                self._inflight_bytes += chunk_bytes
            done[k] = t_done
        self.n_served += n
        return done

    def service_with_rnr(self, arrivals: np.ndarray, psns: np.ndarray,
                         chunk_bytes: float, staging: int, *,
                         kind: str = "data", wire_bytes: int | None = None):
        """Event twin of packet._pool_with_rnr_psns: (t_last, rnr_psns)
        under the shared engine.staging_rnr_mask overflow rule. t_last is
        the MAX done time — on a persistent multi-context pool the
        last-arriving item is not necessarily the last to complete (a
        context still busy with earlier protocol work finishes its item
        after an idle context finishes a later one)."""
        done = self.service_batch(arrivals, chunk_bytes, kind=kind,
                                  wire_bytes=wire_bytes)
        if done.shape[0] == 0:
            return None, psns[:0]
        rnr_psns = psns[engine_mod.staging_rnr_mask(done, arrivals, staging)]
        return float(done.max()), rnr_psns


def resolve_event_params(dpa, workers_n_threads: int) -> EventDpaParams:
    """``dpa=`` argument of the packet simulators -> EventDpaParams: params
    pass through, a DpaConfig is converted, None derives a Table-I UD pool
    sized like the scalar worker pool (the two fidelities then describe the
    same nominal hardware)."""
    if dpa is None:
        return EventDpaParams.from_table1("UD", workers_n_threads)
    if isinstance(dpa, EventDpaParams):
        return dpa
    if isinstance(dpa, dpa_model.DpaConfig):
        return EventDpaParams.from_dpa_config(dpa)
    raise TypeError(f"dpa= expects EventDpaParams | DpaConfig | None, "
                    f"got {type(dpa).__name__}")


# ------------------------------------------------- measured-throughput twins
#
# The event-engine counterparts of dpa.pool_tput / sustained_tput /
# threads_to_saturate / tbit_feasible: each DRIVES the simulator with a
# trace and measures, instead of evaluating a closed form.


def pool_tput_event(params: EventDpaParams, *, chunk_bytes: int = 4096,
                    n_chunks: int | None = None) -> float:
    """Measured processing capacity (bytes/s) of the pool: a saturating
    all-at-once backlog, makespan-timed. The LLC-occupancy term is disabled
    for THIS measurement — the analytic oracle `dpa.pool_tput` has no
    occupancy term (Table I drains its 8 MiB buffer through the DMA engine),
    and an artificial all-at-once backlog would otherwise conflate the two
    effects. The occupancy term is exercised by its own tests/benchmarks."""
    if n_chunks is None:
        n_chunks = max(512, 48 * params.n_threads)
    pool = DpaEventPool(replace(params, llc_bytes=math.inf))
    done = pool.service_batch(np.zeros(n_chunks), chunk_bytes)
    return n_chunks * chunk_bytes / float(done.max())


def _steady_rate(arrivals: np.ndarray, done: np.ndarray) -> float:
    """Items/s over the steady-state second half of a paced trace: immune to
    the ramp-up and final-service tail (a pool that keeps up tracks the
    arrivals at a constant lag; one that cannot drifts at its capacity)."""
    n = done.shape[0]
    mid = n // 2
    span = float(done[-1] - done[mid - 1])
    if span <= 0.0:                       # zero-cost pool: done == arrivals
        span = float(arrivals[-1] - arrivals[mid - 1])
    return (n - mid) / span if span > 0.0 else math.inf


def sustained_tput_event(params: EventDpaParams,
                         link_bytes_per_s: float = dpa_model.LINK_200G_BYTES,
                         *, chunk_bytes: int = 4096,
                         n_chunks: int | None = None) -> float:
    """Measured bytes/s against a LINE-RATE arrival trace (the Fig 13/14
    experiment shape): chunks arrive back-to-back at the link's MTU rate; if
    the pool keeps up the backlog stays bounded (throughput == line rate),
    else the backlog grows — and the LLC term then degrades service exactly
    as outstanding state spills, which is the physical regime."""
    if n_chunks is None:
        n_chunks = max(2048, 48 * params.n_threads)
    arrivals = np.arange(n_chunks) * (chunk_bytes / link_bytes_per_s)
    pool = DpaEventPool(params)
    done = pool.service_batch(arrivals, chunk_bytes)
    return min(_steady_rate(arrivals, done) * chunk_bytes, link_bytes_per_s)


def threads_to_saturate_event(
        transport: str,
        link_bytes_per_s: float = dpa_model.LINK_200G_BYTES, *,
        chunk_bytes: int = 4096) -> int:
    """Fig 13/14 reproduced by measurement: smallest thread count whose
    event-simulated receive datapath sustains >= 99% of line rate."""
    limit = dpa_model.DPA_CORES * dpa_model.DPA_THREADS_PER_CORE
    for t in range(1, limit + 1):
        tput = sustained_tput_event(
            EventDpaParams.from_table1(transport, t), link_bytes_per_s,
            chunk_bytes=chunk_bytes)
        if tput >= 0.99 * link_bytes_per_s:
            return t
    return limit


def sustained_chunk_rate_event(params: EventDpaParams,
                               arrival_rate: float, *,
                               chunk_bytes: int = 64,
                               n_chunks: int | None = None) -> float:
    """Measured chunks/s against an arrival trace paced at ``arrival_rate``
    (Fig 16: the chunk arrival rate of a Tbit/s link at 4 KiB MTU)."""
    if n_chunks is None:
        n_chunks = max(4096, 48 * params.n_threads)
    arrivals = np.arange(n_chunks) / arrival_rate
    pool = DpaEventPool(params)
    done = pool.service_batch(arrivals, chunk_bytes)
    return min(_steady_rate(arrivals, done), arrival_rate)


def tbit_feasible_event(transport: str = "UD", n_threads: int = 128, *,
                        margin: float = 0.01) -> bool:
    """§VII-a by event simulation: can half the DPA (8 cores, 128 threads)
    keep up with the 1.6 Tbit/s chunk arrival rate at 64 B tracked chunks?
    ``margin`` absorbs the measured trace's ramp/tail (the steady rate sits
    exactly on the 8x per-core-cap boundary)."""
    need = dpa_model.link_chunk_arrival_rate(dpa_model.LINK_1600G_BYTES)
    rate = sustained_chunk_rate_event(
        EventDpaParams.from_table1(transport, n_threads), need,
        chunk_bytes=64)
    return rate >= need * (1.0 - margin)
