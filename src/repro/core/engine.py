"""Shared discrete-event engine for concurrent collective streams.

The protocol simulators (simulator.py) and the FSDP contention model below all
need the same primitive: several byte streams ("flows") contending for a
node's injection/ejection bandwidth. This module provides it once:

  Engine / Link / Flow   fluid-flow discrete-event core. A Link is a bandwidth
                         server (one direction of a NIC or one ring direction);
                         active flows share its capacity max-min fair (equal
                         split with per-flow rate caps, water-filling). The
                         event loop advances between flow starts/finishes, so
                         every flow ends up with a piecewise-linear progress
                         curve from which chunk-granularity timestamps are
                         recovered exactly (Flow.chunk_times).

  worker_pool_completion vectorized T-server/deterministic-service queue used
                         for the leaf receive path (staging-ring RNR drops
                         included). O(n_workers) numpy passes instead of the
                         old O(n_chunks) Python loop; the reference loop is
                         kept as worker_pool_completion_loop for regression
                         tests.

  workers_from_dpa       leaf service-rate provider backed by the calibrated
                         DPA model (core/dpa.py): within-core sublinear thread
                         scaling and the per-core NIC-interface cap set the
                         pool's aggregate processing rate.

  simulate_fsdp_step     the paper's motivating scenario: an interleaved
                         forward-AG + backward-RS + compute FSDP timeline at
                         layer granularity, under three link policies —
                         "naive" (AG and RS serialize on one shared
                         half-duplex medium), "mcast" (the paper's M-chain
                         multicast schedule on a full-duplex NIC), and
                         "split" (Insight 2: AG and RS on opposite ring
                         directions, no shared bottleneck). Reports per-phase
                         times, per-link utilization and bubble_fraction.
"""
from __future__ import annotations

import heapq
import itertools
import math
from dataclasses import dataclass
from typing import TYPE_CHECKING

import numpy as np

from repro.core import dpa as dpa_model

if TYPE_CHECKING:  # avoid importing jax-heavy config machinery at module load
    from repro.configs.base import ModelConfig


# ------------------------------------------------------------------ parameters


@dataclass(frozen=True)
class FabricParams:
    b_link: float = 200e9 / 8       # bytes/s per direction
    latency: float = 2e-6           # base one-way latency
    jitter: float = 1e-6            # max extra delay (adaptive routing, OOO)
    p_drop: float = 0.0             # per-datagram fabric drop probability
    mtu: int = 4096
    alpha: float = 50e-6            # cutoff-timer slack


@dataclass(frozen=True)
class WorkerParams:
    n_recv_workers: int = 1
    thread_tput: float = 5.2 * (1 << 30)   # bytes/s per worker (Table I UD)
    staging_chunks: int = 8192
    rnr_barrier_hop: float = 1.5e-6


def workers_from_dpa(cfg: dpa_model.DpaConfig, *, staging_chunks: int = 8192,
                     rnr_barrier_hop: float = 1.5e-6) -> WorkerParams:
    """Derive the leaf worker pool from the calibrated DPA offload model.

    The pool's aggregate service rate comes from dpa.pool_tput (within-core
    T^e latency-hiding, per-core NIC-interface cap, linear across cores) and
    is spread evenly over the pool so the queueing model sees the sublinear
    scaling: 16 UD threads do NOT serve 16x a single thread.
    """
    tput = dpa_model.pool_tput(cfg)
    return WorkerParams(
        n_recv_workers=cfg.n_threads,
        thread_tput=tput / cfg.n_threads,
        staging_chunks=staging_chunks,
        rnr_barrier_hop=rnr_barrier_hop,
    )


# ---------------------------------------------------------------- fluid engine


class Flow:
    """One byte stream on one link. Progress is recorded as piecewise-linear
    segments (t0, t1, bytes_at_t0, rate) by the engine event loop."""

    __slots__ = ("link", "n_bytes", "tag", "t_start", "rate_cap",
                 "remaining", "t_end", "segments", "_eps")

    def __init__(self, link: "Link", n_bytes: float, t_start: float,
                 tag: str | None, rate_cap: float | None):
        self.link = link
        self.n_bytes = float(n_bytes)
        self.tag = tag
        self.t_start = t_start
        self.rate_cap = rate_cap
        self.remaining = float(n_bytes)
        # finish threshold: fluid progress accumulates O(n_bytes * 1e-16) fp
        # error; a sub-byte relative epsilon absorbs it without ever being
        # physically observable (sub-nanosecond at any realistic rate)
        self._eps = 1e-9 + self.n_bytes * 1e-12
        self.t_end: float | None = None
        self.segments: list[tuple[float, float, float, float]] = []

    @property
    def done(self) -> bool:
        return self.t_end is not None

    def time_at_bytes(self, marks: np.ndarray) -> np.ndarray:
        """Times at which cumulative delivered bytes reach each mark (exact on
        the piecewise-linear progress curve)."""
        assert self.done, "flow not finished; Engine.wait/run first"
        if not self.segments:            # zero-byte flow
            return np.full(np.shape(marks), self.t_end)
        ts = [self.segments[0][0]]
        bs = [0.0]
        for t0, t1, b0, rate in self.segments:
            ts.append(t1)
            bs.append(b0 + rate * (t1 - t0))
        bs[-1] = self.n_bytes            # kill accumulated fp error at the end
        return np.interp(np.asarray(marks, dtype=float), bs, ts)

    def chunk_times(self, n_chunks: int, chunk_bytes: float) -> np.ndarray:
        """Completion time of each chunk's last byte."""
        marks = (np.arange(n_chunks) + 1.0) * chunk_bytes
        return self.time_at_bytes(np.minimum(marks, self.n_bytes))


class Link:
    """Bandwidth server: capacity is max-min shared among active flows."""

    __slots__ = ("name", "capacity", "active", "bytes_served")

    def __init__(self, name: str, capacity: float):
        assert capacity > 0, (name, capacity)
        self.name = name
        self.capacity = float(capacity)
        self.active: list[Flow] = []
        self.bytes_served = 0.0

    def rates(self) -> dict[Flow, float]:
        """Water-fill the capacity among active flows honoring rate caps."""
        flows = self.active
        if not flows:
            return {}
        out: dict[Flow, float] = {}
        left = list(flows)
        cap = self.capacity
        while left:
            share = cap / len(left)
            capped = [f for f in left if f.rate_cap is not None and f.rate_cap < share]
            if not capped:
                for f in left:
                    out[f] = share
                break
            for f in capped:
                out[f] = f.rate_cap
                cap -= f.rate_cap
                left.remove(f)
        return out


class Engine:
    """Event-driven fluid simulator. Flows may be submitted with future start
    times; the loop advances between starts and finishes, recomputing each
    link's max-min rate allocation at every event."""

    def __init__(self, t0: float = 0.0):
        self.now = t0
        self._links: dict[str, Link] = {}
        self._pending: list[tuple[float, int, Flow]] = []   # start events
        self._active: list[Flow] = []
        self._seq = itertools.count()

    # -- construction
    def add_link(self, name: str, capacity: float) -> Link:
        if name not in self._links:
            self._links[name] = Link(name, capacity)
        return self._links[name]

    def submit(self, link: str, n_bytes: float, *, t_start: float | None = None,
               tag: str | None = None, rate_cap: float | None = None) -> Flow:
        t = self.now if t_start is None else float(t_start)
        assert t >= self.now - 1e-12, (t, self.now, "cannot submit in the past")
        flow = Flow(self._links[link], n_bytes, t, tag, rate_cap)
        heapq.heappush(self._pending, (t, next(self._seq), flow))
        return flow

    # -- event loop
    def _progress(self, dt: float, rates: dict[Flow, float]) -> None:
        if dt <= 0:
            return
        for f in self._active:
            r = rates.get(f, 0.0)
            f.segments.append((self.now, self.now + dt, f.n_bytes - f.remaining, r))
            moved = min(r * dt, f.remaining)
            f.remaining -= moved
            f.link.bytes_served += moved

    def _step(self, t_limit: float) -> bool:
        """Advance to the next event (or t_limit). Returns False when idle."""
        rates: dict[Flow, float] = {}
        for link in self._links.values():
            rates.update(link.rates())
        t_next = t_limit
        if self._pending:
            t_next = min(t_next, self._pending[0][0])
        for f in self._active:
            r = rates.get(f, 0.0)
            if r > 0:
                t_next = min(t_next, self.now + f.remaining / r)
        if t_next == math.inf:
            return False
        self._progress(t_next - self.now, rates)
        self.now = t_next
        # finishes (also flows whose residual would not advance the clock —
        # their finish time is indistinguishable from `now` in float64)
        still = []
        for f in self._active:
            r = rates.get(f, 0.0)
            stalled = r > 0 and self.now + f.remaining / r <= self.now
            if f.remaining <= f._eps or stalled:
                f.remaining = 0.0
                f.t_end = self.now
                f.link.active.remove(f)
            else:
                still.append(f)
        self._active = still
        # starts
        while self._pending and self._pending[0][0] <= self.now + 1e-15:
            _, _, f = heapq.heappop(self._pending)
            if f.n_bytes <= 0:
                f.t_end = max(self.now, f.t_start)
            else:
                f.link.active.append(f)
                self._active.append(f)
        return bool(self._active or self._pending)

    def advance_to(self, t: float) -> None:
        while self.now < t and self._step(t):
            pass
        self.now = max(self.now, t)

    def wait(self, *flows: Flow) -> float:
        """Advance until all given flows complete; returns the completion time
        of the latest one."""
        while any(not f.done for f in flows):
            if not self._step(math.inf):
                break
        assert all(f.done for f in flows), "deadlock: flows never started"
        return max(f.t_end for f in flows)

    def run(self) -> float:
        """Drain every submitted flow; returns the final time."""
        while self._step(math.inf):
            pass
        return self.now

    def utilization(self, horizon: float | None = None) -> dict[str, float]:
        """Per-link bytes_served / (capacity * horizon)."""
        h = horizon if horizon is not None else self.now
        if h <= 0:
            return {n: 0.0 for n in self._links}
        return {n: l.bytes_served / (l.capacity * h) for n, l in self._links.items()}


# ------------------------------------------------- leaf worker pool (receive)


def worker_pool_completion_loop(arrivals: np.ndarray, n_workers: int,
                                service: float, staging: int) -> tuple[np.ndarray, int]:
    """Reference O(n) implementation of the T-server deterministic-service
    queue with staging-ring (RNR) overflow counting. arrivals must be sorted.
    Kept verbatim from the pre-engine simulator as the regression oracle."""
    n = arrivals.shape[0]
    done = np.empty(n)
    rnr = 0
    for k in range(n):
        start = arrivals[k] if k < n_workers else max(arrivals[k], done[k - n_workers])
        if k >= staging and done[k - staging] > arrivals[k]:
            rnr += 1
        done[k] = start + service
    return done, rnr


def worker_pool_completion(arrivals: np.ndarray, n_workers: int,
                           service: float, staging: int) -> tuple[np.ndarray, int]:
    """Vectorized equivalent of worker_pool_completion_loop.

    With deterministic service s and round-robin dispatch, chunks k, k+W,
    k+2W, ... form independent single-server chains:
        done_i = max(a_i, done_{i-1}) + s = (i+1)s + max_{j<=i}(a_j - j*s)
    — a running max per residue class, so the whole pool is n_workers numpy
    maximum.accumulate passes.
    """
    n = arrivals.shape[0]
    if n == 0:
        return np.empty(0), 0
    done = np.empty(n)
    w = max(int(n_workers), 1)
    for r in range(min(w, n)):
        idx = np.arange(r, n, w)
        i = np.arange(idx.size, dtype=float)
        shifted = arrivals[idx] - i * service
        done[idx] = np.maximum.accumulate(shifted) + (i + 1.0) * service
    if n > staging:
        rnr = int(np.count_nonzero(done[: n - staging] > arrivals[staging:]))
    else:
        rnr = 0
    return done, rnr


# ----------------------------------------------------- FSDP contention model


FSDP_POLICIES = ("naive", "mcast", "split")


@dataclass
class FsdpStepResult:
    policy: str
    step_time: float                  # wall time of fwd + bwd (+ RS drain)
    compute_time: float               # sum of useful layer compute
    bubble_fraction: float            # 1 - compute_time / step_time
    phase_times: dict[str, float]     # forward / backward / rs_drain
    link_utilization: dict[str, float]
    ag_bytes: float                   # per-node AG bytes moved (dominant dir)
    rs_bytes: float
    n_layers: int
    p: int


def _layer_bytes_from_model(model: "ModelConfig", dtype_bytes: int) -> tuple[int, float]:
    """(n_layers, bytes of parameters per layer) from a registered config.
    Imported lazily: configs pull in the jax model builders."""
    from repro.models.model_builder import count_params_analytic

    n_layers = model.num_layers
    return n_layers, count_params_analytic(model) / n_layers * dtype_bytes


def simulate_fsdp_step(model: "ModelConfig | None" = None, *,
                       n_layers: int = 32, layer_bytes: float = 256e6,
                       p: int = 16,
                       fabric: FabricParams | None = None,
                       policy: str = "naive",
                       n_chains: int = 2,
                       tokens_per_device: int = 4096,
                       hw_flops: float = 200e12,
                       dtype_bytes: int = 2) -> FsdpStepResult:
    """Interleaved forward-AG + backward-RS + compute FSDP timeline.

    Per layer the parameters live sharded 1/p per node; the forward pass
    allgathers layer i+1 during layer i's compute (prefetch), the backward
    pass re-gathers parameters in reverse order while asynchronously
    reduce-scattering each layer's gradients — the AG and RS streams overlap
    and contend for the node's injection/ejection bandwidth. Policies:

      naive   AG and RS are P2P rings on one shared half-duplex medium of
              capacity B: every flow carries send+recv bytes and serializes.
      mcast   the paper's M-chain multicast Allgather on a full-duplex NIC:
              AG injects only the node's own shard (the switch replicates),
              its receive stream shares the ejection link with the ring RS
              receive stream; chain activation adds R = P/M latency hops.
      split   Insight 2 direction split: the {AG_mc, RS_inc} pairing of
              cost_model.mc_inc_share — AG_mc is receive-bound (injects only
              1/P), RS_inc is send-bound (in-network reduction: the node
              receives only its reduced shard), so neither direction is a
              shared bottleneck (the torus analogue is concurrent_ag_rs in
              core/collectives.py: AG clockwise, RS counter-clockwise).

    bubble_fraction = 1 - compute_time / step_time: the fraction of the step
    the compute units sit idle waiting on exposed communication.
    """
    assert policy in FSDP_POLICIES, policy
    fabric = fabric or FabricParams()
    if model is not None:
        n_layers, layer_bytes = _layer_bytes_from_model(model, dtype_bytes)
    assert p >= 2 and n_layers >= 1

    b = fabric.b_link
    gather_bytes = (p - 1) / p * layer_bytes     # bytes a node must receive
    shard_bytes = layer_bytes / p
    fwd_t = 2.0 * (layer_bytes / dtype_bytes) * tokens_per_device / hw_flops
    bwd_t = 2.0 * fwd_t

    eng = Engine()
    if policy == "naive":
        eng.add_link("shared", b)

        def submit_ag(t):
            # ring AG: (p-1)/p*L sent + received, all through the shared medium
            return [eng.submit("shared", 2 * gather_bytes, t_start=t, tag="ag")]

        def submit_rs(t):
            return [eng.submit("shared", 2 * gather_bytes, t_start=t, tag="rs")]

        ag_sync = (p - 1) * fabric.latency
    else:  # mcast / split share the multicast AG; they differ in the RS side
        eng.add_link("send", b)
        eng.add_link("recv", b)

        def submit_ag(t):
            # AG_mc: receive-bound (send share 1/p — cost_model.mc_inc_share)
            return [eng.submit("send", shard_bytes, t_start=t, tag="ag"),
                    eng.submit("recv", gather_bytes, t_start=t, tag="ag")]

        if policy == "mcast":
            def submit_rs(t):
                # ring RS: full gather bytes in both directions, so its
                # receive stream contends with AG_mc on the ejection link
                return [eng.submit("send", gather_bytes, t_start=t, tag="rs"),
                        eng.submit("recv", gather_bytes, t_start=t, tag="rs")]
        else:
            def submit_rs(t):
                # RS_inc: send-bound — the switch reduces in-network, the
                # node receives only its own reduced shard
                return [eng.submit("send", gather_bytes, t_start=t, tag="rs"),
                        eng.submit("recv", shard_bytes, t_start=t, tag="rs")]

        rounds = max(p // max(n_chains, 1), 1)
        ag_sync = rounds * fabric.latency

    compute_total = 0.0

    # ---- forward: AG(i+1) prefetched at compute-start of layer i
    ag = [None] * n_layers
    ag[0] = submit_ag(0.0)
    t = 0.0
    for i in range(n_layers):
        t_ready = eng.wait(*ag[i]) + ag_sync
        start = max(t, t_ready)
        if i + 1 < n_layers:
            ag[i + 1] = submit_ag(start)
        t = start + fwd_t
        compute_total += fwd_t
    t_fwd_end = t

    # ---- backward: re-gather params in reverse order, RS grads async
    ag_b = [None] * n_layers
    ag_b[n_layers - 1] = submit_ag(t_fwd_end)
    rs_flows: list[Flow] = []
    for i in range(n_layers - 1, -1, -1):
        t_ready = eng.wait(*ag_b[i]) + ag_sync
        start = max(t, t_ready)
        if i - 1 >= 0:
            ag_b[i - 1] = submit_ag(start)
        t = start + bwd_t
        compute_total += bwd_t
        rs_flows += submit_rs(t)
    t_bwd_end = t

    t_rs_done = eng.wait(*rs_flows) if rs_flows else t_bwd_end
    step_time = max(t_bwd_end, t_rs_done)
    eng.advance_to(step_time)

    return FsdpStepResult(
        policy=policy,
        step_time=step_time,
        compute_time=compute_total,
        bubble_fraction=1.0 - compute_total / step_time,
        phase_times={
            "forward": t_fwd_end,
            "backward": t_bwd_end - t_fwd_end,
            "rs_drain": max(t_rs_done - t_bwd_end, 0.0),
        },
        link_utilization=eng.utilization(step_time),
        ag_bytes=gather_bytes * 2 * n_layers,   # forward prefetch + bwd re-gather
        rs_bytes=gather_bytes * n_layers,       # one RS per layer, backward only
        n_layers=n_layers,
        p=p,
    )


def sweep_fsdp_contention(*, ps=(8, 16, 64), layer_bytes=(64e6, 256e6),
                          n_layers: int = 8,
                          fabric: FabricParams | None = None,
                          policies=FSDP_POLICIES,
                          hw_flops: float = 200e12,
                          tokens_per_device: int = 4096) -> list[dict]:
    """Grid of simulate_fsdp_step calls — the benchmarks/run.py --smoke sweep
    and the paper_figs FSDP-contention table both render these rows."""
    fabric = fabric or FabricParams()
    rows = []
    for p in ps:
        for lb in layer_bytes:
            per_policy = {}
            for pol in policies:
                r = simulate_fsdp_step(
                    n_layers=n_layers, layer_bytes=lb, p=p, fabric=fabric,
                    policy=pol, hw_flops=hw_flops,
                    tokens_per_device=tokens_per_device,
                )
                per_policy[pol] = r
                rows.append({
                    "p": p, "layer_bytes": lb, "policy": pol,
                    "step_time": r.step_time,
                    "bubble_fraction": r.bubble_fraction,
                    "link_utilization": r.link_utilization,
                })
            if "naive" in per_policy and "split" in per_policy:
                assert (per_policy["split"].bubble_fraction
                        <= per_policy["naive"].bubble_fraction + 1e-12), (
                    p, lb, per_policy["split"].bubble_fraction,
                    per_policy["naive"].bubble_fraction,
                )
    return rows
