"""Shared discrete-event engine for concurrent collective streams.

The protocol simulators (simulator.py) and the FSDP contention model below all
need the same primitive: several byte streams ("flows") contending for
bandwidth. This module provides it once:

  Engine / Link / Flow   fluid-flow discrete-event core. A Link is a directed
                         bandwidth server (one direction of a NIC, one fabric
                         cable direction, one ring direction). A Flow traverses
                         a *route* — an ordered set of Links — and its rate is
                         set by global max-min water-filling across every link
                         it crosses (progressive filling: repeatedly saturate
                         the most-constrained link, freeze its flows' rates,
                         subtract, repeat; per-flow rate caps honored). A
                         multicast *tree flow* (Engine.submit_tree) is the
                         switch-replication model: its rate is the min share
                         over every tree edge and it charges bytes_served to
                         each edge, because the switches replicate the stream
                         down every branch concurrently. The event loop
                         advances between flow starts/finishes, so every flow
                         ends up with a piecewise-linear progress curve from
                         which chunk-granularity timestamps are recovered
                         exactly (Flow.chunk_times). Routes come from a
                         core/topology.py Topology (FatTree / Torus2D), whose
                         per-link byte counters are these same Link objects —
                         one engine run yields both the timing and the
                         switch-port traffic (Fig. 12), with no separate
                         static counting pass.

  worker_pool_completion vectorized T-server/deterministic-service queue used
                         for the leaf receive path (staging-ring RNR drops
                         included). O(n_workers) numpy passes instead of the
                         old O(n_chunks) Python loop; the reference loop is
                         kept as worker_pool_completion_loop for regression
                         tests.

  workers_from_dpa       leaf service-rate provider backed by the calibrated
                         DPA model (core/dpa.py): within-core sublinear thread
                         scaling and the per-core NIC-interface cap set the
                         pool's aggregate processing rate.

  simulate_fsdp_step     the paper's motivating scenario: an interleaved
                         forward-AG + backward-RS + compute FSDP timeline at
                         layer granularity, under three link policies —
                         "naive" (AG and RS serialize on one shared
                         half-duplex medium), "mcast" (the paper's M-chain
                         multicast schedule on a full-duplex NIC), and
                         "split" (Insight 2: AG and RS on opposite ring
                         directions, no shared bottleneck). Reports per-phase
                         times, per-link utilization and bubble_fraction.
"""
from __future__ import annotations

import heapq
import itertools
import math
import os
from dataclasses import dataclass
from typing import TYPE_CHECKING

import numpy as np

from repro.core import dpa as dpa_model
from repro.core import profiling
from repro.kernels.pool_np import pool_completion_rows_np

if TYPE_CHECKING:  # avoid importing jax-heavy config machinery at module load
    from repro.configs.base import ModelConfig


# ------------------------------------------------------------------ parameters


@dataclass(frozen=True)
class FabricParams:
    b_link: float = 200e9 / 8       # bytes/s per direction
    latency: float = 2e-6           # base one-way latency
    jitter: float = 1e-6            # max extra delay (adaptive routing, OOO)
    p_drop: float = 0.0             # per-datagram fabric drop probability
    mtu: int = 4096
    alpha: float = 50e-6            # cutoff-timer slack


@dataclass(frozen=True)
class WorkerParams:
    n_recv_workers: int = 1
    thread_tput: float = 5.2 * (1 << 30)   # bytes/s per worker (Table I UD)
    staging_chunks: int = 8192
    rnr_barrier_hop: float = 1.5e-6


def workers_from_dpa(cfg: dpa_model.DpaConfig, *, staging_chunks: int = 8192,
                     rnr_barrier_hop: float = 1.5e-6) -> WorkerParams:
    """Derive the leaf worker pool from the calibrated DPA offload model.

    The pool's aggregate service rate comes from dpa.pool_tput (within-core
    T^e latency-hiding, per-core NIC-interface cap, linear across cores) and
    is spread evenly over the pool so the queueing model sees the sublinear
    scaling: 16 UD threads do NOT serve 16x a single thread.
    """
    tput = dpa_model.pool_tput(cfg)
    return WorkerParams(
        n_recv_workers=cfg.n_threads,
        thread_tput=tput / cfg.n_threads,
        staging_chunks=staging_chunks,
        rnr_barrier_hop=rnr_barrier_hop,
    )


# ---------------------------------------------------------------- fluid engine


class Flow:
    """One byte stream crossing an ordered set of links (a route, or the edge
    set of a multicast tree). Its fluid rate is identical on every link it
    crosses (cut-through, flow conservation) and is set by the engine's global
    max-min allocation. Progress is recorded as piecewise-linear segments
    (t0, t1, bytes_at_t0, rate) by the engine event loop."""

    __slots__ = ("links", "n_bytes", "tag", "t_start", "rate_cap",
                 "remaining", "t_end", "segments", "_eps")

    def __init__(self, links: tuple["Link", ...], n_bytes: float,
                 t_start: float, tag: str | None, rate_cap: float | None):
        self.links = links
        self.n_bytes = float(n_bytes)
        self.tag = tag
        self.t_start = t_start
        self.rate_cap = rate_cap
        self.remaining = float(n_bytes)
        # finish threshold: fluid progress accumulates O(n_bytes * 1e-16) fp
        # error; a sub-byte relative epsilon absorbs it without ever being
        # physically observable (sub-nanosecond at any realistic rate)
        self._eps = 1e-9 + self.n_bytes * 1e-12
        self.t_end: float | None = None
        self.segments: list[tuple[float, float, float, float]] = []

    @property
    def link(self) -> "Link | None":
        """First (injection-side) link — the whole link for single-hop flows."""
        return self.links[0] if self.links else None

    @property
    def done(self) -> bool:
        return self.t_end is not None

    def time_at_bytes(self, marks: np.ndarray) -> np.ndarray:
        """Times at which cumulative delivered bytes reach each mark (exact on
        the piecewise-linear progress curve)."""
        assert self.done, "flow not finished; Engine.wait/run first"
        if not self.segments:            # zero-byte flow
            return np.full(np.shape(marks), self.t_end)
        ts = [self.segments[0][0]]
        bs = [0.0]
        for t0, t1, b0, rate in self.segments:
            ts.append(t1)
            bs.append(b0 + rate * (t1 - t0))
        bs[-1] = self.n_bytes            # kill accumulated fp error at the end
        return np.interp(np.asarray(marks, dtype=float), bs, ts)

    def chunk_times(self, n_chunks: int, chunk_bytes: float) -> np.ndarray:
        """Completion time of each chunk's last byte."""
        marks = (np.arange(n_chunks) + 1.0) * chunk_bytes
        return self.time_at_bytes(np.minimum(marks, self.n_bytes))


class Link:
    """Directed bandwidth server: capacity is max-min shared among the active
    flows that cross it. ``src``/``dst`` carry the topology endpoints when the
    link belongs to a core/topology.py fabric; bytes_served is the live
    switch-port counter (Fig. 12). ``loss`` optionally carries a
    core/packet.py LossModel — the fluid engine itself ignores it; the
    packet-fidelity overlay samples per-packet drops from it."""

    __slots__ = ("name", "capacity", "active", "bytes_served", "src", "dst",
                 "loss")

    def __init__(self, name: str, capacity: float,
                 src: str | None = None, dst: str | None = None):
        assert capacity > 0, (name, capacity)
        self.name = name
        self.capacity = float(capacity)
        self.active: list[Flow] = []
        self.bytes_served = 0.0
        self.src = src
        self.dst = dst
        self.loss = None

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Link({self.name}, cap={self.capacity:g}, bytes={self.bytes_served:g})"


# Membership count at which the numpy progressive-filling path wins over the
# dict-based one (crossover measured on routed fat-tree sweeps).
_NUMPY_RATES_MIN_MEMBERS = 512


def _max_min_rates_py(active: list[Flow]) -> dict[Flow, float]:
    """Global max-min fair allocation by progressive filling (dict path).

    Repeatedly find the most-constrained link (smallest equal share among its
    unfrozen flows), freeze every unfrozen flow crossing any such link at that
    share, subtract the frozen rates from every link they cross, repeat.
    Per-flow rate caps freeze a flow early at its cap."""
    rem: dict[Link, float] = {}
    members: dict[Link, list[Flow]] = {}
    for f in active:
        for link in f.links:
            if link not in rem:
                rem[link] = link.capacity
                members[link] = []
            members[link].append(f)
    out: dict[Flow, float] = {}
    unfrozen: dict[Flow, None] = dict.fromkeys(active)   # insertion-ordered set
    while unfrozen:
        best = math.inf
        for link, fl in members.items():
            n = sum(1 for f in fl if f in unfrozen)
            if n:
                best = min(best, rem[link] / n)
        if best is math.inf:       # every remaining flow crosses no link
            for f in unfrozen:
                out[f] = f.rate_cap if f.rate_cap is not None else math.inf
            break
        capped = [f for f in unfrozen
                  if f.rate_cap is not None and f.rate_cap < best]
        if capped:
            for f in capped:
                out[f] = f.rate_cap
                del unfrozen[f]
                for link in f.links:
                    rem[link] = max(rem[link] - f.rate_cap, 0.0)
            continue
        newly: dict[Flow, None] = {}
        for link, fl in members.items():
            n = sum(1 for f in fl if f in unfrozen)
            if n and rem[link] <= best * n * (1.0 + 1e-12):
                for f in fl:
                    if f in unfrozen:
                        newly[f] = None
        for f in newly:
            out[f] = best
            del unfrozen[f]
        for f in newly:
            for link in f.links:
                rem[link] = max(rem[link] - best, 0.0)
    return out


def _max_min_rates_np(active: list[Flow]) -> dict[Flow, float]:
    """Vectorized progressive filling over the flow-link incidence (COO):
    identical allocation to _max_min_rates_py, used when thousands of tree
    flows cross thousands of fabric links (1024-host fat-tree sweeps)."""
    link_ix: dict[Link, int] = {}
    mf: list[int] = []
    ml: list[int] = []
    for i, f in enumerate(active):
        for link in f.links:
            j = link_ix.setdefault(link, len(link_ix))
            mf.append(i)
            ml.append(j)
    n_flows, n_links = len(active), len(link_ix)
    mfa = np.asarray(mf, dtype=np.intp)
    mla = np.asarray(ml, dtype=np.intp)
    caps = np.empty(n_links)
    for link, j in link_ix.items():
        caps[j] = link.capacity
    fcap = np.array([math.inf if f.rate_cap is None else f.rate_cap
                     for f in active])
    rate = np.zeros(n_flows)
    frozen = np.zeros(n_flows, dtype=bool)
    rem = caps.copy()
    while not frozen.all():
        live = ~frozen[mfa]
        cnt = np.bincount(mla[live], minlength=n_links).astype(float)
        has = cnt > 0
        if not has.any():
            rate[~frozen] = fcap[~frozen]
            break
        share = np.full(n_links, np.inf)
        share[has] = rem[has] / cnt[has]
        best = share.min()
        cap_hit = ~frozen & (fcap < best)
        if cap_hit.any():
            rate[cap_hit] = fcap[cap_hit]
            frozen |= cap_hit
            hit_m = cap_hit[mfa]
            rem -= np.bincount(mla[hit_m], weights=rate[mfa[hit_m]],
                               minlength=n_links)
            np.maximum(rem, 0.0, out=rem)
            continue
        tight = has & (share <= best * (1.0 + 1e-12))
        newly = np.zeros(n_flows, dtype=bool)
        newly[mfa[tight[mla] & live]] = True
        rate[newly] = best
        frozen |= newly
        rem -= best * np.bincount(mla[newly[mfa]], minlength=n_links)
        np.maximum(rem, 0.0, out=rem)
    return dict(zip(active, rate.tolist()))


class Engine:
    """Event-driven fluid simulator. Flows may be submitted with future start
    times; the loop advances between starts and finishes, recomputing the
    max-min rate allocation at every event.

    The allocation is maintained INCREMENTALLY: the engine keeps the
    flow-link incidence live (every Link holds its active flows), and when
    flows arrive or complete it re-runs progressive filling only over the
    affected connected component of the flow-link graph — flows in
    components the event cannot touch keep their cached rates. All events
    sharing a timestamp are batched into one dirty set, so a tree finish
    that releases thousands of links triggers one component solve, not
    thousands. Disjoint components share no links, so per-component
    progressive filling performs the identical float operations in the
    identical order as the global solve (modulo the measure-zero case of a
    cross-component share tie within the 1e-12 freeze tolerance) —
    tests/test_maxmin_incremental.py pins rate-for-rate equality against
    the global oracle on random flow/link DAGs. ``ENGINE_MAXMIN=reference``
    (mirroring ``REPRO_PACKET_ENGINE``) forces the pre-incremental global
    re-solve on every event; a CI matrix leg keeps that path green."""

    def __init__(self, t0: float = 0.0):
        self.now = t0
        self._links: dict[str, Link] = {}
        self._pending: list[tuple[float, int, Flow]] = []   # start events
        self._active: list[Flow] = []
        self._seq = itertools.count()
        mode = os.environ.get("ENGINE_MAXMIN", "") or "incremental"
        assert mode in ("incremental", "reference"), mode
        self._maxmin_mode = mode
        # incremental solver state: cached rates (valid for the current
        # _active set once _dirty drains) + flows whose arrival/completion
        # invalidated their component since the last solve
        self._rates_cache: dict[Flow, float] = {}
        self._dirty: list[Flow] = []
        # solve telemetry (component-locality tests + --profile breakdown)
        self.maxmin_solves = 0
        self.maxmin_flows_solved = 0

    # -- construction
    def add_link(self, name: str, capacity: float) -> Link:
        if name not in self._links:
            self._links[name] = Link(name, capacity)
        return self._links[name]

    def _resolve_links(self, route) -> tuple[Link, ...]:
        """Accepts a link name, a Link, or a sequence of either. Foreign Link
        objects (a topology's) are registered so utilization()/link_bytes()
        see them; name collisions with distinct objects are rejected."""
        if isinstance(route, (str, Link)):
            route = (route,)
        out: list[Link] = []
        seen: set[int] = set()
        for item in route:
            link = self._links[item] if isinstance(item, str) else item
            assert isinstance(link, Link), item
            registered = self._links.setdefault(link.name, link)
            assert registered is link, f"link name collision: {link.name}"
            assert id(link) not in seen, f"duplicate link in route: {link.name}"
            seen.add(id(link))
            out.append(link)
        return tuple(out)

    def submit(self, route, n_bytes: float, *, t_start: float | None = None,
               tag: str | None = None, rate_cap: float | None = None) -> Flow:
        """Submit a flow across ``route``: a registered link name, a Link, or
        an ordered sequence of links (the output of Topology.route /
        Topology.multicast_tree). The flow's rate is the global max-min share,
        never more than the smallest share over the links it crosses; its
        bytes are charged to every link. An empty route completes instantly
        at t_start (src == dst)."""
        t = self.now if t_start is None else float(t_start)
        assert t >= self.now - 1e-12, (t, self.now, "cannot submit in the past")
        flow = Flow(self._resolve_links(route), n_bytes, t, tag, rate_cap)
        heapq.heappush(self._pending, (t, next(self._seq), flow))
        return flow

    def submit_route(self, route, n_bytes: float, **kw) -> Flow:
        """Unicast flow along an ordered Link path (alias of submit)."""
        return self.submit(route, n_bytes, **kw)

    def submit_tree(self, edges, n_bytes: float, **kw) -> Flow:
        """Multicast tree flow: the switch-replication model. The stream is
        replicated down every branch concurrently, so the rate is the min
        share over every tree edge and every edge serves the full n_bytes
        (alias of submit — the fluid mechanics are identical to a route)."""
        return self.submit(edges, n_bytes, **kw)

    # -- event loop
    def _solve(self, flows: list[Flow]) -> dict[Flow, float]:
        """Progressive filling over ``flows``; the numpy COO path cuts in
        by the GLOBAL active membership count (the same rule whether the
        solve covers one component or everything, so incremental and
        reference modes run the same solver on the same scenario)."""
        n_members = sum(len(f.links) for f in self._active)
        solver = (_max_min_rates_np if n_members >= _NUMPY_RATES_MIN_MEMBERS
                  else _max_min_rates_py)
        self.maxmin_solves += 1
        self.maxmin_flows_solved += len(flows)
        if profiling.ENABLED:
            with profiling.phase("engine_solve"):
                return solver(flows)
        return solver(flows)

    def _rates(self) -> dict[Flow, float]:
        """Full (global) max-min allocation over the current active set."""
        if not self._active:
            return {}
        return self._solve(self._active)

    def _component(self, seed_links) -> list[Flow]:
        """Flows connected (transitively, via shared links) to any seed
        link — the dirty component(s) an arrival/completion can affect —
        in _active order, so per-component progressive filling visits
        flows in the same relative order as the global solve."""
        seen_links: set[int] = set()
        stack: list[Link] = []
        for link in seed_links:
            if id(link) not in seen_links:
                seen_links.add(id(link))
                stack.append(link)
        comp_ids: set[int] = set()
        while stack:
            link = stack.pop()
            for f in link.active:
                if id(f) not in comp_ids:
                    comp_ids.add(id(f))
                    for l2 in f.links:
                        if id(l2) not in seen_links:
                            seen_links.add(id(l2))
                            stack.append(l2)
        return [f for f in self._active if id(f) in comp_ids]

    def _current_rates(self) -> dict[Flow, float]:
        """The cached allocation, re-solving only the dirty component(s)
        batched since the last event (``ENGINE_MAXMIN=reference`` escape
        hatch: global re-solve every time, the pre-incremental behavior)."""
        if self._maxmin_mode == "reference":
            return self._rates()
        if self._dirty:
            dirty, self._dirty = self._dirty, []
            cache = self._rates_cache
            seed_links: list[Link] = []
            for f in dirty:
                if f.t_end is not None:
                    cache.pop(f, None)
                seed_links.extend(f.links)
            if not self._active:
                cache.clear()
            else:
                comp = self._component(seed_links)
                if comp:
                    cache.update(self._solve(comp))
        return self._rates_cache

    def _progress(self, dt: float, rates: dict[Flow, float]) -> None:
        if dt <= 0:
            return
        for f in self._active:
            r = rates.get(f, 0.0)
            f.segments.append((self.now, self.now + dt, f.n_bytes - f.remaining, r))
            moved = min(r * dt, f.remaining)
            f.remaining -= moved
            for link in f.links:
                link.bytes_served += moved

    def _step(self, t_limit: float) -> bool:
        """Advance to the next event (or t_limit). Returns False when idle."""
        rates = self._current_rates()
        t_next = t_limit
        if self._pending:
            t_next = min(t_next, self._pending[0][0])
        for f in self._active:
            r = rates.get(f, 0.0)
            if r > 0:
                t_next = min(t_next, self.now + f.remaining / r)
        if t_next == math.inf:
            return False
        self._progress(t_next - self.now, rates)
        self.now = t_next
        # finishes (also flows whose residual would not advance the clock —
        # their finish time is indistinguishable from `now` in float64)
        still = []
        touched: set[Link] = set()
        changed: list[Flow] = []
        for f in self._active:
            r = rates.get(f, 0.0)
            stalled = r > 0 and self.now + f.remaining / r <= self.now
            if f.remaining <= f._eps or stalled:
                f.remaining = 0.0
                f.t_end = self.now
                touched.update(f.links)
                changed.append(f)
            else:
                still.append(f)
        self._active = still
        # batch-remove finished flows per link (a tree finish can retire one
        # flow from thousands of links; per-flow list.remove would be O(n^2))
        for link in touched:
            link.active = [fl for fl in link.active if fl.t_end is None]
        # starts
        while self._pending and self._pending[0][0] <= self.now + 1e-15:
            _, _, f = heapq.heappop(self._pending)
            if f.n_bytes <= 0 or not f.links:
                f.t_end = max(self.now, f.t_start)
            else:
                for link in f.links:
                    link.active.append(f)
                self._active.append(f)
                changed.append(f)
        # every same-timestamp arrival/completion lands in ONE dirty batch;
        # the next _current_rates() call re-solves their component(s) once
        self._dirty.extend(changed)
        return bool(self._active or self._pending)

    def advance_to(self, t: float) -> None:
        while self.now < t and self._step(t):
            pass
        self.now = max(self.now, t)

    def wait(self, *flows: Flow) -> float:
        """Advance until all given flows complete; returns the completion time
        of the latest one."""
        while any(not f.done for f in flows):
            if not self._step(math.inf):
                break
        assert all(f.done for f in flows), "deadlock: flows never started"
        return max(f.t_end for f in flows)

    def run(self) -> float:
        """Drain every submitted flow; returns the final time."""
        while self._step(math.inf):
            pass
        return self.now

    def utilization(self, horizon: float | None = None) -> dict[str, float]:
        """Per-link bytes_served / (capacity * horizon)."""
        h = horizon if horizon is not None else self.now
        if h <= 0:
            return {n: 0.0 for n in self._links}
        return {n: l.bytes_served / (l.capacity * h) for n, l in self._links.items()}

    def link_bytes(self) -> dict[str, float]:
        """Live per-link byte counters — the switch-port view of Fig. 12."""
        return {n: l.bytes_served for n, l in self._links.items()}


# ------------------------------------------------- leaf worker pool (receive)


def worker_pool_completion_loop(arrivals: np.ndarray, n_workers: int,
                                service: float, staging: int) -> tuple[np.ndarray, int]:
    """Reference O(n) implementation of the T-server deterministic-service
    queue with staging-ring (RNR) overflow counting. arrivals must be sorted.
    Kept verbatim from the pre-engine simulator as the regression oracle."""
    n = arrivals.shape[0]
    done = np.empty(n)
    rnr = 0
    for k in range(n):
        start = arrivals[k] if k < n_workers else max(arrivals[k], done[k - n_workers])
        if k >= staging and done[k - staging] > arrivals[k]:
            rnr += 1
        done[k] = start + service
    return done, rnr


def staging_rnr_mask(done: np.ndarray, arrivals: np.ndarray,
                     staging: int) -> np.ndarray:
    """Staging-ring (RNR) overflow rule, shared by EVERY pool fidelity
    (scalar T-server queue, merged allgather pools, the event-level DPA):
    chunk k is dropped when the chunk ``staging`` places ahead of it is
    still unserviced at k's arrival. One definition — the scalar and event
    fidelities must never diverge on it (the zero-cost exactness pins rely
    on that)."""
    n = arrivals.shape[0]
    mask = np.zeros(n, dtype=bool)
    if n > staging:
        over = np.nonzero(done[: n - staging] > arrivals[staging:])[0]
        mask[staging + over] = True
    return mask


def worker_pool_completion(arrivals: np.ndarray, n_workers: int,
                           service: float, staging: int) -> tuple[np.ndarray, int]:
    """Vectorized equivalent of worker_pool_completion_loop.

    With deterministic service s and round-robin dispatch, chunks k, k+W,
    k+2W, ... form independent single-server chains:
        done_i = max(a_i, done_{i-1}) + s = (i+1)s + max_{j<=i}(a_j - j*s)
    — a running max per residue class, so the whole pool is n_workers numpy
    maximum.accumulate passes.
    """
    n = arrivals.shape[0]
    if n == 0:
        return np.empty(0), 0
    done = np.empty(n)
    w = max(int(n_workers), 1)
    for r in range(min(w, n)):
        idx = np.arange(r, n, w)
        i = np.arange(idx.size, dtype=float)
        shifted = arrivals[idx] - i * service
        done[idx] = np.maximum.accumulate(shifted) + (i + 1.0) * service
    rnr = int(staging_rnr_mask(done, arrivals, staging).sum())
    return done, rnr


def worker_pool_completion_rows(arrivals: np.ndarray, n_workers: int,
                                service: float, staging: int,
                                ) -> tuple[np.ndarray, np.ndarray]:
    """Row-batched twin of worker_pool_completion + staging_rnr_mask: one
    pool pass over R stacked arrival rows at once (the vectorized packet
    engine's coalesced per-leaf DPA pass). ``arrivals`` is (R, n), each row
    sorted; ragged rows are padded at the END with +inf. Returns
    (done (R, n), rnr_mask (R, n)); padded columns come back +inf / False.

    Bit-exact per row with the 1-D functions on the real prefix: a chunk's
    residue class is its absolute position mod W and its within-class index
    is position // W — both independent of the row length — and the
    maximum.accumulate runs left-to-right, so trailing +inf padding cannot
    reach any real entry. The same float ops run in the same order as the
    1-D pass (tests/test_engine.py pins the equivalence).

    The inner path is kernels/pool_np.py's residue-class-parallel scan
    (one blocked maximum.accumulate over a (rows, n/W, W) view instead of
    W fancy-index passes — the compiled-kernel twin lives in
    kernels/pool.py); it closed the DESIGN §9 dense big-row allgather
    regime that used to force the packet engine back to the per-leaf
    reference executor."""
    assert arrivals.ndim == 2, arrivals.shape
    n = arrivals.shape[1]
    if n == 0:
        return np.empty_like(arrivals), np.zeros(arrivals.shape, dtype=bool)
    if profiling.ENABLED:
        with profiling.phase("pool_solve"):
            return pool_completion_rows_np(arrivals, n_workers, service,
                                           staging)
    return pool_completion_rows_np(arrivals, n_workers, service, staging)


# ----------------------------------------------------- FSDP contention model


FSDP_POLICIES = ("naive", "mcast", "split")
PROGRESS_ENGINES = ("dpa", "host")


@dataclass
class FsdpStepResult:
    policy: str
    step_time: float                  # wall time of fwd + bwd (+ RS drain)
    compute_time: float               # sum of useful layer compute
    bubble_fraction: float            # 1 - compute_time / step_time
    phase_times: dict[str, float]     # forward / backward / rs_drain
    link_utilization: dict[str, float]
    ag_bytes: float                   # per-node AG bytes moved (dominant dir)
    rs_bytes: float
    n_layers: int
    p: int
    progress_engine: str = "dpa"      # who runs the reliability datapath
    datapath_tput: float | None = None  # host engine bytes/s (None: DPA/line)


@dataclass(frozen=True)
class LayerProfile:
    """One layer of a heterogeneous FSDP step (simulate_fsdp_step
    ``layers=``): compute seconds at full-node capability plus the layer's
    parameter bytes (its AG/RS wire volume). core/train_sim.py derives
    these from registry model shapes via the launch/analytic_costs.py
    roofline; any caller can hand-build them."""
    fwd_s: float          # forward compute seconds (full node, no stealing)
    bwd_s: float          # backward compute seconds
    layer_bytes: float    # parameter bytes gathered/reduce-scattered

    def __post_init__(self):
        assert self.fwd_s >= 0.0 and self.bwd_s >= 0.0, (self.fwd_s,
                                                         self.bwd_s)
        assert self.layer_bytes > 0.0, self.layer_bytes


def _layer_bytes_from_model(model: "ModelConfig", dtype_bytes: int) -> tuple[int, float]:
    """(n_layers, bytes of parameters per layer) from a registered config.
    Imported lazily: configs pull in the jax model builders."""
    from repro.models.model_builder import count_params_analytic

    n_layers = model.num_layers
    return n_layers, count_params_analytic(model) / n_layers * dtype_bytes


def _make_ag_loss_overlay(fidelity: str, loss, rng, policy: str, topology,
                          hosts, p: int, gather_bytes: float,
                          shard_bytes: float, fabric: FabricParams,
                          workers: "WorkerParams | None"):
    """Per-layer AG loss/recovery penalty sampler for fidelity="packet".

    Multicast policies: sample per-Link drops on every AG tree and pay the
    NACK + multicast-retransmission rounds of packet.recovery_overlay (max
    over trees — the layer's AG is ready when ALL trees recovered). Unicast
    "naive": deterministic RC goodput inflation 1/(1-q_path). Returns a
    zero-cost callable for the fluid fidelity.

    The returned callable takes ``(gather_b=gather_bytes,
    shard_b=shard_bytes)`` so heterogeneous layers (``layers=``) pay the
    penalty at THEIR byte volume; the no-argument call keeps the uniform
    path bit-exact (defaults are the uniform quantities)."""
    if fidelity != "packet":
        return lambda *a: 0.0
    from repro.core import packet as packet_mod  # deferred: imports engine

    rng = rng if rng is not None else np.random.default_rng(0)
    template = packet_mod.resolve_loss(loss, fabric)
    if template is None:
        return lambda *a: 0.0
    if workers is None:
        # NACK-service default: a fully-threaded DPA core (workers_from_dpa
        # lets callers derive this from a DpaConfig instead)
        workers = WorkerParams(n_recv_workers=16)
    hosts = list(hosts)

    if policy == "naive":
        if topology is not None:
            hops = [len(topology.route(hosts[i], hosts[(i + 1) % p]))
                    for i in range(p)]
            path_len = max(sum(hops) / len(hops), 1.0)
        else:
            path_len = 1.0
        inflation = packet_mod.rc_goodput_inflation(template.mean_rate,
                                                    path_len)

        def naive_overlay(gather_b: float = gather_bytes,
                          shard_b: float = shard_bytes) -> float:
            return 2.0 * gather_b / fabric.b_link * inflation

        return naive_overlay

    from repro.core.simulator import _chunking  # deferred, like packet_mod

    tree_infos = []
    if topology is not None:
        all_models: dict[int, object] = {}
        for h in hosts:
            tree = topology.multicast_tree(h, hosts)
            paths = packet_mod.tree_paths(
                tree, topology.host(h),
                [topology.host(x) for x in hosts if x != h])
            for links in paths.values():
                for link in links:
                    if id(link) not in all_models:
                        all_models[id(link)] = (link.loss
                                                or template.fork(rng))
            models = {id(link): all_models[id(link)]
                      for links in paths.values() for link in links}
            tree_infos.append((paths, models,
                               min(link.capacity for link in tree)))
    else:
        # one carrier (one loss process) per leaf ejection link, SHARED by
        # every tree crossing it — mirrors simulate_packet_allgather's
        # abstract mode; per-tree forks would decorrelate bursts that
        # physically hit all trees at once
        carriers = {x: packet_mod._AbstractCarrier() for x in hosts}
        leaf_models = {x: template.fork(rng) for x in sorted(carriers)}
        for h in hosts:
            paths = {x: [carriers[x]] for x in hosts if x != h}
            models = {id(carriers[x]): leaf_models[x] for x in hosts
                      if x != h}
            tree_infos.append((paths, models, fabric.b_link))

    def overlay(gather_b: float = gather_bytes,
                shard_b: float = shard_bytes) -> float:
        n_chunks, chunk = _chunking(int(shard_b), fabric.mtu)
        return max(packet_mod.recovery_overlay(
            paths, models, n_chunks, chunk, rate, fabric, workers, rng)
            for paths, models, rate in tree_infos)

    return overlay


def simulate_fsdp_step(model: "ModelConfig | None" = None, *,
                       n_layers: int = 32, layer_bytes: float = 256e6,
                       layers: "list[LayerProfile] | None" = None,
                       p: int = 16,
                       fabric: FabricParams | None = None,
                       policy: str = "naive",
                       n_chains: int = 2,
                       tokens_per_device: int = 4096,
                       hw_flops: float = 200e12,
                       dtype_bytes: int = 2,
                       topology=None, hosts=None,
                       fidelity: str = "fluid", loss=None,
                       rng: "np.random.Generator | None" = None,
                       workers: "WorkerParams | None" = None,
                       progress_engine: str = "dpa",
                       host_cores: int = 2,
                       host_total_cores: int = 108,
                       schedule=None) -> FsdpStepResult:
    """Interleaved forward-AG + backward-RS + compute FSDP timeline.

    Per layer the parameters live sharded 1/p per node; the forward pass
    allgathers layer i+1 during layer i's compute (prefetch), the backward
    pass re-gathers parameters in reverse order while asynchronously
    reduce-scattering each layer's gradients — the AG and RS streams overlap
    and contend for the node's injection/ejection bandwidth. Policies:

      naive   AG and RS are P2P rings on one shared half-duplex medium of
              capacity B: every flow carries send+recv bytes and serializes.
      mcast   the paper's M-chain multicast Allgather on a full-duplex NIC:
              AG injects only the node's own shard (the switch replicates),
              its receive stream shares the ejection link with the ring RS
              receive stream; chain activation adds R = P/M latency hops.
      split   Insight 2 direction split: the {AG_mc, RS_inc} pairing of
              cost_model.mc_inc_share — AG_mc is receive-bound (injects only
              1/P), RS_inc is send-bound (in-network reduction: the node
              receives only its reduced shard), so neither direction is a
              shared bottleneck (the torus analogue is concurrent_ag_rs in
              core/collectives.py: AG clockwise, RS counter-clockwise).

    With ``topology=`` (core/topology.py) the hand-built two-link NIC models
    are replaced by ROUTED traffic on a real fabric, hosts placed at
    ``hosts`` (default 0..p-1); the policies then differ by what they put on
    the wire rather than by link wiring:

      naive   AG and RS are both P2P rings of routed unicast flows (same
              direction), colliding on every shared fabric link.
      mcast   AG is P multicast tree flows (each host injects 1/P, switches
              replicate); RS stays a routed P2P ring, so RS down-traffic
              contends with the AG trees at every ejection port.
      split   AG multicast trees down + RS in-network-reduction aggregation
              trees up (topology.aggregation_tree) — opposite link
              directions, no shared bottleneck (Insight 2 on the fabric).

    bubble_fraction = 1 - compute_time / step_time: the fraction of the step
    the compute units sit idle waiting on exposed communication.

    ``fidelity="packet"`` overlays the core/packet.py loss/recovery model on
    every layer's AG readiness: multicast policies sample per-Link drops on
    the AG trees and pay NACK-aggregation + retransmission rounds at the
    tree bottleneck rate (packet.recovery_overlay — a stated approximation:
    recovery flows do not re-enter the global max-min allocation); the
    unicast "naive" policy pays the RC goodput inflation 1/(1-q_path).
    ``loss`` is a rate or a packet.LossModel; ``rng`` seeds the sampling;
    ``workers`` sets the NACK-service pool (e.g. via workers_from_dpa —
    default: one fully-threaded DPA core, 16 workers).

    ``progress_engine`` selects who runs the reliability datapath (§VII-d):

      "dpa"   (default) the SmartNIC DPA: the receive datapath keeps up
              with the wire (Figs 13/14) and the HOST cores are freed for
              compute — the freed-host-cycles benefit of the offload.
      "host"  1-4 Epyc-class cores (``host_cores``) run the protocol in
              software (Fig 5, core/dpa_engine.py EventDpaParams.host_cpu:
              no hardware thread contexts, nothing hides the stalls). Two
              costs enter the bubble accounting: each layer's AG is not
              ready until its gather bytes ALSO drained through the host
              engine's measured throughput, and the stolen cores stretch
              every layer's compute by host_total_cores /
              (host_total_cores - host_cores) (2x 54-core Xeons per
              SuperPOD node — §VII-d).

    ``layers=`` replaces the uniform (n_layers, layer_bytes, tokens/flops)
    compute model with an explicit heterogeneous per-layer profile (a
    LayerProfile per layer: fwd/bwd seconds + parameter bytes). The op
    template is built at the LARGEST layer's bytes and each layer's flows
    are scaled down to its own volume; compute seconds are taken verbatim
    (compute_scale still applies for the host progress engine). With all
    layers identical the timeline arithmetic is bit-exact the uniform
    path's — tests pin a uniform ``layers=`` call against the legacy
    parameterization. core/train_sim.py derives these profiles from
    registry model shapes.
    """
    assert policy in FSDP_POLICIES, policy
    assert fidelity in ("fluid", "packet"), fidelity
    assert progress_engine in PROGRESS_ENGINES, progress_engine
    # same footgun guard as simulate_broadcast/simulate_allgather: a loss
    # model without packet fidelity would be silently ignored
    assert fidelity == "packet" or loss is None, \
        "loss models require fidelity='packet'"
    fabric = fabric or FabricParams()
    if model is not None:
        assert layers is None, "pass model= or layers=, not both"
        n_layers, layer_bytes = _layer_bytes_from_model(model, dtype_bytes)
    if layers is not None:
        layers = list(layers)
        n_layers = len(layers)
        # the op template carries the largest layer; smaller layers scale
        # their flows down through the submitters' scale argument
        layer_bytes = max(lp.layer_bytes for lp in layers)
    assert p >= 2 and n_layers >= 1

    if progress_engine == "host":
        from repro.core import dpa_engine  # deferred: keeps import light

        assert 1 <= host_cores < host_total_cores, (host_cores,
                                                    host_total_cores)
        datapath_cap = dpa_engine.pool_tput_event(
            dpa_engine.EventDpaParams.host_cpu(host_cores))
        compute_scale = host_total_cores / (host_total_cores - host_cores)
    else:
        datapath_cap = None
        compute_scale = 1.0

    gather_bytes = (p - 1) / p * layer_bytes     # bytes a node must receive
    shard_bytes = layer_bytes / p
    if layers is None:
        fwd_t = (2.0 * (layer_bytes / dtype_bytes) * tokens_per_device
                 / hw_flops * compute_scale)
        bwd_t = 2.0 * fwd_t
        fwd_ts = [fwd_t] * n_layers
        bwd_ts = [bwd_t] * n_layers
        scales = [1.0] * n_layers                # x * 1.0 is bit-exact
        gathers = [gather_bytes] * n_layers
        shards = [shard_bytes] * n_layers
    else:
        fwd_ts = [lp.fwd_s * compute_scale for lp in layers]
        bwd_ts = [lp.bwd_s * compute_scale for lp in layers]
        scales = [lp.layer_bytes / layer_bytes for lp in layers]
        gathers = [(p - 1) / p * lp.layer_bytes for lp in layers]
        shards = [lp.layer_bytes / p for lp in layers]

    # the step's per-layer AG/RS collectives as a schedule graph; the IR
    # lowering (sched_ir.fsdp_submitters) builds the per-policy flows —
    # routed fabric trees/rings or the abstract representative-rank NIC.
    # ``schedule=`` lets sched_ir.execute hand over the already-built graph
    from repro.core import sched_ir  # deferred: sched_ir imports this module

    sched = schedule
    if sched is None:
        sched = sched_ir.build_fsdp_step(
            p=p, n_layers=n_layers, layer_bytes=layer_bytes, policy=policy,
            n_chains=n_chains)
    else:
        assert sched.kind == "fsdp_step" and sched.p == p \
            and sched.meta["policy"] == policy, (sched.kind, sched.p, policy)
    eng = Engine()
    if topology is not None:
        topology.reset()
    submit_ag, submit_rs, ag_sync = sched_ir.fsdp_submitters(
        sched, eng, fabric, topology=topology,
        hosts=hosts if hosts is not None else range(p))

    ag_overlay = _make_ag_loss_overlay(
        fidelity, loss, rng, policy, topology,
        hosts if hosts is not None else range(p), p,
        gather_bytes, shard_bytes, fabric, workers)
    compute_total = 0.0

    # bubble accounting counts USEFUL compute at full-node capability: the
    # host-engine stretch (stolen cores) is protocol overhead and must show
    # up as bubble, exactly like exposed communication — this is where the
    # freed-host-cycles benefit of the DPA offload becomes measurable
    fwd_useful = [ft / compute_scale for ft in fwd_ts]
    bwd_useful = [bt / compute_scale for bt in bwd_ts]

    def ag_ready(t_submit: float, flows, i: int) -> float:
        """A layer's parameters are usable when the wire delivered them AND
        (host progress engine only) the gather bytes drained through the
        software receive datapath at its measured throughput."""
        t_wire = eng.wait(*flows)
        if datapath_cap is not None:
            t_wire = max(t_wire, t_submit + gathers[i] / datapath_cap)
        return t_wire + ag_sync + ag_overlay(gathers[i], shards[i])

    # ---- forward: AG(i+1) prefetched at compute-start of layer i
    ag = [None] * n_layers
    ag[0] = (0.0, submit_ag(0.0, scales[0]))
    t = 0.0
    for i in range(n_layers):
        start = max(t, ag_ready(*ag[i], i))
        if i + 1 < n_layers:
            ag[i + 1] = (start, submit_ag(start, scales[i + 1]))
        t = start + fwd_ts[i]
        compute_total += fwd_useful[i]
    t_fwd_end = t

    # ---- backward: re-gather params in reverse order, RS grads async
    ag_b = [None] * n_layers
    ag_b[n_layers - 1] = (t_fwd_end, submit_ag(t_fwd_end,
                                               scales[n_layers - 1]))
    rs_flows: list[Flow] = []
    for i in range(n_layers - 1, -1, -1):
        start = max(t, ag_ready(*ag_b[i], i))
        if i - 1 >= 0:
            ag_b[i - 1] = (start, submit_ag(start, scales[i - 1]))
        t = start + bwd_ts[i]
        compute_total += bwd_useful[i]
        rs_flows += submit_rs(t, scales[i])
    t_bwd_end = t

    t_rs_done = eng.wait(*rs_flows) if rs_flows else t_bwd_end
    step_time = max(t_bwd_end, t_rs_done)
    eng.advance_to(step_time)

    return FsdpStepResult(
        policy=policy,
        step_time=step_time,
        compute_time=compute_total,
        bubble_fraction=1.0 - compute_total / step_time,
        phase_times={
            "forward": t_fwd_end,
            "backward": t_bwd_end - t_fwd_end,
            "rs_drain": max(t_rs_done - t_bwd_end, 0.0),
        },
        link_utilization=eng.utilization(step_time),
        # forward prefetch + backward re-gather / one RS per layer
        ag_bytes=(gather_bytes * 2 * n_layers if layers is None
                  else 2.0 * sum(gathers)),
        rs_bytes=(gather_bytes * n_layers if layers is None
                  else float(sum(gathers))),
        n_layers=n_layers,
        p=p,
        progress_engine=progress_engine,
        datapath_tput=datapath_cap,
    )


def sweep_fsdp_contention(*, ps=(8, 16, 64), layer_bytes=(64e6, 256e6),
                          n_layers: int = 8,
                          fabric: FabricParams | None = None,
                          policies=FSDP_POLICIES,
                          hw_flops: float = 200e12,
                          tokens_per_device: int = 4096) -> list[dict]:
    """Grid of simulate_fsdp_step calls — the benchmarks/run.py --smoke sweep
    and the paper_figs FSDP-contention table both render these rows."""
    fabric = fabric or FabricParams()
    rows = []
    for p in ps:
        for lb in layer_bytes:
            per_policy = {}
            for pol in policies:
                r = simulate_fsdp_step(
                    n_layers=n_layers, layer_bytes=lb, p=p, fabric=fabric,
                    policy=pol, hw_flops=hw_flops,
                    tokens_per_device=tokens_per_device,
                )
                per_policy[pol] = r
                rows.append({
                    "p": p, "layer_bytes": lb, "policy": pol,
                    "step_time": r.step_time,
                    "bubble_fraction": r.bubble_fraction,
                    "link_utilization": r.link_utilization,
                })
            if "naive" in per_policy and "split" in per_policy:
                assert (per_policy["split"].bubble_fraction
                        <= per_policy["naive"].bubble_fraction + 1e-12), (
                    p, lb, per_policy["split"].bubble_fraction,
                    per_policy["naive"].bubble_fraction,
                )
    return rows


# ------------------------------------------------ multi-job fabric contention


@dataclass
class MultiJobResult:
    policy: str
    n_layers: int
    solo_time: dict[str, float]        # each job alone on the fabric
    contended_time: dict[str, float]   # all jobs co-scheduled
    slowdown: dict[str, float]         # contended / solo, per job
    core_bytes: float                  # contended-run bytes on agg<->core tier
    link_utilization: dict[str, float]  # contended run, per fabric link


def simulate_multi_job(topology, jobs: dict[str, "list[int]"], *,
                       layer_bytes: float = 256e6, n_layers: int = 4,
                       policy: str = "mcast",
                       fabric: FabricParams | None = None,
                       hw_flops: float = 200e12,
                       tokens_per_device: int = 4096,
                       dtype_bytes: int = 2) -> MultiJobResult:
    """Co-simulate several FSDP jobs on DISJOINT host sets of one fabric.

    Each job runs n_layers sequential layer steps: allgather the layer's
    parameters (per ``policy``, routed exactly as simulate_fsdp_step's
    topology mode), then compute, then the next layer's AG. The jobs share no
    hosts, but their routed flows meet on shared edge/agg/core links — the
    contention an abstract per-NIC model cannot see (and the reason Fig. 12
    is measured at switch port counters). Each job is also run alone on the
    same fabric; slowdown = contended / solo isolates the interference.

    The co-simulation interleaves the jobs' timelines on ONE engine: after
    every engine event, any job whose outstanding AG completed submits its
    next layer at now + sync + compute.
    """
    fabric = fabric or FabricParams()
    names = list(jobs)
    all_hosts = [h for hs in jobs.values() for h in hs]
    assert len(set(all_hosts)) == len(all_hosts), "jobs must use disjoint hosts"
    assert all(len(hs) >= 2 for hs in jobs.values())

    from repro.core import sched_ir  # deferred: sched_ir imports this module

    def run(subset: list[str]) -> tuple[dict[str, float], Engine]:
        topology.reset()
        eng = Engine()
        state: dict[str, dict] = {}
        for name in subset:
            hs = list(jobs[name])
            p = len(hs)
            sched = sched_ir.build_fsdp_step(
                p=p, n_layers=n_layers, layer_bytes=layer_bytes,
                policy=policy, n_chains=p)
            submit_ag, _, ag_sync = sched_ir.fsdp_submitters(
                sched, eng, fabric, topology=topology, hosts=hs)
            state[name] = {
                "submit": submit_ag, "sync": ag_sync,
                "fwd": 2.0 * (layer_bytes / dtype_bytes) * tokens_per_device
                       / hw_flops,
                "layer": 0, "flows": None, "end": None,
            }
        for st in state.values():
            st["flows"] = st["submit"](0.0)
        idle_seen = False
        while True:
            progressed = True
            while progressed:      # a finish may unblock several jobs at once
                progressed = False
                for st in state.values():
                    if st["end"] is None and all(f.done for f in st["flows"]):
                        st["layer"] += 1
                        t_next = eng.now + st["sync"] + st["fwd"]
                        if st["layer"] >= n_layers:
                            st["end"] = t_next
                        else:
                            st["flows"] = st["submit"](t_next)
                            progressed = True
            if all(st["end"] is not None for st in state.values()):
                break
            # _step returns False on the same call that retires the last
            # flows; give the completion pass above one more look before
            # calling an idle engine with unfinished jobs a deadlock
            if not eng._step(math.inf):
                assert idle_seen is False, "multi-job co-simulation deadlocked"
                idle_seen = True
            else:
                idle_seen = False
        return {name: state[name]["end"] for name in subset}, eng

    solo: dict[str, float] = {}
    for name in names:
        solo.update(run([name])[0])
    contended, eng = run(names)
    horizon = max(contended.values())
    core = getattr(topology, "core_links", None)
    core_bytes = sum(l.bytes_served for l in core()) if core else 0.0
    return MultiJobResult(
        policy=policy,
        n_layers=n_layers,
        solo_time=solo,
        contended_time=contended,
        slowdown={n: contended[n] / solo[n] for n in names},
        core_bytes=core_bytes,
        link_utilization=eng.utilization(horizon),
    )
