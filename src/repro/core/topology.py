"""Route-providing network topologies backed by live engine Links.

The paper's headline claims are *fabric-level*: Fig. 12 measures the 2x
traffic reduction at switch port counters on a 188-node fat-tree, and the
M-chain Allgather schedule exists to control in-fabric incast. This module is
therefore the route provider for the fluid engine (core/engine.py): a
``Topology`` owns one ``engine.Link`` per directed physical cable, and

  route(src, dst)              returns the ordered Link path (deterministic
                               up-down ECMP on the fat-tree, dimension-ordered
                               shortest ring paths on the torus);
  multicast_tree(root, members) returns the Link edge set of the switch
                               multicast distribution tree;
  aggregation_tree(root, members) the reversed tree — in-network reduction
                               (RS_inc): members send up, switches reduce;
  links()                      every physical directed link, with per-tier
                               capacities and an oversubscription factor.

Byte counters are the Links' own live ``bytes_served``: an Engine run over
routed flows *is* the traffic measurement (the software analogue of the
paper's switch port counters) — ``counters`` is only a read-only view of
them, and the static ``unicast``/``multicast`` helpers (the analytic Fig. 2
path, no timing) charge the same Link objects.

Topologies:
  - FatTree: 3-level full fat-tree of radix-k switches (paper's testbed
    shape; Fig. 2 models 1024 nodes / radix 32).
  - Torus2D: the TPU ICI analogue; bidirectional neighbor ring links.

"Bandwidth-optimal" on the fat-tree means every byte of every send buffer
crosses any link at most once (Insight 1); see DESIGN.md §6 for the fabric
engine architecture.
"""
from __future__ import annotations

import math
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Protocol, Sequence, runtime_checkable

from repro.core.engine import Link

#: default per-direction host link rate (200 Gbit/s, the paper's NIC)
DEFAULT_LINK_BYTES = 200e9 / 8

#: fabric link tiers, innermost first: NVLink/PCIe inside one host, the
#: NVLink/ICI island interconnect between hosts of one island, and the
#: multicast-capable switched fat-tree fabric between islands. Schedule ops
#: may pin themselves to a tier via their ``transport`` field (sched_ir);
#: multicast exists only on the switched tier — islands move bytes by
#: neighbor (ring) unicast, like the torus.
LINK_TIERS = ("intra_host", "island", "switched")


@dataclass
class LinkCounters:
    """Read-only per-link byte view keyed by (src, dst) node names. Built on
    demand from the Links' live bytes_served (Topology.counters) — mutate the
    Links (or use unicast/multicast), never this snapshot."""

    bytes_by_link: dict[tuple[str, str], float] = field(
        default_factory=lambda: defaultdict(float))

    def total(self) -> float:
        return sum(self.bytes_by_link.values())

    def max_link(self) -> float:
        return max(self.bytes_by_link.values(), default=0)

    def switch_port_total(self) -> float:
        """Sum over all switch ports (paper Fig. 12 counts switch port counters:
        every directed link endpoint at a switch counts its traffic)."""
        return self.total()


@runtime_checkable
class Topology(Protocol):
    """Route provider for the fluid engine. Implementations own one
    engine.Link per directed physical cable; route/multicast_tree return
    those same objects, so engine runs charge the topology's counters."""

    def links(self) -> dict[tuple[str, str], Link]: ...

    def route(self, src: int, dst: int) -> list[Link]: ...

    def multicast_tree(self, root: int, members: Sequence[int]) -> list[Link]: ...

    def aggregation_tree(self, root: int, members: Sequence[int]) -> list[Link]: ...

    def reset(self) -> None: ...


@dataclass(frozen=True)
class Cut:
    """One fabric cut: the hosts inside a region plus the aggregate capacity
    of the directed links crossing its boundary. The schedule searcher
    (core/sched_search.py) turns these into admissible lower bounds — any
    byte a schedule moves into (out of) the region crosses ``cap_in``
    (``cap_out``) at least once, and the fluid engine can never push a link
    set past its aggregate capacity — and into cut-derived chain-count
    candidates (how many full-rate streams the bottleneck tier carries)."""

    name: str
    hosts: frozenset[int]              # host ids inside the region
    cap_in: float                      # bytes/s entering the region
    cap_out: float                     # bytes/s leaving the region


class _LinkRegistry:
    """Shared plumbing: the directed-link table plus the validity assertion
    used by every route/tree builder (a hop not in the table is a physically
    nonexistent cable — the old ECMP bug class)."""

    def __init__(self):
        self._links: dict[tuple[str, str], Link] = {}
        self._tiers: dict[tuple[str, str], str] = {}

    def _add(self, a: str, b: str, capacity: float, *,
             tier: str = "switched") -> None:
        assert tier in LINK_TIERS, tier
        if (a, b) not in self._links:
            self._links[(a, b)] = Link(f"{a}->{b}", capacity, a, b)
            self._tiers[(a, b)] = tier

    def tier_of(self, a: str, b: str) -> str:
        """Fabric tier of the directed link a->b (see LINK_TIERS)."""
        self.link(a, b)                     # asserts the cable exists
        return self._tiers[(a, b)]

    def tier_split(self, link_bytes: dict[str, float]) -> dict[str, float]:
        """Split an engine ``link_bytes()`` dict (keyed by Link name
        ``"a->b"``) into per-tier byte totals — the fabric-byte view the
        hier_fabric benchmark gates (how much traffic each tier carried)."""
        out: dict[str, float] = {}
        for (a, b), link in self._links.items():
            v = link_bytes.get(link.name)
            if v:
                t = self._tiers[(a, b)]
                out[t] = out.get(t, 0.0) + v
        return out

    def link(self, a: str, b: str) -> Link:
        """The directed Link a->b; asserts the cable physically exists."""
        link = self._links.get((a, b))
        assert link is not None, f"nonexistent fabric link {a}->{b}"
        return link

    def _resolve(self, hops: Sequence[tuple[str, str]]) -> list[Link]:
        return [self.link(a, b) for a, b in hops]

    def links(self) -> dict[tuple[str, str], Link]:
        return self._links

    @property
    def counters(self) -> LinkCounters:
        """Live per-link bytes as a LinkCounters view (Fig. 12 switch-port
        counters). Derived from Link.bytes_served — there is no separate
        static counter store."""
        c = LinkCounters()
        for (a, b), link in self._links.items():
            if link.bytes_served:
                c.bytes_by_link[(a, b)] = link.bytes_served
        return c

    def reset(self) -> None:
        for link in self._links.values():
            link.bytes_served = 0.0
            link.active = []

    # --- cut introspection (schedule-search lower bounds) ------------------
    def cut_capacity(self, inside: set[str]) -> tuple[float, float]:
        """(cap_in, cap_out) of the cut around node-name set ``inside``:
        aggregate capacity of the directed links entering / leaving the
        region. Computed from the live link table, so degenerate fabrics
        (2-long rings, partially-populated pods) are counted exactly."""
        cap_in = cap_out = 0.0
        for (a, b), link in self._links.items():
            if a not in inside and b in inside:
                cap_in += link.capacity
            elif a in inside and b not in inside:
                cap_out += link.capacity
        return cap_in, cap_out

    def _make_cut(self, name: str, hosts, inside: set[str]) -> Cut:
        cap_in, cap_out = self.cut_capacity(inside)
        return Cut(name, frozenset(hosts), cap_in, cap_out)

    # --- static counting (analytic Fig. 2 path: traffic without timing) ----
    def unicast(self, src: int, dst: int, nbytes: float) -> None:
        for link in self.route(src, dst):
            link.bytes_served += nbytes

    def multicast(self, root: int, members: Sequence[int], nbytes: float) -> None:
        for link in self.multicast_tree(root, members):
            link.bytes_served += nbytes

    def aggregation_tree(self, root: int, members: Sequence[int]) -> list[Link]:
        """Reversed multicast tree: in-network reduction (RS_inc). Every
        member streams its contribution up the tree; switches reduce, so each
        reversed edge carries the payload exactly once and the root receives
        a single aggregate."""
        return [self.link(l.dst, l.src) for l in self.multicast_tree(root, members)]


class FatTree(_LinkRegistry):
    """Full 3-level fat-tree, radix ``k``: k pods, k/2 edge + k/2 agg switches
    per pod, (k/2)^2 cores, (k/2)^2 hosts per pod. Host ids are 0..n_hosts-1.

    Core c attaches to agg index c // (k/2) in every pod. Links exist for the
    pods that actually hold hosts. ``oversubscription`` divides the capacity
    of every switch-to-switch tier (edge-agg and agg-core), modeling the
    usual uplink thinning; host links stay at ``b_host``.
    """

    # hosts are dedicated leaf nodes (h{i}), so the packet lowering's
    # name-based tree-path resolution works on this fabric
    supports_packet = True
    # a flat fat-tree has a single switched tier; per-op transports only
    # mean something on tiered fabrics (IslandFatTree)
    supports_transport = False

    def __init__(self, k: int, n_hosts: int | None = None, *,
                 b_host: float = DEFAULT_LINK_BYTES,
                 oversubscription: float = 1.0):
        super().__init__()
        assert k % 2 == 0
        self.k = k
        h2 = k // 2
        self.max_hosts = k * h2 * h2
        self.n_hosts = n_hosts or self.max_hosts
        assert self.n_hosts <= self.max_hosts
        assert oversubscription >= 1.0
        self.b_host = float(b_host)
        self.oversubscription = float(oversubscription)
        b_up = self.b_host / self.oversubscription
        for h in range(self.n_hosts):
            self._add(self.host(h), self.edge_of(h), self.b_host)
            self._add(self.edge_of(h), self.host(h), self.b_host)
        n_pods = math.ceil(self.n_hosts / (h2 * h2))
        for pod in range(n_pods):
            for e in range(h2):
                for a in range(h2):
                    self._add(f"e{pod}.{e}", self.agg(pod, a), b_up)
                    self._add(self.agg(pod, a), f"e{pod}.{e}", b_up)
            for a in range(h2):
                for j in range(h2):
                    c = a * h2 + j          # core c // h2 == a by construction
                    self._add(self.agg(pod, a), self.core(c), b_up)
                    self._add(self.core(c), self.agg(pod, a), b_up)

    # --- naming -----------------------------------------------------------
    def host(self, h: int) -> str:
        return f"h{h}"

    def edge_of(self, h: int) -> str:
        pod, esw = self._loc(h)
        return f"e{pod}.{esw}"

    def _loc(self, h: int) -> tuple[int, int]:
        per_pod = (self.k // 2) ** 2
        pod = h // per_pod
        esw = (h % per_pod) // (self.k // 2)
        return pod, esw

    def agg(self, pod: int, a: int) -> str:
        return f"a{pod}.{a}"

    def core(self, c: int) -> str:
        return f"c{c}"

    def core_links(self) -> list[Link]:
        """Agg<->core links in both directions — the tier multiple jobs
        share (simulate_multi_job reports their contention)."""
        return [l for (a, b), l in self._links.items()
                if a.startswith("c") or b.startswith("c")]

    # --- search introspection ----------------------------------------------
    def signature(self) -> tuple:
        """Hashable identity of the fabric SHAPE (not its mutable counters):
        two topologies with equal signatures route identically, so schedule
        evaluations can be shared across instances (sched_search.EvalCache)."""
        return ("FatTree", self.k, self.n_hosts, self.b_host,
                self.oversubscription)

    def tier_capacities(self) -> dict[str, float]:
        """Per-link capacity of each fabric tier — the oversubscription view
        the searcher uses to derive chain-count candidates."""
        return {"host": self.b_host,
                "up": self.b_host / self.oversubscription}

    def bottleneck_cuts(self) -> list[Cut]:
        """The fat-tree's natural hierarchy cuts: one representative host,
        one edge-switch group (hosts + their edge switch behind the h2
        uplinks) and one pod (hosts + edge + agg switches behind the h2^2
        core downlinks). Capacities come from the live link table; cuts that
        contain every host (single-pod fabrics) are dropped — they bound
        nothing."""
        h2 = self.k // 2
        per_pod = h2 * h2
        cuts = [self._make_cut("host0", [0], {self.host(0)})]
        edge_hosts = [h for h in range(self.n_hosts)
                      if self.edge_of(h) == self.edge_of(0)]
        if len(edge_hosts) < self.n_hosts:
            cuts.append(self._make_cut(
                "edge0", edge_hosts,
                {self.host(h) for h in edge_hosts} | {self.edge_of(0)}))
        pod_hosts = [h for h in range(self.n_hosts) if h < per_pod]
        if len(pod_hosts) < self.n_hosts:
            inside = {self.host(h) for h in pod_hosts}
            inside |= {f"e0.{e}" for e in range(h2)}
            inside |= {self.agg(0, a) for a in range(h2)}
            cuts.append(self._make_cut("pod0", pod_hosts, inside))
        return cuts

    # --- deterministic ECMP up-down route ----------------------------------
    def route(self, src: int, dst: int) -> list[Link]:
        """Ordered Link path. ECMP choices are deterministic hashes of
        (src, dst); the inter-pod up aggregation switch is DERIVED from the
        chosen core (a = c // h2) so the agg->core hop is always a physical
        link — choosing them independently was the seed's route bug."""
        if src == dst:
            return []
        sp, se = self._loc(src)
        dp, de = self._loc(dst)
        h2 = self.k // 2
        hops = [(self.host(src), self.edge_of(src))]
        if (sp, se) == (dp, de):
            pass
        elif sp == dp:
            a = (src + dst) % h2
            hops += [(self.edge_of(src), self.agg(sp, a)),
                     (self.agg(sp, a), self.edge_of(dst))]
        else:
            c = (src * 31 + dst) % (h2 * h2)
            a = c // h2
            hops += [(self.edge_of(src), self.agg(sp, a)),
                     (self.agg(sp, a), self.core(c)),
                     (self.core(c), self.agg(dp, a)),
                     (self.agg(dp, a), self.edge_of(dst))]
        hops.append((self.edge_of(dst), self.host(dst)))
        return self._resolve(hops)

    # --- multicast spanning tree -------------------------------------------
    def multicast_tree(self, root: int, members: Sequence[int]) -> list[Link]:
        """Link edges of the multicast distribution tree: root -> its edge
        switch -> (agg -> core as needed) -> down to every member's edge
        switch -> hosts. Each fabric link appears once — this is the hardware
        multicast replication the switches perform. The up and down
        aggregation switches both derive from the root's hashed core
        (a = c // h2), so every edge is a physical link."""
        h2 = self.k // 2
        c = (root * 31) % (h2 * h2)
        a = c // h2
        rp, _ = self._loc(root)
        root_edge = self.edge_of(root)
        hops: dict[tuple[str, str], None] = {}   # ordered, deduplicated
        hops[(self.host(root), root_edge)] = None
        for m in members:
            if m == root:
                continue
            mp, me = self._loc(m)
            m_edge = self.edge_of(m)
            if m_edge != root_edge:
                hops[(root_edge, self.agg(rp, a))] = None
                if mp == rp:
                    hops[(self.agg(rp, a), m_edge)] = None
                else:
                    hops[(self.agg(rp, a), self.core(c))] = None
                    hops[(self.core(c), self.agg(mp, a))] = None
                    hops[(self.agg(mp, a), m_edge)] = None
            hops[(m_edge, self.host(m))] = None
        return self._resolve(list(hops))


class IslandFatTree(FatTree):
    """Tiered fabric: the FatTree's switched tier plus NVLink/ICI *islands* —
    consecutive host groups of ``island_size`` joined by a bidirectional
    neighbor ring of ``island`` -tier links at ``b_island`` per direction
    (the NVLink/ICI analogue; typically several times the NIC rate).

    Every host keeps its fat-tree NIC attach, so the two tiers coexist and a
    schedule chooses per op: ``transport="switched"`` forces the fat-tree
    (the only tier with hardware multicast), ``transport="island"`` forces
    the intra-island ring (asserts src/dst share an island), ``None`` routes
    island-local pairs over the island ring and everything else up the
    fat-tree. This is the FlexLink-style tiered fabric (arXiv:2510.15882)
    the hierarchical allgather builder and the searcher's transport-flip /
    island-grouping moves target.
    """

    supports_packet = True
    supports_transport = True

    def __init__(self, k: int, n_hosts: int | None = None, *,
                 island_size: int = 8, b_island: float | None = None,
                 b_host: float = DEFAULT_LINK_BYTES,
                 oversubscription: float = 1.0):
        super().__init__(k, n_hosts, b_host=b_host,
                         oversubscription=oversubscription)
        assert island_size >= 2, "an island needs at least two hosts"
        assert self.n_hosts % island_size == 0, \
            (self.n_hosts, island_size, "islands must tile the host range")
        self.island_size = island_size
        # NVLink-class default: 8x the NIC per direction
        self.b_island = float(b_island if b_island is not None
                              else 8 * self.b_host)
        g = island_size
        for i in range(self.n_islands):
            for j in range(g):
                a, b = i * g + j, i * g + (j + 1) % g
                if a != b:
                    self._add(self.host(a), self.host(b), self.b_island,
                              tier="island")
                    self._add(self.host(b), self.host(a), self.b_island,
                              tier="island")

    # --- island structure ---------------------------------------------------
    @property
    def n_islands(self) -> int:
        return self.n_hosts // self.island_size

    def island_of(self, h: int) -> int:
        return h // self.island_size

    def island_members(self, i: int) -> list[int]:
        g = self.island_size
        return list(range(i * g, (i + 1) * g))

    # --- search introspection ----------------------------------------------
    def signature(self) -> tuple:
        return ("IslandFatTree", self.k, self.n_hosts, self.b_host,
                self.oversubscription, self.island_size, self.b_island)

    def tier_capacities(self) -> dict[str, float]:
        return {"island": self.b_island, "host": self.b_host,
                "up": self.b_host / self.oversubscription}

    def bottleneck_cuts(self) -> list[Cut]:
        """FatTree's cuts plus the island-0 cut: everything a schedule moves
        into an island funnels through its members' g NIC attaches — the
        tiered bound that makes flat schedules look expensive here."""
        cuts = super().bottleneck_cuts()
        if self.island_size < self.n_hosts:
            members = self.island_members(0)
            cuts.append(self._make_cut(
                "island0", members, {self.host(h) for h in members}))
        return cuts

    # --- transport-aware routing -------------------------------------------
    def _island_hops(self, src: int, dst: int) -> list[tuple[str, str]]:
        """Shortest intra-island ring path (ties toward +1), Torus2D-style."""
        g = self.island_size
        base = self.island_of(src) * g
        s, d = src - base, dst - base
        step = Torus2D._dir(s, d, g)
        hops, x = [], s
        while x != d:
            nxt = (x + step) % g
            hops.append((self.host(base + x), self.host(base + nxt)))
            x = nxt
        return hops

    def route(self, src: int, dst: int,
              transport: str | None = None) -> list[Link]:
        if src == dst:
            return []
        local = self.island_of(src) == self.island_of(dst)
        if transport == "island" or (transport is None and local):
            assert local, (src, dst, "island transport across islands")
            return self._resolve(self._island_hops(src, dst))
        assert transport in (None, "switched"), transport
        return super().route(src, dst)

    def multicast_tree(self, root: int, members: Sequence[int],
                       transport: str | None = None) -> list[Link]:
        # hardware replication lives in the switches only — there is no
        # island-tier multicast (islands ring/unicast, sched_ir.validate)
        assert transport in (None, "switched"), \
            (transport, "multicast exists only on the switched tier")
        return super().multicast_tree(root, members)


class Torus2D(_LinkRegistry):
    """2-D torus with bidirectional neighbor links (TPU ICI analogue).
    Node ids are 0..nx*ny-1 with id = x * ny + y. Routes are dimension-ordered
    (x then y) shortest ring paths, ties broken toward +1; multicast trees are
    the confluent union of those routes (row trunk, column branches)."""

    # hosts ARE the torus nodes (t{x}.{y}); the packet lowering resolves
    # leaf paths through topology.host(), so receivers that are interior
    # tree nodes (every non-leaf torus member) work the same as fat-tree
    # h* leaves
    supports_packet = True

    def __init__(self, nx: int, ny: int, *, b_link: float = DEFAULT_LINK_BYTES):
        super().__init__()
        self.nx, self.ny = nx, ny
        self.b_link = float(b_link)
        for x in range(nx):
            for y in range(ny):
                for dx, dy in ((1, 0), (-1, 0), (0, 1), (0, -1)):
                    a, b = self.node(x, y), self.node(x + dx, y + dy)
                    if a != b:
                        # ICI neighbor links are island-tier cables: no
                        # switch multicast, neighbor unicast only
                        self._add(a, b, self.b_link, tier="island")

    def node(self, x: int, y: int) -> str:
        return f"t{x % self.nx}.{y % self.ny}"

    def coord(self, i: int) -> tuple[int, int]:
        return i // self.ny, i % self.ny

    def host(self, h: int) -> str:
        return self.node(*self.coord(h))

    # --- search introspection ----------------------------------------------
    def signature(self) -> tuple:
        return ("Torus2D", self.nx, self.ny, self.b_link)

    def tier_capacities(self) -> dict[str, float]:
        return {"link": self.b_link}

    def bottleneck_cuts(self) -> list[Cut]:
        """Torus cuts: one representative node (its incident links), the
        first column ring and the first row ring — the per-dimension
        bisection-style bottlenecks a schedule's streams must cross."""
        cuts = [self._make_cut("node0", [0], {self.node(0, 0)})]
        if self.nx > 1:
            col_hosts = [self.ny * 0 + y for y in range(self.ny)]
            cuts.append(self._make_cut(
                "col0", col_hosts, {self.node(0, y) for y in range(self.ny)}))
        if self.ny > 1:
            row_hosts = [x * self.ny for x in range(self.nx)]
            cuts.append(self._make_cut(
                "row0", row_hosts, {self.node(x, 0) for x in range(self.nx)}))
        return cuts

    @staticmethod
    def _dir(a: int, b: int, n: int) -> int:
        """Shortest ring direction a -> b on a ring of size n (ties -> +1)."""
        fwd = (b - a) % n
        return +1 if fwd <= n - fwd else -1

    def _hops(self, src: int, dst: int) -> list[tuple[str, str]]:
        sx, sy = self.coord(src)
        dx, dy = self.coord(dst)
        hops = []
        x, y = sx, sy
        step = self._dir(sx, dx, self.nx)
        while x != dx:
            nxt = (x + step) % self.nx
            hops.append((self.node(x, y), self.node(nxt, y)))
            x = nxt
        step = self._dir(sy, dy, self.ny)
        while y != dy:
            nxt = (y + step) % self.ny
            hops.append((self.node(x, y), self.node(x, nxt)))
            y = nxt
        return hops

    def route(self, src: int, dst: int) -> list[Link]:
        return self._resolve(self._hops(src, dst))

    def multicast_tree(self, root: int, members: Sequence[int]) -> list[Link]:
        """Union of the dimension-ordered routes root -> member. The routes
        are confluent (same row trunk per target column, disjoint shortest
        column arcs), so the union is a tree spanning root and members —
        the software stand-in for switch replication on a fabric that has
        none (chunks are forwarded along the tree edges)."""
        hops: dict[tuple[str, str], None] = {}
        for m in members:
            if m == root:
                continue
            for hop in self._hops(root, m):
                hops[hop] = None
        return self._resolve(list(hops))

    # --- ring counting helpers (torus analytic path) -----------------------
    def ring_x_link(self, x: int, y: int, direction: int = +1) -> tuple[str, str]:
        return (self.node(x, y), self.node(x + direction, y))

    def send_ring_x(self, x: int, y: int, nbytes: float, direction: int = +1) -> None:
        a, b = self.ring_x_link(x, y, direction)
        self.link(a, b).bytes_served += nbytes

    def ring_allgather_traffic(self, axis_len: int, shard_bytes: int, *, bidi: bool) -> None:
        """Count per-link bytes for a ring allgather over the x axis rings."""
        per_dir = shard_bytes // (2 if bidi else 1)
        for y in range(self.ny):
            for step in range(axis_len - 1 if not bidi else (axis_len - 1 + 1) // 2):
                for x in range(self.nx):
                    self.send_ring_x(x, y, per_dir, +1)
                    if bidi:
                        self.send_ring_x(x, y, per_dir, -1)
