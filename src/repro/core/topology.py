"""Network topologies with per-link byte counters.

Used to *measure* (by counting, the software analogue of the paper's switch
port counters, Fig. 12) the traffic of P2P vs multicast collective schedules:

  - FatTree: 3-level full fat-tree of radix-k switches (paper's testbed shape;
    Fig. 2 models 1024 nodes / radix 32). Unicast routes are deterministic
    up-down ECMP; multicast routes are spanning trees rooted at the core.
  - Torus2D: the TPU ICI analogue; ring/bidirectional neighbor links.

All counting is exact integer bytes; "bandwidth-optimal" on the fat-tree means
every byte of every send buffer crosses any link at most once (Insight 1).
"""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field


@dataclass
class LinkCounters:
    bytes_by_link: dict[tuple[str, str], int] = field(default_factory=lambda: defaultdict(int))

    def add(self, a: str, b: str, n: int) -> None:
        self.bytes_by_link[(a, b)] += n

    def total(self) -> int:
        return sum(self.bytes_by_link.values())

    def max_link(self) -> int:
        return max(self.bytes_by_link.values(), default=0)

    def switch_port_total(self) -> int:
        """Sum over all switch ports (paper Fig. 12 counts switch port counters:
        every directed link endpoint at a switch counts its traffic)."""
        return self.total()


class FatTree:
    """Full 3-level fat-tree, radix ``k``: k pods, k/2 edge + k/2 agg switches
    per pod, (k/2)^2 cores, (k/2)^2 hosts per pod. Host ids are 0..n_hosts-1.
    """

    def __init__(self, k: int, n_hosts: int | None = None):
        assert k % 2 == 0
        self.k = k
        self.max_hosts = k * (k // 2) ** 2
        self.n_hosts = n_hosts or self.max_hosts
        assert self.n_hosts <= self.max_hosts
        self.counters = LinkCounters()

    # --- naming -----------------------------------------------------------
    def host(self, h: int) -> str:
        return f"h{h}"

    def edge_of(self, h: int) -> str:
        pod, esw = self._loc(h)
        return f"e{pod}.{esw}"

    def _loc(self, h: int) -> tuple[int, int]:
        per_pod = (self.k // 2) ** 2
        pod = h // per_pod
        esw = (h % per_pod) // (self.k // 2)
        return pod, esw

    def agg(self, pod: int, a: int) -> str:
        return f"a{pod}.{a}"

    def core(self, c: int) -> str:
        return f"c{c}"

    # --- deterministic ECMP up-down route ----------------------------------
    def route(self, src: int, dst: int) -> list[tuple[str, str]]:
        if src == dst:
            return []
        sp, se = self._loc(src)
        dp, de = self._loc(dst)
        h2 = self.k // 2
        path = [(self.host(src), self.edge_of(src))]
        if sp == dp and se == de:
            path.append((self.edge_of(src), self.host(dst)))
            return path
        # hash-based ECMP choice, deterministic on (src, dst)
        a = (src + dst) % h2
        if sp == dp:
            path.append((self.edge_of(src), self.agg(sp, a)))
            path.append((self.agg(sp, a), f"e{dp}.{de}"))
        else:
            c = (src * 31 + dst) % (h2 * h2)
            path.append((self.edge_of(src), self.agg(sp, a)))
            path.append((self.agg(sp, a), self.core(c)))
            path.append((self.core(c), self.agg(dp, c // h2)))
            path.append((self.agg(dp, c // h2), f"e{dp}.{de}"))
        path.append((f"e{dp}.{de}", self.host(dst)))
        return path

    def unicast(self, src: int, dst: int, nbytes: int) -> None:
        for a, b in self.route(src, dst):
            self.counters.add(a, b, nbytes)

    # --- multicast spanning tree -------------------------------------------
    def multicast_tree(self, root: int, members: list[int]) -> set[tuple[str, str]]:
        """Edges of the multicast distribution tree: root -> its edge switch ->
        (agg -> core as needed) -> down to every member's edge switch -> hosts.
        Each fabric link appears once — this is the hardware multicast
        replication the switches perform."""
        edges: set[tuple[str, str]] = set()
        rp, _ = self._loc(root)
        h2 = self.k // 2
        up_agg = self.agg(rp, root % h2)
        core = self.core((root * 31) % (h2 * h2))
        pods = {self._loc(m)[0] for m in members if m != root}
        edges.add((self.host(root), self.edge_of(root)))
        cross_pod = any(p != rp for p in pods)
        same_pod_other_edge = any(
            self._loc(m)[0] == rp and self.edge_of(m) != self.edge_of(root)
            for m in members if m != root
        )
        if cross_pod or same_pod_other_edge:
            edges.add((self.edge_of(root), up_agg))
        if cross_pod:
            edges.add((up_agg, core))
        for m in members:
            if m == root:
                continue
            mp, me = self._loc(m)
            if mp == rp:
                if self.edge_of(m) != self.edge_of(root):
                    edges.add((up_agg, f"e{mp}.{me}"))
            else:
                down_agg = self.agg(mp, (root * 31) % (h2 * h2) // h2)
                edges.add((core, down_agg))
                edges.add((down_agg, f"e{mp}.{me}"))
            edges.add((f"e{mp}.{me}", self.host(m)))
        return edges

    def multicast(self, root: int, members: list[int], nbytes: int) -> None:
        for a, b in self.multicast_tree(root, members):
            self.counters.add(a, b, nbytes)

    def reset(self) -> None:
        self.counters = LinkCounters()


class Torus2D:
    """2-D torus with bidirectional neighbor links (TPU ICI analogue)."""

    def __init__(self, nx: int, ny: int):
        self.nx, self.ny = nx, ny
        self.counters = LinkCounters()

    def node(self, x: int, y: int) -> str:
        return f"t{x % self.nx}.{y % self.ny}"

    def ring_x_link(self, x: int, y: int, direction: int = +1) -> tuple[str, str]:
        return (self.node(x, y), self.node(x + direction, y))

    def send_ring_x(self, x: int, y: int, nbytes: int, direction: int = +1) -> None:
        a, b = self.ring_x_link(x, y, direction)
        self.counters.add(a, b, nbytes)

    def ring_allgather_traffic(self, axis_len: int, shard_bytes: int, *, bidi: bool) -> None:
        """Count per-link bytes for a ring allgather over the x axis rings."""
        per_dir = shard_bytes // (2 if bidi else 1)
        for y in range(self.ny):
            for step in range(axis_len - 1 if not bidi else (axis_len - 1 + 1) // 2):
                for x in range(self.nx):
                    self.send_ring_x(x, y, per_dir, +1)
                    if bidi:
                        self.send_ring_x(x, y, per_dir, -1)

    def reset(self) -> None:
        self.counters = LinkCounters()
