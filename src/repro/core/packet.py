"""Packet-level reliable-multicast protocol engine (paper §III, per-packet).

The fluid engine (core/engine.py) times *byte streams*; delivery is lossless
by construction. This module replays the same routed runs at MTU granularity
with loss injected on the engine ``Link``s, reproducing the part of the paper
that distinguishes it from prior multicast collectives: reliability at
~constant cost in node count.

Datapath per Broadcast (simulate_packet_broadcast):

  1. The root's stream is chunked into MTU packets; their injection times
     come from the SAME fluid tree flow the fluid model uses (the fabric
     contention model is shared, not duplicated).
  2. Every tree Link samples a per-packet drop mask from its LossModel —
     i.i.d. Bernoulli or bursty Gilbert–Elliott (per-link chain state
     persists across retransmission rounds, so bursts straddle rounds). A
     packet dropped on an upstream link is lost for every receiver below it:
     the multicast loss correlation falls out of the tree structure.
  3. Each receiver tracks arrival in a PACKED bitmap — the u32 word format of
     kernels/bitmap.py (bitmap_pack_np / bitmap_unpack_np are bit-identical
     numpy twins of the Pallas kernels); surviving packets run through the
     DPA worker pool (engine.worker_pool_completion), whose staging-ring RNR
     drops join the missing set.
  4. Recovery rounds: at the cutoff timer (protocol.cutoff_time) every
     incomplete receiver sends its missing-bitmap NACK up the reverse tree.
     Switches OR-aggregate hop by hop, so the root's DPA services ONE
     aggregated NACK per round (``aggregate_nacks=False`` disables this and
     the root pool serves one NACK per nacker — the ablation that shows why
     aggregation is what keeps recovery flat in P). The root then multicasts
     the UNION of missing chunks down the tree pruned to the NACKing leaves
     (a real engine tree flow: retransmissions contend on, and are counted
     by, the same fabric links). Repeat until every bitmap is complete.

simulate_packet_allgather is a facade over the Collective Schedule IR
(core/sched_ir.py): it builds the explicit Appendix-A schedule graph and
executes it at packet fidelity — R generations of concurrent packet
Broadcasts whose round/root structure comes from the schedule's Activation
edges, chains colliding on the fabric exactly as in the fluid model. The
round loop (and the per-chain runtime state that used to live here as an
ad-hoc chain-state class) lives in sched_ir._packet_allgather; this module
keeps the protocol machinery it lowers onto: loss models, tree paths,
bitmaps, NACK service, the worker pools. scripts/check.sh greps that chain
state never grows back here.

The DPA itself has two fidelities (``dpa_fidelity=``):

  "scalar"  (default) the progress engine is the T-server queue
            engine.worker_pool_completion at the WorkerParams aggregate rate
            (dpa.pool_tput via workers_from_dpa) — the DPA consumed as a
            scalar rate.
  "event"   core/dpa_engine.py: every packet arrival is a CQE event on a
            simulated N-core x M-context DPA (compute serialized on the
            core pipeline, stalls hidden by co-resident contexts, per-core
            NIC-interface caps, LLC-occupancy degradation) and the NACK /
            retransmit-post work items run on the SAME contexts — protocol
            work steals cycles from the receive datapath. ``dpa=`` supplies
            an EventDpaParams or dpa.DpaConfig (default: Table-I UD pool
            sized like the scalar worker pool). With zero per-CQE cost the
            event mode reproduces the scalar mode exactly (pinned).

Closed-form expectations for all of this live in core/protocol.py
(analytic_* functions) and are used by the tests as a cross-check oracle; at
loss rate 0 this engine reproduces the fluid model's times exactly.
"""
from __future__ import annotations

import os
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

from repro.core import protocol
from repro.core.dpa_engine import (
    DPA_FIDELITIES,
    DpaEventPool,
    resolve_event_params,
)
from repro.core import profiling
from repro.core.engine import (
    Engine,
    FabricParams,
    WorkerParams,
    staging_rnr_mask,
    worker_pool_completion,
    worker_pool_completion_rows,
)
from repro.core.sched_ir import PhaseBreakdown, _chunking, _rnr_barrier
from repro.kernels.bitmap_np import (  # jax-free: the packet wire format
    bitmap_pack_np,
    bitmap_pack_rows_np,
    bitmap_popcount_np,
    bitmap_unpack_np,
)

DEFAULT_MAX_ROUNDS = 64

# Packet-round executors: "vectorized" is the batch engine (default),
# "reference" the per-leaf loop it is pinned bit-exact against
# (tests/test_packet_vectorized.py). "auto" resolves to one of them per
# call via resolve_engine() — because the pair is bit-exact, the choice
# only moves wall-clock, never results.
ENGINES = ("vectorized", "reference")

def resolve_engine(engine: str, kind: str, p: int, row_bytes: int) -> str:
    """Map ``engine="auto"`` to a concrete packet executor; pass explicit
    choices through untouched (they stay bit-exact by construction).
    ``kind``/``p``/``row_bytes`` stay in the signature for call-site
    stability: the dense big-row allgather regime (DESIGN §9) used to route
    "auto" to "reference" here, but the residue-class-parallel pool scan
    (kernels/pool_np.py) closed it — vectorized now wins everywhere, so
    "auto" is always "vectorized" and the only remaining redirection is the
    REPRO_PACKET_ENGINE env escape hatch."""
    if engine != "auto":
        assert engine in ENGINES, engine
        return engine
    # CI matrix hook + escape hatch: REPRO_PACKET_ENGINE pins "auto" to one
    # executor so the per-leaf oracle leg stays exercised in CI. Explicit
    # engine= arguments are untouched — the bit-exact pin tests keep
    # comparing both engines.
    override = os.environ.get("REPRO_PACKET_ENGINE")
    if override:
        assert override in ENGINES, override
        return override
    return "vectorized"

# Batched pool passes process leaves in blocks of at most this many matrix
# elements (rows x padded row length) to bound peak memory.
_BLOCK_ELEMS = 1 << 24


# ------------------------------------------------------------------ loss models


class LossModel:
    """Per-link packet-loss process. A model given to a simulator is a
    *template*: ``fork(rng)`` derives an independently-seeded per-link
    instance (loss processes on different cables are independent);
    ``sample(n)`` draws the drop mask for the next n packets crossing the
    link, advancing any internal channel state."""

    def fork(self, rng: np.random.Generator) -> "LossModel":
        raise NotImplementedError

    def sample(self, n: int) -> np.ndarray:
        raise NotImplementedError

    @property
    def mean_rate(self) -> float:
        raise NotImplementedError


class BernoulliLoss(LossModel):
    """i.i.d. per-packet drops at a fixed rate."""

    def __init__(self, rate: float, rng: np.random.Generator | None = None):
        assert 0.0 <= rate < 1.0, rate
        self.rate = float(rate)
        self._rng = rng

    def fork(self, rng: np.random.Generator) -> "BernoulliLoss":
        return BernoulliLoss(
            self.rate, np.random.default_rng(int(rng.integers(1 << 62))))

    def sample(self, n: int) -> np.ndarray:
        if self.rate == 0.0:
            return np.zeros(n, dtype=bool)
        assert self._rng is not None, "sample() on an unforked template"
        return self._rng.random(n) < self.rate

    @property
    def mean_rate(self) -> float:
        return self.rate


class GilbertElliottLoss(LossModel):
    """Two-state bursty channel: GOOD drops with prob e_good, BAD with
    e_bad; per-packet transition probs p_gb (good->bad) and p_bg (bad->good).
    Sojourn times are geometric, so the chain is sampled run-length-wise;
    state persists across sample() calls (bursts straddle recovery rounds)."""

    def __init__(self, p_gb: float, p_bg: float, *, e_good: float = 0.0,
                 e_bad: float = 1.0, rng: np.random.Generator | None = None):
        assert 0.0 < p_gb <= 1.0 and 0.0 < p_bg <= 1.0, (p_gb, p_bg)
        assert 0.0 <= e_good <= 1.0 and 0.0 <= e_bad <= 1.0
        self.p_gb, self.p_bg = float(p_gb), float(p_bg)
        self.e_good, self.e_bad = float(e_good), float(e_bad)
        self._rng = rng
        self._bad = False
        if rng is not None:  # start at the stationary distribution
            pi_bad = self.p_gb / (self.p_gb + self.p_bg)
            self._bad = bool(rng.random() < pi_bad)

    @classmethod
    def from_rate(cls, rate: float, mean_burst: float = 8.0,
                  e_good: float = 0.0) -> "GilbertElliottLoss":
        """Burst model with a target mean loss rate: BAD drops everything,
        sojourns in BAD average ``mean_burst`` packets."""
        assert 0.0 < rate < 1.0 and mean_burst >= 1.0
        p_bg = 1.0 / mean_burst
        # stationary P(bad) must equal the target rate (e_bad=1, e_good~0)
        p_gb = min(p_bg * rate / (1.0 - rate), 1.0)
        return cls(p_gb, p_bg, e_good=e_good, e_bad=1.0)

    def fork(self, rng: np.random.Generator) -> "GilbertElliottLoss":
        return GilbertElliottLoss(
            self.p_gb, self.p_bg, e_good=self.e_good, e_bad=self.e_bad,
            rng=np.random.default_rng(int(rng.integers(1 << 62))))

    def sample(self, n: int) -> np.ndarray:
        assert self._rng is not None, "sample() on an unforked template"
        drops = np.empty(n, dtype=bool)
        i = 0
        while i < n:
            leave = self.p_bg if self._bad else self.p_gb
            run = int(self._rng.geometric(leave))
            take = min(run, n - i)
            e = self.e_bad if self._bad else self.e_good
            if e <= 0.0:
                drops[i:i + take] = False
            elif e >= 1.0:
                drops[i:i + take] = True
            else:
                drops[i:i + take] = self._rng.random(take) < e
            i += take
            if take == run:          # sojourn completed inside this block
                self._bad = not self._bad
        return drops

    @property
    def mean_rate(self) -> float:
        pi_bad = self.p_gb / (self.p_gb + self.p_bg)
        return (1.0 - pi_bad) * self.e_good + pi_bad * self.e_bad


def resolve_loss(loss, fabric: FabricParams) -> LossModel | None:
    """``loss=`` argument -> template: a LossModel passes through, a float is
    a Bernoulli rate, None falls back to fabric.p_drop (0 -> lossless)."""
    if loss is None:
        return BernoulliLoss(fabric.p_drop) if fabric.p_drop > 0 else None
    if isinstance(loss, LossModel):
        return loss
    rate = float(loss)
    return BernoulliLoss(rate) if rate > 0 else None


def rc_goodput_inflation(mean_rate: float, path_len: float) -> float:
    """Reliable-unicast (RC) transport retransmits in hardware (go-back-N),
    so loss appears as a deterministic goodput inflation: the extra
    wire-time fraction 1/(1-q_path) - 1 for a path crossing ``path_len``
    lossy links of mean per-link rate ``mean_rate`` (mean-field treatment;
    DESIGN.md §3.1). Shared by the FSDP "naive" overlay and the
    ring-schedule packet lowering — they must never diverge on it."""
    q_path = 1.0 - (1.0 - mean_rate) ** path_len
    return 1.0 / (1.0 - q_path) - 1.0


def attach_loss(topology, template: LossModel, rng: np.random.Generator,
                predicate=None) -> int:
    """Fork ``template`` onto every fabric Link (optionally only those whose
    name satisfies ``predicate``); returns the number of links armed. Armed
    links keep their model across simulator calls — GE burst state then
    persists across collectives on the same fabric."""
    n = 0
    for link in topology.links().values():
        if predicate is None or predicate(link.name):
            link.loss = template.fork(rng)
            n += 1
    return n


# ------------------------------------------------------------- tree plumbing


def tree_paths(tree_links: Sequence, root_name: str,
               leaf_names: Sequence[str]) -> dict[str, list]:
    """Per-leaf ordered root->leaf Link path inside a multicast tree edge
    set (the tree is a directed arborescence, so the path is unique)."""
    children = defaultdict(list)
    for link in tree_links:
        assert link.src is not None and link.dst is not None, link
        children[link.src].append(link)
    want = set(leaf_names)
    paths: dict[str, list] = {}
    stack = [(root_name, [])]
    while stack:
        node, acc = stack.pop()
        if node in want:
            paths[node] = acc
        for link in children[node]:
            stack.append((link.dst, acc + [link]))
    missing = want - set(paths)
    assert not missing, f"leaves unreachable in tree: {sorted(missing)}"
    return paths


class _LeafState:
    """Per-receiver protocol state: the packed arrival bitmap (the exact u32
    word format of kernels/bitmap.py) plus hop latency and pool progress."""

    __slots__ = ("flags", "hop_lat", "t_done", "rnr")

    def __init__(self, n_chunks: int, hop_lat: float):
        self.flags = np.zeros(n_chunks, dtype=bool)
        self.hop_lat = hop_lat
        self.t_done = 0.0
        self.rnr = 0

    def packed(self) -> np.ndarray:
        """Arrival bitmap in the kernels/bitmap.py packed-u32 wire format
        (this is the NACK payload: receivers send ~packed())."""
        n = self.flags.shape[0]
        pad = (-n) % 32
        return bitmap_pack_np(np.pad(self.flags, (0, pad)))

    def n_received(self) -> int:
        return bitmap_popcount_np(self.packed())

    def missing_idx(self) -> np.ndarray:
        return np.nonzero(~self.flags)[0]

    def complete(self) -> bool:
        return bool(self.flags.all())


def _pool_with_rnr_psns(arrivals: np.ndarray, psns: np.ndarray,
                        workers: WorkerParams, service: float):
    """Worker-pool pass that also identifies WHICH packets the staging ring
    dropped (the vectorized engine pool only counts them). arrivals must be
    sorted; psns aligned with arrivals. Returns (t_last_done, rnr_psns)."""
    done, _ = worker_pool_completion(
        arrivals, workers.n_recv_workers, service, workers.staging_chunks)
    if arrivals.shape[0] == 0:
        return None, psns[:0]
    rnr_psns = psns[staging_rnr_mask(done, arrivals, workers.staging_chunks)]
    return float(done[-1]), rnr_psns


def _or_masks(models: list[LossModel], n: int) -> np.ndarray:
    """Drop mask for a packet crossing every model's link in sequence."""
    lost = np.zeros(n, dtype=bool)
    for m in models:
        if m is not None:
            lost |= m.sample(n)
    return lost


def _sample_link_round(link_models: dict[int, LossModel | None],
                       n: int) -> dict[int, np.ndarray]:
    """One drop mask per distinct link for the round's n packets — sampled
    once per LINK (not per receiver), so an upstream drop is shared by every
    receiver below it."""
    if profiling.ENABLED:
        with profiling.phase("rng"):
            zeros = np.zeros(n, dtype=bool)
            return {lid: (m.sample(n) if m is not None else zeros)
                    for lid, m in link_models.items()}
    zeros = np.zeros(n, dtype=bool)
    return {lid: (m.sample(n) if m is not None else zeros)
            for lid, m in link_models.items()}


def _leaf_lost(path: list, masks: dict[int, np.ndarray], n: int) -> np.ndarray:
    lost = np.zeros(n, dtype=bool)
    for link in path:
        lost |= masks[id(link)]
    return lost


def _stacked_lost(paths: dict, masks: dict[int, np.ndarray], leaves,
                  n: int) -> np.ndarray:
    """Batch twin of per-leaf ``_leaf_lost``: stack the per-link masks into a
    (links x chunks) matrix and OR along every leaf's path one tree LEVEL at
    a time (one fancy-indexed gather per depth instead of p python loops).
    Returns (len(leaves), n) bool rows, row k == _leaf_lost(paths[leaves[k]]).
    """
    row_of: dict[int, int] = {}
    rows = []
    for lid, m in masks.items():
        row_of[lid] = len(rows)
        rows.append(m)
    mat = (np.stack(rows) if rows
           else np.zeros((0, n), dtype=bool))
    lost = np.zeros((len(leaves), n), dtype=bool)
    depth = max((len(paths[lf]) for lf in leaves), default=0)
    for d in range(depth):
        sel = np.array([k for k, lf in enumerate(leaves)
                        if len(paths[lf]) > d], dtype=np.intp)
        idx = np.array([row_of[id(paths[lf][d])] for lf in leaves
                        if len(paths[lf]) > d], dtype=np.intp)
        lost[sel] |= mat[idx]
    return lost


def _models_on_paths(paths: dict, models: dict[int, LossModel | None],
                     leaves) -> dict[int, LossModel | None]:
    """Subset of ``models`` on the given leaves' paths — the links a pruned
    retransmit tree actually traverses."""
    return {id(link): models[id(link)]
            for leaf in leaves for link in paths[leaf]}


def _link_models(paths: dict[str, list], template: LossModel | None,
                 rng: np.random.Generator,
                 cache: dict[int, LossModel | None] | None = None,
                 ) -> dict[int, LossModel | None]:
    """Resolve the per-link model: a Link armed via attach_loss keeps its
    own instance; unarmed links fork the template once (deterministic
    order). ``cache`` shares the forks across callers — the M chains of an
    Allgather crossing the same physical Link must see ONE loss process, not
    M independent ones, and its state must persist across rounds."""
    out: dict[int, LossModel | None] = {}
    for leaf in sorted(paths):
        for link in paths[leaf]:
            lid = id(link)
            if lid in out:
                continue
            if cache is not None and lid in cache:
                out[lid] = cache[lid]
                continue
            model = getattr(link, "loss", None)
            if model is None and template is not None:
                model = template.fork(rng)
            out[lid] = model
            if cache is not None:
                cache[lid] = model
    return out


# --------------------------------------------------------------- NACK + DPA


def _nack_wire_bytes(n_chunks: int, mtu: int) -> int:
    """One (aggregated) NACK message on the wire: an MTU header datagram
    plus the packed missing-bitmap payload (1 bit per tracked chunk)."""
    return mtu + protocol.bitmap_bytes(n_chunks * mtu, mtu)


def _nack_service(n_chunks: int, workers: WorkerParams, mtu: int) -> float:
    """Scalar-DPA service time for one NACK message: CQE-bound like a data
    chunk, plus streaming the packed bitmap payload through the worker (the
    event-DPA twin scales its Table-I cycles by the same wire bytes)."""
    return _nack_wire_bytes(n_chunks, mtu) / workers.thread_tput


@dataclass
class RoundTrace:
    """One NACK/retransmission round of one Broadcast."""
    nack_leaves: int                  # receivers still incomplete
    root_nack_msgs: int               # NACKs the root DPA actually served
    union_chunks: int                 # |union of missing| = retransmit size
    t_nack_root: float                # aggregated NACK arrival at the root
    t_retx_start: float               # retransmit flow injection start
    t_end: float                      # last delivery of the round
    recovered: int                    # chunks recovered this round


# ------------------------------------------------------------ broadcast core


@dataclass
class PacketBcastResult:
    """Field-compatible with simulator.BcastResult (same invariants:
    bytes_fast + bytes_recovery == bytes_total on completion), plus the
    per-round recovery trace of the packet protocol."""
    completion: np.ndarray
    phases: PhaseBreakdown
    delivered_fast: int
    recovered: int
    rnr_drops: int
    bytes_fast: int
    bytes_recovery: int
    bytes_total: int
    link_bytes: dict[str, float] = field(default_factory=dict)
    rounds: list[RoundTrace] = field(default_factory=list)
    retransmit_wire_bytes: int = 0    # root-injected recovery traffic
    duplicates: int = 0               # retransmitted chunks a leaf already had
    completed: bool = True
    delivery_order: dict[int, np.ndarray] = field(default_factory=dict)
    # ^ collect_delivery=True only: per-leaf PSNs in staging-ring arrival
    #   order (fast path then recovery rounds) — the scatter order the
    #   kernels/chunk_reassembly.py datapath replays

    @property
    def time(self) -> float:
        return float(self.completion.max(initial=0.0))

    @property
    def recovery_time(self) -> float:
        """Wall time spent in NACK/retransmission rounds (the Fig. 10
        reliability phase — the quantity the constant-time claim bounds)."""
        return self.phases.reliability


class _BroadcastRun:
    """One packet-level Broadcast: fast-path delivery plus NACK-aggregation
    / retransmission rounds on an Engine. Drives simulate_packet_broadcast.
    NOTE: the allgather executor (sched_ir._packet_allgather) implements its
    round loop separately — its M concurrent chains share every leaf's
    worker pool, so delivery must merge arrivals ACROSS chains before the
    pool pass, which this self-contained per-broadcast datapath cannot
    express. Protocol changes (cutoff rule, NACK service, retransmit
    pruning) must be mirrored there."""

    def __init__(self, p: int, n_bytes: int, fabric: FabricParams,
                 workers: WorkerParams, rng: np.random.Generator,
                 root: int, eng: Engine, *, topology=None, hosts=None,
                 loss=None, aggregate_nacks: bool = True, tag: str = "mcast",
                 collect_delivery: bool = False, dpa_fidelity: str = "scalar",
                 dpa=None):
        self.p, self.fabric, self.workers, self.rng = p, fabric, workers, rng
        self.root, self.eng = root, eng
        self.topology, self.aggregate = topology, aggregate_nacks
        self.n_chunks, self.chunk = _chunking(n_bytes, fabric.mtu)
        self.service = self.chunk / workers.thread_tput
        self.tag = tag
        assert dpa_fidelity in DPA_FIDELITIES, dpa_fidelity
        assert dpa is None or dpa_fidelity == "event", \
            "dpa= requires dpa_fidelity='event'"
        template = resolve_loss(loss, fabric)
        if topology is not None:
            self.hosts = list(hosts) if hosts is not None else list(range(p))
            assert len(self.hosts) == p, (len(self.hosts), p)
            self.tree = topology.multicast_tree(self.hosts[root], self.hosts)
            names = {leaf: topology.host(self.hosts[leaf]) for leaf in range(p)
                     if leaf != root}
            paths = tree_paths(self.tree, topology.host(self.hosts[root]),
                               list(names.values()))
            self.paths = {leaf: paths[n] for leaf, n in names.items()}
            self.models = _link_models(
                {names[leaf]: self.paths[leaf] for leaf in names}, template,
                rng)
        else:
            self.hosts = list(range(p))
            self.tree = None
            # abstract mode: each leaf behind one pseudo-link of independent
            # loss (the leaf's ejection path); timing shares the root link
            self.paths = {leaf: [_AbstractCarrier()] for leaf in range(p)
                          if leaf != root}
            self.models = {
                id(c): (template.fork(rng) if template is not None else None)
                for path in (self.paths[leaf] for leaf in sorted(self.paths))
                for c in path
            }
        self.leaf_ids = sorted(self.paths)
        self._init_leaf_states()
        if dpa_fidelity == "event":
            # one DPA progress engine per NIC, persistent across rounds:
            # NACK service and retransmit posting run on the root's contexts
            # (cycle theft from its receive datapath — visible in the
            # Allgather, where every root also receives)
            params = resolve_event_params(dpa, workers.n_recv_workers)
            self.pools = {leaf: DpaEventPool(params) for leaf in self.leaf_ids}
            self.root_pool = DpaEventPool(params)
        else:
            self.pools = None
            self.root_pool = None
        self.completion = np.zeros(p)
        self.rounds: list[RoundTrace] = []
        self.rnr_total = 0
        self.duplicates = 0
        self.retransmit_wire = 0
        self.t_fast_end = 0.0
        self.t_rel_end = 0.0
        self._cutoff = 0.0
        # arrival-ordered delivered PSNs per leaf (kernels/chunk_reassembly
        # replay: the staging-ring scatter order), kept only on request
        self.delivery = ({leaf: [] for leaf in self.leaf_ids}
                         if collect_delivery else None)

    def _hop_of(self, leaf: int) -> float:
        return (len(self.paths[leaf]) if self.topology is not None else 1) \
            * self.fabric.latency

    def _init_leaf_states(self) -> None:
        """Per-receiver protocol state. The vectorized engine overrides this
        with an array-of-leaves layout (no per-leaf bool bitmaps)."""
        self.leaves = {leaf: _LeafState(self.n_chunks, self._hop_of(leaf))
                       for leaf in self.leaf_ids}

    def _leaf_pool_pass(self, leaf: int, arrivals: np.ndarray,
                        psns: np.ndarray):
        """One receive-datapath pass at ``leaf``: the scalar T-server queue,
        or the leaf's persistent event-level DPA (dpa_fidelity="event")."""
        if self.pools is None:
            return _pool_with_rnr_psns(arrivals, psns, self.workers,
                                       self.service)
        return self.pools[leaf].service_with_rnr(
            arrivals, psns, self.chunk, self.workers.staging_chunks)

    def _record_delivery(self, leaf: int, psns_in_arrival_order: np.ndarray,
                         rnr_psns: np.ndarray) -> None:
        if self.delivery is None:
            return
        got = psns_in_arrival_order
        if rnr_psns.size:
            got = got[~np.isin(got, rnr_psns)]
        self.delivery[leaf].append(got)

    # -- round 0: the multicast fast path
    def submit_fast(self, t_start: float):
        nbytes = self.n_chunks * self.chunk
        if self.tree is not None:
            self.flow = self.eng.submit_tree(self.tree, nbytes,
                                             t_start=t_start, tag=self.tag)
        else:
            link = self.eng.add_link(f"{self.tag}.root{self.root}.send",
                                     self.fabric.b_link)
            self.flow = self.eng.submit(link, nbytes, t_start=t_start,
                                        tag=self.tag)
        self.t_start = t_start
        return self.flow

    def deliver_fast(self) -> None:
        """Engine has run: sample per-link drops, push survivors through
        every leaf's worker pool, record bitmaps (call once)."""
        inject = self.flow.chunk_times(self.n_chunks, self.chunk)
        self._cutoff = self.flow.t_end + self.fabric.alpha
        masks = _sample_link_round(self.models, self.n_chunks)
        fab = self.fabric
        for leaf, st in self.leaves.items():
            lost = _leaf_lost(self.paths[leaf], masks, self.n_chunks)
            psns = np.nonzero(~lost)[0]
            arr = (inject[psns] + st.hop_lat
                   + self.rng.uniform(0.0, fab.jitter, size=psns.shape[0]))
            order = np.argsort(arr, kind="stable")
            t_last, rnr_psns = self._leaf_pool_pass(
                leaf, arr[order], psns[order])
            st.rnr = rnr_psns.shape[0]
            self.rnr_total += st.rnr
            st.flags[psns] = True
            st.flags[rnr_psns] = False      # staging overflow: treat as lost
            self._record_delivery(leaf, psns[order], rnr_psns)
            st.t_done = t_last if t_last is not None else self.t_start
            self.completion[leaf] = st.t_done
            self.t_fast_end = max(self.t_fast_end, st.t_done)
        self.completion[self.root] = self.flow.t_end
        self.t_fast_end = max(self.t_fast_end, self.flow.t_end)

    # -- recovery rounds
    def incomplete(self) -> list[int]:
        return [leaf for leaf, st in self.leaves.items() if not st.complete()]

    def plan_retransmit(self):
        """Build this round's NACK aggregation + retransmit flow. Returns
        None when every leaf is complete, else an opaque meta tuple (flow
        first) to pass to deliver_retransmit() after the engine ran it."""
        nackers = self.incomplete()
        if not nackers:
            return None
        # union of missing = OR of the packed NACK bitmaps (wire format)
        agg_words = np.zeros_like(self.leaves[nackers[0]].packed())
        for leaf in nackers:
            agg_words |= ~self.leaves[leaf].packed()
        union = np.nonzero(bitmap_unpack_np(agg_words, self.n_chunks))[0]
        # NACK ascent: a leaf declares loss at the cutoff timer (or when its
        # pool drained, whichever is later) and sends its bitmap up the tree
        t_send = {leaf: max(self.leaves[leaf].t_done, self._cutoff)
                  + self.leaves[leaf].hop_lat for leaf in nackers}
        if self.aggregate:
            # switches OR hop-by-hop: the root serves ONE aggregated NACK
            arrivals = np.array([max(t_send.values())])
        else:
            arrivals = np.sort(np.array([t_send[leaf] for leaf in nackers]))
        return self._submit_retransmit(union, nackers, arrivals)

    def _submit_retransmit(self, union: np.ndarray, nackers: list[int],
                           arrivals: np.ndarray):
        """Root side of one recovery round (engine-independent): serve the
        NACK arrivals on the root DPA, then inject the pruned retransmit
        flow. Returns the meta tuple for deliver_retransmit()."""
        assert union.size > 0
        fab, wk = self.fabric, self.workers
        if self.root_pool is None:
            t_root_done, _ = _pool_with_rnr_psns(
                arrivals, np.arange(arrivals.shape[0]), wk,
                _nack_service(self.n_chunks, wk, fab.mtu))
        else:
            wire = _nack_wire_bytes(self.n_chunks, fab.mtu)
            t_root_done, _ = self.root_pool.service_with_rnr(
                arrivals, np.arange(arrivals.shape[0]), wire,
                wk.staging_chunks, kind="nack", wire_bytes=wire)
        t_retx = max(t_root_done, self.eng.now)
        if self.root_pool is not None:
            # retransmit WQE posting runs on the same contexts (stealing
            # cycles from whatever else they serve); the wire injection
            # overlaps posting and starts at t_retx
            self.root_pool.service_batch(
                np.full(union.size, t_retx), self.chunk, kind="retx")
        if self.tree is not None:
            members = [self.hosts[self.root]] + [self.hosts[x]
                                                 for x in nackers]
            rtree = self.topology.multicast_tree(self.hosts[self.root],
                                                 members)
            flow = self.eng.submit_tree(rtree, union.size * self.chunk,
                                        t_start=t_retx, tag=f"{self.tag}.retx")
        else:
            flow = self.eng.submit(f"{self.tag}.root{self.root}.send",
                                   union.size * self.chunk, t_start=t_retx,
                                   tag=f"{self.tag}.retx")
        meta = (flow, union, nackers, arrivals, float(t_root_done))
        return meta

    def deliver_retransmit(self, meta) -> None:
        flow, union, nackers, arrivals, t_root_done = meta
        inject = flow.chunk_times(union.size, self.chunk)
        # sample ONLY the links the pruned retransmit tree traverses — the
        # nackers' paths; advancing loss-process state (GE chains) on links
        # that carry no retransmit packets would time-shift their bursts
        masks = _sample_link_round(
            _models_on_paths(self.paths, self.models, nackers), union.size)
        recovered_round = 0
        t_round_end = t_root_done
        for leaf in nackers:
            st = self.leaves[leaf]
            miss = st.missing_idx()
            pos = np.searchsorted(union, miss)      # union ⊇ miss
            self.duplicates += int(union.size - miss.size)
            lost = _leaf_lost(self.paths[leaf], masks, union.size)[pos]
            got_pos, got_psn = pos[~lost], miss[~lost]
            arr = (inject[got_pos] + st.hop_lat
                   + self.rng.uniform(0.0, self.fabric.jitter,
                                      size=got_psn.shape[0]))
            order = np.argsort(arr, kind="stable")
            t_last, rnr_psns = self._leaf_pool_pass(
                leaf, arr[order], got_psn[order])
            self.rnr_total += rnr_psns.shape[0]
            st.flags[got_psn] = True
            st.flags[rnr_psns] = False
            self._record_delivery(leaf, got_psn[order], rnr_psns)
            recovered_round += got_psn.shape[0] - rnr_psns.shape[0]
            if t_last is not None:
                st.t_done = t_last
                self.completion[leaf] = t_last
                t_round_end = max(t_round_end, t_last)
        self._cutoff = flow.t_end + self.fabric.alpha
        self.t_rel_end = max(self.t_rel_end, t_round_end)
        self.rounds.append(RoundTrace(
            nack_leaves=len(nackers),
            root_nack_msgs=int(arrivals.shape[0]),
            union_chunks=int(union.size),
            t_nack_root=float(arrivals.max()),
            t_retx_start=float(flow.t_start),
            t_end=t_round_end,
            recovered=recovered_round,
        ))
        self.retransmit_wire += int(union.size) * self.chunk

    def stats(self) -> dict:
        n_total = (self.p - 1) * self.n_chunks
        recovered = sum(tr.recovered for tr in self.rounds)
        return {
            "delivered_fast": n_total - recovered
            - sum(st.missing_idx().size for st in self.leaves.values()),
            "recovered": recovered,
        }


class _VecBroadcastRun(_BroadcastRun):
    """Batch twin of _BroadcastRun (``engine="vectorized"``, the default):
    the same protocol, state machine and RNG stream, executed with array
    batches instead of per-leaf python loops. Pinned BIT-exact against the
    reference by tests/test_packet_vectorized.py. The layout (DESIGN.md §9):

      - loss: one (links x chunks) mask matrix per round, OR-ed along paths
        one tree level at a time (_stacked_lost) — the per-LINK sample order
        is unchanged, so Gilbert–Elliott chain state advances identically.
      - pool: leaves are padded to a (block x max_row) arrival matrix and
        served by ONE worker_pool_completion_rows call (+inf END padding is
        invisible to the residue-class recurrence and the RNR rule).
      - jitter: per-leaf ``rng.uniform`` calls become one sized draw per
        block; numpy's uniform fills are stream-splittable, so the draws
        are bitwise those of the per-leaf loop. At jitter == 0.0 every draw
        returns exactly 0.0, so the vectorized engine ELIDES them: outputs
        are unchanged, only the caller-visible final rng state differs from
        the reference (the documented RNG-order contract).
      - NACK union: per-leaf missing sets scatter into a bool matrix, pack
        via bitmap_pack_rows_np, and OR-reduce across rows — the same u32
        wire words the reference builds leaf by leaf.
      - bookkeeping: no per-leaf bool bitmaps (O(p·chunks) memory); missing
        PSNs live in a dict of sorted index arrays, absent means complete.
    """

    def _init_leaf_states(self) -> None:
        self.leaves = None                  # array-of-leaves layout instead
        ids = self.leaf_ids
        self._ids = np.array(ids, dtype=np.intp)
        self._pos = {leaf: k for k, leaf in enumerate(ids)}
        self.hop = np.array([self._hop_of(leaf) for leaf in ids])
        self._tdone = np.zeros(len(ids))
        self.missing: dict[int, np.ndarray] = {}   # leaf -> sorted PSNs
        self._lossless = all(m is None for m in self.models.values())
        # All template forks happen in __init__, so after construction the
        # shared rng feeds ONLY jitter draws; at jitter==0 each returns
        # exactly 0.0 and x + 0.0 == x bitwise for the (positive) times —
        # eliding them cannot change any output.
        self._skip_jitter = self.fabric.jitter == 0.0

    def _draw_jitter(self, total: int) -> np.ndarray | None:
        if self._skip_jitter:
            return None
        if profiling.ENABLED:
            with profiling.phase("rng"):
                return self.rng.uniform(0.0, self.fabric.jitter, size=total)
        return self.rng.uniform(0.0, self.fabric.jitter, size=total)

    def _pool_rows(self, leaves, counts, psn_flat, arr_flat):
        """Coalesced pool pass for a block of leaves: pad the ragged
        (arrival, psn) runs to a matrix, sort rows by arrival (the
        reference's per-leaf stable argsort), and run ONE
        worker_pool_completion_rows call — or, at dpa_fidelity="event", the
        per-leaf stateful pools in reference order. Returns (t_last (B,)
        with NaN for empty rows, per-row rnr PSN list, psn matrix in
        arrival order)."""
        B = len(leaves)
        counts = np.asarray(counts, dtype=np.intp)
        total = int(counts.sum())
        maxc = int(counts.max()) if B else 0
        if B and total == B * maxc:
            # dense block (lossless rounds): every row is full, so the
            # row-major flats ARE the matrix -- skip the scatter-pad
            arr_pad = arr_flat.reshape(B, maxc)
            psn_pad = psn_flat.reshape(B, maxc)
        else:
            starts = np.cumsum(counts) - counts
            rows = np.repeat(np.arange(B, dtype=np.intp), counts)
            within = (np.arange(total, dtype=np.intp)
                      - np.repeat(starts, counts))
            arr_pad = np.full((B, maxc), np.inf)
            psn_pad = np.full((B, maxc), -1, dtype=np.intp)
            arr_pad[rows, within] = arr_flat
            psn_pad[rows, within] = psn_flat
        if not self._skip_jitter:
            order = np.argsort(arr_pad, axis=1, kind="stable")
            arr_pad = np.take_along_axis(arr_pad, order, axis=1)
            psn_pad = np.take_along_axis(psn_pad, order, axis=1)
        # else: rows are already arrival-sorted (injection times are
        # nondecreasing in PSN and each row's PSNs ascend), and the
        # reference's stable argsort of a sorted row is the identity
        t_last = np.full(B, np.nan)
        if self.pools is None:
            done, rnr_mask = worker_pool_completion_rows(
                arr_pad, self.workers.n_recv_workers, self.service,
                self.workers.staging_chunks)
            nz = counts > 0
            t_last[nz] = done[np.nonzero(nz)[0], counts[nz] - 1]
            if rnr_mask.any():
                rnr_list = [psn_pad[k, rnr_mask[k]] for k in range(B)]
            else:
                rnr_list = [psn_pad[:1, :0].reshape(0)] * B
            return t_last, rnr_list, psn_pad
        rnr_list = []
        for k, leaf in enumerate(leaves):
            c = int(counts[k])
            tl, rp = self.pools[leaf].service_with_rnr(
                arr_pad[k, :c], psn_pad[k, :c], self.chunk,
                self.workers.staging_chunks)
            if tl is not None:
                t_last[k] = tl
            rnr_list.append(rp)
        return t_last, rnr_list, psn_pad

    def deliver_fast(self) -> None:
        inject = self.flow.chunk_times(self.n_chunks, self.chunk)
        self._cutoff = self.flow.t_end + self.fabric.alpha
        masks = _sample_link_round(self.models, self.n_chunks)
        n, ids = self.n_chunks, self.leaf_ids
        if self._lossless and self._skip_jitter and self.pools is None:
            # dedup fast path: no loss, no jitter, memoryless pool -> every
            # leaf at the same hop latency sees the IDENTICAL arrival row;
            # one pool pass per distinct hop, fanned out to the group
            psns = np.arange(n)
            for h in np.unique(self.hop):
                sel = np.nonzero(self.hop == h)[0]
                t_last, rnr_psns = _pool_with_rnr_psns(
                    inject + h, psns, self.workers, self.service)
                got = psns
                if self.delivery is not None and rnr_psns.size:
                    got = psns[~np.isin(psns, rnr_psns)]
                for k in sel:
                    leaf = ids[k]
                    self.rnr_total += rnr_psns.shape[0]
                    if rnr_psns.size:
                        self.missing[leaf] = rnr_psns
                    if self.delivery is not None:
                        self.delivery[leaf].append(got)
                self._tdone[sel] = t_last
                self.completion[self._ids[sel]] = t_last
                self.t_fast_end = max(self.t_fast_end, t_last)
        else:
            lost_all = (None if self._lossless
                        else _stacked_lost(self.paths, masks, ids, n))
            blk = max(1, _BLOCK_ELEMS // max(n, 1))
            for s0 in range(0, len(ids), blk):
                s1 = min(s0 + blk, len(ids))
                sub = ids[s0:s1]
                if lost_all is None:
                    lost = None
                    counts = np.full(len(sub), n, dtype=np.intp)
                    psn_flat = np.tile(np.arange(n), len(sub))
                else:
                    lost = lost_all[s0:s1]
                    rows, psn_flat = np.nonzero(~lost)
                    counts = np.bincount(rows, minlength=len(sub))
                base = inject[psn_flat] + np.repeat(self.hop[s0:s1], counts)
                jit = self._draw_jitter(base.shape[0])
                if jit is not None:
                    base = base + jit
                t_last, rnr_list, psn_pad = self._pool_rows(
                    sub, counts, psn_flat, base)
                tdone = np.where(np.isnan(t_last), self.t_start, t_last)
                self._tdone[s0:s1] = tdone
                self.completion[self._ids[s0:s1]] = tdone
                self.t_fast_end = max(self.t_fast_end, float(tdone.max()))
                for k, leaf in enumerate(sub):
                    rnr_psns = rnr_list[k]
                    self.rnr_total += rnr_psns.shape[0]
                    if lost is None:
                        miss = rnr_psns if rnr_psns.size else None
                    else:
                        lost_cols = np.nonzero(lost[k])[0]
                        if rnr_psns.size:
                            miss = np.sort(
                                np.concatenate([lost_cols, rnr_psns]))
                        else:
                            miss = lost_cols if lost_cols.size else None
                    if miss is not None:
                        self.missing[leaf] = miss
                    if self.delivery is not None:
                        self._record_delivery(
                            leaf, psn_pad[k, :counts[k]], rnr_psns)
        self.completion[self.root] = self.flow.t_end
        self.t_fast_end = max(self.t_fast_end, self.flow.t_end)

    def incomplete(self) -> list[int]:
        return sorted(self.missing)

    def plan_retransmit(self):
        nackers = self.incomplete()
        if not nackers:
            return None
        n = self.n_chunks
        # union of missing: scatter the per-leaf missing sets into rows,
        # pack every row to the u32 NACK wire format in one batched call,
        # OR-reduce across rows (what the switches do hop by hop)
        flags = np.zeros((len(nackers), n + ((-n) % 32)), dtype=bool)
        for k, leaf in enumerate(nackers):
            flags[k, self.missing[leaf]] = True
        if profiling.ENABLED:
            with profiling.phase("packing"):
                agg_words = np.bitwise_or.reduce(bitmap_pack_rows_np(flags),
                                                 axis=0)
        else:
            agg_words = np.bitwise_or.reduce(bitmap_pack_rows_np(flags),
                                             axis=0)
        union = np.nonzero(bitmap_unpack_np(agg_words, n))[0]
        idx = np.array([self._pos[leaf] for leaf in nackers], dtype=np.intp)
        t_send = np.maximum(self._tdone[idx], self._cutoff) + self.hop[idx]
        if self.aggregate:
            arrivals = np.array([t_send.max()])
        else:
            arrivals = np.sort(t_send)
        return self._submit_retransmit(union, nackers, arrivals)

    def deliver_retransmit(self, meta) -> None:
        flow, union, nackers, arrivals, t_root_done = meta
        u = union.size
        inject = flow.chunk_times(u, self.chunk)
        pruned = _models_on_paths(self.paths, self.models, nackers)
        masks = _sample_link_round(pruned, u)
        lost_all = (_stacked_lost(self.paths, masks, nackers, u)
                    if any(m is not None for m in pruned.values()) else None)
        recovered_round = 0
        t_round_end = t_root_done
        blk = max(1, _BLOCK_ELEMS // max(u, 1))
        for s0 in range(0, len(nackers), blk):
            s1 = min(s0 + blk, len(nackers))
            sub = nackers[s0:s1]
            miss_list = [self.missing[leaf] for leaf in sub]
            sizes = np.array([m.size for m in miss_list], dtype=np.intp)
            miss_flat = np.concatenate(miss_list)
            rows = np.repeat(np.arange(len(sub), dtype=np.intp), sizes)
            pos_flat = np.searchsorted(union, miss_flat)    # union ⊇ miss
            self.duplicates += int(len(sub) * u - miss_flat.size)
            if lost_all is None:
                keep = np.ones(miss_flat.shape[0], dtype=bool)
            else:
                keep = ~lost_all[s0:s1][rows, pos_flat]
            got_counts = np.bincount(rows[keep], minlength=len(sub))
            idx = np.array([self._pos[leaf] for leaf in sub], dtype=np.intp)
            base = (inject[pos_flat[keep]]
                    + np.repeat(self.hop[idx], got_counts))
            jit = self._draw_jitter(base.shape[0])
            if jit is not None:
                base = base + jit
            t_last, rnr_list, psn_pad = self._pool_rows(
                sub, got_counts, miss_flat[keep], base)
            still = miss_flat[~keep]
            still_sizes = np.bincount(rows[~keep], minlength=len(sub))
            still_rows = np.split(still, np.cumsum(still_sizes)[:-1])
            for k, leaf in enumerate(sub):
                rnr_psns = rnr_list[k]
                self.rnr_total += rnr_psns.shape[0]
                recovered_round += int(got_counts[k]) - rnr_psns.shape[0]
                if self.delivery is not None:
                    self._record_delivery(
                        leaf, psn_pad[k, :got_counts[k]], rnr_psns)
                st_lost = still_rows[k]
                if rnr_psns.size:
                    nxt = np.sort(np.concatenate([st_lost, rnr_psns]))
                elif st_lost.size:
                    nxt = st_lost
                else:
                    nxt = None
                if nxt is None:
                    del self.missing[leaf]
                else:
                    self.missing[leaf] = nxt
                if not np.isnan(t_last[k]):
                    tl = float(t_last[k])
                    self._tdone[idx[k]] = tl
                    self.completion[leaf] = tl
                    t_round_end = max(t_round_end, tl)
        self._cutoff = flow.t_end + self.fabric.alpha
        self.t_rel_end = max(self.t_rel_end, t_round_end)
        self.rounds.append(RoundTrace(
            nack_leaves=len(nackers),
            root_nack_msgs=int(arrivals.shape[0]),
            union_chunks=int(union.size),
            t_nack_root=float(arrivals.max()),
            t_retx_start=float(flow.t_start),
            t_end=t_round_end,
            recovered=recovered_round,
        ))
        self.retransmit_wire += int(union.size) * self.chunk

    def stats(self) -> dict:
        n_total = (self.p - 1) * self.n_chunks
        recovered = sum(tr.recovered for tr in self.rounds)
        return {
            "delivered_fast": n_total - recovered
            - sum(m.size for m in self.missing.values()),
            "recovered": recovered,
        }


class _AbstractCarrier:
    """Loss carrier for the no-topology mode: stands in for the single
    abstract hop between the root's send link and one leaf."""

    __slots__ = ("loss",)

    def __init__(self):
        self.loss = None


def simulate_packet_broadcast(
        p: int, n_bytes: int, fabric: FabricParams, workers: WorkerParams,
        rng: np.random.Generator, root: int = 0, *, topology=None,
        hosts=None, loss=None, max_rounds: int = DEFAULT_MAX_ROUNDS,
        aggregate_nacks: bool = True, collect_delivery: bool = False,
        dpa_fidelity: str = "scalar", dpa=None,
        engine: str = "auto") -> PacketBcastResult:
    """Packet-fidelity reliable Broadcast (the ``fidelity="packet"`` backend
    of simulator.simulate_broadcast — see the module docstring for the
    protocol model). At ``loss=None``/``p_drop=0`` it reproduces the fluid
    model's times exactly (bit-exactly with jitter=0; with jitter the two
    draw different samples from the same distribution).
    ``dpa_fidelity="event"`` swaps the scalar worker pool for the
    event-level DPA progress engine of core/dpa_engine.py (``dpa=``
    supplies its EventDpaParams / DpaConfig). ``engine="vectorized"``
    runs the batched round executor; ``engine="reference"`` the per-leaf
    loop it is pinned bit-exact against; ``engine="auto"`` (default)
    resolves via resolve_engine — always "vectorized" for broadcast, whose
    per-leaf rows never merge."""
    engine = resolve_engine(engine, "broadcast", p, n_bytes)
    cls = _VecBroadcastRun if engine == "vectorized" else _BroadcastRun
    t_rnr = _rnr_barrier(p, fabric, workers)
    eng = Engine()
    if topology is not None:
        topology.reset()
    run = cls(p, n_bytes, fabric, workers, rng, root, eng,
              topology=topology, hosts=hosts, loss=loss,
              aggregate_nacks=aggregate_nacks,
              collect_delivery=collect_delivery,
              dpa_fidelity=dpa_fidelity, dpa=dpa)
    run.submit_fast(t_rnr)
    eng.run()
    run.deliver_fast()

    n_rounds = 0
    while run.incomplete() and n_rounds < max_rounds:
        meta = run.plan_retransmit()
        eng.run()
        run.deliver_retransmit(meta)
        n_rounds += 1
    completed = not run.incomplete()

    completion = run.completion
    # final handshake: send final to left, need final from right (§III-C)
    completion = np.maximum(completion, np.roll(completion, -1)) \
        + fabric.latency
    st = run.stats()
    phases = PhaseBreakdown(
        rnr_sync=t_rnr,
        multicast=run.t_fast_end - t_rnr,
        reliability=max(run.t_rel_end - run.t_fast_end, 0.0),
        handshake=fabric.latency,
    )
    return PacketBcastResult(
        completion=completion,
        phases=phases,
        delivered_fast=st["delivered_fast"],
        recovered=st["recovered"],
        rnr_drops=run.rnr_total,
        bytes_fast=st["delivered_fast"] * run.chunk,
        bytes_recovery=st["recovered"] * run.chunk,
        bytes_total=(p - 1) * run.n_chunks * run.chunk,
        link_bytes=eng.link_bytes() if topology is not None else {},
        rounds=run.rounds,
        retransmit_wire_bytes=run.retransmit_wire,
        duplicates=run.duplicates,
        completed=completed,
        delivery_order=(
            {leaf: (np.concatenate(parts) if parts
                    else np.empty(0, dtype=np.intp))
             for leaf, parts in run.delivery.items()}
            if run.delivery is not None else {}),
    )


# ------------------------------------------------------------ allgather core


@dataclass
class PacketAllgatherResult:
    """Field-compatible with simulator.AllgatherResult plus the packet
    protocol's per-chain round traces."""
    time: float
    phases: PhaseBreakdown
    recovered: int
    bytes_fast: int
    bytes_recovery: int
    bytes_total: int
    per_rank_recv_tput: float
    link_bytes: dict[str, float] = field(default_factory=dict)
    rounds: list[RoundTrace] = field(default_factory=list)
    rnr_drops: int = 0
    retransmit_wire_bytes: int = 0
    completed: bool = True


def simulate_packet_allgather(
        p: int, n_bytes: int, fabric: FabricParams, workers: WorkerParams,
        rng: np.random.Generator, n_chains: int = 1, *, topology=None,
        hosts=None, loss=None, max_rounds: int = DEFAULT_MAX_ROUNDS,
        aggregate_nacks: bool = True, dpa_fidelity: str = "scalar",
        dpa=None, engine: str = "auto") -> PacketAllgatherResult:
    """Packet-fidelity Allgather: a facade over the Collective Schedule IR.
    Builds the Appendix-A schedule graph (typed Multicast ops + Activation
    edges, uneven chains supported) and executes it at packet fidelity —
    the round loop lives in sched_ir._packet_allgather and lowers onto this
    module's protocol machinery. ``dpa_fidelity="event"`` gives every host
    a persistent event-level DPA (core/dpa_engine.py); a chain root's NACK
    service and retransmit posting then run on the SAME contexts that
    receive the other chains — protocol work steals cycles from the
    receive datapath."""
    from repro.core import sched_ir   # deferred: sched_ir lowers onto us

    sched = sched_ir.build_allgather(p, n_bytes, n_chains)
    return sched_ir.execute(sched, fabric, workers, rng, fidelity="packet",
                            topology=topology, hosts=hosts, loss=loss,
                            max_rounds=max_rounds,
                            aggregate_nacks=aggregate_nacks,
                            dpa_fidelity=dpa_fidelity, dpa=dpa,
                            engine=engine)


# --------------------------------------------- FSDP overlay (closed timing)


def recovery_overlay(paths: dict, models: dict[int, LossModel | None],
                     n_chunks: int, chunk: int, bottleneck_rate: float,
                     fabric: FabricParams, workers: WorkerParams,
                     rng: np.random.Generator, *,
                     max_rounds: int = DEFAULT_MAX_ROUNDS,
                     aggregate_nacks: bool = True) -> float:
    """Extra completion time a loss process adds to an already-timed tree
    flow (the FSDP packet overlay): sampled NACK/retransmission rounds with
    the retransmit stream served at the tree's bottleneck rate, WITHOUT
    re-entering the global max-min allocation. Used where a full per-layer
    packet replay would be quadratic (simulate_fsdp_step fidelity="packet");
    DESIGN.md §3.1 records the approximation."""
    missing = {}
    masks = _sample_link_round(models, n_chunks)
    for leaf, path in paths.items():
        lost = _leaf_lost(path, masks, n_chunks)
        if lost.any():
            missing[leaf] = lost
    extra = 0.0
    depth = max((len(p) for p in paths.values()), default=1)
    for _ in range(max_rounds):
        if not missing:
            break
        union = np.zeros(n_chunks, dtype=bool)
        for lost in missing.values():
            union |= lost
        n_union = int(union.sum())
        n_msgs = 1 if aggregate_nacks else len(missing)
        # ceil(msgs/workers) service batches: a single aggregated NACK costs
        # one full service on one worker — it cannot be split across the pool
        batches = -(-n_msgs // max(workers.n_recv_workers, 1))
        t_nack = fabric.alpha + depth * fabric.latency \
            + batches * _nack_service(n_chunks, workers, fabric.mtu)
        t_retx = n_union * chunk / bottleneck_rate + depth * fabric.latency
        extra += t_nack + t_retx
        rmasks = _sample_link_round(
            _models_on_paths(paths, models, sorted(missing)), n_union)
        upos = np.nonzero(union)[0]
        nxt = {}
        for leaf, lost in missing.items():
            pos = np.searchsorted(upos, np.nonzero(lost)[0])
            still = _leaf_lost(paths[leaf], rmasks, n_union)[pos]
            if still.any():
                again = np.zeros(n_chunks, dtype=bool)
                again[np.nonzero(lost)[0][still]] = True
                nxt[leaf] = again
        missing = nxt
    return extra
