"""Reliable constant-time Broadcast protocol (paper §III).

Three layers live here:

  1. The *logical* state machines — segmentation with PSNs, receive-side
     staging ring, per-chunk bitmap, cutoff timer, fetch-ring recovery, RNR
     barrier, final handshake — independent of timing; hypothesis property
     tests drive them with adversarial drop/reorder patterns.
  2. The ENGINE-BACKED timing facade (``broadcast_time`` /
     ``allgather_time``): protocol timing is produced by the discrete-event
     engines — the fluid model in core/simulator.py, or the packet-level
     reliable-multicast engine in core/packet.py (``fidelity="packet"``)
     with per-Link loss injection and NACK/retransmission rounds.
  3. The CLOSED-FORM ``analytic_*`` path, kept as the cross-check oracle the
     tests hold the engines against (and the reliable-unicast baseline the
     loss-crossover benchmark compares multicast recovery to).

On TPU this layer applies to the switched inter-pod (DCN) axis; intra-pod ICI
is reliable (DESIGN.md §2).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

MTU = 4096
PSN_BITS = 24           # of the 32-bit CQE immediate (rest: collective id, Fig 7)
IMM_BITS = 32


@dataclass
class Chunk:
    psn: int
    payload: bytes


def segment(buffer: bytes, mtu: int = MTU) -> list[Chunk]:
    """Zero-copy fragmentation at the root (§III-A): chunk PSN enumerates the
    chunk within the send buffer and rides the 32-bit immediate."""
    n = len(buffer)
    n_chunks = -(-n // mtu) if n else 0
    assert n_chunks < (1 << PSN_BITS), "PSN must fit the immediate (Fig 7)"
    return [Chunk(i, buffer[i * mtu : (i + 1) * mtu]) for i in range(n_chunks)]


def max_addressable_buffer(psn_bits: int, mtu: int = MTU) -> int:
    """Fig 7: the receive buffer addressable with psn_bits of immediate."""
    return (1 << psn_bits) * mtu


def bitmap_bytes(buffer_bytes: int, mtu: int = MTU) -> int:
    """Fig 7 / §III-D: one bit per chunk."""
    return (-(-buffer_bytes // mtu) + 7) // 8


@dataclass
class Bitmap:
    n_chunks: int
    words: list[int] = field(default_factory=list)

    def __post_init__(self):
        self.words = [0] * ((self.n_chunks + 63) // 64)

    def set(self, psn: int) -> None:
        assert 0 <= psn < self.n_chunks
        self.words[psn >> 6] |= 1 << (psn & 63)

    def get(self, psn: int) -> bool:
        return bool(self.words[psn >> 6] >> (psn & 63) & 1)

    def popcount(self) -> int:
        return sum(w.bit_count() for w in self.words)

    def complete(self) -> bool:
        return self.popcount() == self.n_chunks

    def missing(self) -> list[int]:
        return [i for i in range(self.n_chunks) if not self.get(i)]


@dataclass
class StagingRing:
    """Receive-side staging area (§III-B): chunks land here (tolerating
    out-of-order arrival), then are copied to the user buffer at the offset
    given by the PSN. Ring occupancy beyond capacity = RNR drop."""
    capacity_chunks: int
    occupied: int = 0
    rnr_drops: int = 0

    def arrive(self) -> bool:
        if self.occupied >= self.capacity_chunks:
            self.rnr_drops += 1
            return False
        self.occupied += 1
        return True

    def drain(self, k: int = 1) -> None:
        assert self.occupied >= k
        self.occupied -= k


class LeafReceiver:
    """Broadcast leaf datapath (§III-B/C): staging -> bitmap -> user buffer."""

    def __init__(self, n_bytes: int, mtu: int = MTU, staging_chunks: int = 8192):
        self.mtu = mtu
        self.n_chunks = -(-n_bytes // mtu) if n_bytes else 0
        self.user = bytearray(n_bytes)
        self.bitmap = Bitmap(max(self.n_chunks, 1))
        self.staging = StagingRing(staging_chunks)
        self.duplicates = 0

    def deliver(self, chunk: Chunk) -> bool:
        """Fast path: a multicast datagram arrived (any order). Returns False
        on RNR drop (staging full)."""
        if not self.staging.arrive():
            return False
        if self.bitmap.get(chunk.psn):
            self.duplicates += 1
        else:
            off = chunk.psn * self.mtu
            self.user[off : off + len(chunk.payload)] = chunk.payload
            self.bitmap.set(chunk.psn)
        self.staging.drain()
        return True

    def fetch_recover(self, peers: list["LeafReceiver"], root_buffer: bytes) -> int:
        """Slow path (§III-C): recursive zero-copy fetch along the ring. For
        each missing chunk, walk left neighbors until a holder is found
        (Broadcast root in the worst case). Returns hops*chunks traversed."""
        cost = 0
        for psn in self.bitmap.missing():
            holder_payload = None
            for hops, peer in enumerate(peers, start=1):
                cost += 1
                if peer.bitmap.get(psn):
                    off = psn * self.mtu
                    holder_payload = bytes(peer.user[off : off + self.mtu])
                    break
            if holder_payload is None:  # fell through to the root
                off = psn * self.mtu
                holder_payload = root_buffer[off : off + self.mtu]
                cost += 1
            self.user[psn * self.mtu : psn * self.mtu + len(holder_payload)] = (
                holder_payload
            )
            self.bitmap.set(psn)
        return cost

    def complete(self) -> bool:
        return self.bitmap.complete()


def cutoff_time(n_bytes: int, b_link: float, alpha: float = 50e-6) -> float:
    """§III-C: timeout = N/B_link + alpha (RNR sync + network noise)."""
    return n_bytes / b_link + alpha


def final_handshake_ok(completed: list[bool]) -> bool:
    """All leaves completed -> every final packet sent+received in the ring."""
    return all(completed)


# ------------------------------------------------- engine-backed timing facade


def broadcast_time(p: int, n_bytes: int, fabric=None, workers=None, *,
                   fidelity: str = "packet", seed: int = 0, dpa=None,
                   **kw) -> float:
    """Completion time of one reliable Broadcast, produced by the
    discrete-event engines (packet fidelity by default — this facade IS the
    protocol's timing model; the closed forms below only cross-check it).

    ``dpa=`` (a dpa.DpaConfig or dpa_engine.EventDpaParams) routes the
    receive datapath through the EVENT-level DPA progress engine
    (core/dpa_engine.py, ``dpa_fidelity="event"``) instead of consuming
    dpa.pool_tput as a scalar worker-pool rate."""
    import numpy as np

    from repro.core import simulator  # deferred: simulator imports protocol

    fabric = fabric or simulator.FabricParams()
    workers = workers or simulator.WorkerParams()
    if dpa is not None:
        assert fidelity == "packet", "dpa= requires fidelity='packet'"
        kw.setdefault("dpa_fidelity", "event")
        kw["dpa"] = dpa
    return simulator.simulate_broadcast(
        p, n_bytes, fabric, workers, np.random.default_rng(seed),
        fidelity=fidelity, **kw).time


def allgather_time(p: int, n_bytes: int, fabric=None, workers=None, *,
                   n_chains: int = 1, fidelity: str = "packet",
                   seed: int = 0, dpa=None, **kw) -> float:
    """Completion time of one reliable M-chain Allgather (engine-backed).
    ``dpa=`` selects the event-level DPA, as in broadcast_time."""
    import numpy as np

    from repro.core import simulator  # deferred: simulator imports protocol

    fabric = fabric or simulator.FabricParams()
    workers = workers or simulator.WorkerParams()
    if dpa is not None:
        assert fidelity == "packet", "dpa= requires fidelity='packet'"
        kw.setdefault("dpa_fidelity", "event")
        kw["dpa"] = dpa
    return simulator.simulate_allgather(
        p, n_bytes, fabric, workers, np.random.default_rng(seed),
        n_chains, fidelity=fidelity, **kw).time


# ----------------------------------------------- closed-form cross-check oracle


def analytic_rnr_barrier(p: int, latency: float,
                         rnr_hop: float = 1.5e-6) -> float:
    """§V-A recursive-doubling RNR barrier (mirrors the engines exactly)."""
    return math.ceil(math.log2(max(p, 2))) * (latency + rnr_hop)


def analytic_bcast_time(p: int, n_bytes: int, b_link: float, latency: float,
                        *, pool_rate: float | None = None, depth: int = 1,
                        rnr_hop: float = 1.5e-6) -> float:
    """Lossless closed form of the engine Broadcast: RNR barrier + stream at
    the slower of wire and worker pool + per-hop latency + final handshake.
    The engines must reproduce this within tolerance at loss 0 — the
    cross-check oracle of tests/test_packet.py."""
    rate = b_link if pool_rate is None else min(b_link, pool_rate)
    return (analytic_rnr_barrier(p, latency, rnr_hop)
            + n_bytes / rate + depth * latency + latency)


def analytic_allgather_time(p: int, n_bytes: int, b_link: float,
                            latency: float, *, n_chains: int = 1,
                            pool_rate: float | None = None,
                            rnr_hop: float = 1.5e-6) -> float:
    """Lossless closed form (lower bound) of the engine Allgather: RNR
    barrier + the receive path ingesting the (P-1)N gathered bytes at the
    slower of wire and worker pool + one activation hop per schedule
    generation (R = ceil(P/M)) + the final handshake. The fluid lowering
    additionally pays MTU chunk rounding and its own-chain echo (it ingests
    P*N), so analytic <= fluid holds across the metamorphic grid."""
    rate = b_link if pool_rate is None else min(b_link, pool_rate)
    rounds = -(-p // n_chains)
    return (analytic_rnr_barrier(p, latency, rnr_hop)
            + (p - 1) * n_bytes / rate + rounds * latency + latency)


def analytic_ring_allgather_time(p: int, n_bytes: int, b_link: float,
                                 latency: float) -> float:
    """Closed form of the ring-Allgather lowering: P-1 generations, each
    forwarding an N-byte shard on the full-duplex NIC plus one hop."""
    return (p - 1) * (n_bytes / b_link + latency)


def analytic_hier_allgather_time(p: int, n_bytes: int, b_link: float,
                                 latency: float, *, island_size: int,
                                 m: int | None = None,
                                 stripe_mode: str = "mcast",
                                 pool_rate: float | None = None,
                                 rnr_hop: float = 1.5e-6,
                                 b_island: float | None = None) -> float:
    """Closed form (lower bound) of the hierarchical island allgather
    (sched_ir.build_hierarchical_allgather): phase B is an I-member
    allgather over the switched tier at ``b_link`` (I = P/g islands; the
    M-chain closed form, or the ring form for the unicast-stripe variant),
    phase C is g-1 island-ring generations each rotating an I*N bundle at
    ``b_island`` (defaults to ``b_link`` for the abstract single-NIC view).

    Tiered admissibility (the searcher's pruning bound): every phase-C hop
    crosses exactly one link of capacity at most ``b_island`` — island-tier
    cables at b_island, or slower multi-hop switched paths for the
    transport-flipped variant — so the ring term evaluated at the island
    capacity lower-bounds any redistribute_transport; the phase-B term
    inherits the flat closed form's NIC-ingest argument at I members."""
    g = island_size
    assert g >= 2 and p % g == 0 and p // g >= 2, (p, g)
    n_islands = p // g
    if stripe_mode == "mcast":
        stripe = analytic_allgather_time(n_islands, n_bytes, b_link, latency,
                                         n_chains=m or 1,
                                         pool_rate=pool_rate,
                                         rnr_hop=rnr_hop)
    else:
        stripe = analytic_ring_allgather_time(n_islands, n_bytes, b_link,
                                              latency)
    b_isl = b_island if b_island is not None else b_link
    return stripe + (g - 1) * (n_islands * n_bytes / b_isl + latency)


def analytic_ring_reduce_scatter_time(p: int, n_bytes: int, b_link: float,
                                      latency: float) -> float:
    """Closed form of the ring Reduce-Scatter lowering over an N-byte
    per-rank buffer: P-1 generations of the N/P shard (reduction combines
    at line rate)."""
    return (p - 1) * (n_bytes / p / b_link + latency)


def analytic_allreduce_time(p: int, n_bytes: int, b_link: float,
                            latency: float, *, m: int | None = None,
                            pool_rate: float | None = None,
                            rnr_hop: float = 1.5e-6) -> float:
    """Closed form of Allreduce = RS ∘ AG (core/sched_ir.build_allreduce):
    ring Reduce-Scatter of the buffer, then an Allgather of the reduced
    N/P shards — ``m=None`` the ring AG, ``m >= 1`` the paper's M-chain
    multicast AG (with its RNR barrier and pool bound)."""
    rs = analytic_ring_reduce_scatter_time(p, n_bytes, b_link, latency)
    shard = max(n_bytes // p, 1)
    if m:
        ag = analytic_allgather_time(p, shard, b_link, latency, n_chains=m,
                                     pool_rate=pool_rate, rnr_hop=rnr_hop)
    else:
        ag = analytic_ring_allgather_time(p, shard, b_link, latency)
    return rs + ag


def pipeline_schedule_time(rs_times: "list[float]",
                           ag_times: "list[float]") -> float:
    """Two-stage pipeline completion time (chunk-granularity RS∘AG
    pipelining): segment s's AG starts once its own RS finished AND the
    previous segment's AG drained; segment s+1's RS follows segment s's RS.
    ONE definition shared by the fluid/packet pipelined-allreduce executor
    (sched_ir._exec_allreduce) and the closed-form bound below — the
    recurrence is monotone in every stage time, so applying it to per-segment
    lower bounds yields a lower bound of the executed schedule (the
    admissibility argument sched_search's pruning rests on)."""
    assert len(rs_times) == len(ag_times) and rs_times
    t_rs = t_ag = 0.0
    for rs, ag in zip(rs_times, ag_times):
        t_rs = t_rs + rs
        t_ag = max(t_rs, t_ag) + ag
    return t_ag


def analytic_pipelined_allreduce_time(p: int, n_bytes: int, b_link: float,
                                      latency: float, *,
                                      m: int | None = None,
                                      n_segments: int = 1,
                                      pool_rate: float | None = None,
                                      rnr_hop: float = 1.5e-6) -> float:
    """Closed form of the segment-pipelined Allreduce
    (sched_ir.build_pipelined_allreduce): the buffer is split into
    ``n_segments`` equal-ish segments, each an RS ∘ AG pair, and segment
    s+1's Reduce-Scatter overlaps segment s's Allgather. ``n_segments=1``
    reduces exactly to analytic_allreduce_time."""
    assert n_segments >= 1
    q, rem = divmod(n_bytes, n_segments)
    segs = [q + (1 if i < rem else 0) for i in range(n_segments)]
    rs_times, ag_times = [], []
    for seg in segs:
        rs_times.append(
            analytic_ring_reduce_scatter_time(p, seg, b_link, latency))
        shard = max(seg // p, 1)
        if m:
            ag_times.append(analytic_allgather_time(
                p, shard, b_link, latency, n_chains=m, pool_rate=pool_rate,
                rnr_hop=rnr_hop))
        else:
            ag_times.append(
                analytic_ring_allgather_time(p, shard, b_link, latency))
    return pipeline_schedule_time(rs_times, ag_times)


# ----------------------------------------------- lower-bound certificates


@dataclass(frozen=True)
class BoundCertificate:
    """Optimality certificate attached to a searched schedule
    (core/sched_search.py): the admissible lower bound the winner was
    pruned against, which term of it binds (the flat closed form or a named
    fabric cut), and the achieved winner-time / bound ratio — 1.0 means the
    schedule provably leaves nothing on the table at this fidelity."""

    kind: str
    p: int
    n_bytes: int
    bound: float                     # admissible lower bound (s)
    winner_time: float               # simulated time of the winner (s)
    binding: str                     # which bound term binds ("analytic",
    #                                  "cut:pod0", ...)

    @property
    def ratio(self) -> float:
        """winner_time / bound — >= 1.0 whenever the bound is admissible."""
        return self.winner_time / self.bound if self.bound > 0 else math.inf


def analytic_expected_rounds(path_loss: float, n_chunks: int,
                             target: float = 0.5) -> float:
    """Expected NACK/retransmission rounds until a receiver behind a path
    with per-packet loss ``path_loss`` completes: missing decays
    geometrically, so rounds ~ log(1/(n_chunks)) / log(q) — the reason
    recovery cost is flat in P at fixed loss."""
    assert 0.0 <= path_loss < 1.0
    if path_loss == 0.0 or n_chunks <= 0:
        return 0.0
    # rounds until E[missing] < target chunks
    return max(math.log(target / n_chunks) / math.log(path_loss), 1.0)


def analytic_recovery_time(p: int, n_bytes: int, b_link: float,
                           latency: float, path_loss: float, *,
                           n_tree_links: int | None = None,
                           link_loss: float | None = None,
                           mtu: int = MTU, depth: int = 6,
                           alpha: float = 50e-6) -> float:
    """Closed-form expected recovery time of the NACK-aggregation +
    multicast-retransmission protocol. Per round: cutoff slack + NACK ascent
    + retransmit of the UNION of missing chunks (1 - (1-q_link)^L of the
    buffer for L lossy tree links) + descent. The p-dependence enters only
    through L (saturating) and the log-depth terms — the analytic form of
    the paper's constant-time claim."""
    n_chunks = max(-(-n_bytes // mtu), 1)
    rounds = analytic_expected_rounds(path_loss, n_chunks)
    if rounds == 0.0:
        return 0.0
    if n_tree_links is not None and link_loss is not None:
        union_frac = 1.0 - (1.0 - link_loss) ** n_tree_links
    else:
        union_frac = min(p * path_loss, 1.0)
    t = 0.0
    frac = union_frac
    for _ in range(int(math.ceil(rounds))):
        t += alpha + 2 * depth * latency + frac * n_bytes / b_link
        frac *= path_loss
    return t


def analytic_ring_pipeline_bcast_time(p: int, n_bytes: int, b_link: float,
                                      latency: float, *, loss_rate: float = 0.0,
                                      mtu: int = MTU) -> float:
    """Reliable-UNICAST baseline: pipelined ring broadcast on RC transport.
    Hardware go-back-N retransmission shows up as a goodput inflation
    1/(1-q) per hop (the crossover benchmark compares packet-multicast
    recovery against this)."""
    assert 0.0 <= loss_rate < 1.0
    n_chunks = max(-(-n_bytes // mtu), 1)
    chunk = min(mtu, n_bytes) if n_bytes else mtu
    wire = (n_chunks + p - 2) * chunk / b_link / (1.0 - loss_rate)
    return wire + (p - 1) * latency


# --------------------------------------------------------- memory footprint


def memory_footprint(n_bytes: int, *, mtu: int = MTU, staging_chunks: int = 1024,
                     n_leaf_rc_qps: int = 2, ctx_bytes: int = 16 << 10) -> dict:
    """§III-D: protocol state per communicator."""
    return {
        "staging_bytes": staging_chunks * mtu,
        "bitmap_bytes": bitmap_bytes(n_bytes, mtu),
        "rc_qps": n_leaf_rc_qps,
        "ud_qps": 1,
        "context_bytes": ctx_bytes,
    }


def communicators_in_llc(llc_bytes: int = int(1.5e6), recvbuf_bytes: int = 16 << 30,
                         ctx_bytes: int = 16 << 10,
                         tracked_chunk: int = 32 << 10) -> int:
    """§III-D(d): how many communicators fit the DPA LLC (paper: >16 with
    64 KiB bitmaps for 16 GB receive buffers — which implies the bitmap tracks
    32 KiB multi-packet UC chunks, not single 4 KiB MTUs; Fig. 15)."""
    per = bitmap_bytes(recvbuf_bytes, tracked_chunk) + ctx_bytes
    return llc_bytes // per
