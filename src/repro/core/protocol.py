"""Reliable constant-time Broadcast protocol state machines (paper §III).

These classes model the *logical* protocol exactly — segmentation with PSNs,
receive-side staging ring, per-chunk bitmap, cutoff timer, fetch-ring
recovery, RNR barrier, final handshake — independent of timing. The
discrete-event timing lives in core/simulator.py; hypothesis property tests
drive these machines directly with adversarial drop/reorder patterns.

On TPU this layer applies to the switched inter-pod (DCN) axis; intra-pod ICI
is reliable (DESIGN.md §2).
"""
from __future__ import annotations

from dataclasses import dataclass, field

MTU = 4096
PSN_BITS = 24           # of the 32-bit CQE immediate (rest: collective id, Fig 7)
IMM_BITS = 32


@dataclass
class Chunk:
    psn: int
    payload: bytes


def segment(buffer: bytes, mtu: int = MTU) -> list[Chunk]:
    """Zero-copy fragmentation at the root (§III-A): chunk PSN enumerates the
    chunk within the send buffer and rides the 32-bit immediate."""
    n = len(buffer)
    n_chunks = -(-n // mtu) if n else 0
    assert n_chunks < (1 << PSN_BITS), "PSN must fit the immediate (Fig 7)"
    return [Chunk(i, buffer[i * mtu : (i + 1) * mtu]) for i in range(n_chunks)]


def max_addressable_buffer(psn_bits: int, mtu: int = MTU) -> int:
    """Fig 7: the receive buffer addressable with psn_bits of immediate."""
    return (1 << psn_bits) * mtu


def bitmap_bytes(buffer_bytes: int, mtu: int = MTU) -> int:
    """Fig 7 / §III-D: one bit per chunk."""
    return (-(-buffer_bytes // mtu) + 7) // 8


@dataclass
class Bitmap:
    n_chunks: int
    words: list[int] = field(default_factory=list)

    def __post_init__(self):
        self.words = [0] * ((self.n_chunks + 63) // 64)

    def set(self, psn: int) -> None:
        assert 0 <= psn < self.n_chunks
        self.words[psn >> 6] |= 1 << (psn & 63)

    def get(self, psn: int) -> bool:
        return bool(self.words[psn >> 6] >> (psn & 63) & 1)

    def popcount(self) -> int:
        return sum(w.bit_count() for w in self.words)

    def complete(self) -> bool:
        return self.popcount() == self.n_chunks

    def missing(self) -> list[int]:
        return [i for i in range(self.n_chunks) if not self.get(i)]


@dataclass
class StagingRing:
    """Receive-side staging area (§III-B): chunks land here (tolerating
    out-of-order arrival), then are copied to the user buffer at the offset
    given by the PSN. Ring occupancy beyond capacity = RNR drop."""
    capacity_chunks: int
    occupied: int = 0
    rnr_drops: int = 0

    def arrive(self) -> bool:
        if self.occupied >= self.capacity_chunks:
            self.rnr_drops += 1
            return False
        self.occupied += 1
        return True

    def drain(self, k: int = 1) -> None:
        assert self.occupied >= k
        self.occupied -= k


class LeafReceiver:
    """Broadcast leaf datapath (§III-B/C): staging -> bitmap -> user buffer."""

    def __init__(self, n_bytes: int, mtu: int = MTU, staging_chunks: int = 8192):
        self.mtu = mtu
        self.n_chunks = -(-n_bytes // mtu) if n_bytes else 0
        self.user = bytearray(n_bytes)
        self.bitmap = Bitmap(max(self.n_chunks, 1))
        self.staging = StagingRing(staging_chunks)
        self.duplicates = 0

    def deliver(self, chunk: Chunk) -> bool:
        """Fast path: a multicast datagram arrived (any order). Returns False
        on RNR drop (staging full)."""
        if not self.staging.arrive():
            return False
        if self.bitmap.get(chunk.psn):
            self.duplicates += 1
        else:
            off = chunk.psn * self.mtu
            self.user[off : off + len(chunk.payload)] = chunk.payload
            self.bitmap.set(chunk.psn)
        self.staging.drain()
        return True

    def fetch_recover(self, peers: list["LeafReceiver"], root_buffer: bytes) -> int:
        """Slow path (§III-C): recursive zero-copy fetch along the ring. For
        each missing chunk, walk left neighbors until a holder is found
        (Broadcast root in the worst case). Returns hops*chunks traversed."""
        cost = 0
        for psn in self.bitmap.missing():
            holder_payload = None
            for hops, peer in enumerate(peers, start=1):
                cost += 1
                if peer.bitmap.get(psn):
                    off = psn * self.mtu
                    holder_payload = bytes(peer.user[off : off + self.mtu])
                    break
            if holder_payload is None:  # fell through to the root
                off = psn * self.mtu
                holder_payload = root_buffer[off : off + self.mtu]
                cost += 1
            self.user[psn * self.mtu : psn * self.mtu + len(holder_payload)] = (
                holder_payload
            )
            self.bitmap.set(psn)
        return cost

    def complete(self) -> bool:
        return self.bitmap.complete()


def cutoff_time(n_bytes: int, b_link: float, alpha: float = 50e-6) -> float:
    """§III-C: timeout = N/B_link + alpha (RNR sync + network noise)."""
    return n_bytes / b_link + alpha


def final_handshake_ok(completed: list[bool]) -> bool:
    """All leaves completed -> every final packet sent+received in the ring."""
    return all(completed)


# --------------------------------------------------------- memory footprint


def memory_footprint(n_bytes: int, *, mtu: int = MTU, staging_chunks: int = 1024,
                     n_leaf_rc_qps: int = 2, ctx_bytes: int = 16 << 10) -> dict:
    """§III-D: protocol state per communicator."""
    return {
        "staging_bytes": staging_chunks * mtu,
        "bitmap_bytes": bitmap_bytes(n_bytes, mtu),
        "rc_qps": n_leaf_rc_qps,
        "ud_qps": 1,
        "context_bytes": ctx_bytes,
    }


def communicators_in_llc(llc_bytes: int = int(1.5e6), recvbuf_bytes: int = 16 << 30,
                         ctx_bytes: int = 16 << 10,
                         tracked_chunk: int = 32 << 10) -> int:
    """§III-D(d): how many communicators fit the DPA LLC (paper: >16 with
    64 KiB bitmaps for 16 GB receive buffers — which implies the bitmap tracks
    32 KiB multi-packet UC chunks, not single 4 KiB MTUs; Fig. 15)."""
    per = bitmap_bytes(recvbuf_bytes, tracked_chunk) + ctx_bytes
    return llc_bytes // per
