# Pallas TPU kernels for the data-movement hot spots the paper offloads:
#   chunk_reassembly — the DPA receive datapath (Appendix C) as a TPU kernel
#   collective_matmul — allgather-fused MXU matmul (latency hiding)
#   bitmap — reliability-state pack/popcount (bitmap_np: jax-free twins)
#   pool — T-server pool completion as a residue-class-parallel scan
#          (pool_np: jax-free twins on the engine's row-batched pool path)
# Validated on CPU via interpret=True against the pure-jnp oracles in ref.py.
#
# Submodules load lazily (PEP 562): the jax-free bitmap_np/pool_np twins are
# on the packet-protocol simulator hot path, so importing them
# must not pull in jax through this package init. Star-import exposes only
# ops/ref (the historical surface); attribute access reaches every submodule.
import importlib

__all__ = ["ops", "ref"]

_SUBMODULES = ("bitmap", "bitmap_np", "chunk_reassembly", "collective_matmul",
               "ops", "pool", "pool_np", "ref", "ring_allgather")


def __getattr__(name):
    if name in _SUBMODULES:
        return importlib.import_module(f"repro.kernels.{name}")
    raise AttributeError(f"module 'repro.kernels' has no attribute {name!r}")
