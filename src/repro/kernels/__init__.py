# Pallas TPU kernels for the data-movement hot spots the paper offloads:
#   chunk_reassembly — the DPA receive datapath (Appendix C) as a TPU kernel
#   collective_matmul — allgather-fused MXU matmul (latency hiding)
#   bitmap — reliability-state pack/popcount
# Validated on CPU via interpret=True against the pure-jnp oracles in ref.py.
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
