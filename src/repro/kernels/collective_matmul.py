"""Collective (allgather-fused) matmul.

The FSDP hot loop is allgather(weights-or-activations) -> matmul. The paper's
DPA thesis — hide data-movement latency behind parallel workers — maps to the
MXU as: consume each ring shard on the MXU while the next shard is in flight.

Two layers:
  - ``matmul_pallas``: the MXU-tiled matmul kernel (pl.pallas_call with
    explicit (bm, bk, bn) BlockSpec VMEM tiling and an f32 VMEM accumulator).
    MXU-aligned tile defaults (128x128x128).
  - ``allgather_matmul_local``: runs inside shard_map over a ring axis;
    at step s it matmuls the shard received at step s-1 while ppermuting the
    next shard — compute/communication overlap at the schedule level (on TPU
    the async collective-permute makes this the classic "collective matmul").
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_pallas(x: jax.Array, w: jax.Array, *, bm: int = 128, bk: int = 128,
                  bn: int = 128, interpret: bool | None = None) -> jax.Array:
    """(m, k) @ (k, n) with MXU-aligned VMEM tiles and f32 accumulation."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    m, k = x.shape
    k2, n = w.shape
    assert k == k2
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (m, k, n, bm, bk, bn)
    nk = k // bk
    return pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)


def allgather_matmul_local(x_shard: jax.Array, w: jax.Array, axis: str, *,
                           use_pallas: bool = True, bm: int = 128,
                           bk: int = 128, bn: int = 128) -> jax.Array:
    """Inside shard_map: computes allgather(x, axis) @ w with the matmul of
    shard s overlapped with the transfer of shard s+1.

    x_shard: (m_loc, k) local shard; returns (P*m_loc, n) (replicated value).
    """
    p = compat.axis_size(axis)
    idx = lax.axis_index(axis)
    mm = (
        functools.partial(matmul_pallas, bm=bm, bk=bk, bn=bn)
        if use_pallas
        else lambda a, b: jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)
    )
    n = w.shape[1]
    out = jnp.zeros((p, x_shard.shape[0], n), x_shard.dtype)

    def step(carry, s):
        out, cur = carry
        nxt = lax.ppermute(cur, axis, [(i, (i + 1) % p) for i in range(p)])
        y = mm(cur, w)                       # compute overlaps the permute
        out = out.at[(idx - s) % p].set(y)
        return (out, nxt), None

    (out, _), _ = lax.scan(step, (out, x_shard), jnp.arange(p))
    return out.reshape(p * x_shard.shape[0], n)


def make_allgather_matmul(mesh, axis: str, **kw):
    """Jitted global version: x (M, K) sharded on dim0 over ``axis``; w
    replicated. Returns allgather(x) @ w, replicated."""
    from jax.sharding import PartitionSpec as P

    local = functools.partial(allgather_matmul_local, axis=axis, **kw)
    sm = compat.shard_map(
        local, mesh=mesh, in_specs=(P(axis, None), P(None, None)),
        out_specs=P(None, None), check_vma=False,
    )
    return jax.jit(sm)
