"""Pure-jnp oracles for every Pallas kernel (tests assert allclose)."""
from __future__ import annotations

import jax.numpy as jnp


def chunk_reassembly_ref(staging, psn, user, n_valid=None):
    n_staged = staging.shape[0]
    if n_valid is None:
        n_valid = n_staged
    valid = jnp.arange(n_staged) < n_valid
    # emulate sequential writes (later duplicates win)
    psn_eff = jnp.where(valid, psn, user.shape[0])  # invalid -> dropped (OOB)
    user_out = user.at[psn_eff].set(staging, mode="drop")
    bitmap = jnp.zeros((user.shape[0],), jnp.uint32).at[psn_eff].set(
        jnp.uint32(1), mode="drop"
    )
    return user_out, bitmap


def matmul_ref(x, w):
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def allgather_matmul_ref(x_full, w):
    """x_full: the already-gathered (M, K)."""
    return matmul_ref(x_full, w)


def bitmap_pack_ref(flags):
    nw = flags.shape[0] // 32
    f = flags.reshape(nw, 32).astype(jnp.uint32)
    shifts = jnp.arange(32, dtype=jnp.uint32)[None, :]
    return jnp.sum(f << shifts, axis=1, dtype=jnp.uint32)


def bitmap_popcount_ref(words):
    bits = (words[:, None] >> jnp.arange(32, dtype=jnp.uint32)[None, :]) & 1
    return jnp.sum(bits, dtype=jnp.uint32)
