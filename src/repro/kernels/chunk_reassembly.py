"""Chunk reassembly kernel — the paper's DPA receive datapath (Appendix C) as
a TPU Pallas kernel.

The protocol stages out-of-order multicast chunks in a ring buffer; each chunk
carries its PSN (buffer offset) in the CQE immediate. The DPA kernel's hot
loop is: read CQE -> set bitmap bit -> DMA chunk from staging to user buffer
at psn*MTU. On TPU the staging ring lands in HBM (e.g. after a DCN receive on
the pod axis) and this kernel performs the scatter:

  HBM staging --(DMA, block i)--> VMEM --(DMA, block psn[i])--> HBM user buf

PSNs are scalar-prefetched (pltpu.PrefetchScalarGridSpec) so the *output*
BlockSpec index_map is driven by the PSN table — the data-dependent DMA
destination is resolved by the sequencer before the block executes, which is
exactly the "hide the cost of data movement" structure the paper offloads to
DPA hardware threads. The user buffer is input/output-aliased: chunks not
present in this staging batch keep their previous contents (partial delivery,
retransmitted tails).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _reassembly_kernel(psn_ref, staging_ref, user_in_ref, valid_ref,
                       user_ref, bitmap_ref):
    """One grid step copies staged chunk i to user[psn[i]] and marks bitmap."""
    i = pl.program_id(0)
    v = valid_ref[0, 0] > i  # number of valid staged chunks
    data = staging_ref[...]
    prev = user_in_ref[...]
    user_ref[...] = jnp.where(v, data, prev)
    bitmap_ref[0, 0] = jnp.where(
        v, jnp.uint32(1), bitmap_ref[0, 0]
    )


def chunk_reassembly(staging: jax.Array, psn: jax.Array, user: jax.Array,
                     n_valid: jax.Array | int | None = None, *,
                     interpret: bool | None = None):
    """Scatter staged chunks into the user buffer by PSN.

    staging: (n_staged, chunk)   — receive ring contents (arrival order)
    psn:     (n_staged,) int32   — destination chunk index per staged entry
    user:    (n_chunks, chunk)   — user receive buffer (aliased in/out)
    n_valid: scalar              — staged entries [0, n_valid) are valid

    Returns (user', bitmap) where bitmap (n_chunks,) uint32 has 1 for every
    chunk written in this batch.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n_staged, chunk = staging.shape
    n_chunks = user.shape[0]
    if n_valid is None:
        n_valid = n_staged
    valid = jnp.full((1, 1), n_valid, jnp.int32)
    bitmap0 = jnp.zeros((n_chunks, 1), jnp.uint32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_staged,),
        in_specs=[
            pl.BlockSpec((1, chunk), lambda i, psn: (i, 0)),           # staging
            pl.BlockSpec((1, chunk), lambda i, psn: (psn[i], 0)),      # user in
            pl.BlockSpec((1, 1), lambda i, psn: (0, 0),
                         memory_space=pltpu.SMEM),                      # n_valid
        ],
        out_specs=[
            pl.BlockSpec((1, chunk), lambda i, psn: (psn[i], 0)),      # user out
            pl.BlockSpec((1, 1), lambda i, psn: (psn[i], 0)),          # bitmap
        ],
    )
    user_out, bitmap = pl.pallas_call(
        _reassembly_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct(user.shape, user.dtype),
            jax.ShapeDtypeStruct((n_chunks, 1), jnp.uint32),
        ],
        input_output_aliases={2: 0},  # user buffer aliased (psn arg is 0)
        interpret=interpret,
    )(psn, staging, user, valid, )
    return user_out, bitmap[:, 0]
