"""Jitted public wrappers for the Pallas kernels (interpret=True on CPU)."""
from __future__ import annotations

import jax

from repro.kernels.bitmap import bitmap_pack, bitmap_popcount
from repro.kernels.chunk_reassembly import chunk_reassembly
from repro.kernels.collective_matmul import (
    allgather_matmul_local,
    make_allgather_matmul,
    matmul_pallas,
)

reassemble = jax.jit(chunk_reassembly, static_argnames=("interpret",))
matmul = jax.jit(
    matmul_pallas, static_argnames=("bm", "bk", "bn", "interpret")
)
pack_bitmap = jax.jit(bitmap_pack, static_argnames=("block_words", "interpret"))
popcount = jax.jit(bitmap_popcount, static_argnames=("block", "interpret"))

__all__ = [
    "allgather_matmul_local",
    "make_allgather_matmul",
    "matmul",
    "matmul_pallas",
    "pack_bitmap",
    "popcount",
    "reassemble",
]
