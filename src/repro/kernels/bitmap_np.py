"""Numpy twins of the kernels/bitmap.py Pallas kernels — jax-free on purpose.

The packet-level protocol engine (core/packet.py) tracks per-receiver
arrival state and builds NACK payloads in the exact packed-u32 wire format
the Pallas kernels consume; importing this module must NOT pull in jax, so
the simulator hot path (and the CI smoke benchmarks) stay numpy-only.
kernels/bitmap.py re-exports these next to the Pallas implementations, and
tests cross-check the two bit-for-bit on the simulator's actual bitmaps.
"""
from __future__ import annotations

import numpy as np


def bitmap_pack_np(flags: np.ndarray) -> np.ndarray:
    """flags (n,) 0/1, n % 32 == 0 -> packed (n/32,) uint32 — bit-identical to
    ``bitmap_pack`` (bit i of word w = flag[32*w + i])."""
    f = np.asarray(flags, dtype=np.uint32).reshape(-1, 32)
    shifts = np.arange(32, dtype=np.uint32)
    return np.bitwise_or.reduce(f << shifts, axis=1).astype(np.uint32)


def bitmap_unpack_np(words: np.ndarray, n_chunks: int | None = None) -> np.ndarray:
    """Packed (w,) uint32 -> (32*w,) bool flags (inverse of bitmap_pack_np),
    truncated to ``n_chunks`` when given."""
    w = np.asarray(words, dtype=np.uint32)
    shifts = np.arange(32, dtype=np.uint32)
    flags = ((w[:, None] >> shifts) & 1).astype(bool).reshape(-1)
    return flags if n_chunks is None else flags[:n_chunks]


def bitmap_popcount_np(words: np.ndarray) -> int:
    """Total set bits across packed u32 words (matches ``bitmap_popcount``)."""
    w = np.asarray(words, dtype=np.uint32)
    if hasattr(np, "bitwise_count"):          # numpy >= 2.0
        return int(np.bitwise_count(w).sum())
    return int(bitmap_unpack_np(w).sum())


def bitmap_pack_rows_np(flags: np.ndarray) -> np.ndarray:
    """Row-batched bitmap_pack_np: flags (r, n) 0/1 with n % 32 == 0 ->
    packed (r, n/32) uint32 — each row bit-identical to bitmap_pack_np of
    that row (the vectorized packet engine packs every leaf's NACK bitmap
    in one call and OR-reduces the rows for the aggregated union)."""
    f = np.asarray(flags, dtype=np.uint32)
    assert f.ndim == 2 and f.shape[1] % 32 == 0, f.shape
    f = f.reshape(f.shape[0], -1, 32)
    shifts = np.arange(32, dtype=np.uint32)
    return np.bitwise_or.reduce(f << shifts, axis=2).astype(np.uint32)


def bitmap_popcount_rows_np(words: np.ndarray) -> np.ndarray:
    """Per-row set-bit counts over packed (r, w) uint32 rows — each entry
    equals bitmap_popcount_np of that row."""
    w = np.asarray(words, dtype=np.uint32)
    assert w.ndim == 2, w.shape
    if hasattr(np, "bitwise_count"):          # numpy >= 2.0
        return np.bitwise_count(w).sum(axis=1).astype(np.int64)
    shifts = np.arange(32, dtype=np.uint32)
    bits = ((w[:, :, None] >> shifts) & 1).astype(np.int64)
    return bits.sum(axis=(1, 2))
