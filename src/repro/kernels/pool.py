"""Pool-completion scan kernel — the T-server leaf receive pool (paper §V).

The deterministic-service worker pool obeys, per worker residue class
mod W,

    done_i = max(a_i, done_{i-W}) + s  =  (i+1)s + max_{j<=i}(a_j - j*s)

With the (rows, n) arrival matrix padded to a multiple of W and viewed as
(rows, n/W, W), every residue class becomes a VPU lane and the recurrence
is ONE running-max scan along the middle axis — the residue-class-parallel
scan. The kernel tiles rows into VMEM blocks and walks the scan axis with
a ``fori_loop`` carrying the per-lane running max; rows x W lanes advance
in parallel each step.

The ``*_np`` twins (kernels/pool_np.py, re-exported here) are the
bit-identical numpy references over the SAME (rows, n/W, W) layout. They
are what core/engine.worker_pool_completion_rows actually runs — the
packet-engine hot path must stay jax-free — and tests cross-check the two
implementations on the simulator's actual arrival matrices
(tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.pool_np import (  # noqa: F401  (re-exported twins)
    pool_completion_rows_np,
    pool_rnr_mask_rows_np,
    pool_scan_rows_np,
)


def _scan_kernel(a_ref, o_ref, *, service):
    br, n_per, w = a_ref.shape
    dt = a_ref.dtype
    s = jnp.asarray(service, dt)

    def body(i, carry):
        fi = i.astype(dt)
        row = a_ref[:, pl.ds(i, 1), :].reshape(br, w) - fi * s
        m = jnp.maximum(carry, row)
        o_ref[:, pl.ds(i, 1), :] = (m + (fi + 1.0) * s).reshape(br, 1, w)
        return m

    init = jnp.full((br, w), -jnp.inf, dt)
    jax.lax.fori_loop(0, n_per, body, init)


def pool_scan_rows(arrivals: jax.Array, n_workers: int, service: float, *,
                   block_rows: int = 8,
                   interpret: bool | None = None) -> jax.Array:
    """(R, n) sorted arrival rows -> (R, n) pool completion times under a
    W-worker deterministic-service pool. Trailing +inf padding (ragged
    rows) comes back +inf. Mirrors pool_scan_rows_np lane for lane."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    rows, n = arrivals.shape
    if rows == 0 or n == 0:
        return jnp.empty_like(arrivals)
    w = max(int(n_workers), 1)
    pad_c = (-n) % w
    n_per = (n + pad_c) // w
    br = min(block_rows, rows)
    pad_r = (-rows) % br
    a = arrivals
    if pad_c or pad_r:
        a = jnp.pad(a, ((0, pad_r), (0, pad_c)),
                    constant_values=jnp.inf)
    a3 = a.reshape(rows + pad_r, n_per, w)
    done = pl.pallas_call(
        functools.partial(_scan_kernel, service=float(service)),
        grid=((rows + pad_r) // br,),
        in_specs=[pl.BlockSpec((br, n_per, w), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((br, n_per, w), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((rows + pad_r, n_per, w),
                                       arrivals.dtype),
        interpret=interpret,
    )(a3)
    return done.reshape(rows + pad_r, n_per * w)[:rows, :n]


def pool_completion_rows(arrivals: jax.Array, n_workers: int, service: float,
                         staging: int, *, block_rows: int = 8,
                         interpret: bool | None = None,
                         ) -> tuple[jax.Array, jax.Array]:
    """Scan + staging-ring RNR mask — the accelerator twin of
    engine.worker_pool_completion_rows (same drop rule: chunk k is dropped
    when the chunk ``staging`` places ahead is still unserviced at k's
    arrival; padded columns come back +inf / False)."""
    done = pool_scan_rows(arrivals, n_workers, service,
                          block_rows=block_rows, interpret=interpret)
    n = arrivals.shape[1]
    mask = jnp.zeros(arrivals.shape, dtype=bool)
    if n > staging:
        mask = mask.at[:, staging:].set(
            done[:, : n - staging] > arrivals[:, staging:])
    return done, mask
