"""Bitmap kernels — the protocol's reliability state (paper §III-C, Fig. 7).

The bitmap is the only protocol state that grows with the receive buffer
(1 bit per MTU chunk; 1.5 MB LLC addresses ~50 GB). Two kernels:

  - ``bitmap_pack``: pack per-chunk received flags (u32 0/1) into u32 words
    (32 chunks/word), tiled so each grid step packs a VMEM block.
  - ``bitmap_popcount``: count set bits per word block (completeness check —
    the "all chunks received -> final handshake" predicate).

The ``*_np`` twins (kernels/bitmap_np.py, re-exported here) are bit-identical
numpy references over the SAME packed u32 word format. They exist so the
packet-level protocol engine (core/packet.py) can track per-receiver arrival
state and build NACK payloads in the exact wire format the Pallas kernels
consume, without a jax dependency on the simulator hot path (core/packet.py
imports them from bitmap_np directly); tests cross-check the two
implementations on the simulator's actual bitmaps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bitmap_np import (  # noqa: F401  (re-exported twins)
    bitmap_pack_np,
    bitmap_pack_rows_np,
    bitmap_popcount_np,
    bitmap_popcount_rows_np,
    bitmap_unpack_np,
)


def _pack_kernel(flags_ref, words_ref):
    f = flags_ref[...]                       # (bw, 32) u32 0/1
    shifts = jax.lax.broadcasted_iota(jnp.uint32, f.shape, 1)
    words_ref[...] = jnp.sum(f << shifts, axis=1, dtype=jnp.uint32)[:, None]


def bitmap_pack(flags: jax.Array, *, block_words: int = 256,
                interpret: bool | None = None) -> jax.Array:
    """flags (n,) uint32 in {0,1}, n % 32 == 0 -> packed (n/32,) uint32."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n = flags.shape[0]
    assert n % 32 == 0
    nw = n // 32
    bw = min(block_words, nw)
    assert nw % bw == 0
    f2 = flags.reshape(nw, 32)
    packed = pl.pallas_call(
        _pack_kernel,
        grid=(nw // bw,),
        in_specs=[pl.BlockSpec((bw, 32), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bw, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nw, 1), jnp.uint32),
        interpret=interpret,
    )(f2)
    return packed[:, 0]


def _popcount_kernel(words_ref, out_ref):
    w = words_ref[...].astype(jnp.uint32)
    # SWAR popcount
    w = w - ((w >> 1) & jnp.uint32(0x55555555))
    w = (w & jnp.uint32(0x33333333)) + ((w >> 2) & jnp.uint32(0x33333333))
    w = (w + (w >> 4)) & jnp.uint32(0x0F0F0F0F)
    cnt = (w * jnp.uint32(0x01010101)) >> 24
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[0, 0] = jnp.uint32(0)

    out_ref[0, 0] += jnp.sum(cnt, dtype=jnp.uint32)


def bitmap_popcount(words: jax.Array, *, block: int = 1024,
                    interpret: bool | None = None) -> jax.Array:
    """Total set bits across packed u32 words (scalar uint32)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n = words.shape[0]
    b = min(block, n)
    assert n % b == 0
    out = pl.pallas_call(
        _popcount_kernel,
        grid=(n // b,),
        in_specs=[pl.BlockSpec((b, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.uint32),
        interpret=interpret,
    )(words[:, None])
    return out[0, 0]
