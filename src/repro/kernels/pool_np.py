"""Numpy twin of the kernels/pool.py Pallas pool-completion scan — jax-free.

The T-server deterministic-service pool (paper §V, the leaf receive path)
obeys, per worker residue class mod W,

    done_i = max(a_i, done_{i-W}) + s  =  (i+1)s + max_{j<=i}(a_j - j*s)

— a running max per class. The row-at-a-time engine path used to walk the
W classes with fancy-index gathers/scatters per class; on the dense
allgather regime (hundreds of leaf rows x tens of thousands of merged
chunks) that strided traffic made the vectorized packet engine ~0.7x the
per-leaf reference (DESIGN §9). Here the W classes are laid side by side
instead: pad each row to a multiple of W with +inf, view it as
(rows, n/W, W), and run ONE ``np.maximum.accumulate`` over the class axis
— every residue class scans in parallel lanes of the same pass
(residue-class-parallel scan). Row blocks bound the temporaries so the
scan stays cache-resident on big matrices.

Bit-exactness: element (k, i, r) sees exactly the float ops of the old
per-class pass — subtract ``i*service``, running max (exact, no
rounding), add ``(i+1.0)*service`` — in the same left-to-right order per
class, and the trailing +inf padding sits at the END of every class's
sequence so the accumulate never feeds it back into a real entry.
core/engine.py's ``worker_pool_completion_rows`` delegates its inner path
here (tests/test_engine.py + tests/test_packet_vectorized.py pin the
equivalence); importing this module must NOT pull in jax so the packet
hot path stays numpy-only. kernels/pool.py mirrors the same scan as a
Pallas kernel and re-exports these twins.
"""
from __future__ import annotations

import numpy as np

#: row-block size cap: 2 temporaries x block_rows x n_cols f64 stay within
#: a few MiB of L2 for the dense-regime column counts (~16k)
_BLOCK_ROW_ELEMS = 1 << 21


def pool_scan_rows_np(arrivals: np.ndarray, n_workers: int,
                      service: float) -> np.ndarray:
    """Pool completion times for (R, n) sorted arrival rows under a W-worker
    deterministic-service pool: the residue-class-parallel scan. Padded
    (+inf) trailing entries come back +inf. Bit-exact per row with
    ``worker_pool_completion``'s per-class passes."""
    assert arrivals.ndim == 2, arrivals.shape
    arrivals = np.asarray(arrivals, dtype=np.float64)   # scan runs in f64
    rows, n = arrivals.shape
    if n == 0:
        return np.empty_like(arrivals)
    w = max(int(n_workers), 1)
    pad = (-n) % w
    n_per = (n + pad) // w
    done = np.empty((rows, n), dtype=np.float64)
    i3 = np.arange(n_per, dtype=float)[None, :, None]
    shift = i3 * service
    unshift = (i3 + 1.0) * service
    blk = max(1, _BLOCK_ROW_ELEMS // max(n, 1))
    scratch = (np.empty((min(blk, rows), n_per * w)) if pad else None)
    for r0 in range(0, rows, blk):
        r1 = min(r0 + blk, rows)
        if pad:
            buf = scratch[: r1 - r0]
            buf[:, :n] = arrivals[r0:r1]
            buf[:, n:] = np.inf
        else:
            # the output rows double as the workspace: subtract, scan and
            # un-shift all run in place on the (block, n/W, W) view
            buf = done[r0:r1]
            buf[:] = arrivals[r0:r1]
        b3 = buf.reshape(r1 - r0, n_per, w)
        np.subtract(b3, shift, out=b3)
        np.maximum.accumulate(b3, axis=1, out=b3)
        np.add(b3, unshift, out=b3)
        if pad:
            done[r0:r1] = buf[:, :n]
    return done


def pool_rnr_mask_rows_np(done: np.ndarray, arrivals: np.ndarray,
                          staging: int) -> np.ndarray:
    """Row-batched staging-ring (RNR) overflow rule: chunk k is dropped when
    the chunk ``staging`` places ahead is still unserviced at k's arrival —
    the same predicate as core/engine.staging_rnr_mask, per row. Padded
    (+inf) columns come back False (inf > inf is False)."""
    mask = np.zeros(arrivals.shape, dtype=bool)
    n = arrivals.shape[1]
    if n > staging:
        mask[:, staging:] = done[:, : n - staging] > arrivals[:, staging:]
    return mask


def pool_completion_rows_np(arrivals: np.ndarray, n_workers: int,
                            service: float, staging: int,
                            ) -> tuple[np.ndarray, np.ndarray]:
    """Scan + RNR mask in one call — the inner path behind
    core/engine.worker_pool_completion_rows."""
    done = pool_scan_rows_np(arrivals, n_workers, service)
    return done, pool_rnr_mask_rows_np(done, arrivals, staging)
