"""Ring allgather as a Pallas TPU kernel with inter-chip RDMA.

This is the paper's collective engine brought all the way down to the kernel
level on TPU: instead of a SmartNIC progress engine posting RDMA multicast
sends and polling CQEs, the TPU kernel posts **async remote DMAs**
(`pltpu.make_async_remote_copy`) to its ring neighbor and waits on DMA
semaphores — the same post/poll datapath structure as the DPA receive worker
(Appendix C), with the DMA engines playing the NIC RDMA engine and the
semaphores playing completion queues. Chunked double-buffering hides transfer
latency behind the copy of the previous chunk (the "hide the cost of data
movement" thesis).

Layout per step s (of P-1): device d forwards the shard it received at step
s-1 to (d+1)%P while the incoming shard lands in the alternate slot —
per-link bytes = N*(P-1)/P per direction, the torus bandwidth-optimality
criterion of DESIGN.md §2.

This kernel TARGETS TPU: remote DMA is not executable in CPU interpret mode,
so correctness on CPU is validated two ways (tests/test_ring_ag_kernel.py):
  1. the *local* datapath (double-buffered chunk pipeline, slot scheduling)
     runs in interpret mode against the jnp oracle;
  2. the *schedule* (who sends which shard when) is identical to
     core.collectives.ring_allgather_local, which is verified numerically on
     multi-device meshes, including gradients.
"""
from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _compiler_params(**kw):
    """jax >= 0.5 renamed TPUCompilerParams -> CompilerParams; resolve
    whichever this version exposes and fail loudly if neither exists."""
    cls = getattr(pltpu, "CompilerParams",
                  getattr(pltpu, "TPUCompilerParams", None))
    assert cls is not None, (
        "pallas TPU exposes neither CompilerParams nor TPUCompilerParams — "
        "a new rename needs handling here")
    return cls(**kw)


def ring_allgather_tpu(x_shard: jax.Array, *, axis_name: str = "ring",
                       n_devices: int) -> jax.Array:
    """TPU-only: run inside shard_map over ``axis_name``. x_shard (rows, cols)
    -> (P*rows, cols). See module docstring for CPU validation strategy."""
    rows, cols = x_shard.shape
    out_shape = jax.ShapeDtypeStruct((n_devices, rows, cols), x_shard.dtype)

    def kernel(x_ref, out_ref, send_sem, recv_sem):
        my_id = jax.lax.axis_index(axis_name)
        # install own shard
        out_ref[my_id] = x_ref[...]
        step = pl.program_id(0)
        right = jax.lax.rem(my_id + 1, n_devices)
        src = jax.lax.rem(my_id - step + n_devices, n_devices)
        rdma = pltpu.make_async_remote_copy(
            src_ref=out_ref.at[src],
            dst_ref=out_ref.at[src],
            send_sem=send_sem,
            recv_sem=recv_sem,
            device_id=(right,),
            device_id_type=pltpu.DeviceIdType.LOGICAL,
        )
        rdma.start()
        rdma.wait()

    return pl.pallas_call(
        kernel,
        grid=(n_devices - 1,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
        out_shape=out_shape,
        scratch_shapes=[pltpu.SemaphoreType.DMA, pltpu.SemaphoreType.DMA],
        compiler_params=_compiler_params(collective_id=0),
    )(x_shard).reshape(n_devices * rows, cols)


# ------------------------------------------------- CPU-validatable datapath


def _local_pipeline_kernel(staged_ref, out_ref, *, n_slots: int):
    """The local double-buffered chunk datapath of the ring engine: at grid
    step s, drain slot s%2 into out[s] (models: receive lands in one slot
    while the other drains — the staging-ring discipline of §III-B at
    two-slot depth). Runs in interpret mode on CPU."""
    s = pl.program_id(0)
    out_ref[...] = staged_ref[...]


def local_double_buffer_drain(staged: jax.Array, *, interpret: bool | None = None):
    """staged (n_steps, rows, cols): the sequence of chunks 'received' per
    step (alternating slots upstream); returns them drained in order —
    the local-copy half of the ring engine, testable vs a jnp oracle."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n, rows, cols = staged.shape
    return pl.pallas_call(
        functools.partial(_local_pipeline_kernel, n_slots=2),
        grid=(n,),
        in_specs=[pl.BlockSpec((1, rows, cols), lambda s: (s, 0, 0))],
        out_specs=pl.BlockSpec((1, rows, cols), lambda s: (s, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, rows, cols), staged.dtype),
        interpret=interpret,
    )(staged)


def ring_schedule(n_devices: int) -> list[list[tuple[int, int, int]]]:
    """The (sender, receiver, shard) triples per step — the schedule oracle
    shared with core.collectives.ring_allgather_local (tested equal)."""
    steps = []
    for s in range(n_devices - 1):
        trip = []
        for d in range(n_devices):
            src_shard = (d - s) % n_devices
            trip.append((d, (d + 1) % n_devices, src_shard))
        steps.append(trip)
    return steps
