from repro.optim.adamw import (
    OptState,
    apply_updates,
    clip_by_global_norm,
    global_norm,
    init,
    lr_schedule,
)

__all__ = [
    "OptState",
    "apply_updates",
    "clip_by_global_norm",
    "global_norm",
    "init",
    "lr_schedule",
]
