"""Sharded AdamW with global-norm clipping and warmup-cosine schedule.

Optimizer state (m, v) inherits the parameter sharding (ZeRO: the sharded
moments live next to the sharded params; no extra collectives beyond the
grad reduce-scatter that AD already emits).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class OptState(NamedTuple):
    m: Any
    v: Any
    step: jax.Array


def init(params, dtype=jnp.float32) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, dtype)
    return OptState(
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
        step=jnp.zeros((), jnp.int32),
    )


def lr_schedule(step, tc: TrainConfig):
    warm = jnp.minimum(step / jnp.maximum(tc.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - tc.warmup_steps) / jnp.maximum(tc.steps - tc.warmup_steps, 1), 0.0, 1.0
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return tc.learning_rate * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jax.Array:
    sq = sum(
        jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(tree)
    )
    return jnp.sqrt(sq)


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


def apply_updates(params, grads, opt: OptState, tc: TrainConfig):
    """One AdamW step. Returns (new_params, new_opt, metrics)."""
    grads, gn = clip_by_global_norm(grads, tc.grad_clip)
    step = opt.step + 1
    lr = lr_schedule(step, tc)
    b1, b2 = tc.beta1, tc.beta2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * jnp.square(g32)
        mh = m / c1
        vh = v / c2
        delta = mh / (jnp.sqrt(vh) + tc.eps) + tc.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt.m)
    flat_v = jax.tree.leaves(opt.v)
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        np_, nm, nv = upd(p, g, m, v)
        new_p.append(np_)
        new_m.append(nm)
        new_v.append(nv)
    return (
        jax.tree.unflatten(treedef, new_p),
        OptState(jax.tree.unflatten(treedef, new_m), jax.tree.unflatten(treedef, new_v), step),
        {"grad_norm": gn, "lr": lr},
    )
