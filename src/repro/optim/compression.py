"""Gradient compression for the reduce-scatter path, with error feedback.

At 1000+ nodes the grad reduce-scatter moves 4 bytes/param (f32) per step;
8-bit block-quantized compression cuts the RS stream 4x at equal step count
when paired with error feedback (the residual of each quantization step is
carried and added to the next gradient — the standard EF-SGD construction,
which keeps convergence unbiased-in-the-limit).

Usage: wrap the grads between backward and the optimizer:

    comp, state = make_compressor(params, block=256)
    grads_c, state = comp(grads, state)      # quantize -> dequantize + EF

On a real fleet the quantized payload is what crosses the wire (the RS stream
in CollectiveConfig units); here the compression is numerically faithful so
the roofline credit is bytes/4 on grad_reduce_scatter.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _quant_block_int8(x: jax.Array, block: int):
    """Blockwise symmetric int8: returns (q int8, scale f32 per block)."""
    n = x.size
    pad = (-n) % block
    flat = jnp.pad(x.reshape(-1), (0, pad)).reshape(-1, block)
    scale = jnp.max(jnp.abs(flat), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    return q, scale, n


def _dequant_block_int8(q, scale, n, shape):
    return (q.astype(jnp.float32) * scale).reshape(-1)[:n].reshape(shape)


def compress_leaf(g: jax.Array, err: jax.Array, block: int):
    """One EF-compressed round trip: returns (g_hat, new_err)."""
    g32 = g.astype(jnp.float32) + err
    q, scale, n = _quant_block_int8(g32, block)
    g_hat = _dequant_block_int8(q, scale, n, g32.shape)
    return g_hat.astype(g.dtype), (g32 - g_hat)


def make_compressor(params, *, block: int = 256):
    """Returns (compress_fn, zero_error_state)."""
    err0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def compress(grads, err_state):
        out = jax.tree.map(
            lambda g, e: compress_leaf(g, e, block), grads, err_state
        )
        g_hat = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree.map(lambda t: t[1], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        return g_hat, new_err

    return compress, err0


def compression_ratio(dtype_bits: int = 32, block: int = 256) -> float:
    """Wire bytes ratio: int8 payload + one f32 scale per block."""
    return dtype_bits / (8 + 32 / block)
