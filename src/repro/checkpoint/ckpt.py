"""Sharded checkpointing with async save and elastic restore.

Layout: <dir>/step_<N>/
    manifest.msgpack.zst   — tree structure, shapes, dtypes, step, metadata
    arrays.npz             — one entry per leaf (host-gathered)

Restore accepts a different mesh than the one that saved (elastic scaling):
arrays are loaded host-side and re-placed with the target sharding. Saves are
atomic (write to .tmp, rename) so a crash mid-save never corrupts the latest
checkpoint — the fault-tolerance loop (runtime/fault.py) relies on this.
"""
from __future__ import annotations

import concurrent.futures as cf
import os
import shutil
from typing import Any

import zlib

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard
except ImportError:          # offline / minimal image: stdlib fallback
    zstandard = None

_EXEC = cf.ThreadPoolExecutor(max_workers=2)

# Manifest codec framing: one format byte ahead of the compressed blob so a
# checkpoint written with either codec restores correctly on any machine.
# Legacy (pre-framing) manifests are raw zstd, whose magic starts with 0x28.
_CODEC_ZSTD = 0x01
_CODEC_ZLIB = 0x02


def _compress_manifest(payload: bytes) -> bytes:
    if zstandard is not None:
        return bytes([_CODEC_ZSTD]) + zstandard.ZstdCompressor().compress(payload)
    return bytes([_CODEC_ZLIB]) + zlib.compress(payload, level=6)


def _decompress_manifest(blob: bytes) -> bytes:
    if not blob:
        raise ValueError("empty checkpoint manifest")
    codec, body = blob[0], blob[1:]
    if codec == _CODEC_ZLIB:
        return zlib.decompress(body)
    if codec == _CODEC_ZSTD or codec == 0x28:   # 0x28: legacy raw zstd frame
        if zstandard is None:
            raise ImportError(
                "checkpoint manifest is zstd-compressed but the 'zstandard' "
                "module is not installed; reinstall it or re-save the "
                "checkpoint on a machine with zstandard available"
            )
        body = blob if codec == 0x28 else body
        return zstandard.ZstdDecompressor().decompress(body)
    raise ValueError(f"unknown checkpoint manifest codec byte {codec:#x}")


def _tree_paths(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "name", k))) for k in path)
        out.append((key, leaf))
    return out


def save(state, directory: str, step: int, *, blocking: bool = True,
         metadata: dict | None = None):
    """Checkpoint ``state`` (pytree). Returns a future if blocking=False."""
    leaves = _tree_paths(state)
    host = {k: np.asarray(jax.device_get(v)) for k, v in leaves}
    treedef = jax.tree.structure(state)

    def _write():
        final = os.path.join(directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        # bf16 -> uint16-view for npz portability
        arrs, dtypes = {}, {}
        for k, v in host.items():
            dtypes[k] = str(v.dtype)
            arrs[k.replace("/", "%")] = (
                v.view(np.uint16) if v.dtype == jnp.bfloat16 else v
            )
        np.savez(os.path.join(tmp, "arrays.npz"), **arrs)
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "keys": [k for k, _ in leaves],
            "dtypes": dtypes,
            "metadata": metadata or {},
        }
        blob = _compress_manifest(msgpack.packb(manifest))
        with open(os.path.join(tmp, "manifest.msgpack.zst"), "wb") as f:
            f.write(blob)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        return final

    if blocking:
        return _write()
    return _EXEC.submit(_write)


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore(directory: str, step: int, like, *, shardings=None):
    """Load into the structure of ``like`` (values ignored). ``shardings`` may
    target a different mesh than the saver's (elastic restore)."""
    final = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(final, "manifest.msgpack.zst"), "rb") as f:
        manifest = msgpack.unpackb(_decompress_manifest(f.read()))
    npz = np.load(os.path.join(final, "arrays.npz"))
    arrays = {}
    for key, dtype in manifest["dtypes"].items():
        raw = npz[key.replace("/", "%")]
        if dtype == "bfloat16":
            raw = raw.view(jnp.bfloat16)
        arrays[key] = raw

    flat_like = _tree_paths(like)
    flat_sh = _tree_paths(shardings) if shardings is not None else None
    leaves = []
    for i, (key, leaf) in enumerate(flat_like):
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = arrays[key]
        want = tuple(leaf.shape)
        if tuple(arr.shape) != want:
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != {want}")
        if flat_sh is not None:
            leaves.append(jax.device_put(arr, flat_sh[i][1]))
        else:
            leaves.append(jnp.asarray(arr))
    return jax.tree.unflatten(jax.tree.structure(like), leaves), manifest
