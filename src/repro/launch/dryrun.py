import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (architecture x input-shape x mesh) cell
lowers, SPMD-partitions, and compiles on the production mesh, and extract the
roofline inputs from the compiled artifact.

The two lines above MUST precede every other import (jax locks the device
count at first init). Do NOT set this flag anywhere global.

Per cell this emits a JSON record with:
  - compiled.memory_analysis()  (fits-in-HBM proof)
  - compiled.cost_analysis()    (raw; loop bodies counted once — cross-check)
  - HLO-parsed collective bytes (launch/hlo_stats.py, loop-scaled)
  - analytic compute/memory/collective models (launch/analytic_costs.py)

Usage:
  python -m repro.launch.dryrun --arch yi-9b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod] --out dryrun_results/
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import (  # noqa: E402
    SHAPES,
    cell_supported,
    get_model_config,
    iter_cells,
    make_run_config,
)
from repro.launch import analytic_costs, hlo_stats  # noqa: E402
from repro.launch.mesh import describe, make_production_mesh  # noqa: E402
from repro.models import batch_dims  # noqa: E402


def _batch_sds(run):
    dims = batch_dims(run.model, run.shape)
    out = {}
    for name, shp in dims.items():
        if name in ("tokens", "targets", "token", "pos"):
            out[name] = jax.ShapeDtypeStruct(shp, jnp.int32)
        else:
            out[name] = jax.ShapeDtypeStruct(shp, jnp.bfloat16)
    return out


def lower_cell(run, mesh):
    """Returns (lowered, loop_chain) for the cell's step function."""
    kind = run.shape.kind
    if kind == "train":
        from repro.runtime.train_loop import abstract_state, jit_train_step

        api, step = jit_train_step(run, mesh)
        state = abstract_state(run)
        lowered = step.lower(state, _batch_sds(run))
        chain = (run.model.num_layers,)
        if run.train.grad_accum > 1:
            chain = (run.train.grad_accum, run.model.num_layers)
        return lowered, chain
    if kind == "prefill":
        from repro.runtime.serve_loop import jit_prefill_step

        api, step = jit_prefill_step(run, mesh)
        lowered = step.lower(_abstract_params(run), _batch_sds(run))
        return lowered, (run.model.num_layers,)
    # decode
    from repro.runtime.serve_loop import ServeState, abstract_cache, jit_decode_step

    api, step = jit_decode_step(run, mesh)
    cache = abstract_cache(run)
    b = run.shape.global_batch
    state = ServeState(cache, jax.ShapeDtypeStruct((b,), jnp.int32))
    token = jax.ShapeDtypeStruct((b,), jnp.int32)
    lowered = step.lower(_abstract_params(run), state, token)
    return lowered, (run.model.num_layers,)


def _abstract_params(run):
    from repro.models import build_model

    api = build_model(run.model)
    return jax.eval_shape(api.init_params, jax.random.PRNGKey(0))


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             fsdp_mode: str = "xla", grad_accum: int = 1,
             remat: str = "full", collect_hlo: bool = True,
             mesh_shape: tuple[int, int] | None = None,
             serve_replicate: bool = False,
             moe_groups: int = 0,
             kv_int8: bool = False,
             prefetch: bool = False) -> dict:
    """mesh_shape: regroup the same 256 chips as (dp, tp) — a §Perf knob
    (the mesh shape is a software view of the physical pod)."""
    t_start = time.monotonic()
    run = make_run_config(arch, shape_name, multi_pod=multi_pod)
    model = run.model
    if moe_groups and model.moe is not None:
        model = dataclasses.replace(
            model, moe=dataclasses.replace(model.moe, routing_groups=moe_groups)
        )
    if kv_int8:
        model = dataclasses.replace(model, kv_cache_dtype="int8")
    run = run.replace(
        model=model,
        train=dataclasses.replace(run.train, grad_accum=grad_accum, remat=remat),
        collective=dataclasses.replace(
            run.collective, fsdp_mode=fsdp_mode,
            serve_params_replicated=serve_replicate, prefetch=prefetch,
        ),
    )
    if mesh_shape is not None:
        assert not multi_pod, "mesh regrouping is a single-pod perf knob"
        mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    rec: dict = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "fsdp_mode": fsdp_mode, "grad_accum": grad_accum, "remat": remat,
        "mesh_shape": list(mesh_shape) if mesh_shape else None,
        "serve_replicate": serve_replicate, "moe_groups": moe_groups,
        "mesh": describe(mesh), "ok": False,
    }
    try:
        lowered, chain = lower_cell(run, mesh)
        t_lower = time.monotonic()
        compiled = lowered.compile()
        t_compile = time.monotonic()
        rec["lower_s"] = round(t_lower - t_start, 2)
        rec["compile_s"] = round(t_compile - t_lower, 2)

        try:
            mem = compiled.memory_analysis()
            rec["memory_analysis"] = {
                k: int(getattr(mem, k))
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(mem, k)
            }
        except Exception as e:  # CPU backend may not implement it
            rec["memory_analysis"] = {"error": str(e)}

        try:
            cost = compiled.cost_analysis()
            rec["cost_analysis_raw"] = {
                k: float(v) for k, v in cost.items()
                if isinstance(v, (int, float)) and k in
                ("flops", "bytes accessed", "transcendentals",
                 "utilization operand 0 {}", "optimal_seconds")
            }
        except Exception as e:
            rec["cost_analysis_raw"] = {"error": str(e)}

        if collect_hlo:
            hlo = compiled.as_text()
            st = hlo_stats.collective_stats(hlo, n_dev, loop_chain=chain)
            rec["collectives_hlo"] = st.as_dict()
            rec["hlo_bytes"] = len(hlo)
            del hlo

        # analytic roofline inputs
        cfg, shape = run.model, run.shape
        cc = analytic_costs.cell_cost(
            cfg, shape, n_dev, remat=remat,
            tp=mesh.shape["model"], serve_replicated=serve_replicate,
        )
        tp = mesh.shape["model"]
        dp = n_dev // tp
        epx = 1.0
        if moe_groups and cfg.moe is not None:
            # cross-EP copies per token: bounded by the active group count
            # instead of top_k (DeepSeek-V3 device-limited routing)
            epx = min(cfg.moe.routing_group_topk, cfg.moe.top_k) / cfg.moe.top_k
        cl = analytic_costs.collective_cost(
            cfg, shape, dp=dp, tp=tp, remat=remat, grad_accum=grad_accum,
            ep_crossing_factor=epx, serve_replicated=serve_replicate,
        )
        rec["analytic"] = {
            "model_flops": cc.model_flops,
            "impl_flops": cc.impl_flops,
            "useful_ratio": cc.useful_ratio,
            "hbm_bytes_per_device": cc.hbm_bytes,
            "params_total": cc.params_total,
            "params_active": cc.params_active,
            "collective_bytes_per_device": {
                "fsdp_allgather": cl.fsdp_allgather,
                "grad_reduce_scatter": cl.grad_reduce_scatter,
                "tp_activations": cl.tp_activations,
                "ep_all_to_all": cl.ep_all_to_all,
                "decode_psum": cl.decode_psum,
                "total": cl.total,
            },
        }
        rec["ok"] = True
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.monotonic() - t_start, 2)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--fsdp-mode", default="xla")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--remat", default="full")
    ap.add_argument("--mesh-shape", default=None,
                    help="regroup the pod, e.g. 64x4 (dp x tp)")
    ap.add_argument("--serve-replicate", action="store_true")
    ap.add_argument("--moe-groups", type=int, default=0)
    ap.add_argument("--kv-int8", action="store_true")
    ap.add_argument("--prefetch", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--no-hlo", action="store_true")
    args = ap.parse_args()
    mesh_shape = None
    if args.mesh_shape:
        mesh_shape = tuple(int(x) for x in args.mesh_shape.split("x"))
        assert len(mesh_shape) == 2 and mesh_shape[0] * mesh_shape[1] == 256

    cells = []
    if args.all:
        for arch, shape, ok, why in iter_cells(include_skipped=True):
            if ok:
                cells.append((arch, shape))
            else:
                print(f"SKIP {arch} x {shape}: {why}", flush=True)
    else:
        ok, why = cell_supported(get_model_config(args.arch), SHAPES[args.shape])
        if not ok:
            print(f"SKIP: {why}")
            sys.exit(0)
        cells.append((args.arch, args.shape))

    results = []
    for arch, shape in cells:
        rec = run_cell(
            arch, shape, args.multi_pod,
            fsdp_mode=args.fsdp_mode, grad_accum=args.grad_accum,
            remat=args.remat, collect_hlo=not args.no_hlo,
            mesh_shape=mesh_shape, serve_replicate=args.serve_replicate,
            moe_groups=args.moe_groups, kv_int8=args.kv_int8,
            prefetch=args.prefetch,
        )
        status = "OK" if rec["ok"] else f"FAIL ({rec.get('error', '?')})"
        print(f"[dryrun] {arch} x {shape} multi_pod={args.multi_pod}: {status} "
              f"(lower {rec.get('lower_s')}s compile {rec.get('compile_s')}s)",
              flush=True)
        results.append(rec)
        if args.out:
            os.makedirs(args.out, exist_ok=True)
            tag = f"{arch}__{shape}__{'2pod' if args.multi_pod else '1pod'}"
            variant = []
            if args.fsdp_mode != "xla":
                variant.append(args.fsdp_mode)
            if args.grad_accum != 1:
                variant.append(f"a{args.grad_accum}")
            if args.remat != "full":
                variant.append(args.remat)
            if mesh_shape:
                variant.append(f"m{mesh_shape[0]}x{mesh_shape[1]}")
            if args.serve_replicate:
                variant.append("srvrep")
            if args.moe_groups:
                variant.append(f"g{args.moe_groups}")
            if args.kv_int8:
                variant.append("kvi8")
            if args.prefetch:
                variant.append("pf")
            if variant:
                tag += "__" + "_".join(variant)
            with open(os.path.join(args.out, tag + ".json"), "w") as f:
                json.dump(rec, f, indent=1)
    n_bad = sum(not r["ok"] for r in results)
    print(f"[dryrun] done: {len(results) - n_bad}/{len(results)} OK", flush=True)
    sys.exit(1 if n_bad else 0)


if __name__ == "__main__":
    main()
