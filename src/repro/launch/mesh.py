"""Production mesh construction.

make_production_mesh() is a FUNCTION (not module-level state) so importing
this module never touches jax device initialization. The dry-run entry point
(launch/dryrun.py) sets XLA_FLAGS for 512 placeholder devices before any jax
import; everything else (tests, benches) sees the real device count.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_for(shape: tuple[int, ...], axes: tuple[str, ...]) -> jax.sharding.Mesh:
    return jax.make_mesh(shape, axes)


def describe(mesh: jax.sharding.Mesh) -> dict:
    return {
        "shape": dict(zip(mesh.axis_names, mesh.devices.shape)),
        "n_devices": mesh.devices.size,
        "platform": mesh.devices.reshape(-1)[0].platform,
    }
