from repro.launch.mesh import make_production_mesh
from repro.launch.train_sim import (TPU_V5E, ChipConstants, LayerProfile,
                                    TrainingRunResult, derive_layer_profiles,
                                    make_fabric, simulate_training_run,
                                    sweep_training_runs)

__all__ = [
    "make_production_mesh",
    "TPU_V5E",
    "ChipConstants",
    "LayerProfile",
    "TrainingRunResult",
    "derive_layer_profiles",
    "make_fabric",
    "simulate_training_run",
    "sweep_training_runs",
]
