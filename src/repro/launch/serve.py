"""Serving launcher: batched prefill + decode with sharded KV caches.

    python -m repro.launch.serve --arch smollm-135m --smoke --new-tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    from repro.configs import ShapeConfig, get_model_config, make_run_config, reduced
    from repro.models import build_model, make_dummy_batch
    from repro.runtime.serve_loop import ServeState

    model = get_model_config(args.arch)
    if args.smoke:
        model = reduced(model)
    api = build_model(model)
    rng = jax.random.PRNGKey(0)
    params = api.init_params(rng)

    b, s = args.batch, args.prompt_len
    cache_len = s + args.new_tokens
    tokens = jax.random.randint(rng, (b, s), 0, model.vocab_size, dtype=jnp.int32)

    decode = jax.jit(api.decode_fn, donate_argnums=(1,))
    cache = api.init_cache(b, cache_len)
    pos = jnp.zeros((b,), jnp.int32)
    tok = tokens[:, 0]
    t0 = time.monotonic()
    out = [tok]
    for t in range(1, s + args.new_tokens):
        logits, cache = decode(params, cache, tok, pos + (t - 1))
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        tok = tokens[:, t] if t < s else nxt
        out.append(tok)
    seqs = jnp.stack(out, axis=1)
    dt = time.monotonic() - t0
    total_new = b * args.new_tokens
    print(f"[serve] {model.name}: {b} seqs, {args.prompt_len} prompt + "
          f"{args.new_tokens} new tokens in {dt:.2f}s "
          f"({total_new/dt:.1f} tok/s)", flush=True)
    print("[serve] sample continuation token ids:", seqs[0, s : s + 8].tolist())


if __name__ == "__main__":
    main()
