"""Parse collective traffic out of lowered/compiled HLO text.

cost_analysis() has FLOPs and memory bytes but no collective traffic, so the
roofline's third term comes from here: every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute instruction is converted to
ring-equivalent *per-device ICI bytes*:

    all-gather        (g-1)/g * result_bytes      (result = gathered buffer)
    reduce-scatter    (g-1)   * result_bytes      (input = g * result)
    all-reduce        2 (g-1)/g * result_bytes    (RS + AG)
    all-to-all        (g-1)/g * result_bytes
    collective-permute          result_bytes

The reported "collective_bytes" is the total over devices (per-device x
group-participating devices), matching the roofline convention
T_coll = collective_bytes / (chips * link_bw).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\]\S*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # replica_groups=[n_groups, group_size]<=[...]
        return int(m.group(2))
    return default


@dataclass
class CollectiveStats:
    per_device_bytes: dict[str, int] = field(default_factory=lambda: defaultdict(int))
    total_bytes: int = 0                 # summed over participating devices
    counts: dict[str, int] = field(default_factory=lambda: defaultdict(int))

    def as_dict(self) -> dict:
        return {
            "per_device_bytes": dict(self.per_device_bytes),
            "per_device_total": sum(self.per_device_bytes.values()),
            "total_bytes": self.total_bytes,
            "counts": dict(self.counts),
        }


_OPNAME_RE = re.compile(r'op_name="([^"]*)"')


def _loop_multiplier(line: str, loop_chain: tuple[int, ...]) -> int:
    """XLA cost/HLO text counts while-loop bodies ONCE; collectives inside the
    layer scan (and grad-accum scan) execute trip_count times. The op_name
    metadata preserves the traced scope ("jit(f)/while/body/..."), so the
    nesting depth tells us how many loops enclose the op; the caller passes
    the known loop-length chain outermost-first (e.g. (grad_accum, n_layers)).
    """
    m = _OPNAME_RE.search(line)
    if not m:
        return 1
    depth = m.group(1).count("while/body")
    mult = 1
    for k in range(min(depth, len(loop_chain))):
        mult *= loop_chain[k]
    return mult


def collective_stats(hlo_text: str, n_devices: int,
                     loop_chain: tuple[int, ...] = ()) -> CollectiveStats:
    st = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        result_txt, op, started = m.group(1), m.group(2), m.group(3)
        if started and "-done" in line:
            continue
        rbytes = _shape_bytes(result_txt)
        g = max(_group_size(line, n_devices), 1)
        if op == "all-gather":
            per_dev = rbytes * (g - 1) // max(g, 1)
        elif op == "reduce-scatter":
            per_dev = rbytes * (g - 1)
        elif op == "all-reduce":
            per_dev = 2 * rbytes * (g - 1) // max(g, 1)
        elif op == "all-to-all":
            per_dev = rbytes * (g - 1) // max(g, 1)
        else:  # collective-permute
            per_dev = rbytes
        per_dev *= _loop_multiplier(line, loop_chain)
        st.per_device_bytes[op] += per_dev
        st.total_bytes += per_dev * g if op != "collective-permute" else per_dev * n_devices
        st.counts[op] += 1
    return st


def scan_trip_counts(hlo_text: str) -> list[int]:
    """While-loop trip counts (scan lengths) — collectives inside loops execute
    trip_count times; used to scale per-iteration collective bytes."""
    return [int(x) for x in re.findall(r'trip_count[":\s=]+(\d+)', hlo_text)]
