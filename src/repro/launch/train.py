"""Training launcher.

    python -m repro.launch.train --arch smollm-135m --steps 100 --smoke
    python -m repro.launch.train --arch yi-9b --shape train_4k \
        --mesh production [--multi-pod] --fsdp-mode mcast

--smoke runs the reduced config of the arch on the local devices (CPU-friendly
end-to-end: data pipeline -> FSDP train step -> checkpoint/restart supervisor).
On a real multi-host fleet, set JAX_COORDINATOR/process env and pass
--distributed to jax.distributed.initialize() before mesh construction.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + local devices (CPU demo)")
    ap.add_argument("--mesh", default="local", choices=["local", "production"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--fsdp-mode", default="xla")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--remat", default="full")
    ap.add_argument("--batch", type=int, default=0, help="override global batch")
    ap.add_argument("--seq", type=int, default=0, help="override seq len")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--distributed", action="store_true")
    args = ap.parse_args()

    if args.distributed:
        jax.distributed.initialize()

    from repro.configs import (SHAPES, ShapeConfig, TrainConfig, get_model_config,
                               make_run_config, reduced)
    from repro.data import SyntheticPipeline
    from repro.runtime import init_state, make_train_step
    from repro.runtime.fault import TrainSupervisor

    run = make_run_config(args.arch, args.shape, multi_pod=args.multi_pod)
    model = run.model
    shape = run.shape
    if args.smoke:
        model = reduced(model)
        shape = ShapeConfig(shape.name, shape.kind, args.seq or 128, args.batch or 8)
    elif args.batch or args.seq:
        shape = ShapeConfig(
            shape.name, shape.kind, args.seq or shape.seq_len,
            args.batch or shape.global_batch,
        )
    run = run.replace(
        model=model, shape=shape,
        train=TrainConfig(
            steps=args.steps, grad_accum=args.grad_accum, remat=args.remat,
            checkpoint_dir=args.ckpt_dir, checkpoint_every=args.ckpt_every,
        ),
        collective=dataclasses.replace(run.collective, fsdp_mode=args.fsdp_mode),
    )

    mesh = None
    if args.mesh == "production":
        from repro.launch.mesh import make_production_mesh

        mesh = make_production_mesh(multi_pod=args.multi_pod)
    elif jax.device_count() > 1:
        n = jax.device_count()
        dp = max(1, n // 2)
        mesh = jax.make_mesh((dp, n // dp), ("data", "model"))

    print(f"[train] {model.name} shape={shape.name} B={shape.global_batch} "
          f"S={shape.seq_len} devices={jax.device_count()} "
          f"fsdp={args.fsdp_mode}", flush=True)

    if mesh is not None:
        from repro.runtime.train_loop import jit_train_step

        api, step_fn = jit_train_step(run, mesh)
    else:
        api, ctx, step_raw = make_train_step(run, None)
        step_fn = jax.jit(step_raw)

    state = init_state(run, mesh, jax.random.PRNGKey(run.train.seed))
    pipe = SyntheticPipeline(model, shape)
    sup = TrainSupervisor(
        step_fn=step_fn, pipeline=pipe, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
    )
    state, history = sup.run(state, args.steps)
    for h in history:
        if h["step"] % args.log_every == 0 or h["step"] == args.steps - 1:
            print(f"step {h['step']:5d} loss {h['loss']:.4f} "
                  f"gnorm {h.get('grad_norm', 0):.3f} dt {h['dt']*1e3:.0f}ms",
                  flush=True)
    print(f"[train] done; stragglers flagged: {len(sup.monitor.events)}")


if __name__ == "__main__":
    main()
