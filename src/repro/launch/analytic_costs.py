"""Exact analytic FLOP / HBM-byte models per (arch x shape) cell.

Why analytic: XLA's cost_analysis() counts while-loop bodies ONCE (verified
empirically — a 10-step scanned matmul reports ~1 matmul of FLOPs), and every
model here is a scan over layers with scans inside (attention blocks, WKV
chunks, xent chunks). The roofline's compute/memory terms therefore come from
these first-principles formulas (the standard way LLM rooflines are built);
the raw cost_analysis numbers are recorded alongside as a cross-check, and
collective bytes come from the partitioned HLO (launch/hlo_stats.py).

Conventions:
  - FLOPs count multiply+add as 2.
  - train step FLOPs = fwd * (1 + 2) (+1 extra fwd when remat="full").
  - causal attention counts the lower triangle only as "useful"
    (MODEL_FLOPS); the baseline blockwise implementation actually computes
    the full masked rectangle — reported as compute_waste so the §Perf
    iteration can drive it down and be measured against a fixed target.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import count_params_analytic


@dataclass(frozen=True)
class CellCost:
    model_flops: float          # useful FLOPs (6*N*D + exact causal attention)
    impl_flops: float           # what the implementation actually executes
    hbm_bytes: float            # per-device HBM traffic per step
    params_total: int
    params_active: int

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / max(self.impl_flops, 1.0)


def _attn_flops(cfg: ModelConfig, s: int, *, causal_frac: float) -> float:
    return 4.0 * s * s * cfg.num_heads * cfg.head_dim * causal_frac


def _attn_impl_flops(cfg: ModelConfig, s: int) -> float:
    """Blockwise attention computes the full masked rectangle (window layers
    slice a fixed kv span instead)."""
    win = cfg.attn_window or (cfg.rglru.window if cfg.rglru else None)
    if win is not None:
        span = min(win + cfg.attn_q_block, s)
        return 4.0 * s * span * cfg.num_heads * cfg.head_dim
    return 4.0 * s * s * cfg.num_heads * cfg.head_dim


def _layer_linear_flops_per_tok(cfg: ModelConfig) -> float:
    """All per-token matmul FLOPs of one layer (= 6 * params_layer / ... kept
    explicit per family)."""
    d, hd = cfg.d_model, cfg.head_dim
    if cfg.family in ("dense", "vlm", "encdec"):
        attn = 2 * d * (cfg.num_heads * hd) * 2 + 2 * d * (cfg.num_kv_heads * hd) * 2
        mlp = (6 if cfg.act == "swiglu" else 4) * d * cfg.d_ff
        return attn + mlp
    if cfg.family == "moe":
        m = cfg.moe
        attn = 2 * d * (cfg.num_heads * hd) * 2 + 2 * d * (cfg.num_kv_heads * hd) * 2
        router = 2 * d * m.n_routed_experts
        routed = 6 * d * m.d_ff_expert * m.top_k
        shared = 6 * d * m.d_ff_expert * m.n_shared_experts
        return attn + router + routed + shared
    if cfg.family == "rwkv":
        tm = 2 * d * d * 5  # r,k,v,g,o projections
        lora = 2 * d * (5 * cfg.rwkv.tokenshift_lora) * 2 + 2 * d * cfg.rwkv.decay_lora * 2
        c = cfg.rwkv.chunk_size
        wkv = 4 * d * (c + hd)  # intra-chunk scores/outputs + state terms
        cm = 2 * d * cfg.d_ff * 2 + 2 * d * d
        return tm + lora + wkv + cm
    if cfg.family == "hybrid":
        # averaged over the (rec, rec, attn) pattern
        w = cfg.rglru.lru_width
        rec = 2 * d * w * 2 + 2 * w * w * 2 + 2 * w * d + 2 * cfg.rglru.conv_width * w
        att = 2 * d * (cfg.num_heads * hd) * 2 + 2 * d * (cfg.num_kv_heads * hd) * 2
        mlp = 6 * d * cfg.d_ff
        n_rec = 2 * (cfg.num_layers // 3) + cfg.num_layers % 3
        n_att = cfg.num_layers // 3
        return ((rec + mlp) * n_rec + (att + mlp) * n_att) / cfg.num_layers
    raise ValueError(cfg.family)


def _n_layers_eff(cfg: ModelConfig) -> int:
    if cfg.family == "encdec":
        return cfg.encdec.enc_layers + cfg.encdec.dec_layers
    return cfg.num_layers


def _fwd_flops(cfg: ModelConfig, s: int, batch: int) -> tuple[float, float]:
    """(useful, implemented) forward FLOPs for a length-s batch."""
    toks = batch * s
    l = _n_layers_eff(cfg)
    lin = _layer_linear_flops_per_tok(cfg) * toks * l
    head = 2.0 * cfg.d_model * cfg.vocab_size * toks
    if cfg.family == "rwkv":
        return lin + head, lin + head
    if cfg.family == "hybrid":
        n_att = cfg.num_layers // 3
        att_use = _attn_flops(cfg, s, causal_frac=0.5) * batch * n_att
        att_impl = _attn_impl_flops(cfg, s) * batch * n_att
        # window attention useful = min(window, s)-bounded triangle
        w = cfg.rglru.window
        att_use = 4.0 * s * min(w, s) * cfg.num_heads * cfg.head_dim * 0.5 * batch * n_att
        return lin + head + att_use, lin + head + att_impl
    if cfg.family == "encdec":
        le, ld = cfg.encdec.enc_layers, cfg.encdec.dec_layers
        self_use = _attn_flops(cfg, s, causal_frac=1.0) * batch * le  # non-causal enc
        self_use += _attn_flops(cfg, s, causal_frac=0.5) * batch * ld
        cross = _attn_flops(cfg, s, causal_frac=1.0) * batch * ld
        impl = (
            _attn_impl_flops(cfg, s) * batch * (le + ld) + cross
            + 2 * cfg.d_model * (cfg.num_kv_heads * cfg.head_dim) * 2 * toks * ld
        )
        use = self_use + cross
        return lin + head + use, lin + head + impl
    att_use = _attn_flops(cfg, s, causal_frac=0.5) * batch * l
    att_impl = _attn_impl_flops(cfg, s) * batch * l
    return lin + head + att_use, lin + head + att_impl


def _cache_bytes(cfg: ModelConfig, s: int, batch: int) -> float:
    bpe = 2.0  # bf16
    if cfg.family == "rwkv":
        return cfg.num_layers * batch * (
            cfg.num_heads * cfg.head_dim * cfg.head_dim * 4.0 + 2 * cfg.d_model * bpe
        )
    if cfg.family == "hybrid":
        ng = cfg.num_layers // 3
        win = min(cfg.rglru.window, s)
        att = ng * batch * cfg.num_kv_heads * win * cfg.head_dim * 2 * bpe
        rec = (2 * ng + cfg.num_layers % 3) * batch * cfg.rglru.lru_width * (
            4.0 + (cfg.rglru.conv_width - 1) * bpe
        )
        return att + rec
    l = cfg.encdec.dec_layers if cfg.family == "encdec" else cfg.num_layers
    mult = 4 if cfg.family == "encdec" else 2  # + cross-attn caches
    if cfg.kv_cache_dtype == "int8":
        bpe = 1.0 + 4.0 / cfg.head_dim  # int8 payload + f32 scale per vector
    return l * batch * cfg.num_kv_heads * s * cfg.head_dim * mult * bpe


@dataclass(frozen=True)
class CollectiveCost:
    """Per-device ICI bytes per step, by stream (documented formulas below)."""
    fsdp_allgather: float      # weight gathers: params_dp_bytes*(dp-1)/dp*(fwd+bwd regather)
    grad_reduce_scatter: float  # f32 grads: params_dp*4*(dp-1)/dp
    tp_activations: float      # SP/TP act gathers+psums around attn/mlp per layer
    ep_all_to_all: float       # MoE dispatch/combine
    decode_psum: float          # flash-decoding LSE combines

    @property
    def total(self) -> float:
        return (self.fsdp_allgather + self.grad_reduce_scatter
                + self.tp_activations + self.ep_all_to_all + self.decode_psum)


def _dp_sharded_param_bytes(cfg: ModelConfig) -> float:
    """Bytes of params whose storage is dp(FSDP)-sharded (≈ all matrices; the
    tiny replicated leaves — norms, biases, loras — are excluded ≈ exactly)."""
    return count_params_analytic(cfg) * 2.0  # bf16


def collective_cost(cfg: ModelConfig, shape: ShapeConfig, *, dp: int, tp: int,
                    remat: str = "full", grad_accum: int = 1,
                    ep_crossing_factor: float = 1.0,
                    serve_replicated: bool = False) -> CollectiveCost:
    b, s = shape.global_batch, shape.seq_len
    bpe = 2.0
    dpf = (dp - 1) / dp if dp > 1 else 0.0
    tpf = (tp - 1) / tp if tp > 1 else 0.0
    pbytes = _dp_sharded_param_bytes(cfg) / tp  # TP split first, FSDP over the rest

    if shape.kind == "train":
        regather = 2.0 if remat == "full" else 1.0
        # per step: gathers repeat per microbatch but move the same bytes
        ag = pbytes * dpf * (1.0 + regather) * 1.0
        ag *= grad_accum
        rs = count_params_analytic(cfg) / tp * 4.0 * dpf
        toks_local = b * s / max(dp, 1)
        # 2 gather+psum pairs per layer, fwd+bwd
        tp_act = 2 * 2 * toks_local * cfg.d_model * bpe * tpf * _n_layers_eff(cfg)
        ep = 0.0
        if cfg.family == "moe":
            ep = (2 * toks_local * cfg.moe.top_k * cfg.d_model * bpe * tpf
                  * _n_layers_eff(cfg) * 2) * ep_crossing_factor
        return CollectiveCost(ag, rs, tp_act, ep, 0.0)

    if shape.kind == "prefill":
        ag = pbytes * dpf
        toks_local = b * s / max(dp, 1)
        tp_act = 2 * toks_local * cfg.d_model * bpe * tpf * _n_layers_eff(cfg)
        ep = 0.0
        if cfg.family == "moe":
            ep = (2 * toks_local * cfg.moe.top_k * cfg.d_model * bpe * tpf
                  * _n_layers_eff(cfg)) * ep_crossing_factor
        if serve_replicated:
            ag = 0.0
        return CollectiveCost(ag, 0.0, tp_act, ep, 0.0)

    # decode: FSDP-sharded weights must be gathered every token step (this is
    # the dominant term — and the motivation for replicating weights over dp
    # at serve time, a §Perf iteration)
    ag = 0.0 if serve_replicated else pbytes * dpf
    b_local = b / max(dp, 1) if b % dp == 0 else b
    psum = (
        3 * b_local * cfg.num_heads * cfg.head_dim * 4.0 * tpf * _n_layers_eff(cfg)
        if tp > 1 else 0.0
    )
    ep = 0.0
    if cfg.family == "moe":
        ep = 2 * b_local * cfg.moe.top_k * cfg.d_model * bpe * tpf * _n_layers_eff(cfg)
    return CollectiveCost(ag, 0.0, 0.0, ep, psum)


def cell_cost(cfg: ModelConfig, shape: ShapeConfig, n_devices: int,
              *, remat: str = "full", opt_bytes_per_param: float = 8.0,
              tp: int = 16, serve_replicated: bool = False) -> CellCost:
    n_total = count_params_analytic(cfg)
    n_active = count_params_analytic(cfg, active_only=True)
    b, s = shape.global_batch, shape.seq_len
    bpe = 2.0

    if shape.kind in ("train", "prefill"):
        use_f, impl_f = _fwd_flops(cfg, s, b)
        if shape.kind == "train":
            mult_use, mult_impl = 3.0, 3.0 + (1.0 if remat == "full" else 0.0)
            use_f, impl_f = use_f * mult_use, impl_f * mult_impl
        # HBM per device: weights are re-read per layer (+grads written,
        # +optimizer state r/w for train); activations make ~c passes.
        w_local = n_total * bpe / n_devices
        act_passes = 8.0 if shape.kind == "train" else 4.0
        acts = b * s * cfg.d_model * bpe / n_devices * _n_layers_eff(cfg) * act_passes
        if shape.kind == "train":
            hbm = w_local * (3.0 + opt_bytes_per_param / bpe) + acts
        else:
            hbm = w_local + acts + _cache_bytes(cfg, s, b) / n_devices
        return CellCost(use_f / n_devices * n_devices, impl_f, hbm, n_total, n_active)

    # decode: one token across the batch
    toks = float(b)
    l = _n_layers_eff(cfg)
    lin = _layer_linear_flops_per_tok(cfg) * toks * l
    head = 2.0 * cfg.d_model * cfg.vocab_size * toks
    if cfg.family == "rwkv":
        attn = 4.0 * cfg.d_model * cfg.head_dim * toks * l  # state update/read
    elif cfg.family == "hybrid":
        n_att = cfg.num_layers // 3
        attn = 4.0 * min(cfg.rglru.window, s) * cfg.num_heads * cfg.head_dim * toks * n_att
        attn += 4.0 * cfg.rglru.lru_width * toks * (cfg.num_layers - n_att)
    else:
        attn = 4.0 * s * cfg.num_heads * cfg.head_dim * toks * l
        if cfg.family == "encdec":
            attn *= 2  # + cross-attention over the encoder cache
    use_f = impl_f = lin + head + attn
    # decode HBM: read all local weights once + local cache once; with
    # serve-replicated weights each device holds 1/tp of the model instead
    # of 1/n_devices (more local reads, no per-token gather)
    w_div = tp if serve_replicated else n_devices
    hbm = n_total * bpe / w_div + _cache_bytes(cfg, s, b) / n_devices
    return CellCost(use_f, impl_f, hbm, n_total, n_active)
