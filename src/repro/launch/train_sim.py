"""Launch facade for the training-run co-simulation.

String-addressed front door over core/train_sim.py: models and shapes by
registry name, fabrics by spec name ("abstract", "fattree", "island",
"torus") sized automatically to the data-parallel group. Keeps scripts and
benchmarks free of topology construction:

    from repro.launch import simulate_training_run
    r = simulate_training_run("granite-34b", n_hosts=64, fabric="island",
                              policy="split")
    print(r.step_time, r.mfu, r.bubble_fraction)
"""
from __future__ import annotations

from repro.core.train_sim import (TPU_V5E, ChipConstants,  # noqa: F401
                                  LayerProfile, TrainingRunResult,
                                  derive_layer_profiles, make_fabric,
                                  sweep_training_runs)
from repro.core.train_sim import simulate_training_run as _core_simulate


def simulate_training_run(model, shape="train_4k", *, n_hosts: int,
                          fabric: str | None = "abstract",
                          oversubscription: float = 4.0,
                          island_size: int = 8,
                          **kw) -> TrainingRunResult:
    """core/train_sim.simulate_training_run with ``fabric=`` as a spec
    string; the topology is sized to the dp group (n_hosts // pp — the
    hosts of ONE pipeline stage share a fabric). All other keywords pass
    through (fabric *parameters* go via ``fabric_params=``)."""
    if "topology" in kw:
        raise TypeError("pass fabric=<spec>; use core.train_sim directly "
                        "for explicit topology objects")
    dp = n_hosts // kw.get("pp", 1)
    topo = make_fabric(fabric, dp, oversubscription=oversubscription,
                       island_size=island_size)
    fabric_params = kw.pop("fabric_params", None)
    return _core_simulate(model, shape, n_hosts=n_hosts, topology=topo,
                          fabric=fabric_params, **kw)
