"""Granite-3 8B [hf:ibm-granite/granite-3.0 family; hf] — GQA dense.

40L d_model=4096 32H (kv=8) d_ff=12800 vocab=49155.
"""
from repro.configs.base import ModelConfig
from repro.configs.registry import register


@register("granite-3-8b")
def granite_3_8b() -> ModelConfig:
    return ModelConfig(
        name="granite-3-8b",
        family="dense",
        num_layers=40,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=12800,
        vocab_size=49155,
        act="swiglu",
        tie_embeddings=True,
        sub_quadratic=False,
    )
