"""RecurrentGemma-9B [arXiv:2402.19427 (Griffin); unverified] — RG-LRU + local attn 1:2.

38L d_model=4096 16H (kv=1, MQA) d_ff=12288 vocab=256000; window 2048.
Pattern: (rec, rec, attn) repeating — 38 = 12*3 + 2 trailing recurrent blocks.
Sub-quadratic: recurrent state + fixed-window KV; long_500k runs.
"""
from repro.configs.base import ModelConfig, RGLRUConfig
from repro.configs.registry import register


@register("recurrentgemma-9b")
def recurrentgemma_9b() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b",
        family="hybrid",
        num_layers=38,
        d_model=4096,
        num_heads=16,
        num_kv_heads=1,
        head_dim=256,
        d_ff=12288,
        vocab_size=256000,
        rglru=RGLRUConfig(lru_width=4096, window=2048, pattern=("rec", "rec", "attn")),
        act="gelu",  # GeGLU
        tie_embeddings=True,  # gemma-style tied embeddings (256k vocab)
        attn_window=2048,
        sub_quadratic=True,
    )
