"""Phi-3-vision 4.2B [hf:microsoft/Phi-3-vision-128k-instruct; hf].

phi3-mini backbone: 32L d_model=3072 32H (kv=32) d_ff=8192 vocab=32064.
CLIP vision frontend is a STUB: ``input_specs()`` provides precomputed patch
embeddings (batch, n_patches, patch_dim) projected into the stream.
"""
from repro.configs.base import ModelConfig, VisionStubConfig
from repro.configs.registry import register


@register("phi-3-vision-4.2b")
def phi_3_vision() -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-4.2b",
        family="vlm",
        num_layers=32,
        d_model=3072,
        num_heads=32,
        num_kv_heads=32,
        head_dim=96,
        d_ff=8192,
        vocab_size=32064,
        vision=VisionStubConfig(n_patches=1024, patch_dim=1024),
        act="swiglu",
        sub_quadratic=False,
    )
