"""Config system for the repro framework.

Frozen dataclasses; every assigned architecture is a ``ModelConfig`` built in its
own module under ``repro.configs`` and registered in ``repro.configs.registry``.

Families:
  dense   — llama-style decoder (GQA/MQA, SwiGLU)
  moe     — dense skeleton + fine-grained routed experts (shared + top-k routed)
  rwkv    — RWKV6 "Finch": token-shift + data-dependent-decay WKV (attention-free)
  hybrid  — RecurrentGemma: RG-LRU recurrent blocks + local attention, 1:2 pattern
  encdec  — whisper-style encoder-decoder (audio-frame frontend stub)
  vlm     — phi-3-vision: decoder backbone + patch-embedding frontend stub
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any

Dtype = str  # "bfloat16" | "float32"


@dataclass(frozen=True)
class MoEConfig:
    n_routed_experts: int = 64
    n_shared_experts: int = 2
    top_k: int = 6
    d_ff_expert: int = 1408
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    router_z_coef: float = 1e-3
    # device-limited routing (DeepSeek-V3 style, beyond-paper §Perf knob):
    # experts are partitioned into ``routing_groups`` EP-aligned groups and
    # each token may only route into its top ``routing_group_topk`` groups,
    # bounding cross-device dispatch copies per token by the group count.
    routing_groups: int = 0          # 0 = unrestricted
    routing_group_topk: int = 2


@dataclass(frozen=True)
class RWKVConfig:
    head_size: int = 64
    # chunk length for the block-parallel WKV scan (training/prefill path);
    # bounds the exact per-pair decay tensor (B, c, c, H, hd) in VMEM/HBM
    chunk_size: int = 32
    # low-rank sizes for the data-dependent decay / token-shift mixers (Finch)
    decay_lora: int = 64
    tokenshift_lora: int = 32


@dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int = 4096
    window: int = 2048          # local attention window
    pattern: tuple[str, ...] = ("rec", "rec", "attn")  # repeating block pattern
    conv_width: int = 4         # temporal conv in the recurrent block


@dataclass(frozen=True)
class EncDecConfig:
    enc_layers: int = 6
    dec_layers: int = 6
    # frontend stub: input_specs() supplies precomputed frame embeddings
    frame_dim: int = 512


@dataclass(frozen=True)
class VisionStubConfig:
    n_patches: int = 1024
    patch_dim: int = 1024  # pre-projection patch embedding dim (stubbed CLIP)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | rwkv | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    moe: MoEConfig | None = None
    rwkv: RWKVConfig | None = None
    rglru: RGLRUConfig | None = None
    encdec: EncDecConfig | None = None
    vision: VisionStubConfig | None = None

    act: str = "swiglu"           # swiglu | gelu
    norm_eps: float = 1e-5
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    attn_window: int | None = None  # None = full causal attention
    attn_logit_softcap: float | None = None

    # capabilities
    sub_quadratic: bool = False   # can run long_500k
    has_decoder: bool = True      # False only for pure encoders

    # numerics
    param_dtype: Dtype = "bfloat16"
    compute_dtype: Dtype = "bfloat16"
    # KV cache storage: "bf16" or "int8" (blockwise per-token/head symmetric
    # quantization — halves decode cache reads; §Perf iteration C2)
    kv_cache_dtype: str = "bf16"

    # attention chunking (online-softmax block sizes)
    attn_q_block: int = 512
    attn_kv_block: int = 1024

    def __post_init__(self):
        assert self.family in ("dense", "moe", "rwkv", "hybrid", "encdec", "vlm")
        if self.family == "moe":
            assert self.moe is not None
        if self.family == "rwkv":
            assert self.rwkv is not None
        if self.family == "hybrid":
            assert self.rglru is not None
        if self.family == "encdec":
            assert self.encdec is not None
        if self.family == "vlm":
            assert self.vision is not None
        if self.family not in ("rwkv",):
            assert self.num_heads % self.num_kv_heads == 0

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    def n_params(self) -> int:
        """Total parameter count (used for MODEL_FLOPS = 6*N*D roofline term)."""
        from repro.models.model_builder import count_params_analytic

        return count_params_analytic(self)

    def n_active_params(self) -> int:
        """Active params per token (MoE: shared + top_k routed experts only)."""
        from repro.models.model_builder import count_params_analytic

        return count_params_analytic(self, active_only=True)


@dataclass(frozen=True)
class ShapeConfig:
    name: str                    # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                    # train | prefill | decode
    seq_len: int
    global_batch: int

    def __post_init__(self):
        assert self.kind in ("train", "prefill", "decode")


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


@dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False

    @property
    def shape(self) -> tuple[int, ...]:
        return (2, 16, 16) if self.multi_pod else (16, 16)

    @property
    def axes(self) -> tuple[str, ...]:
        return ("pod", "data", "model") if self.multi_pod else ("data", "model")

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


@dataclass(frozen=True)
class CollectiveConfig:
    """Configuration of the paper's collective layer (core/collectives.py)."""
    # fsdp_mode:
    #   "xla"   — parameters sharded, XLA inserts all-gather/reduce-scatter (baseline)
    #   "mcast" — explicit broadcast-composed allgather + bidirectional ring RS
    #             on flat padded buckets (the paper's schedule)
    fsdp_mode: str = "xla"
    # number of parallel broadcast chains M (paper Appendix A). 2 == the two
    # ring directions of a full-duplex ICI link (Fig. 1's two trees).
    n_chains: int = 2
    # chunk size (elements) for the pipelined broadcast; MTU analogue.
    chunk_elems: int = 65_536
    # direction-split concurrent AG/RS (Insight 2 analogue)
    direction_split: bool = True
    # serve-time weight layout: replicate params over the dp axes (decode is
    # otherwise collective-bound on per-token FSDP gathers — §Perf knob)
    serve_params_replicated: bool = False
    # explicit prefetch of layer i+1's FSDP gather during layer i's compute
    # (mcast modes only; train path)
    prefetch: bool = False


@dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    learning_rate: float = 3e-4
    warmup_steps: int = 10
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    # gradient accumulation: global_batch is split into this many microbatches
    grad_accum: int = 1
    # remat policy: "none" | "full" | "dots" (checkpoint_dots)
    remat: str = "full"
    seed: int = 0
    log_every: int = 10
    checkpoint_every: int = 0    # 0 = disabled
    checkpoint_dir: str = "/tmp/repro_ckpt"
    opt_dtype: Dtype = "float32"


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    mesh: MeshConfig = field(default_factory=MeshConfig)
    train: TrainConfig = field(default_factory=TrainConfig)
    collective: CollectiveConfig = field(default_factory=CollectiveConfig)

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)


def replace(cfg, **kw):
    return dataclasses.replace(cfg, **kw)


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def reduced(cfg: ModelConfig, *, layers: int | None = None) -> ModelConfig:
    """A tiny same-family variant of ``cfg`` for CPU smoke tests.

    Keeps the structural features (GQA ratio, MoE routing, hybrid pattern,
    enc/dec split, stub frontends) while shrinking every dimension.
    """
    kv = max(1, min(cfg.num_kv_heads, 2))
    heads = kv * min(cfg.q_per_kv, 2) if cfg.family != "rwkv" else 4
    d_model = 64
    head_dim = 16
    if cfg.family == "rwkv":
        head_dim = 16
        heads = d_model // head_dim
    upd: dict[str, Any] = dict(
        name=cfg.name + "-smoke",
        num_layers=layers if layers is not None else (3 if cfg.family == "hybrid" else 2),
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv if cfg.family != "rwkv" else heads,
        head_dim=head_dim,
        d_ff=128,
        vocab_size=256,
        attn_q_block=32,
        attn_kv_block=32,
        param_dtype="float32",
        compute_dtype="float32",
    )
    if cfg.moe is not None:
        upd["moe"] = dataclasses.replace(
            cfg.moe, n_routed_experts=8, n_shared_experts=1, top_k=2, d_ff_expert=32
        )
    if cfg.rwkv is not None:
        upd["rwkv"] = dataclasses.replace(
            cfg.rwkv, head_size=head_dim, chunk_size=16, decay_lora=8, tokenshift_lora=8
        )
    if cfg.rglru is not None:
        upd["rglru"] = dataclasses.replace(
            cfg.rglru, lru_width=d_model, window=32, conv_width=4
        )
    if cfg.encdec is not None:
        upd["encdec"] = dataclasses.replace(
            cfg.encdec, enc_layers=2, dec_layers=2, frame_dim=d_model
        )
        upd["num_layers"] = 2
    if cfg.vision is not None:
        upd["vision"] = dataclasses.replace(cfg.vision, n_patches=8, patch_dim=32)
    if cfg.attn_window is not None:
        upd["attn_window"] = 32
    return dataclasses.replace(cfg, **upd)
