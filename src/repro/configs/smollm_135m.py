"""SmolLM-135M [hf:HuggingFaceTB/SmolLM-135M; hf] — small llama-arch GQA.

30L d_model=576 9H (kv=3) d_ff=1536 vocab=49152.
9 heads are not divisible by model=16: attention TP is head-replicated
(GSPMD pads), FFN/vocab shard cleanly.
"""
from repro.configs.base import ModelConfig
from repro.configs.registry import register


@register("smollm-135m")
def smollm_135m() -> ModelConfig:
    return ModelConfig(
        name="smollm-135m",
        family="dense",
        num_layers=30,
        d_model=576,
        num_heads=9,
        num_kv_heads=3,
        head_dim=64,
        d_ff=1536,
        vocab_size=49152,
        act="swiglu",
        tie_embeddings=True,
        sub_quadratic=False,
    )
