"""Architecture registry: ``--arch <id>`` resolution and the 40-cell enumeration."""
from __future__ import annotations

from typing import Callable, Iterator

from repro.configs.base import SHAPES, MeshConfig, ModelConfig, RunConfig, ShapeConfig

_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn

    return deco


def _ensure_loaded() -> None:
    # import arch modules for their side-effectful @register decorators
    from repro.configs import (  # noqa: F401
        deepseek_moe_16b,
        granite_3_8b,
        granite_34b,
        moonshot_v1_16b_a3b,
        phi_3_vision_4_2b,
        recurrentgemma_9b,
        rwkv6_7b,
        smollm_135m,
        whisper_base,
        yi_9b,
    )


def arch_names() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def get_model_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


#: GPT-small -> 34B dense span swept by core/train_sim.py's benchmark
#: (benchmarks/paper_figs.training_run_sweep) and the co-sim tests
TRAINING_SWEEP_ARCHS: tuple[str, ...] = ("smollm-135m", "yi-9b",
                                         "granite-34b")


def training_sweep_archs() -> tuple[str, ...]:
    _ensure_loaded()
    assert all(a in _REGISTRY for a in TRAINING_SWEEP_ARCHS)
    return TRAINING_SWEEP_ARCHS


def cell_supported(model: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether the (arch, shape) cell is runnable; (ok, reason-if-skipped)."""
    if shape.name == "long_500k" and not model.sub_quadratic:
        return False, (
            "long_500k requires sub-quadratic attention; "
            f"{model.name} is full-attention (skip per assignment)"
        )
    if shape.kind == "decode" and not model.has_decoder:
        return False, f"{model.name} is encoder-only; no decode step"
    return True, ""


def iter_cells(include_skipped: bool = False) -> Iterator[tuple[str, str, bool, str]]:
    """Yield (arch, shape, supported, skip_reason) for the 40-cell table."""
    for arch in arch_names():
        model = get_model_config(arch)
        for shape_name in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            ok, why = cell_supported(model, SHAPES[shape_name])
            if ok or include_skipped:
                yield arch, shape_name, ok, why


def make_run_config(arch: str, shape: str, *, multi_pod: bool = False, **train_kw) -> RunConfig:
    from repro.configs.base import TrainConfig

    return RunConfig(
        model=get_model_config(arch),
        shape=get_shape(shape),
        mesh=MeshConfig(multi_pod=multi_pod),
        train=TrainConfig(**train_kw) if train_kw else TrainConfig(),
    )
