"""Yi-9B [arXiv:2403.04652; hf] — llama-arch GQA dense.

48L d_model=4096 32H (kv=4) d_ff=11008 vocab=64000.
"""
from repro.configs.base import ModelConfig
from repro.configs.registry import register


@register("yi-9b")
def yi_9b() -> ModelConfig:
    return ModelConfig(
        name="yi-9b",
        family="dense",
        num_layers=48,
        d_model=4096,
        num_heads=32,
        num_kv_heads=4,
        head_dim=128,
        d_ff=11008,
        vocab_size=64000,
        act="swiglu",
        sub_quadratic=False,
    )
