"""RWKV6 "Finch" 7B — attention-free, data-dependent decay [arXiv:2404.05892; hf].

32L d_model=4096 d_ff=14336 vocab=65536, head_size 64 (=> 64 WKV heads).
Sub-quadratic: O(1) state per token at decode; long_500k runs.
"""
from repro.configs.base import ModelConfig, RWKVConfig
from repro.configs.registry import register


@register("rwkv6-7b")
def rwkv6_7b() -> ModelConfig:
    d_model = 4096
    head_size = 64
    return ModelConfig(
        name="rwkv6-7b",
        family="rwkv",
        num_layers=32,
        d_model=d_model,
        num_heads=d_model // head_size,
        num_kv_heads=d_model // head_size,
        head_dim=head_size,
        d_ff=14336,
        vocab_size=65536,
        rwkv=RWKVConfig(head_size=head_size, chunk_size=32, decay_lora=64, tokenshift_lora=32),
        act="relu_sq",  # RWKV channel-mix uses squared-ReLU
        sub_quadratic=True,
    )
