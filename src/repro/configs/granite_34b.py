"""Granite-34B code [arXiv:2405.04324; hf] — llama-arch MQA dense.

88L d_model=6144 48H (kv=1, MQA) d_ff=24576 vocab=49152.
Largest dense arch in the pool — the FSDP-allgather stress case.
"""
from repro.configs.base import ModelConfig
from repro.configs.registry import register


@register("granite-34b")
def granite_34b() -> ModelConfig:
    return ModelConfig(
        name="granite-34b",
        family="dense",
        num_layers=88,
        d_model=6144,
        num_heads=48,
        num_kv_heads=1,
        head_dim=128,
        d_ff=24576,
        vocab_size=49152,
        act="gelu",  # granite code models use GPT-style MLP
        sub_quadratic=False,
    )
