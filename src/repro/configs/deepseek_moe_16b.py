"""DeepSeekMoE-16B [arXiv:2401.06066; hf] — fine-grained MoE.

28L d_model=2048 16H (kv=16) d_ff=1408/expert vocab=102400,
2 shared + 64 routed experts, top-6 routing.

Recorded deviation (DESIGN.md §5): the real model's dense layer-0 FFN is
regularized to a uniform MoE stack to keep scan-over-layers homogeneous.
"""
from repro.configs.base import ModelConfig, MoEConfig
from repro.configs.registry import register


@register("deepseek-moe-16b")
def deepseek_moe_16b() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b",
        family="moe",
        num_layers=28,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=1408,
        vocab_size=102400,
        moe=MoEConfig(
            n_routed_experts=64,
            n_shared_experts=2,
            top_k=6,
            d_ff_expert=1408,
            capacity_factor=1.25,
        ),
        act="swiglu",
        sub_quadratic=False,
    )
