"""Whisper-base [arXiv:2212.04356; unverified] — encoder-decoder, conv frontend STUB.

6L(enc)+6L(dec) d_model=512 8H (kv=8) d_ff=2048 vocab=51865.
The audio (mel/conv) frontend is a stub: ``input_specs()`` provides precomputed
frame embeddings of shape (batch, seq, d_model).
"""
from repro.configs.base import EncDecConfig, ModelConfig
from repro.configs.registry import register


@register("whisper-base")
def whisper_base() -> ModelConfig:
    return ModelConfig(
        name="whisper-base",
        family="encdec",
        num_layers=6,  # per-stack depth; encdec.enc_layers/dec_layers authoritative
        d_model=512,
        num_heads=8,
        num_kv_heads=8,
        head_dim=64,
        d_ff=2048,
        vocab_size=51865,
        encdec=EncDecConfig(enc_layers=6, dec_layers=6, frame_dim=512),
        act="gelu",
        rope_theta=0.0,  # whisper uses learned/sinusoidal positions, not RoPE
        sub_quadratic=False,
        has_decoder=True,
    )
