"""Moonshot/Moonlight 16B-A3B [hf:moonshotai/Moonlight-16B-A3B; hf].

48L d_model=2048 16H (kv=16) d_ff=1408/expert vocab=163840, MoE 64e top-6
(+2 shared, deepseek-v3-style fine-grained experts).
"""
from repro.configs.base import ModelConfig, MoEConfig
from repro.configs.registry import register


@register("moonshot-v1-16b-a3b")
def moonshot_v1_16b_a3b() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b",
        family="moe",
        num_layers=48,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=1408,
        vocab_size=163840,
        moe=MoEConfig(
            n_routed_experts=64,
            n_shared_experts=2,
            top_k=6,
            d_ff_expert=1408,
            capacity_factor=1.25,
        ),
        act="swiglu",
        sub_quadratic=False,
    )
