"""RWKV6 "Finch": token-shift with LoRA mixing + data-dependent per-channel decay.

WKV recurrence per head (state S in R^{K x V}):
    o_t = r_t S_{t-1} + (r_t . (u o k_t)) v_t
    S_t = diag(w_t) S_{t-1} + k_t^T v_t            (w_t in (0,1), per channel)

Training/prefill uses the chunked (block-parallel) form: sequential scan over
chunks carrying S, parallel intra-chunk via the decay-factored score matrix
(flash-linear-attention style). Decode is the O(1) recurrent step — this is
what makes long_500k run with constant memory per token.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers

# chunk size bounds the (B, c, c, H, hd) per-pair decay tensor of the exact
# intra-chunk path; 32 keeps it ~16 MB/device at production shapes.
DEFAULT_CHUNK = 32


def _shift(x: jax.Array, prev: jax.Array | None = None) -> jax.Array:
    """Token shift: y_t = x_{t-1}; y_0 = prev (or zeros). x: (B, S, D)."""
    pad = jnp.zeros_like(x[:, :1]) if prev is None else prev[:, None]
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def rwkv_block_init(rng, cfg: ModelConfig, dtype):
    d = cfg.d_model
    r = cfg.rwkv
    ks = jax.random.split(rng, 12)
    s = 1.0 / np.sqrt(d)
    h = cfg.num_heads

    def mat(k, shape, scale):
        return (jax.random.normal(k, shape) * scale).astype(dtype)

    return {
        "ln1": jnp.zeros((d,), jnp.float32),
        "ln2": jnp.zeros((d,), jnp.float32),
        # time-mix interpolation base (r,k,v,w,g) + token-shift LoRA
        "mu": (jax.random.uniform(ks[0], (5, d)) * 0.5).astype(jnp.float32),
        "ts_w1": mat(ks[1], (d, 5 * r.tokenshift_lora), s),
        "ts_w2": mat(ks[2], (5, r.tokenshift_lora, d), 1.0 / np.sqrt(r.tokenshift_lora)),
        "wr": mat(ks[3], (d, d), s),
        "wk": mat(ks[4], (d, d), s),
        "wv": mat(ks[5], (d, d), s),
        "wg": mat(ks[6], (d, d), s),
        "wo": mat(ks[7], (d, d), s),
        # data-dependent decay: w = exp(-exp(base + lora))
        "decay_base": (jax.random.uniform(ks[8], (d,)) * 2.0 - 4.0).astype(jnp.float32),
        "decay_w1": mat(ks[9], (d, r.decay_lora), s),
        "decay_w2": mat(ks[10], (r.decay_lora, d), 1.0 / np.sqrt(r.decay_lora)),
        "bonus": (jax.random.normal(ks[11], (h, cfg.head_dim)) * 0.5).astype(jnp.float32),
        "ln_x_scale": jnp.ones((d,), jnp.float32),
        "ln_x_bias": jnp.zeros((d,), jnp.float32),
        # channel mix
        "cm_mu_k": (jax.random.uniform(jax.random.fold_in(rng, 99), (d,)) * 0.5).astype(
            jnp.float32
        ),
        "cm_mu_r": (jax.random.uniform(jax.random.fold_in(rng, 98), (d,)) * 0.5).astype(
            jnp.float32
        ),
        "cm_wk": mat(jax.random.fold_in(rng, 97), (d, cfg.d_ff), s),
        "cm_wv": mat(
            jax.random.fold_in(rng, 96), (cfg.d_ff, d), 1.0 / np.sqrt(cfg.d_ff)
        ),
        "cm_wr": mat(jax.random.fold_in(rng, 95), (d, d), s),
    }


def rwkv_block_param_count(cfg: ModelConfig) -> int:
    d, f, r = cfg.d_model, cfg.d_ff, cfg.rwkv
    tm = 5 * d * d + d * 5 * r.tokenshift_lora + 5 * r.tokenshift_lora * d
    tm += d * r.decay_lora + r.decay_lora * d + 5 * d + d + cfg.num_heads * cfg.head_dim
    cm = d * f + f * d + d * d + 2 * d
    return tm + cm + 4 * d  # + norms


def _time_mix_inputs(p, x, x_prev, cfg: ModelConfig):
    """Finch 5-way token-shift mixing -> (xr, xk, xv, xw, xg)."""
    dt = x.dtype
    sx = _shift(x, x_prev) - x                     # (B,S,D)
    base = x + sx * p["mu"].astype(dt)[:, None, None, :]  # (5,B,S,D)
    # data-dependent shift offsets
    lora = jnp.tanh(jnp.einsum("bsd,de->bse", x, p["ts_w1"].astype(dt)))
    lora = lora.reshape(*x.shape[:2], 5, -1)       # (B,S,5,ts)
    off = jnp.einsum("bste,ted->tbsd", lora, p["ts_w2"].astype(dt))
    return (base + sx[None] * off).astype(dt)      # (5,B,S,D)


def _decay(p, xw: jax.Array) -> jax.Array:
    """log(w) per channel, guaranteed negative: lw = -exp(base + lora)."""
    lora = jnp.einsum(
        "bsd,de->bse", jnp.tanh(jnp.einsum("bsd,de->bse", xw, p["decay_w1"].astype(xw.dtype))),
        p["decay_w2"].astype(xw.dtype),
    )
    return -jnp.exp(jnp.clip(p["decay_base"] + lora.astype(jnp.float32), -8.0, 4.0))


def wkv_chunked(r, k, v, lw, u, chunk: int):
    """Chunked WKV. r,k,v,lw: (B,S,H,hd) (lw = log decay, f32); u: (H,hd).

    Returns (o (B,S,H,hd) f32, S_final (B,H,K,V) f32)."""
    b, s, h, hd = r.shape
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        zp = ((0, 0), (0, pad), (0, 0), (0, 0))
        r, k, v = jnp.pad(r, zp), jnp.pad(k, zp), jnp.pad(v, zp)
        lw = jnp.pad(lw, zp)  # log w = 0 -> w = 1 for padding (no decay, k=0)

    def resh(x):
        return x.reshape(b, nc, chunk, h, hd).transpose(1, 0, 2, 3, 4).astype(jnp.float32)

    rc, kc, vc, lwc = resh(r), resh(k), resh(v), resh(lw)

    def chunk_step(S, inp):
        rb, kb, vb, lwb = inp                      # (B,c,H,hd)
        L = jnp.cumsum(lwb, axis=1)                # inclusive
        Lx = L - lwb                               # exclusive
        L_last = L[:, -1:]                         # (B,1,H,hd)
        rr = rb * jnp.exp(Lx)                      # decay chunk-start..t-1 (<=1)
        # intra-chunk scores with EXACT per-pair per-channel decay
        # exp(Lx_t - L_s) = prod_{u in (s, t)} w_u  — the exponent is <= 0 for
        # every causal pair, so this never overflows (a single-reference
        # factorization rr*kk does overflow f32 under strong decay).
        dec = jnp.exp(jnp.minimum(Lx[:, :, None] - L[:, None, :], 0.0))
        scores = jnp.einsum("bthk,bshk,btshk->bhts", rb, kb, dec)
        cmask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
        scores = jnp.where(cmask[None, None], scores, 0.0)
        o = jnp.einsum("bhts,bshv->bthv", scores, vb)
        # diagonal bonus term
        diag = jnp.einsum("bthk,bthk->bth", rb, u[None, None] * kb)
        o = o + diag[..., None] * vb
        # contribution from carried state
        o = o + jnp.einsum("bthk,bhkv->bthv", rr, S)
        # state update
        kk2 = kb * jnp.exp(L_last - L)             # decay s+1..chunk-end (<=1)
        S_new = jnp.exp(L_last[:, 0])[..., None] * S + jnp.einsum(
            "bshk,bshv->bhkv", kk2, vb
        )
        return S_new, o

    S0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    S_fin, os = jax.lax.scan(chunk_step, S0, (rc, kc, vc, lwc))
    o = os.transpose(1, 0, 2, 3, 4).reshape(b, nc * chunk, h, hd)
    return o[:, :s], S_fin


def wkv_recurrent(r, k, v, lw, u, S0=None):
    """Naive per-step recurrence (oracle for tests + decode path)."""
    b, s, h, hd = r.shape
    S0 = jnp.zeros((b, h, hd, hd), jnp.float32) if S0 is None else S0

    def step(S, inp):
        rt, kt, vt, lwt = [x.astype(jnp.float32) for x in inp]  # (B,H,hd)
        o = jnp.einsum("bhk,bhkv->bhv", rt, S)
        o = o + jnp.einsum("bhk,bhk->bh", rt, u[None] * kt)[..., None] * vt
        S = jnp.exp(lwt)[..., None] * S + kt[..., None] * vt[..., None, :]
        return S, o

    xs = tuple(x.transpose(1, 0, 2, 3) for x in (r, k, v, lw))
    S_fin, os = jax.lax.scan(step, S0, xs)
    return os.transpose(1, 0, 2, 3), S_fin


def _group_norm_heads(x, scale, bias, eps=1e-5):
    """x (B,S,H,hd): normalize per head; scale/bias per channel (D)."""
    b, s, h, hd = x.shape
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    y = y.reshape(b, s, h * hd)
    return y * scale + bias


def time_mix_apply(p, x, cfg: ModelConfig, *, x_prev=None, state=None, chunked=True):
    """Full RWKV6 time-mix. Returns (out (B,S,D), new_state (B,H,K,V), last_x)."""
    b, s, d = x.shape
    h, hd = cfg.num_heads, cfg.head_dim
    dt = x.dtype
    xr, xk, xv, xw, xg = _time_mix_inputs(p, x, x_prev, cfg)
    r = jnp.einsum("bsd,de->bse", xr, p["wr"].astype(dt)).reshape(b, s, h, hd)
    k = jnp.einsum("bsd,de->bse", xk, p["wk"].astype(dt)).reshape(b, s, h, hd)
    v = jnp.einsum("bsd,de->bse", xv, p["wv"].astype(dt)).reshape(b, s, h, hd)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["wg"].astype(dt)))
    lw = _decay(p, xw).reshape(b, s, h, hd)
    u = p["bonus"].astype(jnp.float32)
    if chunked and s > 1:
        o, S = wkv_chunked(
            r.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
            lw, u, cfg.rwkv.chunk_size,
        )
        if state is not None:
            # carried-in state support for chunked path: fold via recurrent identity
            # (prefill from scratch uses state=None; streaming prefill uses recurrent)
            raise NotImplementedError("chunked WKV with nonzero initial state")
    else:
        o, S = wkv_recurrent(
            r.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
            lw, u, state,
        )
    o = _group_norm_heads(o, p["ln_x_scale"], p["ln_x_bias"]).astype(dt)
    out = jnp.einsum("bse,ed->bsd", (o * g.astype(dt)), p["wo"].astype(dt))
    return out.astype(dt), S, x[:, -1]


def channel_mix_apply(p, x, *, x_prev=None):
    """RWKV channel mix. Returns (out, last_x)."""
    dt = x.dtype
    sx = _shift(x, x_prev) - x
    xk = x + sx * p["cm_mu_k"].astype(dt)
    xr = x + sx * p["cm_mu_r"].astype(dt)
    kk = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xk, p["cm_wk"].astype(dt))))
    vv = jnp.einsum("bsf,fd->bsd", kk, p["cm_wv"].astype(dt))
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["cm_wr"].astype(dt)))
    return rr * vv, x[:, -1]


def rwkv_block_apply(p, x, cfg: ModelConfig, *, state=None, chunked=True):
    """One RWKV6 block. state = None or dict(wkv (B,H,K,V), tm_x (B,D), cm_x (B,D)).
    Returns (x_out, new_state)."""
    st_wkv = None if state is None else state["wkv"]
    tm_prev = None if state is None else state["tm_x"]
    cm_prev = None if state is None else state["cm_x"]
    h = layers.rms_norm(x, p["ln1"], 1e-5)
    att, new_wkv, tm_x = time_mix_apply(
        p, h, cfg, x_prev=tm_prev, state=st_wkv, chunked=chunked
    )
    x = x + att.astype(x.dtype)
    h2 = layers.rms_norm(x, p["ln2"], 1e-5)
    ff, cm_x = channel_mix_apply(p, h2, x_prev=cm_prev)
    x = x + ff.astype(x.dtype)
    return x, {"wkv": new_wkv, "tm_x": tm_x.astype(x.dtype),
               "cm_x": cm_x.astype(x.dtype)}
