"""Whisper-style encoder-decoder backbone.

The audio frontend (mel spectrogram + conv downsampling) is a STUB per the
assignment: ``input_specs()`` supplies precomputed frame embeddings
(B, S_enc, d_model). Positions are sinusoidal (no RoPE). The decoder carries a
self-attention KV cache plus per-layer cross-attention KV computed once at
prefill from the encoder output.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers
from repro.sharding import shard
from repro.sharding.ctx import maybe_gather_params

Params = Any


def _enc_block_init(rng, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(rng)
    return {
        "ln1": jnp.zeros((cfg.d_model,), jnp.float32),
        "attn": layers.attn_proj_init(k1, cfg, dtype),
        "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
        "mlp": layers.mlp_init(k2, cfg.d_model, cfg.d_ff, "gelu", dtype),
    }


def _dec_block_init(rng, cfg: ModelConfig, dtype):
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "ln1": jnp.zeros((cfg.d_model,), jnp.float32),
        "self_attn": layers.attn_proj_init(k1, cfg, dtype),
        "ln_x": jnp.zeros((cfg.d_model,), jnp.float32),
        "cross_attn": layers.attn_proj_init(k2, cfg, dtype),
        "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
        "mlp": layers.mlp_init(k3, cfg.d_model, cfg.d_ff, "gelu", dtype),
    }


def encdec_init(rng, cfg: ModelConfig):
    dtype = layers.dtype_of(cfg.param_dtype)
    ke, k1, k2, kh = jax.random.split(rng, 4)
    return {
        "embed": layers.embed_init(ke, cfg.vocab_size, cfg.d_model, dtype),
        "enc_blocks": layers.stack_layer_init(
            k1, cfg.encdec.enc_layers, lambda r: _enc_block_init(r, cfg, dtype)
        ),
        "enc_ln": jnp.zeros((cfg.d_model,), jnp.float32),
        "dec_blocks": layers.stack_layer_init(
            k2, cfg.encdec.dec_layers, lambda r: _dec_block_init(r, cfg, dtype)
        ),
        "final_ln": jnp.zeros((cfg.d_model,), jnp.float32),
        "lm_head": (
            jax.random.normal(kh, (cfg.d_model, cfg.vocab_size)) / np.sqrt(cfg.d_model)
        ).astype(dtype),
    }


def encdec_param_count(cfg: ModelConfig) -> int:
    a = layers.attn_param_count(cfg)
    m = layers.mlp_param_count(cfg.d_model, cfg.d_ff, "gelu")
    enc = cfg.encdec.enc_layers * (a + m)
    dec = cfg.encdec.dec_layers * (2 * a + m)
    return enc + dec + 2 * cfg.vocab_size * cfg.d_model


def _posenc(x: jax.Array, offset: int = 0) -> jax.Array:
    pe = jnp.asarray(layers.sinusoidal_positions(x.shape[1] + offset, x.shape[2]))
    return x + pe[offset:, :].astype(x.dtype)[None]


def encode(params, cfg: ModelConfig, frames: jax.Array, remat="none") -> jax.Array:
    """frames (B, S_enc, D) — stubbed frontend output — -> encoder states."""
    x = _posenc(frames.astype(layers.dtype_of(cfg.compute_dtype)))
    x = shard(x, "dp", "sp", None)

    def body(h, bp):
        bp = maybe_gather_params(bp)
        hh = layers.rms_norm(h, bp["ln1"], cfg.norm_eps)
        q, k, v = layers.qkv_split(bp["attn"], hh, cfg)
        o = attn.blockwise_attention(
            q, k, v, causal=False, q_block=cfg.attn_q_block, kv_block=cfg.attn_kv_block
        )
        h = h + shard(layers.out_proj(bp["attn"], o), "dp", "sp", None)
        h2 = layers.rms_norm(h, bp["ln2"], cfg.norm_eps)
        h = h + shard(layers.mlp_apply(bp["mlp"], h2, "gelu"), "dp", "sp", None)
        return h, None

    fn = jax.checkpoint(body, prevent_cse=False) if remat == "full" else body
    x, _ = jax.lax.scan(fn, x, params["enc_blocks"])
    return layers.rms_norm(x, params["enc_ln"], cfg.norm_eps)


def _dec_block(bp, x, cfg, enc, *, want_kv):
    """Decoder block over token states x (B,S,D) with encoder states enc."""
    h = layers.rms_norm(x, bp["ln1"], cfg.norm_eps)
    q, k, v = layers.qkv_split(bp["self_attn"], h, cfg)
    o = attn.blockwise_attention(
        q, k, v, causal=True, q_block=cfg.attn_q_block, kv_block=cfg.attn_kv_block
    )
    x = x + shard(layers.out_proj(bp["self_attn"], o), "dp", "sp", None)

    hx = layers.rms_norm(x, bp["ln_x"], cfg.norm_eps)
    qx, kx, vx = _cross_qkv(bp["cross_attn"], hx, enc, cfg)
    ox = attn.blockwise_attention(
        qx, kx, vx, causal=False, q_block=cfg.attn_q_block, kv_block=cfg.attn_kv_block
    )
    x = x + shard(layers.out_proj(bp["cross_attn"], ox), "dp", "sp", None)

    h2 = layers.rms_norm(x, bp["ln2"], cfg.norm_eps)
    x = x + shard(layers.mlp_apply(bp["mlp"], h2, "gelu"), "dp", "sp", None)
    kvs = None
    if want_kv:
        kvs = (
            k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
            kx.transpose(0, 2, 1, 3), vx.transpose(0, 2, 1, 3),
        )
    return x, kvs


def _cross_qkv(p, x, enc, cfg):
    b, s, _ = x.shape
    se = enc.shape[1]
    dt = x.dtype
    q = jnp.einsum("bsd,de->bse", x, p["wq"].astype(dt)).reshape(
        b, s, cfg.num_heads, cfg.head_dim
    )
    k = jnp.einsum("bsd,de->bse", enc, p["wk"].astype(dt)).reshape(
        b, se, cfg.num_kv_heads, cfg.head_dim
    )
    v = jnp.einsum("bsd,de->bse", enc, p["wv"].astype(dt)).reshape(
        b, se, cfg.num_kv_heads, cfg.head_dim
    )
    return q, k, v


def encdec_forward(params, cfg: ModelConfig, batch, *, want_cache=False, remat="none"):
    """batch: frames (B,S_enc,D), tokens (B,S_dec). Returns (hidden, aux, cache)."""
    enc = encode(params, cfg, batch["frames"], remat)
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    x = _posenc(x)
    x = shard(x, "dp", "sp", None)

    def body(h, bp):
        bp = maybe_gather_params(bp)
        h, kvs = _dec_block(bp, h, cfg, enc, want_kv=want_cache)
        return h, kvs

    fn = jax.checkpoint(body, prevent_cse=False) if remat == "full" else body
    x, kvs = jax.lax.scan(fn, x, params["dec_blocks"])
    cache = None
    if want_cache:
        cache = {"k": kvs[0], "v": kvs[1], "xk": kvs[2], "xv": kvs[3]}
    return x, {}, cache


def encdec_logits(params, cfg: ModelConfig, x):
    x = layers.rms_norm(x, params["final_ln"], cfg.norm_eps)
    logits = jnp.einsum("...d,dv->...v", x, params["lm_head"].astype(x.dtype))
    return shard(logits, "dp", None, "tp") if logits.ndim == 3 else logits


def encdec_decode_step(params, cfg: ModelConfig, cache, token, pos):
    """Self-attn cache update + frozen cross-attn KV. token/pos (B,)."""
    x = jnp.take(params["embed"], token[:, None], axis=0)
    pe = jnp.asarray(layers.sinusoidal_positions(cache["k"].shape[3] + 1, cfg.d_model))
    x = x + pe[pos][:, None].astype(x.dtype)
    x = x[:, 0]

    def body(h, xs):
        bp, kc, vc, xk, xv = xs
        hh = layers.rms_norm(h[:, None], bp["ln1"], cfg.norm_eps)
        q, k, v = layers.qkv_split(bp["self_attn"], hh, cfg)
        kc = attn.cache_scatter_update(kc, k[:, 0], pos)
        vc = attn.cache_scatter_update(vc, v[:, 0], pos)
        o = attn.plain_decode_attention(q[:, 0], kc, vc, pos)
        h = h + layers.out_proj(bp["self_attn"], o[:, None])[:, 0]
        hx = layers.rms_norm(h[:, None], bp["ln_x"], cfg.norm_eps)
        qx = jnp.einsum("bsd,de->bse", hx, bp["cross_attn"]["wq"].astype(hx.dtype))
        qx = qx.reshape(h.shape[0], cfg.num_heads, cfg.head_dim)
        se = xk.shape[2]
        ox = attn.plain_decode_attention(
            qx, xk, xv, jnp.full((h.shape[0],), se - 1, jnp.int32)
        )
        h = h + layers.out_proj(bp["cross_attn"], ox[:, None])[:, 0]
        h2 = layers.rms_norm(h[:, None], bp["ln2"], cfg.norm_eps)
        h = h + layers.mlp_apply(bp["mlp"], h2, "gelu")[:, 0]
        return h, (kc, vc)

    x, (kcs, vcs) = jax.lax.scan(
        body, x, (params["dec_blocks"], cache["k"], cache["v"], cache["xk"], cache["xv"])
    )
    logits = encdec_logits(params, cfg, x[:, None])[:, 0]
    return logits, {"k": kcs, "v": vcs, "xk": cache["xk"], "xv": cache["xv"]}


def encdec_init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype):
    kvh, hd = cfg.num_kv_heads, cfg.head_dim
    ld = cfg.encdec.dec_layers
    return {
        "k": jnp.zeros((ld, batch, kvh, seq_len, hd), dtype),
        "v": jnp.zeros((ld, batch, kvh, seq_len, hd), dtype),
        "xk": jnp.zeros((ld, batch, kvh, seq_len, hd), dtype),
        "xv": jnp.zeros((ld, batch, kvh, seq_len, hd), dtype),
    }
