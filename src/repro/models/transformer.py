"""LM stack assembly for all decoder families (dense / moe / vlm / rwkv / hybrid).

Layers are stacked on a leading L dim and consumed with ``jax.lax.scan`` so the
HLO stays compact at 88 layers (granite-34b) and compile times stay sane on the
512-device dry-run. Sharding is expressed through ``repro.sharding.shard``
constraints; with no mesh active everything runs single-device (smoke tests).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers, moe, rglru, rwkv
from repro.sharding import get_ctx, shard
from repro.sharding.ctx import maybe_gather_params

Params = Any


# ------------------------------------------------------------------ dense block


def dense_block_init(rng, cfg: ModelConfig, dtype) -> Params:
    k1, k2, k3 = jax.random.split(rng, 3)
    p = {
        "ln1": jnp.zeros((cfg.d_model,), jnp.float32),
        "attn": layers.attn_proj_init(k1, cfg, dtype),
        "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if cfg.family == "moe":
        p["moe"] = moe.moe_init(k2, cfg, dtype)
    else:
        p["mlp"] = layers.mlp_init(k3, cfg.d_model, cfg.d_ff, _mlp_act(cfg), dtype)
    return p


def _mlp_act(cfg: ModelConfig) -> str:
    return "swiglu" if cfg.act == "swiglu" else cfg.act


def _attn_head_spec(cfg: ModelConfig):
    """Shard attention head dims over tp only when divisible."""
    from repro.sharding import mesh_axis_size

    tp = mesh_axis_size("tp")
    return "tp" if (tp > 1 and cfg.num_heads % tp == 0) else None


def dense_block_apply(p, x: jax.Array, cfg: ModelConfig, *, positions, want_kv: bool):
    """Train/prefill path. x (B,S,D). Returns (x, aux_metrics, (k,v)|None)."""
    hspec = _attn_head_spec(cfg)
    h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
    h = shard(h, "dp", None, None)
    q, k, v = layers.qkv_split(p["attn"], h, cfg)
    q = apply_positions(q, positions, cfg)
    k = apply_positions(k, positions, cfg)
    q = shard(q, "dp", None, hspec, None)
    o = attn.blockwise_attention(
        q, k, v,
        causal=True,
        window=cfg.attn_window,
        q_block=cfg.attn_q_block,
        kv_block=cfg.attn_kv_block,
        softcap=cfg.attn_logit_softcap,
    )
    x = x + shard(layers.out_proj(p["attn"], o), "dp", "sp", None)
    h2 = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
    h2 = shard(h2, "dp", None, None)
    aux = {}
    if cfg.family == "moe":
        ff, aux = moe.moe_apply(p["moe"], h2, cfg)
    else:
        ff = layers.mlp_apply(p["mlp"], h2, _mlp_act(cfg))
    x = x + shard(ff, "dp", "sp", None)
    kv = None
    if want_kv:
        kv = (k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3))  # (B,KV,S,hd)
    return x, aux, kv


def apply_positions(x: jax.Array, positions, cfg: ModelConfig) -> jax.Array:
    if not cfg.rope_theta:
        return x
    return layers.apply_rope(x, positions, cfg.rope_theta)


def dense_block_decode(p, x: jax.Array, cfg: ModelConfig, kc, vc, pos,
                       ks=None, vs=None):
    """Decode path. x (B,D); kc/vc (B,KV,S,hd) (int8 when quantized, with
    ks/vs scales (B,KV,S,1)); pos (B,). Returns (x, kc, vc, ks, vs)."""
    ctx = get_ctx()
    quant = ks is not None
    h = layers.rms_norm(x[:, None], p["ln1"], cfg.norm_eps)  # (B,1,D)
    q, k, v = layers.qkv_split(p["attn"], h, cfg)
    q = apply_positions(q, pos[:, None], cfg)
    k = apply_positions(k, pos[:, None], cfg)
    q1, k1, v1 = q[:, 0], k[:, 0], v[:, 0]                   # (B,H,hd)/(B,KV,hd)
    if quant:
        k1q, k1s = attn.quantize_kv(k1)
        v1q, v1s = attn.quantize_kv(v1)
        kc = attn.cache_scatter_update(kc, k1q, pos)
        vc = attn.cache_scatter_update(vc, v1q, pos)
        ks = attn.cache_scatter_update(ks, k1s, pos)
        vs = attn.cache_scatter_update(vs, v1s, pos)
        kc_a = attn.dequantize_kv(kc, ks, k1.dtype)
        vc_a = attn.dequantize_kv(vc, vs, v1.dtype)
    else:
        kc = attn.cache_scatter_update(kc, k1, pos)
        vc = attn.cache_scatter_update(vc, v1, pos)
        kc_a, vc_a = kc, vc
    s = kc.shape[2]
    tp = ctx.mesh.shape[ctx.tp_axis] if (ctx.mesh and ctx.tp_axis) else 1
    if ctx.mesh is not None and tp > 1 and s % tp == 0:
        o = attn.flash_decode_attention(
            ctx.mesh, q1, kc_a, vc_a, pos,
            seq_axis=ctx.tp_axis,
            batch_axes=(ctx.dp_axes if ctx.shard_batch else ()),
            window=cfg.attn_window, softcap=cfg.attn_logit_softcap,
        )
    else:
        o = attn.plain_decode_attention(
            q1, kc_a, vc_a, pos, window=cfg.attn_window,
            softcap=cfg.attn_logit_softcap,
        )
    x = x + layers.out_proj(p["attn"], o[:, None])[:, 0]
    h2 = layers.rms_norm(x[:, None], p["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        ff, _ = moe.moe_apply(p["moe"], h2, cfg, no_drop=True)
    else:
        ff = layers.mlp_apply(p["mlp"], h2, _mlp_act(cfg))
    return x + ff[:, 0], kc, vc, ks, vs


# ----------------------------------------------------------------- LM skeleton


def lm_init(rng, cfg: ModelConfig) -> Params:
    dtype = layers.dtype_of(cfg.param_dtype)
    ke, kb, kh, kv_ = jax.random.split(rng, 4)
    p: dict[str, Any] = {
        "embed": layers.embed_init(ke, cfg.vocab_size, cfg.d_model, dtype),
        "final_ln": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = (
            jax.random.normal(kh, (cfg.d_model, cfg.vocab_size)) / np.sqrt(cfg.d_model)
        ).astype(dtype)
    if cfg.family in ("dense", "moe", "vlm"):
        p["blocks"] = layers.stack_layer_init(
            kb, cfg.num_layers, lambda r: dense_block_init(r, cfg, dtype)
        )
    elif cfg.family == "rwkv":
        p["blocks"] = layers.stack_layer_init(
            kb, cfg.num_layers, lambda r: rwkv.rwkv_block_init(r, cfg, dtype)
        )
    elif cfg.family == "hybrid":
        p.update(_hybrid_init(kb, cfg, dtype))
    else:
        raise ValueError(cfg.family)
    if cfg.family == "vlm":
        p["patch_proj"] = (
            jax.random.normal(kv_, (cfg.vision.patch_dim, cfg.d_model))
            / np.sqrt(cfg.vision.patch_dim)
        ).astype(dtype)
    return p


def embed_tokens(params, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.family == "hybrid":  # gemma-style embedding scale
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    return shard(x, "dp", "sp", None)


def lm_logits(params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    x = layers.rms_norm(x, params["final_ln"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("...d,dv->...v", x, head.astype(x.dtype))
    return shard(logits, "dp", None, "tp") if logits.ndim == 3 else logits


# ------------------------------------------------------- dense/moe/vlm forward


def _scan_blocks(params, cfg, x, positions, *, want_kv, remat: str = "none"):
    ctx = get_ctx()
    if (getattr(ctx, "prefetch_params", False) and ctx.gather_params is not None
            and not want_kv and cfg.num_layers > 1):
        return _scan_blocks_prefetch(params, cfg, x, positions, remat=remat)

    def body(carry, bp):
        h, aux_acc = carry
        bp = maybe_gather_params(bp)  # FSDP gather (paper schedule) if active
        h, aux, kv = dense_block_apply(bp, h, cfg, positions=positions, want_kv=want_kv)
        aux_acc = {k: aux_acc.get(k, 0.0) + v for k, v in aux.items()} if aux else aux_acc
        return (h, aux_acc), kv

    aux0 = (
        {"moe_aux": 0.0, "moe_zloss": 0.0, "moe_drop_frac": 0.0}
        if cfg.family == "moe"
        else {}
    )
    fn = body
    if remat == "full":
        fn = jax.checkpoint(body, prevent_cse=False)
    elif remat == "dots":
        fn = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots, prevent_cse=False
        )
    (x, aux), kvs = jax.lax.scan(fn, (x, aux0), params["blocks"])
    if cfg.family == "moe":
        aux = {k: v / cfg.num_layers for k, v in aux.items()}
    return x, aux, kvs


def _scan_blocks_prefetch(params, cfg, x, positions, *, remat: str = "none"):
    """Explicit compute/gather overlap (the paper's interleaved-collectives
    discipline): the scan carry holds the ALREADY-GATHERED params of layer i;
    each step first issues the gather of layer i+1 (a ppermute chain with no
    data dependency on the block compute), then computes layer i — XLA's
    scheduler runs the two concurrently. Train path only (no kv cache)."""
    blocks = params["blocks"]
    first = jax.tree.map(lambda l: l[0], blocks)
    rest = jax.tree.map(lambda l: l[1:], blocks)
    g0 = maybe_gather_params(first)
    aux0 = (
        {"moe_aux": 0.0, "moe_zloss": 0.0, "moe_drop_frac": 0.0}
        if cfg.family == "moe"
        else {}
    )

    def body(carry, bp_next_raw):
        h, aux_acc, gathered = carry
        g_next = maybe_gather_params(bp_next_raw)   # prefetch layer i+1
        h, aux, _ = dense_block_apply(gathered, h, cfg, positions=positions,
                                      want_kv=False)
        aux_acc = {k: aux_acc.get(k, 0.0) + v for k, v in aux.items()} if aux else aux_acc
        return (h, aux_acc, g_next), None

    fn = body
    if remat == "full":
        fn = jax.checkpoint(body, prevent_cse=False)
    elif remat == "dots":
        fn = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots, prevent_cse=False
        )
    (x, aux, g_last), _ = jax.lax.scan(fn, (x, aux0, g0), rest)
    x, aux_l, _ = dense_block_apply(g_last, x, cfg, positions=positions,
                                    want_kv=False)
    if aux_l:
        aux = {k: aux.get(k, 0.0) + v for k, v in aux_l.items()}
    if cfg.family == "moe":
        aux = {k: v / cfg.num_layers for k, v in aux.items()}
    return x, aux, None


def dense_forward(params, cfg: ModelConfig, batch, *, want_cache=False, remat="none"):
    """batch: tokens (B,S) [+ patches (B,Np,pd) for vlm]. Returns (logits, aux, cache)."""
    tokens = batch["tokens"]
    x = embed_tokens(params, cfg, tokens)
    if cfg.family == "vlm":
        patches = jnp.einsum(
            "bpe,ed->bpd", batch["patches"].astype(x.dtype), params["patch_proj"]
        )
        x = jnp.concatenate([patches, x], axis=1)
        x = shard(x, "dp", "sp", None)
    s = x.shape[1]
    positions = jnp.arange(s)[None, :]
    x, aux, kvs = _scan_blocks(params, cfg, x, positions, want_kv=want_cache, remat=remat)
    cache = None
    if want_cache:
        if cfg.kv_cache_dtype == "int8":
            kq, ks_ = attn.quantize_kv(kvs[0])
            vq, vs_ = attn.quantize_kv(kvs[1])
            cache = {"k": kq, "v": vq, "ks": ks_, "vs": vs_}
        else:
            cache = {"k": kvs[0], "v": kvs[1]}  # (L,B,KV,S,hd)
    return x, aux, cache


def dense_decode_step(params, cfg: ModelConfig, cache, token, pos):
    """token (B,), pos (B,). Returns (logits (B,V), new cache)."""
    x = embed_tokens(params, cfg, token[:, None])[:, 0]     # (B,D)
    quant = "ks" in cache

    if quant:
        def body(h, xs):
            bp, kc, vc, ks, vs = xs
            h, kc, vc, ks, vs = dense_block_decode(bp, h, cfg, kc, vc, pos, ks, vs)
            return h, (kc, vc, ks, vs)

        x, (kcs, vcs, kss, vss) = jax.lax.scan(
            body, x,
            (params["blocks"], cache["k"], cache["v"], cache["ks"], cache["vs"]),
        )
        new_cache = {"k": kcs, "v": vcs, "ks": kss, "vs": vss}
    else:
        def body(h, xs):
            bp, kc, vc = xs
            h, kc, vc, _, _ = dense_block_decode(bp, h, cfg, kc, vc, pos)
            return h, (kc, vc)

        x, (kcs, vcs) = jax.lax.scan(
            body, x, (params["blocks"], cache["k"], cache["v"])
        )
        new_cache = {"k": kcs, "v": vcs}
    logits = lm_logits(params, cfg, x[:, None])[:, 0]
    return logits, new_cache


def dense_init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype):
    shp = (cfg.num_layers, batch, cfg.num_kv_heads, seq_len, cfg.head_dim)
    if cfg.kv_cache_dtype == "int8":
        sshp = shp[:-1] + (1,)
        return {
            "k": jnp.zeros(shp, jnp.int8), "v": jnp.zeros(shp, jnp.int8),
            "ks": jnp.zeros(sshp, jnp.float32), "vs": jnp.zeros(sshp, jnp.float32),
        }
    return {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}


# --------------------------------------------------------------- rwkv forward


def rwkv_forward(params, cfg: ModelConfig, batch, *, want_cache=False, remat="none"):
    x = embed_tokens(params, cfg, batch["tokens"])

    def body(h, bp):
        bp = maybe_gather_params(bp)
        h, st = rwkv.rwkv_block_apply(bp, h, cfg, state=None, chunked=True)
        return h, (st if want_cache else None)

    fn = jax.checkpoint(body, prevent_cse=False) if remat == "full" else body
    x, sts = jax.lax.scan(fn, x, params["blocks"])
    return x, {}, (sts if want_cache else None)


def rwkv_decode_step(params, cfg: ModelConfig, cache, token, pos):
    x = embed_tokens(params, cfg, token[:, None])[:, 0]

    def body(h, xs):
        bp, st = xs
        h2, st2 = rwkv.rwkv_block_apply(bp, h[:, None], cfg, state=st, chunked=False)
        return h2[:, 0], st2

    x, sts = jax.lax.scan(body, x, (params["blocks"], cache))
    logits = lm_logits(params, cfg, x[:, None])[:, 0]
    return logits, sts


def rwkv_init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype):
    h, hd, d = cfg.num_heads, cfg.head_dim, cfg.d_model
    return {
        "wkv": jnp.zeros((cfg.num_layers, batch, h, hd, hd), jnp.float32),
        "tm_x": jnp.zeros((cfg.num_layers, batch, d), dtype),
        "cm_x": jnp.zeros((cfg.num_layers, batch, d), dtype),
    }


# -------------------------------------------------------------- hybrid forward


def _hybrid_layout(cfg: ModelConfig) -> tuple[int, int]:
    """(n_groups of the repeating pattern, n_trailing_rec)."""
    plen = len(cfg.rglru.pattern)
    return cfg.num_layers // plen, cfg.num_layers % plen


def _hybrid_attn_layer_init(rng, cfg, dtype):
    k1, k2 = jax.random.split(rng)
    return {
        "ln1": jnp.zeros((cfg.d_model,), jnp.float32),
        "attn": layers.attn_proj_init(k1, cfg, dtype),
        "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
        "mlp": layers.mlp_init(k2, cfg.d_model, cfg.d_ff, "swiglu", dtype),
    }


def _hybrid_rec_layer_init(rng, cfg, dtype):
    k1, k2 = jax.random.split(rng)
    return {
        "rec": rglru.rec_block_init(k1, cfg, dtype),
        "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
        "mlp": layers.mlp_init(k2, cfg.d_model, cfg.d_ff, "swiglu", dtype),
    }


def _hybrid_init(rng, cfg: ModelConfig, dtype):
    ng, nt = _hybrid_layout(cfg)
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "grp_rec_a": layers.stack_layer_init(
            k1, ng, lambda r: _hybrid_rec_layer_init(r, cfg, dtype)
        ),
        "grp_rec_b": layers.stack_layer_init(
            jax.random.fold_in(k1, 1), ng, lambda r: _hybrid_rec_layer_init(r, cfg, dtype)
        ),
        "grp_attn": layers.stack_layer_init(
            k2, ng, lambda r: _hybrid_attn_layer_init(r, cfg, dtype)
        ),
        "tail_rec": layers.stack_layer_init(
            k3, max(nt, 1), lambda r: _hybrid_rec_layer_init(r, cfg, dtype)
        ),
    }


def _hybrid_rec_apply(p, x, cfg, state):
    x, st = rglru.rec_block_apply(p["rec"], x, cfg, state=state)
    h = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
    return x + shard(layers.mlp_apply(p["mlp"], h, "swiglu"), "dp", "sp", None), st


def _hybrid_attn_apply(p, x, cfg, positions, want_kv):
    h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = layers.qkv_split(p["attn"], h, cfg)
    q = apply_positions(q, positions, cfg)
    k = apply_positions(k, positions, cfg)
    o = attn.blockwise_attention(
        q, k, v, causal=True, window=cfg.rglru.window,
        q_block=cfg.attn_q_block, kv_block=cfg.attn_kv_block,
    )
    x = x + shard(layers.out_proj(p["attn"], o), "dp", "sp", None)
    h2 = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + shard(layers.mlp_apply(p["mlp"], h2, "swiglu"), "dp", "sp", None)
    kv = (k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)) if want_kv else None
    return x, kv


def hybrid_forward(params, cfg: ModelConfig, batch, *, want_cache=False, remat="none"):
    x = embed_tokens(params, cfg, batch["tokens"])
    positions = jnp.arange(x.shape[1])[None, :]
    ng, nt = _hybrid_layout(cfg)

    def body(h, gp):
        gp = maybe_gather_params(gp)
        h, st_a = _hybrid_rec_apply(gp["grp_rec_a"], h, cfg, None)
        h, st_b = _hybrid_rec_apply(gp["grp_rec_b"], h, cfg, None)
        h, kv = _hybrid_attn_apply(gp["grp_attn"], h, cfg, positions, want_cache)
        ys = (st_a, st_b, kv) if want_cache else None
        return h, ys

    fn = jax.checkpoint(body, prevent_cse=False) if remat == "full" else body
    xs = {k: params[k] for k in ("grp_rec_a", "grp_rec_b", "grp_attn")}
    x, ys = jax.lax.scan(fn, x, xs)

    def tail(h, tp_):
        tp_ = maybe_gather_params(tp_)
        h, st = _hybrid_rec_apply(tp_, h, cfg, None)
        return h, (st if want_cache else None)

    tfn = jax.checkpoint(tail, prevent_cse=False) if remat == "full" else tail
    if nt:
        x, tail_sts = jax.lax.scan(tfn, x, params["tail_rec"])
    else:
        tail_sts = None
    cache = None
    if want_cache:
        st_a, st_b, kv = ys
        cache = {
            "rec_a": st_a, "rec_b": st_b,
            "attn_k": _window_clip(kv[0], cfg), "attn_v": _window_clip(kv[1], cfg),
            "tail": tail_sts,
        }
    return x, {}, cache


def _window_clip(kv, cfg: ModelConfig):
    """Keep only the trailing window of prefill KV (hybrid decode needs <= W)."""
    w = cfg.rglru.window
    s = kv.shape[3]
    return kv[:, :, :, max(0, s - w):] if s > w else kv


def hybrid_decode_step(params, cfg: ModelConfig, cache, token, pos):
    x = embed_tokens(params, cfg, token[:, None])[:, 0]
    ng, nt = _hybrid_layout(cfg)

    def rec_step(h, p, st):
        h2, st2 = _hybrid_rec_apply(p, h[:, None], cfg, st)
        return h2[:, 0], st2

    def attn_step(h, p, kc, vc):
        hh = layers.rms_norm(h[:, None], p["ln1"], cfg.norm_eps)
        q, k, v = layers.qkv_split(p["attn"], hh, cfg)
        q = apply_positions(q, pos[:, None], cfg)
        k = apply_positions(k, pos[:, None], cfg)
        w = kc.shape[2]
        slot = pos % w
        kc = attn.cache_scatter_update(kc, k[:, 0], slot)
        vc = attn.cache_scatter_update(vc, v[:, 0], slot)
        # ring-buffer positions: absolute position stored at slot s is the
        # largest p' <= pos with p' % w == s
        idx = jnp.arange(w)
        abs_pos = pos[:, None] - ((pos[:, None] - idx[None, :]) % w)
        o = attn.ring_decode_attention(q[:, 0], kc, vc, abs_pos, pos, cfg.rglru.window)
        h = h + layers.out_proj(p["attn"], o[:, None])[:, 0]
        h2 = layers.rms_norm(h[:, None], p["ln2"], cfg.norm_eps)
        return h + layers.mlp_apply(p["mlp"], h2, "swiglu")[:, 0], kc, vc

    def body(h, xs):
        gp, st_a, st_b, kc, vc = xs
        h, st_a = rec_step(h, gp["grp_rec_a"], st_a)
        h, st_b = rec_step(h, gp["grp_rec_b"], st_b)
        h, kc, vc = attn_step(h, gp["grp_attn"], kc, vc)
        return h, (st_a, st_b, kc, vc)

    xs = (
        {k: params[k] for k in ("grp_rec_a", "grp_rec_b", "grp_attn")},
        cache["rec_a"], cache["rec_b"], cache["attn_k"], cache["attn_v"],
    )
    x, (st_a, st_b, kcs, vcs) = jax.lax.scan(body, x, xs)

    def tail_body(h, xs):
        tp_, st = xs
        h, st = rec_step(h, tp_, st)
        return h, st

    if nt:
        x, tail_sts = jax.lax.scan(tail_body, x, (params["tail_rec"], cache["tail"]))
    else:
        tail_sts = cache["tail"]
    logits = lm_logits(params, cfg, x[:, None])[:, 0]
    return logits, {
        "rec_a": st_a, "rec_b": st_b, "attn_k": kcs, "attn_v": vcs, "tail": tail_sts,
    }


def hybrid_init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype):
    ng, nt = _hybrid_layout(cfg)
    w = min(cfg.rglru.window, seq_len)
    lru = cfg.rglru.lru_width
    kcw = cfg.rglru.conv_width - 1

    def rec_state(n):
        return {
            "h": jnp.zeros((n, batch, lru), jnp.float32),
            "conv": jnp.zeros((n, batch, kcw, lru), dtype),
        }

    return {
        "rec_a": rec_state(ng),
        "rec_b": rec_state(ng),
        "attn_k": jnp.zeros((ng, batch, cfg.num_kv_heads, w, cfg.head_dim), dtype),
        "attn_v": jnp.zeros((ng, batch, cfg.num_kv_heads, w, cfg.head_dim), dtype),
        "tail": rec_state(max(nt, 1)),
    }
