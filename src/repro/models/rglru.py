"""RecurrentGemma / Griffin building blocks: RG-LRU recurrent block with
temporal conv, gated branches; local-attention blocks live in attention.py.

RG-LRU (diagonal linear recurrence, associative-scan friendly):
    r_t = sigmoid(W_a x_t);  i_t = sigmoid(W_x x_t)
    a_t = exp(-c * softplus(lam) * r_t)                 (c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Decode is a single-step update: state = h (B, lru_width) + conv tail — O(1)
per token, which is what lets long_500k run.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

RGLRU_C = 8.0


def rglru_scan(a: jax.Array, b: jax.Array, h0: jax.Array | None = None):
    """h_t = a_t h_{t-1} + b_t along axis 1. a,b: (B,S,W). Returns (h (B,S,W), h_last)."""
    if h0 is not None:
        # fold initial state into the first step
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h, h[:, -1]


def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array, tail: jax.Array | None = None):
    """Depthwise causal conv along seq. x (B,S,W), w (K,W), tail (B,K-1,W) or None.
    Returns (y (B,S,W), new_tail (B,K-1,W))."""
    kw = w.shape[0]
    pad = (
        jnp.zeros((x.shape[0], kw - 1, x.shape[2]), x.dtype) if tail is None else tail
    )
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+K-1, W)
    y = sum(
        xp[:, i : i + x.shape[1]] * w[i].astype(x.dtype) for i in range(kw)
    ) + b.astype(x.dtype)
    new_tail = xp[:, -(kw - 1) :] if kw > 1 else jnp.zeros_like(pad)
    return y, new_tail


def rec_block_init(rng, cfg: ModelConfig, dtype):
    d = cfg.d_model
    w = cfg.rglru.lru_width
    kw = cfg.rglru.conv_width
    ks = jax.random.split(rng, 8)
    s = 1.0 / np.sqrt(d)
    sw = 1.0 / np.sqrt(w)
    return {
        "ln": jnp.zeros((d,), jnp.float32),
        "w_gate_in": (jax.random.normal(ks[0], (d, w)) * s).astype(dtype),
        "w_rec_in": (jax.random.normal(ks[1], (d, w)) * s).astype(dtype),
        "conv_w": (jax.random.normal(ks[2], (kw, w)) * sw).astype(dtype),
        "conv_b": jnp.zeros((w,), jnp.float32),
        "lru_a_gate": (jax.random.normal(ks[3], (w, w)) * sw).astype(dtype),
        "lru_a_bias": jnp.zeros((w,), jnp.float32),
        "lru_x_gate": (jax.random.normal(ks[4], (w, w)) * sw).astype(dtype),
        "lru_x_bias": jnp.zeros((w,), jnp.float32),
        # lambda parametrized so a^2 is uniform-ish in (0.9, 0.999) at r=1
        "lru_lam": (jax.random.uniform(ks[5], (w,)) * 2.0 + 2.0).astype(jnp.float32),
        "w_out": (jax.random.normal(ks[6], (w, d)) * sw).astype(dtype),
    }


def rec_block_param_count(cfg: ModelConfig) -> int:
    d, w = cfg.d_model, cfg.rglru.lru_width
    kw = cfg.rglru.conv_width
    return 2 * d * w + kw * w + 2 * w * w + w * d + 5 * w + d


def rglru_apply(p, x: jax.Array, h0=None):
    """Core RG-LRU. x (B,S,W) post-conv. Returns (y, h_last)."""
    x32 = x.astype(jnp.float32)
    r = jax.nn.sigmoid(
        jnp.einsum("bsw,wv->bsv", x32, p["lru_a_gate"].astype(jnp.float32))
        + p["lru_a_bias"]
    )
    i = jax.nn.sigmoid(
        jnp.einsum("bsw,wv->bsv", x32, p["lru_x_gate"].astype(jnp.float32))
        + p["lru_x_bias"]
    )
    log_a = -RGLRU_C * jax.nn.softplus(p["lru_lam"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * x32)
    h, h_last = rglru_scan(a, b, h0)
    return h.astype(x.dtype), h_last


def rec_block_apply(p, x: jax.Array, cfg: ModelConfig, *, state=None):
    """Full Griffin recurrent block. state = None | dict(h (B,W) f32, conv (B,K-1,W)).
    Returns (x_out, new_state)."""
    from repro.models import layers

    dt = x.dtype
    h = layers.rms_norm(x, p["ln"], 1e-6)
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", h, p["w_gate_in"].astype(dt)))
    rec = jnp.einsum("bsd,dw->bsw", h, p["w_rec_in"].astype(dt))
    tail = None if state is None else state["conv"]
    rec, new_tail = causal_conv1d(rec, p["conv_w"], p["conv_b"], tail)
    h0 = None if state is None else state["h"]
    rec, h_last = rglru_apply(p, rec, h0)
    out = jnp.einsum("bsw,wd->bsd", gate * rec, p["w_out"].astype(dt))
    return x + out, {"h": h_last, "conv": new_tail}
