"""Fine-grained Mixture-of-Experts (DeepSeekMoE / Moonlight style).

Token-choice top-k routing with GShard-style capacity dropping, expressed as
static-shape gather/scatter so it lowers cleanly under pjit:

  router -> top_k(gates) -> position-in-expert (cumsum) -> capacity drop
  -> dispatch gather (E, C, D) -> per-expert FFN einsum -> combine scatter-add.

Experts are sharded over the ``model`` mesh axis (EP); the dispatch/combine
gathers become the EP collective traffic the paper's scheduler interleaves
with the FSDP allgather/reduce-scatter streams.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers


def moe_init(rng, cfg: ModelConfig, dtype):
    m = cfg.moe
    d = cfg.d_model
    fe = m.d_ff_expert
    kr, ks, kg = jax.random.split(rng, 3)
    s_in = 1.0 / np.sqrt(d)
    s_out = 1.0 / np.sqrt(fe)
    k1, k2, k3 = jax.random.split(kr, 3)
    p = {
        "router": (jax.random.normal(kg, (d, m.n_routed_experts)) * s_in).astype(
            jnp.float32
        ),
        "w_gate": (jax.random.normal(k1, (m.n_routed_experts, d, fe)) * s_in).astype(dtype),
        "w_up": (jax.random.normal(k2, (m.n_routed_experts, d, fe)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(k3, (m.n_routed_experts, fe, d)) * s_out).astype(dtype),
    }
    if m.n_shared_experts:
        p["shared"] = layers.mlp_init(ks, d, fe * m.n_shared_experts, "swiglu", dtype)
    return p


def moe_param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    m = cfg.moe
    n_routed = m.top_k if active_only else m.n_routed_experts
    routed = n_routed * 3 * cfg.d_model * m.d_ff_expert
    shared = 3 * cfg.d_model * m.d_ff_expert * m.n_shared_experts
    router = cfg.d_model * m.n_routed_experts
    return routed + shared + router


def moe_apply(p, x: jax.Array, cfg: ModelConfig, *, no_drop: bool = False):
    """x: (B, S, D) -> (out (B, S, D), aux_metrics dict).

    ``no_drop=True`` (decode path): capacity = T so routing never drops —
    single-token decode must be exact, not capacity-truncated.
    """
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    e, k = m.n_routed_experts, m.top_k
    cap = t if no_drop else int(np.ceil(t * k / e * m.capacity_factor))
    xt = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    if m.routing_groups and m.routing_groups > 1:
        # device-limited routing (DeepSeek-V3 style): keep only the top
        # ``routing_group_topk`` expert groups per token, bounding cross-EP
        # dispatch copies per token by the group count.
        g = m.routing_groups
        gs = e // g
        grp = probs.reshape(t, g, gs)
        # group score = sum of top-2 experts within the group
        top2 = jax.lax.top_k(grp, min(2, gs))[0].sum(-1)          # (T, g)
        _, gsel = jax.lax.top_k(top2, m.routing_group_topk)        # (T, G_act)
        gmask = jnp.zeros((t, g), bool).at[
            jnp.arange(t)[:, None], gsel
        ].set(True)
        probs = (grp * gmask[..., None]).reshape(t, e)
    gates, expert_idx = jax.lax.top_k(probs, k)          # (T, k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)

    # position of each (token, choice) within its expert, in token order
    flat_e = expert_idx.reshape(t * k)                    # (T*k,)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)   # (T*k, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) - 1             # (T*k, E)
    pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    keep = pos < cap

    # dispatch table: slot (E*C) -> source token id (+ validity)
    dest = flat_e * cap + jnp.where(keep, pos, 0)
    token_id = jnp.repeat(jnp.arange(t), k)
    disp_tok = jnp.zeros((e * cap,), jnp.int32).at[dest].set(
        jnp.where(keep, token_id, 0), mode="drop"
    )
    disp_valid = jnp.zeros((e * cap,), jnp.bool_).at[dest].set(keep, mode="drop")
    disp_gate = jnp.zeros((e * cap,), jnp.float32).at[dest].set(
        jnp.where(keep, gates.reshape(t * k), 0.0), mode="drop"
    )

    xs = jnp.take(xt, disp_tok, axis=0)                   # (E*C, D)
    xs = jnp.where(disp_valid[:, None], xs, 0).reshape(e, cap, d)

    dt = x.dtype
    g = jnp.einsum("ecd,edf->ecf", xs, p["w_gate"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", xs, p["w_up"].astype(dt))
    ys = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, p["w_down"].astype(dt))
    ys = ys.reshape(e * cap, d) * disp_gate[:, None].astype(dt)

    out = jnp.zeros((t, d), dt).at[disp_tok].add(
        jnp.where(disp_valid[:, None], ys, 0)
    )

    if m.n_shared_experts:
        out = out + layers.mlp_apply(p["shared"], xt, "swiglu")

    # aux losses (Switch-style load balance + router z-loss)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(expert_idx, e, dtype=jnp.float32), axis=(0, 1)
    )  # mean over (T, k)
    frac_probs = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac_tokens * frac_probs)
    zloss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    metrics = {
        "moe_aux": aux * m.router_aux_coef,
        "moe_zloss": zloss * m.router_z_coef,
        "moe_drop_frac": dropped,
    }
    return out.reshape(b, s, d), metrics
