"""Shared model primitives: norms, activations, RoPE, initializers.

All models are pure-functional: params are pytrees of jnp arrays, layer stacks
are stored with a leading ``L`` dim and consumed by ``jax.lax.scan``.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

Params = Any  # pytree


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[name]


# ---------------------------------------------------------------- norms / act


def rms_norm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(dt)


def activation(name: str, x: jax.Array) -> jax.Array:
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "silu":
        return jax.nn.silu(x)
    if name == "relu_sq":
        return jnp.square(jax.nn.relu(x))
    raise ValueError(name)


def mlp_apply(p: Params, x: jax.Array, act: str) -> jax.Array:
    """SwiGLU (w_gate/w_up/w_down) or plain 2-layer MLP (w_in/w_out)."""
    dt = x.dtype
    if "w_gate" in p:
        g = jnp.einsum("...d,df->...f", x, p["w_gate"].astype(dt))
        u = jnp.einsum("...d,df->...f", x, p["w_up"].astype(dt))
        h = jax.nn.silu(g) * u
        return jnp.einsum("...f,fd->...d", h, p["w_down"].astype(dt))
    h = jnp.einsum("...d,df->...f", x, p["w_in"].astype(dt))
    h = activation(act, h)
    return jnp.einsum("...f,fd->...d", h, p["w_out"].astype(dt))


def mlp_init(rng, d_model: int, d_ff: int, act: str, dtype) -> Params:
    k1, k2, k3 = jax.random.split(rng, 3)
    s_in = 1.0 / np.sqrt(d_model)
    s_out = 1.0 / np.sqrt(d_ff)
    if act == "swiglu":
        return {
            "w_gate": (jax.random.normal(k1, (d_model, d_ff)) * s_in).astype(dtype),
            "w_up": (jax.random.normal(k2, (d_model, d_ff)) * s_in).astype(dtype),
            "w_down": (jax.random.normal(k3, (d_ff, d_model)) * s_out).astype(dtype),
        }
    return {
        "w_in": (jax.random.normal(k1, (d_model, d_ff)) * s_in).astype(dtype),
        "w_out": (jax.random.normal(k2, (d_ff, d_model)) * s_out).astype(dtype),
    }


def mlp_param_count(d_model: int, d_ff: int, act: str) -> int:
    return d_model * d_ff * (3 if act == "swiglu" else 2)


# ----------------------------------------------------------------------- RoPE


def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    if not theta:
        return x
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta))          # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]                    # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, d_model: int) -> np.ndarray:
    pos = np.arange(seq_len, dtype=np.float32)[:, None]
    dim = np.arange(0, d_model, 2, dtype=np.float32)[None, :]
    ang = pos / np.power(10000.0, dim / d_model)
    out = np.zeros((seq_len, d_model), dtype=np.float32)
    out[:, 0::2] = np.sin(ang)
    out[:, 1::2] = np.cos(ang)
    return out


# ----------------------------------------------------------------- attention proj


def attn_proj_init(rng, cfg: ModelConfig, dtype, *, cross: bool = False) -> Params:
    kq, kk, kv, ko = jax.random.split(rng, 4)
    d, h, kvh, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    s = 1.0 / np.sqrt(d)
    so = 1.0 / np.sqrt(h * hd)
    return {
        "wq": (jax.random.normal(kq, (d, h * hd)) * s).astype(dtype),
        "wk": (jax.random.normal(kk, (d, kvh * hd)) * s).astype(dtype),
        "wv": (jax.random.normal(kv, (d, kvh * hd)) * s).astype(dtype),
        "wo": (jax.random.normal(ko, (h * hd, d)) * so).astype(dtype),
    }


def attn_param_count(cfg: ModelConfig) -> int:
    d, h, kvh, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return d * h * hd * 2 + d * kvh * hd * 2


def qkv_split(p: Params, x: jax.Array, cfg: ModelConfig):
    """x: (B, S, D) -> q (B,S,H,hd), k/v (B,S,KV,hd)."""
    dt = x.dtype
    b, s, _ = x.shape
    q = jnp.einsum("bsd,de->bse", x, p["wq"].astype(dt)).reshape(
        b, s, cfg.num_heads, cfg.head_dim
    )
    k = jnp.einsum("bsd,de->bse", x, p["wk"].astype(dt)).reshape(
        b, s, cfg.num_kv_heads, cfg.head_dim
    )
    v = jnp.einsum("bsd,de->bse", x, p["wv"].astype(dt)).reshape(
        b, s, cfg.num_kv_heads, cfg.head_dim
    )
    return q, k, v


def out_proj(p: Params, attn_out: jax.Array) -> jax.Array:
    """attn_out: (B, S, H, hd) -> (B, S, D)."""
    b, s, h, hd = attn_out.shape
    return jnp.einsum("bse,ed->bsd", attn_out.reshape(b, s, h * hd), p["wo"].astype(attn_out.dtype))


# ------------------------------------------------------------------ stacked init


def stack_layer_init(rng, n_layers: int, init_one):
    """Initialize ``n_layers`` copies of a layer and stack each leaf on axis 0."""
    rngs = jax.random.split(rng, n_layers)
    layers = [init_one(r) for r in rngs]
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *layers)


def embed_init(rng, vocab: int, d_model: int, dtype) -> jax.Array:
    return (jax.random.normal(rng, (vocab, d_model)) * 0.02).astype(dtype)


def softmax_xent(logits: jax.Array, targets: jax.Array, mask: jax.Array | None = None):
    """Mean token cross-entropy. logits (B,S,V) f32-upcast; targets (B,S) int."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        return jnp.sum(nll * mask) / denom
    return jnp.mean(nll)


def replace(cfg, **kw):
    return dataclasses.replace(cfg, **kw)
