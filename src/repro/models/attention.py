"""Attention: chunked online-softmax (flash-style) for train/prefill, and
sequence-parallel flash-decoding for the serve path.

Memory: the chunked path never materializes (S x S) scores — it scans over KV
blocks carrying the online-softmax state (m, l, acc), so the working set is
O(S * q_block) per step. Causality/windowing is applied as a block mask; fully
masked-out KV blocks still cost FLOPs in the baseline (recorded as a §Perf
hillclimb opportunity in EXPERIMENTS.md).

Decode: KV caches are laid out (B, KV, S, hd) with the sequence dim sharded
over the ``model`` mesh axis. ``flash_decode`` computes per-shard partial
attention with a log-sum-exp combine over the axis (the TPU analogue of
flash-decoding), so a 32k-context cache never needs gathering.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat

NEG_INF = -1e30


def _block_mask(q_pos, k_pos, *, causal: bool, window: Optional[int]):
    """q_pos (qb,), k_pos (kb,) -> bool (qb, kb); True = attend."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        m &= q_pos[:, None] - k_pos[None, :] < window
    return m


def chunked_attention(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Skv, KV, hd)
    v: jax.Array,  # (B, Skv, KV, hd)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    kv_block: int = 1024,
    q_offset: int = 0,
    softcap: Optional[float] = None,
) -> jax.Array:
    """Online-softmax attention, scanning over KV blocks. Returns (B, Sq, H, hd)."""
    b, sq, h, hd = q.shape
    _, skv, kvh, _ = k.shape
    assert h % kvh == 0
    qpkv = h // kvh
    kv_block = min(kv_block, skv)
    # pad kv to a block multiple
    nkb = -(-skv // kv_block)
    pad = nkb * kv_block - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(b, nkb, kv_block, kvh, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nkb, kv_block, kvh, hd).transpose(1, 0, 2, 3, 4)

    qq = q.reshape(b, sq, kvh, qpkv, hd).astype(jnp.float32)
    scale = hd ** -0.5
    q_pos = q_offset + jnp.arange(sq)

    def step(carry, inp):
        m, l, acc = carry
        kblk, vblk, kidx = inp  # (B, kb, KV, hd) x2, scalar block idx
        k_pos = kidx * kv_block + jnp.arange(kv_block)
        s = jnp.einsum(
            "bqkgh,bckh->bkgqc", qq, kblk.astype(jnp.float32)
        ) * scale  # (B, KV, G, Sq, kb)
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        mask = _block_mask(q_pos, k_pos, causal=causal, window=window)
        valid = k_pos < skv
        mask &= valid[None, :]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqc,bckh->bkgqh", p, vblk.astype(jnp.float32))
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kvh, qpkv, sq), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((b, kvh, qpkv, sq), dtype=jnp.float32)
    a0 = jnp.zeros((b, kvh, qpkv, sq, hd), dtype=jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kb, vb, jnp.arange(nkb)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]          # (B, KV, G, Sq, hd)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd)
    return out.astype(q.dtype)


def blockwise_attention(
    q: jax.Array,  # (B, S, H, hd)
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_block: int = 512,
    kv_block: int = 1024,
    softcap: Optional[float] = None,
) -> jax.Array:
    """Outer scan over Q blocks, inner online-softmax scan over KV blocks.

    Working set per step is O(q_block * kv_block) scores. For windowed
    attention each Q block slices a fixed-size KV window (no full-length scan).
    """
    b, s, h, hd = q.shape
    if s <= q_block:
        return chunked_attention(
            q, k, v, causal=causal, window=window, kv_block=kv_block, softcap=softcap
        )
    q_block = min(q_block, s)
    nqb = -(-s // q_block)
    pad = nqb * q_block - s
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qb = q.reshape(b, nqb, q_block, h, hd).transpose(1, 0, 2, 3, 4)

    if window is not None:
        # fixed-size KV slice per q block: [end - window - q_block, end)
        span = window + q_block
        span = min(-(-span // kv_block) * kv_block, k.shape[1])

        def step_w(_, inp):
            qblk, i = inp
            q_off = i * q_block
            start = jnp.clip(q_off + q_block - span, 0, k.shape[1] - span)
            ks = jax.lax.dynamic_slice_in_dim(k, start, span, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(v, start, span, axis=1)
            # positions inside the slice are start..start+span-1; causal+window
            # masks are computed from absolute positions via q_offset handling:
            out = _attend_block(
                qblk, ks, vs, q_off, start, causal=causal, window=window,
                kv_block=kv_block, softcap=softcap, skv_valid=k.shape[1],
            )
            return None, out

        _, outs = jax.lax.scan(step_w, None, (qb, jnp.arange(nqb)))
    else:

        def step(_, inp):
            qblk, i = inp
            out = _attend_block(
                qblk, k, v, i * q_block, 0, causal=causal, window=None,
                kv_block=kv_block, softcap=softcap, skv_valid=k.shape[1],
            )
            return None, out

        _, outs = jax.lax.scan(step, None, (qb, jnp.arange(nqb)))

    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, nqb * q_block, h, hd)
    return out[:, :s]


def _attend_block(
    qblk, k, v, q_off, kv_off, *, causal, window, kv_block, softcap, skv_valid
):
    """One q block against a KV range starting at absolute position kv_off."""
    b, sq, h, hd = qblk.shape
    skv = k.shape[1]
    kvh = k.shape[2]
    qpkv = h // kvh
    kv_block = min(kv_block, skv)
    nkb = -(-skv // kv_block)
    padk = nkb * kv_block - skv
    if padk:
        k = jnp.pad(k, ((0, 0), (0, padk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, padk), (0, 0), (0, 0)))
    kb = k.reshape(b, nkb, kv_block, kvh, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nkb, kv_block, kvh, hd).transpose(1, 0, 2, 3, 4)
    qq = qblk.reshape(b, sq, kvh, qpkv, hd).astype(jnp.float32)
    scale = hd ** -0.5
    q_pos = q_off + jnp.arange(sq)

    def inner(carry, inp):
        m, l, acc = carry
        kblk, vblk, j = inp
        k_pos = kv_off + j * kv_block + jnp.arange(kv_block)
        s = jnp.einsum("bqkgh,bckh->bkgqc", qq, kblk.astype(jnp.float32)) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        mask = _block_mask(q_pos, k_pos, causal=causal, window=window)
        mask &= (k_pos < skv_valid)[None, :]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqc,bckh->bkgqh", p, vblk.astype(jnp.float32))
        return (m_new, l_new, pv + acc * corr[..., None]), None

    m0 = jnp.full((b, kvh, qpkv, sq), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((b, kvh, qpkv, sq), dtype=jnp.float32)
    a0 = jnp.zeros((b, kvh, qpkv, sq, hd), dtype=jnp.float32)
    (m, l, acc), _ = jax.lax.scan(inner, (m0, l0, a0), (kb, vb, jnp.arange(nkb)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd).astype(qblk.dtype)


# --------------------------------------------------------------------- decode


def plain_decode_attention(
    q: jax.Array,       # (B, H, hd) — single new token per sequence
    k_cache: jax.Array,  # (B, KV, S, hd)
    v_cache: jax.Array,  # (B, KV, S, hd)
    pos: jax.Array,      # (B,) int32 — current positions (cache[0..pos] valid)
    *,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
) -> jax.Array:
    """Reference single-token decode over the full cache (no seq sharding)."""
    b, h, hd = q.shape
    _, kvh, s, _ = k_cache.shape
    qpkv = h // kvh
    qq = q.reshape(b, kvh, qpkv, hd).astype(jnp.float32)
    scores = jnp.einsum("bkgh,bksh->bkgs", qq, k_cache.astype(jnp.float32)) * hd ** -0.5
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    idx = jnp.arange(s)
    mask = idx[None, :] <= pos[:, None]
    if window is not None:
        mask &= idx[None, :] > pos[:, None] - window
    scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bksh->bkgh", p, v_cache.astype(jnp.float32))
    return out.reshape(b, h, hd).astype(q.dtype)


def flash_decode_attention(
    mesh: jax.sharding.Mesh,
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    pos: jax.Array,
    *,
    seq_axis: str = "model",
    batch_axes=("data",),
    window: Optional[int] = None,
    softcap: Optional[float] = None,
) -> jax.Array:
    """Sequence-parallel decode: cache seq dim sharded over ``seq_axis``;
    per-shard partial softmax states combined with an LSE merge (pmax/psum).
    """
    n_shards = mesh.shape[seq_axis]
    s = k_cache.shape[2]
    assert s % n_shards == 0, (s, n_shards)
    s_local = s // n_shards

    def shard_fn(q_l, k_l, v_l, pos_l):
        # q_l (Bl, H, hd); k_l/v_l (Bl, KV, S_local, hd); pos_l (Bl,)
        bl, h, hd = q_l.shape
        kvh = k_l.shape[1]
        qpkv = h // kvh
        shard_id = jax.lax.axis_index(seq_axis)
        offset = shard_id * s_local
        qq = q_l.reshape(bl, kvh, qpkv, hd).astype(jnp.float32)
        scores = jnp.einsum("bkgh,bksh->bkgs", qq, k_l.astype(jnp.float32)) * hd ** -0.5
        if softcap:
            scores = jnp.tanh(scores / softcap) * softcap
        idx = offset + jnp.arange(s_local)
        mask = idx[None, :] <= pos_l[:, None]
        if window is not None:
            mask &= idx[None, :] > pos_l[:, None] - window
        scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
        m_loc = jnp.max(scores, axis=-1)
        m_glob = jax.lax.pmax(m_loc, seq_axis)
        p = jnp.exp(scores - m_glob[..., None])
        l_loc = jnp.sum(p, axis=-1)
        acc = jnp.einsum("bkgs,bksh->bkgh", p, v_l.astype(jnp.float32))
        l_glob = jax.lax.psum(l_loc, seq_axis)
        acc_glob = jax.lax.psum(acc, seq_axis)
        out = acc_glob / jnp.maximum(l_glob, 1e-30)[..., None]
        return out.reshape(bl, h, hd).astype(q_l.dtype)

    dp = P(batch_axes)
    return compat.shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(
            P(batch_axes, None, None),
            P(batch_axes, None, seq_axis, None),
            P(batch_axes, None, seq_axis, None),
            dp,
        ),
        out_specs=P(batch_axes, None, None),
        check_vma=False,
    )(q, k_cache, v_cache, pos)


def ring_decode_attention(
    q: jax.Array,        # (B, H, hd)
    k_cache: jax.Array,  # (B, KV, W, hd) — ring buffer (slot = pos % W)
    v_cache: jax.Array,
    abs_pos: jax.Array,  # (B, W) absolute position stored at each slot
    pos: jax.Array,      # (B,) current position
    window: int,
) -> jax.Array:
    """Decode attention over a fixed-size ring-buffer window cache."""
    b, h, hd = q.shape
    kvh = k_cache.shape[1]
    qpkv = h // kvh
    qq = q.reshape(b, kvh, qpkv, hd).astype(jnp.float32)
    scores = jnp.einsum("bkgh,bksh->bkgs", qq, k_cache.astype(jnp.float32)) * hd ** -0.5
    mask = (
        (abs_pos <= pos[:, None])
        & (abs_pos > pos[:, None] - window)
        & (abs_pos >= 0)
    )
    scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bksh->bkgh", p, v_cache.astype(jnp.float32))
    return out.reshape(b, h, hd).astype(q.dtype)


def cache_scatter_update(
    cache: jax.Array,   # (B, KV, S, hd) — possibly seq-sharded at the XLA level
    new: jax.Array,     # (B, KV, hd)
    pos: jax.Array,     # (B,)
) -> jax.Array:
    """Write ``new`` at cache[b, :, pos[b], :] via a drop-mode scatter (in-place
    under donation; with a seq-sharded cache only the owning shard writes)."""
    b = cache.shape[0]
    return cache.at[jnp.arange(b), :, pos, :].set(new, mode="drop")


# ------------------------------------------------------- int8 KV quantization


def quantize_kv(x: jax.Array):
    """Symmetric per-vector int8: x (..., hd) -> (q int8, scale (..., 1) f32).

    Halves the decode-path HBM reads of the KV cache; dequantization happens
    on-chip (VMEM) so only int8 bytes cross the HBM interface on TPU.
    """
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_kv(q: jax.Array, scale: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def attention_flops(sq: int, skv: int, h: int, hd: int, *, causal: bool) -> int:
    """Analytic attention FLOPs (QK^T + PV), for the roofline MODEL_FLOPS term."""
    pair_frac = 0.5 if causal and sq == skv else 1.0
    return int(4 * sq * skv * h * hd * pair_frac)
