from repro.models.model_builder import (
    ModelApi,
    batch_dims,
    build_model,
    chunked_xent,
    count_params_analytic,
    make_dummy_batch,
)

__all__ = [
    "ModelApi",
    "batch_dims",
    "build_model",
    "chunked_xent",
    "count_params_analytic",
    "make_dummy_batch",
]
