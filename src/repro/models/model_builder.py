"""Unified model API: every family exposes the same five functions.

    api = build_model(cfg)
    params = api.init_params(rng)
    loss, metrics = api.loss_fn(params, batch)            # train shapes
    logits, cache = api.prefill_fn(params, batch)         # inference-prefill
    logits, cache = api.decode_fn(params, cache, tok, pos)  # one decode step

The loss head uses chunked cross-entropy (scan over sequence chunks with
rematerialized logits) so (B, S, V) never materializes in f32 — required for
49k-256k vocabs at 32k context.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, layers, moe, rglru, rwkv, transformer

Params = Any
XENT_CHUNK = 512


def count_params_analytic(cfg: ModelConfig, active_only: bool = False) -> int:
    d, v, l = cfg.d_model, cfg.vocab_size, cfg.num_layers
    embed = v * d * (1 if cfg.tie_embeddings else 2)
    if cfg.family in ("dense", "vlm"):
        per = layers.attn_param_count(cfg) + layers.mlp_param_count(d, cfg.d_ff, cfg.act)
        n = l * per + embed
        if cfg.family == "vlm":
            n += cfg.vision.patch_dim * d
        return n
    if cfg.family == "moe":
        per = layers.attn_param_count(cfg) + moe.moe_param_count(cfg, active_only)
        return l * per + embed
    if cfg.family == "rwkv":
        return l * rwkv.rwkv_block_param_count(cfg) + embed
    if cfg.family == "hybrid":
        ng, nt = transformer._hybrid_layout(cfg)
        mlp = layers.mlp_param_count(d, cfg.d_ff, "swiglu")
        rec = rglru.rec_block_param_count(cfg) + mlp
        att = layers.attn_param_count(cfg) + mlp
        return ng * (2 * rec + att) + nt * rec + embed
    if cfg.family == "encdec":
        return encdec.encdec_param_count(cfg)
    raise ValueError(cfg.family)


def chunked_xent(hidden: jax.Array, head: jax.Array, targets: jax.Array,
                 chunk: int = XENT_CHUNK):
    """Token-mean cross-entropy without materializing full-seq f32 logits.

    hidden (B,S,D); head (D,V); targets (B,S) int32, -1 = masked out.
    """
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)), constant_values=-1)
    hc = hidden.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    tc = targets.reshape(b, nc, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def body(carry, inp):
        tot, cnt = carry
        h, t = inp
        logits = jnp.einsum("bcd,dv->bcv", h, head.astype(h.dtype)).astype(jnp.float32)
        mask = (t >= 0).astype(jnp.float32)
        tt = jnp.maximum(t, 0)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tt[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mask
        return (tot + jnp.sum(nll), cnt + jnp.sum(mask)), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())), (hc, tc))
    return tot / jnp.maximum(cnt, 1.0)


def _final_hidden(params, cfg, x):
    return layers.rms_norm(x, params["final_ln"], cfg.norm_eps)


def _head_matrix(params, cfg):
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


@dataclass
class ModelApi:
    cfg: ModelConfig
    init_params: Callable
    loss_fn: Callable              # (params, batch) -> (loss, metrics)
    forward_fn: Callable           # (params, batch) -> hidden (B,S,D)
    prefill_fn: Callable           # (params, batch) -> (last_logits, cache)
    decode_fn: Callable            # (params, cache, token, pos) -> (logits, cache)
    init_cache: Callable           # (batch, seq) -> cache pytree (zeros)


def build_model(cfg: ModelConfig, remat: str = "none") -> ModelApi:
    dt = layers.dtype_of(cfg.param_dtype)
    fam = cfg.family

    if fam == "encdec":
        def init_params(rng):
            return encdec.encdec_init(rng, cfg)

        def forward_fn(params, batch):
            x, _, _ = encdec.encdec_forward(params, cfg, batch, remat=remat)
            return _final_hidden_encdec(params, cfg, x)

        def loss_fn(params, batch):
            x, aux, _ = encdec.encdec_forward(params, cfg, batch, remat=remat)
            x = layers.rms_norm(x, params["final_ln"], cfg.norm_eps)
            loss = chunked_xent(x, params["lm_head"], batch["targets"])
            return loss, {"xent": loss}

        def prefill_fn(params, batch):
            x, _, cache = encdec.encdec_forward(
                params, cfg, batch, want_cache=True, remat=remat
            )
            logits = encdec.encdec_logits(params, cfg, x[:, -1:])[:, 0]
            return logits, cache

        def decode_fn(params, cache, token, pos):
            return encdec.encdec_decode_step(params, cfg, cache, token, pos)

        def init_cache(batch, seq):
            return encdec.encdec_init_cache(cfg, batch, seq, dt)

        return ModelApi(cfg, init_params, loss_fn, forward_fn, prefill_fn, decode_fn, init_cache)

    fwd = {
        "dense": transformer.dense_forward,
        "moe": transformer.dense_forward,
        "vlm": transformer.dense_forward,
        "rwkv": transformer.rwkv_forward,
        "hybrid": transformer.hybrid_forward,
    }[fam]
    dec = {
        "dense": transformer.dense_decode_step,
        "moe": transformer.dense_decode_step,
        "vlm": transformer.dense_decode_step,
        "rwkv": transformer.rwkv_decode_step,
        "hybrid": transformer.hybrid_decode_step,
    }[fam]
    cache_init = {
        "dense": transformer.dense_init_cache,
        "moe": transformer.dense_init_cache,
        "vlm": transformer.dense_init_cache,
        "rwkv": transformer.rwkv_init_cache,
        "hybrid": transformer.hybrid_init_cache,
    }[fam]

    def init_params(rng):
        return transformer.lm_init(rng, cfg)

    def forward_fn(params, batch):
        x, _, _ = fwd(params, cfg, batch, remat=remat)
        return _final_hidden(params, cfg, x)

    def loss_fn(params, batch):
        x, aux, _ = fwd(params, cfg, batch, remat=remat)
        x = _final_hidden(params, cfg, x)
        loss = chunked_xent(x, _head_matrix(params, cfg), batch["targets"])
        metrics = {"xent": loss}
        if fam == "moe":
            loss = loss + aux["moe_aux"] + aux["moe_zloss"]
            metrics.update(aux)
        return loss, metrics

    def prefill_fn(params, batch):
        x, _, cache = fwd(params, cfg, batch, want_cache=True, remat=remat)
        logits = transformer.lm_logits(params, cfg, x[:, -1:])[:, 0]
        return logits, cache

    def decode_fn(params, cache, token, pos):
        return dec(params, cfg, cache, token, pos)

    def init_cache(batch, seq):
        return cache_init(cfg, batch, seq, dt)

    return ModelApi(cfg, init_params, loss_fn, forward_fn, prefill_fn, decode_fn, init_cache)


def _final_hidden_encdec(params, cfg, x):
    return layers.rms_norm(x, params["final_ln"], cfg.norm_eps)


# -------------------------------------------------------------- batch helpers


def batch_dims(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, tuple]:
    """Shapes (no data) for every input of the (cfg, shape) cell."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind in ("train",):
        if cfg.family == "encdec":
            return {
                "frames": (b, s, cfg.encdec.frame_dim),
                "tokens": (b, s),
                "targets": (b, s),
            }
        if cfg.family == "vlm":
            np_ = cfg.vision.n_patches
            return {
                "patches": (b, np_, cfg.vision.patch_dim),
                "tokens": (b, s - np_),
                "targets": (b, s),
            }
        return {"tokens": (b, s), "targets": (b, s)}
    if shape.kind == "prefill":
        if cfg.family == "encdec":
            return {"frames": (b, s, cfg.encdec.frame_dim), "tokens": (b, s)}
        if cfg.family == "vlm":
            np_ = cfg.vision.n_patches
            return {"patches": (b, np_, cfg.vision.patch_dim), "tokens": (b, s - np_)}
        return {"tokens": (b, s)}
    # decode
    return {"token": (b,), "pos": (b,)}


def make_dummy_batch(cfg: ModelConfig, shape: ShapeConfig, rng) -> dict[str, jax.Array]:
    dims = batch_dims(cfg, shape)
    out = {}
    for name, shp in dims.items():
        rng, k = jax.random.split(rng)
        if name in ("tokens", "targets", "token"):
            out[name] = jax.random.randint(k, shp, 0, cfg.vocab_size, dtype=jnp.int32)
        elif name == "pos":
            out[name] = jnp.zeros(shp, jnp.int32)
        else:
            out[name] = (jax.random.normal(k, shp) * 0.02).astype(
                layers.dtype_of(cfg.compute_dtype)
            )
    if cfg.family == "vlm" and "targets" in out:
        np_ = cfg.vision.n_patches
        out["targets"] = out["targets"].at[:, :np_].set(-1)  # no loss on patches
    return out
