"""Deterministic synthetic data pipeline.

Generates a reproducible token stream (and stub frame/patch embeddings) per
(seed, step), sharded across hosts: each host materializes only its slice of
the global batch and the global array is assembled with
``jax.make_array_from_callback``. Determinism across restarts is what lets
checkpoint/restart resume mid-stream (runtime/fault.py) — the step index is
the only data-pipeline state.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import batch_dims


@dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    # markov-ish structure so the loss has signal to descend on
    n_states: int = 64


class SyntheticPipeline:
    """next_batch(step) -> dict of jnp/global arrays for the (model, shape) cell."""

    def __init__(self, model: ModelConfig, shape: ShapeConfig,
                 data: DataConfig = DataConfig(), sharding=None):
        self.model = model
        self.shape = shape
        self.data = data
        self.sharding = sharding  # dict name -> jax.sharding.Sharding | None
        self.dims = batch_dims(model, shape)

    def _host_tokens(self, step: int, lo: int, hi: int, seq: int) -> np.ndarray:
        """Deterministic pseudo-text: a noisy periodic walk over the vocab."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.data.seed, step, lo])
        )
        b = hi - lo
        v = self.model.vocab_size
        base = rng.integers(0, self.data.n_states, size=(b, 1))
        drift = np.cumsum(rng.integers(0, 3, size=(b, seq)), axis=1)
        noise = rng.integers(0, 2, size=(b, seq))
        return ((base + drift + noise) % v).astype(np.int32)

    def _full(self, name: str, step: int) -> np.ndarray:
        shp = self.dims[name]
        if name in ("tokens", "targets", "token"):
            seq = shp[1] if len(shp) > 1 else 1
            toks = self._host_tokens(step, 0, shp[0], seq + 1)
            if name == "targets":
                out = toks[:, 1 : seq + 1]
                if self.model.family == "vlm":
                    np_ = self.model.vision.n_patches
                    pad = np.full((shp[0], np_), -1, np.int32)
                    out = np.concatenate([pad, out[:, : shp[1] - np_]], axis=1)
                return out[:, : shp[1]] if out.ndim > 1 else out[:, 0]
            out = toks[:, :seq]
            return out if len(shp) > 1 else out[:, 0]
        if name == "pos":
            return np.zeros(shp, np.int32)
        rng = np.random.default_rng(
            np.random.SeedSequence([self.data.seed, step, hash(name) & 0xFFFF])
        )
        return (rng.standard_normal(shp) * 0.02).astype(np.float32)

    def next_batch(self, step: int) -> dict[str, jax.Array]:
        out = {}
        for name in self.dims:
            arr = self._full(name, step)
            if self.sharding and self.sharding.get(name) is not None:
                sh = self.sharding[name]
                out[name] = jax.make_array_from_callback(
                    arr.shape, sh, lambda idx, a=arr: a[idx]
                )
            else:
                dt = jnp.int32 if arr.dtype == np.int32 else None
                out[name] = jnp.asarray(arr, dtype=dt)
                if arr.dtype != np.int32:
                    out[name] = out[name].astype(
                        {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[
                            self.model.compute_dtype
                        ]
                    )
        return out
