"""Pallas kernel sweeps vs pure-jnp oracles (interpret=True on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("mkn", [(128, 128, 128), (256, 384, 128),
                                 (512, 256, 256), (128, 512, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_kernel(mkn, dtype):
    m, k, n = mkn
    rng = np.random.default_rng(m + k + n)
    x = jnp.asarray(rng.standard_normal((m, k)), dtype)
    w = jnp.asarray(rng.standard_normal((k, n)), dtype)
    y = ops.matmul(x, w)
    yr = ref.matmul_ref(x, w)
    tol = 0.5 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(yr, np.float32), atol=tol
    )


@pytest.mark.parametrize("tiles", [(128, 128, 128), (64, 128, 128), (128, 64, 64)])
def test_matmul_tile_sweep(tiles):
    bm, bk, bn = tiles
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)
    y = ops.matmul(x, w, bm=bm, bk=bk, bn=bn)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref.matmul_ref(x, w)),
                               atol=2e-3)


@pytest.mark.parametrize("cfg", [
    # (n_chunks, chunk, n_staged, n_valid, dtype)
    (32, 256, 20, 15, jnp.float32),
    (64, 128, 64, 64, jnp.float32),
    (16, 512, 10, 0, jnp.float32),     # nothing valid
    (32, 256, 20, 20, jnp.bfloat16),
    (8, 1024, 8, 5, jnp.int32),
])
def test_chunk_reassembly(cfg):
    n_chunks, chunk, n_staged, n_valid, dtype = cfg
    rng = np.random.default_rng(n_chunks + n_staged)
    if dtype == jnp.int32:
        staging = jnp.asarray(rng.integers(0, 1000, (n_staged, chunk)), dtype)
        user = jnp.zeros((n_chunks, chunk), dtype) - 1
    else:
        staging = jnp.asarray(rng.standard_normal((n_staged, chunk)), dtype)
        user = jnp.zeros((n_chunks, chunk), dtype) - 1.0
    psn = jnp.asarray(rng.permutation(n_chunks)[:n_staged], jnp.int32)
    u1, b1 = ops.reassemble(staging, psn, user, n_valid)
    u2, b2 = ref.chunk_reassembly_ref(staging, psn, user, n_valid)
    np.testing.assert_array_equal(np.asarray(u1), np.asarray(u2))
    np.testing.assert_array_equal(np.asarray(b1), np.asarray(b2))


def test_chunk_reassembly_out_of_order_with_duplicates():
    """Adaptive-routing OOO + retransmitted duplicates: last write wins and
    the untouched chunks keep previous content (input/output aliasing)."""
    n_chunks, chunk = 16, 128
    rng = np.random.default_rng(5)
    user = jnp.asarray(rng.standard_normal((n_chunks, chunk)), jnp.float32)
    staging = jnp.asarray(rng.standard_normal((6, chunk)), jnp.float32)
    psn = jnp.asarray([3, 9, 3, 0, 9, 12], jnp.int32)  # dups of 3 and 9
    u1, b1 = ops.reassemble(staging, psn, user)
    u2, b2 = ref.chunk_reassembly_ref(staging, psn, user)
    np.testing.assert_array_equal(np.asarray(u1), np.asarray(u2))
    # untouched chunk preserved
    np.testing.assert_array_equal(np.asarray(u1[1]), np.asarray(user[1]))
    assert int(b1.sum()) == 4  # chunks {0,3,9,12}


@pytest.mark.parametrize("n", [32 * 8, 32 * 256, 32 * 1024])
def test_bitmap_roundtrip(n):
    rng = np.random.default_rng(n)
    flags = jnp.asarray(rng.integers(0, 2, n), jnp.uint32)
    words = ops.pack_bitmap(flags)
    np.testing.assert_array_equal(
        np.asarray(words), np.asarray(ref.bitmap_pack_ref(flags))
    )
    blk = min(1024, n // 32)
    assert int(ops.popcount(words, block=blk)) == int(flags.sum())


def test_collective_matmul_multidev(multidev):
    multidev(
        """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.kernels import ops, ref
mesh = jax.make_mesh((8,), ('x',))
rng = np.random.default_rng(1)
x = jnp.asarray(rng.standard_normal((8*128, 256)), jnp.float32)
w = jnp.asarray(rng.standard_normal((256, 128)), jnp.float32)
xs = jax.device_put(x, NamedSharding(mesh, P('x', None)))
y = ops.make_allgather_matmul(mesh, 'x')(xs, w)
yr = ref.allgather_matmul_ref(x, w)
assert float(jnp.max(jnp.abs(y - yr))) < 1e-3
print('ok')
"""
    )


# ------------------------------------------------- pool-completion scan


@pytest.mark.parametrize("cfg", [
    # (rows, n, n_workers, service, staging) — ragged (w does not divide n),
    # aligned, w > n, single element, and a staging window wider than W
    (5, 17, 4, 0.3, 3),
    (8, 32, 8, 1.5, 2),
    (3, 7, 16, 0.01, 1),
    (1, 1, 2, 1.0, 4),
    (13, 40, 5, 0.7, 6),
])
def test_pool_scan_kernel_bit_exact_vs_numpy_twin(cfg):
    """The Pallas residue-class-parallel scan must be BIT-exact with its
    jax-free numpy twin (the engine's production inner path) in f64 — both
    run the identical per-lane op sequence, so equality is exact, not
    approximate."""
    from jax.experimental import enable_x64

    from repro.kernels import pool
    from repro.kernels.pool_np import pool_completion_rows_np

    rows, n, w, s, staging = cfg
    rng = np.random.default_rng(rows * 1000 + n)
    a = np.sort(rng.uniform(0.0, 10.0, (rows, n)), axis=1)
    d_np, m_np = pool_completion_rows_np(a, w, s, staging)
    with enable_x64():
        d_j, m_j = pool.pool_completion_rows(jnp.asarray(a), w, s, staging)
        assert np.asarray(d_j).dtype == np.float64
        np.testing.assert_array_equal(np.asarray(d_j), d_np)
        np.testing.assert_array_equal(np.asarray(m_j), m_np)


def test_pool_scan_kernel_f32_lane_semantics():
    """In f32 (jax default) the kernel replays the same lane ops at f32
    precision — pin it bitwise against the scan replayed in f32 numpy."""
    from repro.kernels import pool

    rows, n, w, s = 6, 23, 4, 0.3
    rng = np.random.default_rng(7)
    a32 = np.sort(rng.uniform(0.0, 10.0, (rows, n)), axis=1) \
        .astype(np.float32)
    d_j = np.asarray(pool.pool_scan_rows(jnp.asarray(a32), w, s))
    assert d_j.dtype == np.float32
    s32 = np.float32(s)
    pad = (-n) % w
    n_per = (n + pad) // w
    buf = np.full((rows, n_per * w), np.inf, np.float32)
    buf[:, :n] = a32
    b3 = buf.reshape(rows, n_per, w)
    i3 = np.arange(n_per, dtype=np.float32)[None, :, None]
    b3 = np.maximum.accumulate(b3 - i3 * s32, axis=1) \
        + (i3 + np.float32(1.0)) * s32
    np.testing.assert_array_equal(d_j, b3.reshape(rows, -1)[:, :n])
