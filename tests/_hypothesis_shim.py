"""Minimal offline stand-in for `hypothesis` so the property suites collect
and run with zero network access (the real package is preferred when present).

Exposes the subset the repo's tests use:

    from _hypothesis_shim import given, settings, strategies as st

Strategies are seeded-random samplers (numpy Generator); `given` derives a
deterministic per-test seed from the test name, so runs are reproducible and
failures repeatable (the CI reproducibility contract — when the real
hypothesis IS installed, tests/conftest.py pins it with a derandomized
profile for the same guarantee). Set REPRO_TEST_SEED=<int> to salt every
per-test seed and explore a different deterministic sample set locally.
This shim does NOT shrink counterexamples or track a database — it is a
sampler, not a replacement for real hypothesis.
"""
from __future__ import annotations

import functools
import os
import zlib

import numpy as np

_SEED_SALT = int(os.environ.get("REPRO_TEST_SEED", "0"))

_DEFAULT_MAX_EXAMPLES = 25


class _Strategy:
    """A sampler: example(rng) -> value. map/flatmap/filter compose lazily."""

    def __init__(self, sample):
        self._sample = sample

    def example(self, rng: np.random.Generator):
        return self._sample(rng)

    def map(self, fn) -> "_Strategy":
        return _Strategy(lambda rng: fn(self._sample(rng)))

    def flatmap(self, fn) -> "_Strategy":
        return _Strategy(lambda rng: fn(self._sample(rng)).example(rng))

    def filter(self, pred) -> "_Strategy":
        def sample(rng):
            for _ in range(1000):
                v = self._sample(rng)
                if pred(v):
                    return v
            raise ValueError("filter predicate too strict (1000 rejections)")
        return _Strategy(sample)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def floats(min_value: float, max_value: float, **_kw) -> _Strategy:
    return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


def booleans() -> _Strategy:
    return _Strategy(lambda rng: bool(rng.integers(0, 2)))


def sampled_from(seq) -> _Strategy:
    items = list(seq)
    return _Strategy(lambda rng: items[int(rng.integers(0, len(items)))])


def lists(elements: _Strategy, *, min_size: int = 0, max_size: int = 10) -> _Strategy:
    def sample(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [elements.example(rng) for _ in range(n)]
    return _Strategy(sample)


def composite(fn):
    """@st.composite: fn(draw, *args) -> value. draw(strategy) samples it."""
    def builder(*args, **kw):
        def sample(rng):
            return fn(lambda strategy: strategy.example(rng), *args, **kw)
        return _Strategy(sample)
    return builder


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._shim_settings = {"max_examples": max_examples}
        return fn
    return deco


def given(*strategies: _Strategy):
    def deco(fn):
        @functools.wraps(fn)
        def run(*args, **kw):
            # looked up lazily so @settings works as inner OR outer decorator
            # (outer @settings annotates `run`, inner annotates `fn`)
            cfg = getattr(run, "_shim_settings", None) or getattr(
                fn, "_shim_settings", {}
            )
            max_examples = cfg.get("max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = np.random.default_rng(
                zlib.crc32(fn.__qualname__.encode()) + _SEED_SALT)
            for i in range(max_examples):
                drawn = [s.example(rng) for s in strategies]
                try:
                    fn(*args, *drawn, **kw)
                except Exception as e:  # noqa: BLE001 — annotate and re-raise
                    raise AssertionError(
                        f"property failed on example {i}: {drawn!r}"
                    ) from e
        # pytest must not see the property's drawn parameters as fixtures
        del run.__wrapped__
        return run
    return deco


class _StrategiesNamespace:
    integers = staticmethod(integers)
    floats = staticmethod(floats)
    booleans = staticmethod(booleans)
    sampled_from = staticmethod(sampled_from)
    lists = staticmethod(lists)
    composite = staticmethod(composite)


strategies = _StrategiesNamespace()
st = strategies
