"""Property tests for the Appendix-A broadcast sequencer."""
try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:  # offline: seeded-random shim (tests/_hypothesis_shim.py)
    from _hypothesis_shim import given, settings, strategies as st

from repro.core import schedule


def pm_pairs():
    return st.integers(1, 64).flatmap(
        lambda m: st.integers(1, 8).map(lambda r: (m * r, m))
    )


def pm_pairs_uneven():
    """(P, M) with no divisibility constraint — uneven chains included."""
    return st.integers(1, 96).flatmap(
        lambda p: st.integers(1, p).map(lambda m: (p, m))
    )


@given(pm_pairs())
@settings(max_examples=100, deadline=None)
def test_schedule_invariants(pm):
    p, m = pm
    schedule.validate_schedule(p, m)


@given(pm_pairs_uneven())
@settings(max_examples=100, deadline=None)
def test_schedule_invariants_uneven(pm):
    """M need not divide P: last chains shorter, every rank roots once."""
    p, m = pm
    schedule.validate_schedule(p, m)
    lens = schedule.chain_lengths(p, m)
    assert lens == tuple(sorted(lens, reverse=True))   # last chains shorter
    # chains partition [0, P) contiguously
    members = [schedule.chain_members(c, p, m) for c in range(m)]
    flat = [x for ms in members for x in ms]
    assert flat == list(range(p))


@given(pm_pairs())
@settings(max_examples=50, deadline=None)
def test_appendix_a_formula(pm):
    """G^i = {P_i, P_{R+i}, ..., P_{(M-1)R+i}} exactly."""
    p, m = pm
    r = p // m
    for i in range(r):
        g = schedule.active_group(i, p, m)
        assert g == tuple(i + j * r for j in range(m))


@given(pm_pairs())
@settings(max_examples=50, deadline=None)
def test_activation_chain(pm):
    p, m = pm
    edges = schedule.activation_edges(p, m)
    # every non-initial rank is activated exactly once, within its chain
    targets = [t for _, t in edges]
    assert len(targets) == len(set(targets)) == p - m
    for f, t in edges:
        assert schedule.chain_of(f, p, m) == schedule.chain_of(t, p, m)
        assert t == f + 1  # successor in chain


@given(st.integers(1, 32), st.integers(0, 10_000_000))
@settings(max_examples=50, deadline=None)
def test_subgroups_partition(n, total):
    segs = schedule.subgroup_assignment(n, total)
    assert len(segs) == n
    assert segs[0][0] == 0 and segs[-1][1] == total
    for (a, b), (c, d) in zip(segs, segs[1:]):
        assert b == c and b >= a and d >= c
    sizes = [b - a for a, b in segs]
    assert max(sizes) - min(sizes) <= 1  # even split


def test_worker_split_paper_example():
    """§IV-C: 16 procs, 4 subgroups -> 1 send worker, 4 receive workers."""
    s, r = schedule.worker_split(4, 16)
    assert (s, r) == (1, 4)


def test_worker_split_discrepancy_rule_caps_at_peers():
    """§IV-C discrepancy rule: receive workers = min(subgroups, P-1) — at
    most P-1 peers can be sending concurrently, so workers beyond that
    would idle; the send path always keeps exactly one worker."""
    assert schedule.worker_split(8, 4) == (1, 3)    # capped by P-1
    assert schedule.worker_split(4, 2) == (1, 1)    # single peer
    assert schedule.worker_split(1, 16) == (1, 1)
    assert schedule.worker_split(16, 16) == (1, 15)
    assert schedule.worker_split(3, 1) == (1, 1)    # degenerate P=1


@given(st.integers(1, 64), st.integers(1, 256))
@settings(max_examples=60, deadline=None)
def test_worker_split_properties(n_sub, p):
    s, r = schedule.worker_split(n_sub, p)
    assert s == 1
    assert 1 <= r <= max(min(n_sub, p - 1), 1)


def test_uneven_active_group_example():
    """P=6, M=4: chains (0,1) (2,3) (4) (5); step 0 activates all four
    chain heads, step 1 only the two chains still that long."""
    assert schedule.chain_lengths(6, 4) == (2, 2, 1, 1)
    assert schedule.active_group(0, 6, 4) == (0, 2, 4, 5)
    assert schedule.active_group(1, 6, 4) == (1, 3)
    assert schedule.n_rounds(6, 4) == 2
    assert schedule.activation_edges(6, 4) == [(0, 1), (2, 3)]
