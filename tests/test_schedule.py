"""Property tests for the Appendix-A broadcast sequencer."""
try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:  # offline: seeded-random shim (tests/_hypothesis_shim.py)
    from _hypothesis_shim import given, settings, strategies as st

from repro.core import schedule


def pm_pairs():
    return st.integers(1, 64).flatmap(
        lambda m: st.integers(1, 8).map(lambda r: (m * r, m))
    )


@given(pm_pairs())
@settings(max_examples=100, deadline=None)
def test_schedule_invariants(pm):
    p, m = pm
    schedule.validate_schedule(p, m)


@given(pm_pairs())
@settings(max_examples=50, deadline=None)
def test_appendix_a_formula(pm):
    """G^i = {P_i, P_{R+i}, ..., P_{(M-1)R+i}} exactly."""
    p, m = pm
    r = p // m
    for i in range(r):
        g = schedule.active_group(i, p, m)
        assert g == tuple(i + j * r for j in range(m))


@given(pm_pairs())
@settings(max_examples=50, deadline=None)
def test_activation_chain(pm):
    p, m = pm
    edges = schedule.activation_edges(p, m)
    # every non-initial rank is activated exactly once, within its chain
    targets = [t for _, t in edges]
    assert len(targets) == len(set(targets)) == p - m
    for f, t in edges:
        assert schedule.chain_of(f, p, m) == schedule.chain_of(t, p, m)
        assert t == f + 1  # successor in chain


@given(st.integers(1, 32), st.integers(0, 10_000_000))
@settings(max_examples=50, deadline=None)
def test_subgroups_partition(n, total):
    segs = schedule.subgroup_assignment(n, total)
    assert len(segs) == n
    assert segs[0][0] == 0 and segs[-1][1] == total
    for (a, b), (c, d) in zip(segs, segs[1:]):
        assert b == c and b >= a and d >= c
    sizes = [b - a for a, b in segs]
    assert max(sizes) - min(sizes) <= 1  # even split


def test_worker_split_paper_example():
    """§IV-C: 16 procs, 4 subgroups -> 1 send worker, 4 receive workers."""
    s, r = schedule.worker_split(4, 16)
    assert (s, r) == (1, 4)
