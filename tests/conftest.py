import os
import subprocess
import sys

import pytest

try:  # pin real-hypothesis runs: CI must be reproducible (the offline shim
    # in _hypothesis_shim.py derives per-test seeds and is always pinned)
    from hypothesis import settings as _hyp_settings

    _hyp_settings.register_profile("repro-ci", derandomize=True,
                                   deadline=None)
    _hyp_settings.load_profile("repro-ci")
except ImportError:
    pass

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_multidev(code: str, n_devices: int = 8, timeout: int = 300):
    """Run a python snippet in a subprocess with N fake CPU devices.

    XLA_FLAGS must NOT be set globally (smoke tests see 1 device), so
    multi-device tests run in their own process.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True,
        text=True, timeout=timeout,
    )
    if res.returncode != 0:
        raise AssertionError(
            f"multidev subprocess failed:\nSTDOUT:\n{res.stdout[-4000:]}\n"
            f"STDERR:\n{res.stderr[-4000:]}"
        )
    return res.stdout


@pytest.fixture(scope="session")
def multidev():
    return run_multidev
