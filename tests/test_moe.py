"""MoE dispatch/combine correctness."""
import pytest
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_model_config, reduced
from repro.models import moe

# jax model/integration tier: excluded from the fast CI
# lane (scripts/check.sh), run by the `slow` CI job
pytestmark = pytest.mark.slow


def _cfg(capacity_factor=8.0, top_k=2):
    cfg = reduced(get_model_config("deepseek-moe-16b"))
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=capacity_factor,
                                     top_k=top_k, n_shared_experts=0)
    )


def naive_moe(p, x, cfg):
    """Direct per-token top-k mixture (no capacity) — oracle."""
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gates, idx = jax.lax.top_k(probs, cfg.moe.top_k)
    gates = gates / gates.sum(-1, keepdims=True)
    out = jnp.zeros_like(xt)
    for e in range(cfg.moe.n_routed_experts):
        ye = (jax.nn.silu(xt @ p["w_gate"][e]) * (xt @ p["w_up"][e])) @ p["w_down"][e]
        w = jnp.where(idx == e, gates, 0.0).sum(-1)
        out = out + ye * w[:, None]
    return out.reshape(b, s, d)


def test_matches_naive_when_capacity_ample():
    cfg = _cfg(capacity_factor=8.0)
    rng = jax.random.PRNGKey(0)
    p = moe.moe_init(rng, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    out, metrics = moe.moe_apply(p, x, cfg)
    ref = naive_moe(p, x, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)
    assert float(metrics["moe_drop_frac"]) == 0.0


def test_no_drop_mode_exact():
    cfg = _cfg(capacity_factor=0.5)   # tight capacity
    rng = jax.random.PRNGKey(0)
    p = moe.moe_init(rng, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 8, cfg.d_model))
    out, m = moe.moe_apply(p, x, cfg, no_drop=True)
    ref = naive_moe(p, x, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)
    assert float(m["moe_drop_frac"]) == 0.0


def test_capacity_dropping_happens():
    cfg = _cfg(capacity_factor=0.25)
    rng = jax.random.PRNGKey(0)
    p = moe.moe_init(rng, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 64, cfg.d_model))
    out, m = moe.moe_apply(p, x, cfg)
    assert float(m["moe_drop_frac"]) > 0.0
    assert jnp.all(jnp.isfinite(out))


def test_aux_losses_balanced_router():
    """Uniform router -> aux loss ~ 1.0 (E * sum(1/E * 1/E) * E)."""
    cfg = _cfg()
    rng = jax.random.PRNGKey(0)
    p = moe.moe_init(rng, cfg, jnp.float32)
    p = dict(p, router=jnp.zeros_like(p["router"]))  # uniform routing
    x = jax.random.normal(jax.random.PRNGKey(4), (4, 64, cfg.d_model))
    _, m = moe.moe_apply(p, x, cfg)
    aux = float(m["moe_aux"]) / cfg.moe.router_aux_coef
    assert 0.9 < aux < 1.3
