"""Discrete-event engine invariants: byte conservation, monotonicity in drop
rate and message size, determinism, and fluid-engine bookkeeping."""
import numpy as np
import pytest

from repro.core.dpa import DpaConfig, pool_tput
from repro.core.engine import Engine, FabricParams, WorkerParams, workers_from_dpa
from repro.core.simulator import simulate_allgather, simulate_broadcast


# ------------------------------------------------------------- fluid core


def test_single_flow_runs_at_capacity():
    eng = Engine()
    eng.add_link("l", 100.0)
    f = eng.submit("l", 1000.0)
    eng.run()
    assert f.t_end == pytest.approx(10.0)
    np.testing.assert_allclose(f.chunk_times(4, 250.0), [2.5, 5.0, 7.5, 10.0])


def test_two_flows_share_capacity_max_min():
    eng = Engine()
    eng.add_link("l", 100.0)
    a = eng.submit("l", 500.0)
    b = eng.submit("l", 1500.0)
    eng.run()
    # equal split while both active: a done at 10s; b then runs alone
    assert a.t_end == pytest.approx(10.0)
    assert b.t_end == pytest.approx(20.0)
    assert eng.utilization()["l"] == pytest.approx(1.0)


def test_rate_cap_water_filling():
    eng = Engine()
    eng.add_link("l", 100.0)
    capped = eng.submit("l", 100.0, rate_cap=10.0)
    free = eng.submit("l", 900.0)
    eng.run()
    # capped flow runs at 10; the other water-fills to 90
    assert capped.t_end == pytest.approx(10.0)
    assert free.t_end == pytest.approx(10.0)


def test_future_start_and_zero_byte_flow():
    eng = Engine()
    eng.add_link("l", 10.0)
    z = eng.submit("l", 0.0, t_start=3.0)
    f = eng.submit("l", 10.0, t_start=5.0)
    eng.run()
    assert z.t_end == pytest.approx(3.0)
    assert f.t_end == pytest.approx(6.0)


def test_large_flow_terminates_without_fp_spin():
    # regression: residual fp bytes must not stall the event loop
    eng = Engine()
    eng.add_link("l", 200e9 / 8)
    flows = [eng.submit("l", 256e6 * (1 + 0.1 * i)) for i in range(5)]
    eng.run()
    assert all(f.done for f in flows)


# ------------------------------------------------------ multi-link route flows


def test_route_flow_progressive_filling():
    """Textbook global max-min: A on L1, B on L1+L2, C on L2. L1 is the
    bottleneck (A, B at 50 each); B's frozen rate leaves C water-filled to
    250 on L2."""
    eng = Engine()
    eng.add_link("L1", 100.0)
    eng.add_link("L2", 300.0)
    a = eng.submit("L1", 500.0)
    b = eng.submit_route(["L1", "L2"], 500.0)
    c = eng.submit("L2", 2500.0)
    eng.run()
    assert a.t_end == pytest.approx(10.0)
    assert b.t_end == pytest.approx(10.0)
    assert c.t_end == pytest.approx(10.0)           # 250 B/s * 10 s
    # a route flow charges every link it crosses
    assert eng.link_bytes()["L1"] == pytest.approx(1000.0)
    assert eng.link_bytes()["L2"] == pytest.approx(3000.0)


def test_tree_flow_min_share_and_per_edge_bytes():
    """A tree flow runs at the min share over every edge and serves its full
    byte count on each edge (switch replication)."""
    from repro.core.engine import Link

    e1, e2, e3 = Link("e1", 100.0), Link("e2", 100.0), Link("e3", 10.0)
    eng = Engine()
    t = eng.submit_tree([e1, e2, e3], 100.0)
    u = eng.submit(e1, 900.0)
    eng.run()
    assert t.t_end == pytest.approx(10.0)           # e3 caps the tree at 10
    assert u.t_end == pytest.approx(10.0)           # water-fills e1 to 90
    assert e1.bytes_served == pytest.approx(1000.0)
    assert e2.bytes_served == pytest.approx(100.0)
    assert e3.bytes_served == pytest.approx(100.0)


def test_numpy_and_python_fillings_agree():
    """The vectorized progressive filling must allocate identically to the
    dict-based one on a contended multi-link flow set."""
    from repro.core.engine import Link, _max_min_rates_np, _max_min_rates_py

    rng = np.random.default_rng(0)
    links = [Link(f"l{i}", float(rng.integers(10, 200))) for i in range(12)]
    flows = []
    eng = Engine()
    for i in range(30):
        sel = rng.choice(12, size=int(rng.integers(1, 5)), replace=False)
        cap = float(rng.uniform(1.0, 50.0)) if rng.random() < 0.3 else None
        flows.append(eng.submit([links[j] for j in sel], 1e6, rate_cap=cap))
    # force the flows active
    eng.advance_to(1e-9)
    py = _max_min_rates_py(eng._active)
    vec = _max_min_rates_np(eng._active)
    assert set(py) == set(vec)
    for f, r in py.items():
        assert vec[f] == pytest.approx(r, rel=1e-9, abs=1e-12)


def test_empty_route_completes_instantly():
    eng = Engine()
    f = eng.submit_route([], 1000.0, t_start=2.0)
    eng.run()
    assert f.t_end == pytest.approx(2.0)


def test_duplicate_link_in_route_rejected():
    eng = Engine()
    eng.add_link("l", 10.0)
    with pytest.raises(AssertionError, match="duplicate link"):
        eng.submit_route(["l", "l"], 10.0)


# ------------------------------------------------------- protocol invariants


def _run_bcast(p=8, n=1 << 20, seed=0, **fab):
    return simulate_broadcast(p, n, FabricParams(**fab), WorkerParams(8),
                              np.random.default_rng(seed))


def _run_ag(p=8, n=1 << 18, seed=0, n_chains=1, **fab):
    return simulate_allgather(p, n, FabricParams(**fab), WorkerParams(8),
                              np.random.default_rng(seed), n_chains=n_chains)


@pytest.mark.parametrize("p_drop", [0.0, 0.01, 0.2])
def test_broadcast_byte_conservation(p_drop):
    r = _run_bcast(p_drop=p_drop)
    assert r.bytes_fast + r.bytes_recovery == r.bytes_total
    assert r.delivered_fast + r.recovered == r.bytes_total // 4096


@pytest.mark.parametrize("n_chains", [1, 2, 8])
@pytest.mark.parametrize("p_drop", [0.0, 0.05])
def test_allgather_byte_conservation(n_chains, p_drop):
    r = _run_ag(n_chains=n_chains, p_drop=p_drop)
    assert r.bytes_fast + r.bytes_recovery == r.bytes_total


def test_completion_monotone_in_p_drop():
    times = [_run_bcast(seed=7, p_drop=d).time
             for d in (0.0, 0.01, 0.05, 0.1, 0.3)]
    assert all(b >= a for a, b in zip(times, times[1:])), times


def test_allgather_monotone_in_p_drop():
    times = [_run_ag(seed=7, p_drop=d).time for d in (0.0, 0.02, 0.1, 0.3)]
    assert all(b >= a for a, b in zip(times, times[1:])), times


def test_completion_monotone_in_n_bytes():
    # jitter off: adjacent sizes differ by less than one jitter draw otherwise
    times = [_run_bcast(n=n, jitter=0.0).time
             for n in (1 << 16, 1 << 18, 1 << 20, 1 << 22)]
    assert all(b >= a for a, b in zip(times, times[1:])), times
    times = [_run_ag(n=n, jitter=0.0).time
             for n in (1 << 14, 1 << 16, 1 << 18, 1 << 20)]
    assert all(b >= a for a, b in zip(times, times[1:])), times


def test_bit_identical_across_seeded_runs():
    a = _run_bcast(seed=123, p_drop=0.02)
    b = _run_bcast(seed=123, p_drop=0.02)
    np.testing.assert_array_equal(a.completion, b.completion)
    assert (a.time, a.recovered, a.bytes_fast) == (b.time, b.recovered, b.bytes_fast)
    x = _run_ag(seed=42, p_drop=0.02, n_chains=2)
    y = _run_ag(seed=42, p_drop=0.02, n_chains=2)
    assert (x.time, x.recovered, x.bytes_fast) == (y.time, y.recovered, y.bytes_fast)


# ------------------------------------------------------------- DPA wiring


def test_workers_from_dpa_respects_sublinear_scaling():
    one = workers_from_dpa(DpaConfig("UD", 1))
    sixteen = workers_from_dpa(DpaConfig("UD", 16))
    assert sixteen.n_recv_workers == 16
    # pool throughput grows, but NOT 16x (within-core latency hiding)
    total_1 = one.n_recv_workers * one.thread_tput
    total_16 = sixteen.n_recv_workers * sixteen.thread_tput
    assert total_1 < total_16 < 16 * total_1
    assert total_16 == pytest.approx(pool_tput(DpaConfig("UD", 16)))


def test_dpa_backed_broadcast_faster_with_more_threads():
    fab = FabricParams()
    rng = np.random.default_rng(0)
    slow = simulate_broadcast(4, 8 << 20, fab,
                              workers_from_dpa(DpaConfig("UD", 1)), rng)
    rng = np.random.default_rng(0)
    fast = simulate_broadcast(4, 8 << 20, fab,
                              workers_from_dpa(DpaConfig("UD", 16)), rng)
    assert fast.time < slow.time
