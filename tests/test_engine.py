"""Discrete-event engine invariants: byte conservation, monotonicity in drop
rate and message size, determinism, and fluid-engine bookkeeping."""
import numpy as np
import pytest

from repro.core.dpa import DpaConfig, pool_tput
from repro.core.engine import Engine, FabricParams, WorkerParams, workers_from_dpa
from repro.core.simulator import simulate_allgather, simulate_broadcast


# ------------------------------------------------------------- fluid core


def test_single_flow_runs_at_capacity():
    eng = Engine()
    eng.add_link("l", 100.0)
    f = eng.submit("l", 1000.0)
    eng.run()
    assert f.t_end == pytest.approx(10.0)
    np.testing.assert_allclose(f.chunk_times(4, 250.0), [2.5, 5.0, 7.5, 10.0])


def test_two_flows_share_capacity_max_min():
    eng = Engine()
    eng.add_link("l", 100.0)
    a = eng.submit("l", 500.0)
    b = eng.submit("l", 1500.0)
    eng.run()
    # equal split while both active: a done at 10s; b then runs alone
    assert a.t_end == pytest.approx(10.0)
    assert b.t_end == pytest.approx(20.0)
    assert eng.utilization()["l"] == pytest.approx(1.0)


def test_rate_cap_water_filling():
    eng = Engine()
    eng.add_link("l", 100.0)
    capped = eng.submit("l", 100.0, rate_cap=10.0)
    free = eng.submit("l", 900.0)
    eng.run()
    # capped flow runs at 10; the other water-fills to 90
    assert capped.t_end == pytest.approx(10.0)
    assert free.t_end == pytest.approx(10.0)


def test_future_start_and_zero_byte_flow():
    eng = Engine()
    eng.add_link("l", 10.0)
    z = eng.submit("l", 0.0, t_start=3.0)
    f = eng.submit("l", 10.0, t_start=5.0)
    eng.run()
    assert z.t_end == pytest.approx(3.0)
    assert f.t_end == pytest.approx(6.0)


def test_large_flow_terminates_without_fp_spin():
    # regression: residual fp bytes must not stall the event loop
    eng = Engine()
    eng.add_link("l", 200e9 / 8)
    flows = [eng.submit("l", 256e6 * (1 + 0.1 * i)) for i in range(5)]
    eng.run()
    assert all(f.done for f in flows)


# ------------------------------------------------------- protocol invariants


def _run_bcast(p=8, n=1 << 20, seed=0, **fab):
    return simulate_broadcast(p, n, FabricParams(**fab), WorkerParams(8),
                              np.random.default_rng(seed))


def _run_ag(p=8, n=1 << 18, seed=0, n_chains=1, **fab):
    return simulate_allgather(p, n, FabricParams(**fab), WorkerParams(8),
                              np.random.default_rng(seed), n_chains=n_chains)


@pytest.mark.parametrize("p_drop", [0.0, 0.01, 0.2])
def test_broadcast_byte_conservation(p_drop):
    r = _run_bcast(p_drop=p_drop)
    assert r.bytes_fast + r.bytes_recovery == r.bytes_total
    assert r.delivered_fast + r.recovered == r.bytes_total // 4096


@pytest.mark.parametrize("n_chains", [1, 2, 8])
@pytest.mark.parametrize("p_drop", [0.0, 0.05])
def test_allgather_byte_conservation(n_chains, p_drop):
    r = _run_ag(n_chains=n_chains, p_drop=p_drop)
    assert r.bytes_fast + r.bytes_recovery == r.bytes_total


def test_completion_monotone_in_p_drop():
    times = [_run_bcast(seed=7, p_drop=d).time
             for d in (0.0, 0.01, 0.05, 0.1, 0.3)]
    assert all(b >= a for a, b in zip(times, times[1:])), times


def test_allgather_monotone_in_p_drop():
    times = [_run_ag(seed=7, p_drop=d).time for d in (0.0, 0.02, 0.1, 0.3)]
    assert all(b >= a for a, b in zip(times, times[1:])), times


def test_completion_monotone_in_n_bytes():
    # jitter off: adjacent sizes differ by less than one jitter draw otherwise
    times = [_run_bcast(n=n, jitter=0.0).time
             for n in (1 << 16, 1 << 18, 1 << 20, 1 << 22)]
    assert all(b >= a for a, b in zip(times, times[1:])), times
    times = [_run_ag(n=n, jitter=0.0).time
             for n in (1 << 14, 1 << 16, 1 << 18, 1 << 20)]
    assert all(b >= a for a, b in zip(times, times[1:])), times


def test_bit_identical_across_seeded_runs():
    a = _run_bcast(seed=123, p_drop=0.02)
    b = _run_bcast(seed=123, p_drop=0.02)
    np.testing.assert_array_equal(a.completion, b.completion)
    assert (a.time, a.recovered, a.bytes_fast) == (b.time, b.recovered, b.bytes_fast)
    x = _run_ag(seed=42, p_drop=0.02, n_chains=2)
    y = _run_ag(seed=42, p_drop=0.02, n_chains=2)
    assert (x.time, x.recovered, x.bytes_fast) == (y.time, y.recovered, y.bytes_fast)


# ------------------------------------------------------------- DPA wiring


def test_workers_from_dpa_respects_sublinear_scaling():
    one = workers_from_dpa(DpaConfig("UD", 1))
    sixteen = workers_from_dpa(DpaConfig("UD", 16))
    assert sixteen.n_recv_workers == 16
    # pool throughput grows, but NOT 16x (within-core latency hiding)
    total_1 = one.n_recv_workers * one.thread_tput
    total_16 = sixteen.n_recv_workers * sixteen.thread_tput
    assert total_1 < total_16 < 16 * total_1
    assert total_16 == pytest.approx(pool_tput(DpaConfig("UD", 16)))


def test_dpa_backed_broadcast_faster_with_more_threads():
    fab = FabricParams()
    rng = np.random.default_rng(0)
    slow = simulate_broadcast(4, 8 << 20, fab,
                              workers_from_dpa(DpaConfig("UD", 1)), rng)
    rng = np.random.default_rng(0)
    fast = simulate_broadcast(4, 8 << 20, fab,
                              workers_from_dpa(DpaConfig("UD", 16)), rng)
    assert fast.time < slow.time
