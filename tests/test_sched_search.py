"""Schedule search (core/sched_search.py): every emitted candidate is a
valid acyclic schedule that lowers at all three fidelities; the winner
respects its admissible lower-bound certificate; the searched schedule
never loses to the best hand-written builder (and strictly beats it on the
oversubscribed FatTree and the Torus — the repo's acceptance fabrics); the
memoized evaluation cache is shared with autotune_chains; and the
engine="auto" packet-executor heuristic keeps explicit overrides
bit-exact."""
import math

import numpy as np
import pytest

from repro.core import packet as pk
from repro.core import protocol, sched_ir, sched_search
from repro.core.engine import FabricParams, WorkerParams
from repro.core.sched_search import EvalCache, EvalContext, search
from repro.core.topology import FatTree, Torus2D

FAB = FabricParams(jitter=0.0)
WK = WorkerParams(n_recv_workers=8)
P, N = 8, 1 << 20


def _fattree():
    return FatTree(k=8, n_hosts=16, oversubscription=4.0)


def _torus():
    return Torus2D(4, 4)


# --------------------------------------------------- candidate properties


@pytest.mark.parametrize("collective", sched_search.COLLECTIVES)
def test_candidates_validate_and_are_acyclic(collective):
    for cand in sched_search.candidates(collective, P, N, _fattree()):
        sched_ir.validate(cand.sched)          # asserts DAG-ness + typing
        gens = cand.sched.rounds()             # topological generations
        assert sum(len(g) for g in gens) == len(cand.sched.ops)


@pytest.mark.parametrize("collective", sched_search.COLLECTIVES)
def test_candidates_lower_at_all_three_fidelities(collective):
    rng = np.random.default_rng(0)
    for cand in sched_search.candidates(collective, P, N, None):
        a = sched_ir.execute(cand.sched, FAB, WK, fidelity="analytic")
        f = sched_ir.execute(cand.sched, FAB, WK, rng, fidelity="fluid")
        p = sched_ir.execute(cand.sched, FAB, WK, rng, fidelity="packet")
        assert math.isfinite(a) and a > 0
        assert a <= f.time + 1e-12 <= p.time + 1e-9


def test_chain_candidates_include_divisors_and_cut_derived():
    ms = sched_search.chain_candidates(16, _fattree())
    assert {1, 2, 4, 8, 16} <= set(ms)
    # oversubscription 4 -> the thin tier carries ~P/4 concurrent chains
    assert any(3 <= m <= 5 for m in ms)


# --------------------------------------------------- bounds / certificates


@pytest.mark.parametrize("topo_fn", [lambda: None, _fattree, _torus],
                         ids=["abstract", "fattree", "torus"])
def test_winner_respects_lower_bound_certificate(topo_fn):
    r = search("allreduce", P, N, topology=topo_fn(), validate_packet=False)
    assert r.certificate.bound <= r.winner_time + 1e-12
    assert r.certificate.ratio >= 1.0 - 1e-9
    # the per-candidate bounds are admissible for every SIMULATED candidate
    for row in r.table:
        if row.time is not None:
            assert row.bound <= row.time + 1e-12, row.name


@pytest.mark.parametrize("topo_fn", [_fattree, _torus],
                         ids=["fattree", "torus"])
def test_cut_lower_bound_admissible_for_builders(topo_fn):
    topo = topo_fn()
    rng = np.random.default_rng(0)
    for cand in sched_search.candidates("allreduce", 16, N, topo):
        topo.reset()
        t = sched_ir.execute(cand.sched, FAB, WK, rng, fidelity="fluid",
                             topology=topo).time
        lb = sched_search.cut_lower_bound(cand.sched, topo)
        assert lb <= t + 1e-12, cand.name


def test_bound_certificate_ratio_infinite_on_zero_bound():
    cert = protocol.BoundCertificate("allgather", 2, 1, 0.0, 1.0, "analytic")
    assert math.isinf(cert.ratio)


# ------------------------------------------------------- search outcomes


def test_search_never_loses_to_builders_and_wins_on_fattree():
    r = search("allreduce", 16, 16 << 20, topology=_fattree(), loss=0.001)
    assert r.searched_vs_best_builder <= 1.0
    assert r.winner_time < r.best_builder_time          # strict win
    assert r.winner.origin == "derived"
    assert r.packet_validated is True


def test_search_wins_strictly_on_torus():
    r = search("allreduce", 16, 16 << 20, topology=_torus(), loss=0.001)
    assert r.winner_time < r.best_builder_time
    # packet validation runs on the real torus (supports_packet=True:
    # leaf paths resolve via topology.host) and must converge under loss
    assert r.packet_validated is True


def test_search_matches_builder_when_space_is_builders_only():
    r = search("broadcast", P, N, validate_packet=False)
    assert r.winner.origin == "builder"
    assert r.searched_vs_best_builder == 1.0


def test_search_table_covers_every_candidate():
    r = search("allreduce", P, N, validate_packet=False)
    assert len(r.table) == r.evaluations + r.pruned
    assert all(row.time is None for row in r.table
               if row.name not in {t.name for t in r.table
                                   if t.time is not None})
    # pruned candidates were cut by the incumbent, not silently dropped
    for row in r.table:
        if row.time is None:
            assert row.bound >= r.winner_time - 1e-12


# ------------------------------------------------ metamorphic: more links


def test_adding_capacity_never_worsens_searched_time_fattree():
    """Adding links == raising cut capacity: de-oversubscribing the fabric
    (equivalently, adding parallel uplink cables at fluid fidelity) must
    never make the searched schedule slower."""
    cache = EvalCache()
    thin = search("allreduce", 16, 16 << 20, validate_packet=False,
                  topology=FatTree(k=8, n_hosts=16, oversubscription=4.0),
                  cache=cache)
    fat = search("allreduce", 16, 16 << 20, validate_packet=False,
                 topology=FatTree(k=8, n_hosts=16, oversubscription=1.0),
                 cache=cache)
    assert fat.winner_time <= thin.winner_time + 1e-12


def test_adding_capacity_never_worsens_searched_time_torus():
    slow = search("allreduce", 16, 16 << 20, validate_packet=False,
                  topology=Torus2D(4, 4, b_link=12.5e9))
    fast = search("allreduce", 16, 16 << 20, validate_packet=False,
                  topology=Torus2D(4, 4, b_link=25e9))
    assert fast.winner_time <= slow.winner_time + 1e-12


# -------------------------------------------------------- cache semantics


def test_search_reuses_cache_across_runs():
    cache = EvalCache()
    r1 = search("allreduce", P, N, validate_packet=False, cache=cache)
    misses = cache.misses
    r2 = search("allreduce", P, N, validate_packet=False, cache=cache)
    assert cache.misses == misses                 # fully served from cache
    assert r2.cache_hits == r2.evaluations
    assert r1.winner_time == r2.winner_time


def test_cache_key_separates_contexts():
    cache = EvalCache()
    sched = sched_ir.build_allgather(P, N, 2)
    ctx_a = EvalContext(FAB, WK)
    ctx_b = EvalContext(FAB, WorkerParams(n_recv_workers=1))
    t_a = cache.evaluate(sched, ctx_a).time
    t_b = cache.evaluate(sched, ctx_b).time
    assert cache.misses == 2 and t_a != t_b


def test_canonical_key_content_addressed():
    a = sched_ir.build_allgather(P, N, 2)
    b = sched_ir.build_allgather(P, N, 2)
    c = sched_ir.build_allgather(P, N, 4)
    assert sched_ir.canonical_key(a) == sched_ir.canonical_key(b)
    assert sched_ir.canonical_key(a) != sched_ir.canonical_key(c)


def test_autotune_chains_shares_cache_and_returns_full_sweep():
    cache = EvalCache()
    best, times = sched_ir.autotune_chains(sched_ir.build_allgather,
                                           p=P, n_bytes=N, cache=cache)
    assert set(times) == {m for m in range(1, P + 1) if P % m == 0}
    assert best == min(times, key=lambda m: (times[m], m))
    best2, times2 = sched_ir.autotune_chains(sched_ir.build_allgather,
                                             p=P, n_bytes=N, cache=cache)
    assert (best2, times2) == (best, times)
    assert cache.hits == len(times)               # second sweep: all hits


def test_autotune_chains_matches_direct_execution():
    _, times = sched_ir.autotune_chains(sched_ir.build_allgather,
                                        p=P, n_bytes=N)
    for m, t in times.items():
        direct = sched_ir.execute(sched_ir.build_allgather(P, N, m),
                                  FAB, WK, np.random.default_rng(0),
                                  fidelity="fluid")
        assert t == direct.time


# ----------------------------------------------- pipelined allreduce IR


def test_pipelined_allreduce_fidelity_ordering():
    sched = sched_ir.build_pipelined_allreduce(P, 4 << 20, 4, n_segments=4)
    rng = np.random.default_rng(0)
    a = sched_ir.execute(sched, FAB, WK, fidelity="analytic")
    f = sched_ir.execute(sched, FAB, WK, rng, fidelity="fluid")
    p = sched_ir.execute(sched, FAB, WK, rng, fidelity="packet")
    assert a <= f.time + 1e-12 <= p.time + 1e-9
    assert len(f.segments) == 4
    assert f.bytes_total > 0 and f.rs_time > 0 and f.ag_time > 0


def test_pipelined_single_segment_matches_barrier_time():
    rng = np.random.default_rng(0)
    pipe = sched_ir.build_pipelined_allreduce(P, 4 << 20, 4, n_segments=1)
    barrier = sched_ir.build_allreduce(P, 4 << 20, 4)
    tp = sched_ir.execute(pipe, FAB, WK, rng, fidelity="fluid").time
    tb = sched_ir.execute(barrier, FAB, WK, rng, fidelity="fluid").time
    assert tp == pytest.approx(tb, rel=1e-12)


def test_pipeline_recurrence_reduces_to_sum_for_one_segment():
    assert protocol.pipeline_schedule_time([3.0], [2.0]) == 5.0
    # overlap: second RS hides under first AG
    assert protocol.pipeline_schedule_time([1.0, 1.0], [1.0, 1.0]) == 3.0


def test_segment_bytes_partition():
    segs = sched_ir.segment_bytes(10, 3)
    assert sum(segs) == 10 and max(segs) - min(segs) <= 1


# ------------------------------------------------------- engine="auto"


def test_resolve_engine_passthrough_and_heuristic(monkeypatch):
    # this test pins the built-in resolution — shed any CI matrix override
    monkeypatch.delenv("REPRO_PACKET_ENGINE", raising=False)
    assert pk.resolve_engine("vectorized", "allgather", 8, 1 << 30) \
        == "vectorized"
    assert pk.resolve_engine("reference", "allgather", 1024, 1) \
        == "reference"
    # the dense big-row fallback (DESIGN §9) is retired: the pool scan in
    # kernels/pool_np closed the regime, so "auto" is vectorized everywhere
    assert pk.resolve_engine("auto", "allgather", 8, 32 << 20) == "vectorized"
    assert pk.resolve_engine("auto", "allgather", 8, 1 << 20) == "vectorized"
    assert pk.resolve_engine("auto", "allgather", 512, 1 << 30) \
        == "vectorized"
    assert pk.resolve_engine("auto", "broadcast", 8, 1 << 30) == "vectorized"
    with pytest.raises(AssertionError):
        pk.resolve_engine("nope", "allgather", 8, 1)


def test_engine_auto_bit_exact_with_explicit():
    """auto only picks between the bit-exact pair, so the default change
    can never alter results — pin it on both sides of the regime split."""
    for n_bytes in (1 << 18, (16 << 20) // 4):   # sparse / dense rows (m=4)
        res = {}
        for eng in ("auto", "vectorized", "reference"):
            sched = sched_ir.build_allgather(4, n_bytes, 4)
            r = sched_ir.execute(sched, FAB, WK, np.random.default_rng(7),
                                 fidelity="packet", loss=0.01, engine=eng)
            res[eng] = (r.time, r.recovered, r.bytes_fast)
        assert res["auto"] == res["vectorized"] == res["reference"]


# ------------------------------------------------------------- wall-clock


def test_search_wall_clock_budget_p64():
    r = search("allreduce", 64, 16 << 20, validate_packet=False,
               topology=FatTree(k=8, n_hosts=64, oversubscription=4.0))
    assert r.wall_s < 30.0
    assert r.searched_vs_best_builder <= 1.0


# --------------------------------------- parallel tier / persistent cache


def _result_fields(r):
    return (r.winner.name, r.winner_time, r.winner_fabric_bytes,
            r.best_builder.name, r.best_builder_time, r.evaluations,
            r.cache_hits, r.pruned,
            [(c.name, c.origin, c.bound, c.time, c.fabric_bytes)
             for c in r.table])


def test_parallel_search_bitwise_identical_to_serial(monkeypatch):
    monkeypatch.delenv("REPRO_SEARCH_WORKERS", raising=False)
    serial = search("allgather", 16, N, topology=_fattree(),
                    hosts=list(range(16)), validate_packet=False)
    par = search("allgather", 16, N, topology=_fattree(),
                 hosts=list(range(16)), validate_packet=False, n_jobs=2)
    assert _result_fields(par) == _result_fields(serial)


def test_search_workers_env_opt_in(monkeypatch):
    # the env var is the CI/benchmark opt-in — it must route through the
    # same replay tier and change nothing about the result
    serial = search("allreduce", P, N, validate_packet=False)
    monkeypatch.setenv("REPRO_SEARCH_WORKERS", "2")
    par = search("allreduce", P, N, validate_packet=False)
    assert _result_fields(par) == _result_fields(serial)


def test_eval_cache_persists_across_processes_keyspace(tmp_path):
    """Disk round-trip: a fresh cache object (standing in for a fresh
    process) serves every evaluation of a rerun from disk — zero misses."""
    path = str(tmp_path / "evals.json")
    r1 = search("allgather", 16, N, topology=_fattree(),
                hosts=list(range(16)), validate_packet=False,
                cache=EvalCache(path))
    warm = EvalCache(path)
    r2 = search("allgather", 16, N, topology=_fattree(),
                hosts=list(range(16)), validate_packet=False, cache=warm)
    assert warm.misses == 0
    assert r2.cache_hits == r2.evaluations
    assert r2.winner_time == r1.winner_time
    assert r2.winner.name == r1.winner.name


def test_eval_cache_never_persists_identity_keyed_topologies(tmp_path):
    """A topology without signature() is keyed by id() — process-local, so
    its entries must stay out of the disk file."""
    class Opaque:
        supports_packet = False

        def reset(self):
            pass

    path = str(tmp_path / "evals.json")
    cache = EvalCache(path)
    sched = sched_ir.build_allgather(P, N, 2)
    cache.evaluate(sched, EvalContext(FAB, WK))             # persistable
    ctx_id = EvalContext(FAB, WK, Opaque())
    try:
        cache.evaluate(sched, ctx_id)
    except Exception:
        pass          # the opaque topology cannot lower — key still formed
    cache.save()
    reread = EvalCache(path)
    assert all("'id'" not in k for k in reread._disk)
    assert len(reread._disk) >= 1


def test_eval_cache_survives_corrupt_file(tmp_path):
    path = tmp_path / "evals.json"
    path.write_text("{not json")
    cache = EvalCache(str(path))
    assert len(cache._disk) == 0
    sched = sched_ir.build_allgather(P, N, 2)
    cache.evaluate(sched, EvalContext(FAB, WK))
    cache.save()                                   # replaces the bad file
    assert len(EvalCache(str(path))._disk) == 1


def test_eval_cache_persistent_classmethod(tmp_path, monkeypatch):
    monkeypatch.delenv("REPRO_EVAL_CACHE", raising=False)
    assert EvalCache.persistent().path is None
    p = str(tmp_path / "c.json")
    monkeypatch.setenv("REPRO_EVAL_CACHE", p)
    cache = EvalCache.persistent()
    assert cache.path == p
    sched = sched_ir.build_allgather(P, N, 2)
    cache.evaluate(sched, EvalContext(FAB, WK))
    cache.save()
    assert EvalCache.persistent().misses == 0      # loads, ready to serve


def test_sweep_chains_saves_shared_cache(tmp_path):
    path = str(tmp_path / "evals.json")
    best, times = sched_search.sweep_chains(
        sched_ir.build_allgather, p=P, n_bytes=N, fabric=FAB, workers=WK,
        candidates=[1, 2, 4], cache=EvalCache(path))
    warm = EvalCache(path)
    best2, times2 = sched_search.sweep_chains(
        sched_ir.build_allgather, p=P, n_bytes=N, fabric=FAB, workers=WK,
        candidates=[1, 2, 4], cache=warm)
    assert warm.misses == 0 and (best2, times2) == (best, times)
