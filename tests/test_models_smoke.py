"""Per-arch smoke tests: every assigned architecture instantiates a REDUCED
same-family config and runs one forward/train step on CPU (shapes + no NaNs).
The FULL configs are exercised only via the dry-run (ShapeDtypeStruct)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ShapeConfig, arch_names, get_model_config, reduced
from repro.models import build_model, count_params_analytic, make_dummy_batch

# jax model/integration tier: excluded from the fast CI
# lane (scripts/check.sh), run by the `slow` CI job
pytestmark = pytest.mark.slow

ALL_ARCHS = [
    "rwkv6-7b", "whisper-base", "phi-3-vision-4.2b", "deepseek-moe-16b",
    "moonshot-v1-16b-a3b", "yi-9b", "granite-3-8b", "granite-34b",
    "smollm-135m", "recurrentgemma-9b",
]


def test_registry_has_all_assigned():
    assert set(ALL_ARCHS) <= set(arch_names())


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_smoke(arch):
    cfg = reduced(get_model_config(arch))
    api = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = api.init_params(rng)
    shape = ShapeConfig("t", "train", 64, 2)
    batch = make_dummy_batch(cfg, shape, rng)
    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(api.loss_fn, has_aux=True)
    )(params, batch)
    assert jnp.isfinite(loss), arch
    assert 1.0 < float(loss) < 20.0  # ~ln(vocab) at init
    for leaf in jax.tree.leaves(grads):
        assert jnp.all(jnp.isfinite(leaf)), arch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_smoke(arch):
    cfg = reduced(get_model_config(arch))
    api = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = api.init_params(rng)
    b, s = 2, 64
    batch = make_dummy_batch(cfg, ShapeConfig("p", "prefill", s, b), rng)
    logits, cache = jax.jit(api.prefill_fn)(params, batch)
    assert logits.shape == (b, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits))
    dcache = api.init_cache(b, s)
    lg, dcache2 = jax.jit(api.decode_fn)(
        params, dcache, jnp.zeros((b,), jnp.int32), jnp.zeros((b,), jnp.int32)
    )
    assert lg.shape == (b, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(lg))
    assert jax.tree.structure(dcache2) == jax.tree.structure(dcache)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_param_counts(arch):
    """The FULL configs roughly match their published sizes (catches config
    transcription errors without allocating anything)."""
    cfg = get_model_config(arch)
    n = count_params_analytic(cfg)
    expected = {
        "rwkv6-7b": (6e9, 9e9),
        "whisper-base": (6e7, 1.3e8),
        "phi-3-vision-4.2b": (3.4e9, 4.6e9),
        "deepseek-moe-16b": (14e9, 19e9),
        "moonshot-v1-16b-a3b": (25e9, 33e9),  # 48L variant per assignment
        "yi-9b": (8e9, 10e9),
        "granite-3-8b": (7e9, 10e9),
        "granite-34b": (30e9, 38e9),
        "smollm-135m": (1.1e8, 1.7e8),
        "recurrentgemma-9b": (7.5e9, 11e9),
    }[arch]
    assert expected[0] < n < expected[1], f"{arch}: {n/1e9:.2f}B params"


def test_moe_active_params_fraction():
    cfg = get_model_config("deepseek-moe-16b")
    total = count_params_analytic(cfg)
    active = count_params_analytic(cfg, active_only=True)
    assert active < total / 3  # fine-grained MoE: ~2.8B active of 16B
