"""Route/tree invariants of the fabric engine (ISSUE 2 satellite):

  - every routed link physically exists in Topology.links()
  - up-down routes are loop-free; agg->core hops obey the attachment rule
    (core c hangs off agg c // (k/2) — the seed's ECMP inconsistency)
  - multicast trees are connected, span root + all members, and are trees
  - the routed ENGINE's per-link bytes equal the old static LinkCounters
    pass for identical schedules (ring and multicast-composition allgather)

Property-driven via hypothesis or the offline seeded shim.
"""
try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:  # offline: seeded-random shim (tests/_hypothesis_shim.py)
    from _hypothesis_shim import given, settings, strategies as st
import pytest

from repro.core import cost_model as cm
from repro.core.engine import Engine
from repro.core.topology import FatTree, Torus2D, Topology


def _assert_physical(topo, links):
    table = topo.links()
    for link in links:
        assert table.get((link.src, link.dst)) is link, (link.src, link.dst)


def _assert_tree(topo, root_name, member_names, links):
    """Connected, spanning, acyclic: every non-root node has exactly one
    in-edge and is reachable from the root."""
    children = {}
    in_deg = {}
    nodes = set()
    for link in links:
        children.setdefault(link.src, []).append(link.dst)
        in_deg[link.dst] = in_deg.get(link.dst, 0) + 1
        nodes.update((link.src, link.dst))
    assert all(d == 1 for d in in_deg.values()), in_deg
    assert root_name not in in_deg
    reached = {root_name}
    stack = [root_name]
    while stack:
        for nxt in children.get(stack.pop(), []):
            if nxt not in reached:
                reached.add(nxt)
                stack.append(nxt)
    assert reached == nodes
    for m in member_names:
        assert m in nodes, m
    assert len(links) == len(nodes) - 1


# ------------------------------------------------------------ fat-tree routes


@given(st.integers(2, 5).map(lambda h: 2 * h),        # k in {4, 6, 8, 10}
       st.integers(0, 10_000), st.integers(0, 10_000))
@settings(max_examples=60, deadline=None)
def test_fat_tree_route_links_exist_and_loop_free(k, a, b):
    tree = FatTree(k=k)
    src, dst = a % tree.n_hosts, b % tree.n_hosts
    route = tree.route(src, dst)
    if src == dst:
        assert route == []
        return
    _assert_physical(tree, route)
    # contiguous path host(src) -> ... -> host(dst)
    assert route[0].src == tree.host(src)
    assert route[-1].dst == tree.host(dst)
    for x, y in zip(route, route[1:]):
        assert x.dst == y.src
    # loop-free: no node visited twice
    visited = [route[0].src] + [l.dst for l in route]
    assert len(visited) == len(set(visited))
    assert len(route) <= 6                      # up-down: at most 6 hops


@given(st.integers(2, 5).map(lambda h: 2 * h), st.integers(0, 10_000),
       st.integers(0, 10_000))
@settings(max_examples=60, deadline=None)
def test_fat_tree_core_attachment_rule(k, a, b):
    """The regression for the seed's ECMP bug: on inter-pod routes the
    agg->core and core->agg hops must obey core // (k/2) == agg index."""
    tree = FatTree(k=k)
    h2 = k // 2
    route = tree.route(a % tree.n_hosts, b % tree.n_hosts)
    for link in route:
        ends = {link.src, link.dst}
        cores = [n for n in ends if n.startswith("c")]
        if cores:
            (core,) = cores
            (agg,) = ends - set(cores)
            c = int(core[1:])
            a_ix = int(agg.split(".")[1])
            assert c // h2 == a_ix, (link.src, link.dst)


@given(st.integers(2, 5).map(lambda h: 2 * h), st.integers(0, 10_000),
       st.lists(st.integers(0, 10_000), min_size=1, max_size=24))
@settings(max_examples=60, deadline=None)
def test_fat_tree_multicast_tree_spans_members(k, root, members):
    tree = FatTree(k=k)
    root = root % tree.n_hosts
    members = sorted({m % tree.n_hosts for m in members} | {root})
    links = tree.multicast_tree(root, members)
    _assert_physical(tree, links)
    _assert_tree(tree, tree.host(root), [tree.host(m) for m in members if m != root],
                 links)


# --------------------------------------------------------------- torus routes


@given(st.integers(2, 6), st.integers(2, 6),
       st.integers(0, 10_000), st.integers(0, 10_000))
@settings(max_examples=60, deadline=None)
def test_torus_route_shortest_and_physical(nx, ny, a, b):
    tz = Torus2D(nx, ny)
    n = nx * ny
    src, dst = a % n, b % n
    route = tz.route(src, dst)
    _assert_physical(tz, route)
    sx, sy = tz.coord(src)
    dx, dy = tz.coord(dst)
    dist = min((dx - sx) % nx, (sx - dx) % nx) + min((dy - sy) % ny, (sy - dy) % ny)
    assert len(route) == dist
    if route:
        assert route[0].src == tz.node(sx, sy)
        assert route[-1].dst == tz.node(dx, dy)
        for x, y in zip(route, route[1:]):
            assert x.dst == y.src


@given(st.integers(2, 6), st.integers(2, 6), st.integers(0, 10_000),
       st.lists(st.integers(0, 10_000), min_size=1, max_size=16))
@settings(max_examples=60, deadline=None)
def test_torus_multicast_tree_spans_members(nx, ny, root, members):
    tz = Torus2D(nx, ny)
    n = nx * ny
    root = root % n
    members = sorted({m % n for m in members} | {root})
    links = tz.multicast_tree(root, members)
    _assert_physical(tz, links)
    _assert_tree(tz, tz.node(*tz.coord(root)),
                 [tz.node(*tz.coord(m)) for m in members if m != root], links)


def test_topologies_satisfy_protocol():
    assert isinstance(FatTree(k=4), Topology)
    assert isinstance(Torus2D(2, 2), Topology)


def test_aggregation_tree_is_reversed_multicast_tree():
    tree = FatTree(k=8, n_hosts=32)
    members = list(range(0, 32, 3))
    down = tree.multicast_tree(3, members)
    up = tree.aggregation_tree(3, members)
    assert {(l.src, l.dst) for l in up} == {(l.dst, l.src) for l in down}
    _assert_physical(tree, up)


def test_nonexistent_link_asserts():
    tree = FatTree(k=4)
    with pytest.raises(AssertionError, match="nonexistent fabric link"):
        tree.link("a0.0", "c3")       # core 3 hangs off agg 1, not agg 0


# ------------------------------- routed engine == static counters equivalence


def _engine_per_link(eng):
    return {name: b for name, b in eng.link_bytes().items() if b}


def test_routed_ring_equals_static_counters():
    """The compressed routed ring schedule (one flow per neighbor route
    carrying (P-1)*shard) must charge exactly the bytes the old static
    per-round unicast pass counts."""
    p, nbytes = 24, 3 << 20
    tree = FatTree(k=8, n_hosts=p)
    _, engine_bytes = cm.routed_ring_allgather(tree, p, nbytes)
    engine_bytes = {k: v for k, v in engine_bytes.items() if v}

    tree.reset()
    shard = nbytes // p
    for _ in range(p - 1):
        for src in range(p):
            tree.unicast(src, (src + 1) % p, shard)
    static = {l.name: l.bytes_served for l in tree.links().values()
              if l.bytes_served}
    assert static.keys() == engine_bytes.keys()
    for name, b in static.items():
        assert engine_bytes[name] == pytest.approx(b, rel=1e-9), name


def test_routed_mcast_allgather_equals_static_counters():
    """P concurrent multicast tree flows through the engine charge the same
    per-link bytes as the static broadcast-composition pass (Insight 1:
    every byte on every tree link exactly once)."""
    p, shard = 16, 1 << 16
    tree = FatTree(k=8, n_hosts=p)
    hosts = list(range(p))

    tree.reset()
    eng = Engine()
    flows = [eng.submit_tree(tree.multicast_tree(h, hosts), shard, tag=f"c{h}")
             for h in hosts]
    eng.run()
    assert all(f.done for f in flows)
    engine_bytes = _engine_per_link(eng)

    tree.reset()
    for root in hosts:
        tree.multicast(root, hosts, shard)
    static = {l.name: l.bytes_served for l in tree.links().values()
              if l.bytes_served}
    assert static.keys() == engine_bytes.keys()
    for name, b in static.items():
        assert engine_bytes[name] == pytest.approx(b, rel=1e-9), name


def test_routed_flow_rate_is_min_share_over_route():
    """A route flow crossing a thin tier runs at the thin link's share even
    while its host links are idle-fast (oversubscription bites)."""
    tree = FatTree(k=4, n_hosts=4, b_host=100.0, oversubscription=4.0)
    eng = Engine()
    r = tree.route(0, 2)                      # crosses edge->agg at cap 25
    assert any(l.capacity == pytest.approx(25.0) for l in r)
    f = eng.submit_route(r, 250.0)
    eng.run()
    assert f.t_end == pytest.approx(10.0)     # 250 bytes at 25 B/s

    # the same path at full bisection runs at host line rate
    flat = FatTree(k=4, n_hosts=4, b_host=100.0)
    eng2 = Engine()
    f2 = eng2.submit_route(flat.route(0, 2), 250.0)
    eng2.run()
    assert f2.t_end == pytest.approx(2.5)


# --------------------------------------------- torus packet fidelity (PR 9)


def test_torus_supports_packet():
    assert Torus2D(4, 4).supports_packet is True
    assert Torus2D(4, 4).host(6) == "t1.2"


def test_torus_zero_loss_packet_reproduces_fluid_broadcast():
    """Loss-0 packet == fluid on Torus2D, same pin the fat-tree fabrics
    carry: leaf paths resolve through topology.host(), so receivers that
    are interior tree nodes (every non-leaf torus member) work too."""
    from repro.core.engine import FabricParams, WorkerParams
    from repro.core.simulator import simulate_broadcast
    import numpy as np

    fab = FabricParams(jitter=0.0)
    wk = WorkerParams(n_recv_workers=8)
    tz = Torus2D(4, 4)
    a = simulate_broadcast(16, 1 << 20, fab, wk, np.random.default_rng(0),
                           topology=tz)
    b = simulate_broadcast(16, 1 << 20, fab, wk, np.random.default_rng(0),
                           topology=tz, fidelity="packet")
    assert b.time == pytest.approx(a.time, rel=1e-9)
    assert a.link_bytes == pytest.approx(b.link_bytes)


def test_torus_zero_loss_packet_reproduces_fluid_allgather():
    """Routed allgather at loss 0: the packet engine lands within the same
    per-hop-handshake margin of the fluid time on Torus2D as on FatTree —
    and EXACTLY matches the fat-tree packet time at equal line rate (both
    fabrics are non-blocking for this pattern), so the torus leaf-path
    resolution introduces no deviation of its own."""
    from repro.core import sched_ir
    from repro.core.engine import FabricParams, WorkerParams
    from repro.core.topology import FatTree
    import numpy as np

    fab = FabricParams(jitter=0.0)
    wk = WorkerParams(n_recv_workers=8)
    sched = sched_ir.build_allgather(16, 1 << 20, 4)
    res = {}
    for fid in ("fluid", "packet"):
        res[fid] = sched_ir.execute(sched, fab, wk, np.random.default_rng(0),
                                    fidelity=fid, topology=Torus2D(4, 4))
    assert res["packet"].time == pytest.approx(res["fluid"].time, rel=0.05)
    assert res["packet"].recovered == 0 and res["packet"].completed
    ft = sched_ir.execute(sched, fab, wk, np.random.default_rng(0),
                          fidelity="packet", topology=FatTree(k=8, n_hosts=16))
    assert res["packet"].time == pytest.approx(ft.time, rel=1e-12)


def test_torus_lossy_packet_converges_and_is_slower():
    from repro.core import sched_ir
    from repro.core.engine import FabricParams, WorkerParams
    import numpy as np

    fab = FabricParams(jitter=0.0)
    wk = WorkerParams(n_recv_workers=8)
    sched = sched_ir.build_allgather(16, 1 << 20, 4)
    tz = Torus2D(4, 4)
    clean = sched_ir.execute(sched, fab, wk, np.random.default_rng(0),
                             fidelity="packet", topology=tz)
    tz2 = Torus2D(4, 4)
    lossy = sched_ir.execute(sched, fab, wk, np.random.default_rng(0),
                             fidelity="packet", topology=tz2, loss=0.01)
    assert lossy.completed and lossy.recovered > 0
    assert lossy.time > clean.time
