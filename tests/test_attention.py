"""Blockwise online-softmax attention vs a naive oracle (+ decode paths)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as A


def naive_attention(q, k, v, causal=True, window=None, softcap=None):
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    qq = q.reshape(b, sq, kvh, h // kvh, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qq, k.astype(jnp.float32)) * hd**-0.5
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= qpos - kpos < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bkgqh", p, v.astype(jnp.float32))
    return o.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd).astype(q.dtype)


CASES = [
    # (S, H, KV, hd, causal, window, qb, kb)
    (64, 4, 4, 16, True, None, 16, 16),
    (96, 4, 2, 16, True, None, 32, 16),   # GQA, ragged blocks
    (64, 4, 1, 16, True, None, 16, 32),   # MQA
    (100, 2, 2, 8, True, None, 32, 32),   # non-divisible padding
    (64, 4, 4, 16, False, None, 16, 16),  # non-causal (encoder/cross)
    (128, 4, 2, 16, True, 32, 32, 32),    # windowed (RG local attention)
]


@pytest.mark.parametrize("case", CASES)
def test_blockwise_matches_naive(case):
    s, h, kv, hd, causal, window, qb, kb = case
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((2, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, s, kv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, s, kv, hd)), jnp.float32)
    out = A.blockwise_attention(
        q, k, v, causal=causal, window=window, q_block=qb, kv_block=kb
    )
    ref = naive_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_softcap():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((1, 32, 2, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 32, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 32, 2, 8)), jnp.float32)
    out = A.blockwise_attention(q, k, v, causal=True, q_block=8, kv_block=8,
                                softcap=5.0)
    ref = naive_attention(q, k, v, causal=True, softcap=5.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_plain_decode_matches_naive_last_row():
    rng = np.random.default_rng(2)
    b, s, h, kv, hd = 2, 40, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, kv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, kv, hd)), jnp.float32)
    full = naive_attention(q, k, v, causal=True)
    # decode the last position against the cache
    kc = k.transpose(0, 2, 1, 3)
    vc = v.transpose(0, 2, 1, 3)
    pos = jnp.full((b,), s - 1, jnp.int32)
    out = A.plain_decode_attention(q[:, -1], kc, vc, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(full[:, -1]), atol=2e-5)


def test_ring_decode_matches_window():
    rng = np.random.default_rng(3)
    b, h, kv, hd, w = 2, 4, 1, 16, 16
    s = 40  # decode at position 39 with a 16-deep ring
    q_all = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
    k_all = jnp.asarray(rng.standard_normal((b, s, kv, hd)), jnp.float32)
    v_all = jnp.asarray(rng.standard_normal((b, s, kv, hd)), jnp.float32)
    full = naive_attention(q_all, k_all, v_all, causal=True, window=w)
    # build the ring cache for the last w positions
    kc = jnp.zeros((b, kv, w, hd))
    vc = jnp.zeros((b, kv, w, hd))
    for p in range(s):
        kc = kc.at[:, :, p % w].set(k_all[:, p])
        vc = vc.at[:, :, p % w].set(v_all[:, p])
    pos = jnp.full((b,), s - 1, jnp.int32)
    idx = jnp.arange(w)
    abs_pos = pos[:, None] - ((pos[:, None] - idx[None, :]) % w)
    out = A.ring_decode_attention(q_all[:, -1], kc, vc, abs_pos, pos, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(full[:, -1]), atol=2e-5)


def test_cache_scatter_update():
    b, kv, s, hd = 3, 2, 16, 8
    cache = jnp.zeros((b, kv, s, hd))
    new = jnp.ones((b, kv, hd))
    pos = jnp.array([0, 5, 15], jnp.int32)
    out = A.cache_scatter_update(cache, new, pos)
    for i, p in enumerate([0, 5, 15]):
        assert float(out[i, :, p].sum()) == kv * hd
    assert float(out.sum()) == b * kv * hd
