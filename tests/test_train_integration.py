"""End-to-end training integration: descent, grad-accum equivalence, and the
FSDP-mode equivalence on a multi-device mesh."""
import jax
import pytest

from repro.configs import (RunConfig, ShapeConfig, TrainConfig,
                           get_model_config, reduced)
from repro.data import SyntheticPipeline
from repro.runtime import init_state, make_train_step
# jax model/integration tier: excluded from the fast CI
# lane (scripts/check.sh), run by the `slow` CI job
pytestmark = pytest.mark.slow


def _run(grad_accum=1, steps=30):
    cfg = reduced(get_model_config("smollm-135m"))
    return RunConfig(
        model=cfg, shape=ShapeConfig("t", "train", 64, 8),
        train=TrainConfig(steps=steps, grad_accum=grad_accum,
                          learning_rate=1e-2, warmup_steps=2),
    )


def test_loss_descends():
    run = _run()
    api, ctx, step = make_train_step(run, None)
    state = init_state(run, None, jax.random.PRNGKey(0))
    pipe = SyntheticPipeline(run.model, run.shape)
    jstep = jax.jit(step)
    losses = []
    for i in range(30):
        state, m = jstep(state, pipe.next_batch(i))
        losses.append(float(m["loss"]))
    assert min(losses[-5:]) < losses[0] - 0.3, losses[:3] + losses[-3:]


def test_grad_accum_equivalence():
    """accum=2 on the same global batch gives (nearly) the same first step."""
    pipe = SyntheticPipeline(_run().model, _run().shape)
    batch = pipe.next_batch(0)
    results = {}
    for a in (1, 2):
        run = _run(grad_accum=a)
        api, ctx, step = make_train_step(run, None)
        state = init_state(run, None, jax.random.PRNGKey(0))
        _, m = jax.jit(step)(state, batch)
        results[a] = (float(m["loss"]), float(m["grad_norm"]))
    assert results[1][0] == pytest.approx(results[2][0], rel=1e-5)
    assert results[1][1] == pytest.approx(results[2][1], rel=1e-3)


def test_fsdp_modes_bitwise_equal(multidev):
    """xla vs mcast vs mcast_bcast: identical loss/grad-norm on a (2,4) mesh."""
    multidev(
        """
import jax, dataclasses
from repro.configs import (CollectiveConfig, MeshConfig, RunConfig, ShapeConfig,
                           TrainConfig, get_model_config, reduced)
from repro.runtime import init_state
from repro.runtime.train_loop import jit_train_step
from repro.data import SyntheticPipeline

class SmallMesh(MeshConfig):
    @property
    def shape(self): return (2, 4)
    @property
    def axes(self): return ('data', 'model')

cfg = reduced(get_model_config('smollm-135m'))
out = {}
for mode in ['xla', 'mcast', 'mcast_bcast']:
    run = RunConfig(model=cfg, shape=ShapeConfig('t','train',64,4), mesh=SmallMesh(),
                    train=TrainConfig(steps=5),
                    collective=CollectiveConfig(fsdp_mode=mode, n_chains=2))
    mesh = jax.make_mesh((2,4), ('data','model'))
    api, jstep = jit_train_step(run, mesh)
    state = init_state(run, mesh, jax.random.PRNGKey(0))
    pipe = SyntheticPipeline(cfg, run.shape)
    state, m = jstep(state, pipe.next_batch(0))
    out[mode] = (float(m['loss']), float(m['grad_norm']))
base = out['xla']
for mode, val in out.items():
    assert abs(val[0] - base[0]) < 1e-6, (mode, val, base)
    assert abs(val[1] - base[1]) < 1e-5, (mode, val, base)
print('ok', out)
"""
    )


def test_moe_train_multidev(multidev):
    """MoE arch trains on the mesh (EP dispatch lowers + finite loss)."""
    multidev(
        """
import jax
from repro.configs import (MeshConfig, RunConfig, ShapeConfig, TrainConfig,
                           get_model_config, reduced)
from repro.runtime import init_state
from repro.runtime.train_loop import jit_train_step
from repro.data import SyntheticPipeline

class SmallMesh(MeshConfig):
    @property
    def shape(self): return (2, 4)
    @property
    def axes(self): return ('data', 'model')

cfg = reduced(get_model_config('deepseek-moe-16b'))
run = RunConfig(model=cfg, shape=ShapeConfig('t','train',64,4), mesh=SmallMesh(),
                train=TrainConfig(steps=2))
mesh = jax.make_mesh((2,4), ('data','model'))
api, jstep = jit_train_step(run, mesh)
state = init_state(run, mesh, jax.random.PRNGKey(0))
pipe = SyntheticPipeline(cfg, run.shape)
state, m = jstep(state, pipe.next_batch(0))
import math

assert math.isfinite(float(m['loss']))
print('ok', float(m['loss']))
"""
    )
