"""Property tests: the reliable-broadcast protocol recovers from ANY drop and
reorder pattern (paper §III) — hypothesis drives adversarial fabrics."""
try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:  # offline: seeded-random shim (tests/_hypothesis_shim.py)
    from _hypothesis_shim import given, settings, strategies as st
import numpy as np

from repro.core import protocol


@st.composite
def broadcast_case(draw):
    n_bytes = draw(st.integers(1, 40_000))
    mtu = draw(st.sampled_from([512, 1024, 4096]))
    p = draw(st.integers(2, 6))
    seed = draw(st.integers(0, 2**31))
    drop = draw(st.floats(0.0, 0.9))
    return n_bytes, mtu, p, seed, drop


@given(broadcast_case())
@settings(max_examples=40, deadline=None)
def test_recovery_under_arbitrary_drops(case):
    n_bytes, mtu, p, seed, drop = case
    rng = np.random.default_rng(seed)
    buf = bytes(rng.integers(0, 256, n_bytes, dtype=np.uint8))
    chunks = protocol.segment(buf, mtu)
    leaves = [protocol.LeafReceiver(n_bytes, mtu) for _ in range(p - 1)]
    # out-of-order delivery with independent drops per leaf
    for leaf in leaves:
        order = rng.permutation(len(chunks))
        for i in order:
            if rng.random() >= drop:
                leaf.deliver(chunks[i])
    # fetch-ring recovery (left neighbours, root as last resort)
    for li, leaf in enumerate(leaves):
        peers = [leaves[(li - 1 - j) % len(leaves)] for j in range(len(leaves) - 1)]
        leaf.fetch_recover(peers, buf)
    for leaf in leaves:
        assert leaf.complete()
        assert bytes(leaf.user) == buf
    assert protocol.final_handshake_ok([l.complete() for l in leaves])


@given(st.integers(1, 100_000), st.sampled_from([512, 4096]))
@settings(max_examples=40, deadline=None)
def test_bitmap_tracks_exactly(n_bytes, mtu):
    n_chunks = -(-n_bytes // mtu)
    bm = protocol.Bitmap(n_chunks)
    rng = np.random.default_rng(0)
    got = set(rng.choice(n_chunks, size=max(n_chunks // 2, 1), replace=False).tolist())
    for i in got:
        bm.set(i)
    assert bm.popcount() == len(got)
    assert set(bm.missing()) == set(range(n_chunks)) - got
    assert bm.complete() == (len(got) == n_chunks)


def test_duplicate_delivery_idempotent():
    buf = bytes(range(256)) * 16
    chunks = protocol.segment(buf, 512)
    leaf = protocol.LeafReceiver(len(buf), 512)
    for c in chunks:
        leaf.deliver(c)
        leaf.deliver(c)  # duplicates (multicast re-tx) must be harmless
    assert leaf.complete() and bytes(leaf.user) == buf
    assert leaf.duplicates == len(chunks)


def test_staging_rnr_drop():
    s = protocol.StagingRing(capacity_chunks=2)
    assert s.arrive() and s.arrive()
    assert not s.arrive()          # full -> RNR drop
    assert s.rnr_drops == 1
    s.drain()
    assert s.arrive()


def test_fig7_memory_model():
    # 24-bit PSN at 4 KiB MTU addresses 64 GiB; 16 GiB buffer -> 64 KiB bitmap
    assert protocol.max_addressable_buffer(24) == (1 << 24) * 4096
    assert protocol.bitmap_bytes(16 << 30) == (16 << 30) // 4096 // 8
    # §III-D(d): >16 communicators fit the 1.5 MB LLC with 16 GiB recv buffers
    assert protocol.communicators_in_llc() > 16


def test_cutoff_time_scaling():
    t1 = protocol.cutoff_time(1 << 20, 25e9)
    t2 = protocol.cutoff_time(1 << 24, 25e9)
    assert t2 > t1  # N/B + alpha


def test_analytic_oracle_shapes():
    """Closed-form cross-check path: the ring baseline inflates with loss,
    expected recovery rounds shrink as loss drops, and the engine-backed
    facade agrees with the oracle at loss 0."""
    b, lat = 25e9, 2e-6
    t0 = protocol.analytic_ring_pipeline_bcast_time(16, 1 << 20, b, lat)
    t1 = protocol.analytic_ring_pipeline_bcast_time(16, 1 << 20, b, lat,
                                                    loss_rate=0.1)
    assert t1 > t0 > 0
    assert protocol.analytic_ring_pipeline_bcast_time(
        64, 1 << 20, b, lat) > t0          # more hops, more latency
    r_hi = protocol.analytic_expected_rounds(0.1, 256)
    r_lo = protocol.analytic_expected_rounds(0.001, 256)
    assert r_hi > r_lo >= 1.0
    assert protocol.analytic_expected_rounds(0.0, 256) == 0.0
    assert protocol.analytic_recovery_time(
        16, 1 << 20, b, lat, 0.0) == 0.0
    assert protocol.analytic_recovery_time(
        64, 1 << 20, b, lat, 0.01) > protocol.analytic_recovery_time(
        64, 1 << 20, b, lat, 0.0001)


def test_engine_backed_facade():
    """protocol.broadcast_time/allgather_time ARE the engine-backed timing
    model (packet fidelity by default) and agree with the closed form."""
    t_pkt = protocol.broadcast_time(16, 1 << 20)
    t_fluid = protocol.broadcast_time(16, 1 << 20, fidelity="fluid")
    assert t_pkt > 0 and t_fluid > 0
    assert protocol.allgather_time(8, 1 << 18, n_chains=8) > 0
    ana = protocol.analytic_bcast_time(16, 1 << 20, 200e9 / 8, 2e-6,
                                       pool_rate=5.2 * (1 << 30))
    assert 0.5 < t_pkt / ana < 2.0


def test_facade_routes_dpa_config_to_event_engine():
    """``dpa=`` on the facade replaces the scalar pool_tput consumption
    with the event-level DPA engine (core/dpa_engine.py): a DpaConfig is
    accepted directly, a fatter pool is never slower, and the analytic
    closed form still brackets the event-backed time."""
    from repro.core.dpa import DpaConfig

    t_16 = protocol.broadcast_time(16, 1 << 20, dpa=DpaConfig("UD", 16))
    t_2 = protocol.broadcast_time(16, 1 << 20, dpa=DpaConfig("UD", 2))
    assert 0 < t_16 <= t_2
    ana = protocol.analytic_bcast_time(16, 1 << 20, 200e9 / 8, 2e-6)
    assert ana <= t_16 < 3.0 * ana
    assert protocol.allgather_time(8, 1 << 18, n_chains=8,
                                   dpa=DpaConfig("UC", 16)) > 0
