"""The paper's shard_map collectives on 8 fake devices (subprocess)."""
import pytest

# jax model/integration tier: excluded from the fast CI
# lane (scripts/check.sh), run by the `slow` CI job
pytestmark = pytest.mark.slow



def test_allgather_modes(multidev):
    multidev(
        """
import pytest
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core import collectives as C
mesh = jax.make_mesh((8,), ('x',))
n = 64
full = jnp.arange(8 * n, dtype=jnp.float32)
sharded = jax.device_put(full, NamedSharding(mesh, P('x')))
for mode in ['ring', 'bidi']:
    out = C.make_allgather(mesh, 'x', mode)(sharded)
    assert np.allclose(np.asarray(out), np.asarray(full)), mode
for m in [1, 2, 4, 8]:
    out = C.make_allgather(mesh, 'x', 'bcast', n_chains=m)(sharded)
    assert np.allclose(np.asarray(out), np.asarray(full)), m
print('ok')
"""
    )


def test_reduce_scatter_and_concurrent(multidev):
    multidev(
        """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro import compat
from repro.core import collectives as C
mesh = jax.make_mesh((8,), ('x',))
n = 64
full = jnp.arange(8 * n, dtype=jnp.float32)
per_dev = jnp.stack([full * (i + 1) for i in range(8)])
for mode, local in [('ring', C.ring_reduce_scatter_local),
                    ('bidi', C.bidi_ring_reduce_scatter_local)]:
    sm = compat.shard_map(lambda x: local(x[0], 'x'), mesh=mesh,
                       in_specs=P('x'), out_specs=P('x'), check_vma=False)
    out = sm(per_dev)
    expect = np.asarray(full).reshape(8, n) * 36
    assert np.allclose(np.asarray(out), expect.reshape(-1)), mode
# concurrent AG+RS (direction split)
sharded = jax.device_put(full, NamedSharding(mesh, P('x')))
agf, rss = jax.jit(lambda a, r: compat.shard_map(
    lambda aa, rr: C.concurrent_ag_rs_local(aa, rr[0], 'x'),
    mesh=mesh, in_specs=(P('x'), P('x')), out_specs=(P(), P('x')),
    check_vma=False)(a, r))(sharded, per_dev.reshape(8, 8 * n))
assert np.allclose(np.asarray(agf), np.asarray(full))
assert np.allclose(np.asarray(rss), (np.asarray(full).reshape(8, n) * 36).reshape(-1))
print('ok')
"""
    )


def test_pipelined_broadcast_roots_and_chunks(multidev):
    multidev(
        """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core import collectives as C
mesh = jax.make_mesh((8,), ('x',))
n = 64
full = jnp.arange(8 * n, dtype=jnp.float32)
sharded = jax.device_put(full, NamedSharding(mesh, P('x')))
for root in [0, 3, 7]:
    for nc in [1, 4, 8, 16]:
        out = C.make_broadcast(mesh, 'x', root=root, n_chunks=nc)(sharded)
        assert np.allclose(np.asarray(out), np.asarray(full[root*n:(root+1)*n])), (root, nc)
print('ok')
"""
    )


def test_collectives_gradients(multidev):
    """AD through the ppermute collectives: grad of sum(allgather(x)) == ones
    broadcast back (the transpose is the matching reduce-scatter)."""
    multidev(
        """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core import collectives as C

mesh = jax.make_mesh((8,), ('x',))
n = 32
full = jnp.arange(8 * n, dtype=jnp.float32)
sharded = jax.device_put(full, NamedSharding(mesh, P('x')))
for mode in ['ring', 'bidi']:
    ag = C.make_allgather(mesh, 'x', mode)
    g = jax.grad(lambda x: jnp.sum(ag(x) * 2.0))(sharded)
    assert np.allclose(np.asarray(g), 2.0), mode
print('ok')
"""
    )
