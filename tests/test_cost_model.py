"""Cost-model + topology traffic properties (paper Fig 2 / 12, Appendix B)."""
try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:  # offline: seeded-random shim (tests/_hypothesis_shim.py)
    from _hypothesis_shim import given, settings, strategies as st
import pytest

from repro.core import cost_model as cm
from repro.core.topology import FatTree


@given(st.integers(2, 4096))
@settings(max_examples=50, deadline=None)
def test_speedup_formula(p):
    s = cm.concurrent_ag_rs_speedup(p)
    assert s == pytest.approx(2 - 2 / p)
    assert 1.0 <= s < 2.0
    # derived from the bandwidth shares (Appendix B eq. 3)
    t_rr = cm.concurrent_completion_time(1 << 20, p, 25e9, "ring_ring")
    t_mi = cm.concurrent_completion_time(1 << 20, p, 25e9, "mc_inc")
    assert t_rr / t_mi == pytest.approx(s)


def test_nic_shares_no_shared_bottleneck():
    sh = cm.mc_inc_share(16)
    # AG_mc recv-bound, RS_inc send-bound: each direction sums to full B_nic
    assert sh.ag_recv + sh.rs_recv == pytest.approx(1.0)
    assert sh.ag_send + sh.rs_send == pytest.approx(1.0)
    assert sh.ag_recv > sh.ag_send  # receive-bound
    assert sh.rs_send > sh.rs_recv  # send-bound


@pytest.mark.parametrize("p", [16, 64, 256])
def test_fat_tree_traffic_reduction(p):
    """Fig 2/12: multicast allgather moves 1.5-2x less traffic than P2P ring,
    and >=P/2 x less than linear."""
    tree = FatTree(k=16, n_hosts=p)
    n = 1 << 20
    ring = cm.p2p_ring_allgather_traffic(tree, p, n)
    mc = cm.mcast_allgather_traffic(tree, p, n)
    linear = cm.p2p_linear_allgather_traffic(tree, p, n)
    assert mc < ring
    assert 1.3 < ring / mc < 3.0       # paper: 1.5-2x
    assert linear > ring                # direct P2P pays full path lengths


def test_bandwidth_optimality_per_link():
    """Insight 1: multicast broadcast puts each byte on each link at most once;
    the max per-link bytes equals the buffer size."""
    p, n = 64, 1 << 20
    tree = FatTree(k=16, n_hosts=p)
    cm.mcast_bcast_traffic(tree, p, n)
    assert tree.counters.max_link() == n


def test_bcast_traffic_vs_knomial():
    p, n = 64, 1 << 20
    tree = FatTree(k=16, n_hosts=p)
    kno = cm.p2p_knomial_bcast_traffic(tree, p, n)
    mc = cm.mcast_bcast_traffic(tree, p, n)
    assert mc < kno


def test_multicast_tree_is_connected_and_minimal():
    tree = FatTree(k=8)
    members = list(range(10))
    edges = tree.multicast_tree(0, members)
    nodes = set()
    for link in edges:
        nodes.add(link.src)
        nodes.add(link.dst)
    for m in members:
        assert tree.host(m) in nodes
    # spanning tree: every node except the root has exactly one in-edge
    assert len(edges) == len(nodes) - 1


def test_torus_ring_per_link_optimality():
    """DESIGN.md torus criterion: bidi ring halves per-direction link bytes."""
    uni = cm.torus_ring_per_link_bytes(16, 1 << 20, bidi=False)
    bidi = cm.torus_ring_per_link_bytes(16, 1 << 20, bidi=True)
    assert bidi == pytest.approx(uni / 2)


def test_bcast_time_models_constant_vs_tree():
    n, b = 64 << 20, 25e9
    t64 = cm.bcast_time_multicast(n, b, 64)
    t1024 = cm.bcast_time_multicast(n, b, 1024)
    assert t1024 == pytest.approx(t64, rel=0.01)  # constant in P
    assert cm.bcast_time_binary_tree(n, b, 1024) > 1.5 * t1024
    assert cm.bcast_time_knomial(n, b, 1024, k=4) > t1024
