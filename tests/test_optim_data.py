"""AdamW reference step, LR schedule, data determinism."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ShapeConfig, TrainConfig, get_model_config, reduced
from repro.data import DataConfig, SyntheticPipeline
from repro.optim import adamw


def test_adamw_matches_reference():
    tc = TrainConfig(learning_rate=0.1, warmup_steps=0, steps=1,
                     weight_decay=0.0, grad_clip=1e9)
    p = {"w": jnp.array([1.0, -2.0])}
    g = {"w": jnp.array([0.5, 0.5])}
    opt = adamw.init(p)
    newp, opt2, m = adamw.apply_updates(p, g, opt, tc)
    # step 1: m_hat = g, v_hat = g^2 -> delta = g/(|g|+eps) = sign(g)
    lr = float(adamw.lr_schedule(jnp.array(1), tc))
    expect = np.array([1.0, -2.0]) - lr * np.sign([0.5, 0.5])
    np.testing.assert_allclose(np.asarray(newp["w"]), expect, rtol=1e-4)
    assert int(opt2.step) == 1


def test_grad_clip():
    g = {"a": jnp.array([3.0, 4.0])}  # norm 5
    clipped, gn = adamw.clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(5.0)
    np.testing.assert_allclose(np.asarray(clipped["a"]), [0.6, 0.8], rtol=1e-5)


def test_lr_schedule_shape():
    tc = TrainConfig(learning_rate=1.0, warmup_steps=10, steps=100)
    lrs = [float(adamw.lr_schedule(jnp.array(s), tc)) for s in range(0, 101, 5)]
    assert lrs[0] < lrs[2]            # warmup ascends
    assert max(lrs) == pytest.approx(1.0, rel=0.05)
    assert lrs[-1] < 0.2              # cosine decays
    assert lrs[-1] >= 0.0999          # floor at 10%


def test_weight_decay_applied():
    tc = TrainConfig(learning_rate=0.1, warmup_steps=0, steps=1,
                     weight_decay=1.0, grad_clip=1e9)
    p = {"w": jnp.array([10.0])}
    g = {"w": jnp.array([0.0])}
    newp, *_ = adamw.apply_updates(p, g, adamw.init(p), tc)
    assert float(newp["w"][0]) < 10.0


def test_data_determinism_and_structure():
    cfg = reduced(get_model_config("smollm-135m"))
    shape = ShapeConfig("t", "train", 64, 4)
    p1 = SyntheticPipeline(cfg, shape, DataConfig(seed=7))
    p2 = SyntheticPipeline(cfg, shape, DataConfig(seed=7))
    b1, b2 = p1.next_batch(3), p2.next_batch(3)
    for k in b1:
        np.testing.assert_array_equal(np.asarray(b1[k]), np.asarray(b2[k]))
    b3 = p1.next_batch(4)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))
    # targets are next-token shifted
    np.testing.assert_array_equal(
        np.asarray(b1["targets"][:, :-1]), np.asarray(b1["tokens"][:, 1:])
    )


def test_vlm_targets_masked():
    cfg = reduced(get_model_config("phi-3-vision-4.2b"))
    shape = ShapeConfig("t", "train", 64, 2)
    pipe = SyntheticPipeline(cfg, shape)
    b = pipe.next_batch(0)
    np_ = cfg.vision.n_patches
    assert np.all(np.asarray(b["targets"][:, :np_]) == -1)
    assert np.all(np.asarray(b["targets"][:, np_:]) >= 0)
