"""Gradient compression: quantization fidelity + error-feedback convergence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.compression import (compress_leaf, compression_ratio,
                                     make_compressor)

# jax model/integration tier: excluded from the fast CI
# lane (scripts/check.sh), run by the `slow` CI job
pytestmark = pytest.mark.slow


def test_roundtrip_accuracy():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal((1000,)) * 0.01, jnp.float32)
    g_hat, err = compress_leaf(g, jnp.zeros_like(g), block=256)
    rel = float(jnp.linalg.norm(g_hat - g) / jnp.linalg.norm(g))
    assert rel < 0.01  # int8 blockwise: <1% relative error on gaussian grads
    np.testing.assert_allclose(np.asarray(g_hat + err), np.asarray(g), atol=1e-7)


def test_error_feedback_unbiased_accumulation():
    """Sum of EF-compressed grads converges to the sum of true grads."""
    rng = np.random.default_rng(1)
    true_sum = jnp.zeros((512,))
    comp_sum = jnp.zeros((512,))
    err = jnp.zeros((512,))
    for i in range(50):
        g = jnp.asarray(rng.standard_normal(512) * (0.1 / (i + 1)), jnp.float32)
        true_sum = true_sum + g
        g_hat, err = compress_leaf(g, err, block=128)
        comp_sum = comp_sum + g_hat
    # EF guarantees the residual is bounded by one step's quantization error
    drift = float(jnp.max(jnp.abs(comp_sum + err - true_sum)))
    assert drift < 1e-5


def test_training_with_compression_descends():
    from repro.configs import RunConfig, ShapeConfig, TrainConfig, get_model_config, reduced
    from repro.data import SyntheticPipeline
    from repro.optim import adamw
    from repro.models import build_model

    cfg = reduced(get_model_config("smollm-135m"))
    api = build_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    tc = TrainConfig(steps=25, learning_rate=1e-2, warmup_steps=2)
    opt = adamw.init(params)
    comp, err = make_compressor(params)
    pipe = SyntheticPipeline(cfg, ShapeConfig("t", "train", 64, 8))

    @jax.jit
    def step(params, opt, err, batch):
        (loss, _), grads = jax.value_and_grad(api.loss_fn, has_aux=True)(params, batch)
        grads, err = comp(grads, err)
        params, opt, _ = adamw.apply_updates(params, grads, opt, tc)
        return params, opt, err, loss

    losses = []
    for i in range(25):
        params, opt, err, loss = step(params, opt, err, pipe.next_batch(i))
        losses.append(float(loss))
    assert min(losses[-5:]) < losses[0] - 0.3, losses[:3] + losses[-3:]


def test_wire_ratio():
    assert compression_ratio(32, 256) == pytest.approx(32 / 8.125)
