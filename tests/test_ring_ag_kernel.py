"""Ring-allgather TPU kernel: schedule oracle + CPU-validatable datapath.

The remote-DMA kernel itself executes only on TPU hardware; on CPU we verify
(1) the forwarding schedule equals the numerically-verified shard_map
implementation, and (2) the local double-buffered chunk datapath in
interpret mode.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat

from repro.kernels.ring_allgather import (local_double_buffer_drain,
                                          ring_allgather_tpu, ring_schedule)


@pytest.mark.parametrize("p", [2, 4, 8, 16])
def test_schedule_delivers_every_shard_once(p):
    deliveries = {}  # (receiver, shard) -> step
    for s, trip in enumerate(ring_schedule(p)):
        assert len(trip) == p  # every link busy every step (bandwidth-optimal)
        for snd, rcv, shard in trip:
            assert rcv == (snd + 1) % p
            key = (rcv, shard)
            assert key not in deliveries, "duplicate delivery"
            deliveries[key] = s
    # after P-1 steps every device has every shard except... exactly the P-1
    # foreign shards were delivered to each device
    for d in range(p):
        got = {sh for (rcv, sh) in deliveries if rcv == d}
        assert got == set(range(p)) - {d}


def test_schedule_matches_shardmap_collective(multidev):
    """The kernel's (sender, shard) schedule is exactly what the verified
    ring_allgather_local executes: shard (d-s)%P leaves device d at step s."""
    multidev(
        """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core import collectives as C
from repro.kernels.ring_allgather import ring_schedule
mesh = jax.make_mesh((8,), ('x',))
full = jnp.arange(8 * 16, dtype=jnp.float32)
sharded = jax.device_put(full, NamedSharding(mesh, P('x')))
out = C.make_allgather(mesh, 'x', 'ring')(sharded)
assert np.allclose(np.asarray(out), np.asarray(full))
sched = ring_schedule(8)
assert sched[0][3] == (3, 4, 3)   # step 0: device d sends its own shard
assert sched[2][0] == (0, 1, 6)   # step 2: device 0 forwards shard (0-2)%8
print('ok')
"""
    )


@pytest.mark.parametrize("shape", [(6, 8, 128), (3, 16, 64)])
def test_local_datapath_interpret(shape):
    rng = np.random.default_rng(0)
    staged = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    out = local_double_buffer_drain(staged)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(staged))


def test_tpu_kernel_traces_and_shapes():
    """The remote-DMA kernel cannot EXECUTE off-TPU, but it must always
    TRACE: abstract evaluation runs the full pallas_call lowering contract
    (BlockSpecs, scratch semaphores, compiler params) without touching
    hardware. Replaces a perpetual TPU-only skip — and this exact check
    caught a pltpu.CompilerParams/TPUCompilerParams API break. On a real
    TPU backend the same function additionally executes and must match the
    identity allgather."""
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((jax.device_count(),), ("ring",))
    n = jax.device_count()
    x = jnp.arange(n * 8 * 128, dtype=jnp.float32).reshape(n * 8, 128)
    f = compat.shard_map(
        lambda xs: ring_allgather_tpu(xs, n_devices=n),
        mesh=mesh, in_specs=P("ring", None), out_specs=P(None, None),
        check_vma=False,
    )
    out = jax.eval_shape(f, x)
    assert out.shape == x.shape and out.dtype == x.dtype
    if jax.default_backend() == "tpu":   # numerical check where it can run
        np.testing.assert_allclose(np.asarray(f(x)), np.asarray(x))
