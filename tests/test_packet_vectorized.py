"""Differential harness pinning the vectorized packet engine bit-exact
against the per-leaf reference (core/packet.py engine="vectorized" vs
"reference", and the same knob through sched_ir.execute for allgather).

ZERO tolerance everywhere: the batch engine is a pure re-execution strategy
— same protocol, same RNG stream (modulo the documented jitter-elision
contract at jitter == 0), same floats in the same order — so every field of
every result, every per-round trace, and the staging-ring delivery order
must match EXACTLY. Property suites run through tests/_hypothesis_shim.py
(or real hypothesis when installed); REPRO_TEST_SEED salts the sample sets.
"""
import numpy as np
import pytest

from repro.core.engine import (
    FabricParams,
    WorkerParams,
    worker_pool_completion,
    worker_pool_completion_rows,
)
from repro.core.packet import (
    GilbertElliottLoss,
    attach_loss,
    simulate_packet_allgather,
    simulate_packet_broadcast,
)
from repro.core.topology import FatTree
from repro.kernels.bitmap_np import (
    bitmap_pack_np,
    bitmap_pack_rows_np,
    bitmap_popcount_np,
    bitmap_popcount_rows_np,
)

try:
    import hypothesis.strategies as hyp_st
    from hypothesis import given as hyp_given, settings as hyp_settings
except ImportError:
    from _hypothesis_shim import (given as hyp_given,
                                  settings as hyp_settings,
                                  strategies as hyp_st)

FAB = FabricParams(jitter=0.0)
FABJ = FabricParams()                      # default jitter 1e-6
WK = WorkerParams(n_recv_workers=8)        # pool rate > wire rate: no RNR
WK1 = WorkerParams()                       # 1 worker: RNR-prone


def assert_bcast_equal(a, b, ctx=""):
    """Every observable of PacketBcastResult, exactly."""
    np.testing.assert_array_equal(a.completion, b.completion, err_msg=ctx)
    assert a.phases == b.phases, ctx
    assert (a.delivered_fast, a.recovered, a.rnr_drops) == \
        (b.delivered_fast, b.recovered, b.rnr_drops), ctx
    assert (a.bytes_fast, a.bytes_recovery, a.bytes_total) == \
        (b.bytes_fast, b.bytes_recovery, b.bytes_total), ctx
    assert (a.retransmit_wire_bytes, a.duplicates, a.completed) == \
        (b.retransmit_wire_bytes, b.duplicates, b.completed), ctx
    assert a.link_bytes == b.link_bytes, ctx
    assert len(a.rounds) == len(b.rounds), ctx
    for ta, tb in zip(a.rounds, b.rounds):
        assert ta == tb, (ctx, ta, tb)
    assert sorted(a.delivery_order) == sorted(b.delivery_order), ctx
    for leaf in a.delivery_order:
        np.testing.assert_array_equal(a.delivery_order[leaf],
                                      b.delivery_order[leaf],
                                      err_msg=f"{ctx} leaf={leaf}")


def assert_ag_equal(a, b, ctx=""):
    """Every observable of PacketAllgatherResult, exactly."""
    assert (a.time, a.completed) == (b.time, b.completed), ctx
    assert a.phases == b.phases, ctx
    assert (a.recovered, a.rnr_drops, a.retransmit_wire_bytes) == \
        (b.recovered, b.rnr_drops, b.retransmit_wire_bytes), ctx
    assert (a.bytes_fast, a.bytes_recovery, a.bytes_total) == \
        (b.bytes_fast, b.bytes_recovery, b.bytes_total), ctx
    assert a.per_rank_recv_tput == b.per_rank_recv_tput, ctx
    assert a.link_bytes == b.link_bytes, ctx
    assert len(a.rounds) == len(b.rounds), ctx
    for ta, tb in zip(a.rounds, b.rounds):
        assert ta == tb, (ctx, ta, tb)


def run_bcast(engine, p, n, fab, wk, seed, **kw):
    return simulate_packet_broadcast(p, n, fab, wk,
                                     np.random.default_rng(seed), **kw,
                                     engine=engine)


# ------------------------------------------------- broadcast differential grid


GE = GilbertElliottLoss.from_rate(0.01, mean_burst=8.0)

BCAST_GRID = [
    # (p, n_bytes, fab, wk, loss, routed, seed)
    (4, 1 << 16, FAB, WK, None, False, 0),
    (4, 1 << 16, FABJ, WK, 0.02, False, 1),
    (16, 1 << 18, FAB, WK, 0.01, False, 0),
    (16, 1 << 18, FABJ, WK, None, False, 2),
    (16, 1 << 18, FABJ, WK1, 0.01, False, 3),       # RNR + loss + jitter
    (16, 1 << 18, FAB, WK, GE, False, 0),           # bursty chains
    (16, 1 << 17, FABJ, WK, 0.01, True, 1),         # routed FatTree
    (64, 1 << 18, FAB, WK, 0.005, True, 0),
    (64, 1 << 18, FABJ, WK1, GE, False, 4),
    (512, 1 << 18, FAB, WK, 0.002, False, 0),
]


@pytest.mark.parametrize("p,n,fab,wk,loss,routed,seed", BCAST_GRID)
def test_broadcast_vectorized_matches_reference(p, n, fab, wk, loss,
                                                routed, seed):
    topo = (FatTree(k=8 if p <= 64 else 32, n_hosts=p, b_host=fab.b_link)
            if routed else None)
    kw = dict(topology=topo, loss=loss, collect_delivery=True)
    a = run_bcast("vectorized", p, n, fab, wk, seed, **kw)
    b = run_bcast("reference", p, n, fab, wk, seed, **kw)
    assert_bcast_equal(a, b, ctx=f"p={p} loss={loss} routed={routed}")


def test_broadcast_unaggregated_nacks_match():
    for seed in (0, 1):
        a = run_bcast("vectorized", 16, 1 << 18, FABJ, WK, seed, loss=0.02,
                      aggregate_nacks=False, collect_delivery=True)
        b = run_bcast("reference", 16, 1 << 18, FABJ, WK, seed, loss=0.02,
                      aggregate_nacks=False, collect_delivery=True)
        assert_bcast_equal(a, b, ctx=f"noagg seed={seed}")


def test_broadcast_event_dpa_fidelity_matches():
    """dpa_fidelity="event": the vectorized engine must drive the stateful
    per-leaf DpaEventPools in the reference's sequential order."""
    for seed, loss in ((0, 0.02), (1, None)):
        a = run_bcast("vectorized", 16, 1 << 18, FABJ, WK, seed, loss=loss,
                      dpa_fidelity="event", collect_delivery=True)
        b = run_bcast("reference", 16, 1 << 18, FABJ, WK, seed, loss=loss,
                      dpa_fidelity="event", collect_delivery=True)
        assert_bcast_equal(a, b, ctx=f"event seed={seed} loss={loss}")


def test_broadcast_heavy_loss_multi_round_matches():
    """Many recovery rounds + staging overflow: the retransmit/NACK union
    and still-lost bookkeeping must agree round by round."""
    a = run_bcast("vectorized", 32, 1 << 18, FABJ, WK1, 5, loss=0.2,
                  collect_delivery=True)
    b = run_bcast("reference", 32, 1 << 18, FABJ, WK1, 5, loss=0.2,
                  collect_delivery=True)
    assert len(a.rounds) >= 2
    assert_bcast_equal(a, b, ctx="heavy loss")


def test_broadcast_delivery_replays_identically_through_reassembly():
    """The staging order both engines hand to kernels/chunk_reassembly.py is
    the same array, so the replayed scatter is the same buffer (checked
    jax-free here: the scatter is a pure permutation replay)."""
    mtu = 128
    fab = FabricParams(jitter=0.0, mtu=mtu)
    a = run_bcast("vectorized", 8, 64 * mtu, fab, WK, 11, loss=0.05,
                  collect_delivery=True)
    b = run_bcast("reference", 8, 64 * mtu, fab, WK, 11, loss=0.05,
                  collect_delivery=True)
    assert a.completed and a.recovered > 0
    src = np.arange(64 * mtu, dtype=np.uint8).reshape(64, mtu)
    for leaf, order in a.delivery_order.items():
        np.testing.assert_array_equal(order, b.delivery_order[leaf])
        assert sorted(order.tolist()) == list(range(64))   # exactly-once
        user = np.zeros_like(src)
        user[order] = src[order]                           # scatter replay
        np.testing.assert_array_equal(user, src)


# ------------------------------------------------- allgather differential grid


AG_GRID = [
    # (p, n_bytes, m, fab, wk, loss, routed, seed)
    (4, 1 << 16, 1, FAB, WK, None, False, 0),
    (8, 1 << 17, 2, FABJ, WK, None, False, 1),
    (16, 1 << 17, 2, FAB, WK, 0.01, False, 0),
    (16, 1 << 17, 4, FABJ, WK1, 0.01, False, 2),    # RNR + loss
    (16, 1 << 17, 2, FABJ, WK, GE, False, 0),
    (16, 1 << 16, 2, FABJ, WK, 0.005, True, 1),     # routed FatTree
    (16, 1 << 16, 4, FAB, WK1, None, False, 3),     # RNR at jitter 0
]


@pytest.mark.parametrize("p,n,m,fab,wk,loss,routed,seed", AG_GRID)
def test_allgather_vectorized_matches_reference(p, n, m, fab, wk, loss,
                                                routed, seed):
    topo = FatTree(k=8, n_hosts=p, b_host=fab.b_link) if routed else None
    res = {}
    for eng in ("vectorized", "reference"):
        res[eng] = simulate_packet_allgather(
            p, n, fab, wk, np.random.default_rng(seed), m, topology=topo,
            loss=loss, engine=eng)
    assert_ag_equal(res["vectorized"], res["reference"],
                    ctx=f"p={p} m={m} loss={loss} routed={routed}")


def test_allgather_event_dpa_fidelity_matches():
    for seed in (0, 1):
        res = {}
        for eng in ("vectorized", "reference"):
            res[eng] = simulate_packet_allgather(
                8, 1 << 16, FABJ, WK, np.random.default_rng(seed), 2,
                loss=0.02, dpa_fidelity="event", engine=eng)
        assert_ag_equal(res["vectorized"], res["reference"],
                        ctx=f"event seed={seed}")


# ----------------------------------------------------- property suites (shim)


@hyp_settings(max_examples=12, deadline=None)
@hyp_given(hyp_st.integers(4, 48), hyp_st.floats(0.0, 0.08),
           hyp_st.booleans(), hyp_st.booleans(),
           hyp_st.integers(0, 2**31 - 1))
def test_property_vectorized_equals_reference(p, rate, jitter, burst, seed):
    """The headline property: over random (p, loss rate, model family,
    jitter, seed) configurations the two engines are indistinguishable."""
    fab = FABJ if jitter else FAB
    loss = None
    if rate > 1e-4:
        loss = (GilbertElliottLoss.from_rate(rate, mean_burst=6.0)
                if burst else rate)
    a = run_bcast("vectorized", p, 1 << 17, fab, WK, seed, loss=loss,
                  collect_delivery=True)
    b = run_bcast("reference", p, 1 << 17, fab, WK, seed, loss=loss,
                  collect_delivery=True)
    assert_bcast_equal(a, b, ctx=f"p={p} rate={rate:g} burst={burst}")


@hyp_settings(max_examples=10, deadline=None)
@hyp_given(hyp_st.integers(4, 32), hyp_st.floats(0.005, 0.1),
           hyp_st.integers(0, 2**31 - 1))
def test_property_exactly_once_conservation(p, rate, seed):
    """Every leaf receives every chunk EXACTLY once across the fast path
    and all recovery rounds (no duplicate deliveries to the user buffer,
    no holes), and fast + recovered counts conserve chunks."""
    n = 1 << 17
    r = run_bcast("vectorized", p, n, FABJ, WK, seed, loss=rate,
                  collect_delivery=True)
    assert r.completed
    n_chunks = -(-n // FABJ.mtu)
    for leaf, order in r.delivery_order.items():
        assert sorted(order.tolist()) == list(range(n_chunks)), leaf
    assert r.delivered_fast + r.recovered == (p - 1) * n_chunks


@hyp_settings(max_examples=10, deadline=None)
@hyp_given(hyp_st.integers(4, 32), hyp_st.floats(0.002, 0.04),
           hyp_st.floats(2.0, 8.0), hyp_st.integers(0, 2**31 - 1))
def test_property_recovery_monotone_in_loss(p, rate, mult, seed):
    """Coupled monotonicity: Bernoulli drops are sampled as u < rate from
    the same forked stream, so with identical seeds the drop sets are
    NESTED in the rate — recovery can only do more work, never less, and
    the lossless run's reliability phase is exactly zero."""
    r0 = run_bcast("vectorized", p, 1 << 17, FAB, WK, seed, loss=None)
    r1 = run_bcast("vectorized", p, 1 << 17, FAB, WK, seed, loss=rate)
    r2 = run_bcast("vectorized", p, 1 << 17, FAB, WK, seed,
                   loss=min(rate * mult, 0.3))
    assert r0.phases.reliability == 0.0
    assert r1.recovered <= r2.recovered
    assert r1.phases.reliability <= r2.phases.reliability + 1e-15
    assert r0.time <= r1.time <= r2.time + 1e-15


@hyp_settings(max_examples=8, deadline=None)
@hyp_given(hyp_st.floats(0.01, 0.08), hyp_st.floats(2.0, 16.0),
           hyp_st.integers(0, 2**31 - 1))
def test_property_ge_chain_state_advances_identically(rate, burst, seed):
    """Gilbert-Elliott statefulness under the batch engine: after a run on
    an attach_loss-armed fabric, every armed link's chain rng state and
    good/bad phase must equal the reference's — the vectorized mask
    batching samples the same per-link draws in the same order."""
    template = GilbertElliottLoss.from_rate(rate, mean_burst=burst)
    p, n = 8, 1 << 17

    def run(engine):
        topo = FatTree(k=8, n_hosts=p, b_host=FAB.b_link)
        attach_loss(topo, template, np.random.default_rng(13))
        r = simulate_packet_broadcast(
            p, n, FAB, WK, np.random.default_rng(seed), topology=topo,
            engine=engine)
        return r, {name: link.loss for name, link in topo.links().items()}

    ra, ma = run("vectorized")
    rb, mb = run("reference")
    assert_bcast_equal(ra, rb, ctx="armed fabric")
    assert sorted(ma) == sorted(mb)
    advanced = 0
    for name in ma:
        sa, sb = ma[name]._rng.bit_generator.state, \
            mb[name]._rng.bit_generator.state
        assert sa == sb, name
        assert ma[name]._bad == mb[name]._bad, name
        advanced += ma[name]._rng.bit_generator.state != \
            GilbertElliottLoss.from_rate(rate, mean_burst=burst).fork(
                np.random.default_rng(0))._rng.bit_generator.state
    assert advanced, "no chain advanced"


# ------------------------------------------------ batched-primitive twins


@hyp_settings(max_examples=20, deadline=None)
@hyp_given(hyp_st.integers(1, 12), hyp_st.integers(0, 40),
           hyp_st.integers(1, 8), hyp_st.integers(1, 64),
           hyp_st.integers(0, 2**31 - 1))
def test_pool_rows_twin_matches_scalar(rows, maxn, n_workers, staging,
                                       seed):
    """worker_pool_completion_rows == per-row worker_pool_completion on the
    real prefix (ragged rows, +inf END padding, empty rows included)."""
    rng = np.random.default_rng(seed)
    counts = rng.integers(0, maxn + 1, size=rows)
    width = int(counts.max()) if rows else 0
    arr = np.full((rows, width), np.inf)
    for k, c in enumerate(counts):
        arr[k, :c] = np.sort(rng.uniform(0.0, 1e-3, size=c))
    service = float(rng.uniform(1e-7, 1e-5))
    done, mask = worker_pool_completion_rows(arr, n_workers, service,
                                             staging)
    for k, c in enumerate(counts):
        d1, rnr1 = worker_pool_completion(arr[k, :c], n_workers, service,
                                          staging)
        np.testing.assert_array_equal(done[k, :c], d1, err_msg=str(k))
        assert int(mask[k, :c].sum()) == rnr1, k
        assert not mask[k, c:].any(), k
        assert np.all(np.isinf(done[k, c:])), k


@hyp_settings(max_examples=20, deadline=None)
@hyp_given(hyp_st.integers(1, 8), hyp_st.integers(1, 12),
           hyp_st.integers(0, 2**31 - 1))
def test_bitmap_rows_twins_match_scalar(rows, words, seed):
    """bitmap_pack_rows_np / bitmap_popcount_rows_np == the 1-D twins row
    by row, on the exact u32 wire words the NACK aggregation ORs."""
    rng = np.random.default_rng(seed)
    flags = rng.integers(0, 2, size=(rows, words * 32)).astype(bool)
    packed = bitmap_pack_rows_np(flags)
    pops = bitmap_popcount_rows_np(packed)
    for k in range(rows):
        np.testing.assert_array_equal(
            packed[k], bitmap_pack_np(flags[k].astype(np.uint32)))
        assert pops[k] == bitmap_popcount_np(packed[k])
        assert pops[k] == int(flags[k].sum())


# ------------------------------------------------------------ scale anchors


def test_vectorized_512_hosts_fast_and_exact():
    """Mid-scale anchor that runs in the fast tier: 512 hosts, both
    engines, full equality (the 10k case is slow-marked below)."""
    a = run_bcast("vectorized", 512, 1 << 22, FAB, WK, 0, loss=0.001)
    b = run_bcast("reference", 512, 1 << 22, FAB, WK, 0, loss=0.001)
    assert a.completed
    assert_bcast_equal(a, b, ctx="512-host anchor")


@pytest.mark.slow
def test_vectorized_10k_hosts_1gib_single_digit_seconds():
    """The tentpole scale target: 10k hosts at 1 GiB completes in
    single-digit seconds on the vectorized engine (the reference loop
    takes minutes — benchmarks/paper_figs.py packet_scale_sweep records
    the measured speedup, gated at >= 20x in BENCH_smoke.json)."""
    import time

    t0 = time.perf_counter()
    r = run_bcast("vectorized", 10_000, 1 << 30, FAB, WK, 0)
    wall = time.perf_counter() - t0
    assert r.completed and r.rnr_drops == 0
    assert wall < 10.0, wall
