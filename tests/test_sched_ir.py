"""Collective Schedule IR: structural properties of the op DAG, the
builder->executor byte conservation per fidelity, the cross-fidelity
metamorphic ordering for the NEW collectives (reduce-scatter, allreduce),
and the acceptance pins that the facade entry points reproduce the
pre-refactor engine results exactly at loss 0."""
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:  # offline: seeded-random shim (tests/_hypothesis_shim.py)
    from _hypothesis_shim import given, settings, strategies as st

from repro.core import protocol, sched_ir
from repro.core import schedule as seq
from repro.core.engine import simulate_fsdp_step
from repro.core.sched_ir import (
    FabricParams,
    WorkerParams,
    build_allgather,
    build_allreduce,
    build_broadcast_tree,
    build_fsdp_step,
    build_ring_allgather,
    build_ring_reduce_scatter,
    execute,
    payload_bytes,
)
from repro.core.simulator import (
    _chunking,
    simulate_allgather,
    simulate_broadcast,
)
from repro.core.topology import FatTree

FAB = FabricParams(jitter=0.0)
WK = WorkerParams(n_recv_workers=8)


def pm_pairs():
    """(P, M) pairs INCLUDING uneven chains (M does not divide P)."""
    return st.integers(2, 48).flatmap(
        lambda p: st.integers(1, p).map(lambda m: (p, m))
    )


# ------------------------------------------------------------- IR structure


@given(pm_pairs())
@settings(max_examples=60, deadline=None)
def test_allgather_every_rank_roots_exactly_once(pm):
    p, m = pm
    sched = build_allgather(p, 1 << 14, m)
    sched_ir.validate(sched)                 # roots-once + rounds==Appendix A
    assert sorted(op.root for op in sched.ops) == list(range(p))
    gens = sched.rounds()
    assert len(gens) == seq.n_rounds(p, m)
    assert len(gens[0]) == m                 # every chain starts in round 0


@given(pm_pairs())
@settings(max_examples=40, deadline=None)
def test_activation_dag_matches_chain_signal(pm):
    """The Activation edges ARE the §IV-A chain signal: one edge per
    non-head chain member, from its predecessor's op."""
    p, m = pm
    sched = build_allgather(p, 1 << 14, m)
    assert len(sched.activation) == p - m
    for a, b in sched.activation:
        fa, fb = sched.ops[a].root, sched.ops[b].root
        assert seq.chain_of(fa, p, m) == seq.chain_of(fb, p, m)
        assert fb == fa + 1


def test_rounds_rejects_cycles():
    sched = sched_ir.Schedule(
        "allgather", 2, 64,
        (sched_ir.Multicast(0, (0, 1), 64), sched_ir.Multicast(1, (0, 1), 64)),
        activation=((0, 1), (1, 0)))
    with pytest.raises(AssertionError):
        sched.rounds()


@pytest.mark.parametrize("build", [
    lambda p: build_broadcast_tree(p, 1 << 14),
    lambda p: build_allgather(p, 1 << 14, 4),
    lambda p: build_ring_allgather(p, 1 << 14),
    lambda p: build_ring_reduce_scatter(p, 1 << 16),
    lambda p: build_allreduce(p, 1 << 16, m=p),
    lambda p: build_allreduce(p, 1 << 16),
    lambda p: build_fsdp_step(p=p, n_layers=3, layer_bytes=1e6,
                              policy="split"),
])
def test_builders_validate(build):
    sched = build(8)
    sched_ir.validate(sched)


def test_fsdp_builder_op_shapes():
    p, n_layers = 8, 3
    for policy, ag_t, rs_t in [("naive", sched_ir.Unicast, sched_ir.Reduce),
                               ("mcast", sched_ir.Multicast, sched_ir.Reduce),
                               ("split", sched_ir.Multicast, sched_ir.Reduce)]:
        sched = build_fsdp_step(p=p, n_layers=n_layers, layer_bytes=8e6,
                                policy=policy)
        # forward AG per layer + backward AG + RS per layer, p ops each
        assert len(sched.ops) == 3 * p * n_layers
        ags = [op for op in sched.ops if isinstance(op, ag_t)]
        rss = [op for op in sched.ops if isinstance(op, sched_ir.Reduce)]
        assert len(ags) >= 2 * p * n_layers and len(rss) == p * n_layers
        if policy == "split":     # in-network aggregation: every src reduced
            assert all(len(op.srcs) == p - 1 for op in rss)
        else:                     # ring step: single-source Reduce edges
            assert all(len(op.srcs) == 1 for op in rss)


# -------------------------------------------------------- byte conservation


@pytest.mark.parametrize("p,m", [(8, 2), (16, 4), (6, 4)])
def test_allgather_packet_bytes_conserve_builder_to_executor(p, m):
    """Builder-side payload (chunk-rounded) == packet executor bytes_total,
    and fast + recovery == total on completion."""
    n = 1 << 18
    sched = build_allgather(p, n, m)
    r = execute(sched, FAB, WK, np.random.default_rng(0), fidelity="packet")
    n_chunks, chunk = _chunking(n, FAB.mtu)
    expect = sum((len(op.group) - 1) * n_chunks * chunk for op in sched.ops)
    assert r.bytes_total == expect == p * (p - 1) * n_chunks * chunk
    assert r.bytes_fast + r.bytes_recovery == r.bytes_total


def test_ring_routed_bytes_conserve_builder_to_executor():
    """Routed ring lowering: every host's fabric uplink carries exactly its
    schedule ops' payload — total injected == payload_bytes(schedule)."""
    p, n = 16, 1 << 18
    topo = FatTree(k=8, n_hosts=p, b_host=FAB.b_link)
    sched = build_ring_reduce_scatter(p, n)
    r = execute(sched, FAB, WK, np.random.default_rng(0), topology=topo)
    uplinks = {k: v for k, v in r.link_bytes.items()
               if k.startswith("h") and v}
    assert sum(uplinks.values()) == pytest.approx(payload_bytes(sched),
                                                  rel=1e-9)
    assert r.bytes_total == pytest.approx(payload_bytes(sched))


def test_allreduce_bytes_compose_rs_and_ag():
    p, n = 8, 1 << 20
    r = execute(build_allreduce(p, n, m=p), FAB, WK,
                np.random.default_rng(0))
    assert r.bytes_total == r.rs.bytes_total + r.ag.bytes_total
    assert r.time == r.rs_time + r.ag_time


# ------------------------------------- cross-fidelity metamorphic ordering
# (mirrors test_packet.py's grid, for the NEW reduce-scatter / allreduce)


@pytest.mark.parametrize("p", [4, 16])
@pytest.mark.parametrize("loss", [0.0, 1e-3, 1e-2])
@pytest.mark.parametrize("n_bytes", [1 << 17, 1 << 20])
def test_reduce_scatter_fidelity_ordering(p, loss, n_bytes):
    """analytic <= fluid <= packet for the ring reduce-scatter, with the
    packet loss-0 leg reproducing the fluid lowering exactly."""
    sched = build_ring_reduce_scatter(p, n_bytes)
    ana = execute(sched, FAB, WK, fidelity="analytic")
    assert ana == protocol.analytic_ring_reduce_scatter_time(
        p, n_bytes, FAB.b_link, FAB.latency)
    fluid = execute(sched, FAB, WK, np.random.default_rng(0))
    pkt0 = execute(sched, FAB, WK, np.random.default_rng(0),
                   fidelity="packet")
    pkt = execute(sched, FAB, WK, np.random.default_rng(0),
                  fidelity="packet", loss=loss)
    assert ana <= fluid.time * (1.0 + 1e-12)
    assert fluid.time == pkt0.time                   # loss-0 leg is EXACT
    assert fluid.time <= pkt.time * (1.0 + 1e-12)
    if loss > 0.0:
        assert pkt.time > fluid.time                 # loss only adds time
        assert pkt.bytes_recovery > 0


@pytest.mark.parametrize("p", [4, 16])
@pytest.mark.parametrize("loss", [0.0, 1e-3, 1e-2])
@pytest.mark.parametrize("n_bytes", [1 << 17, 1 << 20])
@pytest.mark.parametrize("m", [None, "full"])
def test_allreduce_fidelity_ordering(p, loss, n_bytes, m):
    """analytic <= fluid <= packet for Allreduce = RS∘AG, both the ring-AG
    and the paper's multicast-AG composition; the multicast AG leg runs the
    real NACK/retransmission protocol engine under loss."""
    m = p if m == "full" else None
    sched = build_allreduce(p, n_bytes, m=m)
    ana = execute(sched, FAB, WK, fidelity="analytic")
    fluid = execute(sched, FAB, WK, np.random.default_rng(0))
    pkt0 = execute(sched, FAB, WK, np.random.default_rng(0),
                   fidelity="packet")
    pkt = execute(sched, FAB, WK, np.random.default_rng(0),
                  fidelity="packet", loss=loss)
    assert ana <= fluid.time * (1.0 + 1e-12)
    assert fluid.time == pytest.approx(pkt0.time, rel=1e-12)
    assert fluid.time <= pkt.time * (1.0 + 1e-12)
    shard_chunks = max(n_bytes // p // 4096, 1)
    if m is not None and loss * p * (p - 1) * shard_chunks > 20:
        assert pkt.ag.recovered > 0     # the AG leg exercised real recovery


# --------------------------------------------------------- uneven chains


@pytest.mark.parametrize("p,m", [(6, 4), (10, 3), (7, 2)])
def test_uneven_chains_all_fidelities(p, m):
    """M need not divide P: the last chains are shorter, the engines agree
    to float tolerance (per-leaf vs merged pool summation order), and the
    packet run completes + conserves."""
    n = 1 << 16
    fl = simulate_allgather(p, n, FAB, WK, np.random.default_rng(0),
                            n_chains=m)
    pk = simulate_allgather(p, n, FAB, WK, np.random.default_rng(0),
                            n_chains=m, fidelity="packet")
    assert fl.time == pytest.approx(pk.time, rel=1e-9)
    assert pk.completed
    assert pk.bytes_fast + pk.bytes_recovery == pk.bytes_total
    # more chains => fewer activation generations => no slower (same bytes)
    full = simulate_allgather(p, n, FAB, WK, np.random.default_rng(0),
                              n_chains=p)
    assert full.time <= fl.time * (1.0 + 1e-12)


def test_uneven_chains_round_structure():
    sched = build_allgather(6, 1 << 14, 4)       # chains (2, 2, 1, 1)
    gens = sched.rounds()
    assert [len(g) for g in gens] == [4, 2]
    assert [sched.ops[i].root for i in gens[0]] == [0, 2, 4, 5]
    assert [sched.ops[i].root for i in gens[1]] == [1, 3]


# ------------------------------------------------- facade acceptance pins
# Pre-refactor engine outputs, captured at the seed commit (PR 4). The IR
# facades must reproduce them EXACTLY — same arithmetic, same rng draws.


def test_facades_reproduce_prerefactor_times_exactly():
    wk = WorkerParams(n_recv_workers=8)
    fab0 = FabricParams(jitter=0.0)
    fabj = FabricParams()                        # default jitter: rng order
    cases = [
        (simulate_broadcast(8, 1 << 20, fab0, wk, np.random.default_rng(0)),
         5.717663562800481e-05),
        (simulate_broadcast(8, 1 << 20, fab0, wk, np.random.default_rng(0),
                            fidelity="packet"), 5.717663562800481e-05),
        (simulate_allgather(16, 1 << 18, fab0, wk, np.random.default_rng(0),
                            n_chains=4), 0.0002027065425120192),
        (simulate_allgather(16, 1 << 18, fab0, wk, np.random.default_rng(0),
                            n_chains=4, fidelity="packet"),
         0.0002027065425120192),
        (simulate_broadcast(8, 1 << 20, fabj, wk, np.random.default_rng(5)),
         5.815140294963682e-05),
        (simulate_broadcast(8, 1 << 20, FabricParams(p_drop=0.01), wk,
                            np.random.default_rng(5)),
         0.00012942607999999998),
        (simulate_allgather(16, 1 << 18, fabj, wk, np.random.default_rng(5),
                            n_chains=4), 0.00020617006355919465),
        (simulate_allgather(16, 1 << 18, fabj, wk, np.random.default_rng(5),
                            n_chains=4, fidelity="packet", loss=0.02),
         0.0006192941191779647),
        (simulate_broadcast(16, 1 << 20, fabj, wk, np.random.default_rng(5),
                            fidelity="packet", loss=0.02),
         0.00017734423415138832),
    ]
    for i, (res, expect) in enumerate(cases):
        assert float(res.time) == expect, (i, res.time, expect)


def test_facades_reproduce_prerefactor_routed_times_exactly():
    wk = WorkerParams(n_recv_workers=8)
    fab = FabricParams(jitter=0.0)
    topo = FatTree(k=8, n_hosts=16, b_host=fab.b_link)
    t = simulate_allgather(16, 1 << 18, fab, wk, np.random.default_rng(0),
                           n_chains=16, topology=topo).time
    assert float(t) == 0.00017875359125600957
    topo = FatTree(k=8, n_hosts=16, b_host=fab.b_link)
    t = simulate_allgather(16, 1 << 18, fab, wk, np.random.default_rng(7),
                           n_chains=8, topology=topo, fidelity="packet",
                           loss=0.01).time
    assert float(t) == 0.00046639386498387257


def test_fsdp_facade_reproduces_prerefactor_exactly():
    expect = {
        "naive": (0.06037144, 0.7394688614351421),
        "mcast": (0.03172288, 0.5041862529505519),
        "split": (0.026276479999999998, 0.40141754146674136),
    }
    for policy, (t, bub) in expect.items():
        r = simulate_fsdp_step(n_layers=4, layer_bytes=64e6, p=16,
                               policy=policy)
        assert (r.step_time, r.bubble_fraction) == (t, bub), policy
    routed = {
        "naive": 0.031792879999999996,
        "mcast": 0.030412159999999997,
        "split": 0.026276479999999994,
    }
    topo = FatTree(k=8, n_hosts=16, b_host=FabricParams().b_link)
    for policy, t in routed.items():
        topo.reset()
        r = simulate_fsdp_step(n_layers=4, layer_bytes=64e6, p=16,
                               policy=policy, topology=topo)
        assert r.step_time == t, policy
    r = simulate_fsdp_step(n_layers=4, layer_bytes=64e6, p=16,
                           policy="mcast", fidelity="packet", loss=0.005,
                           rng=np.random.default_rng(2))
    assert r.step_time == 0.03233466152988904


# ------------------------------------------------------------- autotune


def test_autotune_chains_prefers_full_parallelism_on_flat_fabric():
    best, times = sched_ir.autotune_chains(
        build_allgather, p=16, n_bytes=1 << 18, fabric=FAB, workers=WK)
    assert best == 16                            # flat: more chains, less sync
    assert set(times) == {1, 2, 4, 8, 16}        # divisors of P
    assert times[16] <= min(times.values()) + 1e-18


def test_autotune_chains_routed_and_analytic():
    topo = FatTree(k=8, n_hosts=16, b_host=FAB.b_link)
    best, times = sched_ir.autotune_chains(
        build_allgather, topo, p=16, n_bytes=1 << 18, fabric=FAB,
        workers=WK, candidates=(2, 4, 16))
    assert best in (2, 4, 16) and len(times) == 3
    best_a, _ = sched_ir.autotune_chains(
        build_allgather, p=16, n_bytes=1 << 18, fabric=FAB, workers=WK,
        fidelity="analytic")
    assert best_a == 16                          # fewer activation rounds


# --------------------------------------------------------- executor guards


def test_execute_fsdp_schedule_matches_entry_point():
    """execute(build_fsdp_step(...)) hands the built graph to the timeline
    executor — identical result to calling simulate_fsdp_step directly."""
    sched = build_fsdp_step(p=16, n_layers=4, layer_bytes=64e6,
                            policy="split")
    r = sched_ir.execute(sched, FabricParams(), WorkerParams())
    d = simulate_fsdp_step(n_layers=4, layer_bytes=64e6, p=16,
                           policy="split")
    assert (r.step_time, r.bubble_fraction) == (d.step_time,
                                                d.bubble_fraction)


def test_analytic_respects_caller_worker_params():
    """The analytic oracle must stay a lower bound for the CALLER's worker
    pool too (rnr_barrier_hop forwarded, not the default)."""
    wk = WorkerParams(n_recv_workers=8, rnr_barrier_hop=0.0)
    for sched in (build_broadcast_tree(16, 1 << 12),
                  build_allgather(16, 1 << 12, 4),
                  build_allreduce(16, 1 << 16, m=16)):
        ana = execute(sched, FAB, wk, fidelity="analytic")
        fl = execute(sched, FAB, wk, np.random.default_rng(0))
        assert ana <= fl.time * (1.0 + 1e-12), sched.kind


def test_analytic_rejects_topology():
    topo = FatTree(k=8, n_hosts=4, b_host=FAB.b_link)
    with pytest.raises(AssertionError):
        execute(build_broadcast_tree(4, 1 << 14), FAB, WK,
                fidelity="analytic", topology=topo)


def test_execute_rejects_bad_inputs():
    sched = build_broadcast_tree(4, 1 << 14)
    with pytest.raises(AssertionError):
        execute(sched, FAB, WK, fidelity="quantum")
    with pytest.raises(AssertionError):
        execute(sched, FAB, WK, np.random.default_rng(0), loss=0.1)  # fluid
    with pytest.raises(AssertionError):
        execute(sched, FAB, WK, fidelity="analytic", loss=0.1)
    with pytest.raises(AssertionError):
        execute(build_ring_reduce_scatter(4, 1 << 14), FAB, WK,
                np.random.default_rng(0), fidelity="packet",
                dpa_fidelity="event")            # RC rings have no DPA path
