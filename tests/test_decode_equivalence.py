"""Autoregressive decode == parallel forward, per family (the strongest
end-to-end correctness property of the serving path)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_model_config, reduced
from repro.models import build_model
from repro.models.model_builder import _head_matrix

# jax model/integration tier: excluded from the fast CI
# lane (scripts/check.sh), run by the `slow` CI job
pytestmark = pytest.mark.slow

FAMS = ["smollm-135m", "rwkv6-7b", "recurrentgemma-9b", "deepseek-moe-16b"]


@pytest.mark.parametrize("arch", FAMS)
def test_decode_matches_forward(arch):
    cfg = reduced(get_model_config(arch))
    if cfg.moe is not None:  # avoid capacity drops in the parallel pass
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0)
        )
    api = build_model(cfg)
    rng = jax.random.PRNGKey(1)
    params = api.init_params(rng)
    b, s = 2, 24
    tokens = jax.random.randint(rng, (b, s), 0, cfg.vocab_size, dtype=jnp.int32)
    hid = api.forward_fn(params, {"tokens": tokens})
    full = jnp.einsum("bsd,dv->bsv", hid, _head_matrix(params, cfg).astype(hid.dtype))
    cache = api.init_cache(b, s)
    dec = jax.jit(api.decode_fn)
    err = 0.0
    for t in range(s):
        lg, cache = dec(params, cache, tokens[:, t], jnp.full((b,), t, jnp.int32))
        err = max(err, float(jnp.max(jnp.abs(lg - full[:, t]))))
    assert err < 5e-4, f"{arch}: decode/forward divergence {err}"


def test_prefill_then_decode_continues(multidev=None):
    """prefill(s tokens) then decode token s == forward(s+1)."""
    cfg = reduced(get_model_config("smollm-135m"))
    api = build_model(cfg)
    rng = jax.random.PRNGKey(2)
    params = api.init_params(rng)
    b, s = 2, 16
    tokens = jax.random.randint(rng, (b, s + 1), 0, cfg.vocab_size, dtype=jnp.int32)
    hid = api.forward_fn(params, {"tokens": tokens})
    full = jnp.einsum("bsd,dv->bsv", hid, _head_matrix(params, cfg).astype(hid.dtype))
    logits_pre, cache = api.prefill_fn(params, {"tokens": tokens[:, :s]})
    assert float(jnp.max(jnp.abs(logits_pre - full[:, s - 1]))) < 5e-4
    # grow the cache to s+1 and decode position s
    grown = jax.tree.map(
        lambda c: jnp.pad(c, [(0, 0)] * 3 + [(0, 1), (0, 0)]), cache
    )
    lg, _ = api.decode_fn(params, grown, tokens[:, s], jnp.full((b,), s, jnp.int32))
    assert float(jnp.max(jnp.abs(lg - full[:, s]))) < 5e-4
