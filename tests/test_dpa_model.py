"""DPA offload model: reproduces the paper's measured anchors (Table I,
Figs 5/13/14/15/16, §VII)."""
import pytest

from repro.core import dpa


def test_table1_single_thread():
    assert dpa.single_thread_tput("UD") == pytest.approx(5.2 * 2**30)
    assert dpa.single_thread_tput("UC") == pytest.approx(11.9 * 2**30)
    # IPC consistency: instr/cycle ~ 0.1 (low-IPC data movement)
    for t in ("UD", "UC"):
        row = dpa.TABLE1[t]
        assert row["instr_per_cqe"] / row["cycles_per_cqe"] == pytest.approx(
            row["ipc"], rel=0.1
        )


def test_fig13_14_saturation_thread_counts():
    assert dpa.threads_to_saturate("UC") <= 4           # paper: ~4
    assert 8 <= dpa.threads_to_saturate("UD") <= 16     # paper: 8-16


def test_one_core_reaches_link_rate():
    """§VI-d: 16 threads (1 core) reach practical link throughput for both."""
    for t in ("UD", "UC"):
        tput = dpa.sustained_tput(dpa.DpaConfig(t, 16))
        assert tput >= 0.99 * dpa.LINK_200G_BYTES


def test_dpa_core_beats_cpu_core():
    """Fig 5/§VII-d: one DPA core outperforms a single CPU core by ~25%."""
    dpa_core = dpa.sustained_tput(dpa.DpaConfig("UD", 16))
    cpu = dpa.CPU_CORE_TPUT_GIB["RC_no_reliability"] * 2**30
    assert dpa_core / cpu > 1.2
    assert cpu < dpa.LINK_200G_BYTES  # CPU core can't sustain the link


def test_fig15_larger_chunks_saturate_with_fewer_threads():
    t_small = next(
        t for t in range(1, 257)
        if dpa.sustained_tput(dpa.DpaConfig("UC", t, 4096)) >= 0.99 * dpa.LINK_200G_BYTES
    )
    t_big = next(
        t for t in range(1, 257)
        if dpa.sustained_tput(dpa.DpaConfig("UC", t, 32768)) >= 0.99 * dpa.LINK_200G_BYTES
    )
    assert t_big <= t_small


def test_fig16_tbit_feasible_with_half_dpa():
    assert dpa.tbit_feasible("UD", 128)
    assert dpa.tbit_feasible("UC", 128)
    # but a handful of threads is NOT enough
    assert not dpa.tbit_feasible("UD", 8)


def test_economics():
    eco = dpa.economics_summary()
    assert eco["cpu_cores_needed_4x1600g"] >= 64  # §VII-d: "at least 64 cores"


def test_nack_rate_matches_cqe_bound_pool():
    """NACK processing is CQE-bound like the data path: the pool's NACK
    message rate equals its chunk rate (same Table-I per-CQE cost), scales
    with threads, and respects the per-core NIC-interface cap."""
    one = dpa.nack_rate(dpa.DpaConfig("UD", 1))
    assert one == pytest.approx(dpa.single_thread_tput("UD") / 4096.0)
    sixteen = dpa.nack_rate(dpa.DpaConfig("UD", 16))
    assert one < sixteen <= 16 * one          # sublinear within a core
    assert sixteen <= dpa.CORE_CAP_CHUNKS_PER_S
    # consistent with the data-path chunk rate: one CQE is one CQE
    assert sixteen == pytest.approx(
        dpa.pool_tput(dpa.DpaConfig("UD", 16)) / 4096.0)
