"""FSDP AG/RS injection-contention model: policy ordering, bubble accounting,
the routed topology mode, multi-job fabric contention, and the vectorized
worker-pool regression against the reference loop."""
import numpy as np
import pytest

from repro.core.engine import (
    FSDP_POLICIES,
    simulate_fsdp_step,
    simulate_multi_job,
    sweep_fsdp_contention,
    worker_pool_completion,
    worker_pool_completion_loop,
)
from repro.core.topology import FatTree


def test_direction_split_beats_naive_default_config():
    """Acceptance: strictly lower bubble_fraction for the Insight-2 direction
    split than the naive shared link on the default 200 Gbit/s fabric."""
    naive = simulate_fsdp_step(policy="naive")
    split = simulate_fsdp_step(policy="split")
    assert split.bubble_fraction < naive.bubble_fraction
    assert split.step_time < naive.step_time


@pytest.mark.parametrize("p", [4, 16, 64])
@pytest.mark.parametrize("layer_bytes", [16e6, 256e6])
@pytest.mark.parametrize("n_layers", [2, 12])
def test_split_never_worse_than_naive_grid(p, layer_bytes, n_layers):
    res = {
        pol: simulate_fsdp_step(n_layers=n_layers, layer_bytes=layer_bytes,
                                p=p, policy=pol)
        for pol in FSDP_POLICIES
    }
    assert res["split"].bubble_fraction <= res["naive"].bubble_fraction + 1e-12
    # the paper's multicast schedule also never loses to the naive baseline
    assert res["mcast"].bubble_fraction <= res["naive"].bubble_fraction + 1e-12


def test_bubble_accounting_consistent():
    r = simulate_fsdp_step(n_layers=6, layer_bytes=64e6, p=8, policy="mcast")
    assert 0.0 <= r.bubble_fraction < 1.0
    assert r.step_time >= r.compute_time
    assert r.bubble_fraction == pytest.approx(1 - r.compute_time / r.step_time)
    phases = r.phase_times
    assert phases["forward"] + phases["backward"] + phases["rs_drain"] == (
        pytest.approx(r.step_time)
    )
    for util in r.link_utilization.values():
        assert 0.0 <= util <= 1.0 + 1e-9


def test_compute_bound_regime_has_small_bubbles():
    """With enormous compute per byte, every policy hides nearly all comms."""
    for pol in FSDP_POLICIES:
        r = simulate_fsdp_step(n_layers=8, layer_bytes=8e6, p=8, policy=pol,
                               hw_flops=1e12)  # slow chip -> long compute
        assert r.bubble_fraction < 0.1, (pol, r.bubble_fraction)


def test_comm_bound_regime_orders_policies():
    """Fast chip -> comms exposed: naive > mcast > split bubble fractions."""
    res = {
        pol: simulate_fsdp_step(n_layers=8, layer_bytes=256e6, p=16,
                                policy=pol, hw_flops=2e15)
        for pol in FSDP_POLICIES
    }
    assert res["naive"].bubble_fraction > res["mcast"].bubble_fraction
    assert res["mcast"].bubble_fraction > res["split"].bubble_fraction


def test_sweep_rows_and_internal_assertion():
    rows = sweep_fsdp_contention(ps=(4, 8), layer_bytes=(32e6,), n_layers=4)
    assert len(rows) == 2 * 1 * len(FSDP_POLICIES)
    for row in rows:
        assert set(row) >= {"p", "layer_bytes", "policy", "step_time",
                            "bubble_fraction"}


def test_model_config_parameterization():
    """layer bytes derived from a registered model config (configs/)."""
    from repro.configs import get_model_config

    cfg = get_model_config("smollm-135m")
    r = simulate_fsdp_step(cfg, p=8, policy="split")
    assert r.n_layers == cfg.num_layers
    assert r.step_time > 0


# --------------------------------------------------- routed topology mode


def test_topology_mode_policies_ordered_comm_bound():
    """On a real fat-tree the policies differ by routed traffic: P2P rings
    colliding everywhere (naive) >= multicast AG + ring RS (mcast) >=
    multicast down + aggregation trees up (split)."""
    topo = FatTree(k=8, n_hosts=16)
    res = {
        pol: simulate_fsdp_step(n_layers=4, layer_bytes=256e6, p=16,
                                policy=pol, hw_flops=2e15, topology=topo)
        for pol in FSDP_POLICIES
    }
    assert res["split"].step_time <= res["mcast"].step_time + 1e-12
    assert res["mcast"].step_time <= res["naive"].step_time + 1e-12
    assert res["split"].bubble_fraction < res["naive"].bubble_fraction
    for r in res.values():
        assert r.step_time >= r.compute_time
        for util in r.link_utilization.values():
            assert 0.0 <= util <= 1.0 + 1e-9


def test_topology_mode_custom_host_placement():
    """Ranks may be placed on arbitrary fabric hosts; a spread placement
    pushes ring traffic through agg/core links and cannot be faster than the
    packed one under naive P2P."""
    topo = FatTree(k=8, n_hosts=64)
    packed = simulate_fsdp_step(n_layers=2, layer_bytes=128e6, p=8,
                                policy="naive", hw_flops=2e15,
                                topology=topo, hosts=list(range(8)))
    spread = simulate_fsdp_step(n_layers=2, layer_bytes=128e6, p=8,
                                policy="naive", hw_flops=2e15,
                                topology=topo, hosts=list(range(0, 64, 8)))
    assert spread.step_time >= packed.step_time - 1e-12


def test_multi_job_isolated_at_full_bisection():
    topo = FatTree(k=8, n_hosts=32)
    jobs = {"A": list(range(0, 32, 2)), "B": list(range(1, 32, 2))}
    r = simulate_multi_job(topo, jobs, layer_bytes=64e6, n_layers=2,
                           policy="mcast")
    for name in jobs:
        assert r.slowdown[name] == pytest.approx(1.0, abs=1e-2)
    assert r.core_bytes > 0          # the jobs do traverse the core


def test_multi_job_contends_when_oversubscribed():
    jobs = {"A": list(range(0, 32, 2)), "B": list(range(1, 32, 2))}
    thin = FatTree(k=8, n_hosts=32, oversubscription=4.0)
    r = simulate_multi_job(thin, jobs, layer_bytes=64e6, n_layers=2,
                           policy="mcast")
    for name in jobs:
        assert r.contended_time[name] >= r.solo_time[name] - 1e-12
        assert r.slowdown[name] > 1.3
    assert max(r.link_utilization.values()) <= 1.0 + 1e-9


def test_multi_job_rejects_overlapping_hosts():
    topo = FatTree(k=8, n_hosts=32)
    with pytest.raises(AssertionError, match="disjoint"):
        simulate_multi_job(topo, {"A": [0, 1, 2, 3], "B": [3, 4, 5, 6]},
                           n_layers=1)


# ------------------------------------------ vectorized worker pool regression


@pytest.mark.parametrize("seed", range(8))
def test_worker_pool_vectorized_matches_loop(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 2000))
    arrivals = np.sort(rng.uniform(0, 1e-3, size=n))
    n_workers = int(rng.integers(1, 17))
    service = float(rng.uniform(1e-8, 1e-5))
    staging = int(rng.integers(1, 256))
    d_vec, rnr_vec = worker_pool_completion(arrivals, n_workers, service, staging)
    d_loop, rnr_loop = worker_pool_completion_loop(arrivals, n_workers, service, staging)
    np.testing.assert_allclose(d_vec, d_loop, rtol=1e-12, atol=1e-15)
    assert rnr_vec == rnr_loop


def test_worker_pool_edge_cases():
    empty = np.empty(0)
    d, rnr = worker_pool_completion(empty, 4, 1e-6, 8)
    assert d.size == 0 and rnr == 0
    one = np.array([1.0])
    d, rnr = worker_pool_completion(one, 4, 1e-6, 8)
    np.testing.assert_allclose(d, [1.0 + 1e-6])
    assert rnr == 0
    # more workers than chunks
    few = np.array([0.0, 1e-7, 2e-7])
    d_vec, r_vec = worker_pool_completion(few, 16, 1e-6, 2)
    d_loop, r_loop = worker_pool_completion_loop(few, 16, 1e-6, 2)
    np.testing.assert_allclose(d_vec, d_loop)
    assert r_vec == r_loop


def test_worker_pool_vectorized_is_fast():
    """The vectorized path must beat the reference loop by a wide margin on
    large-message sweeps; best-of-3 timings keep the relative bound robust
    against scheduler noise on loaded CI runners."""
    import time

    arrivals = np.sort(np.random.default_rng(0).uniform(0, 1.0, size=200_000))

    def best_of_3(fn):
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
        return best

    done, _ = worker_pool_completion(arrivals, 8, 1e-6, 8192)
    dt_vec = best_of_3(lambda: worker_pool_completion(arrivals, 8, 1e-6, 8192))
    dt_loop = best_of_3(
        lambda: worker_pool_completion_loop(arrivals, 8, 1e-6, 8192))
    assert done.shape == arrivals.shape
    assert dt_vec < dt_loop / 10, (dt_vec, dt_loop)


def test_packet_fidelity_loss_inflates_step():
    """fidelity="packet": per-layer AG readiness pays the sampled
    NACK/retransmission overlay; at loss 0 the overlay is free and the
    fluid step time is reproduced exactly."""
    for policy in FSDP_POLICIES:
        fluid = simulate_fsdp_step(n_layers=4, layer_bytes=64e6, p=16,
                                   policy=policy)
        zero = simulate_fsdp_step(n_layers=4, layer_bytes=64e6, p=16,
                                  policy=policy, fidelity="packet", loss=0.0)
        lossy = simulate_fsdp_step(n_layers=4, layer_bytes=64e6, p=16,
                                   policy=policy, fidelity="packet",
                                   loss=1e-3,
                                   rng=np.random.default_rng(0))
        assert zero.step_time == pytest.approx(fluid.step_time, rel=1e-12)
        assert lossy.step_time > fluid.step_time, policy
        assert lossy.bubble_fraction >= fluid.bubble_fraction - 1e-12


def test_progress_engine_host_vs_dpa():
    """§VII-d offload economics in the bubble accounting: running the
    reliability datapath on host cores (no hardware multithreading — Fig 5)
    both caps each layer's AG readiness at the software engine's measured
    throughput AND steals compute cores, so the DPA offload strictly wins;
    fewer host cores lose harder. The default is the DPA path, unchanged."""
    kw = dict(n_layers=4, layer_bytes=64e6, p=16, policy="split")
    d = simulate_fsdp_step(**kw)
    d_explicit = simulate_fsdp_step(**kw, progress_engine="dpa")
    assert d_explicit.step_time == d.step_time
    assert d.progress_engine == "dpa" and d.datapath_tput is None
    h2 = simulate_fsdp_step(**kw, progress_engine="host", host_cores=2)
    h1 = simulate_fsdp_step(**kw, progress_engine="host", host_cores=1)
    assert h2.progress_engine == "host" and h2.datapath_tput is not None
    assert h2.datapath_tput < 200e9 / 8         # two cores can't hold 200G
    assert h2.step_time > d.step_time
    assert h2.bubble_fraction > d.bubble_fraction
    assert h1.step_time > h2.step_time          # fewer cores, slower datapath
    # freed-host-cycles: compute accounting is at full-node capability, so
    # the host engine's stolen cores surface as bubble, not as compute
    assert h2.compute_time == pytest.approx(d.compute_time)


def test_progress_engine_host_topology_mode():
    topo = FatTree(k=8, n_hosts=16)
    d = simulate_fsdp_step(n_layers=3, layer_bytes=64e6, p=16,
                           policy="mcast", topology=topo)
    topo = FatTree(k=8, n_hosts=16)
    h = simulate_fsdp_step(n_layers=3, layer_bytes=64e6, p=16,
                           policy="mcast", topology=topo,
                           progress_engine="host", host_cores=2)
    assert h.step_time > d.step_time
    assert h.bubble_fraction > d.bubble_fraction


def test_packet_fidelity_topology_mode():
    topo = FatTree(k=8, n_hosts=16)
    fluid = simulate_fsdp_step(n_layers=3, layer_bytes=32e6, p=16,
                               policy="split", topology=topo)
    topo = FatTree(k=8, n_hosts=16)
    lossy = simulate_fsdp_step(n_layers=3, layer_bytes=32e6, p=16,
                               policy="split", topology=topo,
                               fidelity="packet", loss=1e-3,
                               rng=np.random.default_rng(1))
    assert lossy.step_time > fluid.step_time
