"""Event-level DPA progress engine (core/dpa_engine.py): property suite.

Drives the simulator with hypothesis-sampled hardware shapes and arrival
traces (or the offline seeded shim — REPRO_TEST_SEED salts the sample set)
and pins:

  - conservation: every CQE submitted is serviced exactly once
  - monotonicity: more thread contexts never slow a saturating batch down
    (until the per-core NIC-interface cap, where the curves merge)
  - convergence: measured pool capacity tracks the analytic oracle
    dpa.pool_tput at 4 KiB chunks — exact at the T=1 anchor, within 10% at
    full-core multiples, within a documented band mid-range (the linear
    stall-contention mechanism vs the T^e envelope) and at partial trailing
    cores (static round-robin dispatch under-serves them vs the oracle's
    perfect balance — DESIGN.md §7)
  - the degenerate contract: zero compute / zero contention / no caps makes
    DpaEventPool bit-identical to engine.worker_pool_completion (which is
    what the packet engine's zero-cost exactness rests on)
  - the paper anchors: Fig 13/14 saturation thread counts, Fig 16 Tbit
    feasibility, Fig 5 host-CPU inferiority, LLC-occupancy degradation and
    protocol work stealing receive cycles.
"""
import math

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:  # offline: seeded-random shim (tests/_hypothesis_shim.py)
    from _hypothesis_shim import given, settings, strategies as st
import numpy as np
import pytest

from repro.core import dpa
from repro.core.dpa_engine import (
    DpaEventPool,
    EventDpaParams,
    pool_tput_event,
    resolve_event_params,
    sustained_chunk_rate_event,
    sustained_tput_event,
    tbit_feasible_event,
    threads_to_saturate_event,
)
from repro.core.engine import worker_pool_completion

GIB = 1 << 30


@st.composite
def arrival_traces(draw):
    """Sorted CQE arrival trace: bursts + paced stretches (what the packet
    engine's fast path + recovery rounds actually produce)."""
    n = draw(st.integers(8, 600))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    kind = draw(st.sampled_from(["burst", "paced", "mixed"]))
    if kind == "burst":
        arr = np.zeros(n)
    elif kind == "paced":
        arr = np.arange(n) * float(rng.uniform(1e-8, 2e-6))
    else:
        arr = np.sort(rng.uniform(0.0, 1e-3, size=n))
    return arr


@st.composite
def hw_shapes(draw):
    transport = draw(st.sampled_from(["UD", "UC"]))
    n_threads = draw(st.integers(1, 48))
    return transport, n_threads


# ------------------------------------------------------------- conservation


@settings(max_examples=30, deadline=None)
@given(hw_shapes(), arrival_traces(), st.integers(6, 14),
       st.sampled_from([4, 8, 16]))
def test_conservation_every_cqe_serviced_once(shape, arrivals, chunk_log2,
                                              threads_per_core):
    """One done time per submitted CQE, no earlier than its arrival plus the
    single-CQE floor; n_served counts every submission across batches —
    across core counts (threads_per_core varies the core split)."""
    import dataclasses

    transport, n_threads = shape
    params = dataclasses.replace(
        EventDpaParams.from_table1(transport, n_threads),
        threads_per_core=threads_per_core)
    pool = DpaEventPool(params)
    chunk = 1 << chunk_log2
    floor = (params.cycles_compute + params.cycles_stall) / params.freq_hz
    split = arrivals.shape[0] // 2
    total = 0
    for batch in (arrivals[:split], arrivals[split:]):
        done = pool.service_batch(batch, chunk)
        assert done.shape == batch.shape
        assert np.isfinite(done).all()
        assert (done >= batch + floor - 1e-18).all()
        total += batch.shape[0]
    assert pool.n_served == total


# ------------------------------------------------------------- monotonicity


@settings(max_examples=25, deadline=None)
@given(st.sampled_from(["UD", "UC"]), st.integers(1, 24))
def test_more_threads_never_slower_until_core_cap(transport, t_small):
    """Doubling the contexts never lengthens a saturating batch's makespan:
    added contexts inflate each other's stalls (shared LLC ports) but the
    aggregate service rate still rises until the per-core NIC-interface cap
    levels both configurations off."""
    t_big = 2 * t_small
    n = 64 * t_big                       # divisible by both thread counts
    mk = {}
    for t in (t_small, t_big):
        pool = DpaEventPool(EventDpaParams.from_table1(transport, t))
        mk[t] = float(pool.service_batch(np.zeros(n), 4096).max())
    assert mk[t_big] <= mk[t_small] * (1.0 + 1e-9), mk


# ------------------------------------------- convergence to the analytic oracle


@settings(max_examples=30, deadline=None)
@given(hw_shapes())
def test_capacity_converges_to_pool_tput(shape):
    """Event-measured pool capacity vs dpa.pool_tput at 4 KiB chunks.
    Bands (DESIGN.md §7): mid-range the linear stall-contention mechanism
    sits up to ~30% above the T^0.55 envelope, and a partial trailing core
    is under-served by static round-robin dispatch down to ~0.78x."""
    transport, n_threads = shape
    ev = pool_tput_event(EventDpaParams.from_table1(transport, n_threads))
    an = dpa.pool_tput(dpa.DpaConfig(transport, n_threads))
    assert 0.78 <= ev / an <= 1.32, (transport, n_threads, ev / an)


@pytest.mark.parametrize("transport", ["UD", "UC"])
def test_capacity_anchors_exact(transport):
    """T=1 sits exactly on Table I; full-core multiples land within 10% of
    the oracle (both are cap-limited there)."""
    one = pool_tput_event(EventDpaParams.from_table1(transport, 1))
    assert one == pytest.approx(dpa.single_thread_tput(transport), rel=0.02)
    for t in (16, 32, 64):
        ev = pool_tput_event(EventDpaParams.from_table1(transport, t))
        an = dpa.pool_tput(dpa.DpaConfig(transport, t))
        assert ev == pytest.approx(an, rel=0.10), (transport, t)


# --------------------------------------------------- the degenerate contract


@settings(max_examples=30, deadline=None)
@given(arrival_traces(), st.integers(1, 16),
       st.floats(1e-8, 1e-5), st.integers(1, 256))
def test_degenerate_pool_is_worker_pool_completion(arrivals, w, service,
                                                   staging):
    """Zero compute, zero contention, no cap, no LLC: the event pool IS the
    scalar T-server queue — identical done times AND identical staging-ring
    RNR decisions."""
    params = EventDpaParams(
        n_threads=w, cycles_compute=0.0,
        cycles_stall=service * dpa.DPA_FREQ_HZ, mem_contention=0.0,
        core_cap_msgs=None, llc_bytes=math.inf)
    done_ref, rnr_ref = worker_pool_completion(arrivals, w, service, staging)
    done_ev = DpaEventPool(params).service_batch(arrivals, 4096)
    np.testing.assert_allclose(done_ev, done_ref, rtol=1e-12, atol=1e-18)
    psns = np.arange(arrivals.shape[0])
    _, rnr_psns = DpaEventPool(params).service_with_rnr(
        arrivals, psns, 4096, staging)
    assert rnr_psns.shape[0] == rnr_ref


def test_zero_cost_pool_is_transparent():
    arr = np.sort(np.random.default_rng(0).uniform(0, 1e-3, 200))
    done = DpaEventPool(EventDpaParams.zero_cost(4)).service_batch(arr, 4096)
    np.testing.assert_array_equal(done, arr)


# ------------------------------------------------------- mechanism anchors


def test_fig13_14_saturation_thread_counts_event():
    """Acceptance: the event engine saturates 200G at ~4 UC threads and
    within 8-16 UD threads — measured, not asserted via the analytic
    envelope."""
    assert threads_to_saturate_event("UC") <= 4
    assert 8 <= threads_to_saturate_event("UD") <= 16


def test_fig16_tbit_feasibility_event():
    """Acceptance: 128 threads sustain the 1.6 Tbit/s chunk arrival rate at
    64 B chunks, within 10% of the analytic oracle; 8 threads cannot."""
    assert tbit_feasible_event("UD", 128)
    assert not tbit_feasible_event("UD", 8)
    need = dpa.link_chunk_arrival_rate(dpa.LINK_1600G_BYTES)
    rate = sustained_chunk_rate_event(
        EventDpaParams.from_table1("UD", 128), need, chunk_bytes=64)
    an = dpa.sustained_chunk_rate(
        dpa.DpaConfig("UD", 128, 64, dpa.LINK_1600G_BYTES))
    assert rate == pytest.approx(an, rel=0.10)


def test_per_core_interface_cap_binds():
    """No thread count pushes a core past its NIC-interface message rate."""
    cap_bytes = dpa.CORE_CAP_CHUNKS_PER_S * 4096
    for t in (16, 32, 48):
        ev = pool_tput_event(EventDpaParams.from_table1("UC", t))
        n_cores = -(-t // 16)
        assert ev <= n_cores * cap_bytes * (1.0 + 1e-9), (t, ev)


def test_llc_occupancy_degrades_service():
    """A burst whose outstanding chunk state spills the 1.5 MB LLC is served
    slower than under an infinite LLC; a trickle that never spills is not."""
    params = EventDpaParams.from_table1("UD", 4)
    burst = np.zeros(1024)               # 4 MiB outstanding at t=0
    spilled = DpaEventPool(params)
    t_spill = float(spilled.service_batch(burst, 4096).max())
    import dataclasses
    no_llc = dataclasses.replace(params, llc_bytes=math.inf)
    t_free = float(DpaEventPool(no_llc).service_batch(burst, 4096).max())
    assert spilled.llc_spill_events > 0
    assert t_spill > t_free * 1.2, (t_spill, t_free)
    trickle = np.arange(64) * 1e-3       # backlog never builds
    calm = DpaEventPool(params)
    calm.service_batch(trickle, 4096)
    assert calm.llc_spill_events == 0


def test_protocol_work_steals_receive_cycles():
    """NACK service and retransmit posting occupy the same contexts: a pool
    that served protocol work first finishes the SAME data batch later."""
    params = EventDpaParams.from_table1("UD", 4)
    data = np.arange(256) * 1e-7
    clean = DpaEventPool(params)
    t_clean = float(clean.service_batch(data, 4096).max())
    busy = DpaEventPool(params)
    busy.service_batch(np.zeros(16), 4096 + 32, kind="nack", wire_bytes=4128)
    busy.service_batch(np.zeros(64), 4096, kind="retx")
    t_busy = float(busy.service_batch(data, 4096).max())
    assert t_busy > t_clean


def test_host_cpu_baseline_calibration():
    """Fig 5: one Epyc core lands on its measured 9.0 GiB/s (UD +
    reliability), scales linearly in cores (no shared-core contention), and
    a single core cannot hold a 200 Gbit/s link — while one multithreaded
    DPA core can."""
    host1 = pool_tput_event(EventDpaParams.host_cpu(1))
    assert host1 == pytest.approx(
        dpa.CPU_CORE_TPUT_GIB["UD_reliability"] * GIB, rel=0.02)
    host4 = pool_tput_event(EventDpaParams.host_cpu(4))
    assert host4 == pytest.approx(4 * host1, rel=0.05)
    assert host1 < dpa.LINK_200G_BYTES
    dpa_core = sustained_tput_event(EventDpaParams.from_table1("UD", 16))
    assert dpa_core >= 0.99 * dpa.LINK_200G_BYTES
    assert dpa_core / host1 > 1.2


def test_host_cpu_has_no_latency_hiding():
    """The host baseline's per-CQE wall time is the FULL compute+stall
    budget: adding a second core doubles throughput but a single core's
    service never overlaps (contrast: 16 DPA threads on one core serve far
    more than one thread)."""
    host = EventDpaParams.host_cpu(1)
    service = (host.cycles_compute + host.cycles_stall) / host.freq_hz
    done = DpaEventPool(host).service_batch(np.zeros(10), 4096)
    np.testing.assert_allclose(done, (np.arange(10) + 1) * service, rtol=1e-12)
    one = pool_tput_event(EventDpaParams.from_table1("UD", 1))
    sixteen = pool_tput_event(EventDpaParams.from_table1("UD", 16))
    assert sixteen > 3 * one             # latency hiding, sublinear but real


# ------------------------------------------------------------- param plumbing


def test_resolve_event_params():
    assert resolve_event_params(None, 8).n_threads == 8
    cfg = dpa.DpaConfig("UC", 4)
    p = resolve_event_params(cfg, 8)
    assert p.transport == "UC" and p.n_threads == 4
    assert resolve_event_params(p, 8) is p
    with pytest.raises(TypeError):
        resolve_event_params("UD", 8)
    with pytest.raises(ValueError):
        EventDpaParams.from_table1("UD", 2).service_cycles("bogus")
