"""Chunked (block-parallel) WKV vs the naive recurrence oracle."""
try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:  # offline: seeded-random shim (tests/_hypothesis_shim.py)
    from _hypothesis_shim import given, settings, strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.rwkv import wkv_chunked, wkv_recurrent

# jax model/integration tier: excluded from the fast CI
# lane (scripts/check.sh), run by the `slow` CI job
pytestmark = pytest.mark.slow


def _case(b, s, h, hd, seed, decay_scale=1.0):
    rng = np.random.default_rng(seed)
    r = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
    lw = -jnp.asarray(
        rng.uniform(0.001, decay_scale, (b, s, h, hd)), jnp.float32
    )
    u = jnp.asarray(rng.standard_normal((h, hd)), jnp.float32) * 0.5
    return r, k, v, lw, u


@pytest.mark.parametrize("chunk", [4, 16, 64])
@pytest.mark.parametrize("s", [16, 60, 128])
def test_chunked_matches_recurrent(chunk, s):
    r, k, v, lw, u = _case(2, s, 2, 8, seed=chunk + s)
    o_c, s_c = wkv_chunked(r, k, v, lw, u, chunk)
    o_r, s_r = wkv_recurrent(r, k, v, lw, u)
    np.testing.assert_allclose(np.asarray(o_c), np.asarray(o_r), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_c), np.asarray(s_r), atol=1e-4)


@given(st.integers(0, 1000), st.sampled_from([8, 24, 33]),
       st.floats(0.01, 2.0))
@settings(max_examples=20, deadline=None)
def test_chunked_matches_recurrent_property(seed, s, decay):
    r, k, v, lw, u = _case(1, s, 2, 4, seed=seed, decay_scale=decay)
    o_c, s_c = wkv_chunked(r, k, v, lw, u, 8)
    o_r, s_r = wkv_recurrent(r, k, v, lw, u)
    np.testing.assert_allclose(np.asarray(o_c), np.asarray(o_r), atol=1e-3)


def test_state_carry_streaming():
    """Recurrent decode from the chunked-prefill state == full recurrence."""
    r, k, v, lw, u = _case(1, 32, 2, 8, seed=7)
    o_full, s_full = wkv_recurrent(r, k, v, lw, u)
    _, s_pre = wkv_chunked(r[:, :24], k[:, :24], v[:, :24], lw[:, :24], u, 8)
    o_tail, s_tail = wkv_recurrent(
        r[:, 24:], k[:, 24:], v[:, 24:], lw[:, 24:], u, S0=s_pre
    )
    np.testing.assert_allclose(np.asarray(o_tail), np.asarray(o_full[:, 24:]),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_tail), np.asarray(s_full), atol=1e-4)
