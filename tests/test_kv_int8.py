"""int8 KV cache: decode equivalence within quantization tolerance."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_model_config, reduced
from repro.models import build_model
from repro.models.attention import dequantize_kv, quantize_kv
from repro.models.model_builder import _head_matrix


def test_quantize_roundtrip():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((4, 2, 16, 32)), jnp.float32)
    q, s = quantize_kv(x)
    y = dequantize_kv(q, s, jnp.float32)
    rel = float(jnp.max(jnp.abs(y - x)) / jnp.max(jnp.abs(x)))
    assert rel < 0.02
    assert q.dtype == jnp.int8


def test_int8_decode_close_to_fp():
    cfg = reduced(get_model_config("smollm-135m"))
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    api = build_model(cfg)
    api8 = build_model(cfg8)
    rng = jax.random.PRNGKey(1)
    params = api.init_params(rng)
    b, s = 2, 24
    tokens = jax.random.randint(rng, (b, s), 0, cfg.vocab_size, dtype=jnp.int32)
    hid = api.forward_fn(params, {"tokens": tokens})
    full = jnp.einsum("bsd,dv->bsv", hid, _head_matrix(params, cfg).astype(hid.dtype))

    cache = api8.init_cache(b, s)
    assert cache["k"].dtype == jnp.int8 and "ks" in cache
    dec = jax.jit(api8.decode_fn)
    err = 0.0
    for t in range(s):
        lg, cache = dec(params, cache, tokens[:, t], jnp.full((b,), t, jnp.int32))
        err = max(err, float(jnp.max(jnp.abs(lg - full[:, t]))))
    # quantized cache: small but nonzero divergence
    assert err < 0.1, err


def test_int8_prefill_builds_quantized_cache():
    cfg = dataclasses.replace(
        reduced(get_model_config("yi-9b")), kv_cache_dtype="int8"
    )
    api = build_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                cfg.vocab_size, dtype=jnp.int32)
    logits, cache = jax.jit(api.prefill_fn)(params, {"tokens": tokens})
    assert cache["k"].dtype == jnp.int8
    assert cache["ks"].shape == cache["k"].shape[:-1] + (1,)
    assert jnp.all(jnp.isfinite(logits))
