"""Discrete-event simulator behaviour (paper §VI evaluation properties),
including the routed topology mode (ranks placed on a real fat-tree)."""
import numpy as np
import pytest

from repro.core.simulator import (
    FabricParams,
    WorkerParams,
    simulate_allgather,
    simulate_broadcast,
    sweep_phase_breakdown,
)
from repro.core.topology import FatTree


def _fab(**kw):
    return FabricParams(**kw)


def test_clean_fabric_fast_path_only():
    r = simulate_broadcast(8, 1 << 20, _fab(), WorkerParams(n_recv_workers=8),
                           np.random.default_rng(0))
    assert r.recovered == 0 and r.rnr_drops == 0
    assert r.bytes_recovery == 0
    assert r.time > 0


def test_drops_recovered_and_slower():
    rng = np.random.default_rng(1)
    clean = simulate_broadcast(8, 1 << 20, _fab(), WorkerParams(8), rng)
    rng = np.random.default_rng(1)
    lossy = simulate_broadcast(8, 1 << 20, _fab(p_drop=0.02), WorkerParams(8), rng)
    assert lossy.recovered > 0
    assert lossy.time > clean.time


def test_broadcast_constant_time_in_p():
    """The multicast broadcast time is ~constant in P for fixed N (§III):
    doubling participants adds only log-P sync, not transmission time."""
    n = 4 << 20
    times = []
    for p in (4, 16, 64, 188):
        r = simulate_broadcast(p, n, _fab(), WorkerParams(8),
                               np.random.default_rng(0))
        times.append(r.time)
    assert times[-1] < times[0] * 1.2


def test_allgather_receive_bound_for_any_chains():
    """Paper §VI-b: allgather time is bounded by the receive path regardless
    of the chain split M — the leaf must ingest (P-1)N bytes either way.
    Fewer chains only add per-round activation sync (more rounds)."""
    n = 1 << 20
    t_full = simulate_allgather(16, n, _fab(), WorkerParams(8),
                                np.random.default_rng(0), n_chains=16).time
    t_one = simulate_allgather(16, n, _fab(), WorkerParams(8),
                               np.random.default_rng(0), n_chains=1).time
    assert t_one > t_full                # R=16 rounds of sync vs 1
    assert t_one < t_full * 1.25         # but both receive-bound


def test_fig10_trend_multicast_dominates_at_scale():
    """Paper Fig 10: as size and node count grow, the non-blocking multicast
    datapath dominates the critical path (sync overheads become negligible)."""
    rows = sweep_phase_breakdown(
        sizes=[1 << 12, 4 << 20], nodes=[4, 64], seed=0
    )
    small = next(r for r in rows if r["nodes"] == 4 and r["bytes"] == 1 << 12)
    large = next(r for r in rows if r["nodes"] == 64 and r["bytes"] == 4 << 20)
    assert large["mcast_frac"] > 0.95  # 99% claim at scale
    assert small["mcast_frac"] < large["mcast_frac"]
    assert small["rnr_frac"] > large["rnr_frac"]


def test_routed_broadcast_counts_tree_bytes():
    """Topology mode: one engine run yields timing AND per-link bytes; the
    tree serves the full buffer on every edge (max = buffer size, Insight 1)."""
    p, n = 16, 1 << 20
    fab = _fab(jitter=0.0)
    topo = FatTree(k=8, n_hosts=p, b_host=fab.b_link)
    r = simulate_broadcast(p, n, fab, WorkerParams(8),
                           np.random.default_rng(0), topology=topo)
    assert r.recovered == 0
    served = {k: v for k, v in r.link_bytes.items() if v}
    tree_edges = topo.multicast_tree(0, list(range(p)))
    assert len(served) == len(tree_edges)
    assert max(served.values()) == pytest.approx(n, rel=1e-6)
    # counters view is derived from the same live links
    assert topo.counters.total() == pytest.approx(sum(served.values()))
    assert r.time > 0


def test_routed_broadcast_slower_through_oversubscribed_fabric():
    p, n = 16, 4 << 20
    fab = _fab(jitter=0.0)
    flat = FatTree(k=8, n_hosts=p, b_host=fab.b_link)
    thin = FatTree(k=8, n_hosts=p, b_host=fab.b_link, oversubscription=4.0)
    t_flat = simulate_broadcast(p, n, fab, WorkerParams(8),
                                np.random.default_rng(0), topology=flat).time
    t_thin = simulate_broadcast(p, n, fab, WorkerParams(8),
                                np.random.default_rng(0), topology=thin).time
    assert t_thin > t_flat * 1.5      # tree rate = min share over edges


def test_routed_allgather_chains_collide_and_conserve():
    """M concurrent chains on a real fat-tree: per-link bytes equal the
    broadcast-composition totals, every leaf ejection link carries the whole
    gathered buffer minus its own shard, and the time stays receive-bound."""
    p, n = 16, 1 << 18
    fab = _fab(jitter=0.0)
    topo = FatTree(k=8, n_hosts=p, b_host=fab.b_link)
    r = simulate_allgather(p, n, fab, WorkerParams(8),
                           np.random.default_rng(0), n_chains=p, topology=topo)
    served = {k: v for k, v in r.link_bytes.items() if v}
    hosts = list(range(p))
    expect = n * sum(len(topo.multicast_tree(h, hosts)) for h in hosts)
    assert sum(served.values()) == pytest.approx(expect, rel=1e-6)
    for h in hosts:   # ejection link of every host: (P-1) shards
        eject = served[f"e{topo._loc(h)[0]}.{topo._loc(h)[1]}->h{h}"]
        assert eject == pytest.approx((p - 1) * n, rel=1e-6)
    assert r.time >= (p - 1) * n / fab.b_link


def test_routed_allgather_fewer_chains_same_bytes_more_sync():
    p, n = 16, 1 << 18
    fab = _fab(jitter=0.0)
    topo = FatTree(k=8, n_hosts=p, b_host=fab.b_link)
    full = simulate_allgather(p, n, fab, WorkerParams(8),
                              np.random.default_rng(0), n_chains=p,
                              topology=topo)
    chained = simulate_allgather(p, n, fab, WorkerParams(8),
                                 np.random.default_rng(0), n_chains=2,
                                 topology=topo)
    assert sum(chained.link_bytes.values()) == pytest.approx(
        sum(full.link_bytes.values()), rel=1e-6)
    assert chained.time > full.time            # R=8 rounds of activation sync
    assert chained.time < full.time * 1.5      # but still receive-bound


def test_worker_scaling_helps_when_underprovisioned():
    n = 8 << 20
    slow = simulate_broadcast(4, n, _fab(), WorkerParams(n_recv_workers=1,
                              thread_tput=2.0 * (1 << 30)),
                              np.random.default_rng(0))
    fast = simulate_broadcast(4, n, _fab(), WorkerParams(n_recv_workers=8,
                              thread_tput=2.0 * (1 << 30)),
                              np.random.default_rng(0))
    assert fast.time < slow.time


def test_packet_fidelity_routed_loss_recovery():
    """fidelity="packet" plugs the core/packet.py engine under the same
    call: routed run, per-link loss, NACK/retransmission recovery, and the
    recovery traffic lands on the same switch-port counters."""
    p, n = 16, 1 << 20
    fab = _fab(jitter=0.0)
    topo = FatTree(k=8, n_hosts=p, b_host=fab.b_link)
    clean = simulate_broadcast(p, n, fab, WorkerParams(8),
                               np.random.default_rng(0), topology=topo,
                               fidelity="packet")
    topo = FatTree(k=8, n_hosts=p, b_host=fab.b_link)
    lossy = simulate_broadcast(p, n, fab, WorkerParams(8),
                               np.random.default_rng(0), topology=topo,
                               fidelity="packet", loss=0.01)
    assert clean.recovered == 0 and lossy.recovered > 0
    assert lossy.time > clean.time
    assert sum(lossy.link_bytes.values()) > sum(clean.link_bytes.values())


def test_fluid_rejects_loss_models_and_bad_fidelity():
    with pytest.raises(AssertionError):
        simulate_broadcast(4, 1 << 16, _fab(), WorkerParams(2),
                           np.random.default_rng(0), loss=0.1)
    with pytest.raises(AssertionError):
        simulate_allgather(4, 1 << 16, _fab(), WorkerParams(2),
                           np.random.default_rng(0), fidelity="quantum")
