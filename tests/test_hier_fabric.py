"""Tiered island fabrics (core/topology.IslandFatTree), the mixed-transport
hierarchical allgather builder (core/sched_ir.build_hierarchical_allgather)
and the searcher's tiered moves (core/sched_search.hier_candidates): link
tiers route and count per tier, per-op transports are validated and
respected by the lowerings, the tiered analytic lower bound stays
admissible, and the searched mixed-transport schedule strictly beats both
flat builders on an island fabric — the PR's acceptance property at bench
scale, held here at test scale."""
import math

import numpy as np
import pytest

from repro.core import packet as pk
from repro.core import protocol, sched_ir, sched_search
from repro.core.engine import FabricParams, WorkerParams
from repro.core.sched_ir import build_hierarchical_allgather, execute
from repro.core.sched_search import EvalCache, EvalContext, search
from repro.core.topology import LINK_TIERS, FatTree, IslandFatTree

FAB = FabricParams(jitter=0.0)
WK = WorkerParams(n_recv_workers=8)
P, G, N = 16, 4, 1 << 20


def _island_fabric(**kw):
    return IslandFatTree(4, P, island_size=G, **kw)


# ------------------------------------------------------------- topology


def test_tiers_and_island_structure():
    topo = _island_fabric()
    assert topo.n_islands == P // G
    assert topo.island_of(0) == 0 and topo.island_of(G) == 1
    assert topo.island_members(1) == list(range(G, 2 * G))
    # NVLink-class default: 8x the NIC rate per direction
    assert topo.b_island == 8 * topo.b_host
    assert topo.tier_of("h0", "h1") == "island"
    assert topo.tier_of("h0", "e0.0") == "switched"
    for t in topo.tier_capacities():
        assert t in ("island", "host", "up")
    assert set(LINK_TIERS) == {"intra_host", "island", "switched"}
    # searcher cache identity includes the island shape
    assert topo.signature() != FatTree(4, P).signature()
    assert "island_size" in str(topo.signature()) or G in topo.signature()


def test_route_respects_transport():
    topo = _island_fabric()
    # island-local pairs default onto the island ring (one ICI hop)
    hops = topo.route(0, 1)
    assert [l.name for l in hops] == ["h0->h1"]
    # ring shortest path goes backwards for the last member
    assert [l.name for l in topo.route(0, G - 1)] == [f"h0->h{G - 1}"]
    # transport="switched" forces the same pair up the fat-tree
    up = topo.route(0, 1, transport="switched")
    assert up[0].name == "h0->e0.0" and len(up) > 1
    # cross-island pairs route the fat-tree whatever the default says
    assert topo.route(0, G)[0].name == "h0->e0.0"
    with pytest.raises(AssertionError):
        topo.route(0, G, transport="island")   # not island-local
    with pytest.raises(AssertionError):
        topo.multicast_tree(0, list(range(G)), transport="island")


def test_tier_split_buckets_fabric_bytes():
    topo = _island_fabric()
    topo.unicast(0, 1, 100.0)        # island hop
    topo.unicast(0, G, 40.0)         # switched (cross-island)
    link_bytes = {f"{a}->{b}": v
                  for (a, b), v in topo.counters.bytes_by_link.items()}
    split = topo.tier_split(link_bytes)
    assert split["island"] == pytest.approx(100.0)
    # h0->edge->...->h4: every hop is switched tier
    assert split["switched"] == pytest.approx(40.0 * len(topo.route(0, G)))


def test_island0_bottleneck_cut():
    topo = _island_fabric()
    cuts = {c.name: c for c in topo.bottleneck_cuts()}
    assert "island0" in cuts
    cut = cuts["island0"]
    assert set(cut.hosts) == set(range(G))
    # the island funnels through its members' NIC attaches only — the
    # island-tier ring cables never cross the cut
    assert cut.cap_in == pytest.approx(G * topo.b_host)


# ------------------------------------------------------- schedule builder


def test_hier_builder_validates_and_pins_transports():
    sched = build_hierarchical_allgather(P, N, G, m=2)
    sched_ir.validate(sched)
    g = sched.meta["island_size"]
    mcasts = [op for op in sched.ops if isinstance(op, sched_ir.Multicast)]
    unis = [op for op in sched.ops if isinstance(op, sched_ir.Unicast)]
    assert mcasts and all(op.transport == "switched" for op in mcasts)
    # phase C: island-tier unicasts that never leave their island
    ring = [op for op in unis if op.transport == "island"]
    assert ring and all(op.src // g == op.dst // g for op in ring)
    assert sched.meta["bundle_bytes"] == (P // G) * N
    # transport flips change the schedule identity the EvalCache keys on
    alt = build_hierarchical_allgather(P, N, G, m=2,
                                       redistribute_transport="switched")
    sched_ir.validate(alt)
    assert sched_ir.canonical_key(alt) != sched_ir.canonical_key(sched)


def test_hier_builder_rejects_degenerate_groupings():
    with pytest.raises(AssertionError):
        build_hierarchical_allgather(P, N, 3)       # islands must tile P
    with pytest.raises(AssertionError):
        build_hierarchical_allgather(P, N, P)       # needs >= 2 islands
    with pytest.raises(AssertionError):
        build_hierarchical_allgather(P, N, G, stripe_mode="bogus")


@pytest.mark.parametrize("stripe_mode", ["mcast", "ring"])
def test_hier_fidelity_ordering_abstract(stripe_mode):
    rng = np.random.default_rng(0)
    sched = build_hierarchical_allgather(P, N, G, stripe_mode=stripe_mode)
    a = execute(sched, FAB, WK, fidelity="analytic")
    f = execute(sched, FAB, WK, rng, fidelity="fluid")
    p = execute(sched, FAB, WK, rng, fidelity="packet")
    assert math.isfinite(a) and a > 0
    assert a <= f.time + 1e-12 <= p.time + 1e-9
    assert p.completed


def test_hier_fluid_beats_flat_builders_on_island_fabric():
    topo = _island_fabric()
    rng = np.random.default_rng(0)
    hosts = list(range(P))

    def fluid(sched):
        topo.reset()
        return execute(sched, FAB, WK, rng, fidelity="fluid",
                       topology=topo, hosts=hosts).time

    hier = fluid(build_hierarchical_allgather(P, N, G))
    flat = fluid(sched_ir.build_allgather(P, N, P))
    ring = fluid(sched_ir.build_ring_allgather(P, N))
    assert hier < flat and hier < ring


def test_hier_moves_bytes_onto_island_tier():
    topo = _island_fabric()
    hosts = list(range(P))
    res = execute(build_hierarchical_allgather(P, N, G), FAB, WK,
                  np.random.default_rng(0), fidelity="fluid",
                  topology=topo, hosts=hosts)
    split = topo.tier_split(res.link_bytes)
    topo.reset()
    flat = execute(sched_ir.build_allgather(P, N, P), FAB, WK,
                   np.random.default_rng(0), fidelity="fluid",
                   topology=topo, hosts=hosts)
    flat_split = topo.tier_split(flat.link_bytes)
    # the redistribution phase rides the island tier; the flat multicast
    # puts every byte on the switched fabric
    assert split.get("island", 0.0) > 0
    assert flat_split.get("island", 0.0) == 0
    assert split["switched"] < flat_split["switched"]


def test_hier_packet_island_redistribution_is_lossless():
    topo = _island_fabric()
    hosts = list(range(P))
    sched = build_hierarchical_allgather(P, N, G)
    res = execute(sched, FAB, WK, np.random.default_rng(0),
                  fidelity="packet", topology=topo, hosts=hosts, loss=0.02)
    assert res.completed
    # intra-island ICI is reliable (DESIGN §2/§11): phase C ran lossless,
    # so its time matches the lossless run of the same ring bit-for-bit
    topo.reset()
    clean = execute(sched, FAB, WK, np.random.default_rng(0),
                    fidelity="packet", topology=topo, hosts=hosts)
    assert res.ring.time == pytest.approx(clean.ring.time)
    # while the switched stripe did see the loss process
    assert res.stripe.time >= clean.stripe.time


def test_hier_switched_redistribution_keeps_loss_model():
    topo = _island_fabric()
    hosts = list(range(P))
    sched = build_hierarchical_allgather(P, N, G,
                                         redistribute_transport="switched")
    res = execute(sched, FAB, WK, np.random.default_rng(0),
                  fidelity="packet", topology=topo, hosts=hosts, loss=0.05)
    assert res.completed and math.isfinite(res.time)
    topo.reset()
    clean = execute(sched, FAB, WK, np.random.default_rng(0),
                    fidelity="packet", topology=topo, hosts=hosts)
    assert res.ring.time > clean.ring.time   # recovery rounds cost time


# ----------------------------------------------------- bounds and search


def test_tiered_analytic_bound_monotone_in_island_rate():
    slow = protocol.analytic_hier_allgather_time(
        P, N, FAB.b_link, FAB.latency, island_size=G, m=1,
        b_island=FAB.b_link)
    fast = protocol.analytic_hier_allgather_time(
        P, N, FAB.b_link, FAB.latency, island_size=G, m=1,
        b_island=8 * FAB.b_link)
    assert fast < slow


def test_hier_candidates_only_on_island_fabrics():
    assert sched_search.hier_candidates(P, N, FatTree(4, P)) == []
    assert sched_search.hier_candidates(P, N, None) == []
    cands = sched_search.hier_candidates(P, N, _island_fabric())
    names = [c.name for c in cands]
    assert any(c.origin == "builder" and f"g={G}" in c.name for c in cands)
    # the searcher moves: island regrouping, stripe transport flip,
    # redistribution transport flip, chain fan-out/depth mutation
    assert any("g=2" in n for n in names)
    assert any("ring-stripe" in n for n in names)
    assert any("switched-redist" in n for n in names)
    assert any("fanout" in n for n in names)
    # fan-out mutations are exactly the M*/2 and 2M* neighbours not already
    # probed, and they can be switched off (the never-worsened pin)
    plain = sched_search.hier_candidates(P, N, _island_fabric(),
                                         fanout_moves=False)
    assert not any("fanout" in c.name for c in plain)
    assert {c.name for c in plain} < {c.name for c in cands}
    for c in cands:
        sched_ir.validate(c.sched)


def test_hier_lower_bound_admissible():
    topo = _island_fabric()
    ctx = EvalContext(FAB, WK, topo, tuple(range(P)), "fluid", 0)
    cache = EvalCache()
    for cand in sched_search.hier_candidates(P, N, topo):
        bound, _ = sched_search.lower_bound(cand.sched, ctx)
        res = cache.evaluate(cand.sched, ctx)
        assert bound <= res.time + 1e-12, cand.name


def test_search_picks_mixed_transport_winner():
    topo = _island_fabric()
    r = search("allgather", P, N, topology=topo, hosts=list(range(P)),
               validate_packet=True)
    assert r.winner.sched.kind == "hier_allgather"
    assert r.packet_validated
    assert r.certificate.ratio >= 1.0 - 1e-9
    flat = {row.name: row for row in r.table}
    # the acceptance property at test scale: the searched schedule strictly
    # beats the flat multicast builder AND the pure unicast ring
    flat_times = [row.time for row in r.table
                  if row.origin == "builder" and "hier" not in row.name
                  and row.time is not None]
    assert flat_times and r.winner_time < min(flat_times)
    assert any("hier" in name for name in flat)


# ------------------------------------------- packet-engine selection (auto)


def test_engine_auto_sees_stripe_not_global_rows(monkeypatch):
    """engine="auto" dense big-row detection runs on the switched stripe
    sub-schedule (p = n_islands), never on the full-P rank count and never
    on the island-local phase-C rows (RC ring transport bypasses the
    multicast engines entirely) — island rows must not trip the dense
    heuristic."""
    monkeypatch.delenv("REPRO_PACKET_ENGINE", raising=False)
    calls = []
    real = pk.resolve_engine

    def spy(engine, kind, p, row_bytes):
        calls.append((engine, kind, p, row_bytes))
        return real(engine, kind, p, row_bytes)

    monkeypatch.setattr(pk, "resolve_engine", spy)
    topo = _island_fabric()
    sched = build_hierarchical_allgather(P, N, G, m=P // G)
    execute(sched, FAB, WK, np.random.default_rng(0), fidelity="packet",
            topology=topo, hosts=list(range(P)), engine="auto")
    assert len(calls) == 1                  # the stripe leg only
    engine, kind, p_seen, row_bytes = calls[0]
    assert (engine, kind) == ("auto", "allgather")
    assert p_seen == P // G                 # island count, not P
    # "auto" resolves to the vectorized engine (the dense fallback is
    # retired) — big island-local bundles never enter the multicast engines
    assert real(*calls[0]) == "vectorized"


def test_repro_packet_engine_env_override(monkeypatch):
    monkeypatch.delenv("REPRO_PACKET_ENGINE", raising=False)
    assert pk.resolve_engine("auto", "allgather", 8, 32 << 20) == "vectorized"
    monkeypatch.setenv("REPRO_PACKET_ENGINE", "vectorized")
    assert pk.resolve_engine("auto", "allgather", 8, 32 << 20) == "vectorized"
    monkeypatch.setenv("REPRO_PACKET_ENGINE", "reference")
    assert pk.resolve_engine("auto", "broadcast", 8, 1) == "reference"
    # explicit engine choices ignore the override — the bit-exact pin
    # tests must keep comparing both engines under any environment
    assert pk.resolve_engine("vectorized", "allgather", 8, 32 << 20) \
        == "vectorized"
    monkeypatch.setenv("REPRO_PACKET_ENGINE", "bogus")
    with pytest.raises(AssertionError):
        pk.resolve_engine("auto", "broadcast", 8, 1)


def test_engine_auto_matrix_consistent_results(monkeypatch):
    """The env override only moves which executor runs — results are pinned
    bit-exact, so a hier packet run must produce identical times."""
    topo = _island_fabric()
    sched = build_hierarchical_allgather(P, N, G)
    kw = dict(fidelity="packet", topology=topo, hosts=list(range(P)),
              loss=0.01)
    base = execute(sched, FAB, WK, np.random.default_rng(3), **kw)
    monkeypatch.setenv("REPRO_PACKET_ENGINE", "reference")
    topo.reset()
    ref = execute(sched, FAB, WK, np.random.default_rng(3), **kw)
    assert ref.time == pytest.approx(base.time, rel=0, abs=0)
    assert ref.stripe.time == base.stripe.time


# ---------------------------------------------- inter-stripe contention


def _contended_fabric():
    # k=8 pods: stripes' multicast trees genuinely collide on shared
    # agg/core uplinks (deterministic ECMP), unlike the tiny k=4 fabric
    return IslandFatTree(8, 32, island_size=4)


def test_interstripe_contention_factor_measured_and_applied():
    """DESIGN §11 deviation closed: sibling stripes share agg/core uplinks.
    The fluid stripe leg runs ALL stripes' flows on one engine, so its time
    equals solo-time x the measured contention factor; the factor is > 1
    on a fabric where the stripe trees collide."""
    topo = _contended_fabric()
    p, g = 32, 4
    hosts = list(range(p))
    sched = build_hierarchical_allgather(p, N, g)
    stripe_hosts = [j * g for j in range(p // g)]
    co = [[j * g + r for j in range(p // g)] for r in range(1, g)]
    factor = sched_ir._stripe_contention_factor(
        sched.meta["stripe_ag"], FAB, WK, topo, stripe_hosts, co)
    assert factor > 1.0
    solo = sched_ir._fluid_allgather(
        sched.meta["stripe_ag"], FAB, WK, np.random.default_rng(0),
        topology=topo, hosts=stripe_hosts)
    res = execute(sched, FAB, WK, np.random.default_rng(0), fidelity="fluid",
                  topology=topo, hosts=hosts)
    assert res.stripe.time == pytest.approx(solo.time * factor, rel=1e-9)


def test_interstripe_contention_packet_scales_with_fluid_factor():
    """Packet stripe leg pays the same fluid-validated contention factor:
    loss-0 packet stripe time stays >= the contended fluid stripe time, and
    the full fidelity ordering analytic <= fluid <= packet holds routed."""
    topo = _contended_fabric()
    p, g = 32, 4
    hosts = list(range(p))
    sched = build_hierarchical_allgather(p, N, g)
    fl = execute(sched, FAB, WK, np.random.default_rng(0), fidelity="fluid",
                 topology=topo, hosts=hosts)
    topo.reset()
    pk_res = execute(sched, FAB, WK, np.random.default_rng(0),
                     fidelity="packet", topology=topo, hosts=hosts)
    assert fl.time <= pk_res.time + 1e-9
    assert pk_res.stripe.time >= fl.stripe.time - 1e-12


def test_interstripe_contention_preserves_link_bytes():
    """Byte accounting is fidelity-invariant: the fluid engine now counts
    every stripe's tree bytes directly; they must equal the packet path's
    static sibling-stripe count, link for link."""
    topo = _contended_fabric()
    p, g = 32, 4
    hosts = list(range(p))
    sched = build_hierarchical_allgather(p, N, g)
    fl = execute(sched, FAB, WK, np.random.default_rng(0), fidelity="fluid",
                 topology=topo, hosts=hosts)
    topo.reset()
    pk_res = execute(sched, FAB, WK, np.random.default_rng(0),
                     fidelity="packet", topology=topo, hosts=hosts)
    assert set(fl.link_bytes) == set(pk_res.link_bytes)
    for name, v in fl.link_bytes.items():
        assert v == pytest.approx(pk_res.link_bytes[name], rel=1e-9), name


def test_fanout_moves_never_worsen_search_winner(monkeypatch):
    """The PR-8 open item's acceptance pin: adding the fan-out/depth
    mutation moves can only grow the candidate pool, so the searched winner
    on an island fabric is never worse than without them — and the moves
    really enter the search table."""
    topo = _island_fabric()
    cache = EvalCache()
    real = sched_search.hier_candidates
    monkeypatch.setattr(
        sched_search, "hier_candidates",
        lambda p, n, t: real(p, n, t, fanout_moves=False))
    base = search("allgather", P, N, topology=topo, hosts=list(range(P)),
                  validate_packet=False, cache=cache)
    monkeypatch.setattr(sched_search, "hier_candidates", real)
    full = search("allgather", P, N, topology=topo, hosts=list(range(P)),
                  validate_packet=False, cache=cache)
    assert full.winner_time <= base.winner_time + 1e-15
    assert any("fanout" in row.name for row in full.table)
