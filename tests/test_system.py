"""System behaviour: dry-run machinery on a small mesh + HLO collective stats.

The production 512-device dry-run runs via ``python -m repro.launch.dryrun``;
here we validate the same machinery end-to-end at test scale (8 devices).
"""
import pytest

# jax model/integration tier: excluded from the fast CI
# lane (scripts/check.sh), run by the `slow` CI job
pytestmark = pytest.mark.slow



def test_hlo_collective_stats(multidev):
    multidev(
        """
import pytest
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.launch.hlo_stats import collective_stats
mesh = jax.make_mesh((2, 4), ('data', 'model'))

def f(x, w):
    y = x @ w                          # contraction over sharded dim -> AR/RS
    return jax.lax.with_sharding_constraint(y, NamedSharding(mesh, P()))

x = jax.ShapeDtypeStruct((128, 256), jnp.float32,
                         sharding=NamedSharding(mesh, P('data', 'model')))
w = jax.ShapeDtypeStruct((256, 64), jnp.float32,
                         sharding=NamedSharding(mesh, P('model', None)))
comp = jax.jit(f).lower(x, w).compile()
st = collective_stats(comp.as_text(), 8)
assert st.total_bytes > 0, st.as_dict()
assert sum(st.counts.values()) >= 1
print('ok', st.as_dict())
"""
    )


def test_loop_scaled_collectives(multidev):
    """Collectives inside a scan are multiplied by the loop-chain length."""
    multidev(
        """
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.launch.hlo_stats import collective_stats
mesh = jax.make_mesh((8,), ('x',))

def f(x, ws):
    def body(c, w):
        wg = jax.lax.with_sharding_constraint(w, NamedSharding(mesh, P()))
        return jnp.tanh(c @ wg), None
    y, _ = jax.lax.scan(body, x, ws)
    return y

x = jax.ShapeDtypeStruct((64, 64), jnp.float32,
                         sharding=NamedSharding(mesh, P()))
ws = jax.ShapeDtypeStruct((12, 64, 64), jnp.float32,
                          sharding=NamedSharding(mesh, P(None, 'x', None)))
comp = jax.jit(f).lower(x, ws).compile()
txt = comp.as_text()
st1 = collective_stats(txt, 8, loop_chain=())
st12 = collective_stats(txt, 8, loop_chain=(12,))
in_loop = any('while/body' in l and 'all-gather' in l for l in txt.splitlines())
if in_loop:
    assert st12.total_bytes > st1.total_bytes
print('ok', st1.total_bytes, st12.total_bytes, 'in_loop', in_loop)
"""
    )


def test_dryrun_cell_machinery(multidev):
    """run_cell on a full config compiles on the production mesh and emits
    roofline inputs (512 fake devices; one fast cell)."""
    multidev(
        """
import os
assert os.environ['XLA_FLAGS'].endswith('512')
from repro.launch.dryrun import run_cell

rec = run_cell('smollm-135m', 'decode_32k', False)
assert rec['ok'], rec.get('error')
assert rec['analytic']['model_flops'] > 0
assert rec['analytic']['hbm_bytes_per_device'] > 0
assert rec['collectives_hlo']['per_device_total'] >= 0
print('ok', rec['compile_s'])
""",
        n_devices=512,
        timeout=420,
    )


def test_cell_enumeration():
    from repro.configs import iter_cells

    cells = list(iter_cells(include_skipped=True))
    assert len(cells) == 40
    runnable = [c for c in cells if c[2]]
    skipped = [c for c in cells if not c[2]]
    # long_500k runs only for the two sub-quadratic archs
    assert len(runnable) == 32
    assert all(c[1] == "long_500k" for c in skipped)
    assert {c[0] for c in cells if c[1] == "long_500k" and c[2]} == {
        "rwkv6-7b", "recurrentgemma-9b"
    }
