"""Checkpoint roundtrip, fault-tolerant supervision, elastic restore."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore, save
from repro.configs import RunConfig, ShapeConfig, TrainConfig, get_model_config, reduced
from repro.data import SyntheticPipeline
from repro.runtime import init_state, make_train_step
from repro.runtime.fault import FailureInjector, StragglerMonitor, TrainSupervisor
# jax model/integration tier: excluded from the fast CI
# lane (scripts/check.sh), run by the `slow` CI job
pytestmark = pytest.mark.slow


def _tiny_run():
    cfg = reduced(get_model_config("smollm-135m"))
    return RunConfig(model=cfg, shape=ShapeConfig("t", "train", 32, 2),
                     train=TrainConfig(steps=50))


def test_checkpoint_roundtrip(tmp_path):
    state = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
             "b": {"c": jnp.ones((4,), jnp.bfloat16), "d": jnp.array(3)}}
    save(state, str(tmp_path), 7)
    assert latest_step(str(tmp_path)) == 7
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    restored, manifest = restore(str(tmp_path), 7, like)
    assert manifest["step"] == 7
    for l1, l2 in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(l1, np.float32),
                                      np.asarray(l2, np.float32))


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    save({"a": jnp.ones((2,))}, str(tmp_path), 1)
    with pytest.raises(ValueError):
        restore(str(tmp_path), 1, {"a": jax.ShapeDtypeStruct((3,), jnp.float32)})


def test_async_checkpoint(tmp_path):
    fut = save({"a": jnp.ones((8,))}, str(tmp_path), 2, blocking=False)
    fut.result()
    assert latest_step(str(tmp_path)) == 2


def test_supervisor_recovers_from_failures(tmp_path):
    run = _tiny_run()
    api, ctx, step = make_train_step(run, None)
    state = init_state(run, None, jax.random.PRNGKey(0))
    pipe = SyntheticPipeline(run.model, run.shape)
    jstep = jax.jit(step)

    # run to completion WITH two injected failures; checkpoint every 4 steps
    sup = TrainSupervisor(
        step_fn=jstep, pipeline=pipe, ckpt_dir=str(tmp_path), ckpt_every=4,
        injector=FailureInjector(fail_at_steps=(6, 11)), async_ckpt=False,
    )
    final, hist = sup.run(state, 16)
    executed = [h["step"] for h in hist]
    assert executed[-1] == 15
    # failure at 6 -> restart from ckpt@4 (replays 4,5); at 11 -> from 8
    assert executed.count(4) >= 2 or executed.count(5) >= 2
    assert int(final.opt.step) > 0

    # determinism: a failure-free run from the same seed reaches the same loss
    state2 = init_state(run, None, jax.random.PRNGKey(0))
    sup2 = TrainSupervisor(step_fn=jstep, pipeline=pipe, ckpt_dir=str(tmp_path) + "2",
                           ckpt_every=0, async_ckpt=False)
    final2, hist2 = sup2.run(state2, 16)
    assert hist[-1]["loss"] == pytest.approx(hist2[-1]["loss"], abs=1e-5)


def test_straggler_monitor():
    mon = StragglerMonitor(threshold=2.0)
    for i in range(10):
        assert not mon.observe(i, 0.1)
    assert mon.observe(10, 1.0)       # 10x slower -> flagged
    assert len(mon.events) == 1
    assert not mon.observe(11, 0.1)   # recovers


def test_elastic_restore_into_other_mesh(multidev):
    multidev(
        """
import jax, jax.numpy as jnp, numpy as np
from repro.configs import RunConfig, ShapeConfig, TrainConfig, MeshConfig, get_model_config, reduced
from repro.runtime import init_state
from repro.runtime.elastic import reshard_state, scale_plan
from repro.checkpoint import save, restore
from repro.runtime.train_loop import state_pspecs
from jax.sharding import NamedSharding, PartitionSpec as P

class M24(MeshConfig):
    @property
    def shape(self): return (2, 4)
    @property
    def axes(self): return ('data', 'model')

class M42(MeshConfig):
    @property
    def shape(self): return (4, 2)
    @property
    def axes(self): return ('data', 'model')

cfg = reduced(get_model_config('smollm-135m'))
run1 = RunConfig(model=cfg, shape=ShapeConfig('t','train',32,8), mesh=M24())
mesh1 = jax.make_mesh((2,4), ('data','model'))
state = init_state(run1, mesh1, jax.random.PRNGKey(0))
import tempfile, os

d = tempfile.mkdtemp()
save(state, d, 5)

# restore into a (4,2) mesh — elastic rescale
run2 = run1.replace(mesh=M42())
mesh2 = jax.make_mesh((4,2), ('data','model'))
specs = state_pspecs(run2, mesh2)
sh = jax.tree.map(lambda s: NamedSharding(mesh2, s), specs, is_leaf=lambda x: isinstance(x, P))
like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
restored, _ = restore(d, 5, like, shardings=sh)
for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
    np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
# in-memory reshard path
rs = reshard_state(state, run2, mesh2)
for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(rs)):
    np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
plan = scale_plan(2, 4, 32)
assert plan['new_per_replica'] == 8
print('ok')
"""
    )
