"""Packet-level reliable-multicast engine (core/packet.py): loss models,
NACK-aggregation/retransmission recovery, the kernels/bitmap.py packed-word
state, the chunk_reassembly delivery replay, and the analytic-vs-engine
cross-check oracle (at loss 0 the packet model must reproduce the fluid
engine's times). All stochastic tests pin their seeds — CI runs are
bit-reproducible."""
import numpy as np
import pytest

from repro.core import protocol
from repro.core.packet import (
    BernoulliLoss,
    GilbertElliottLoss,
    attach_loss,
    simulate_packet_broadcast,
    tree_paths,
)
from repro.core.simulator import (
    FabricParams,
    WorkerParams,
    simulate_allgather,
    simulate_broadcast,
)
from repro.core.topology import FatTree

FAB = FabricParams(jitter=0.0)
WK = WorkerParams(n_recv_workers=8)


# --------------------------------------------------------------- loss models


def test_bernoulli_rate_and_determinism():
    rng = np.random.default_rng(0)
    m = BernoulliLoss(0.05).fork(rng)
    drops = m.sample(200_000)
    assert abs(drops.mean() - 0.05) < 0.005
    m2 = BernoulliLoss(0.05).fork(np.random.default_rng(0))
    assert np.array_equal(m2.sample(1000),
                          BernoulliLoss(0.05).fork(
                              np.random.default_rng(0)).sample(1000))


def test_gilbert_elliott_mean_rate_and_burstiness():
    rate, burst = 0.02, 16.0
    tmpl = GilbertElliottLoss.from_rate(rate, mean_burst=burst)
    assert abs(tmpl.mean_rate - rate) < 1e-12
    m = tmpl.fork(np.random.default_rng(3))
    drops = m.sample(500_000)
    assert abs(drops.mean() - rate) < rate * 0.25
    # burstiness: mean run length of consecutive drops ~ mean_burst
    d = np.asarray(drops, dtype=np.int8)
    starts = np.sum((d[1:] == 1) & (d[:-1] == 0)) + int(d[0] == 1)
    mean_run = d.sum() / max(starts, 1)
    assert burst / 2 < mean_run < burst * 2, mean_run
    # i.i.d. at the same rate has runs ~ 1/(1-q) ~ 1
    b = BernoulliLoss(rate).fork(np.random.default_rng(3)).sample(500_000)
    bi = np.asarray(b, dtype=np.int8)
    bstarts = np.sum((bi[1:] == 1) & (bi[:-1] == 0)) + int(bi[0] == 1)
    assert bi.sum() / max(bstarts, 1) < 2.0


def test_gilbert_elliott_state_persists_across_calls():
    """Bursts straddle sample() boundaries (recovery rounds): the chain is
    one process, so two calls of n/2 equal one call of n under the same
    seed."""
    a = GilbertElliottLoss.from_rate(0.1, 4.0).fork(np.random.default_rng(7))
    b = GilbertElliottLoss.from_rate(0.1, 4.0).fork(np.random.default_rng(7))
    one = a.sample(1000)
    two = np.concatenate([b.sample(500), b.sample(500)])
    # identical rng stream, identical chain — allow the boundary truncation
    # to shift at most one sojourn's worth of packets
    assert np.array_equal(one[:400], two[:400])


# ----------------------------------------- zero-loss cross-check vs the fluid


@pytest.mark.parametrize("p", [4, 16, 64])
@pytest.mark.parametrize("n_bytes", [1 << 17, 1 << 20, 4 << 20])
def test_zero_loss_reproduces_fluid_broadcast(p, n_bytes):
    """Satellite cross-check: at loss 0 the packet engine and the fluid
    engine are the SAME timing model (identical injection, pool, handshake),
    across p and message sizes."""
    a = simulate_broadcast(p, n_bytes, FAB, WK, np.random.default_rng(0))
    b = simulate_broadcast(p, n_bytes, FAB, WK, np.random.default_rng(0),
                           fidelity="packet")
    assert b.time == pytest.approx(a.time, rel=1e-9)
    np.testing.assert_allclose(b.completion, a.completion, rtol=1e-9)
    assert b.recovered == 0 and not b.rounds and b.completed


@pytest.mark.parametrize("n_chains", [2, 4, 16])
def test_zero_loss_reproduces_fluid_allgather(n_chains):
    p, n = 16, 1 << 18
    a = simulate_allgather(p, n, FAB, WK, np.random.default_rng(0),
                           n_chains=n_chains)
    b = simulate_allgather(p, n, FAB, WK, np.random.default_rng(0),
                           n_chains=n_chains, fidelity="packet")
    assert b.time == pytest.approx(a.time, rel=1e-6)
    assert b.recovered == 0 and b.completed


def test_zero_loss_reproduces_fluid_routed():
    p, n = 16, 1 << 20
    topo = FatTree(k=8, n_hosts=p, b_host=FAB.b_link)
    a = simulate_broadcast(p, n, FAB, WK, np.random.default_rng(0),
                           topology=topo)
    b = simulate_broadcast(p, n, FAB, WK, np.random.default_rng(0),
                           topology=topo, fidelity="packet")
    assert b.time == pytest.approx(a.time, rel=1e-9)
    # same engine run, same switch-port byte counters
    assert a.link_bytes == pytest.approx(b.link_bytes)


def test_analytic_oracle_brackets_engine():
    """protocol.analytic_bcast_time is the closed-form cross-check of the
    engine-backed path (kept per the PR contract): lossless engine times
    land within 10% of the oracle across scale and size."""
    for p in (4, 16, 64):
        for n in (1 << 17, 4 << 20):
            t_eng = simulate_broadcast(p, n, FAB, WK,
                                       np.random.default_rng(0),
                                       fidelity="packet").time
            t_ana = protocol.analytic_bcast_time(
                p, n, FAB.b_link, FAB.latency,
                pool_rate=WK.n_recv_workers * WK.thread_tput)
            assert t_eng == pytest.approx(t_ana, rel=0.10), (p, n)


# ---------------------------------------------------------- lossy recovery


def test_loss_recovers_and_conserves():
    topo = FatTree(k=8, n_hosts=16, b_host=FAB.b_link)
    clean = simulate_broadcast(16, 1 << 20, FAB, WK,
                               np.random.default_rng(1), topology=topo,
                               fidelity="packet")
    topo = FatTree(k=8, n_hosts=16, b_host=FAB.b_link)
    lossy = simulate_broadcast(16, 1 << 20, FAB, WK,
                               np.random.default_rng(1), topology=topo,
                               fidelity="packet", loss=0.01)
    assert lossy.completed and lossy.recovered > 0 and lossy.rounds
    assert lossy.time > clean.time
    assert lossy.bytes_fast + lossy.bytes_recovery == lossy.bytes_total
    # recovery traffic rides the same fabric counters as the fast path
    assert sum(lossy.link_bytes.values()) > sum(clean.link_bytes.values())


def test_heavier_loss_slower_recovery():
    t = {}
    for q in (0.002, 0.2):
        t[q] = simulate_broadcast(16, 1 << 20, FAB, WK,
                                  np.random.default_rng(5),
                                  fidelity="packet", loss=q)
        assert t[q].completed
    assert t[0.2].time > t[0.002].time
    assert t[0.2].recovered > t[0.002].recovered


def test_bursty_loss_recovers():
    ge = GilbertElliottLoss.from_rate(0.05, mean_burst=32)
    r = simulate_broadcast(16, 1 << 20, FAB, WK, np.random.default_rng(2),
                           fidelity="packet", loss=ge)
    assert r.completed and r.recovered > 0
    assert r.bytes_fast + r.bytes_recovery == r.bytes_total


def test_nack_aggregation_one_root_message():
    """In-tree OR aggregation: the root DPA serves exactly ONE NACK per
    round regardless of how many receivers lost packets — the mechanism
    behind the constant-time recovery claim. The ablation serves one per
    nacker and can only be slower."""
    topo = FatTree(k=8, n_hosts=32, b_host=FAB.b_link)
    agg = simulate_broadcast(32, 1 << 20, FAB, WK, np.random.default_rng(4),
                            topology=topo, fidelity="packet", loss=0.02)
    assert agg.rounds and all(tr.root_nack_msgs == 1 for tr in agg.rounds)
    topo = FatTree(k=8, n_hosts=32, b_host=FAB.b_link)
    noagg = simulate_broadcast(32, 1 << 20, FAB, WK, np.random.default_rng(4),
                               topology=topo, fidelity="packet", loss=0.02,
                               aggregate_nacks=False)
    assert any(tr.root_nack_msgs > 1 for tr in noagg.rounds)
    assert all(a.root_nack_msgs <= b.root_nack_msgs for a, b in
               zip(agg.rounds, noagg.rounds))
    assert noagg.time >= agg.time - 1e-12


def test_upstream_drop_correlates_receivers():
    """A drop on a shared up-tree link must be missed by every receiver
    below it: arm ONLY the root's host->edge uplink with total loss of the
    first sample round and watch every leaf NACK."""
    p = 16
    topo = FatTree(k=8, n_hosts=p, b_host=FAB.b_link)
    rng = np.random.default_rng(0)
    n_armed = attach_loss(topo, BernoulliLoss(0.5), rng,
                          predicate=lambda name: name == "h0->e0.0")
    assert n_armed == 1
    r = simulate_broadcast(p, 1 << 20, FAB, WK, np.random.default_rng(0),
                           topology=topo, fidelity="packet")
    assert r.completed and r.rounds
    # every non-root leaf sits below the armed link -> all 15 NACK
    assert r.rounds[0].nack_leaves == p - 1


def test_allgather_packet_loss_routed():
    p, n = 16, 1 << 18
    topo = FatTree(k=8, n_hosts=p, b_host=FAB.b_link)
    clean = simulate_allgather(p, n, FAB, WK, np.random.default_rng(0),
                               n_chains=p, topology=topo, fidelity="packet")
    topo = FatTree(k=8, n_hosts=p, b_host=FAB.b_link)
    lossy = simulate_allgather(p, n, FAB, WK, np.random.default_rng(0),
                               n_chains=p, topology=topo, fidelity="packet",
                               loss=0.005)
    assert lossy.completed and lossy.recovered > 0
    assert lossy.time > clean.time
    assert lossy.bytes_fast + lossy.bytes_recovery == lossy.bytes_total


def test_seeded_runs_bit_identical():
    kw = dict(fidelity="packet", loss=0.01)
    a = simulate_broadcast(16, 1 << 20, FAB, WK, np.random.default_rng(9), **kw)
    b = simulate_broadcast(16, 1 << 20, FAB, WK, np.random.default_rng(9), **kw)
    assert a.time == b.time and a.recovered == b.recovered
    np.testing.assert_array_equal(a.completion, b.completion)


def test_recovery_time_log_bound_in_p():
    """The tentpole acceptance property at test scale: recovery time at a
    fixed 0.1% per-link loss grows no faster than the O(log p) envelope
    (benchmarks/paper_figs.protocol_loss_sweep measures the full curve)."""
    rec = {}
    for p in (16, 128):
        per = []
        for s in (0, 1, 2):
            topo = FatTree(k=16, n_hosts=p, b_host=FAB.b_link)
            r = simulate_broadcast(p, 1 << 20, FAB, WK,
                                   np.random.default_rng(s), topology=topo,
                                   fidelity="packet", loss=1e-3)
            assert r.completed
            per.append(r.phases.reliability)
        rec[p] = np.mean(per)
    bound = np.log2(128) / np.log2(16)
    assert rec[128] <= rec[16] * bound * 1.5, rec


# ------------------------------------------ packed bitmaps + reassembly replay


def test_bitmap_np_twins_match_pallas_kernels():
    from repro.kernels.bitmap import (bitmap_pack, bitmap_pack_np,
                                      bitmap_popcount, bitmap_popcount_np,
                                      bitmap_unpack_np)
    import jax.numpy as jnp

    rng = np.random.default_rng(0)
    flags = rng.integers(0, 2, 2048).astype(np.uint32)
    words_np = bitmap_pack_np(flags)
    words_pl = np.asarray(bitmap_pack(jnp.asarray(flags), interpret=True))
    np.testing.assert_array_equal(words_np, words_pl)
    assert bitmap_popcount_np(words_np) == int(
        bitmap_popcount(jnp.asarray(words_np), interpret=True))
    np.testing.assert_array_equal(bitmap_unpack_np(words_np).astype(np.uint32),
                                  flags)


def test_delivery_replay_through_chunk_reassembly():
    """The packet engine's arrival order drives the SAME datapath the DPA
    offloads: replay a lossy run's staging order through the
    kernels/chunk_reassembly.py scatter and recover the full buffer plus a
    complete bitmap."""
    from repro.kernels.chunk_reassembly import chunk_reassembly
    import jax.numpy as jnp

    mtu = 128
    fab = FabricParams(jitter=0.0, mtu=mtu)
    n_bytes = 64 * mtu
    r = simulate_packet_broadcast(
        8, n_bytes, fab, WK, np.random.default_rng(11), loss=0.05,
        collect_delivery=True)
    assert r.completed and r.recovered > 0
    buf = np.arange(n_bytes, dtype=np.uint8).tobytes()
    chunks = protocol.segment(buf, mtu)
    src = np.frombuffer(buf, np.uint8).reshape(len(chunks), mtu)
    leaf = max(r.delivery_order,
               key=lambda x: 0 if r.delivery_order[x].size == 64 else 1)
    order = r.delivery_order[leaf]
    assert sorted(order.tolist()) == list(range(64))     # exactly-once
    staging = src[order].astype(np.float32)
    user = np.zeros_like(src, dtype=np.float32)
    out, bitmap = chunk_reassembly(
        jnp.asarray(staging), jnp.asarray(order, jnp.int32),
        jnp.asarray(user), interpret=True)
    np.testing.assert_array_equal(np.asarray(out), src.astype(np.float32))
    assert int(np.asarray(bitmap).sum()) == 64


def test_tree_paths_cover_all_leaves():
    p = 32
    topo = FatTree(k=8, n_hosts=p, b_host=FAB.b_link)
    tree = topo.multicast_tree(0, list(range(p)))
    paths = tree_paths(tree, "h0", [f"h{i}" for i in range(1, p)])
    assert len(paths) == p - 1
    for name, path in paths.items():
        assert path[-1].dst == name            # ends at the leaf host
        assert path[0].src == "h0"             # starts at the root host
        for a, b in zip(path, path[1:]):       # contiguous chain
            assert a.dst == b.src


# --------------------------------------- cross-fidelity metamorphic ordering


@pytest.mark.parametrize("p", [4, 16])
@pytest.mark.parametrize("loss", [0.0, 1e-3, 1e-2])
@pytest.mark.parametrize("n_bytes", [1 << 17, 1 << 20])
def test_fidelity_refinement_ordering(p, loss, n_bytes):
    """Each fidelity layer only ADDS modeled cost, across a (p, loss, size)
    grid:

        analytic <= fluid <= packet(scalar-DPA) <= packet(event-DPA)

    The fluid leg runs lossless: its drop model recovers through the
    per-chunk fetch ring — a DIFFERENT protocol whose serial cost overtakes
    NACK-multicast recovery at high loss x size, so it is not a
    lower-fidelity view of the packet engine's recovery (DESIGN.md §3.1);
    the loss axis enters through the packet legs, whose lossy runs are also
    pinned against their own lossless runs."""
    ana = protocol.analytic_bcast_time(
        p, n_bytes, FAB.b_link, FAB.latency,
        pool_rate=WK.n_recv_workers * WK.thread_tput)
    fluid = simulate_broadcast(p, n_bytes, FAB, WK, np.random.default_rng(0))
    pkt_s = simulate_broadcast(p, n_bytes, FAB, WK, np.random.default_rng(0),
                               fidelity="packet", loss=loss)
    pkt_s0 = simulate_broadcast(p, n_bytes, FAB, WK, np.random.default_rng(0),
                                fidelity="packet")
    pkt_e = simulate_broadcast(p, n_bytes, FAB, WK, np.random.default_rng(0),
                               fidelity="packet", loss=loss,
                               dpa_fidelity="event")
    assert pkt_s.completed and pkt_e.completed
    assert ana <= fluid.time * (1.0 + 1e-12)
    assert fluid.time == pytest.approx(pkt_s0.time, rel=1e-9)  # loss-0 leg
    assert fluid.time <= pkt_s.time * (1.0 + 1e-12)
    assert pkt_s.time <= pkt_e.time * (1.0 + 1e-12)
    if loss > 0.0:
        assert pkt_s.time >= pkt_s0.time - 1e-15   # loss only adds time


def test_event_dpa_zero_cost_reproduces_packet_exactly():
    """Acceptance pin: with zero per-CQE cost (the infinite-thread /
    free-progress-engine limit) the event-DPA packet engine reproduces the
    scalar packet engine EXACTLY — same times, same completions, same
    recovery — across loss rates, scales, chains and a routed topology."""
    import math as _math

    from repro.core.dpa_engine import EventDpaParams

    wk_free = WorkerParams(n_recv_workers=8, thread_tput=_math.inf)
    for p, n, loss in [(4, 1 << 17, 0.0), (16, 1 << 20, 0.01),
                       (8, 1 << 18, 0.05)]:
        a = simulate_broadcast(p, n, FAB, wk_free, np.random.default_rng(3),
                               fidelity="packet", loss=loss)
        b = simulate_broadcast(p, n, FAB, wk_free, np.random.default_rng(3),
                               fidelity="packet", loss=loss,
                               dpa_fidelity="event",
                               dpa=EventDpaParams.zero_cost(8))
        assert b.time == a.time
        np.testing.assert_array_equal(b.completion, a.completion)
        assert (b.recovered, b.rnr_drops, b.bytes_fast) == (
            a.recovered, a.rnr_drops, a.bytes_fast)
    topo = FatTree(k=8, n_hosts=16, b_host=FAB.b_link)
    a = simulate_broadcast(16, 1 << 20, FAB, wk_free,
                           np.random.default_rng(1), topology=topo,
                           fidelity="packet", loss=0.01)
    topo = FatTree(k=8, n_hosts=16, b_host=FAB.b_link)
    b = simulate_broadcast(16, 1 << 20, FAB, wk_free,
                           np.random.default_rng(1), topology=topo,
                           fidelity="packet", loss=0.01,
                           dpa_fidelity="event",
                           dpa=EventDpaParams.zero_cost(8))
    assert b.time == a.time
    ag_a = simulate_allgather(8, 1 << 18, FAB, wk_free,
                              np.random.default_rng(0), n_chains=8,
                              fidelity="packet", loss=0.01)
    ag_b = simulate_allgather(8, 1 << 18, FAB, wk_free,
                              np.random.default_rng(0), n_chains=8,
                              fidelity="packet", loss=0.01,
                              dpa_fidelity="event",
                              dpa=EventDpaParams.zero_cost(8))
    assert ag_b.time == ag_a.time and ag_b.recovered == ag_a.recovered


def test_event_dpa_allgather_ordering_and_conservation():
    """The event DPA under the packet Allgather: chain roots' NACK service
    and retransmit posting steal receive cycles, so the event run can only
    be slower than the scalar run; byte conservation still holds."""
    a = simulate_allgather(8, 1 << 18, FAB, WK, np.random.default_rng(0),
                           n_chains=8, fidelity="packet", loss=0.01)
    b = simulate_allgather(8, 1 << 18, FAB, WK, np.random.default_rng(0),
                           n_chains=8, fidelity="packet", loss=0.01,
                           dpa_fidelity="event")
    assert b.completed and b.time >= a.time - 1e-15
    assert b.bytes_fast + b.bytes_recovery == b.bytes_total


# ------------------------------------------------ loss-model statefulness fuzz

try:
    import hypothesis.strategies as hyp_st
    from hypothesis import given as hyp_given, settings as hyp_settings
except ImportError:
    from _hypothesis_shim import (given as hyp_given,
                                  settings as hyp_settings,
                                  strategies as hyp_st)


@hyp_settings(max_examples=15, deadline=None)
@hyp_given(hyp_st.floats(0.02, 0.15), hyp_st.floats(1.5, 32.0),
           hyp_st.integers(0, 2**31 - 1))
def test_gilbert_elliott_chain_state_persists_across_replays(rate, burst,
                                                             seed):
    """Regression guard for PR 3's per-link statefulness: links armed via
    attach_loss keep ONE Gilbert-Elliott process each across simulator
    replays (REPRO_TEST_SEED salts the sampled parameter set). Pins: the
    armed model objects survive a run untouched in identity, their chain
    rng state ADVANCES (bursts straddle collectives), a fresh-armed
    same-seed fabric reproduces the first run bit-exactly, and a second
    replay on the persistent fabric sees different drops (unless neither
    run dropped anything)."""
    template = GilbertElliottLoss.from_rate(rate, mean_burst=burst)
    p, n = 8, 1 << 18

    def armed_tree():
        topo = FatTree(k=8, n_hosts=p, b_host=FAB.b_link)
        n_armed = attach_loss(topo, template, np.random.default_rng(11))
        assert n_armed == len(topo.links())
        return topo

    topo = armed_tree()
    models = {name: link.loss for name, link in topo.links().items()}
    states0 = {name: repr(m._rng.bit_generator.state)
               for name, m in models.items()}
    r1 = simulate_broadcast(p, n, FAB, WK, np.random.default_rng(seed),
                            topology=topo, fidelity="packet")
    assert r1.completed
    # identity: the run consumed the ARMED processes, it did not re-fork
    for name, link in topo.links().items():
        assert link.loss is models[name], name
    advanced = [name for name, m in models.items()
                if repr(m._rng.bit_generator.state) != states0[name]]
    assert advanced, "no armed chain advanced — loss state was not consumed"
    # a fresh fabric armed with the same template+seed replays run 1 exactly
    r1b = simulate_broadcast(p, n, FAB, WK, np.random.default_rng(seed),
                             topology=armed_tree(), fidelity="packet")
    assert r1b.time == r1.time and r1b.recovered == r1.recovered
    np.testing.assert_array_equal(r1b.completion, r1.completion)
    # the persistent fabric's chains kept moving: a second replay diverges
    r2 = simulate_broadcast(p, n, FAB, WK, np.random.default_rng(seed),
                            topology=topo, fidelity="packet")
    if r1.recovered or r2.recovered:
        assert (r2.time != r1.time) or (r2.recovered != r1.recovered), (
            "second replay reproduced the first — chain state was reset")


def test_packet_hot_path_is_jax_free():
    """The packet engine's wire-format bitmaps come from the jax-free
    kernels/bitmap_np.py twins: importing the simulator/protocol/packet
    stack must never pull in jax (the CI smoke benchmarks depend on it).
    Runs BOTH engines end to end — the vectorized default's batched
    bitmap/pool imports (and the PEP 562 lazy kernels re-exports) must not
    regress the jax-free guarantee either."""
    import subprocess
    import sys

    code = (
        "import sys\n"
        "import numpy as np\n"
        "from repro.core.packet import (simulate_packet_broadcast,\n"
        "                               simulate_packet_allgather)\n"
        "from repro.core.engine import FabricParams, WorkerParams\n"
        "import repro.core.protocol, repro.kernels.bitmap_np\n"
        "fab, wk = FabricParams(), WorkerParams(n_recv_workers=8)\n"
        "for eng in ('vectorized', 'reference'):\n"
        "    r = simulate_packet_broadcast(8, 1 << 16, fab, wk,\n"
        "                                  np.random.default_rng(0),\n"
        "                                  loss=0.02, engine=eng)\n"
        "    assert r.completed\n"
        "    a = simulate_packet_allgather(4, 1 << 15, fab, wk,\n"
        "                                  np.random.default_rng(0), 2,\n"
        "                                  loss=0.02, engine=eng)\n"
        "    assert a.completed\n"
        "assert 'jax' not in sys.modules, 'jax leaked into the hot path'\n")
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True)
    assert res.returncode == 0, res.stderr[-2000:]
