"""Incremental max-min solver (core/engine.Engine): property suite.

The engine re-solves only the dirty connected component of the flow-link
graph on every arrival/completion (Engine._current_rates). This suite
pins the two contracts that make that safe:

  - EQUALITY: on hypothesis-sampled flow/link DAGs with random
    arrival/completion interleavings, the incremental engine's rate
    allocation is identical RATE FOR RATE (every progress segment, every
    completion time, exact float equality) to the pre-incremental global
    progressive-filling oracle (``ENGINE_MAXMIN=reference``). Disjoint
    components share no links, so per-component progressive filling runs
    the identical float ops in the identical order as the global solve.
  - LOCALITY: events in one component never trigger solver work in
    another — pinned via the engine's ``maxmin_flows_solved`` telemetry.
"""
try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:  # offline: seeded-random shim (tests/_hypothesis_shim.py)
    from _hypothesis_shim import given, settings, strategies as st
import pytest

from repro.core.engine import Engine

# capacities drawn from a small irrational-ish palette: shares collide in
# interesting ways (equal bottleneck shares) without being hand-tuned ties
_CAPS = (1.0, 2.0, 3.5, 5.0, 8.0, 10.0, 16.0)


@st.composite
def _scenario(draw):
    """(n_links, [(route_link_ids, n_bytes, t_start, rate_cap)]) — random
    flow/link DAGs: several disjoint-or-overlapping routes, batched and
    staggered start times (duplicate timestamps exercise same-event
    batching), occasional rate caps."""
    n_links = draw(st.integers(2, 8))
    caps = [draw(st.sampled_from(_CAPS)) for _ in range(n_links)]
    n_flows = draw(st.integers(1, 12))
    # a handful of start times, reused across flows so arrivals batch
    starts = [draw(st.floats(0.0, 3.0)) for _ in range(4)]
    flows = []
    for _ in range(n_flows):
        r_len = draw(st.integers(1, min(3, n_links)))
        first = draw(st.integers(0, n_links - 1))
        route = [first]
        while len(route) < r_len:
            nxt = draw(st.integers(0, n_links - 1))
            if nxt not in route:
                route.append(nxt)
        n_bytes = draw(st.floats(0.5, 20.0))
        t_start = starts[draw(st.integers(0, len(starts) - 1))]
        cap = draw(st.sampled_from((None, None, None, 1.5, 4.0)))
        flows.append((tuple(route), n_bytes, t_start, cap))
    return caps, flows


def _run(caps, flows, mode):
    eng = Engine()
    eng._maxmin_mode = mode
    links = [eng.add_link(f"l{i}", c) for i, c in enumerate(caps)]
    out = []
    for route, n_bytes, t_start, cap in flows:
        out.append(eng.submit([links[i] for i in route], n_bytes,
                              t_start=t_start, rate_cap=cap))
    eng.run()
    return eng, out


@settings(max_examples=40, deadline=None)
@given(_scenario())
def test_incremental_allocation_identical_to_global_oracle(scenario):
    caps, flows = scenario
    _, ref = _run(caps, flows, "reference")
    _, inc = _run(caps, flows, "incremental")
    for fr, fi in zip(ref, inc):
        assert fi.t_end == fr.t_end          # exact: same floats, same order
        assert fi.segments == fr.segments    # rate for rate, segment for
        #                                      segment — not just end times


@settings(max_examples=15, deadline=None)
@given(_scenario(), st.floats(0.1, 5.0))
def test_incremental_interleaved_advance_to(scenario, t_cut):
    """Same contract under partial advancement (the training-run drivers
    call advance_to between submissions)."""
    caps, flows = scenario
    engines = {}
    for mode in ("reference", "incremental"):
        eng = Engine()
        eng._maxmin_mode = mode
        links = [eng.add_link(f"l{i}", c) for i, c in enumerate(caps)]
        fs = []
        mid = len(flows) // 2
        for route, n_bytes, t_start, cap in flows[:mid]:
            fs.append(eng.submit([links[i] for i in route], n_bytes,
                                 t_start=t_start, rate_cap=cap))
        eng.advance_to(t_cut)
        for route, n_bytes, t_start, cap in flows[mid:]:
            fs.append(eng.submit([links[i] for i in route], n_bytes,
                                 t_start=max(t_start, eng.now),
                                 rate_cap=cap))
        eng.run()
        engines[mode] = (eng, fs)
    (er, fr), (ei, fi) = engines["reference"], engines["incremental"]
    assert ei.now == er.now
    for a, b in zip(fr, fi):
        assert b.t_end == a.t_end and b.segments == a.segments


def test_component_locality_counters():
    """Arrivals in one component must not re-solve the other: a long flow
    on an isolated link is solved exactly once (its own arrival batch)
    while a train of flows churns a disjoint link."""
    eng = Engine()
    la = eng.add_link("a", 1.0)
    lb = eng.add_link("b", 1.0)
    eng.submit(la, 100.0, t_start=0.0)             # the isolated long flow
    for k in range(5):                             # churn on b: arrivals at
        eng.submit(lb, 1.0, t_start=float(k))      # t=0..4, finishes between
    eng.run()
    # t=0 batch: both arrivals share the batch -> one solve of 2 flows (the
    # components are solved together only because they went dirty together).
    # Every later b-event (4 arrivals + 5 completions, some coinciding)
    # re-solves ONLY b's 1-2 flows; flow a is never revisited until its own
    # completion (solving an emptied component is skipped entirely).
    assert eng.maxmin_solves <= 10
    assert eng.maxmin_flows_solved <= 2 + 2 * 9
    # the reference mode re-solves flow a on every event
    ref = Engine()
    ref._maxmin_mode = "reference"
    la = ref.add_link("a", 1.0)
    lb = ref.add_link("b", 1.0)
    ref.submit(la, 100.0, t_start=0.0)
    for k in range(5):
        ref.submit(lb, 1.0, t_start=float(k))
    ref.run()
    assert ref.maxmin_flows_solved > eng.maxmin_flows_solved


def test_component_bfs_respects_active_order():
    """The component is returned in _active order — progressive filling
    must visit flows in the same relative order as the global solve."""
    eng = Engine()
    l1 = eng.add_link("x", 2.0)
    l2 = eng.add_link("y", 2.0)
    f1 = eng.submit([l1], 4.0, t_start=0.0)
    f2 = eng.submit([l1, l2], 4.0, t_start=0.0)
    f3 = eng.submit([l2], 4.0, t_start=0.0)
    eng.advance_to(0.5)                            # all active, one component
    comp = eng._component([l2])
    assert comp == [f1, f2, f3]                    # via shared links, ordered


def test_engine_maxmin_env_wiring(monkeypatch):
    monkeypatch.delenv("ENGINE_MAXMIN", raising=False)
    assert Engine()._maxmin_mode == "incremental"
    monkeypatch.setenv("ENGINE_MAXMIN", "reference")
    assert Engine()._maxmin_mode == "reference"
    monkeypatch.setenv("ENGINE_MAXMIN", "bogus")
    with pytest.raises(AssertionError):
        Engine()
