"""simulate_training_run (core/train_sim.py): the compute+comm co-sim is
pinned three ways — the engine's heterogeneous ``layers=`` path is
bit-exact the legacy uniform path on identical profiles; the degenerate
mix (pp=1, grad_accum=1) reproduces engine.simulate_fsdp_step bit-exact;
and the three fidelities keep their ordering (analytic <= fluid <= packet)
on abstract and routed fabrics. MFU stays in (0, 1] and never improves
under loss; the pipeline composition, the searcher hook and the launch
facade each get a functional pin."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline: seeded-random shim (tests/_hypothesis_shim.py)
    from _hypothesis_shim import given, settings, strategies as st

from repro.configs.base import reduced
from repro.configs.registry import get_model_config, training_sweep_archs
from repro.core import train_sim
from repro.core.engine import (FabricParams, LayerProfile, WorkerParams,
                               simulate_fsdp_step)
from repro.core.topology import FatTree, IslandFatTree, Torus2D
from repro.core.train_sim import (TPU_V5E, derive_layer_profiles,
                                  make_fabric, simulate_training_run)

FAB = FabricParams(jitter=0.0)
WK = WorkerParams(n_recv_workers=8)
MODEL = "smollm-135m"


# ----------------------------- engine layers= generalization (bit-exact)


@pytest.mark.parametrize("policy", ["naive", "mcast", "split"])
@pytest.mark.parametrize("routed", [False, True], ids=["abstract", "routed"])
def test_uniform_layers_bit_exact_vs_legacy(policy, routed):
    """A uniform ``layers=`` profile must reproduce the legacy
    (n_layers, layer_bytes, tokens/flops) parameterization bit-exact —
    the heterogeneous generalization cannot move the fsdp.* baselines."""
    lb, p, n = 256e6, 16, 6
    fwd = 2.0 * (lb / 2) * 4096 / 200e12
    prof = [LayerProfile(fwd, 2.0 * fwd, lb)] * n
    topo = FatTree(k=8, n_hosts=16, oversubscription=4.0) if routed else None
    kw = dict(p=p, fabric=FAB, policy=policy, topology=topo,
              hosts=range(p) if routed else None)
    legacy = simulate_fsdp_step(n_layers=n, layer_bytes=lb, **kw)
    hetero = simulate_fsdp_step(layers=prof, **kw)
    assert hetero.step_time == legacy.step_time
    assert hetero.bubble_fraction == legacy.bubble_fraction
    assert hetero.phase_times == legacy.phase_times
    assert hetero.ag_bytes == legacy.ag_bytes
    assert hetero.rs_bytes == legacy.rs_bytes


def test_heterogeneous_layers_shift_the_timeline():
    """Skewed per-layer volumes must actually matter: making one layer 4x
    heavier (compute AND bytes) is slower than the uniform average."""
    lb, p = 128e6, 8
    fwd = 1e-3
    uniform = [LayerProfile(fwd, 2 * fwd, lb)] * 4
    skewed = [LayerProfile(fwd / 2, fwd, lb / 2)] * 3 + \
        [LayerProfile(fwd * 2.5, 5 * fwd, lb * 2.5)]
    assert sum(l.layer_bytes for l in skewed) == sum(l.layer_bytes
                                                     for l in uniform)
    tu = simulate_fsdp_step(layers=uniform, p=p, fabric=FAB, policy="split")
    ts = simulate_fsdp_step(layers=skewed, p=p, fabric=FAB, policy="split")
    assert ts.step_time > tu.step_time


# -------------------------------------------- degenerate cases, bit-exact


def test_degenerate_mix_matches_simulate_fsdp_step_bit_exact():
    """pp=1, grad_accum=1: the co-sim IS one engine step on the derived
    profiles — bit-exact, fluid and analytic alike."""
    prof = derive_layer_profiles(MODEL, dp=16)
    for policy in ("naive", "split"):
        r = simulate_training_run(MODEL, n_hosts=16, policy=policy,
                                  fabric=FAB)
        d = simulate_fsdp_step(layers=prof, p=16, fabric=FAB, policy=policy)
        assert r.step_time == d.step_time
        assert r.micro_time == d.step_time
        assert r.compute_time == d.compute_time
        assert r.bubble_fraction == d.bubble_fraction
        assert r.fsdp.step_time == d.step_time


def test_single_layer_model_matches_engine_bit_exact():
    """A 1-layer model is the smallest degenerate case: one AG prefetch,
    one backward re-gather, one RS."""
    cfg = reduced(get_model_config(MODEL), layers=1)
    prof = derive_layer_profiles(cfg, dp=8)
    assert len(prof) == 1
    r = simulate_training_run(cfg, n_hosts=8, policy="split", fabric=FAB)
    d = simulate_fsdp_step(layers=prof, p=8, fabric=FAB, policy="split")
    assert r.step_time == d.step_time


def test_single_host_is_pure_compute():
    """dp=1: nothing on the wire; every fidelity collapses to the compute
    timeline and there is no engine result."""
    prof = derive_layer_profiles(MODEL, dp=1)
    want = sum(p.fwd_s for p in prof) + sum(p.bwd_s for p in prof)
    for fid in ("analytic", "fluid", "packet"):
        r = simulate_training_run(MODEL, n_hosts=1, fidelity=fid, fabric=FAB)
        assert r.step_time == want
        assert r.fsdp is None
        assert r.bubble_fraction == 0.0
        assert 0.0 < r.mfu <= 1.0


# --------------------------------------------------- fidelity ordering


@pytest.mark.parametrize("policy", ["naive", "mcast", "split"])
@pytest.mark.parametrize("topo_fn", [
    lambda: None,
    lambda: FatTree(k=8, n_hosts=16, oversubscription=4.0),
    lambda: IslandFatTree(4, 16, island_size=4),
    lambda: Torus2D(4, 4),
], ids=["abstract", "fattree", "island", "torus"])
def test_fidelity_ordering(policy, topo_fn):
    """analytic <= fluid <= packet per (policy, fabric) — the same
    contract the collective IR keeps (test_sched_search)."""
    kw = dict(n_hosts=16, policy=policy, fabric=FAB, workers=WK)
    a = simulate_training_run(MODEL, fidelity="analytic",
                              topology=topo_fn(), **kw)
    f = simulate_training_run(MODEL, fidelity="fluid",
                              topology=topo_fn(), **kw)
    p = simulate_training_run(MODEL, fidelity="packet", loss=0.01,
                              rng=np.random.default_rng(0),
                              topology=topo_fn(), **kw)
    assert a.step_time <= f.step_time + 1e-12 <= p.step_time + 1e-9
    for r in (a, f, p):
        assert 0.0 < r.mfu <= 1.0
        assert 0.0 <= r.bubble_fraction < 1.0


@pytest.mark.parametrize("arch", training_sweep_archs())
def test_sweep_models_all_run(arch):
    """Every sweep model x a host-count pair, end-to-end on the abstract
    fabric: times scale down with hosts, MFU stays physical."""
    lo = simulate_training_run(arch, n_hosts=16, fabric=FAB)
    hi = simulate_training_run(arch, n_hosts=64, fabric=FAB)
    assert hi.step_time < lo.step_time
    assert 0.0 < hi.mfu <= 1.0 and 0.0 < lo.mfu <= 1.0
    assert lo.n_devices == 16 and hi.n_devices == 64


# ------------------------------------------------ MFU under loss (property)


@settings(max_examples=15, deadline=None)
@given(st.floats(min_value=0.0, max_value=0.02),
       st.floats(min_value=0.0, max_value=0.02))
def test_mfu_monotone_non_increasing_in_loss(q1, q2):
    """More loss can only slow the step: MFU(q_hi) <= MFU(q_lo), and both
    stay in (0, 1]. The naive policy's RC-goodput overlay is deterministic,
    so the property is exact, not statistical."""
    lo, hi = sorted((q1, q2))

    def mfu(q):
        if q == 0.0:
            return simulate_training_run(MODEL, n_hosts=8, policy="naive",
                                         fabric=FAB).mfu
        return simulate_training_run(MODEL, n_hosts=8, policy="naive",
                                     fidelity="packet", loss=q,
                                     fabric=FAB).mfu
    m_lo, m_hi = mfu(lo), mfu(hi)
    assert 0.0 < m_hi <= m_lo <= 1.0


# --------------------------------------------- pipeline / search / facade


def test_pipeline_composition():
    ga, pp = 4, 2
    r = simulate_training_run(MODEL, n_hosts=16, pp=pp, grad_accum=ga,
                              fabric=FAB)
    assert r.dp == 8
    assert r.step_time == (ga + pp - 1) * r.micro_time
    assert r.pipeline_bubble_fraction == (pp - 1) / (ga + pp - 1)
    # the simulated slice is the compute-heaviest contiguous stage
    prof = r.layer_profiles
    per = -(-len(prof) // pp)
    spans = [(lo, min(lo + per, len(prof)))
             for lo in range(0, len(prof), per)]
    heaviest = max(spans, key=lambda sp: sum(p.fwd_s + p.bwd_s
                                             for p in prof[sp[0]:sp[1]]))
    assert r.stage_span == heaviest
    # more microbatches amortize the pipeline bubble
    r2 = simulate_training_run(MODEL, n_hosts=16, pp=pp, grad_accum=16,
                               fabric=FAB)
    assert r2.pipeline_bubble_fraction < r.pipeline_bubble_fraction


def test_layer_profiles_are_heterogeneous():
    """The embedding/head placement must produce real volume skew — the
    whole point of the per-layer generalization."""
    prof = derive_layer_profiles("yi-9b", dp=16)
    body = prof[1:-1]
    assert prof[0].layer_bytes > body[0].layer_bytes      # + embedding
    assert prof[-1].layer_bytes > body[0].layer_bytes     # + LM head
    assert prof[-1].fwd_s > body[0].fwd_s                 # head FLOPs
    assert len({p.layer_bytes for p in body}) == 1        # uniform trunk


def test_search_hook_attaches_search_result():
    r = simulate_training_run(MODEL, n_hosts=8, fabric=FAB, search=True)
    assert r.searched is not None
    assert r.searched.winner_time > 0
    assert r.searched_step_time is not None and r.searched_step_time > 0


def test_make_fabric_specs():
    assert make_fabric("abstract", 16) is None and make_fabric(None, 4) is None
    ft = make_fabric("fattree", 16)
    assert isinstance(ft, FatTree) and ft.n_hosts == 16 and ft.k == 4
    isl = make_fabric("island", 64, island_size=8)
    assert isinstance(isl, IslandFatTree) and isl.island_size == 8
    t = make_fabric("torus", 32)
    assert isinstance(t, Torus2D) and t.nx * t.ny == 32
    with pytest.raises(AssertionError):
        make_fabric("torus", 24)          # not a power of two
    with pytest.raises(ValueError):
        make_fabric("dragonfly", 16)


def test_launch_facade():
    from repro.launch import simulate_training_run as launch_sim

    r = launch_sim(MODEL, n_hosts=16, fabric="fattree", fabric_params=FAB)
    assert 0.0 < r.mfu <= 1.0
    with pytest.raises(TypeError):
        launch_sim(MODEL, n_hosts=16, topology=FatTree(k=4, n_hosts=16))


def test_split_beats_naive_mfu_on_oversubscribed_fabric():
    """The paper's direction-split schedule must win where it matters: at
    oversubscription >= 2 the naive ring collides with itself on the thin
    tier while AG_mc+RS_inc stream both directions. Gated as a train.*
    benchmark ratio (benchmarks/paper_figs.training_run_sweep)."""
    topo = lambda: FatTree(k=8, n_hosts=16, oversubscription=4.0)  # noqa: E731
    naive = simulate_training_run(MODEL, n_hosts=16, policy="naive",
                                  topology=topo(), fabric=FAB)
    split = simulate_training_run(MODEL, n_hosts=16, policy="split",
                                  topology=topo(), fabric=FAB)
    assert split.mfu > naive.mfu
    assert split.step_time < naive.step_time


def test_chip_constants_scale_compute():
    """Halving peak FLOPs cannot speed anything up, and the default chip
    is the roofline's TPU v5e."""
    slow = train_sim.ChipConstants(name="half", peak_flops=TPU_V5E.peak_flops / 2,
                                   hbm_bw=TPU_V5E.hbm_bw)
    r_fast = simulate_training_run(MODEL, n_hosts=16, fabric=FAB)
    r_slow = simulate_training_run(MODEL, n_hosts=16, fabric=FAB, chip=slow)
    assert r_slow.step_time > r_fast.step_time
    assert TPU_V5E.peak_flops == 197e12 and TPU_V5E.hbm_bw == 819e9
