"""End-to-end driver: train the REAL smollm-135m (~135M params) with the full
stack — synthetic data pipeline, FSDP-capable train step, AdamW, checkpointing
and fault-tolerant supervision — for a few hundred steps.

On this 1-core CPU container a (batch=2, seq=64) step is ~2-4 s, so 200 steps
is ~10 min; on real hardware use --batch/--seq/--steps at will.

    PYTHONPATH=src python examples/train_100m.py --steps 200
"""
import argparse

import jax

from repro.configs import RunConfig, ShapeConfig, TrainConfig, get_model_config
from repro.data import SyntheticPipeline
from repro.runtime import init_state, make_train_step
from repro.runtime.fault import StragglerMonitor, TrainSupervisor


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    model = get_model_config("smollm-135m")   # the real ~135M-param config
    run = RunConfig(
        model=model,
        shape=ShapeConfig("t", "train", args.seq, args.batch),
        train=TrainConfig(steps=args.steps, learning_rate=3e-4, warmup_steps=20,
                          remat="none"),
    )
    api, ctx, step = make_train_step(run, None)
    state = init_state(run, None, jax.random.PRNGKey(0))
    n_params = sum(l.size for l in jax.tree.leaves(state.params))
    print(f"[100m] smollm-135m: {n_params/1e6:.1f}M params, "
          f"B={args.batch} S={args.seq}, {args.steps} steps")

    pipe = SyntheticPipeline(model, run.shape)
    sup = TrainSupervisor(
        step_fn=jax.jit(step), pipeline=pipe, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, monitor=StragglerMonitor(threshold=4.0),
    )
    state, hist = sup.run(state, args.steps)
    first = sum(h["loss"] for h in hist[:10]) / max(len(hist[:10]), 1)
    last = sum(h["loss"] for h in hist[-10:]) / max(len(hist[-10:]), 1)
    for h in hist:
        if h["step"] % 25 == 0 or h["step"] == args.steps - 1:
            print(f"step {h['step']:4d}  loss {h['loss']:.4f}  dt {h['dt']:.2f}s")
    print(f"[100m] mean loss first-10 {first:.4f} -> last-10 {last:.4f} "
          f"(descended: {last < first})")


if __name__ == "__main__":
    main()
