import os
import sys
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

"""FSDP training with the paper's collectives on a (data=2, model=4) mesh.

Runs the same step with fsdp_mode = xla (GSPMD-inserted all-gathers) and
fsdp_mode = mcast (explicit bidirectional-ring broadcast-composed gathers,
core/collectives.py) and verifies they produce identical numerics — the
schedule is exchanged underneath an unchanged model.

    python examples/fsdp_mcast_train.py        (sets 8 fake CPU devices itself)
"""
import dataclasses  # noqa: E402

import jax  # noqa: E402

from repro.configs import (CollectiveConfig, MeshConfig, RunConfig, ShapeConfig,  # noqa: E402
                           TrainConfig, get_model_config, reduced)
from repro.data import SyntheticPipeline  # noqa: E402
from repro.runtime import init_state  # noqa: E402
from repro.runtime.train_loop import jit_train_step  # noqa: E402


class DemoMesh(MeshConfig):
    @property
    def shape(self):
        return (2, 4)

    @property
    def axes(self):
        return ("data", "model")


def main():
    model = reduced(get_model_config("yi-9b"))
    results = {}
    for mode in ("xla", "mcast", "mcast_bcast"):
        run = RunConfig(
            model=model,
            shape=ShapeConfig("t", "train", 128, 8),
            mesh=DemoMesh(),
            train=TrainConfig(steps=5, learning_rate=1e-2),
            collective=CollectiveConfig(fsdp_mode=mode, n_chains=2),
        )
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        api, jstep = jit_train_step(run, mesh)
        state = init_state(run, mesh, jax.random.PRNGKey(0))
        pipe = SyntheticPipeline(model, run.shape)
        for i in range(5):
            state, m = jstep(state, pipe.next_batch(i))
        results[mode] = float(m["loss"])
        print(f"fsdp_mode={mode:12s} step-5 loss = {results[mode]:.6f}")
    base = results["xla"]
    for mode, loss in results.items():
        assert abs(loss - base) < 1e-5, (mode, loss, base)
    print("all FSDP modes numerically identical — the paper's schedule is a "
          "drop-in replacement for the XLA collectives")


if __name__ == "__main__":
    main()
