import os
import sys
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

"""FSDP training with the paper's collectives on a (data=2, model=4) mesh.

Runs the same step with fsdp_mode = xla (GSPMD-inserted all-gathers) and
fsdp_mode = mcast (explicit bidirectional-ring broadcast-composed gathers,
core/collectives.py) and verifies they produce identical numerics — the
schedule is exchanged underneath an unchanged model.

    python examples/fsdp_mcast_train.py        (sets 8 fake CPU devices itself)
"""
import dataclasses  # noqa: E402

import jax  # noqa: E402

from repro.configs import (CollectiveConfig, MeshConfig, RunConfig, ShapeConfig,  # noqa: E402
                           TrainConfig, get_model_config, reduced)
from repro.data import SyntheticPipeline  # noqa: E402
from repro.runtime import init_state  # noqa: E402
from repro.runtime.train_loop import jit_train_step  # noqa: E402


class DemoMesh(MeshConfig):
    @property
    def shape(self):
        return (2, 4)

    @property
    def axes(self):
        return ("data", "model")


def contention_report(model_name: str = "yi-9b") -> None:
    """The motivating scenario in numbers: simulate one FSDP step of the full
    (non-reduced) model with interleaved AG/RS under the three link policies
    (core/engine.py) and report the pipeline-bubble reduction the multicast
    schedule and direction split buy."""
    from repro.core.engine import FSDP_POLICIES, simulate_fsdp_step

    model = get_model_config(model_name)
    print(f"\nsimulated FSDP-step injection contention — {model_name}, "
          f"P=16, 200 Gbit/s NIC:")
    results = {
        pol: simulate_fsdp_step(model, p=16, policy=pol)
        for pol in FSDP_POLICIES
    }
    for pol, r in results.items():
        print(f"  policy={pol:6s} step={r.step_time*1e3:8.2f} ms  "
              f"bubble_fraction={r.bubble_fraction:.3f}  "
              f"link_util={ {k: round(v, 2) for k, v in r.link_utilization.items()} }")
    naive, split = results["naive"], results["split"]
    print(f"  direction split removes "
          f"{(1 - split.step_time / naive.step_time) * 100:.0f}% of step time "
          f"vs the naive shared link")
    assert split.bubble_fraction < naive.bubble_fraction

    # the same step with the ranks placed on a real fat-tree: the policies
    # now differ by routed traffic (trees vs rings on shared fabric links)
    from repro.core.topology import FatTree

    topo = FatTree(k=8, n_hosts=16)
    routed = {
        pol: simulate_fsdp_step(model, p=16, policy=pol, topology=topo)
        for pol in FSDP_POLICIES
    }
    print("  routed on a k=8 fat-tree:", "  ".join(
        f"{pol}={r.step_time*1e3:.1f}ms" for pol, r in routed.items()))
    assert routed["split"].step_time <= routed["naive"].step_time + 1e-12


def main():
    model = reduced(get_model_config("yi-9b"))
    results = {}
    for mode in ("xla", "mcast", "mcast_bcast"):
        run = RunConfig(
            model=model,
            shape=ShapeConfig("t", "train", 128, 8),
            mesh=DemoMesh(),
            train=TrainConfig(steps=5, learning_rate=1e-2),
            collective=CollectiveConfig(fsdp_mode=mode, n_chains=2),
        )
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        api, jstep = jit_train_step(run, mesh)
        state = init_state(run, mesh, jax.random.PRNGKey(0))
        pipe = SyntheticPipeline(model, run.shape)
        for i in range(5):
            state, m = jstep(state, pipe.next_batch(i))
        results[mode] = float(m["loss"])
        print(f"fsdp_mode={mode:12s} step-5 loss = {results[mode]:.6f}")
    base = results["xla"]
    for mode, loss in results.items():
        assert abs(loss - base) < 1e-5, (mode, loss, base)
    print("all FSDP modes numerically identical — the paper's schedule is a "
          "drop-in replacement for the XLA collectives")
    contention_report()


if __name__ == "__main__":
    main()
