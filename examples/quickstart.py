"""Quickstart: build a model, train a few steps, then prefill+decode — CPU, <1 min.

    PYTHONPATH=src python examples/quickstart.py [--arch smollm-135m]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import RunConfig, ShapeConfig, TrainConfig, get_model_config, reduced
from repro.data import SyntheticPipeline
from repro.runtime import init_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=20)
    args = ap.parse_args()

    model = reduced(get_model_config(args.arch))  # tiny same-family variant
    run = RunConfig(
        model=model,
        shape=ShapeConfig("t", "train", 128, 8),
        train=TrainConfig(steps=args.steps, learning_rate=1e-2, warmup_steps=2),
    )
    n_params = sum(l.size for l in jax.tree.leaves(
        init_state(run, None, jax.random.PRNGKey(0)).params))
    print(f"model: {model.name} ({model.family}), {n_params:,} params")

    api, ctx, step = make_train_step(run, None)
    state = init_state(run, None, jax.random.PRNGKey(0))
    pipe = SyntheticPipeline(model, run.shape)
    jstep = jax.jit(step)
    t0 = time.time()
    for i in range(args.steps):
        state, m = jstep(state, pipe.next_batch(i))
        if i % 5 == 0 or i == args.steps - 1:
            print(f"step {i:3d}  loss {float(m['loss']):.4f}  "
                  f"lr {float(m['lr']):.2e}")
    print(f"trained {args.steps} steps in {time.time()-t0:.1f}s")

    # prefill + greedy decode a few tokens
    from repro.models import make_dummy_batch

    pshape = ShapeConfig("p", "prefill", 32, 2)
    batch = make_dummy_batch(model, pshape, jax.random.PRNGKey(1))
    logits, _ = jax.jit(api.prefill_fn)(state.params, batch)
    cache = api.init_cache(2, 48)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    decode = jax.jit(api.decode_fn)
    out = [int(tok[0])]
    for t in range(8):
        lg, cache = decode(state.params, cache, tok, jnp.full((2,), t, jnp.int32))
        tok = jnp.argmax(lg, -1).astype(jnp.int32)
        out.append(int(tok[0]))
    print("greedy continuation token ids:", out)


if __name__ == "__main__":
    main()
