"""Route-aware fabric engine tour: what per-NIC models cannot see.

1. Fig. 2/12 on the routed engine: the P2P ring vs multicast-composition
   Allgather, timing AND switch-port bytes from the same engine run.
2. FSDP policies as routed traffic on a fat-tree (naive / mcast / split).
3. Two FSDP jobs on disjoint hosts sharing the fabric core: isolated at
   full bisection, interfering under oversubscription.

    PYTHONPATH=src python examples/fabric_contention.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.core import cost_model as cm  # noqa: E402
from repro.core.engine import (FSDP_POLICIES, simulate_fsdp_step,  # noqa: E402
                               simulate_multi_job)
from repro.core.simulator import (FabricParams, WorkerParams,  # noqa: E402
                                  simulate_allgather)
from repro.core.topology import FatTree  # noqa: E402


def routed_fig2():
    print("=" * 72)
    print("1. Fig 2/12 on the routed engine (k=32 fat-tree, 64 KiB shards)")
    fab = FabricParams(p_drop=0.0, jitter=0.0)
    wk = WorkerParams(n_recv_workers=16)
    shard = 64 << 10
    for p in (128, 512):
        topo = FatTree(k=32, n_hosts=p, b_host=fab.b_link)
        ag = simulate_allgather(p, shard, fab, wk, np.random.default_rng(0),
                                n_chains=p, topology=topo)
        mc = sum(ag.link_bytes.values())
        t_ring, ring_lb = cm.routed_ring_allgather(topo, p, p * shard, fab)
        ring = sum(ring_lb.values())
        print(f"   P={p:4d}: ring {ring/2**30:6.2f} GiB / {t_ring*1e3:5.2f} ms"
              f"   mcast {mc/2**30:6.2f} GiB / {ag.time*1e3:5.2f} ms"
              f"   -> x{ring/mc:.2f} less traffic, earlier finish")


def routed_fsdp():
    print("=" * 72)
    print("2. FSDP policies as routed traffic (P=16 on a k=8 fat-tree)")
    topo = FatTree(k=8, n_hosts=16)
    for pol in FSDP_POLICIES:
        r = simulate_fsdp_step(n_layers=4, layer_bytes=256e6, p=16,
                               policy=pol, hw_flops=2e15, topology=topo)
        busiest = max(r.link_utilization, key=r.link_utilization.get)
        print(f"   policy={pol:6s} step={r.step_time*1e3:7.2f} ms  "
              f"bubble={r.bubble_fraction:.3f}  busiest link "
              f"{busiest} @ {r.link_utilization[busiest]:.2f}")


def multi_job():
    print("=" * 72)
    print("3. Two FSDP jobs, disjoint hosts, one fabric (k=8, 32 hosts)")
    jobs = {"A": list(range(0, 32, 2)), "B": list(range(1, 32, 2))}
    slow = {}
    for o in (1.0, 2.0, 4.0):
        topo = FatTree(k=8, n_hosts=32, oversubscription=o)
        r = simulate_multi_job(topo, jobs, layer_bytes=128e6, n_layers=3,
                               policy="mcast")
        slow[o] = max(r.slowdown.values())
        print(f"   oversubscription {o:g}: solo "
              f"{min(r.solo_time.values())*1e3:6.2f} ms  contended "
              f"{max(r.contended_time.values())*1e3:6.2f} ms  slowdown "
              f"{slow[o]:.2f}x  (core traffic {r.core_bytes/1e9:.2f} GB)")
    assert slow[1.0] < 1.01 <= slow[4.0], slow
    print("   full bisection isolates the jobs; oversubscription makes their"
          " trees collide on shared agg/core links")


def main():
    routed_fig2()
    routed_fsdp()
    multi_job()


if __name__ == "__main__":
    main()
