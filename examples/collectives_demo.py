"""A guided tour of the paper's algorithms (no accelerators needed).

1. The Appendix-A broadcast sequencer (chains, activation signals).
2. Fat-tree traffic counting: P2P vs multicast (Fig 2 / Fig 12).
3. The reliable-broadcast protocol under drops + reordering (§III).
4. The discrete-event simulator: phase breakdown (Fig 10).
5. The DPA offload model: thread scaling to 1.6 Tbit/s (Figs 13-16).
6. The Schedule IR: allreduce (RS ∘ AG) built once, lowered per fidelity.

    PYTHONPATH=src python examples/collectives_demo.py
"""
import numpy as np

from repro.core import cost_model as cm
from repro.core import dpa, protocol, sched_ir, schedule
from repro.core.simulator import FabricParams, WorkerParams, simulate_broadcast
from repro.core.topology import FatTree


def main():
    print("=" * 72)
    print("1. Broadcast sequencer (P=16, M=4 chains) — Appendix A")
    for st in schedule.allgather_schedule(16, 4):
        print(f"   step {st.index}: active roots G^{st.index} = {st.roots}")
    print(f"   activation edges: {schedule.activation_edges(16, 4)[:6]} ...")

    print("=" * 72)
    print("2. Fat-tree traffic (1024 nodes, radix 32) — Fig 2")
    tree = FatTree(k=32, n_hosts=1024)
    n = 1 << 20
    ring = cm.p2p_ring_allgather_traffic(tree, 1024, n)
    mc = cm.mcast_allgather_traffic(tree, 1024, n)
    print(f"   allgather P2P-ring : {ring/2**30:8.2f} GiB on fabric")
    print(f"   allgather multicast: {mc/2**30:8.2f} GiB  ({ring/mc:.2f}x less)")

    print("=" * 72)
    print("3. Reliable broadcast under 20% drops + reordering — §III")
    rng = np.random.default_rng(0)
    buf = bytes(rng.integers(0, 256, 1 << 16, dtype=np.uint8))
    chunks = protocol.segment(buf)
    leaves = [protocol.LeafReceiver(len(buf)) for _ in range(4)]
    for leaf in leaves:
        for i in rng.permutation(len(chunks)):       # out-of-order
            if rng.random() > 0.2:                    # 20% drops
                leaf.deliver(chunks[i])
    missing = [len(l.bitmap.missing()) for l in leaves]
    print(f"   after fast path: missing per leaf = {missing}")
    for li, leaf in enumerate(leaves):
        peers = [leaves[(li - 1 - j) % 4] for j in range(3)]
        leaf.fetch_recover(peers, buf)
    ok = all(l.complete() and bytes(l.user) == buf for l in leaves)
    print(f"   after fetch-ring recovery: all complete = {ok}")

    print("=" * 72)
    print("4. Protocol phase breakdown (188 nodes) — Fig 10")
    for size in (4096, 4 << 20):
        r = simulate_broadcast(188, size, FabricParams(b_link=56e9 / 8),
                               WorkerParams(n_recv_workers=2),
                               np.random.default_rng(1))
        ph = r.phases
        print(f"   N={size:>8d}B: rnr {ph.rnr_sync*1e6:7.1f}us | "
              f"mcast {ph.multicast*1e6:9.1f}us | hs {ph.handshake*1e6:5.1f}us")

    print("=" * 72)
    print("5. DPA offload scaling — Figs 13/16")
    for t in (1, 4, 16):
        for tr in ("UD", "UC"):
            g = dpa.sustained_tput(dpa.DpaConfig(tr, t)) / 2**30
            print(f"   {tr} x{t:2d} threads: {g:5.1f} GiB/s", end="")
        print()
    need = dpa.link_chunk_arrival_rate(dpa.LINK_1600G_BYTES) / 1e6
    got = dpa.sustained_chunk_rate(
        dpa.DpaConfig("UD", 128, 64, dpa.LINK_1600G_BYTES)) / 1e6
    print(f"   1.6 Tbit/s needs {need:.1f} Mchunks/s; 128 threads sustain "
          f"{got:.1f} -> feasible = {got >= need}")

    print("=" * 72)
    print("6. Schedule IR: Allreduce = RS ∘ AG from one schedule graph")
    # quickstart: build once, lower onto any fidelity (sched_ir.execute)
    p, n = 16, 1 << 22
    fab = FabricParams(jitter=0.0)
    wk = WorkerParams(n_recv_workers=8)
    mc = sched_ir.execute(sched_ir.build_allreduce(p, n, m=p), fab, wk,
                          np.random.default_rng(0))
    ring = sched_ir.execute(sched_ir.build_allreduce(p, n), fab, wk,
                            np.random.default_rng(0))
    lb = sched_ir.execute(sched_ir.build_allreduce(p, n, m=p), fab, wk,
                          fidelity="analytic")
    print(f"   allreduce 4MiB x{p}: multicast-AG {mc.time*1e6:7.1f}us "
          f"(RS {mc.rs_time*1e6:.1f} + AG {mc.ag_time*1e6:.1f}) | "
          f"ring {ring.time*1e6:7.1f}us | analytic LB {lb*1e6:7.1f}us")
    best_m, times = sched_ir.autotune_chains(sched_ir.build_allgather,
                                             p=p, n_bytes=1 << 18,
                                             fabric=fab, workers=wk)
    print(f"   autotune_chains(allgather, flat fabric): best M = {best_m} "
          f"of {sorted(times)}")


if __name__ == "__main__":
    main()
