"""Regenerate EXPERIMENTS.md from dryrun_results/ + the benchmark suite.

    PYTHONPATH=src python -m benchmarks.make_experiments_md
"""
from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.roofline import HBM_BW, ICI_BW, PEAK_FLOPS, roofline_terms, what_would_help


def _load(result_dir="dryrun_results"):
    recs = []
    for p in sorted(glob.glob(os.path.join(result_dir, "*.json"))):
        r = json.load(open(p))
        r["_file"] = os.path.basename(p)
        recs.append(r)
    return recs


def _is_baseline(r):
    return (not r.get("mesh_shape") and not r.get("serve_replicate")
            and not r.get("moe_groups") and r.get("fsdp_mode", "xla") == "xla"
            and r.get("grad_accum", 1) == 1 and r.get("remat", "full") == "full")


def dryrun_section(recs):
    base = [r for r in recs if _is_baseline(r)]
    ok = sum(r["ok"] for r in base)
    lines = [
        "## §Dry-run — 40 cells x {16x16, 2x16x16}",
        "",
        f"**{ok}/{len(base)} lower+compile PASS** (every runnable cell on both the",
        "single-pod 256-chip mesh and the 2-pod 512-chip mesh; "
        "`python -m repro.launch.dryrun --all [--multi-pod]`).",
        "",
        "`long_500k` is skipped by design for the 8 full-attention archs "
        "(assignment rule; sub-quadratic `rwkv6-7b` and `recurrentgemma-9b` run it) "
        "— 32 runnable cells of the 40-cell grid, both meshes.",
        "",
        "Columns: XLA-reported per-device argument bytes (params+opt+cache),",
        "collective instructions found in the partitioned HLO, and the",
        "loop-scaled per-device collective traffic parsed from it.",
        "",
        "| arch | shape | mesh | compile s | args GiB/dev "
        "| HLO collective ops | coll GB/dev (HLO) |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in sorted(base, key=lambda x: (x["arch"], x["shape"], x["multi_pod"])):
        ma = r.get("memory_analysis", {})
        ch = r.get("collectives_hlo", {})
        ops = " ".join(f"{k.split('-')[-1] if '-' in k else k}:{v}"
                       for k, v in sorted(ch.get("counts", {}).items()))
        lines.append(
            f"| {r['arch']} | {r['shape']} | "
            f"{'2x16x16' if r['multi_pod'] else '16x16'} | "
            f"{r.get('compile_s', '-')} | "
            f"{ma.get('argument_size_in_bytes', 0)/2**30:.2f} | {ops} | "
            f"{ch.get('per_device_total', 0)/1e9:.2f} |"
        )
    lines += [
        "",
        "Notes:",
        "- `memory_analysis()` on the CPU backend reports per-device argument",
        "  sizes faithfully; its `temp` numbers are upper bounds (the host",
        "  backend skips donation/aliasing optimizations), so HBM residency is",
        "  additionally estimated analytically in §Roofline.",
        "- collective bytes are ring-equivalent per-device bytes; ops inside",
        "  the layer scan are multiplied by the loop chain (launch/hlo_stats.py",
        "  — XLA's cost_analysis counts while bodies ONCE, verified empirically).",
        "",
    ]
    return "\n".join(lines)


def roofline_section(recs):
    rows = []
    for r in recs:
        if _is_baseline(r) and not r["multi_pod"] and r["ok"]:
            r2 = dict(r)
            rows.append(roofline_terms(r2))
    rows.sort(key=lambda x: (x["arch"], x["shape"]))
    lines = [
        "## §Roofline — per-cell terms (single-pod 16x16 baseline, fsdp=xla)",
        "",
        f"Constants: {PEAK_FLOPS/1e12:.0f} TFLOP/s bf16/chip, {HBM_BW/1e9:.0f} GB/s HBM, "
        f"{ICI_BW/1e9:.0f} GB/s/link ICI.",
        "Terms: `Tc = impl_FLOPs/(chips*peak)`, `Tm = HBM_bytes/dev / bw`,",
        "`Tx = collective_bytes/dev / link_bw` (analytic models,",
        "launch/analytic_costs.py; HLO-parsed collectives as cross-check).",
        "`frac` = MODEL_FLOPS-based compute time / dominant term — the",
        "roofline fraction; `useful` = MODEL_FLOPS / impl_FLOPs.",
        "",
        "| arch | shape | Tc (s) | Tm (s) | Tx (s) | dominant "
        "| frac | useful | params B | next lever |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.2e} | "
            f"{r['t_memory_s']:.2e} | {r['t_collective_s']:.2e} | "
            f"{r['dominant']} | {r['roofline_fraction']:.3f} | "
            f"{r['useful_ratio']:.2f} | {r['params_B']:.1f} | "
            f"{what_would_help(r)} |"
        )
    lines += [
        "",
        "Reading the table:",
        "- **Train/prefill cells are mostly collective-bound** at (16,16):",
        "  Megatron-style TP=16 activation gathers dominate dense archs;",
        "  EP token dispatch dominates the MoE archs (deepseek/moonshot at",
        "  frac 0.09 — the worst of the grid together with decode).",
        "- **Decode cells are collective-catastrophic** (frac ~0.005): FSDP-",
        "  sharded weights are re-gathered every decoded token. This motivates",
        "  the serve-weight-replication iteration in §Perf.",
        "- granite-34b (largest dense) is the only compute-dominant train cell",
        "  (frac 0.71) — its FSDP gathers amortize over the most FLOPs/byte.",
        "- `useful≈0.70` for train cells = remat=full recompute (4/3 fwd) x",
        "  masked-attention waste; both are §Perf levers.",
        "- rwkv/recurrentgemma long_500k decode: O(1) state, memory-trivial —",
        "  the sub-quadratic rationale validated.",
        "",
    ]
    return "\n".join(lines)


def main():
    recs = _load()
    out = [dryrun_section(recs), roofline_section(recs)]
    print("\n".join(out))


if __name__ == "__main__":
    main()
