"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Hardware constants: TPU v5e — 197 TFLOP/s bf16/chip, 819 GB/s HBM/chip,
~50 GB/s/link ICI. Terms per (arch x shape x mesh):

  T_compute    = impl_FLOPs   / (chips * 197e12)
  T_memory     = HBM_bytes    / (chips * 819e9)     [per-device bytes * chips]
  T_collective = coll_bytes   / (chips * 50e9)      [total over devices]

Dominant term = the bottleneck; roofline fraction = T_compute / max(all)
(how much of the step is MXU-limited — 1.0 means compute-bound at peak).
MODEL_FLOPS/impl_FLOPs flags masked-attention waste and remat recompute.
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def load_cells(result_dir: str) -> list[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(result_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("ok"):
            rec["_file"] = os.path.basename(path)
            out.append(rec)
    return out


def roofline_terms(rec: dict) -> dict:
    chips = rec["mesh"]["n_devices"]
    an = rec["analytic"]
    t_comp = an["impl_flops"] / (chips * PEAK_FLOPS)
    t_comp_useful = an["model_flops"] / (chips * PEAK_FLOPS)
    t_mem = an["hbm_bytes_per_device"] / HBM_BW
    coll_per_dev = an["collective_bytes_per_device"]["total"]
    t_coll = coll_per_dev / ICI_BW
    # cross-check: HLO-parsed collective bytes (loop-scaled)
    hlo_coll = rec.get("collectives_hlo", {}).get("per_device_total", 0)
    t_coll_hlo = hlo_coll / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    step = max(terms.values())
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": "2pod" if rec["multi_pod"] else "1pod",
        "t_compute_s": t_comp,
        "t_memory_s": t_mem,
        "t_collective_s": t_coll,
        "t_collective_hlo_s": t_coll_hlo,
        "dominant": dom,
        "roofline_fraction": t_comp_useful / step if step else 0.0,
        "useful_ratio": an["useful_ratio"],
        "model_flops": an["model_flops"],
        "impl_flops": an["impl_flops"],
        "params_B": an["params_total"] / 1e9,
        "fsdp_mode": rec.get("fsdp_mode", "xla"),
        "tag": rec["_file"].replace(".json", ""),
    }


def what_would_help(row: dict) -> str:
    d = row["dominant"]
    if d == "collective":
        return ("overlap/bidirectional AG+RS schedule; replicate serve weights "
                "over dp; larger per-gather granularity")
    if d == "memory":
        return ("remat policy with fewer activation passes; fuse elementwise "
                "chains; bf16 optimizer reads; KV layout")
    return ("remove masked-attention waste (triangle scheduling); drop remat "
            "recompute where memory allows")


def rows(result_dir: str = "dryrun_results", only_1pod: bool = True):
    out = []
    for rec in load_cells(result_dir):
        if only_1pod and rec["multi_pod"]:
            continue
        if (rec.get("fsdp_mode", "xla") != "xla" or rec.get("mesh_shape")
                or rec.get("serve_replicate") or rec.get("moe_groups")
                or rec.get("grad_accum", 1) != 1):
            continue  # baselines only; perf variants reported in §Perf
        r = roofline_terms(rec)
        out.append((
            f"roofline.{r['arch']}.{r['shape']}.{r['mesh']}",
            round(r["roofline_fraction"], 4),
            f"dom={r['dominant']} Tc={r['t_compute_s']:.2e} "
            f"Tm={r['t_memory_s']:.2e} Tx={r['t_collective_s']:.2e} "
            f"useful={r['useful_ratio']:.2f}",
        ))
    return out


def full_table(result_dir: str = "dryrun_results"):
    return [roofline_terms(r) for r in load_cells(result_dir)]
