"""Benchmark driver: one function per paper table/figure + the roofline table.

Prints ``name,value,derived`` CSV. Paper-claim assertions fire inside each
benchmark — a failing claim fails the run.

    PYTHONPATH=src python -m benchmarks.run [--skip-roofline]
    PYTHONPATH=src python -m benchmarks.run --smoke   # seconds-scale CI sweep

``--smoke`` also emits ``BENCH_smoke.json``: per-scenario wall times plus
every derived RATIO metric (bubble fractions, slowdown/reduction factors,
the protocol loss-crossover). Ratios are deterministic model outputs —
machine-independent — so scripts/bench_gate.py diffs them against the
committed ``benchmarks/baseline_smoke.json`` and fails CI on regression.
Machine-dependent wall-clock rows (``*_wall_s`` / ``*_speedup``) land in the
report's ``wall_clock`` section, alongside a ``wall.calibration_wall_s``
row timing a fixed numpy workload. bench_gate gates them loosely: ``_wall_s``
rows as a ratio-of-ratios against the calibration row (machine speed divides
out), ``_speedup`` rows raw (already machine-internal ratios), both at a
generous tolerance that only catches order-of-magnitude regressions.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import paper_figs, roofline  # noqa: E402

#: benchmark rows gated by scripts/bench_gate.py: dimensionless derived
#: ratios (and the crossover loss rate), never wall-clock measurements
RATIO_SUFFIXES = ("_x", ".bubble_frac", ".crossover_loss")

#: machine-dependent wall-clock rows (engine timings, speedups, search
#: wall): carried in BENCH_smoke.json under "wall_clock"; gated loosely by
#: scripts/bench_gate.py after machine-normalizing against CALIBRATION_ROW
WALL_SUFFIXES = ("_wall_s", "_speedup")

#: fixed-workload timing row used by bench_gate to divide machine speed out
#: of the other _wall_s rows (ratio-of-ratios gating)
CALIBRATION_ROW = "wall.calibration_wall_s"


def is_ratio_row(name: str) -> bool:
    return name.endswith(RATIO_SUFFIXES)


def is_wall_row(name: str) -> bool:
    return name.endswith(WALL_SUFFIXES)


def calibration_wall_s() -> float:
    """Time a fixed numpy workload (matmul + tanh, the smoke benches' own
    compute mix) so wall-clock rows can be gated as multiples of THIS
    machine's speed rather than absolute seconds."""
    import numpy as np

    rng = np.random.default_rng(0)
    a = rng.standard_normal((512, 512))
    best = math.inf
    for _ in range(3):                      # best-of-3 damps scheduler noise
        t0 = time.perf_counter()
        b = a
        for _ in range(10):
            b = np.tanh(b @ a / 512)
        best = min(best, time.perf_counter() - t0)
    return best


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-roofline", action="store_true")
    ap.add_argument("--results", default="dryrun_results")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI sweep; also writes --json")
    ap.add_argument("--write-json", action="store_true",
                    help="emit the report JSON for a full (non-smoke) run "
                         "too — the nightly CI job uploads it as a "
                         "BENCH_*.json artifact so wall-clock drift rows "
                         "accumulate history")
    ap.add_argument("--json", default=None,
                    help="report path (written with --smoke or "
                         "--write-json; defaults to BENCH_smoke.json / "
                         "BENCH_full.json in the repo root, where "
                         "scripts/bench_gate.py looks for it)")
    ap.add_argument("--profile", action="store_true",
                    help="per-phase wall breakdown (engine max-min solves / "
                         "pool scans / RNG / bitmap packing) accumulated "
                         "across every bench, printed as profile.* rows and "
                         "written into the report JSON under \"profile\"")
    args = ap.parse_args()
    if args.json is None:
        args.json = os.path.join(
            os.path.dirname(__file__), "..",
            "BENCH_smoke.json" if args.smoke else "BENCH_full.json")

    benches = paper_figs.SMOKE if args.smoke else paper_figs.ALL
    if args.smoke:
        args.skip_roofline = True

    if args.profile:
        from repro.core import profiling

        profiling.reset()
        profiling.enable()

    print("name,value,derived")
    failures = 0
    report = {"scenarios": {}, "ratios": {}, "wall_clock": {}}
    cal = calibration_wall_s()
    print(f"{CALIBRATION_ROW},{cal:.4f},fixed numpy workload (normalizer)")
    report["wall_clock"][CALIBRATION_ROW] = round(cal, 4)
    for fn in benches:
        t0 = time.perf_counter()
        n_rows = 0
        try:
            for name, value, derived in fn():
                print(f"{name},{value},{derived}")
                n_rows += 1
                if is_ratio_row(name):
                    v = float(value)
                    # null sentinel: inf/nan are not valid strict JSON and
                    # must never reach the committed baseline as `Infinity`
                    report["ratios"][name] = v if math.isfinite(v) else None
                elif is_wall_row(name):
                    v = float(value)
                    report["wall_clock"][name] = (v if math.isfinite(v)
                                                  else None)
        except AssertionError as e:
            failures += 1
            print(f"{fn.__name__},FAILED,{e}")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{fn.__name__},ERROR,{type(e).__name__}: {e}")
        dt = time.perf_counter() - t0
        print(f"bench.{fn.__name__}.us_per_call,{dt*1e6:.0f},wall")
        report["scenarios"][fn.__name__] = {
            "wall_s": round(dt, 4), "rows": n_rows,
        }

    if args.profile:
        prof = profiling.report()
        profiling.disable()
        for phase, row in prof.items():
            print(f"profile.{phase}.wall_s,{row['wall_s']},"
                  f"{row['calls']} calls")
        report["profile"] = prof

    if args.smoke or args.write_json:
        report["failures"] = failures
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True, allow_nan=False)
            f.write("\n")
        print(f"bench.report,{args.json},"
              f"{len(report['ratios'])} gated ratios", file=sys.stderr)

    if not args.skip_roofline and os.path.isdir(args.results):
        try:
            for name, value, derived in roofline.rows(args.results):
                print(f"{name},{value},{derived}")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"roofline,ERROR,{type(e).__name__}: {e}")

    if failures:
        print(f"bench.failures,{failures},", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
