"""Benchmark driver: one function per paper table/figure + the roofline table.

Prints ``name,value,derived`` CSV. Paper-claim assertions fire inside each
benchmark — a failing claim fails the run.

    PYTHONPATH=src python -m benchmarks.run [--skip-roofline]
    PYTHONPATH=src python -m benchmarks.run --smoke   # seconds-scale CI sweep
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import paper_figs, roofline  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-roofline", action="store_true")
    ap.add_argument("--results", default="dryrun_results")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale FSDP-contention sweep only (CI)")
    args = ap.parse_args()

    benches = paper_figs.SMOKE if args.smoke else paper_figs.ALL
    if args.smoke:
        args.skip_roofline = True

    print("name,value,derived")
    failures = 0
    for fn in benches:
        t0 = time.perf_counter()
        try:
            for name, value, derived in fn():
                print(f"{name},{value},{derived}")
        except AssertionError as e:
            failures += 1
            print(f"{fn.__name__},FAILED,{e}")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{fn.__name__},ERROR,{type(e).__name__}: {e}")
        dt = (time.perf_counter() - t0) * 1e6
        print(f"bench.{fn.__name__}.us_per_call,{dt:.0f},wall")

    if not args.skip_roofline and os.path.isdir(args.results):
        try:
            for name, value, derived in roofline.rows(args.results):
                print(f"{name},{value},{derived}")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"roofline,ERROR,{type(e).__name__}: {e}")

    if failures:
        print(f"bench.failures,{failures},", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
