"""One benchmark per paper table/figure. Each returns a list of CSV rows
(name, value, derived) and asserts the paper's headline claim."""
from __future__ import annotations

import time

import numpy as np

import math

from repro.core import cost_model as cm
from repro.core import dpa, protocol
from repro.core.engine import simulate_multi_job, sweep_fsdp_contention
from repro.core.simulator import (FabricParams, WorkerParams, simulate_allgather,
                                  simulate_broadcast, sweep_phase_breakdown)
from repro.core.topology import FatTree, Torus2D

GIB = 1 << 30
ROWS = list


def fig2_traffic_model():
    """Fig 2: theoretical bandwidth savings, 1024-node fat-tree, radix 32."""
    tree = FatTree(k=32, n_hosts=1024)
    n = 1 << 20
    rows = []
    ring = cm.p2p_ring_allgather_traffic(tree, 1024, n)
    mc_ag = cm.mcast_allgather_traffic(tree, 1024, n)
    kno = cm.p2p_knomial_bcast_traffic(tree, 1024, n, k=4)
    mc_bc = cm.mcast_bcast_traffic(tree, 1024, n)
    rows.append(("fig2.allgather_ring_bytes", ring, f"x{ring/mc_ag:.2f} vs mcast"))
    rows.append(("fig2.allgather_mcast_bytes", mc_ag, "every byte crosses each link once"))
    rows.append(("fig2.bcast_knomial_bytes", kno, f"x{kno/mc_bc:.2f} vs mcast"))
    rows.append(("fig2.bcast_mcast_bytes", mc_bc, "bandwidth-optimal"))
    assert 1.5 <= ring / mc_ag <= 2.5, "paper: ~2x traffic reduction"
    return rows


def fig5_cpu_datapath():
    """Fig 5: single CPU core vs single multithreaded DPA core at 200 Gbit/s."""
    link = dpa.LINK_200G_BYTES
    rows = []
    for name, gib in dpa.CPU_CORE_TPUT_GIB.items():
        rows.append((f"fig5.cpu_core.{name}_gibs", gib,
                     f"{gib*GIB/link*100:.0f}% of 200G link"))
        assert gib * GIB < link  # CPU core cannot sustain the link
    d = dpa.sustained_tput(dpa.DpaConfig("UD", 16)) / GIB
    rows.append(("fig5.dpa_core16t_UD_gibs", round(d, 2), "scales to peak"))
    assert d * GIB >= 0.99 * link
    return rows


def fig10_critical_path():
    """Fig 10: protocol phase breakdown vs scale and message size."""
    rows = []
    data = sweep_phase_breakdown(
        sizes=[4096, 1 << 17, 4 << 20], nodes=[2, 16, 188], seed=0
    )
    for r in data:
        rows.append((
            f"fig10.P{r['nodes']}.{r['bytes']}B.mcast_frac",
            round(r["mcast_frac"], 4),
            f"rnr={r['rnr_frac']:.3f} rel={r['reliability_frac']:.3f}",
        ))
    big = next(r for r in data if r["nodes"] >= 16 and r["bytes"] == 4 << 20)
    assert big["mcast_frac"] > 0.99, "paper: 99% of time in data movement at 16+ nodes"
    return rows


def fig11_throughput_188():
    """Fig 11: per-rank receive throughput at 188 nodes (56 Gbit/s CX-3)."""
    fab = FabricParams(b_link=56e9 / 8)
    wk = WorkerParams(n_recv_workers=2, thread_tput=9.0 * GIB)
    rng = np.random.default_rng(0)
    rows = []
    p = 188
    for size in (1 << 14, 1 << 17, 1 << 20):
        ag = simulate_allgather(p, size, fab, wk, rng)
        t_ring = cm.allgather_time_ring(size, fab.b_link, p)
        ring_tput = (p - 1) * size / t_ring
        rows.append((f"fig11.allgather.{size}B.mcast_GBs",
                     round(ag.per_rank_recv_tput / 1e9, 3),
                     f"ring={ring_tput/1e9:.3f} GB/s (both receive-bound)"))
        # paper: mcast ~ ring for 128-256 KiB (receive-bound alignment)
        if size == 1 << 17:
            assert 0.5 < ag.per_rank_recv_tput / ring_tput < 1.5
    n = 8 << 20  # paper reports the tree-vs-mcast gaps at large messages
    t_mc = cm.bcast_time_multicast(n, fab.b_link, p)
    t_kno = cm.bcast_time_knomial(n, fab.b_link, p)
    t_bin = cm.bcast_time_binary_tree(n, fab.b_link, p)
    rows.append(("fig11.bcast.mcast_vs_knomial_x", round(t_kno / t_mc, 2),
                 "paper: up to 1.3x"))
    rows.append(("fig11.bcast.mcast_vs_binary_x", round(t_bin / t_mc, 2),
                 "paper: up to 4.75x (ours is the store-and-forward bound)"))
    assert 1.05 < t_kno / t_mc < 1.8
    assert t_bin / t_mc > 3.0
    return rows


def fig12_traffic_savings():
    """Fig 12: switch-port counter savings on the 188-node, 18-switch testbed."""
    tree = FatTree(k=16, n_hosts=188)
    n = 1 << 16  # 64 KiB per the paper's counter experiment
    rows = []
    ring = cm.p2p_ring_allgather_traffic(tree, 188, n * 188)
    mc = cm.mcast_allgather_traffic(tree, 188, n * 188)
    ringb = cm.p2p_ring_pipeline_bcast_traffic(tree, 188, n)
    kno = cm.p2p_knomial_bcast_traffic(tree, 188, n)
    mcb = cm.mcast_bcast_traffic(tree, 188, n)
    rows.append(("fig12.allgather_reduction_x", round(ring / mc, 2),
                 "paper: 1.5-2x"))
    rows.append(("fig12.bcast_reduction_x", round(ringb / mcb, 2),
                 "vs pipelined-ring P2P; paper: 1.5x"))
    rows.append(("fig12.bcast_vs_knomial_x", round(kno / mcb, 2),
                 "vs locality-naive k-nomial (worse baseline)"))
    assert 1.5 <= ring / mc <= 2.2
    assert 1.3 <= ringb / mcb <= 2.5
    return rows


def table1_datapath():
    """Table I: single-thread DPA receive datapath metrics."""
    rows = []
    for t in ("UD", "UC"):
        r = dpa.TABLE1[t]
        rows.append((f"table1.{t}.tput_gibs", r["tput_gib"], ""))
        rows.append((f"table1.{t}.cycles_per_cqe", r["cycles_per_cqe"],
                     f"ipc={r['ipc']}"))
    assert dpa.TABLE1["UC"]["tput_gib"] / dpa.TABLE1["UD"]["tput_gib"] > 2
    return rows


def fig13_14_thread_scaling():
    """Figs 13/14: receive throughput vs DPA threads (8 MiB buffer, 4 KiB)."""
    rows = []
    for t in ("UD", "UC"):
        for n in (1, 2, 4, 8, 16):
            tput = dpa.sustained_tput(dpa.DpaConfig(t, n)) / GIB
            rows.append((f"fig13.{t}.{n}threads_gibs", round(tput, 2), ""))
        sat = dpa.threads_to_saturate(t)
        rows.append((f"fig14.{t}.threads_to_linerate", sat,
                     "paper: UC~4, UD 8-16"))
    assert dpa.threads_to_saturate("UC") <= 4
    assert 8 <= dpa.threads_to_saturate("UD") <= 16
    return rows


def fig15_chunk_sizes():
    """Fig 15: UC multi-packet chunks saturate with fewer threads."""
    rows = []
    for chunk in (4096, 8192, 16384, 32768):
        n = next(
            t for t in range(1, 257)
            if dpa.sustained_tput(dpa.DpaConfig("UC", t, chunk))
            >= 0.99 * dpa.LINK_200G_BYTES
        )
        rows.append((f"fig15.UC.{chunk}B.threads_to_linerate", n, ""))
    return rows


def fig16_tbit():
    """Fig 16: 64 B chunks — sustained chunk rate vs the 1.6 Tbit/s arrival."""
    need = dpa.link_chunk_arrival_rate(dpa.LINK_1600G_BYTES)
    rows = [("fig16.required_Mchunks_s", round(need / 1e6, 1), "1.6T, 4KiB MTU")]
    for n in (16, 64, 128):
        r = dpa.sustained_chunk_rate(
            dpa.DpaConfig("UD", n, 64, dpa.LINK_1600G_BYTES)
        )
        rows.append((f"fig16.UD.{n}threads_Mchunks_s", round(r / 1e6, 1),
                     "sustains 1.6T" if r >= need else "below"))
    assert dpa.tbit_feasible("UD", 128)
    return rows


def appendix_b_speedup():
    """Appendix B: S = 2 - 2/P for concurrent {AG, RS}."""
    rows = []
    for p in (2, 16, 256, 1024):
        s = cm.concurrent_ag_rs_speedup(p)
        t_rr = cm.concurrent_completion_time(1 << 20, p, 25e9, "ring_ring")
        t_mi = cm.concurrent_completion_time(1 << 20, p, 25e9, "mc_inc")
        rows.append((f"appB.S(P={p})", round(s, 4),
                     f"sim ratio {t_rr/t_mi:.4f}"))
        assert abs(t_rr / t_mi - s) < 1e-9
    return rows


def fabric_sweep(hosts_list=(128, 512, 1024)):
    """Fig. 2's P2P-vs-multicast port-counter curve on the ROUTED engine:
    all P ranks placed on a k=32 fat-tree, every transfer a routed/tree flow,
    so ONE engine run per schedule yields both the completion time and the
    per-link switch-port bytes (no static counting pass). Asserts byte
    conservation against the tree/route edge counts and the paper's Insight-1
    reduction: multicast Allgather <= 0.55x the P2P ring bytes at >=512
    hosts (~2x, Fig. 12)."""
    k = 32
    shard = 64 << 10                       # 64 KiB per rank (Fig. 12 counter run)
    fab = FabricParams(p_drop=0.0, jitter=0.0)
    wk = WorkerParams(n_recv_workers=16)
    rows = []
    for p in hosts_list:
        topo = FatTree(k=k, n_hosts=p, b_host=fab.b_link)
        hosts = list(range(p))
        ag = simulate_allgather(p, shard, fab, wk, np.random.default_rng(0),
                                n_chains=p, topology=topo)
        mc_bytes = sum(ag.link_bytes.values())
        # conservation: each tree flow serves its bytes on every tree edge
        mc_expect = shard * sum(
            len(topo.multicast_tree(h, hosts)) for h in hosts)
        assert abs(mc_bytes - mc_expect) <= 1e-6 * mc_expect, (mc_bytes, mc_expect)

        t_ring, ring_lb = cm.routed_ring_allgather(topo, p, p * shard, fab)
        ring_bytes = sum(ring_lb.values())
        ring_expect = (p - 1) * shard * sum(
            len(topo.route(hosts[i], hosts[(i + 1) % p])) for i in range(p))
        assert abs(ring_bytes - ring_expect) <= 1e-6 * ring_expect, (
            ring_bytes, ring_expect)

        red = ring_bytes / mc_bytes
        rows.append((f"fabric.P{p}.ring_port_bytes", int(ring_bytes),
                     f"t={t_ring*1e3:.2f}ms"))
        rows.append((f"fabric.P{p}.mcast_port_bytes", int(mc_bytes),
                     f"t={ag.time*1e3:.2f}ms x{red:.2f} less traffic"))
        # Insight 1 at scale: >= ~2x reduction measured at the switch ports,
        # from the same runs that produced the times
        if p >= 512:
            assert mc_bytes <= 0.55 * ring_bytes, (p, mc_bytes / ring_bytes)
        else:
            assert mc_bytes < ring_bytes
        # both schedules are receive-bound (paper: "such alignment is
        # expected") — but the ring pays P-1 activation latencies while the
        # multicast pays constant sync, so it must not be slower
        t_bound = (p - 1) * shard / fab.b_link
        assert t_bound * 0.95 <= ag.time <= t_ring, (t_bound, ag.time, t_ring)
    return rows


def fabric_sweep_smoke():
    """CI-sized fabric_sweep (<~10 s): same asserts, capped at 512 hosts."""
    return fabric_sweep(hosts_list=(128, 512))


def multi_job_contention():
    """Two FSDP jobs on disjoint hosts of one fat-tree: full bisection
    isolates them (slowdown 1.0x); oversubscribing the switch tiers makes
    their multicast trees collide on shared agg/core links."""
    rows = []
    jobs = {"A": list(range(0, 32, 2)), "B": list(range(1, 32, 2))}
    slowdowns = {}
    for o in (1.0, 2.0, 4.0):
        topo = FatTree(k=8, n_hosts=32, oversubscription=o)
        r = simulate_multi_job(topo, jobs, layer_bytes=128e6, n_layers=3,
                               policy="mcast")
        s = max(r.slowdown.values())
        slowdowns[o] = s
        rows.append((f"multijob.oversub{o:g}.slowdown_x", round(s, 3),
                     f"solo={min(r.solo_time.values())*1e3:.2f}ms "
                     f"core={r.core_bytes/1e9:.2f}GB"))
    assert slowdowns[1.0] < 1.01, slowdowns       # full bisection: isolated
    assert slowdowns[4.0] > 1.3, slowdowns        # oversubscribed: interference
    assert slowdowns[1.0] <= slowdowns[2.0] <= slowdowns[4.0], slowdowns
    return rows


def protocol_loss_sweep(p_list=(16, 64, 256, 512), *, n_bytes=1 << 20,
                        link_loss=1e-3, seeds=(0, 1, 2), crossover_p=64,
                        loss_grid=(1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2,
                                   1e-1, 2e-1, 3e-1)):
    """Packet-level reliability headline (§III): at a fixed 0.1% per-link
    loss, NACK-aggregation + multicast-retransmission recovery time grows
    no faster than O(log p) — the fat-tree depth is constant, the root
    serves ONE aggregated NACK per round, and the retransmit union
    saturates. Also locates the loss rate at which reliable-unicast ring
    broadcast overtakes multicast+recovery (it must sit well above the
    paper's operating point), and reports the Gilbert-Elliott bursty-loss
    contrast at equal mean rate."""
    from repro.core.packet import GilbertElliottLoss

    fab = FabricParams(jitter=0.0)
    wk = WorkerParams(n_recv_workers=16)
    rows = []

    # -- part A: recovery-time growth in p at fixed per-link loss
    rec = {}
    for p in p_list:
        k = 32 if p > 128 else 16
        per = []
        for s in seeds:
            topo = FatTree(k=k, n_hosts=p, b_host=fab.b_link)
            r = simulate_broadcast(p, n_bytes, fab, wk,
                                   np.random.default_rng(s), topology=topo,
                                   fidelity="packet", loss=link_loss)
            assert r.completed, (p, s)
            assert r.bytes_fast + r.bytes_recovery == r.bytes_total
            per.append(r.phases.reliability)
        rec[p] = sum(per) / len(per)
        rows.append((f"proto.P{p}.recovery_us", round(rec[p] * 1e6, 1),
                     f"{link_loss:g} per-link loss, mean of {len(seeds)} seeds"))
    p0, p1 = min(p_list), max(p_list)
    growth = rec[p1] / rec[p0]
    log_bound = math.log2(p1) / math.log2(p0)
    rows.append(("proto.recovery_growth_x", round(growth, 3),
                 f"P{p0}->P{p1}; O(log p) bound {log_bound:.2f}"))
    # constant-time claim: growth bounded by the log-p envelope (slack for
    # sampling noise); a linear-in-p protocol would show ~p1/p0 = 32x here
    assert growth <= log_bound * 1.5, (growth, log_bound)

    # NACK-aggregation ablation (same seed, same loss draws): without
    # in-tree ORs the root pool serves one NACK per nacker instead of one
    # aggregate, so recovery can only get slower
    k1 = 32 if p1 > 128 else 16
    runs = {}
    for agg in (True, False):
        topo = FatTree(k=k1, n_hosts=p1, b_host=fab.b_link)
        runs[agg] = simulate_broadcast(
            p1, n_bytes, fab, wk, np.random.default_rng(seeds[0]),
            topology=topo, fidelity="packet", loss=link_loss,
            aggregate_nacks=agg)
    rows.append((f"proto.P{p1}.noagg_recovery_us",
                 round(runs[False].phases.reliability * 1e6, 1),
                 f"vs {runs[True].phases.reliability*1e6:.1f}us aggregated"))
    assert (runs[False].phases.reliability
            >= runs[True].phases.reliability - 1e-12)
    # DPA NACK budget context: even WITHOUT aggregation a 16-thread pool
    # could absorb every leaf's NACK each round at the largest scale here
    nack_budget = dpa.nack_rate(dpa.DpaConfig("UD", 16))
    rows.append(("proto.dpa_nack_rate_msgs_per_s", int(nack_budget),
                 f"16 UD threads; P{p1} worst case needs {p1 - 1}/round"))
    assert nack_budget > p1 - 1

    # -- part B: multicast-vs-unicast crossover loss rate
    p = crossover_p
    t_mc, t_ring = [], []
    for q in loss_grid:
        per = [simulate_broadcast(p, n_bytes, fab, wk,
                                  np.random.default_rng(s),
                                  fidelity="packet", loss=q).time
               for s in seeds]
        t_mc.append(sum(per) / len(per))
        t_ring.append(protocol.analytic_ring_pipeline_bcast_time(
            p, n_bytes, fab.b_link, fab.latency, loss_rate=q))
    crossover = None
    for i, q in enumerate(loss_grid):
        rows.append((f"proto.loss{q:g}.mcast_vs_ring_x",
                     round(t_mc[i] / t_ring[i], 3),
                     f"mcast={t_mc[i]*1e6:.0f}us ring={t_ring[i]*1e6:.0f}us"))
        if crossover is None and t_mc[i] > t_ring[i]:
            crossover = (math.sqrt(loss_grid[i - 1] * q) if i else q)
    rows.append(("proto.crossover_loss",
                 crossover if crossover is not None else float("inf"),
                 f"P={p}, {n_bytes>>10} KiB: unicast ring wins above this"))
    # multicast+recovery must still win at the paper's 0.1% operating point
    assert crossover is None or crossover > 1e-3, crossover

    # -- part C: bursty (Gilbert-Elliott) vs i.i.d. loss at equal mean rate
    rate, burst = 1e-2, 16.0
    ge = GilbertElliottLoss.from_rate(rate, mean_burst=burst)
    r_ge = simulate_broadcast(p, n_bytes, fab, wk, np.random.default_rng(0),
                              fidelity="packet", loss=ge)
    r_iid = simulate_broadcast(p, n_bytes, fab, wk, np.random.default_rng(0),
                               fidelity="packet", loss=rate)
    assert r_ge.completed and r_iid.completed
    rows.append(("proto.ge_vs_iid_recovery_x",
                 round(r_ge.phases.reliability
                       / max(r_iid.phases.reliability, 1e-12), 3),
                 f"burst={burst:g} pkts at rate {rate:g}"))
    return rows


def protocol_loss_sweep_smoke():
    """CI-sized protocol_loss_sweep (seconds): same asserts, capped at 128
    hosts / 256 KiB and a coarser crossover grid."""
    return protocol_loss_sweep(
        p_list=(16, 64, 128), n_bytes=1 << 18, seeds=(0, 1),
        loss_grid=(1e-3, 1e-2, 3e-2, 1e-1, 3e-1))


def packet_scale_sweep(grid=((512, 1 << 26), (2048, 1 << 26), (10000, GIB)),
                       ref_grid=((512, 1 << 26), (2048, 1 << 26)),
                       big=(10000, GIB), ag_point=(512, 1 << 20, 4),
                       ag_dense=(128, 16 << 20, 4),
                       min_big_speedup=20.0, min_dense_speedup=1.0):
    """Simulator-throughput benchmark: wall-clock of the packet-fidelity
    engine itself vs host count, vectorized batch engine (default) against
    the per-leaf reference oracle. Lossless jitter-0 fabric with an 8-thread
    pool (pool rate > wire rate, so no staging RNR) — both engines replay
    the identical protocol and must return identical results; the lossy /
    RNR / multi-chain grid is pinned bit-exact by
    tests/test_packet_vectorized.py. Wall-clock rows (``*_wall_s`` /
    ``*_speedup``) are machine-dependent: benchmarks/run.py carries them in
    BENCH_smoke.json's ``wall_clock`` section and scripts/bench_gate.py
    reports their drift informationally — they are never gated."""
    fab = FabricParams(jitter=0.0)
    wk = WorkerParams(n_recv_workers=8)
    rows = []
    vec_wall = {}

    def timed(fn, *args, **kw):
        t0 = time.perf_counter()
        r = fn(*args, **kw)
        return r, time.perf_counter() - t0

    for p, n in grid:
        r, w = timed(simulate_broadcast, p, n, fab, wk,
                     np.random.default_rng(0), fidelity="packet",
                     engine="vectorized")
        assert r.completed, (p, n)
        vec_wall[p] = w
        rows.append((f"pscale.P{p}.vec_wall_s", round(w, 4),
                     f"bcast {n >> 20} MiB, vectorized engine"))
    # reference oracle at the small/mid points: identical results, and the
    # measured per-leaf wall-clock the batch engine is judged against
    for p, n in ref_grid:
        rr, w = timed(simulate_broadcast, p, n, fab, wk,
                      np.random.default_rng(0), fidelity="packet",
                      engine="reference")
        rv = simulate_broadcast(p, n, fab, wk, np.random.default_rng(0),
                                fidelity="packet", engine="vectorized")
        assert (rr.time, rr.completed, rr.bytes_total, rr.bytes_recovery) \
            == (rv.time, rv.completed, rv.bytes_total, rv.bytes_recovery)
        rows.append((f"pscale.P{p}.ref_wall_s", round(w, 4),
                     f"bcast {n >> 20} MiB, per-leaf reference"))
        rows.append((f"pscale.P{p}.ref_vs_vec_speedup",
                     round(w / max(vec_wall[p], 1e-9), 1),
                     "reference / vectorized wall-clock"))
    # the 10k-host headline: full reference run, recorded + floor-asserted
    if big is not None:
        p, n = big
        rr, w = timed(simulate_broadcast, p, n, fab, wk,
                      np.random.default_rng(0), fidelity="packet",
                      engine="reference")
        assert rr.completed, (p, n)
        speedup = w / max(vec_wall[p], 1e-9)
        rows.append((f"pscale.P{p}.ref_wall_s", round(w, 4),
                     f"bcast {n >> 20} MiB, per-leaf reference"))
        rows.append((f"pscale.P{p}.ref_vs_vec_speedup", round(speedup, 1),
                     f"floor {min_big_speedup:g}x"))
        assert speedup >= min_big_speedup, (speedup, w, vec_wall[p])
    # allgather point: same contract on the multi-chain path
    p, n, m = ag_point
    ra, wv = timed(simulate_allgather, p, n, fab, wk,
                   np.random.default_rng(0), m, fidelity="packet",
                   engine="vectorized")
    rf, wr = timed(simulate_allgather, p, n, fab, wk,
                   np.random.default_rng(0), m, fidelity="packet",
                   engine="reference")
    assert ra.completed and (ra.time, ra.bytes_total, ra.bytes_recovery) \
        == (rf.time, rf.bytes_total, rf.bytes_recovery)
    rows.append((f"pscale.AG.P{p}.vec_wall_s", round(wv, 4),
                 f"allgather {n >> 20} MiB x{m} chains, vectorized"))
    rows.append((f"pscale.AG.P{p}.ref_wall_s", round(wr, 4),
                 f"allgather {n >> 20} MiB x{m} chains, reference"))
    rows.append((f"pscale.AG.P{p}.ref_vs_vec_speedup",
                 round(wr / max(wv, 1e-9), 1),
                 "reference / vectorized wall-clock"))
    # dense big-row allgather (DESIGN §9/§13): few hosts, >= 16 MiB merged
    # rows — the regime the residue-class-parallel pool scan closed. The
    # engine="auto" fallback is retired, so this point carries a hard
    # vectorized >= reference floor (the closure must not silently reopen).
    if ag_dense is not None:
        p, n, m = ag_dense
        ra, wv = timed(simulate_allgather, p, n, fab, wk,
                       np.random.default_rng(0), m, fidelity="packet",
                       engine="vectorized")
        rf, wr = timed(simulate_allgather, p, n, fab, wk,
                       np.random.default_rng(0), m, fidelity="packet",
                       engine="reference")
        assert ra.completed and (ra.time, ra.bytes_total, ra.bytes_recovery) \
            == (rf.time, rf.bytes_total, rf.bytes_recovery)
        dense = wr / max(wv, 1e-9)
        rows.append((f"pscale.AGdense.P{p}.vec_wall_s", round(wv, 4),
                     f"allgather {n >> 20} MiB x{m} chains, vectorized"))
        rows.append((f"pscale.AGdense.P{p}.ref_wall_s", round(wr, 4),
                     f"allgather {n >> 20} MiB x{m} chains, reference"))
        rows.append((f"pscale.AGdense.P{p}.ref_vs_vec_speedup",
                     round(dense, 2), f"floor {min_dense_speedup:g}x"))
        assert dense >= min_dense_speedup, (dense, wr, wv)
    return rows


def packet_scale_sweep_smoke():
    """CI-sized packet_scale_sweep: keeps the acceptance-gating 10k-host /
    1 GiB reference-vs-vectorized speedup (the one long row, ~2 min of
    reference wall-clock) but trims the mid-scale reference points."""
    return packet_scale_sweep(grid=((512, 1 << 26), (10000, GIB)),
                              ref_grid=((512, 1 << 26),),
                              ag_point=(256, 1 << 20, 4))


def dpa_scaling_sweep(thread_grid=(1, 2, 4, 8, 16)):
    """Figs 13/14/16 + §VII-d on the EVENT-level DPA progress engine
    (core/dpa_engine.py): thread-scaling and saturation measured by driving
    the simulator with line-rate traces — multithreading hides the
    stalled-on-memory cycles mechanistically instead of applying the
    analytic T^e envelope — with core/dpa.py retained as the cross-check
    oracle (full-core capacity and the Fig-16 margin must land within 10%).
    Also pins the §VII-d offload economics: one DPA core vs one host core
    (Fig 5), the FSDP freed-host-cycles benefit, and the cycle-stealing
    cost of running the recovery protocol on the receive contexts."""
    from repro.core import dpa_engine as de
    from repro.core.engine import simulate_fsdp_step
    from repro.core.simulator import simulate_broadcast as sim_bcast

    rows = []
    # -- Figs 13/14: receive throughput vs threads, saturation thread counts
    for t in ("UD", "UC"):
        for n in thread_grid:
            ev = de.sustained_tput_event(de.EventDpaParams.from_table1(t, n))
            rows.append((f"dpaev.fig13.{t}.{n}threads_gibs",
                         round(ev / GIB, 2),
                         f"analytic {dpa.sustained_tput(dpa.DpaConfig(t, n))/GIB:.2f}"))
        sat_ev = de.threads_to_saturate_event(t)
        sat_an = dpa.threads_to_saturate(t)
        rows.append((f"dpaev.fig14.{t}.sat_vs_analytic_x",
                     round(sat_ev / sat_an, 3),
                     f"event saturates 200G at {sat_ev} threads, "
                     f"analytic at {sat_an}"))
    assert de.threads_to_saturate_event("UC") <= 4          # paper: ~4
    assert 8 <= de.threads_to_saturate_event("UD") <= 16    # paper: 8-16
    # full-core capacity anchors: the event engine must land on the oracle
    for t in ("UD", "UC"):
        ev = de.pool_tput_event(de.EventDpaParams.from_table1(t, 16))
        an = dpa.pool_tput(dpa.DpaConfig(t, 16))
        rows.append((f"dpaev.{t}.core16_vs_oracle_x", round(ev / an, 3),
                     f"event {ev/GIB:.2f} vs pool_tput {an/GIB:.2f} GiB/s"))
        assert abs(ev / an - 1.0) < 0.10, (t, ev, an)

    # -- Fig 16: 64 B chunks, 128 threads vs the 1.6 Tbit/s arrival rate
    need = dpa.link_chunk_arrival_rate(dpa.LINK_1600G_BYTES)
    rate = de.sustained_chunk_rate_event(
        de.EventDpaParams.from_table1("UD", 128), need, chunk_bytes=64)
    an_rate = dpa.sustained_chunk_rate(
        dpa.DpaConfig("UD", 128, 64, dpa.LINK_1600G_BYTES))
    rows.append(("dpaev.fig16.UD128_vs_required_x", round(rate / need, 3),
                 f"{rate/1e6:.1f} of {need/1e6:.1f} Mchunks/s"))
    assert de.tbit_feasible_event("UD", 128)
    assert not de.tbit_feasible_event("UD", 8)
    assert abs(rate / an_rate - 1.0) < 0.10, (rate, an_rate)  # 10% of oracle

    # -- Fig 5 / §VII-d: one multithreaded DPA core vs one host CPU core
    dpa_core = de.sustained_tput_event(de.EventDpaParams.from_table1("UD", 16))
    host_core = de.pool_tput_event(de.EventDpaParams.host_cpu(1))
    rows.append(("dpaev.fig5.dpa_core_vs_host_core_x",
                 round(dpa_core / host_core, 3),
                 f"host core {host_core/GIB:.1f} GiB/s cannot hold 200G"))
    assert dpa_core / host_core > 1.2 and host_core < dpa.LINK_200G_BYTES

    # -- freed-host-cycles benefit in the FSDP bubble accounting
    kw = dict(n_layers=4, layer_bytes=64e6, p=16, policy="split")
    d = simulate_fsdp_step(**kw)
    h = simulate_fsdp_step(**kw, progress_engine="host", host_cores=2)
    rows.append(("dpaev.fsdp.host_vs_dpa_step_x",
                 round(h.step_time / d.step_time, 3),
                 f"host bubbles {h.bubble_fraction:.3f} vs DPA "
                 f"{d.bubble_fraction:.3f}"))
    assert h.step_time > d.step_time
    assert h.bubble_fraction > d.bubble_fraction

    # -- cycle stealing: the same lossy Broadcast through the scalar pool
    # and through the event engine (NACK + retransmit posting contend with
    # the receive datapath) — the event fidelity can only be slower
    fab = FabricParams(jitter=0.0)
    wk = WorkerParams(n_recv_workers=16)
    scl = sim_bcast(16, 1 << 20, fab, wk, np.random.default_rng(0),
                    fidelity="packet", loss=1e-3)
    evt = sim_bcast(16, 1 << 20, fab, wk, np.random.default_rng(0),
                    fidelity="packet", loss=1e-3, dpa_fidelity="event")
    rows.append(("dpaev.P16.event_vs_scalar_x",
                 round(evt.time / scl.time, 4),
                 f"event {evt.time*1e6:.1f}us scalar {scl.time*1e6:.1f}us"))
    assert evt.completed and evt.time >= scl.time - 1e-12
    return rows


def dpa_scaling_smoke():
    """CI-sized dpa_scaling_sweep: the full sweep is already seconds-scale
    (event traces are tens of thousands of CQEs), so smoke == full grid."""
    return dpa_scaling_sweep()


def schedule_ir_sweep():
    """Collective Schedule IR smoke: Allreduce lowered from ONE schedule
    graph, comparing the RS∘multicast-AG composition (the paper's AG as the
    second phase) against the classical ring allreduce — wall time on the
    abstract full-duplex NIC and switch-port bytes on a routed fat-tree
    (Insight 1 transplanted to allreduce) — plus the per-fabric chain
    autotune. All rows are deterministic model ratios (jitter 0, loss 0)."""
    from repro.core import sched_ir

    fab = FabricParams(jitter=0.0)
    wk = WorkerParams(n_recv_workers=8)
    n = 1 << 22                                   # 4 MiB per-rank buffer
    rows = []
    for p in (16, 64):
        mc = sched_ir.execute(sched_ir.build_allreduce(p, n, m=p), fab, wk,
                              np.random.default_rng(0))
        ring = sched_ir.execute(sched_ir.build_allreduce(p, n), fab, wk,
                                np.random.default_rng(0))
        rows.append((f"schedir.P{p}.allreduce_ring_vs_mcast_time_x",
                     round(ring.time / mc.time, 4),
                     f"ring={ring.time*1e6:.1f}us mcast={mc.time*1e6:.1f}us"))
        topo = FatTree(k=8, n_hosts=p, b_host=fab.b_link)
        mc_r = sched_ir.execute(sched_ir.build_allreduce(p, n, m=p), fab, wk,
                                np.random.default_rng(0), topology=topo)
        mc_bytes = sum(mc_r.link_bytes.values())
        topo = FatTree(k=8, n_hosts=p, b_host=fab.b_link)
        ring_r = sched_ir.execute(sched_ir.build_allreduce(p, n), fab, wk,
                                  np.random.default_rng(0), topology=topo)
        ring_bytes = sum(ring_r.link_bytes.values())
        # Insight 1 on the composed collective: switch replication must cut
        # the fabric bytes of the AG phase
        assert mc_bytes < ring_bytes, (p, mc_bytes, ring_bytes)
        rows.append((f"schedir.P{p}.allreduce_mcast_vs_ring_fabric_bytes_x",
                     round(mc_bytes / ring_bytes, 4),
                     f"mcast={mc_bytes/GIB:.3f}GiB ring={ring_bytes/GIB:.3f}GiB"))
    best, times = sched_ir.autotune_chains(
        sched_ir.build_allgather, p=64, n_bytes=1 << 18, fabric=fab,
        workers=wk)
    assert best == 64, times                     # flat fabric: full parallelism
    rows.append(("schedir.autotune_flat_best_m", best,
                 f"candidates={sorted(times)}"))
    thin = FatTree(k=8, n_hosts=16, b_host=fab.b_link, oversubscription=4.0)
    best_thin, _ = sched_ir.autotune_chains(
        sched_ir.build_allgather, thin, p=16, n_bytes=1 << 18, fabric=fab,
        workers=wk)
    rows.append(("schedir.autotune_oversub4_best_m", best_thin,
                 "16 hosts, 4x oversubscribed fat-tree"))
    return rows


def search_sweep():
    """Derived schedules (core/sched_search.py): on the oversubscribed
    fat-tree AND the torus the searched allreduce must beat the best
    hand-written builder at fluid fidelity (strictly on at least one),
    validate at packet fidelity under loss, and report its lower-bound
    certificate — all inside the smoke wall budget. The eval cache is the
    persistent one ($REPRO_EVAL_CACHE when set — nightly CI carries it
    across runs as an artifact); a warmed re-search of both fabrics then
    self-verifies the cache contract: >= 3x faster than the cold fluid
    sweep, identical winners."""
    from repro.core import sched_search

    cache = sched_search.EvalCache.persistent()
    p, n = 16, 16 << 20
    scenarios = [
        ("fattree_os4", FatTree(k=8, n_hosts=p, oversubscription=4.0)),
        ("torus4x4", Torus2D(4, 4)),
    ]
    rows = []
    ratios = []
    t0 = time.perf_counter()
    for label, topo in scenarios:
        r = sched_search.search("allreduce", p, n, topology=topo,
                                loss=1e-3, cache=cache)
        assert r.packet_validated, f"{label}: winner failed packet validation"
        assert r.certificate.ratio >= 1.0 - 1e-9, \
            f"{label}: winner beat its own admissible bound"
        ratio = r.searched_vs_best_builder
        ratios.append(ratio)
        rows.append((f"search.{label}.searched_vs_best_builder_x",
                     round(ratio, 4),
                     f"{r.winner.name} vs {r.best_builder.name}"))
        rows.append((f"search.{label}.bound_cert_x",
                     round(r.certificate.ratio, 4),
                     f"winner/bound, binding={r.certificate.binding}"))
        rows.append((f"search.{label}.fabric_bytes_x",
                     round(r.winner_fabric_bytes
                           / r.best_builder_fabric_bytes, 4),
                     f"routed bytes, winner={r.winner_fabric_bytes/GIB:.3f}"
                     f"GiB"))
    wall = time.perf_counter() - t0
    assert all(x <= 1.0 + 1e-9 for x in ratios), ratios
    assert min(ratios) < 1.0, f"no strict win over builders: {ratios}"
    assert wall < 30.0, f"search sweep blew the smoke budget: {wall:.1f}s"
    rows.append(("search.allreduce_p16_wall_s", round(wall, 3),
                 "both fabrics, shared eval cache"))
    # warm-cache contract: a cold fluid sweep (fresh cache, no packet
    # validation so the comparison isolates the searcher) vs the same sweep
    # served from the now-populated cache — the memoization must buy >= 3x
    # and change nothing about the winners
    t_cold = time.perf_counter()
    for label, topo in scenarios:
        sched_search.search("allreduce", p, n, topology=topo,
                            validate_packet=False,
                            cache=sched_search.EvalCache())
    wall_cold = time.perf_counter() - t_cold
    t_warm = time.perf_counter()
    warm_hits0 = cache.hits
    for label, topo in scenarios:
        rw = sched_search.search("allreduce", p, n, topology=topo,
                                 validate_packet=False, cache=cache)
        assert rw.cache_hits == rw.evaluations, (label, rw.cache_hits)
    wall_warm = time.perf_counter() - t_warm
    warm_x = wall_cold / max(wall_warm, 1e-9)
    rows.append(("search.warm_cache_speedup", round(warm_x, 1),
                 f"cold {wall_cold:.2f}s vs warm {wall_warm:.3f}s, "
                 f"{cache.hits - warm_hits0} hits"))
    assert warm_x >= 3.0, (warm_x, wall_cold, wall_warm)
    cache.save()
    # informational (ungated: neither a ratio nor a wall row) — the nightly
    # CI job lifts this into $GITHUB_STEP_SUMMARY next to the uploaded
    # persistent-cache artifact
    total_evals = cache.hits + cache.misses
    rows.append(("search.eval_cache_hit_rate",
                 round(cache.hits / max(total_evals, 1), 4),
                 f"{cache.hits}/{total_evals} evals served from cache"
                 + (f"; persisted to {cache.path}" if cache.path else "")))
    return rows


def hier_fabric_sweep():
    """Tiered island fabrics (core/topology.IslandFatTree): the searched
    mixed-transport allgather must strictly beat BOTH the flat multicast
    builder and the pure island-ring builder at P in {64, 256}, carry a
    BoundCertificate ratio >= 1 from the tiered analytic bounds, and shed
    switched-tier fabric bytes onto the island tier (FlexLink-style,
    arXiv:2510.15882). All gated rows are deterministic model ratios."""
    from repro.core import sched_ir, sched_search
    from repro.core.topology import IslandFatTree

    fab = FabricParams(jitter=0.0)
    wk = WorkerParams(n_recv_workers=8)
    n = 1 << 20                                   # 1 MiB per-rank buffer
    cache = sched_search.EvalCache()
    rows = []
    t0 = time.perf_counter()
    for k, p in ((8, 64), (16, 256)):
        topo = IslandFatTree(k, p, island_size=8)
        hosts = list(range(p))
        r = sched_search.search("allgather", p, n, topology=topo,
                                hosts=hosts, cache=cache)
        assert r.winner.sched.kind == "hier_allgather", r.winner.name
        assert r.packet_validated, f"P={p}: winner failed packet validation"
        assert r.certificate.ratio >= 1.0 - 1e-9, \
            f"P={p}: winner beat its own admissible tiered bound"
        flat_t = min(row.time for row in r.table
                     if row.name.startswith("builder:mcast")
                     and row.time is not None)
        ring_t = next(row.time for row in r.table
                      if row.name == "builder:ring")
        assert r.winner_time < flat_t and r.winner_time < ring_t, \
            (p, r.winner_time, flat_t, ring_t)
        rows.append((f"hier.P{p}.searched_vs_flat_mcast_x",
                     round(r.winner_time / flat_t, 4),
                     f"{r.winner.name} vs best flat multicast"))
        rows.append((f"hier.P{p}.searched_vs_island_ring_x",
                     round(r.winner_time / ring_t, 4),
                     f"{r.winner.name} vs routed unicast ring"))
        rows.append((f"hier.P{p}.bound_cert_x",
                     round(r.certificate.ratio, 4),
                     f"winner/bound, binding={r.certificate.binding}"))
        # per-tier fabric bytes: the winner's switched-tier relief is the
        # headline — total routed bytes barely move (the redistribution
        # still touches every rank), they just ride the island cables
        topo.reset()
        win = sched_ir.execute(r.winner.sched, fab, wk,
                               np.random.default_rng(0), topology=topo,
                               hosts=hosts)
        win_split = topo.tier_split(win.link_bytes)
        topo.reset()
        flat = sched_ir.execute(sched_ir.build_allgather(p, n, p), fab, wk,
                                np.random.default_rng(0), topology=topo,
                                hosts=hosts)
        flat_split = topo.tier_split(flat.link_bytes)
        assert win_split["switched"] < flat_split["switched"], (p, win_split)
        assert flat_split.get("island", 0.0) == 0.0
        rows.append((f"hier.P{p}.switched_bytes_vs_flat_x",
                     round(win_split["switched"] / flat_split["switched"], 4),
                     f"winner switched={win_split['switched']/GIB:.3f}GiB "
                     f"island={win_split.get('island', 0.0)/GIB:.3f}GiB"))
    wall = time.perf_counter() - t0
    rows.append(("hier.allgather_search_wall_s", round(wall, 3),
                 "P=64+256 island fabrics, shared eval cache"))
    return rows


def fsdp_contention_sweep():
    """Abstract's opening claim: interleaved AG/RS contend for injection
    bandwidth; the multicast schedule and the Insight-2 direction split cut
    the resulting pipeline bubbles (core/engine.py FSDP timeline)."""
    data = sweep_fsdp_contention(ps=(16, 64), layer_bytes=(64e6, 256e6),
                                 n_layers=8)
    rows = []
    bubbles = {}
    for r in data:
        key = (r["p"], r["layer_bytes"])
        bubbles.setdefault(key, {})[r["policy"]] = r["bubble_fraction"]
        rows.append((
            f"fsdp.P{r['p']}.{int(r['layer_bytes']/1e6)}MBlayer."
            f"{r['policy']}.bubble_frac",
            round(r["bubble_fraction"], 4),
            f"step={r['step_time']*1e3:.1f}ms "
            f"util={max(r['link_utilization'].values()):.2f}",
        ))
    for key, b in bubbles.items():
        assert b["split"] < b["naive"], (key, b)   # strictly lower bubbles
    return rows


def training_run_sweep():
    """GPT-scale compute+comm co-sim (core/train_sim.py): the registry
    span smollm-135m -> granite-34b end-to-end at three host scales, the
    split-vs-naive MFU win on an oversubscribed fabric, the loss
    degradation curve and the fidelity ordering. All gated rows are
    deterministic model ratios (machine-independent)."""
    from repro.configs.registry import training_sweep_archs
    from repro.core.train_sim import simulate_training_run

    fab = FabricParams(jitter=0.0)
    rows = []
    t0 = time.perf_counter()

    # ---- host-count scaling: every sweep model x {16, 64, 256} hosts
    for arch in training_sweep_archs():
        steps = {}
        for n_hosts in (16, 64, 256):
            r = simulate_training_run(arch, n_hosts=n_hosts, policy="split",
                                      fabric=fab)
            assert 0.0 < r.mfu <= 1.0, (arch, n_hosts, r.mfu)
            steps[n_hosts] = r.step_time
        assert steps[16] > steps[64] > steps[256], (arch, steps)
        rows.append((f"train.{arch}.scale16to256_x",
                     round(steps[16] / steps[256], 4),
                     f"step 16h={steps[16]:.3f}s 256h={steps[256]:.4f}s"))

    # ---- the split-policy MFU win at oversubscription 4 (Insight 2 on
    # the fabric: AG_mc down + RS_inc up vs the self-colliding ring)
    pols = {}
    for pol in ("naive", "split"):
        pols[pol] = simulate_training_run(
            "smollm-135m", n_hosts=16, policy=pol, fabric=fab,
            topology=FatTree(k=8, n_hosts=16, oversubscription=4.0))
    assert pols["split"].mfu > pols["naive"].mfu, pols
    assert pols["split"].step_time < pols["naive"].step_time
    rows.append(("train.smollm-135m.P16.split_vs_naive_mfu_x",
                 round(pols["split"].mfu / pols["naive"].mfu, 4),
                 f"split mfu={pols['split'].mfu:.3f} "
                 f"naive={pols['naive'].mfu:.3f} (oversub 4 fat-tree)"))
    for pol, r in pols.items():
        rows.append((f"train.smollm-135m.P16.{pol}.bubble_frac",
                     round(r.bubble_fraction, 4),
                     f"step={r.step_time*1e3:.1f}ms mfu={r.mfu:.3f}"))
    assert pols["split"].bubble_fraction < pols["naive"].bubble_fraction

    # ---- loss degradation + fidelity ordering (abstract fabric)
    fl = simulate_training_run("smollm-135m", n_hosts=16, policy="split",
                               fabric=fab)
    an = simulate_training_run("smollm-135m", n_hosts=16, policy="split",
                               fabric=fab, fidelity="analytic")
    pk = {}
    for q in (0.001, 0.01):
        pk[q] = simulate_training_run(
            "smollm-135m", n_hosts=16, policy="split", fabric=fab,
            fidelity="packet", loss=q, rng=np.random.default_rng(0))
    assert an.step_time <= fl.step_time + 1e-12
    assert fl.step_time <= pk[0.001].step_time <= pk[0.01].step_time + 1e-9
    assert pk[0.01].mfu <= pk[0.001].mfu <= fl.mfu
    rows.append(("train.smollm-135m.P16.loss1pct_step_x",
                 round(pk[0.01].step_time / fl.step_time, 4),
                 f"packet(q=1%) vs fluid; mfu {fl.mfu:.3f}->"
                 f"{pk[0.01].mfu:.3f}"))
    rows.append(("train.smollm-135m.P16.analytic_vs_fluid_x",
                 round(an.step_time / fl.step_time, 4),
                 "closed-form lower bound / fluid engine (<= 1)"))

    # ---- pipeline composition at scale (1F1B bubble is exact model math)
    pp_r = simulate_training_run("granite-34b", n_hosts=64, pp=4,
                                 grad_accum=8, policy="split", fabric=fab)
    assert pp_r.pipeline_bubble_fraction == (4 - 1) / (8 + 4 - 1)
    rows.append(("train.granite-34b.P64.pp4ga8.bubble_frac",
                 round(pp_r.bubble_fraction, 4),
                 f"dp={pp_r.dp} step={pp_r.step_time:.2f}s "
                 f"mfu={pp_r.mfu:.3f} "
                 f"pipe_bubble={pp_r.pipeline_bubble_fraction:.3f}"))

    rows.append(("train.sweep_wall_s",
                 round(time.perf_counter() - t0, 3),
                 "3 models x 3 scales + routed policy pair + loss curve"))
    return rows


def measured_protocol_micro():
    """Measured on THIS machine: protocol hot-path microbenchmarks (us/call)."""
    rows = []
    buf = bytes(np.random.default_rng(0).integers(0, 256, 1 << 20, dtype=np.uint8))
    t0 = time.perf_counter()
    chunks = protocol.segment(buf)
    dt = (time.perf_counter() - t0) * 1e6
    rows.append(("micro.segment_1MiB_us", round(dt, 1), f"{len(chunks)} chunks"))
    leaf = protocol.LeafReceiver(len(buf))
    t0 = time.perf_counter()
    for c in chunks:
        leaf.deliver(c)
    dt = (time.perf_counter() - t0) * 1e6 / len(chunks)
    rows.append(("micro.deliver_per_chunk_us", round(dt, 2), "bitmap+copy"))
    rng = np.random.default_rng(1)
    t0 = time.perf_counter()
    r = simulate_broadcast(32, 1 << 20, FabricParams(p_drop=0.001),
                           WorkerParams(8), rng)
    dt = (time.perf_counter() - t0) * 1e6
    rows.append(("micro.simulate_bcast32_us", round(dt, 0),
                 f"recovered={r.recovered}"))
    return rows


def measured_jax_collectives():
    """Measured on THIS machine (8 fake CPU devices, subprocess): wall time of
    the shard_map collective kernels. The host has no duplex ICI links, so
    bidi/concurrent gains show structurally (validated in tests), not in
    host wall-clock; the rows document measured reality."""
    import os
    import subprocess
    import sys

    code = """
import time, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core import collectives as C
mesh = jax.make_mesh((8,), ('x',))
n = 1 << 20
full = jnp.arange(8 * n, dtype=jnp.float32)
sharded = jax.device_put(full, NamedSharding(mesh, P('x')))
per_dev = jnp.stack([full * (i + 1) for i in range(8)])
def t(f, *a):
    f(*a)[0].block_until_ready() if isinstance(f(*a), tuple) else jax.block_until_ready(f(*a))
    t0 = time.perf_counter()
    for _ in range(5):
        jax.block_until_ready(f(*a))
    return (time.perf_counter() - t0) / 5 * 1e6
for mode in ['ring', 'bidi', 'bcast']:
    ag = C.make_allgather(mesh, 'x', mode, n_chains=4 if mode == 'bcast' else None)
    print(f'collective.allgather_{mode}_32MB_us,{t(ag, sharded):.0f},measured 8dev')
rs = C.make_reduce_scatter(mesh, 'x', 'bidi')
print(f'collective.reduce_scatter_bidi_32MB_us,{t(rs, per_dev.reshape(-1)):.0f},measured 8dev')
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(__file__), "..", "src"
    ) + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    rows = []
    for line in res.stdout.splitlines():
        if line.startswith("collective."):
            name, val, der = line.split(",", 2)
            rows.append((name, val, der))
    assert rows, res.stderr[-2000:]
    return rows


ALL = [
    fig2_traffic_model, fig5_cpu_datapath, fig10_critical_path,
    fig11_throughput_188, fig12_traffic_savings, table1_datapath,
    fig13_14_thread_scaling, fig15_chunk_sizes, fig16_tbit,
    appendix_b_speedup, dpa_scaling_sweep, fsdp_contention_sweep,
    fabric_sweep, protocol_loss_sweep, packet_scale_sweep,
    multi_job_contention,
    schedule_ir_sweep, search_sweep, hier_fabric_sweep,
    training_run_sweep,
    measured_protocol_micro, measured_jax_collectives,
]

# seconds-scale subset for benchmarks/run.py --smoke / CI: the FSDP
# contention grid, the routed fabric sweep (capped at 512 hosts so its
# traffic-conservation and Insight-1 asserts run on every check in < ~60 s),
# the packet-protocol loss sweep (constant-time recovery + unicast
# crossover), the event-level DPA scaling sweep (Figs 13/14/16 + offload
# economics), the multi-job contention scenario and the schedule-IR
# allreduce-vs-ring sweep (ring/mcast time + fabric-byte ratios, autotune),
# the packet-engine scale sweep (vectorized-vs-reference wall-clock,
# including the 10k-host / 1 GiB speedup floor), and the tiered island
# fabric sweep (searched mixed-transport allgather vs flat builders with
# per-tier fabric-byte relief at P=64/256 — the ISSUE-8 acceptance gates),
# and the training-run co-sim sweep (GPT-small -> 34B step time / MFU /
# bubble fraction at 16-256 hosts, split-vs-naive MFU win, loss curve)
SMOKE = [fsdp_contention_sweep, fabric_sweep_smoke, protocol_loss_sweep_smoke,
         dpa_scaling_smoke, multi_job_contention, schedule_ir_sweep,
         search_sweep, packet_scale_sweep_smoke, hier_fabric_sweep,
         training_run_sweep]
