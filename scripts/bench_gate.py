#!/usr/bin/env python
"""Benchmark regression gate: diff the smoke report's derived ratios against
the committed baseline and fail CI when any drifts past tolerance.

The gated metrics (benchmarks/run.py RATIO_SUFFIXES) are deterministic model
outputs — bubble fractions, traffic-reduction and slowdown factors, the
protocol loss-crossover — not wall-clock, so they are machine-independent
and the tolerance only absorbs intentional-model-change review, never timer
noise. Wall times are carried in the report for humans but never gated: the
``wall_clock`` section (packet_scale_sweep's engine timings and speedups)
and per-scenario wall_s are printed as an informational drift report when a
baseline carries reference values, and never affect the exit code.

    python scripts/bench_gate.py                       # gate current vs baseline
    python scripts/bench_gate.py --update              # bless current as baseline
    python scripts/bench_gate.py --tolerance 0.05      # tighter band

Exit codes: 0 ok, 1 regression (or missing/new ratio), 2 usage error.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_BASELINE = os.path.join(REPO, "benchmarks", "baseline_smoke.json")
DEFAULT_CURRENT = os.path.join(REPO, "BENCH_smoke.json")


def load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def compare(baseline: dict, current: dict, tolerance: float) -> list[str]:
    """Regression messages (empty = gate passes). A ratio regresses when it
    deviates from baseline by more than ``tolerance`` relative (with a small
    absolute floor for near-zero ratios); added or removed ratios must be
    blessed explicitly with --update."""
    base = baseline.get("ratios", {})
    cur = current.get("ratios", {})
    problems = []
    for name in sorted(set(base) | set(cur)):
        if name not in cur:
            problems.append(f"MISSING  {name}: in baseline but not in report")
            continue
        if name not in base:
            problems.append(f"NEW      {name}={cur[name]:g}: not in baseline "
                            f"(bless with --update)")
            continue
        if base[name] is None or cur[name] is None:
            # null = run.py's non-finite sentinel (e.g. crossover never
            # reached in the loss grid); only consistent nulls pass
            if base[name] != cur[name]:
                problems.append(f"DRIFT    {name}: {base[name]} -> "
                                f"{cur[name]} (non-finite sentinel)")
            continue
        b, c = float(base[name]), float(cur[name])
        if math.isnan(b) or math.isnan(c):
            # NaN compares False against everything — catch it explicitly or
            # a corrupted metric sails through the gate
            problems.append(f"INVALID  {name}: {b:g} -> {c:g} (NaN)")
            continue
        if math.isinf(b) or math.isinf(c):
            if b != c:
                problems.append(f"DRIFT    {name}: {b:g} -> {c:g}")
            continue
        denom = max(abs(b), 1e-9)
        rel = abs(c - b) / denom
        if rel > tolerance and abs(c - b) > 1e-6:
            problems.append(
                f"DRIFT    {name}: {b:g} -> {c:g} ({rel*100:.1f}% > "
                f"{tolerance*100:.0f}% tolerance)")
    if current.get("failures"):
        problems.append(f"FAILURES {current['failures']} benchmark(s) failed")
    return problems


def wall_report(baseline: dict, current: dict) -> list[str]:
    """Informational wall-clock lines — printed, never gated. Covers the
    report's ``wall_clock`` rows (engine timings / speedups from
    packet_scale_sweep); drift vs baseline is shown when the baseline file
    happens to carry wall_clock values (the blessed baseline normally does
    not — wall-clock is machine-dependent by design)."""
    base = baseline.get("wall_clock", {}) or {}
    cur = current.get("wall_clock", {}) or {}
    lines = []
    for name in sorted(cur):
        c = cur[name]
        if name in base and base[name] and c:
            rel = (float(c) - float(base[name])) / max(abs(float(base[name])),
                                                       1e-9)
            lines.append(f"{name}: {c:g} ({rel:+.0%} vs baseline "
                         f"{base[name]:g})")
        else:
            lines.append(f"{name}: {c:g}" if c is not None
                         else f"{name}: null")
    return lines


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--current", default=DEFAULT_CURRENT)
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="relative drift allowed per ratio (default 10%%)")
    ap.add_argument("--update", action="store_true",
                    help="bless the current report as the new baseline")
    args = ap.parse_args()

    if not os.path.exists(args.current):
        print(f"bench_gate: no report at {args.current}; run "
              f"`python -m benchmarks.run --smoke` first", file=sys.stderr)
        return 2
    if args.update:
        # bless ONLY the gated ratios: wall_s etc. are machine-dependent and
        # would churn the committed baseline with timing noise
        ratios = load(args.current).get("ratios", {})
        with open(args.baseline, "w") as f:
            json.dump({"ratios": ratios}, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"bench_gate: blessed {args.current} -> {args.baseline} "
              f"({len(ratios)} ratios)")
        return 0
    if not os.path.exists(args.baseline):
        print(f"bench_gate: no baseline at {args.baseline}; bless one with "
              f"--update", file=sys.stderr)
        return 2

    baseline, current = load(args.baseline), load(args.current)
    problems = compare(baseline, current, args.tolerance)
    n = len(current.get("ratios", {}))
    walls = wall_report(baseline, current)
    if walls:
        print(f"bench_gate: wall-clock (informational, {len(walls)} rows, "
              f"never gated):")
        for w in walls:
            print(f"  {w}")
    if problems:
        print(f"bench_gate: FAIL ({len(problems)} problem(s), {n} ratios "
              f"checked at {args.tolerance*100:.0f}% tolerance)")
        for p in problems:
            print(f"  {p}")
        return 1
    print(f"bench_gate: OK ({n} ratios within {args.tolerance*100:.0f}% of "
          f"baseline)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
