#!/usr/bin/env python
"""Benchmark regression gate: diff the smoke report's derived ratios against
the committed baseline and fail CI when any drifts past tolerance.

The gated metrics (benchmarks/run.py RATIO_SUFFIXES) are deterministic model
outputs — bubble fractions, traffic-reduction and slowdown factors, the
protocol loss-crossover — not wall-clock, so they are machine-independent
and the tolerance only absorbs intentional-model-change review, never timer
noise.

The report's ``wall_clock`` rows are gated too, but loosely and
machine-normalized: every ``_wall_s`` row is divided by the report's own
``wall.calibration_wall_s`` (a fixed numpy workload timed in the same run,
benchmarks/run.py), so machine speed cancels in the ratio-of-ratios and
only genuine order-of-magnitude slowdowns trip the generous
``--wall-tolerance``; ``_speedup`` rows are already machine-internal ratios
and compare raw. New or vanished wall rows (and rows lacking a calibration
reference) stay informational — the ratio gate owns coverage.

    python scripts/bench_gate.py                       # gate current vs baseline
    python scripts/bench_gate.py --update              # bless current as baseline
    python scripts/bench_gate.py --tolerance 0.05      # tighter ratio band
    python scripts/bench_gate.py --wall-tolerance 1.0  # tighter wall band

Exit codes: 0 ok, 1 ratio regression (or missing/new ratio, or benchmark
failures), 2 usage error, 3 ONLY loosely-gated wall-clock rows drifted
(ratios all green — likely machine noise, not a model regression; CI keeps
the codes apart so a wall-only trip reads differently at a glance). When
``$GITHUB_STEP_SUMMARY`` is set (GitHub Actions), a markdown verdict table
of every drifted row lands in the job summary.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_BASELINE = os.path.join(REPO, "benchmarks", "baseline_smoke.json")
DEFAULT_CURRENT = os.path.join(REPO, "BENCH_smoke.json")

#: run.py's fixed-workload timing row — the machine-speed normalizer for
#: the _wall_s rows (never itself gated)
CALIBRATION_ROW = "wall.calibration_wall_s"


def load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def compare(baseline: dict, current: dict, tolerance: float) -> list[str]:
    """Regression messages (empty = gate passes). A ratio regresses when it
    deviates from baseline by more than ``tolerance`` relative (with a small
    absolute floor for near-zero ratios); added or removed ratios must be
    blessed explicitly with --update."""
    base = baseline.get("ratios", {})
    cur = current.get("ratios", {})
    problems = []
    for name in sorted(set(base) | set(cur)):
        if name not in cur:
            problems.append(f"MISSING  {name}: in baseline but not in report")
            continue
        if name not in base:
            problems.append(f"NEW      {name}={cur[name]:g}: not in baseline "
                            f"(bless with --update)")
            continue
        if base[name] is None or cur[name] is None:
            # null = run.py's non-finite sentinel (e.g. crossover never
            # reached in the loss grid); only consistent nulls pass
            if base[name] != cur[name]:
                problems.append(f"DRIFT    {name}: {base[name]} -> "
                                f"{cur[name]} (non-finite sentinel)")
            continue
        b, c = float(base[name]), float(cur[name])
        if math.isnan(b) or math.isnan(c):
            # NaN compares False against everything — catch it explicitly or
            # a corrupted metric sails through the gate
            problems.append(f"INVALID  {name}: {b:g} -> {c:g} (NaN)")
            continue
        if math.isinf(b) or math.isinf(c):
            if b != c:
                problems.append(f"DRIFT    {name}: {b:g} -> {c:g}")
            continue
        denom = max(abs(b), 1e-9)
        rel = abs(c - b) / denom
        if rel > tolerance and abs(c - b) > 1e-6:
            problems.append(
                f"DRIFT    {name}: {b:g} -> {c:g} ({rel*100:.1f}% > "
                f"{tolerance*100:.0f}% tolerance)")
    if current.get("failures"):
        problems.append(f"FAILURES {current['failures']} benchmark(s) failed")
    return problems


def wall_compare(baseline: dict, current: dict,
                 tolerance: float) -> tuple[list[str], list[str]]:
    """Loose machine-normalized wall-clock gate -> (problems, info lines).
    ``_wall_s`` rows gate on (current / current-calibration) vs
    (baseline / baseline-calibration) — the ratio-of-ratios a faster or
    slower machine leaves unchanged; ``_speedup`` rows gate raw. Rows
    missing on either side, null sentinels, and rows without a calibration
    reference are informational only."""
    base = baseline.get("wall_clock", {}) or {}
    cur = current.get("wall_clock", {}) or {}
    b_cal, c_cal = base.get(CALIBRATION_ROW), cur.get(CALIBRATION_ROW)
    problems, info = [], []
    for name in sorted(set(base) | set(cur)):
        if name == CALIBRATION_ROW:
            if c_cal:
                info.append(f"{name}: {c_cal:g} (normalizer)")
            continue
        b, c = base.get(name), cur.get(name)
        if b is None or c is None:
            v = "null" if c is None else f"{c:g}"
            tag = ("not in baseline" if name not in base
                   else "missing from report" if name not in cur
                   else "null sentinel")
            info.append(f"{name}: {v} ({tag}; informational)")
            continue
        if name.endswith("_speedup"):
            bn, cn = float(b), float(c)
            what = "speedup"
        elif b_cal and c_cal:
            bn, cn = float(b) / float(b_cal), float(c) / float(c_cal)
            what = "normalized wall"
        else:
            info.append(f"{name}: {c:g} (no calibration row; informational)")
            continue
        rel = abs(cn - bn) / max(abs(bn), 1e-9)
        line = (f"{name}: {c:g} ({what} {bn:g} -> {cn:g}, "
                f"{rel*100:.0f}% drift)")
        if rel > tolerance:
            problems.append(f"WALL     {line} > {tolerance*100:.0f}% "
                            f"tolerance")
        else:
            info.append(line)
    return problems, info


def write_step_summary(ratio_problems: list[str], wall_problems: list[str],
                       n_ratios: int, tolerance: float,
                       wall_tolerance: float,
                       path: str | None = None) -> None:
    """Append a markdown verdict table to ``$GITHUB_STEP_SUMMARY`` (no-op
    outside GitHub Actions) so a glance at the job page separates hard
    ratio regressions from loosely-gated wall-clock noise."""
    path = path if path is not None else os.environ.get(
        "GITHUB_STEP_SUMMARY")
    if not path:
        return
    if ratio_problems:
        verdict = "❌ ratio regression"
    elif wall_problems:
        verdict = "⚠️ wall-clock drift only (machine noise?)"
    else:
        verdict = "✅ all gates green"
    lines = [
        "### bench_gate",
        "",
        f"**{verdict}** — {n_ratios} ratios checked at "
        f"{tolerance * 100:.0f}% tolerance, wall rows at "
        f"{wall_tolerance * 100:.0f}% machine-normalized tolerance.",
        "",
    ]
    if ratio_problems or wall_problems:
        lines += ["| gate | detail |", "|---|---|"]
        lines += [f"| strict (ratio) | `{p}` |" for p in ratio_problems]
        lines += [f"| loose (wall) | `{p}` |" for p in wall_problems]
        lines.append("")
    with open(path, "a") as f:
        f.write("\n".join(lines) + "\n")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--current", default=DEFAULT_CURRENT)
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="relative drift allowed per ratio (default 10%%)")
    ap.add_argument("--wall-tolerance", type=float, default=2.0,
                    help="relative drift allowed per machine-normalized "
                         "wall row (default 200%% — catches order-of-"
                         "magnitude regressions only)")
    ap.add_argument("--update", action="store_true",
                    help="bless the current report as the new baseline")
    args = ap.parse_args()

    if not os.path.exists(args.current):
        print(f"bench_gate: no report at {args.current}; run "
              f"`python -m benchmarks.run --smoke` first", file=sys.stderr)
        return 2
    if args.update:
        # bless the gated ratios plus the wall_clock reference (raw seconds
        # are machine-dependent, but the gate only ever reads them relative
        # to the same run's calibration row, which is blessed alongside)
        cur = load(args.current)
        blessed = {"ratios": cur.get("ratios", {}),
                   "wall_clock": cur.get("wall_clock", {})}
        with open(args.baseline, "w") as f:
            json.dump(blessed, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"bench_gate: blessed {args.current} -> {args.baseline} "
              f"({len(blessed['ratios'])} ratios, "
              f"{len(blessed['wall_clock'])} wall rows)")
        return 0
    if not os.path.exists(args.baseline):
        print(f"bench_gate: no baseline at {args.baseline}; bless one with "
              f"--update", file=sys.stderr)
        return 2

    baseline, current = load(args.baseline), load(args.current)
    ratio_problems = compare(baseline, current, args.tolerance)
    n = len(current.get("ratios", {}))
    wall_problems, wall_info = wall_compare(baseline, current,
                                            args.wall_tolerance)
    if wall_info:
        print(f"bench_gate: wall-clock ({len(wall_info)} rows within "
              f"{args.wall_tolerance*100:.0f}% machine-normalized "
              f"tolerance):")
        for w in wall_info:
            print(f"  {w}")
    write_step_summary(ratio_problems, wall_problems, n, args.tolerance,
                       args.wall_tolerance)
    if ratio_problems:
        print(f"bench_gate: FAIL ({len(ratio_problems + wall_problems)} "
              f"problem(s), {n} ratios checked at "
              f"{args.tolerance*100:.0f}% tolerance)")
        for p in ratio_problems + wall_problems:
            print(f"  {p}")
        return 1
    if wall_problems:
        # distinct exit code: every strict ratio is green, only the loose
        # machine-normalized wall gate tripped — probably machine noise
        print(f"bench_gate: WALL-DRIFT ({len(wall_problems)} wall row(s) "
              f"past {args.wall_tolerance*100:.0f}% tolerance; all {n} "
              f"ratios green)")
        for p in wall_problems:
            print(f"  {p}")
        return 3
    print(f"bench_gate: OK ({n} ratios within {args.tolerance*100:.0f}% of "
          f"baseline)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
