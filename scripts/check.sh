#!/usr/bin/env bash
# CI entrypoint: tier-1 test suite + the seconds-scale smoke sweep
# (FSDP-contention grid, the routed fabric sweep with its
# traffic-conservation / Insight-1 asserts capped at 512 hosts, and the
# multi-job contention scenario — the smoke subset stays well under 60 s).
# Runs fully offline (no hypothesis/zstandard required — see README).
#
#   scripts/check.sh             # everything
#   scripts/check.sh -k engine   # extra args are forwarded to pytest
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q "$@"
python -m benchmarks.run --smoke
