#!/usr/bin/env bash
# CI entrypoint: tier-1 test suite + the seconds-scale FSDP-contention smoke
# sweep. Runs fully offline (no hypothesis/zstandard required — see README).
#
#   scripts/check.sh             # everything
#   scripts/check.sh -k engine   # extra args are forwarded to pytest
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q "$@"
python -m benchmarks.run --smoke
