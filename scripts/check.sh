#!/usr/bin/env bash
# CI entrypoint — fully offline (no package index, no hypothesis/zstandard
# required; see README):
#
#   1. lint        ruff when installed, else the same ruleset via the
#                  offline fallback scripts/lint.py (kept in sync with
#                  pyproject.toml [tool.ruff.lint])
#   2. fast tests  pytest -m "not slow": the simulator/protocol/fabric core
#                  (< 1 min — the `slow` marker holds the jax model tier)
#   3. smoke bench seconds-scale paper-claim sweep; writes BENCH_smoke.json
#   4. bench gate  scripts/bench_gate.py diffs the smoke report's derived
#                  ratios against benchmarks/baseline_smoke.json
#
#   scripts/check.sh             # lint + fast tier + smoke + gate
#   scripts/check.sh -k engine   # extra args forwarded to pytest
#   RUN_SLOW=1 scripts/check.sh  # additionally run the slow (jax model) tier
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if command -v ruff >/dev/null 2>&1; then
    echo "== lint (ruff)"
    ruff check src tests benchmarks scripts examples
else
    echo "== lint (offline fallback: scripts/lint.py)"
    python scripts/lint.py
fi

echo "== schedule-IR guard"
# The Collective Schedule IR (core/sched_ir.py) owns ALL chain/round flow
# construction; the facades must never regrow their own. `_ChainState` was
# packet.py's pre-IR per-chain state — its reappearance (or any direct
# chain-state class) outside sched_ir.py means orchestration is being
# duplicated again.
if grep -n "_ChainState" src/repro/core/simulator.py src/repro/core/packet.py; then
    echo "ERROR: chain-construction state outside core/sched_ir.py —" \
         "build a Schedule and lower it via sched_ir.execute instead" >&2
    exit 1
fi

echo "== tests (fast tier)"
python -m pytest -x -q -m "not slow" --durations=15 --durations-min=1.0 "$@"

if [[ "${RUN_SLOW:-0}" != "0" ]]; then
    echo "== tests (slow tier: jax model/integration)"
    python -m pytest -x -q -m slow
fi

echo "== smoke benchmarks"
python -m benchmarks.run --smoke

echo "== benchmark regression gate"
# exit 3 = only loosely-gated wall-clock rows drifted (ratios all green) —
# machine noise, not a model regression: warn, don't fail the check
rc=0
python scripts/bench_gate.py || rc=$?
if [[ $rc -eq 3 ]]; then
    echo "WARNING: bench_gate wall-clock-only drift (exit 3); ratios green"
elif [[ $rc -ne 0 ]]; then
    exit "$rc"
fi
